"""Build and run experiments from a ``RunSpec`` — the ONE training loop.

Before this layer, ``launch/train.py``, ``benchmarks/bench_trainer.py``, and
every example carried its own copy of the jit'd round loop (key schedule,
communication accounting, logging, checkpointing) with slightly different
wiring. ``build(spec)`` assembles the experiment (method over the shared
round engine + task data + loss + corrupt_fn) and ``run(spec)`` drives it
with one canonical, fully seeded schedule:

    k_init, k_run = split(PRNGKey(spec.seed))
    params        = init_params(k_init)
    state         = method.init(params, anchor(0), k_run)
    per round it:   k_step, k_batch = split(fold_in(k_run, it + 1))
                    state, metrics = step(state, minibatch(it, k_batch),
                                          anchor(it), k_step)

so a trajectory is a pure function of the spec. ``tests/test_api_parity.py``
pins ``run(spec)`` bit-for-bit against the engine driven the PR-1 way
(hand-assembled config + ``make_method``) on fixed seeds for every method.
"""
from __future__ import annotations

import dataclasses
import json
import time
from typing import Any, Callable, Optional

import jax

from repro.core import tree_utils as tu
from repro.core.engine import Method, make_method


# ---------------------------------------------------------------------------
# experiment assembly
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Experiment:
    """A fully-assembled experiment: the method plus its data plumbing.

    ``minibatch(it, key)`` / ``anchor(it)`` return stacked (n, ...) pytrees;
    tasks that sample deterministically (TokenStream) ignore the key.
    """
    spec: Any                            # RunSpec
    cfg: Any                             # ByzVRMarinaConfig
    method: Method
    loss_fn: Callable
    corrupt_fn: Optional[Callable]
    init_params: Callable                # key -> params
    minibatch: Callable                  # (it, key) -> stacked batch
    anchor: Callable                     # it -> stacked anchor batch
    data: Any = None                     # LogRegData (logreg task)
    arch_cfg: Any = None                 # ArchConfig (lm task)

    def run(self, **run_kw) -> "RunResult":
        return _run_experiment(self, **run_kw)


def build(spec) -> Experiment:
    """Assemble (method, stream, loss_fn, corrupt_fn) for ``spec``."""
    cfg = spec.build_config()
    builder = _build_logreg if spec.task == "logreg" else _build_lm
    exp = builder(spec, cfg)
    if spec.agg_mode == "all_to_all":
        # the mesh/grad_specs extras are environment-derived (like "auto"),
        # so the spec stays serializable; rebuild the method over the
        # mesh-carrying config.
        exp.cfg = _attach_all_to_all_mesh(spec, exp)
        exp.method = make_method(spec.method, exp.cfg, exp.loss_fn,
                                 exp.corrupt_fn, **spec.method_kwargs)
    return exp


def _attach_all_to_all_mesh(spec, exp: Experiment):
    """agg_mode="all_to_all" shards the worker axis over real devices
    (shard_map; core/sharded_agg.py). Build a (n_workers, model) mesh from
    the visible devices and attach leaf-wise grad PartitionSpecs."""
    from jax.sharding import PartitionSpec as P
    from repro.launch.mesh import sanitize_specs

    n_dev = len(jax.devices())
    if n_dev % spec.n_workers:
        raise ValueError(
            f"agg_mode='all_to_all' needs the {spec.n_workers}-worker axis "
            f"sharded over devices, but {n_dev} device(s) are visible — run "
            "with XLA_FLAGS=--xla_force_host_platform_device_count="
            f"{spec.n_workers} (CPU) or on a pod, or use agg_mode='gspmd'")
    mesh = jax.make_mesh((spec.n_workers, n_dev // spec.n_workers),
                         ("data", "model"))
    params_abs = jax.eval_shape(exp.init_params, jax.random.PRNGKey(0))
    if exp.arch_cfg is not None:
        from repro.models import param_specs
        pspecs = sanitize_specs(mesh, params_abs, param_specs(exp.arch_cfg))
    else:
        pspecs = jax.tree.map(lambda _: P(), params_abs)
    return dataclasses.replace(exp.cfg, worker_axes=("data",),
                               model_axis="model", mesh=mesh,
                               grad_specs=pspecs)


def _build_logreg(spec, cfg) -> Experiment:
    from repro.data import (corrupt_labels_logreg, init_logreg_params,
                            logreg_loss, make_logreg_data)

    dk = spec.data_kwargs
    dim = int(dk.get("dim", 30))
    lam = float(dk.get("lam", 0.01))
    batch_size = int(dk.get("batch_size", 32))
    data = make_logreg_data(
        jax.random.PRNGKey(int(dk.get("data_seed", 0))),
        n_samples=int(dk.get("n_samples", 400)), dim=dim,
        n_workers=spec.n_workers,
        homogeneous=bool(dk.get("homogeneous", True)),
        noise=float(dk.get("noise", 0.1)))
    loss = logreg_loss(lam, nonconvex=bool(dk.get("nonconvex", False)))
    anchor = data.stacked()

    if dk.get("sampling", "uniform") == "importance":
        from repro.core import theory
        probs, _ = theory.importance_weights(data.features, lam)

        def minibatch(it, key):
            return data.sample_batches_importance(key, batch_size, probs)
    else:
        def minibatch(it, key):
            return data.sample_batches(key, batch_size)

    return Experiment(
        spec=spec, cfg=cfg,
        method=make_method(spec.method, cfg, loss, corrupt_labels_logreg,
                           **spec.method_kwargs),
        loss_fn=loss, corrupt_fn=corrupt_labels_logreg,
        init_params=lambda key: init_logreg_params(dim),
        minibatch=minibatch, anchor=lambda it: anchor, data=data)


def _build_lm(spec, cfg) -> Experiment:
    from repro.configs import get_config
    from repro.data import TokenStream, corrupt_labels_lm
    from repro.models import init_params as model_init
    from repro.models import loss_fn as model_loss

    dk = spec.data_kwargs
    acfg = get_config(spec.arch)
    if dk.get("reduced", False):
        acfg = acfg.reduced()
    stream = TokenStream(
        vocab_size=acfg.vocab_size, seq_len=int(dk.get("seq_len", 128)),
        n_workers=spec.n_workers,
        per_worker_batch=int(dk.get("per_worker_batch", 4)),
        num_codebooks=acfg.num_codebooks,
        frontend_tokens=acfg.frontend_tokens, d_model=acfg.d_model,
        heterogeneous=bool(dk.get("heterogeneous", False)), seed=spec.seed)
    remat = bool(dk.get("remat", False))

    def loss(params, batch, key):
        return model_loss(params, acfg, batch, remat=remat)

    return Experiment(
        spec=spec, cfg=cfg,
        method=make_method(spec.method, cfg, loss, corrupt_labels_lm,
                           **spec.method_kwargs),
        loss_fn=loss, corrupt_fn=corrupt_labels_lm,
        init_params=lambda key: model_init(key, acfg),
        minibatch=lambda it, key: stream.minibatch(it),
        anchor=stream.anchor, arch_cfg=acfg)


# ---------------------------------------------------------------------------
# the shared training loop
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RunResult:
    spec: Any
    history: list                        # logged metric dicts
    state: dict                          # final engine state
    n_params: int
    comm_bits: float                     # total uploaded bits per worker
    wall_s: float
    traces: list = dataclasses.field(default_factory=list)
    # host RoundTrace dicts, one per logged step (spec.trace runs only)

    @property
    def params(self):
        return self.state["params"]

    @property
    def final(self) -> dict:
        return self.history[-1] if self.history else {}

    def detection_summary(self, frac: float = 0.5) -> dict:
        """Mean filter precision/recall + byzantine influence leakage over
        the run's logged RoundTraces ({} without spec.trace)."""
        from repro.obs import detect
        return detect.summarize(self.traces, frac)

    def to_dict(self) -> dict:
        """Artifact payload: the resolved spec next to the trajectory, so a
        result file alone reproduces the run."""
        out = {"spec": self.spec.to_dict(), "n_params": self.n_params,
               "comm_bits": self.comm_bits, "wall_s": self.wall_s,
               "history": self.history}
        if self.traces:
            out["detection"] = self.detection_summary()
        return out


def run(spec, **run_kw) -> RunResult:
    """``build(spec)`` + the canonical loop. See module docstring for the
    key schedule; keyword options are the loop knobs that used to live in
    each driver separately:

      log_every    — record (and with verbose=True, print) every k-th step.
      verbose      — print per-log-step progress lines.
      warmup       — run one throwaway step first (compile) so wall_s is
                     steady-state; the trajectory is unchanged.
      checkpoint   — path prefix: save the FULL engine state (params +
                     estimator extras + step) via repro.checkpoint, at the
                     end of the run and every ``checkpoint_every`` steps.
      checkpoint_every — periodic checkpoint cadence in steps (needs
                     ``checkpoint``); the crash-restart point.
      resume       — checkpoint prefix to restart from: the engine state is
                     restored and the loop continues at the saved step with
                     the SAME key schedule, so an interrupted-and-resumed
                     run reproduces the uninterrupted trajectory exactly.
                     (history/comm_bits restart at the resume point — they
                     cover the resumed segment only.)
      metrics_out  — path: dump ``RunResult.to_dict()`` JSON (spec included).
      callback     — fn(it, state, logged_metrics) probe (e.g. a benchmark's
                     gap-vs-f*); a truthy return stops the run early
                     (rounds-to-target benchmarks).
      callback_every — callback cadence in steps (default: the log steps).
                     Metrics are float()-materialized (a device sync) only
                     on log/callback steps, so a frequent probe doesn't
                     force per-step syncs via log_every=1.
      sink         — repro.obs.sink.MetricSink: every logged round is also
                     emitted as a {"type": "round"} event, traced rounds as
                     {"type": "trace"} (spec.trace), and the run itself as a
                     {"type": "span", "name": "run"}.
      metrics_jsonl — path: shorthand for (and fan-out with) a JsonlSink.
    """
    return _run_experiment(build(spec), **run_kw)


def _run_experiment(exp: Experiment, *, log_every: int = 10,
                    verbose: bool = False, warmup: bool = False,
                    checkpoint: Optional[str] = None,
                    checkpoint_every: Optional[int] = None,
                    resume: Optional[str] = None,
                    metrics_out: Optional[str] = None,
                    callback: Optional[Callable] = None,
                    callback_every: Optional[int] = None,
                    sink=None,
                    metrics_jsonl: Optional[str] = None) -> RunResult:
    spec = exp.spec
    own_jsonl = None
    if metrics_jsonl:
        from repro.obs.sink import FanoutSink, JsonlSink
        own_jsonl = JsonlSink(metrics_jsonl)
        sink = FanoutSink(sink, own_jsonl) if sink is not None else own_jsonl
    key = jax.random.PRNGKey(spec.seed)
    k_init, k_run = jax.random.split(key)
    params = exp.init_params(k_init)
    n_params = int(tu.tree_size(params))
    state = exp.method.init(params, exp.anchor(0), k_run)
    start = 0
    if resume:
        from repro.checkpoint import load_checkpoint
        state, ck_step = load_checkpoint(resume, like=state)
        start = int(ck_step or 0)
        if verbose:
            print(f"[run] resumed from {resume}.npz at step {start}")
    step = jax.jit(exp.method.step)
    step_traced = None
    if spec.trace:
        from repro.obs import detect as obs_detect
        from repro.obs import trace as obs_trace
        step_traced = jax.jit(exp.method.step_traced)

    if warmup and spec.steps > 0:
        k_step, k_batch = jax.random.split(jax.random.fold_in(k_run, 1))
        wargs = (state, exp.minibatch(0, k_batch), exp.anchor(0), k_step)
        thrown, _ = step(*wargs)
        if step_traced is not None:      # compile the telemetry twin too,
            thrown, _ = step_traced(*wargs)   # so log steps never compile
        jax.block_until_ready(thrown["g"])
        del thrown, wargs

    if checkpoint:
        from repro.checkpoint import save_checkpoint

    history = []
    traces: list = []
    comm_bits_total = 0.0
    # partial participation: only the sampled cohort uploads, so the
    # per-configured-worker average is scaled by n_active/n_workers — the
    # measured twin of theory.comm_bits_per_round(..., participation=...)
    # (pinned by the conformance harness)
    part_frac = spec.resolved_participation() / spec.n_workers
    pending_ck = []          # device arrays; synced only on log steps so the
    t0 = time.time()         # loop keeps JAX's async dispatch pipelined
    for it in range(start, spec.steps):
        k_step, k_batch = jax.random.split(jax.random.fold_in(k_run, it + 1))
        last = it == spec.steps - 1
        do_log = it % max(log_every, 1) == 0 or last
        do_cb = callback is not None and (
            (it + 1) % max(callback_every, 1) == 0 or last
            if callback_every is not None else do_log)
        # the telemetry twin runs only at log cadence (bit-identical
        # trajectory, pinned by tests/test_obs.py), so the off-cadence hot
        # path stays the untraced jaxpr
        fn = step_traced if (step_traced is not None
                             and (do_log or do_cb)) else step
        state, metrics = fn(state, exp.minibatch(it, k_batch),
                            exp.anchor(it), k_step)
        rt = metrics.pop("trace", None) if spec.trace else None
        pending_ck.append(metrics.get("c_k"))
        if do_log or do_cb:
            for ck in pending_ck:
                comm_bits_total += part_frac * exp.method.round_bits(
                    n_params, True if ck is None else bool(ck))
            pending_ck.clear()
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = it
            m["wall_s"] = round(time.time() - t0, 2)
            m["comm_bits"] = comm_bits_total
            m["comm_gbits"] = round(comm_bits_total / 1e9, 4)
            trace_host = None
            if rt is not None:
                # the only extra sync is here, at log cadence, where the
                # float() materialization above already fenced the device
                trace_host = obs_trace.to_host(rt)
                det = obs_detect.detection_metrics(trace_host)
                m["detect_precision"] = det["precision"]
                m["detect_recall"] = det["recall"]
                m["byz_leakage"] = det["byz_leakage"]
                m["n_filtered"] = det["n_filtered"]
                fm = obs_detect.fault_metrics(trace_host)
                if fm:                 # chaos rounds: guard-vs-injected
                    m["fault_precision"] = fm["fault_precision"]
                    m["fault_recall"] = fm["fault_recall"]
                    m["n_fault_rejected"] = fm["n_rejected"]
            if do_log:
                history.append(m)
                if trace_host is not None:
                    traces.append(trace_host)
                if sink is not None:
                    sink.emit({"type": "round", **m})
                    if trace_host is not None:
                        sink.emit({"type": "trace", "step": it,
                                   **trace_host})
            if verbose and do_log:
                ck = f" c_k={int(m['c_k'])}" if "c_k" in m else ""
                print(f"  step {it:5d} loss {m['loss']:.4f} "
                      f"|g| {m['g_norm']:.3e}{ck} "
                      f"comm {m['comm_gbits']:.3g}Gb ({m['wall_s']}s)")
            if do_cb and callback(it, state, m):
                if not do_log:           # record the stop point
                    history.append(m)
                break                    # callback asked for early stop
        if (checkpoint and checkpoint_every
                and (it + 1) % checkpoint_every == 0 and not last):
            save_checkpoint(checkpoint, state, step=int(state["step"]))
            if verbose:
                print(f"[run] checkpoint @ step {it + 1} -> "
                      f"{checkpoint}.npz")
    jax.block_until_ready(state["g"])
    result = RunResult(spec=spec, history=history, state=state,
                       n_params=n_params, comm_bits=comm_bits_total,
                       wall_s=time.time() - t0, traces=traces)
    if sink is not None:
        sink.emit({"type": "span", "name": "run",
                   "wall_s": round(result.wall_s, 6),
                   "steps": spec.steps - start})
        if traces:
            sink.emit({"type": "gauge", "name": "detection_summary",
                       "value": result.detection_summary()})
        if own_jsonl is not None:
            own_jsonl.close()

    if checkpoint:
        # the FULL engine state (params + estimator extras + step), so a
        # later run(..., resume=checkpoint) restarts the exact trajectory
        save_checkpoint(checkpoint, state, step=int(state["step"]))
        if verbose:
            print(f"[run] checkpoint -> {checkpoint}.npz")
    if metrics_out:
        with open(metrics_out, "w") as f:
            json.dump(result.to_dict(), f, indent=1)
    return result
