"""Baseline estimators (BR-SGDm, CSGD, BR-DIANA, Byrd-SVRG) sanity tests."""
import jax
import jax.numpy as jnp
import pytest

from repro.core import (ByzVRMarinaConfig, get_aggregator, get_attack,
                        get_compressor)
from repro.core.baselines import (make_byrd_svrg_step, make_csgd_step,
                                  make_diana_step, make_sgd_step)
from repro.data import (corrupt_labels_logreg, init_logreg_params,
                        logreg_loss, make_logreg_data)

KEY = jax.random.PRNGKey(0)
DIM = 15


@pytest.fixture(scope="module")
def problem():
    data = make_logreg_data(KEY, n_samples=300, dim=DIM, n_workers=5,
                            homogeneous=True)
    return data, logreg_loss(0.01), {"x": data.features, "y": data.labels}


def _descends(problem, init_state, step, iters=150):
    data, loss_fn, full = problem
    anchor = data.stacked()
    l0 = float(loss_fn(init_state["params"], full))
    state = init_state
    k = KEY
    step = jax.jit(step)
    for it in range(iters):
        k, k1, k2 = jax.random.split(k, 3)
        state, m = step(state, data.sample_batches(k1, 16), anchor, k2)
        assert jnp.isfinite(m["loss"])
    l1 = float(loss_fn(state["params"], full))
    assert l1 < l0 - 0.02, (l0, l1)
    return l1


def _cfg(**kw):
    base = dict(n_workers=5, n_byz=1, lr=0.3, p=0.1,
                aggregator=get_aggregator("cm", bucket_size=2),
                attack=get_attack("ALIE"))
    base.update(kw)
    return ByzVRMarinaConfig(**base)


def test_parallel_sgd(problem):
    data, loss_fn, _ = problem
    cfg = _cfg(n_byz=0, attack=get_attack("NA"),
               aggregator=get_aggregator("mean"))
    init, step = make_sgd_step(cfg, loss_fn, corrupt_labels_logreg)
    _descends(problem, init(init_logreg_params(DIM)), step)


def test_br_sgdm(problem):
    data, loss_fn, _ = problem
    cfg = _cfg()
    init, step = make_sgd_step(cfg, loss_fn, corrupt_labels_logreg,
                               momentum=0.9)
    _descends(problem, init(init_logreg_params(DIM)), step)


def test_br_csgd(problem):
    data, loss_fn, _ = problem
    cfg = _cfg(compressor=get_compressor("randk", ratio=0.2))
    init, step = make_csgd_step(cfg, loss_fn, corrupt_labels_logreg)
    _descends(problem, init(init_logreg_params(DIM)), step)


def test_br_diana(problem):
    data, loss_fn, _ = problem
    cfg = _cfg(compressor=get_compressor("randk", ratio=0.2), lr=0.2)
    init, step = make_diana_step(cfg, loss_fn, corrupt_labels_logreg)
    _descends(problem, init(init_logreg_params(DIM), d_hint=DIM + 1), step)


def test_byrd_svrg(problem):
    data, loss_fn, _ = problem
    cfg = _cfg(aggregator=get_aggregator("rfa", bucket_size=2))
    init, step = make_byrd_svrg_step(cfg, loss_fn, corrupt_labels_logreg)
    state = jax.jit(init)(init_logreg_params(DIM), data.stacked(), KEY)
    _descends(problem, state, step)
