"""Pallas TPU kernels for the norm-based aggregation rules (RFA / Krum) and
the zero-copy bucket/attack machinery shared with the coordinate kernels.

The jnp tree path (core/aggregators.py, kept as the parity oracle) re-sweeps
the full (n, d) worker stack many times per call: RFA's smoothed Weiszfeld
materializes an (n, d) diff tensor per iteration (distance pass) plus a
weighted-sum pass, and Krum's pairwise Gram adds bucketize/gram/weighted-sum
passes. These kernels bring every rule to the roofline floor of
read(n·d) + write(d) HBM traffic *per pass*:

* ``pair_gram``     — one sweep: streams (n, TILE_D) blocks and accumulates
                      the (m, m) Gram matrix in the revisited output block
                      (VMEM); the (m, m) pairwise-distance matrix (Krum
                      scoring) is sq[i]+sq[j]-2G with sq = diag(G).
* ``rfa_iter``      — one fused Weiszfeld pass: z = wᵀ·xb and the squared
                      distances ||xb_i - z||² accumulate in the SAME sweep,
                      so T smoothed-Weiszfeld iterations + the final
                      weighted sum cost T+1 sweeps total (≤ 2 per iteration)
                      instead of the jnp path's ~4 per iteration.
* ``weighted_sum``  — one sweep: Σ_i w_i · sent_i (Krum winner extraction,
                      RFA finalization; bucketing rides in the weights).

Zero-copy message phase: the Alg. 2 bucketing permutation never touches HBM
— it is carried on-chip as the tiny (nb, n) linear operator
``bucket_matrix(perm)`` (W @ x ≡ ``aggregators._bucketize_perm(x, perm)``,
stacked-mean padding of a partial last bucket included) and applied to each
(n, TILE_D) block in VMEM on the MXU. A one-hot matmul is the TPU idiom for
a sublane gather: dynamically-indexed row gathers don't vectorize on the
VPU, W rides in VMEM like SMEM-prefetched indices would, and n ≤ 64 makes
the (nb, n)·(n, TILE_D) product negligible next to the HBM stream.
Omniscient-attack injection is fused the same way: the byzantine mask
((n, 1)) and the good workers' per-coordinate mean/std (tiled like x) enter
the kernel and ``attack.coord_apply`` runs on the block in VMEM, so the
attacked ``sent`` tensor is never written to HBM either.

Grid layout matches robust_agg.py: worker axis in sublanes (n ≤ 64), TILE_D
lane-aligned, sequential 1-D grid over d so revisited output blocks
(constant index map) accumulate in VMEM across grid steps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.backend import resolve_interpret
from repro.kernels import quantize


DEFAULT_TILE_D = 2048     # (64 workers x 2048 lanes x 4B = 512 KiB in VMEM)

# the fused kernels keep the WHOLE worker axis resident in sublanes, which
# caps them at MAX_FUSED_WORKERS; callers route larger stacks to the blocked
# kernels below (worker axis tiled too — DESIGN.md §7). One threshold shared
# with the jnp oracle's blocked-Gram dispatch (core/aggregators.py, which
# imports nothing from repro — no cycle).
from repro.core.aggregators import MAX_FUSED_WORKERS  # noqa: E402

DEFAULT_TILE_N = 64       # worker tile of the blocked kernels


# ---------------------------------------------------------------------------
# bucketing as a linear operator (the in-kernel permutation)
# ---------------------------------------------------------------------------

def bucket_matrix(perm, n: int, s: int):
    """(nb, n) fp32 W with W @ x == ``aggregators._bucketize_perm(x, perm, s)``
    (Alg. 2): W[b, j] = (#{i in bucket b : perm[i] == j} + pad_b / n) / s,
    where the partial last bucket's ``pad_b`` rows are the stacked mean
    (= (1/n) Σ_j x_j, permutation-invariant)."""
    nb = -(-n // s)
    pad = nb * s - n
    onehot = jax.nn.one_hot(perm, n, dtype=jnp.float32)        # (n, n)
    member = jax.nn.one_hot(jnp.arange(n) // s, nb,
                            dtype=jnp.float32)                 # (n, nb)
    w = member.T @ onehot                                      # (nb, n)
    if pad:
        w = w.at[nb - 1, :].add(pad / n)
    return w / s


# ---------------------------------------------------------------------------
# shared block machinery: input assembly + in-VMEM attack/bucket prologue
# ---------------------------------------------------------------------------

def _tile_for(d: int, tile_d: int) -> int:
    """Lane-aligned tile; shrink for small d so tiny leaves stay one block."""
    return min(tile_d, max(128, -(-d // 128) * 128))


def _pad_cols(a, dp):
    """Zero-pad the trailing columns. Zero is attack/bucket-neutral: every
    coord_apply maps 0-stat/0-value pad columns to 0, W @ 0 = 0, and zero
    columns contribute nothing to Gram or squared-distance accumulators."""
    pad = dp - a.shape[-1]
    if pad:
        a = jnp.pad(a, ((0, 0),) * (a.ndim - 1) + ((0, pad),))
    return a


def src_dims(x):
    """(n, d) of a kernel input — dense (n, d) array or quantize.WireSrc."""
    if isinstance(x, quantize.WireSrc):
        return x.n, x.d
    return x.shape


def _assemble(x, w_mat, mask, good_mean, good_std, tile_d, valid=None):
    """Build (vals, in_specs, names, grid, dp, wire) for the optional-input
    kernels.

    x is either the dense (n, d) stack — riding as (n, tile) blocks over a
    1-D grid — or a ``quantize.WireSrc`` whose payload arrays ride instead
    (the dense candidate matrix then never exists in HBM; the kernels
    reconstruct per block via ``_prologue``). w_mat (nb, n), mask (n, 1) and
    the RFA weights are tiny constant blocks revisited every step; mean/std
    are (1, tile) blocks tiled like x. ``valid`` (fault guard, DESIGN.md §6)
    is the (n,) row-validity mask riding like ``mask``; ``_prologue``
    select-zeroes invalid rows in VMEM so a NaN/inf row never reaches the
    bucket matmul or the rule.
    """
    n, d = src_dims(x)
    wire = None
    if isinstance(x, quantize.WireSrc):
        tile = quantize.wire_tile(x, tile_d)
        dp = -(-d // tile) * tile
        vals, specs, names, wire = quantize.wire_inputs(x, tile, dp)
    else:
        tile = _tile_for(d, tile_d)
        dp = -(-d // tile) * tile
        vals = [_pad_cols(x, dp)]
        specs = [pl.BlockSpec((n, tile), lambda i: (0, i))]
        names = ["x"]
    if w_mat is not None:
        vals.append(w_mat)
        specs.append(pl.BlockSpec(w_mat.shape, lambda i: (0, 0)))
        names.append("w_mat")
    if mask is not None:
        vals.append(mask.reshape(n, 1).astype(jnp.float32))
        specs.append(pl.BlockSpec((n, 1), lambda i: (0, 0)))
        names.append("mask")
    if valid is not None:
        vals.append(valid.reshape(n, 1).astype(jnp.float32))
        specs.append(pl.BlockSpec((n, 1), lambda i: (0, 0)))
        names.append("valid")
    for nm, stat in (("mean", good_mean), ("std", good_std)):
        if stat is not None:
            vals.append(_pad_cols(stat.reshape(1, d).astype(jnp.float32), dp))
            specs.append(pl.BlockSpec((1, tile), lambda i: (0, i)))
            names.append(nm)
    return vals, specs, names, (dp // tile,), dp, wire


def _prologue(env, attack_fn, wire=None):
    """sent = attack(x) on the block in VMEM, then xb = W @ sent (MXU).

    With ``wire`` (a quantize.WireMeta), x is first RECONSTRUCTED on-chip
    from the payload blocks (``quantize.recon_block``: decode + base add,
    candidate-dtype faithful) — the corrupt→compress→reconstruct→attack→
    bucket→aggregate chain then runs in one VMEM residency.

    The attacked values round-trip through the candidate dtype before the
    fp32 select, matching ``apply_attack``'s ``.astype(h.dtype)`` exactly —
    a bf16 candidate tree sees the same bf16-quantized malicious vectors
    whether the attack is fused or materialized.
    """
    if wire is None:
        raw = env["x"][...]
        x = raw.astype(jnp.float32)
        cand_dtype = raw.dtype
    else:
        x = quantize.recon_block(env, wire)
        cand_dtype = wire.cand_dtype
    if attack_fn is not None and "mask" in env:
        mu = env["mean"][...] if "mean" in env else None
        sd = env["std"][...] if "std" in env else None
        v = attack_fn(x, mu, sd).astype(cand_dtype).astype(jnp.float32)
        x = jnp.where(env["mask"][...] > 0.0, v, x)
    if "valid" in env:
        # fault guard (DESIGN.md §6): select-zero invalid rows — NEVER
        # multiply (0·NaN = NaN) — before the bucket matmul, so a
        # non-finite worker row cannot reach any accumulator.
        x = jnp.where(env["valid"][...] > 0.0, x, 0.0)
    if "w_mat" in env:
        x = jnp.dot(env["w_mat"][...], x, preferred_element_type=jnp.float32)
    return x


# ---------------------------------------------------------------------------
# kernels
# ---------------------------------------------------------------------------

@functools.partial(jax.jit,
                   static_argnames=("attack_fn", "tile_d", "interpret"))
def pair_gram(x, w_mat=None, mask=None, good_mean=None, good_std=None,
              valid=None, *, attack_fn=None, tile_d: int = DEFAULT_TILE_D,
              interpret=None):
    """One-HBM-sweep (m, m) Gram matrix of the (attacked, bucketed) worker
    stack; m = nb when ``w_mat`` is given else n. Krum's pairwise squared
    distances are d²[i,j] = G[i,i] + G[j,j] - 2 G[i,j]."""
    n, d = src_dims(x)
    m = w_mat.shape[0] if w_mat is not None else n
    vals, specs, names, grid, dp, wire = _assemble(x, w_mat, mask, good_mean,
                                                   good_std, tile_d,
                                                   valid=valid)

    def kernel(*refs):
        env = dict(zip(names, refs[:-1]))
        o_ref = refs[-1]
        xb = _prologue(env, attack_fn, wire)

        @pl.when(pl.program_id(0) == 0)
        def _():
            o_ref[...] = jnp.zeros_like(o_ref)

        o_ref[...] += jnp.dot(xb, xb.T, preferred_element_type=jnp.float32)

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=specs,
        out_specs=pl.BlockSpec((m, m), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((m, m), jnp.float32),
        interpret=resolve_interpret(interpret),
    )(*vals)


@functools.partial(jax.jit,
                   static_argnames=("attack_fn", "tile_d", "interpret"))
def rfa_iter(x, w, w_mat=None, mask=None, good_mean=None, good_std=None,
             valid=None, *, attack_fn=None, tile_d: int = DEFAULT_TILE_D,
             interpret=None):
    """One fused smoothed-Weiszfeld pass in ONE sweep of x:
    z = Σ_b w_b · xb_b written tile-wise, and sq_b = ||xb_b - z||² accumulated
    in the revisited (m, 1) output block. Returns (z (d,), sq (m,)) fp32."""
    n, d = src_dims(x)
    m = w_mat.shape[0] if w_mat is not None else n
    vals, specs, names, grid, dp, wire = _assemble(x, w_mat, mask, good_mean,
                                                   good_std, tile_d,
                                                   valid=valid)
    tile = dp // grid[0]
    vals.append(w.reshape(m, 1).astype(jnp.float32))
    specs.append(pl.BlockSpec((m, 1), lambda i: (0, 0)))
    names.append("w")

    def kernel(*refs):
        env = dict(zip(names, refs[:-2]))
        z_ref, sq_ref = refs[-2], refs[-1]
        xb = _prologue(env, attack_fn, wire)
        z = jnp.sum(xb * env["w"][...], axis=0, keepdims=True)   # (1, tile)
        z_ref[...] = z
        diff = xb - z

        @pl.when(pl.program_id(0) == 0)
        def _():
            sq_ref[...] = jnp.zeros_like(sq_ref)

        sq_ref[...] += jnp.sum(diff * diff, axis=1, keepdims=True)

    z, sq = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=specs,
        out_specs=(pl.BlockSpec((1, tile), lambda i: (0, i)),
                   pl.BlockSpec((m, 1), lambda i: (0, 0))),
        out_shape=(jax.ShapeDtypeStruct((1, dp), jnp.float32),
                   jax.ShapeDtypeStruct((m, 1), jnp.float32)),
        interpret=resolve_interpret(interpret),
    )(*vals)
    return z[0, :d], sq[:, 0]


@functools.partial(jax.jit,
                   static_argnames=("attack_fn", "tile_d", "interpret"))
def weighted_sum(x, w, mask=None, good_mean=None, good_std=None, valid=None,
                 *, attack_fn=None, tile_d: int = DEFAULT_TILE_D,
                 interpret=None):
    """z = Σ_i w_i · sent_i in one sweep. Bucketing rides in the weights
    (w_eff = Wᵀ · w_bucket), so no bucketed matrix is ever formed."""
    n, d = src_dims(x)
    vals, specs, names, grid, dp, wire = _assemble(x, None, mask, good_mean,
                                                   good_std, tile_d,
                                                   valid=valid)
    tile = dp // grid[0]
    vals.append(w.reshape(n, 1).astype(jnp.float32))
    specs.append(pl.BlockSpec((n, 1), lambda i: (0, 0)))
    names.append("w")

    def kernel(*refs):
        env = dict(zip(names, refs[:-1]))
        o_ref = refs[-1]
        sent = _prologue(env, attack_fn, wire)
        o_ref[...] = jnp.sum(sent * env["w"][...], axis=0, keepdims=True)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=specs,
        out_specs=pl.BlockSpec((1, tile), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((1, dp), jnp.float32),
        interpret=resolve_interpret(interpret),
    )(*vals)
    return out[0, :d]


# ---------------------------------------------------------------------------
# rule drivers over segment lists (one logical (n, Σd_j) stack, leaf-wise)
# ---------------------------------------------------------------------------
#
# A "segment" is one (n, d_j) 2-D view of the stacked candidate pytree — a
# large leaf, or the packed buffer of many tiny leaves (core/sharded_agg.py).
# Global distances sum tiny per-segment accumulators; no concatenated
# (n, D) matrix is ever built.

def rfa_segments(segs, *, w_mat=None, mask=None, means=None, stds=None,
                 attack_fn=None, iters: int = 8, eps: float = 1e-8,
                 tile_d: int = DEFAULT_TILE_D, interpret=None,
                 return_info: bool = False, valid=None, bvalid=None):
    """Smoothed Weiszfeld (Pillutla et al. 2022) with global distances across
    segments; semantics of ``Aggregator._rfa_tree``. T+1 sweeps total: the
    t-th fused pass computes z_t = w_tᵀ·xb AND the distances to z_t; uniform
    w_0 makes z_0 the (bucketed) mean, and the final weighted sum realizes
    z_T. Returns the list of per-segment (d_j,) fp32 aggregates.

    ``valid`` / ``bvalid`` (fault guard, DESIGN.md §6): worker-level rows
    are select-zeroed in the kernel prologue, and the Weiszfeld weights of
    invalid (bucketed) rows are pinned to zero every iteration — the rule's
    twin of ``Aggregator._rfa_masked``.

    ``return_info`` (repro.obs telemetry) additionally returns the rule's own
    intermediates ``{"bucket_weights": w_T, "rfa_sq": ||xb - z_T||²}`` — the
    final Weiszfeld weights and, via ONE extra fused pass, the squared
    distances of the (bucketed) rows to the output. The aggregate itself is
    computed by the identical calls either way."""
    n = src_dims(segs[0])[0]
    m = w_mat.shape[0] if w_mat is not None else n
    means = means if means is not None else [None] * len(segs)
    stds = stds if stds is not None else [None] * len(segs)
    if bvalid is not None:
        bv = bvalid.astype(jnp.float32)
        w = bv / jnp.maximum(jnp.sum(bv), 1.0)
    else:
        w = jnp.full((m,), 1.0 / m, jnp.float32)
    for _ in range(iters):
        sq = sum(rfa_iter(xs, w, w_mat, mask, mu, sd, valid,
                          attack_fn=attack_fn, tile_d=tile_d,
                          interpret=interpret)[1]
                 for xs, mu, sd in zip(segs, means, stds))
        w = 1.0 / jnp.sqrt(sq + eps)
        if bvalid is not None:
            w = jnp.where(bvalid, w, 0.0)
        w = w / jnp.maximum(jnp.sum(w), 1e-30)
    w_eff = w if w_mat is None else w @ w_mat
    outs = [weighted_sum(xs, w_eff, mask, mu, sd, valid,
                         attack_fn=attack_fn, tile_d=tile_d,
                         interpret=interpret)
            for xs, mu, sd in zip(segs, means, stds)]
    if not return_info:
        return outs
    sq_t = sum(rfa_iter(xs, w, w_mat, mask, mu, sd, valid,
                        attack_fn=attack_fn, tile_d=tile_d,
                        interpret=interpret)[1]
               for xs, mu, sd in zip(segs, means, stds))
    return outs, {"bucket_weights": w, "rfa_sq": sq_t}


def krum_select(g, n_byz: int, bvalid=None):
    """Krum scoring (Eq. 15) from an (m, m) Gram matrix — the tiny O(m²)
    jnp step between the two kernel sweeps, shared by the fused and blocked
    drivers. Returns ``(onehot, scores, best)``: the winner's selection
    one-hot over the (bucketed) rows, the per-row scores, and the argmin.

    ``bvalid`` (fault guard): invalid rows/cols leave the distance pool, the
    neighbour count tracks the valid count, and an invalid row can never be
    selected — ``Aggregator._krum_masked``'s twin."""
    m = g.shape[0]
    sq = jnp.diag(g)
    d2 = jnp.maximum(sq[:, None] + sq[None, :] - 2.0 * g, 0.0)
    d2 = d2 + jnp.diag(jnp.full((m,), jnp.inf, d2.dtype))
    if bvalid is not None:
        pair_ok = bvalid[:, None] & bvalid[None, :]
        d2 = jnp.where(pair_ok, d2, jnp.inf)
        c = jnp.sum(bvalid.astype(jnp.int32))
        kv = jnp.maximum(c - n_byz - 2, 1)
        near = jnp.arange(m)[None, :] < kv
        srt = jnp.sort(d2, axis=1)
        scores = jnp.sum(jnp.where(near, srt, 0.0), axis=1)
        scores = jnp.where(bvalid, scores, jnp.inf)
    else:
        k = max(m - n_byz - 2, 1)
        scores = jnp.sum(jnp.sort(d2, axis=1)[:, :k], axis=1)
    best = jnp.argmin(scores)
    onehot = jax.nn.one_hot(best, m, dtype=jnp.float32)
    return onehot, scores, best


def krum_segments(segs, *, w_mat=None, mask=None, means=None, stds=None,
                  attack_fn=None, n_byz: int = 1,
                  tile_d: int = DEFAULT_TILE_D, interpret=None,
                  return_info: bool = False, valid=None, bvalid=None):
    """Krum (Eq. 15) in 2 sweeps: one Gram pass (global pairwise distances),
    tiny O(m²) scoring in jnp, one weighted-sum pass extracting the winner
    (through Wᵀ when bucketed). Semantics of ``Aggregator._krum_tree``.

    ``return_info`` (repro.obs telemetry) additionally returns
    ``{"bucket_weights": onehot, "krum_scores": scores, "krum_selected":
    argmin}`` — the scoring intermediates this driver computes anyway between
    the two sweeps; the aggregate is the identical calls either way."""
    means = means if means is not None else [None] * len(segs)
    stds = stds if stds is not None else [None] * len(segs)
    g = sum(pair_gram(xs, w_mat, mask, mu, sd, valid, attack_fn=attack_fn,
                      tile_d=tile_d, interpret=interpret)
            for xs, mu, sd in zip(segs, means, stds))
    onehot, scores, best = krum_select(g, n_byz, bvalid)
    w_eff = onehot if w_mat is None else onehot @ w_mat
    outs = [weighted_sum(xs, w_eff, mask, mu, sd, valid,
                         attack_fn=attack_fn, tile_d=tile_d,
                         interpret=interpret)
            for xs, mu, sd in zip(segs, means, stds)]
    if not return_info:
        return outs
    return outs, {"bucket_weights": onehot, "krum_scores": scores,
                  "krum_selected": best}


# ---------------------------------------------------------------------------
# blocked kernels (giant n — worker axis tiled too; DESIGN.md §7)
# ---------------------------------------------------------------------------
#
# Above MAX_FUSED_WORKERS the fused layout (whole worker axis in sublanes)
# no longer holds. The blocked twins tile the worker axis as well: no VMEM
# block ever holds more than (TILE_N, TILE_D) of the stack, and no kernel
# materializes anything that scales like n² · d — the Gram matrix
# accumulates (TILE_N, TILE_N) output blocks over a d-fastest grid.
#
# Inputs here are DENSE fp32 stacks with attack / guard select-zero /
# bucketing already materialized (core/sharded_agg.py runs the jnp prologue
# for this tier — the zero-copy fusion is a ≤64-worker luxury, traded for
# unbounded n). Zero-padded worker rows carry zero weight (weighted sums),
# are sliced away (Gram / distances), or both — always neutral.

def _pad_rows(a, mp):
    """Zero-pad the leading (worker) axis to ``mp`` rows."""
    pad = mp - a.shape[0]
    if pad:
        a = jnp.pad(a, ((0, pad),) + ((0, 0),) * (a.ndim - 1))
    return a


def _tile_n_for(m: int, tile_n: int) -> int:
    """Sublane-aligned worker tile; shrink for small m (one block)."""
    return min(tile_n, max(8, -(-m // 8) * 8))


@functools.partial(jax.jit,
                   static_argnames=("tile_n", "tile_d", "interpret"))
def pair_gram_blocked(x, *, tile_n: int = DEFAULT_TILE_N,
                      tile_d: int = DEFAULT_TILE_D, interpret=None):
    """(m, m) Gram of a dense (m, d) stack with BOTH axes tiled: grid
    (mi, mj, dk), d fastest, so each (tile_n, tile_n) output block
    accumulates its d-sweep in VMEM. Peak VMEM is 2·(tile_n, tile_d) input
    blocks + one (tile_n, tile_n) accumulator, independent of m and d."""
    m, d = x.shape
    tile = _tile_for(d, tile_d)
    dp = -(-d // tile) * tile
    tn = _tile_n_for(m, tile_n)
    mp = -(-m // tn) * tn
    xp = _pad_rows(_pad_cols(x.astype(jnp.float32), dp), mp)

    def kernel(a_ref, b_ref, o_ref):
        @pl.when(pl.program_id(2) == 0)
        def _():
            o_ref[...] = jnp.zeros_like(o_ref)

        o_ref[...] += jnp.dot(a_ref[...], b_ref[...].T,
                              preferred_element_type=jnp.float32)

    g = pl.pallas_call(
        kernel,
        grid=(mp // tn, mp // tn, dp // tile),
        in_specs=[pl.BlockSpec((tn, tile), lambda i, j, k: (i, k)),
                  pl.BlockSpec((tn, tile), lambda i, j, k: (j, k))],
        out_specs=pl.BlockSpec((tn, tn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, mp), jnp.float32),
        interpret=resolve_interpret(interpret),
    )(xp, xp)
    return g[:m, :m]


@functools.partial(jax.jit,
                   static_argnames=("tile_n", "tile_d", "interpret"))
def sqdist_to_blocked(x, z, *, tile_n: int = DEFAULT_TILE_N,
                      tile_d: int = DEFAULT_TILE_D, interpret=None):
    """(m,) squared distances ||x_i − z||² of a dense (m, d) stack to z
    (d,), worker axis tiled: grid (mi, dk), d fastest, each (tile_n, 1)
    output block accumulating its d-sweep in VMEM."""
    m, d = x.shape
    tile = _tile_for(d, tile_d)
    dp = -(-d // tile) * tile
    tn = _tile_n_for(m, tile_n)
    mp = -(-m // tn) * tn
    xp = _pad_rows(_pad_cols(x.astype(jnp.float32), dp), mp)
    zp = _pad_cols(z.reshape(1, d).astype(jnp.float32), dp)

    def kernel(x_ref, z_ref, o_ref):
        @pl.when(pl.program_id(1) == 0)
        def _():
            o_ref[...] = jnp.zeros_like(o_ref)

        diff = x_ref[...] - z_ref[...]
        o_ref[...] += jnp.sum(diff * diff, axis=1, keepdims=True)

    sq = pl.pallas_call(
        kernel,
        grid=(mp // tn, dp // tile),
        in_specs=[pl.BlockSpec((tn, tile), lambda i, k: (i, k)),
                  pl.BlockSpec((1, tile), lambda i, k: (0, k))],
        out_specs=pl.BlockSpec((tn, 1), lambda i, k: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((mp, 1), jnp.float32),
        interpret=resolve_interpret(interpret),
    )(xp, zp)
    return sq[:m, 0]


@functools.partial(jax.jit,
                   static_argnames=("tile_n", "tile_d", "interpret"))
def weighted_sum_blocked(x, w, *, tile_n: int = DEFAULT_TILE_N,
                         tile_d: int = DEFAULT_TILE_D, interpret=None):
    """z = Σ_i w_i · x_i over a dense (m, d) stack, worker axis tiled:
    grid (dk, mi), WORKER tiles fastest, so each (1, tile_d) output block
    accumulates its worker sweep in VMEM. Padded rows get weight 0."""
    m, d = x.shape
    tile = _tile_for(d, tile_d)
    dp = -(-d // tile) * tile
    tn = _tile_n_for(m, tile_n)
    mp = -(-m // tn) * tn
    xp = _pad_rows(_pad_cols(x.astype(jnp.float32), dp), mp)
    wp = _pad_rows(w.reshape(m, 1).astype(jnp.float32), mp)

    def kernel(x_ref, w_ref, o_ref):
        @pl.when(pl.program_id(1) == 0)
        def _():
            o_ref[...] = jnp.zeros_like(o_ref)

        o_ref[...] += jnp.sum(x_ref[...] * w_ref[...], axis=0, keepdims=True)

    out = pl.pallas_call(
        kernel,
        grid=(dp // tile, mp // tn),
        in_specs=[pl.BlockSpec((tn, tile), lambda k, i: (i, k)),
                  pl.BlockSpec((tn, 1), lambda k, i: (i, 0))],
        out_specs=pl.BlockSpec((1, tile), lambda k, i: (0, k)),
        out_shape=jax.ShapeDtypeStruct((1, dp), jnp.float32),
        interpret=resolve_interpret(interpret),
    )(xp, wp)
    return out[0, :d]


# ---------------------------------------------------------------------------
# blocked rule drivers (dense segments; prologue pre-materialized)
# ---------------------------------------------------------------------------

def rfa_segments_blocked(segs, *, iters: int = 8, eps: float = 1e-8,
                         bvalid=None, tile_n: int = DEFAULT_TILE_N,
                         tile_d: int = DEFAULT_TILE_D, interpret=None,
                         return_info: bool = False):
    """Giant-n smoothed Weiszfeld over dense (m, d_j) segments with global
    distances — semantics of ``Aggregator._rfa_tree`` / ``_rfa_masked``
    (via ``bvalid``). Costs 2 blocked sweeps per iteration (weighted sum +
    distances) + 1 final, vs the fused driver's 1 + 1 — the price of a
    worker axis of unbounded size. Returns per-segment (d_j,) aggregates;
    ``return_info`` mirrors ``rfa_segments``."""
    m = segs[0].shape[0]
    kw = dict(tile_n=tile_n, tile_d=tile_d, interpret=interpret)
    if bvalid is not None:
        bv = bvalid.astype(jnp.float32)
        w = bv / jnp.maximum(jnp.sum(bv), 1.0)
    else:
        w = jnp.full((m,), 1.0 / m, jnp.float32)
    for _ in range(iters):
        zs = [weighted_sum_blocked(xs, w, **kw) for xs in segs]
        sq = sum(sqdist_to_blocked(xs, z, **kw)
                 for xs, z in zip(segs, zs))
        w = 1.0 / jnp.sqrt(sq + eps)
        if bvalid is not None:
            w = jnp.where(bvalid, w, 0.0)
        w = w / jnp.maximum(jnp.sum(w), 1e-30)
    outs = [weighted_sum_blocked(xs, w, **kw) for xs in segs]
    if not return_info:
        return outs
    sq_t = sum(sqdist_to_blocked(xs, z, **kw)
               for xs, z in zip(segs, outs))
    return outs, {"bucket_weights": w, "rfa_sq": sq_t}


def krum_segments_blocked(segs, *, n_byz: int = 1, bvalid=None,
                          tile_n: int = DEFAULT_TILE_N,
                          tile_d: int = DEFAULT_TILE_D, interpret=None,
                          return_info: bool = False):
    """Giant-n Krum over dense (m, d_j) segments: blocked Gram (global
    pairwise distances, (tile_n, tile_n) accumulation — nothing n²·d-sized
    ever exists), tiny O(m²) scoring in jnp (``krum_select``), one blocked
    weighted-sum sweep extracting the winner. Semantics of
    ``Aggregator._krum_tree`` / ``_krum_masked`` (via ``bvalid``)."""
    kw = dict(tile_n=tile_n, tile_d=tile_d, interpret=interpret)
    g = sum(pair_gram_blocked(xs, **kw) for xs in segs)
    onehot, scores, best = krum_select(g, n_byz, bvalid)
    outs = [weighted_sum_blocked(xs, onehot, **kw) for xs in segs]
    if not return_info:
        return outs
    return outs, {"bucket_weights": onehot, "krum_scores": scores,
                  "krum_selected": best}
