"""Serving example: buffered-asynchronous Byzantine-robust LM training
through the streaming-aggregation service (repro.serve, DESIGN.md §4).

Clients compute LM gradient updates against a registered arch config and
dispatch them over a seeded arrival process with stragglers and dropouts;
the service dedups, staleness-weights and robustly aggregates every
``--buffer-size`` of them. Everything is declared through a
registry-validated ``ServeSpec``, so the printed spec JSON alone
reproduces the run.

  PYTHONPATH=src python examples/serve_lm.py --arch qwen3-1.7b --reduced
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.api import ServeSpec

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="qwen3-1.7b")
ap.add_argument("--reduced", action="store_true",
                help="smoke mode: reduced arch, tiny stream, few rounds")
ap.add_argument("--n-clients", type=int, default=8)
ap.add_argument("--n-byz", type=int, default=2)
ap.add_argument("--buffer-size", type=int, default=4)
ap.add_argument("--rounds", type=int, default=None)
ap.add_argument("--attack", default="ALIE")
ap.add_argument("--aggregator", default="cm")
args = ap.parse_args()

reduced = bool(args.reduced)
spec = ServeSpec(
    task="lm", arch=args.arch, method="sgd",
    n_clients=args.n_clients, n_byz=args.n_byz,
    attack=args.attack, aggregator=args.aggregator,
    buffer_size=args.buffer_size,
    rounds=(args.rounds if args.rounds is not None
            else (3 if reduced else 20)),
    lr=3e-3, arrival="exp",
    arrival_kwargs={"mean_latency": 1.0, "straggler_frac": 0.25,
                    "straggler_factor": 4.0, "dropout": 0.05},
    data_kwargs={"reduced": reduced,
                 "seq_len": 16 if reduced else 128,
                 "per_worker_batch": 1 if reduced else 4})

print(f"[serve_lm] spec: {spec.to_json(indent=None)}")
res = spec.build().run(verbose=True)
m = res.final
print(f"[serve_lm] {res.stats['rounds']} rounds over "
      f"{res.stats['accepted']} accepted updates "
      f"({res.stats['dropped']} dropped, "
      f"{res.stats['rej_dup_client'] + res.stats['rej_replay']} deduped) "
      f"— {res.updates_per_s:.2f} updates/s")
print(f"[serve_lm] final loss {m['loss']:.4f} |g| {m['g_norm']:.3e} "
      f"staleness mean {m['staleness_mean']:.2f} "
      f"max {m['staleness_max']}")
