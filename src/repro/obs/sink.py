"""MetricSink — the structured metric-event protocol (DESIGN.md §5).

Every layer that produces telemetry (api/runner, exec/scheduler,
serve/service) emits plain-dict EVENTS into a sink instead of growing its
own logging format. An event always carries a ``"type"``:

  {"type": "round",   ...}   — one logged training/fired round (metrics)
  {"type": "trace",   ...}   — a host-materialized RoundTrace (obs.trace)
  {"type": "counter", "name": ..., "value": ...}  — monotonic counts
  {"type": "gauge",   "name": ..., "value": ...}  — point-in-time values
  {"type": "span",    "name": ..., "wall_s": ...} — timed sections

Sinks are deliberately tiny: ``emit(event)`` + ``close()``. ``JsonlSink``
appends one JSON line per event (the artifact stream CI uploads),
``RingSink`` keeps the last N events in memory (tests, live probes),
``FanoutSink`` multiplexes, ``TagSink`` stamps extra key/values (e.g. the
sweep run_id) onto every event before forwarding.

Span-fencing rule: emitters must NOT force a device sync per event — wall
timing fences with ``block_until_ready`` only at log-cadence boundaries
(the runner's float() materialization is that fence), so telemetry stays
off the async-dispatch hot path.
"""
from __future__ import annotations

import collections
import contextlib
import json
import math
import time
from typing import Protocol, runtime_checkable


@runtime_checkable
class MetricSink(Protocol):
    def emit(self, event: dict) -> None: ...
    def close(self) -> None: ...


class NullSink:
    """Swallows everything; the no-telemetry default."""

    def emit(self, event: dict) -> None:
        pass

    def close(self) -> None:
        pass


class JsonlSink:
    """One JSON line per event, appended to ``path``. Line-buffered so a
    crashed run still leaves a readable stream."""

    def __init__(self, path: str):
        self.path = path
        self._f = open(path, "a", buffering=1)

    def emit(self, event: dict) -> None:
        self._f.write(json.dumps(event) + "\n")

    def close(self) -> None:
        if not self._f.closed:
            self._f.close()


class RingSink:
    """Keeps the last ``capacity`` events in memory (``.events``)."""

    def __init__(self, capacity: int = 4096):
        self.events: collections.deque = collections.deque(maxlen=capacity)

    def emit(self, event: dict) -> None:
        self.events.append(event)

    def close(self) -> None:
        pass

    def by_type(self, etype: str) -> list:
        return [e for e in self.events if e.get("type") == etype]

    def by_name(self, name: str) -> list:
        return [e for e in self.events if e.get("name") == name]


class FanoutSink:
    """Multiplexes events to several sinks; close() closes them all."""

    def __init__(self, *sinks):
        self.sinks = [s for s in sinks if s is not None]

    def emit(self, event: dict) -> None:
        for s in self.sinks:
            s.emit(event)

    def close(self) -> None:
        for s in self.sinks:
            s.close()


class TagSink:
    """Stamps ``tags`` onto every event before forwarding (the sweep
    scheduler tags each cell's events with its run_id). Does NOT close the
    underlying sink — it is shared across cells."""

    def __init__(self, base, **tags):
        self.base = base
        self.tags = tags

    def emit(self, event: dict) -> None:
        self.base.emit({**self.tags, **event})

    def close(self) -> None:
        pass


@contextlib.contextmanager
def span(sink, name: str, **fields):
    """Wall-clock a section and emit one span event on exit. The caller is
    responsible for fencing (block_until_ready) if device work must be
    included — and should only do so at log-cadence boundaries."""
    t0 = time.perf_counter()
    try:
        yield
    finally:
        if sink is not None:
            sink.emit({"type": "span", "name": name,
                       "wall_s": round(time.perf_counter() - t0, 6),
                       **fields})


# ---------------------------------------------------------------------------
# stream verification (the CI gate for traced-smoke artifacts)
# ---------------------------------------------------------------------------

def verify_jsonl(path: str) -> dict:
    """Fail-closed check of a metrics JSONL stream: the file must exist,
    parse line-by-line, contain at least one event, and no numeric field
    of any trace/round/fault event may be NaN/Inf. Returns counts per type.

    ``{"type": "fault", ...}`` events (the chaos layer's injection /
    degradation records, DESIGN.md §6) are additionally schema-checked:
    each must carry a ``kind`` from the ``repro.faults`` registry and a
    ``site`` from the known injection sites — a schema-less fault event
    means some emitter is improvising, which would silently corrupt the
    fault-matrix report downstream.

    One deliberate carve-out: a trace event that declares a chaos context
    (``fault_mask`` or ``guard_valid`` present) may carry non-finite
    values in its rule-intermediate diagnostics — a rejected bucket's
    krum score IS ``+inf`` (the guard's sort-fill), and recording that is
    honest telemetry, not a blow-up. Training metrics (round events) and
    every other field stay strictly finite, so a diverged trajectory
    still fails the gate.
    """
    counts: dict = {}
    bad: list = []
    bad_schema: list = []
    # rule intermediates where the fail-closed guard legitimately leaves
    # non-finite markers for rejected rows/buckets (chaos traces only)
    chaos_diag = ("influence", "dist_to_agg", "bucket_weights",
                  "krum_scores", "rfa_weights", "rfa_residual")

    def scan(prefix, v, exempt=()):
        if isinstance(v, dict):
            for k, x in v.items():
                scan(f"{prefix}.{k}", x, () if k not in exempt else ("*",))
        elif isinstance(v, list):
            for i, x in enumerate(v):
                scan(f"{prefix}[{i}]", x, exempt)
        elif (isinstance(v, float) and not math.isfinite(v)
              and "*" not in exempt):
            bad.append(prefix)

    from repro.faults.plan import FAULTS
    fault_sites = ("tensor", "wire", "process")

    with open(path) as f:
        for ln, line in enumerate(f, 1):
            if not line.strip():
                continue
            ev = json.loads(line)
            counts[ev.get("type", "?")] = counts.get(ev.get("type", "?"),
                                                     0) + 1
            if ev.get("type") in ("trace", "round", "fault"):
                chaos = (ev.get("type") == "trace"
                         and ("fault_mask" in ev or "guard_valid" in ev))
                scan(f"line {ln}", ev, chaos_diag if chaos else ())
            if ev.get("type") == "fault":
                if ev.get("kind") not in FAULTS:
                    bad_schema.append(
                        f"line {ln}: kind={ev.get('kind')!r}")
                elif ev.get("site") not in fault_sites:
                    bad_schema.append(
                        f"line {ln}: site={ev.get('site')!r}")
    if not counts:
        raise ValueError(f"{path}: empty metrics stream")
    if bad:
        raise ValueError(
            f"{path}: non-finite values in {len(bad)} field(s), first: "
            + ", ".join(bad[:5]))
    if bad_schema:
        raise ValueError(
            f"{path}: {len(bad_schema)} malformed fault event(s) "
            f"(need kind in {FAULTS} and site in {fault_sites}), first: "
            + "; ".join(bad_schema[:5]))
    return counts


def _main(argv=None):
    import argparse
    ap = argparse.ArgumentParser(
        description="verify a metrics JSONL stream (non-empty, finite)")
    ap.add_argument("--verify", required=True, metavar="PATH")
    args = ap.parse_args(argv)
    counts = verify_jsonl(args.verify)
    total = sum(counts.values())
    print(f"[obs.sink] {args.verify}: {total} events ok — "
          + ", ".join(f"{k}={v}" for k, v in sorted(counts.items())))


if __name__ == "__main__":
    _main()
