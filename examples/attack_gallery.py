"""Fig. 1 reproduction driver: every (aggregator x attack) cell, with and
without compression, printed as the paper's grid. Feeds EXPERIMENTS.md
§Paper-validation.

  PYTHONPATH=src python examples/attack_gallery.py [--iters 600]
"""
import argparse
import sys

sys.path.insert(0, "src")

import jax

from repro.core import (ByzVRMarinaConfig, get_aggregator, get_attack,
                        get_compressor, make_init, make_step)
from repro.data import (corrupt_labels_logreg, init_logreg_params,
                        logreg_loss, make_logreg_data)

ap = argparse.ArgumentParser()
ap.add_argument("--iters", type=int, default=600)
ap.add_argument("--n-workers", type=int, default=5)
ap.add_argument("--n-byz", type=int, default=1)
ap.add_argument("--heterogeneous", action="store_true")
args = ap.parse_args()

DIM = 30
key = jax.random.PRNGKey(0)
data = make_logreg_data(key, n_samples=600, dim=DIM,
                        n_workers=args.n_workers,
                        homogeneous=not args.heterogeneous)
loss_fn = logreg_loss(0.01)
full = {"x": data.features, "y": data.labels}
p_star = init_logreg_params(DIM)
gd = jax.jit(lambda p: jax.tree.map(
    lambda a, g: a - 0.5 * g, p, jax.grad(loss_fn)(p, full)))
for _ in range(3000):
    p_star = gd(p_star)
f_star = float(loss_fn(p_star, full))

ATTACKS = ["NA", "LF", "BF", "ALIE", "IPM"]
AGGS = [("AVG", "mean", 0), ("CM", "cm", 2), ("RFA", "rfa", 2)]

for comp_name, comp in [("no compression", get_compressor("identity")),
                        ("RandK K=0.1d", get_compressor("randk", ratio=0.1))]:
    print(f"\n=== Byz-VR-MARINA, {comp_name} "
          f"({args.n_workers} workers, {args.n_byz} byzantine) ===")
    print(f"{'agg':>5} | " + " | ".join(f"{a:>9}" for a in ATTACKS))
    for label, rule, bucket in AGGS:
        row = []
        for attack in ATTACKS:
            cfg = ByzVRMarinaConfig(
                n_workers=args.n_workers, n_byz=args.n_byz, p=0.1, lr=0.5,
                aggregator=get_aggregator(rule, bucket_size=bucket),
                compressor=comp, attack=get_attack(attack))
            step = jax.jit(make_step(cfg, loss_fn, corrupt_labels_logreg))
            anchor = data.stacked()
            state = make_init(cfg, loss_fn, corrupt_labels_logreg)(
                init_logreg_params(DIM), anchor, key)
            k = jax.random.PRNGKey(1)
            for it in range(args.iters):
                k, k1, k2 = jax.random.split(k, 3)
                state, _ = step(state, data.sample_batches(k1, 32), anchor,
                                k2)
            gap = float(loss_fn(state["params"], full)) - f_star
            row.append(f"{gap:9.1e}")
        print(f"{label:>5} | " + " | ".join(row))
print("\n(cells = final optimality gap f(x)-f*; the paper's Fig. 1 pattern: "
      "CM/RFA rows reach ~0 everywhere, AVG breaks under BF/ALIE/IPM)")
