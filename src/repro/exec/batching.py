"""Jit-signature grouping + vmapped multi-seed execution (DESIGN.md §1.6).

A sweep cell's jit signature is its spec minus the ``seed`` field: two
cells that differ only in seed trace to the *same* jitted trajectory, so a
5-seed x 6-cell grid costs 6 compiles instead of 30 when each group runs
as one ``jax.vmap``-over-seeds step. ``group_cells`` partitions cells by
that signature; ``run_group`` executes one group as a single jitted
``vmap(step)`` with the iteration index passed as a traced scalar, so the
whole trajectory is exactly ONE compile (``stats["step_compiles"]``).

Per-seed semantics mirror ``api.runner`` exactly — the same canonical key
schedule (``split(fold_in(k_run, it + 1))``), log cadence, and per-seed
communication accounting — so a vmapped trajectory is numerically
equivalent to the serial one (bit-level differences are float
reassociation only; pinned to ~1e-6 by tests/test_exec_batching.py).

Batching eligibility (``can_batch``) is conservative: the logreg task
(shared dataset; the LM TokenStream bakes the seed into its data stream)
on the dense gspmd backend (vmap over shard_map / pallas grids is not
supported), a method whose estimator declares ``seed_batchable`` (SAGA's
per-worker gradient tables must not be stacked over a seed axis — those
cells take the serial / WorkerPool path), with no host-side callback in
the loop knobs.
"""
from __future__ import annotations

import json
import time
from typing import Mapping, Sequence, Tuple

import jax
import numpy as np

from repro.api.runner import RunResult, build
from repro.core import estimators
from repro.core import tree_utils as tu

GROUP_AXIS = "seed"

# loop knobs a vmapped group understands; anything else forces serial cells
_BATCHABLE_RUN_KW = {"log_every", "warmup", "verbose"}


def group_key(spec) -> str:
    """Canonical jit-signature key: the spec dict minus the seed axis."""
    d = spec.to_dict()
    d.pop(GROUP_AXIS, None)
    return json.dumps(d, sort_keys=True)


def group_cells(cells: Sequence[Tuple[str, object]]):
    """[(run_id, spec)] -> [(key, [(run_id, spec), ...])] preserving the
    first-seen order of both groups and members."""
    groups: dict = {}
    order = []
    for run_id, spec in cells:
        key = group_key(spec)
        if key not in groups:
            groups[key] = []
            order.append(key)
        groups[key].append((run_id, spec))
    return [(key, groups[key]) for key in order]


def can_batch(cells: Sequence[Tuple[str, object]],
              run_kw: Mapping = None) -> bool:
    """True when a same-signature group can run as one vmapped trajectory."""
    if len(cells) < 2:
        return False                     # nothing to amortize
    if run_kw and set(run_kw) - _BATCHABLE_RUN_KW:
        return False                     # callbacks/checkpoints are per-cell
    spec = cells[0][1]
    if spec.task != "logreg":
        return False                     # TokenStream data is seed-baked
    if spec.agg_mode != "gspmd":
        return False                     # shard_map/pallas don't vmap
    if getattr(spec, "trace", False):
        return False                     # traces are per-trajectory host
        # artifacts; the vmapped group loop has no log-cadence twin
    if not estimators.seed_batchable(spec.method):
        return False                     # per-worker tables don't stack
    seen = set()
    for _, s in cells:
        if group_key(s) != group_key(spec) or s.seed in seen:
            return False
        seen.add(s.seed)
    return True


def run_group(cells: Sequence[Tuple[str, object]], *, log_every: int = 10,
              warmup: bool = False, verbose: bool = False):
    """Run one same-signature group as a single vmapped trajectory.

    Returns ``({run_id: RunResult}, stats)``; each RunResult carries the
    per-seed slice of the batched state and its own history/communication
    accounting, shaped exactly like the serial runner's.
    """
    assert can_batch(cells), "run_group needs a batchable group"
    exp = build(cells[0][1])
    spec0 = exp.spec
    seeds = jax.numpy.asarray([s.seed for _, s in cells])
    k = len(cells)
    anchor = exp.anchor(0)               # logreg: constant anchor set

    def init_one(seed):
        k_init, k_run = jax.random.split(jax.random.PRNGKey(seed))
        params = exp.init_params(k_init)
        return exp.method.init(params, anchor, k_run), k_run

    states, k_runs = jax.vmap(init_one)(seeds)
    n_params = int(tu.tree_size(exp.init_params(jax.random.PRNGKey(0))))

    def step_one(state, k_run, it):
        k_step, k_batch = jax.random.split(jax.random.fold_in(k_run, it + 1))
        return exp.method.step(state, exp.minibatch(it, k_batch), anchor,
                               k_step)

    # `it` is a traced scalar, so every round of every seed shares ONE
    # compilation — the whole point of the batched engine.
    vstep = jax.jit(jax.vmap(step_one, in_axes=(0, 0, None)))

    if warmup and spec0.steps > 0:
        thrown, _ = vstep(states, k_runs, 0)
        jax.block_until_ready(thrown["g"])
        del thrown

    histories = [[] for _ in range(k)]
    comm_bits = [0.0] * k
    # participation is part of the group key (to_dict minus seed), so one
    # scale factor covers every member of the batch
    part_frac = spec0.resolved_participation() / spec0.n_workers
    pending_ck = []                      # per-step (k,) arrays; synced lazily
    t0 = time.time()
    metrics = {}
    for it in range(spec0.steps):
        states, metrics = vstep(states, k_runs, it)
        pending_ck.append(metrics.get("c_k"))
        last = it == spec0.steps - 1
        if it % max(log_every, 1) == 0 or last:
            for ck in pending_ck:
                cks = None if ck is None else np.asarray(ck)
                for i in range(k):
                    comm_bits[i] += part_frac * exp.method.round_bits(
                        n_params, True if cks is None else bool(cks[i]))
            pending_ck.clear()
            mats = {name: np.asarray(v) for name, v in metrics.items()}
            wall = round(time.time() - t0, 2)
            for i in range(k):
                m = {name: float(v[i]) for name, v in mats.items()}
                m["step"] = it
                m["wall_s"] = wall
                m["comm_bits"] = comm_bits[i]
                m["comm_gbits"] = round(comm_bits[i] / 1e9, 4)
                histories[i].append(m)
            if verbose:
                loss = mats.get("loss")
                print(f"  [group x{k}] step {it:5d} "
                      f"loss {np.mean(loss):.4f} ({wall}s)")
    jax.block_until_ready(states["g"])
    wall_s = time.time() - t0

    results = {}
    for i, (run_id, spec) in enumerate(cells):
        state_i = jax.tree.map(lambda x, i=i: x[i], states)
        results[run_id] = RunResult(
            spec=spec, history=histories[i], state=state_i,
            n_params=n_params, comm_bits=comm_bits[i], wall_s=wall_s)
    cache_size = getattr(vstep, "_cache_size", lambda: 1)()
    stats = {"group_size": k, "steps": spec0.steps,
             "step_compiles": cache_size}
    return results, stats
