"""Cartesian grid expansion over ``RunSpec`` fields.

The paper's figures are grids (aggregator x attack x compression); a
``Sweep`` makes any such grid a one-liner with stable, human-readable run
ids, so benchmark artifacts are addressable and diffable:

    sweep = Sweep(base=RunSpec(task="logreg", steps=500),
                  grid={"aggregator": ("mean", "cm", "rfa"),
                        "attack": ("NA", "BF", "ALIE"),
                        "compressor_kwargs.ratio": (0.1, 1.0)})
    for run_id, spec in sweep.expand():
        result = spec.run()

Grid keys are spec field names; dotted keys reach into the per-component
kwargs dicts (``spec.replace`` semantics). Every expanded spec is validated
at construction, so an invalid cell fails before any training starts.
"""
from __future__ import annotations

import dataclasses
import itertools
import json
import os
import re
from typing import Mapping, Sequence

from repro.api.spec import RunSpec


def _fmt(value) -> str:
    s = str(value)
    return re.sub(r"[^A-Za-z0-9_.+-]+", "-", s) or "none"


@dataclasses.dataclass(frozen=True)
class Sweep:
    """``base`` spec + ``grid`` of field -> candidate values (insertion
    order of ``grid`` fixes both the expansion order and the run-id field
    order, so ids are stable across runs)."""
    base: RunSpec
    grid: Mapping[str, Sequence]

    def __post_init__(self):
        for key in self.grid:
            field = key.split(".", 1)[0]
            if field not in {f.name for f in dataclasses.fields(RunSpec)}:
                raise ValueError(
                    f"sweep grid key {key!r}: {field!r} is not a RunSpec "
                    "field")

    def __len__(self) -> int:
        n = 1
        for vals in self.grid.values():
            n *= len(vals)
        return n

    def run_id(self, overrides: Mapping) -> str:
        return "__".join(f"{k}={_fmt(v)}" for k, v in overrides.items())

    def expand(self):
        """Yield ``(run_id, spec)`` per grid cell, row-major in grid order."""
        names = list(self.grid)
        for combo in itertools.product(*(self.grid[n] for n in names)):
            overrides = dict(zip(names, combo))
            yield self.run_id(overrides), self.base.replace(**overrides)


def run_sweep(sweep: Sweep, *, out_dir: str = None, **run_kw) -> dict:
    """Run every cell; returns {run_id: RunResult}. With ``out_dir``, each
    cell's resolved spec + trajectory is written to ``<run_id>.json`` so the
    sweep is reproducible from artifacts alone."""
    results = {}
    if out_dir:
        os.makedirs(out_dir, exist_ok=True)
    for run_id, spec in sweep.expand():
        result = spec.run(**run_kw)
        results[run_id] = result
        if out_dir:
            with open(os.path.join(out_dir, run_id + ".json"), "w") as f:
                json.dump(result.to_dict(), f, indent=1)
    return results
