"""Message-site fault injection — deterministic, trace-safe, replayable.

Every mask here is a pure function of ``(plan, key)`` where ``key`` is the
engine's per-round attack key: ``fault_key`` folds the plan seed and the
FaultSpec's index into it, so injections are bit-for-bit replayable and the
traced telemetry twin can *recompute* the ground-truth ``fault_mask``
without any side channel. All branching on the plan itself is Python-level
(the plan is static config), so a ``fault_plan=None`` run traces the exact
same jaxpr as before the faults layer existed.
"""
from __future__ import annotations

import dataclasses
import zlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.faults.plan import (MESSAGE_FAULTS, TENSOR_FILL, TENSOR_FAULTS,
                               WIRE_FAULTS, FaultPlan)

_SALT = 0xFA17  # folds the fault stream away from the attack stream


def fault_key(plan: FaultPlan, key, index: int):
    """The RNG key for FaultSpec ``index``: attack key ⊕ plan seed ⊕ index."""
    k = jax.random.fold_in(key, _SALT + plan.seed % (1 << 20))
    return jax.random.fold_in(k, index)


def _eligible(spec, n: int):
    """Static (n,) eligibility mask from the spec's worker list."""
    if not spec.workers:
        return np.ones((n,), bool)
    m = np.zeros((n,), bool)
    m[[w for w in spec.workers if w < n]] = True
    return m


def _spec_mask(plan, spec, index, key, n):
    """Traced (n,) bool: does ``spec`` hit worker i this round?"""
    elig = jnp.asarray(_eligible(spec, n))
    if spec.prob >= 1.0:
        return elig
    if spec.prob <= 0.0:
        return jnp.zeros((n,), bool)
    hit = jax.random.bernoulli(fault_key(plan, key, index), spec.prob, (n,))
    return hit & elig


def fault_masks(plan: FaultPlan, key, n: int, kinds=MESSAGE_FAULTS):
    """Per-kind (n,) hit masks for this round, OR-ed across same-kind
    specs. Only kinds with at least one spec appear in the dict."""
    masks = {}
    for i, spec in enumerate(plan.faults):
        if spec.kind not in kinds:
            continue
        m = _spec_mask(plan, spec, i, key, n)
        masks[spec.kind] = masks[spec.kind] | m if spec.kind in masks else m
    return masks


def injected_mask(plan: FaultPlan, key, n: int, kinds=MESSAGE_FAULTS):
    """Ground-truth (n,) bool: any fault of ``kinds`` hit worker i this
    round. This is what ``RoundTrace.fault_mask`` records."""
    masks = fault_masks(plan, key, n, kinds)
    out = jnp.zeros((n,), bool)
    for m in masks.values():
        out = out | m
    return out


# ---------------------------------------------------------------------------
# dense candidates
# ---------------------------------------------------------------------------

def inject_candidates(plan: FaultPlan, key, cand):
    """Apply the plan's tensor faults to a dense stacked candidate tree
    (leaves (n, ...)). Later registry kinds overwrite earlier ones on
    overlapping workers (a NaN worker that also replays stays stale)."""
    masks = fault_masks(plan, key, jax.tree.leaves(cand)[0].shape[0],
                        TENSOR_FAULTS)
    if not masks:
        return cand

    def fill_rows(leaf, mask, value):
        m = mask.reshape((-1,) + (1,) * (leaf.ndim - 1))
        return jnp.where(m, jnp.asarray(value, leaf.dtype), leaf)

    for kind in TENSOR_FAULTS:
        if kind in masks:
            cand = jax.tree.map(
                lambda l, kind=kind: fill_rows(l, masks[kind],
                                               TENSOR_FILL[kind]), cand)
    return cand


# ---------------------------------------------------------------------------
# wire payloads
# ---------------------------------------------------------------------------

_BITCAST = {np.dtype(jnp.float32): jnp.uint32,
            np.dtype(jnp.bfloat16): jnp.uint16,
            np.dtype(jnp.float16): jnp.uint16}


def _flip_bits(arr, key):
    """XOR every element with random bits (float dtypes round-trip through
    their same-width unsigned carrier)."""
    dt = np.dtype(arr.dtype)
    if np.issubdtype(dt, np.floating) or dt == np.dtype(jnp.bfloat16):
        carrier = _BITCAST[dt]
        bits = jax.lax.bitcast_convert_type(arr, carrier)
        rnd = jax.random.bits(key, arr.shape, carrier)
        return jax.lax.bitcast_convert_type(bits ^ rnd, arr.dtype)
    rnd = jax.random.bits(key, arr.shape, jnp.dtype(dt)
                          if np.issubdtype(dt, np.unsignedinteger)
                          else {1: jnp.uint8, 2: jnp.uint16,
                                4: jnp.uint32}[dt.itemsize])
    if np.issubdtype(dt, np.unsignedinteger):
        return arr ^ rnd
    return jax.lax.bitcast_convert_type(
        jax.lax.bitcast_convert_type(arr, rnd.dtype) ^ rnd, arr.dtype)


def inject_wire(plan: FaultPlan, key, wc):
    """Apply the plan's message faults to a ``WireCandidates``:

    * ``corrupt_wire`` — random bit-flips XORed into every payload array of
      the hit workers' rows (floats garble to arbitrary bit patterns,
      sparse indices to arbitrary int32s — usually out of range, which the
      decode guard rejects).
    * tensor kinds — the hit workers' *float* payload arrays take the
      kind's fill value (NaN / inf / 0): the wire-mode analogue of a
      corrupted candidate row.
    """
    masks = fault_masks(plan, key, wc.n, MESSAGE_FAULTS)
    if not masks:
        return wc

    def is_float(a):
        dt = np.dtype(a.dtype)
        return np.issubdtype(dt, np.floating) or dt == np.dtype(jnp.bfloat16)

    new_payloads = []
    for j, payload in enumerate(wc.payloads):
        out = dict(payload)
        for kind in TENSOR_FAULTS:
            if kind not in masks:
                continue
            m = masks[kind]
            for name, arr in out.items():
                if not is_float(arr):
                    continue
                mm = m.reshape((-1,) + (1,) * (arr.ndim - 1))
                out[name] = jnp.where(
                    mm, jnp.asarray(TENSOR_FILL[kind], arr.dtype), arr)
        for kind in WIRE_FAULTS:
            if kind not in masks:
                continue
            m = masks[kind]
            for name, arr in out.items():
                k = jax.random.fold_in(fault_key(plan, key, _SALT + j),
                                       zlib.crc32(name.encode()) % (1 << 20))
                mm = m.reshape((-1,) + (1,) * (arr.ndim - 1))
                out[name] = jnp.where(mm, _flip_bits(arr, k), arr)
        new_payloads.append(out)
    return dataclasses.replace(wc, payloads=tuple(new_payloads))
