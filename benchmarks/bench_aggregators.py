"""Server-side aggregation throughput: jnp tree path vs Pallas kernels,
across ALL five rules × bucketed/unbucketed (interpret mode on CPU — on TPU
the kernel path is the compiled one). One row per (impl, rule, bucket, n, d),
both impls timed with the SAME ``time_fn`` iteration count.

Besides wall time, every row carries the analytic HBM-sweep count — tensor
traversals in units of the raw (n, d) stack, materialize-counted for the jnp
path (each jnp op reads its inputs and writes its result to HBM; sorting and
reductions on the s-bucketed matrix count 1/s) and read(n·d)+write(d) per
pass for the kernels. On a bandwidth-bound TPU, sweeps ∝ wall time;
``normalized_speedup`` = jnp_sweeps / pallas_sweeps is therefore the
interpret-overhead-free throughput ratio the fusion buys. The whole table is
recorded as ``experiments/bench/BENCH_agg.json`` (ISSUE 4 acceptance: fused
RFA ≤ 2 sweeps per Weiszfeld iteration, ≥ 2× normalized over jnp at
n=16, d=2^20).
"""
import json
import os

import jax

from benchmarks.common import ART_DIR, emit, time_fn
from repro.core.aggregators import COORD_KERNEL_RULE, get_aggregator
from repro.kernels import norm_agg, ops

KEY = jax.random.PRNGKey(0)
ITERS = 3          # same for BOTH impls (the old asymmetry made GB/s lies)
WARMUP = 1
RFA_T = 8          # paper default Weiszfeld iterations
BENCH_TILE_D = 1 << 16   # fewer grid steps -> less interpret-mode overhead

# giant-n scaling section (DESIGN.md §7): n-axis for the blocked tier.
# Interpret mode pays per-grid-step Python overhead, so the blocked kernels
# are only TIMED up to GIANT_PALLAS_MAX_N on CI hosts (at n=4096 one
# interpret-mode Gram exceeds 10 minutes); the n=4096 kernel row is carried
# analytically (sweep counts are exact), and on a real TPU the compiled
# kernels cover the full axis.
GIANT_NS = (256, 1024, 4096)
GIANT_D = 1 << 11
GIANT_RFA_T = 2
GIANT_PALLAS_MAX_N = 1024


def analytic_sweeps(impl: str, rule: str, s: int) -> float:
    """(n·d)-equivalent HBM traversals per call; materialize-counted."""
    if impl == "pallas":
        # every pass re-streams the raw stack once (bucketing is in-VMEM)
        return {"mean": 1.0, "cm": 1.0, "tm": 1.0,
                "rfa": RFA_T + 1.0, "krum": 2.0}[rule]
    bucketize = (3.0 + 1.0 / s) if s > 1 else 0.0   # gather r+w, mean r, w/s
    b = 1.0 / s if s > 1 else 1.0                   # bucketed-matrix sweep
    if rule == "mean":
        return 1.0
    if rule in ("cm", "tm"):                        # sort r+w, reduce r
        return bucketize + 3.0 * b
    if rule == "rfa":                               # init mean + per iter:
        # diff r+w, square-reduce r, weighted-sum r
        return bucketize + b + RFA_T * 4.0 * b
    if rule == "krum":                              # gram r + weighted-sum r
        return bucketize + 2.0 * b
    raise ValueError(rule)


def _pallas_fn(rule, bucket, agg):
    kw = dict(tile_d=BENCH_TILE_D, interpret=True)
    if rule in COORD_KERNEL_RULE:
        kernel_rule = COORD_KERNEL_RULE[rule]
        return lambda k, a: ops.robust_agg(
            a, k if bucket > 1 else None, bucket_size=bucket,
            rule=kernel_rule, trim=agg.trim, **kw)
    if rule == "rfa":
        return lambda k, a: ops.rfa_agg(
            a, k if bucket > 1 else None, bucket_size=bucket,
            iters=agg.iters, eps=agg.eps, **kw)
    return lambda k, a: ops.krum_agg(
        a, k if bucket > 1 else None, bucket_size=bucket, n_byz=agg.n_byz,
        **kw)


def run():
    rows = []
    for n, d in [(16, 1 << 16), (16, 1 << 20), (32, 1 << 16)]:
        x = jax.random.normal(KEY, (n, d))
        nbytes = n * d * 4
        for rule in ["mean", "cm", "tm", "rfa", "krum"]:
            for bucket in ([1] if rule == "mean" else [1, 2]):
                agg = get_aggregator(rule, bucket_size=bucket, n_byz=1)
                impls = {
                    "jnp": jax.jit(lambda k, a, agg=agg: agg(k, a)),
                    "pallas": _pallas_fn(rule, bucket, agg),
                }
                us = {}
                for impl, fn in impls.items():
                    us[impl] = time_fn(fn, KEY, x, warmup=WARMUP,
                                       iters=ITERS)
                    sweeps = analytic_sweeps(impl, rule, bucket)
                    name = f"agg/{impl}/{rule}/b{bucket}/n{n}/d{d}"
                    emit(name, us[impl],
                         f"GBps={nbytes / us[impl] / 1e3:.2f}"
                         f";sweeps={sweeps:g}")
                    rows.append({"impl": impl, "rule": rule,
                                 "bucket": bucket, "n": n, "d": d,
                                 "us": us[impl], "sweeps": sweeps})
                rows.append({
                    "impl": "speedup", "rule": rule, "bucket": bucket,
                    "n": n, "d": d,
                    "measured_interp": us["jnp"] / us["pallas"],
                    "normalized": (analytic_sweeps("jnp", rule, bucket)
                                   / analytic_sweeps("pallas", rule,
                                                     bucket))})
    rows += giant_n_rows()
    payload = {
        "schema": 2,
        "note": ("sweeps = (n*d)-equivalent HBM traversals per call, "
                 "materialize-counted for jnp; normalized speedup = "
                 "jnp_sweeps/pallas_sweeps (bandwidth-bound TPU ratio); "
                 "measured us are CPU interpret mode, same iters both "
                 "impls; tier=giant rows are the blocked/hierarchical "
                 "n-axis (DESIGN.md §7)"),
        "rfa_weiszfeld_iters": RFA_T,
        "rfa_pallas_sweeps_per_iter": (RFA_T + 1.0) / RFA_T,
        "rows": rows,
        "n_scaling": n_scaling_curve(rows),
    }
    os.makedirs(ART_DIR, exist_ok=True)
    with open(os.path.join(ART_DIR, "BENCH_agg.json"), "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)


def giant_n_rows():
    """The n-axis of the blocked tier: Krum/RFA at n ∈ GIANT_NS, jnp
    (jit-compiled blocked Gram) at every n, the blocked Pallas drivers
    (interpret on CPU) up to GIANT_PALLAS_MAX_N."""
    rows = []
    for n in GIANT_NS:
        d = GIANT_D
        x = jax.random.normal(KEY, (n, d))
        nbytes = n * d * 4
        for rule in ["krum", "rfa"]:
            n_byz = max(1, n // 16)
            agg = get_aggregator(rule, bucket_size=1, n_byz=n_byz,
                                 iters=GIANT_RFA_T)
            if rule == "krum":
                def pallas_fn(k, a, n_byz=n_byz):
                    return norm_agg.krum_segments_blocked(
                        [a], n_byz=n_byz, interpret=True)[0]
            else:
                def pallas_fn(k, a):
                    return norm_agg.rfa_segments_blocked(
                        [a], iters=GIANT_RFA_T, interpret=True)[0]
            impls = {"jnp": jax.jit(lambda k, a, agg=agg: agg(k, a)),
                     "pallas": pallas_fn}
            for impl, fn in impls.items():
                row = {"impl": impl, "rule": rule, "bucket": 1, "n": n,
                       "d": d, "tier": "giant",
                       "sweeps": analytic_sweeps_giant(impl, rule)}
                if impl == "pallas" and n > GIANT_PALLAS_MAX_N:
                    row["us"] = None       # analytic-only on interpret hosts
                    rows.append(row)
                    continue
                us = time_fn(fn, KEY, x, warmup=1, iters=1)
                emit(f"agg_giant/{impl}/{rule}/n{n}/d{d}", us,
                     f"GBps={nbytes / us / 1e3:.2f}")
                row["us"] = us
                rows.append(row)
    return rows


def analytic_sweeps_giant(impl: str, rule: str) -> float:
    """(n·d)-equivalent traversals for the giant-n tier (bucket off).
    Blocked RFA pays 2 sweeps/iteration (weighted sum + distances) — the
    fused single-pass trick needs the whole worker axis in sublanes."""
    if impl == "pallas":
        return {"rfa": 2.0 * GIANT_RFA_T + 1.0, "krum": 2.0}[rule]
    if rule == "rfa":
        return 1.0 + GIANT_RFA_T * 4.0
    return 2.0


def n_scaling_curve(rows):
    """Per (impl, rule): the giant-tier n axis with per-worker cost — the
    scaling curve the docs/CI read. Krum's blocked Gram is O(n²·d) compute
    on O(n·d + n²) memory, so us/n grows ~linearly in n; RFA stays ~flat."""
    curve = {}
    for r in rows:
        if r.get("tier") != "giant" or r.get("us") is None:
            continue
        curve.setdefault(f"{r['impl']}/{r['rule']}", []).append(
            {"n": r["n"], "us": r["us"],
             "us_per_worker": r["us"] / r["n"]})
    for pts in curve.values():
        pts.sort(key=lambda p: p["n"])
    return curve


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
