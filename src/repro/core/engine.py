"""The Byzantine-robust round engine (DESIGN.md §2).

The paper's central observation is architectural: Byz-VR-MARINA and every
method it is compared against (SGD, BR-SGDm, CSGD, BR-DIANA, BR-MVR,
Byrd-SVRG) share one round skeleton and differ *only* in the gradient
estimator. This module owns that skeleton, once:

    1. parameter update            x^{k+1} = x^k - γ g^k  (or optim.Optimizer)
    2. data corruption             label-flipping byzantines (corrupt_fn)
    3. candidate computation       ← the pluggable ``GradientEstimator``
    4. omniscient attack           byzantines replace their message
    5. robust aggregation          backend dispatch (``AGG_BACKENDS``)
    6. server finalization         estimator post-processing (e.g. DIANA's
                                   shift mean) + state carry
    7. metrics + communication     loss, |g|, per-round uploaded bits

Estimators declare whether the parameter update happens *before* the
candidates are computed (MARINA-family: workers need x^{k+1} and x^k) or
*after* (SGD-family: the aggregate is the update direction), and which named
RNG streams they consume — the engine splits the per-round key exactly once,
so a method's trajectory is a pure function of (seed, estimator, config).

Aggregation-backend dispatch (``aggregate``):

  * ``gspmd``          — paper-faithful jnp over the stacked worker axis;
                         GSPMD inserts the all-gather on a mesh.
  * ``all_to_all``     — shard_map sharded aggregation (core/sharded_agg.py).
  * ``sparse_support`` — common-randomness RandK support-only aggregation
                         (handled inside the MARINA estimator; dense rounds
                         stay gspmd).
  * ``pallas``         — one-sweep-per-pass Pallas kernels for EVERY rule
                         (kernels/robust_agg + kernels/norm_agg), launched
                         leaf-wise with the bucketing permutation carried
                         on-chip; ``message_phase`` additionally fuses
                         kernel-fusable attacks into the aggregation load so
                         the attacked tensor never hits HBM.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.core import tree_utils as tu


AGG_BACKENDS = ("gspmd", "all_to_all", "sparse_support", "pallas")


# ---------------------------------------------------------------------------
# shared round primitives
# ---------------------------------------------------------------------------

def apply_attack(cfg, key, cand, mask=None, stats_valid=None):
    """cand: stacked pytree (n, ...). Returns the vectors actually 'sent'.

    Omniscient attacks see the good workers' per-coordinate mean/std; NA/LF
    leave the candidates untouched (LF acts at the data level). ``mask``
    overrides ``cfg.byz_mask()`` for callers whose byzantine set is decided
    per call rather than by worker index — the buffered-async service
    (repro.serve) passes the byzantine flags of whatever updates happen to
    sit in the fired buffer. ``stats_valid`` (fault guard, DESIGN.md §6)
    additionally restricts the attack's mean/std statistics to valid rows,
    so a NaN-faulted honest worker cannot poison the omniscient attack the
    way it cannot poison the masked aggregate.
    """
    if cfg.attack.name in ("NA", "LF") or (mask is None and cfg.n_byz == 0):
        return cand
    if mask is None:
        mask = cfg.byz_mask()
    good = ~mask
    if stats_valid is not None:
        good = good & stats_valid
    means, stds = tu.masked_mean_std(cand, good,
                                     sanitize=stats_valid is not None)

    def leaf(h, m, s):
        v = cfg.attack.apply(key, h, m, s).astype(h.dtype)
        bm = mask.reshape((-1,) + (1,) * (h.ndim - 1))
        return jnp.where(bm, v, h)

    return jax.tree.map(leaf, cand, means, stds)


def stacked_grads(loss_fn, params, batches, keys):
    """vmap(value_and_grad) over the leading worker axis of ``batches``."""
    def one(batch, key):
        return jax.value_and_grad(loss_fn)(params, batch, key)

    losses, grads = jax.vmap(one)(batches, keys)
    return jnp.mean(losses), grads


def aggregate(cfg, key, sent, valid=None):
    """Backend dispatch for line 10 (g = ARAgg(sent_1, ..., sent_n)).

    ``valid`` (fault guard) is the (n,) row-validity mask: invalid rows get
    zero aggregation weight via the masked rule twins. ``None`` (the
    default) is byte-for-byte the unguarded dispatch."""
    mode = cfg.agg_mode
    if mode in ("gspmd", "sparse_support"):
        # sparse_support only changes the MARINA VR branch (the estimator
        # aggregates on the shared support itself); dense aggregations
        # (init, full-grad rounds, other estimators) stay gspmd.
        if valid is not None:
            return cfg.aggregator.tree_masked(key, sent, valid)
        return cfg.aggregator.tree(key, sent)
    if mode == "all_to_all":
        if valid is not None:
            raise ValueError("fault_guard is not supported under "
                             "agg_mode='all_to_all' (guarded backends: "
                             "gspmd, pallas — DESIGN.md §6)")
        from repro.core.sharded_agg import tree_aggregate_all_to_all
        return tree_aggregate_all_to_all(cfg, key, sent)
    if mode == "pallas":
        from repro.core.sharded_agg import tree_aggregate_pallas
        return tree_aggregate_pallas(cfg, key, sent, valid=valid)
    # backstop only: ByzVRMarinaConfig/RunSpec validate agg_mode eagerly at
    # construction, so a hand-rolled cfg is the only way to get here.
    raise ValueError(f"agg_mode {mode!r} not in {AGG_BACKENDS}")


def fusable_attack_ctx(cfg, cand, mask, stats_valid=None):
    """Build the ``sharded_agg.AttackCtx`` for a kernel-fusable omniscient
    attack (BF/ALIE/IPM via ``Attack.coord_apply``): the byzantine mask plus
    the good workers' per-coordinate mean/std trees, computed only when the
    attack reads them. Shared by ``message_phase``/``ingest_message_phase``
    and the traced twins in ``repro.obs.trace``. ``stats_valid`` (fault
    guard) restricts the statistics to valid rows."""
    from repro.core.sharded_agg import AttackCtx
    means = stds = None
    if cfg.attack.needs_mean or cfg.attack.needs_std:
        good = ~mask if stats_valid is None else ~mask & stats_valid
        means, stds = tu.masked_mean_std(cand, good,
                                         sanitize=stats_valid is not None)
        if not cfg.attack.needs_std:
            stds = None
    return AttackCtx(fn=cfg.attack.coord_apply, mask=mask,
                     means=means, stds=stds)


# Round-scoped participation routing (DESIGN.md §7). Like ``_PHASE_TRACE``
# below, this is a module-level cell read at trace time only: the engine
# step sets it to the (n,) sampled-worker mask for the duration of the
# round when ``cfg.n_active`` requests partial participation, so message
# phases owned by estimators (MARINA's lax.cond branches) route through
# ``participating_message_phase`` without any signature change. With the
# cell at None (full participation) every phase traces the byte-identical
# jaxpr it did before the participation axis existed.
_PHASE_SAMPLED = [None]

# fold_in salt for the participation sampling stream — distinct from the
# fault layer's 0xFA17 so the three per-round streams (attack, fault,
# participation) are pairwise independent of each other's knobs (pinned in
# tests/test_participation.py).
_PART_SALT = 0x5A3B1E


def sampled_worker_mask(cfg, step_key):
    """(n,) bool — the uniformly-sampled participation cohort this round,
    or None under full participation.

    The draw folds ``_PART_SALT`` into the per-round step key (the key the
    engine splits into the estimator's named streams), so the sampling
    stream is disjoint from every named stream by construction and the
    sampled set is bit-replayable from (spec, seed) alone. A uniform
    m-subset without replacement: rank the n workers by a seeded
    permutation and take the first ``n_active``.
    """
    n_active = getattr(cfg, "n_active", None)
    if n_active is None or n_active >= cfg.n_workers:
        return None
    part_key = jax.random.fold_in(step_key, _PART_SALT)
    rank = jax.random.permutation(part_key, cfg.n_workers)
    return rank < n_active


def participating_message_phase(cfg, attack_key, agg_key, cand, sampled):
    """``message_phase`` over the sampled cohort: non-sampled rows get zero
    aggregation weight (select-zero via the masked rule twins — the same
    machinery the fault guard uses), the omniscient attack's mean/std
    statistics see only the sampled good workers (a non-participant is
    invisible to an in-round adversary), and under the guard the validity
    mask is ``sampled & finite`` so the two maskings compose.

    ``WireCandidates`` are densified first (``wire.reconstruct``): the
    fused wire kernels have no masked twin, and partial participation
    already pays the dense roster in simulation. Bucket renormalization
    over the survivors is ``faults.guard.masked_bucket_matrix`` — exactly
    the δ-over-active-set semantics the spec validates against.
    """
    from repro.core import wire
    plan = getattr(cfg, "fault_plan", None)
    if isinstance(cand, wire.WireCandidates):
        if plan is not None and plan.message_faults:
            from repro.faults import inject
            cand = inject.inject_wire(plan, attack_key, cand)
        cand = wire.reconstruct(cand)
    elif plan is not None and plan.tensor_faults:
        from repro.faults import inject
        cand = inject.inject_candidates(plan, attack_key, cand)
    if getattr(cfg, "fault_guard", False):
        from repro.faults import guard as fguard
        valid_pre = fguard.finite_row_mask(cand) & sampled
        sent = apply_attack(cfg, attack_key, cand, stats_valid=valid_pre)
        valid = fguard.finite_row_mask(sent) & sampled
        if cfg.agg_mode == "pallas":
            from repro.core.sharded_agg import tree_aggregate_pallas
            return tree_aggregate_pallas(cfg, agg_key, sent, valid=valid)
        return aggregate(cfg, agg_key, sent, valid=valid)
    if cfg.agg_mode == "pallas":
        from repro.core.sharded_agg import tree_aggregate_pallas
        clean = cfg.n_byz == 0 or cfg.attack.name in ("NA", "LF")
        if clean:
            return tree_aggregate_pallas(cfg, agg_key, cand, valid=sampled)
        if cfg.attack.coord_apply is not None:
            ctx = fusable_attack_ctx(cfg, cand, cfg.byz_mask(),
                                     stats_valid=sampled)
            return tree_aggregate_pallas(cfg, agg_key, cand, attack_ctx=ctx,
                                         valid=sampled)
        sent = apply_attack(cfg, attack_key, cand, stats_valid=sampled)
        return tree_aggregate_pallas(cfg, agg_key, sent, valid=sampled)
    sent = apply_attack(cfg, attack_key, cand, stats_valid=sampled)
    return aggregate(cfg, agg_key, sent, valid=sampled)


def message_phase(cfg, attack_key, agg_key, cand):
    """Lines 9-10 of the round: omniscient attack, then robust aggregation.

    For ``agg_mode="pallas"`` with a kernel-fusable attack (BF/ALIE/IPM via
    ``Attack.coord_apply``; NA/LF and n_byz=0 trivially) the injection
    happens inside the aggregation kernels' VMEM load — the attacked
    ``sent`` tensor is never written to HBM (DESIGN.md §3). RN (needs the
    exact jax.random stream) and the other backends materialize ``sent``
    via ``apply_attack`` as before.

    ``cand`` may also be a ``wire.WireCandidates`` payload (estimators whose
    compressor declares a kernel wire format, under pallas): then even the
    candidates themselves never materialize — the kernels reconstruct
    base + decode(payload) per VMEM block (DESIGN.md §Wire).

    The chaos layer (repro.faults, DESIGN.md §6) hooks in here: a
    ``cfg.fault_plan`` injects message-site faults into ``cand`` before the
    attack, and ``cfg.fault_guard`` reroutes to the fail-closed
    ``guarded_message_phase``. Both are static Python branches — with the
    plan unset and the guard off this function traces the identical jaxpr
    it did before the faults layer existed (pinned in tests/test_faults).

    Partial participation (DESIGN.md §7) routes here too: when the engine
    step has published a sampled-worker mask (``_PHASE_SAMPLED``), the
    round aggregates over the sampled cohort only. Full participation
    leaves the cell at None and this body is untouched.
    """
    if _PHASE_SAMPLED[0] is not None:
        return participating_message_phase(cfg, attack_key, agg_key, cand,
                                           _PHASE_SAMPLED[0])
    from repro.core import wire
    plan = getattr(cfg, "fault_plan", None)
    if isinstance(cand, wire.WireCandidates):
        if plan is not None and plan.message_faults:
            from repro.faults import inject
            cand = inject.inject_wire(plan, attack_key, cand)
        return wire.wire_message_phase(cfg, attack_key, agg_key, cand)
    if plan is not None and plan.tensor_faults:
        from repro.faults import inject
        cand = inject.inject_candidates(plan, attack_key, cand)
    if getattr(cfg, "fault_guard", False):
        return guarded_message_phase(cfg, attack_key, agg_key, cand)
    if cfg.agg_mode == "pallas":
        from repro.core.sharded_agg import tree_aggregate_pallas
        clean = cfg.n_byz == 0 or cfg.attack.name in ("NA", "LF")
        if clean:
            return tree_aggregate_pallas(cfg, agg_key, cand)
        if cfg.attack.coord_apply is not None:
            ctx = fusable_attack_ctx(cfg, cand, cfg.byz_mask())
            return tree_aggregate_pallas(cfg, agg_key, cand, attack_ctx=ctx)
    sent = apply_attack(cfg, attack_key, cand)
    return aggregate(cfg, agg_key, sent)


def guarded_message_phase(cfg, attack_key, agg_key, cand, return_valid=False):
    """Fail-closed twin of ``message_phase`` over dense candidates: rows
    that are non-finite in any coordinate get zero aggregation weight and
    count toward the δ budget (they are treated exactly as explicitly
    dropped workers — the equivalence the fault-matrix test pins).

    * attack statistics see only honest AND valid rows, matching the oracle
      that never saw the faulted workers;
    * a Byzantine row overwritten by the attack is valid again (the attack
      value is finite by construction — it is a *statistical* adversary,
      which is the aggregator's job, not the guard's);
    * materializing paths re-check finiteness on the attacked tensor, so
      even a non-finite attack output fails closed.

    ``return_valid`` additionally returns the final (n,) validity mask (the
    obs layer records ``~valid`` as the guard's detection next to the
    injected ground truth).
    """
    from repro.faults import guard as fguard
    valid_pre = fguard.finite_row_mask(cand)
    clean = cfg.n_byz == 0 or cfg.attack.name in ("NA", "LF")
    byz = None if clean else cfg.byz_mask()
    if cfg.agg_mode == "pallas":
        from repro.core.sharded_agg import tree_aggregate_pallas
        if clean:
            agg = tree_aggregate_pallas(cfg, agg_key, cand, valid=valid_pre)
            return (agg, valid_pre) if return_valid else agg
        if cfg.attack.coord_apply is not None:
            ctx = fusable_attack_ctx(cfg, cand, byz, stats_valid=valid_pre)
            # keep valid_pre: BF-style coord_apply transforms the candidate
            # value, so a byz∩faulty row's attacked value is still NaN —
            # crediting byz rows back as valid would let it through. The
            # prologue orders attack-select -> valid-select, zeroing it.
            agg = tree_aggregate_pallas(cfg, agg_key, cand, attack_ctx=ctx,
                                        valid=valid_pre)
            return (agg, valid_pre) if return_valid else agg
        sent = apply_attack(cfg, attack_key, cand, stats_valid=valid_pre)
        valid = fguard.finite_row_mask(sent)
        agg = tree_aggregate_pallas(cfg, agg_key, sent, valid=valid)
        return (agg, valid) if return_valid else agg
    sent = apply_attack(cfg, attack_key, cand, stats_valid=valid_pre)
    valid = fguard.finite_row_mask(sent)
    agg = aggregate(cfg, agg_key, sent, valid=valid)
    return (agg, valid) if return_valid else agg


# Trace-time routing for estimators that own their message phase (MARINA's
# lax.cond branches): the telemetry twin built by make_engine_step(trace=True)
# flips this flag while est.round traces, so ``phase_with_trace`` — called
# from INSIDE the branch — returns (agg, RoundTrace) and the trace escapes
# the cond through ``RoundOutput.trace`` (both branches build the same
# RoundTrace structure for a fixed rule, so lax.cond accepts it). The flag is
# read at trace time only; with it off the call is byte-for-byte
# ``message_phase`` and the extra None output adds no jaxpr equations.
_PHASE_TRACE = [False]


def phase_with_trace(cfg, attack_key, agg_key, cand):
    """``message_phase`` that also returns this round's RoundTrace when the
    enclosing engine step is the telemetry twin; ``(agg, None)`` otherwise."""
    if _PHASE_TRACE[0]:
        from repro.obs import trace as obs_trace
        return obs_trace.traced_message_phase(cfg, attack_key, agg_key, cand)
    return message_phase(cfg, attack_key, agg_key, cand), None


def ingest_message_phase(cfg, attack_key, agg_key, cand, *, byz_mask=None,
                         weights=None):
    """Partial/buffered-candidate entry to lines 9-10 of the round.

    Twin of ``message_phase`` for callers that aggregate a BUFFER of updates
    rather than the full worker roster (the streaming service, repro.serve):

    * ``byz_mask`` — (K,) bool over the buffered entries: which of them came
      from byzantine clients. The byzantine fraction is defined over the
      *buffered* set, so the mask is per-call data (traced), not the static
      ``cfg.byz_mask()`` worker-index prefix.
    * ``weights``  — optional (K,) per-entry multiplicative scale applied to
      the sent vectors before bucketing/rule (staleness weighting: the
      service passes ``K * s(tau_i) / sum_j s(tau_j)``, so ``rule="mean"``
      reproduces the FedBuff weighted mean exactly). Under pallas the scale
      is fused into the aggregation's on-chip ``w`` operator (a diagonal
      composed with the bucket matrix — zero extra HBM traffic); the jnp
      path materializes the scaled tree, which is also the test oracle.

    With both omitted this IS ``message_phase``. ``WireCandidates`` are not
    accepted — the service buffer holds dense (decoded) updates.
    """
    from repro.core import wire
    if isinstance(cand, wire.WireCandidates):
        raise TypeError(
            "ingest_message_phase aggregates dense buffered updates; decode "
            "wire payloads at ingest (serve/buffer.py) before firing")
    if byz_mask is None and weights is None:
        return message_phase(cfg, attack_key, agg_key, cand)
    clean = cfg.attack.name in ("NA", "LF") or (byz_mask is None
                                                and cfg.n_byz == 0)
    if getattr(cfg, "fault_guard", False):
        from repro.faults import guard as fguard
        valid_pre = fguard.finite_row_mask(cand)
        sent = apply_attack(cfg, attack_key, cand, mask=byz_mask,
                            stats_valid=valid_pre)
        valid = fguard.finite_row_mask(sent)
        if cfg.agg_mode == "pallas":
            from repro.core.sharded_agg import tree_aggregate_pallas
            return tree_aggregate_pallas(cfg, agg_key, sent, weights=weights,
                                         valid=valid)
        if weights is not None:
            w = weights.astype(jnp.float32)
            sent = jax.tree.map(
                lambda a: (a.astype(jnp.float32)
                           * w.reshape((-1,) + (1,) * (a.ndim - 1))
                           ).astype(a.dtype), sent)
        return aggregate(cfg, agg_key, sent, valid=valid)
    if cfg.agg_mode == "pallas":
        from repro.core.sharded_agg import tree_aggregate_pallas
        if clean:
            return tree_aggregate_pallas(cfg, agg_key, cand, weights=weights)
        if cfg.attack.coord_apply is not None:
            mask = byz_mask if byz_mask is not None else cfg.byz_mask()
            ctx = fusable_attack_ctx(cfg, cand, mask)
            return tree_aggregate_pallas(cfg, agg_key, cand, attack_ctx=ctx,
                                         weights=weights)
        # unfusable attack (RN): materialize, but keep the weights fused
        sent = apply_attack(cfg, attack_key, cand, mask=byz_mask)
        return tree_aggregate_pallas(cfg, agg_key, sent, weights=weights)
    sent = apply_attack(cfg, attack_key, cand, mask=byz_mask)
    if weights is not None:
        w = weights.astype(jnp.float32)
        sent = jax.tree.map(
            lambda a: (a.astype(jnp.float32)
                       * w.reshape((-1,) + (1,) * (a.ndim - 1))
                       ).astype(a.dtype), sent)
    return aggregate(cfg, agg_key, sent)


def param_update(cfg, params, g, opt_state):
    """x <- x - γ g (dtype-preserving, fp32 math) or cfg.optimizer.update."""
    if cfg.optimizer is None:
        new = jax.tree.map(
            lambda x, gg: (x.astype(jnp.float32)
                           - cfg.lr * gg.astype(jnp.float32)).astype(x.dtype),
            params, g)
        return new, opt_state
    return cfg.optimizer.update(g, opt_state, params)


def maybe_corrupt(cfg, corrupt_fn, batch):
    """Data-level attacks (label flipping) on the byzantine workers."""
    if corrupt_fn is not None and cfg.attack.flips_labels and cfg.n_byz:
        return corrupt_fn(batch, cfg.byz_mask())
    return batch


# ---------------------------------------------------------------------------
# estimator protocol
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class RoundOutput:
    """What an estimator hands back to the engine each round.

    Either ``cand`` (stacked candidates the engine attacks + aggregates,
    with optional ``finalize(agg) -> (g, state_updates)`` server-side
    post-processing) or ``g_new`` (the estimator ran the message phase
    itself — the sparse-support path, where attack/aggregation happen on
    the shared RandK support only). ``trace`` carries the RoundTrace out of
    estimator-owned message phases (``phase_with_trace``) when the
    telemetry twin is running; None otherwise.
    """
    loss: Any
    cand: Any = None
    finalize: Optional[Callable] = None
    g_new: Any = None
    updates: Optional[dict] = None
    metrics: Optional[dict] = None
    trace: Any = None


class GradientEstimator:
    """Interface for pluggable per-worker gradient estimators.

    Subclasses set:
      * ``name``                — registry key.
      * ``rng``                 — ordered per-round RNG stream names; must
                                  end with ("attack", "agg"). The engine
                                  splits the round key into exactly these.
      * ``update_params_first`` — True for MARINA-family estimators whose
                                  candidates are computed at x^{k+1}.
      * ``seed_batchable``      — False when state must not be vmapped over
                                  seeds (per-worker gradient tables); the
                                  sweep engine then runs such cells on the
                                  serial / WorkerPool path (DESIGN.md §2).
      * ``streamable``          — True when the candidate computation is a
                                  pure per-client function of (params, batch,
                                  local state) so updates can be computed at
                                  dispatch time and aggregated later from a
                                  buffer (the buffered-async service,
                                  repro.serve / DESIGN.md §4). Estimators
                                  whose round couples clients through shared
                                  per-round draws or anchor full-gradient
                                  broadcasts (MARINA's c_k coin, SVRG
                                  snapshots) stay False.
    and implement ``init_extras`` and ``round``.
    """
    name: str = "?"
    rng: tuple = ("grad", "attack", "agg")
    update_params_first: bool = False
    seed_batchable: bool = True
    streamable: bool = False

    def init_extras(self, cfg, loss_fn, params, anchor, key):
        """-> (g0, extras): the initial server estimate and any extra state
        (stacked worker momenta / shifts / snapshots ...)."""
        raise NotImplementedError

    def round(self, cfg, loss_fn, state, params, old_params, batch, anchor,
              keys) -> RoundOutput:
        """Compute this round's candidate messages (or the full message
        phase, for estimators that own their aggregation)."""
        raise NotImplementedError

    # -- communication accounting (paper Fig. 8 / footnote 3) --------------
    def round_bits(self, cfg, d: int, full_round: bool = True) -> int:
        """Bits uploaded per worker this round."""
        return 32 * d

    def expected_bits(self, cfg, d: int) -> float:
        return float(self.round_bits(cfg, d))


def carry_unsampled_state(state, updates, sampled, n_workers):
    """Freeze the per-worker state of non-participants (DESIGN.md §7).

    A worker that was not sampled this round neither computed nor uploaded
    anything, so its estimator state — SAGA gradient tables, EF21
    ``worker_g``, cmfilter ``worker_m``/``worker_u``, SVRG snapshots — must
    carry forward bit-identically. Estimators mark per-worker stacked state
    with the ``worker_*`` key prefix (every leaf leading axis = n_workers);
    for those keys the round's update is select-merged row-wise against
    the previous state. Server-side updates (``snapshot``, ``prev_params``,
    DIANA's shift mean) pass through untouched: the server did run this
    round, over the sampled cohort.
    """
    out = {}
    for k, new in updates.items():
        old = state.get(k)
        if old is None or not k.startswith("worker_"):
            out[k] = new
            continue

        def merge(nl, ol):
            assert nl.shape[0] == n_workers, (k, nl.shape)
            keep = sampled.reshape((-1,) + (1,) * (nl.ndim - 1))
            return jnp.where(keep, nl, ol)

        out[k] = jax.tree.map(merge, new, old)
    return out


# ---------------------------------------------------------------------------
# engine step / init factories
# ---------------------------------------------------------------------------

def make_engine_init(cfg, loss_fn, estimator: GradientEstimator,
                     corrupt_fn: Optional[Callable] = None):
    def init(params, anchor, key):
        if anchor is not None:
            anchor = maybe_corrupt(cfg, corrupt_fn, anchor)
        g0, extras = estimator.init_extras(cfg, loss_fn, params, anchor, key)
        opt_state = (cfg.optimizer.init(params)
                     if cfg.optimizer is not None else None)
        return {"params": params, "g": g0, "opt_state": opt_state,
                "step": jnp.zeros((), jnp.int32), **extras}

    return init


def make_engine_step(cfg, loss_fn, estimator: GradientEstimator,
                     corrupt_fn: Optional[Callable] = None,
                     trace: bool = False):
    """``trace=True`` builds the telemetry twin: the message phase runs
    through ``repro.obs.trace.traced_message_phase`` — the identical
    aggregation calls plus the rule's own intermediates — and the returned
    metrics gain a ``"trace"`` RoundTrace entry. Estimators that own their
    message phase route through ``phase_with_trace`` and hand the trace back
    via ``RoundOutput.trace`` (None when they aggregate without the shared
    phase, e.g. sparse-support VR rounds). The default ``trace=False`` path
    is byte-for-byte today's step."""
    est = estimator
    assert est.rng[-2:] == ("attack", "agg"), est.rng

    def step(state, batch, anchor, key):
        keys = dict(zip(est.rng, jax.random.split(key, len(est.rng))))
        old_params = state["params"]
        sampled = sampled_worker_mask(cfg, key)

        if est.update_params_first:
            new_params, new_opt = param_update(cfg, old_params, state["g"],
                                               state["opt_state"])
        else:
            new_params, new_opt = old_params, state["opt_state"]

        batch = maybe_corrupt(cfg, corrupt_fn, batch)
        anchor = maybe_corrupt(cfg, corrupt_fn, anchor)

        prev_flag, prev_sampled = _PHASE_TRACE[0], _PHASE_SAMPLED[0]
        _PHASE_TRACE[0] = trace
        _PHASE_SAMPLED[0] = sampled
        try:
            ro = est.round(cfg, loss_fn, state, new_params, old_params,
                           batch, anchor, keys)
            updates = dict(ro.updates or {})

            rt = None
            if ro.g_new is not None:
                g = ro.g_new
                rt = ro.trace
            else:
                if trace:
                    from repro.obs import trace as obs_trace
                    agg, rt = obs_trace.traced_message_phase(
                        cfg, keys["attack"], keys["agg"], ro.cand)
                else:
                    agg = message_phase(cfg, keys["attack"], keys["agg"],
                                        ro.cand)
                if ro.finalize is not None:
                    g, fin_updates = ro.finalize(agg)
                    updates.update(fin_updates)
                else:
                    g = agg
        finally:
            _PHASE_TRACE[0] = prev_flag
            _PHASE_SAMPLED[0] = prev_sampled

        if sampled is not None:
            updates = carry_unsampled_state(state, updates, sampled,
                                            cfg.n_workers)

        if not est.update_params_first:
            new_params, new_opt = param_update(cfg, old_params, g,
                                               state["opt_state"])

        new_state = {**state, **updates, "params": new_params, "g": g,
                     "opt_state": new_opt, "step": state["step"] + 1}
        metrics = {"loss": ro.loss,
                   **(ro.metrics or {}),
                   "g_norm": jnp.sqrt(tu.tree_norm_sq(g))}
        if trace:
            metrics["trace"] = rt
        return new_state, metrics

    return step


# ---------------------------------------------------------------------------
# method registry
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Method:
    """A fully-assembled Byzantine-robust training method.

    ``init(params, anchor, key) -> state`` and
    ``step(state, batch, anchor, key) -> (state, metrics)`` run through the
    shared engine; ``estimator`` is the plugged-in GradientEstimator.
    ``step_traced`` is the telemetry twin (metrics carry a ``"trace"``
    RoundTrace; the trajectory is bit-identical to ``step``) used by the
    runner on log-cadence steps when ``RunSpec.trace`` is on.
    """
    name: str
    estimator: GradientEstimator
    init: Callable
    step: Callable
    cfg: Any
    step_traced: Optional[Callable] = None

    def round_bits(self, d: int, full_round: bool = True) -> int:
        return self.estimator.round_bits(self.cfg, d, full_round)

    def expected_bits(self, d: int) -> float:
        return self.estimator.expected_bits(self.cfg, d)


def make_method(name: str, cfg, loss_fn,
                corrupt_fn: Optional[Callable] = None, **est_kw) -> Method:
    """Assemble a registered method over the shared round engine.

    name in ``list_methods()``: marina | sgd | sgdm | csgd | diana | mvr
    | svrg | byz_ef21 | cmfilter | saga. ``est_kw`` are estimator knobs
    (momentum, alpha, batch_size, ...).
    """
    from repro.core import estimators as E
    est = E.get_estimator(name, cfg, **est_kw)
    return Method(
        name=name, estimator=est, cfg=cfg,
        init=make_engine_init(cfg, loss_fn, est, corrupt_fn),
        step=make_engine_step(cfg, loss_fn, est, corrupt_fn),
        step_traced=make_engine_step(cfg, loss_fn, est, corrupt_fn,
                                     trace=True))


def list_methods():
    from repro.core import estimators as E
    return sorted(E.ESTIMATORS)
