"""Training driver: Byzantine-robust LM training through the declarative
experiment API — any registered method, attack, and aggregation backend.

The CLI is *generated* from ``RunSpec``'s fields, with choices enumerated
from the unified component registry (``repro.api.registry``), so a backend
or method registered anywhere in the framework is immediately drivable here
— no hand-maintained ``choices=[...]`` lists to drift out of sync. Legacy
flags (``--agg``, ``--bucket``, ``--opt``, ``--compress-ratio``) keep
working as aliases. Example:

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --reduced \\
      --steps 100 --n-workers 8 --n-byz 2 --attack ALIE --agg cm \\
      --method marina --agg-mode auto

--agg-mode "auto" resolves to the fused Pallas kernel path on TPU and the
paper-faithful gspmd path elsewhere; "all_to_all" shards the worker axis
over the visible devices (CPU: set
XLA_FLAGS=--xla_force_host_platform_device_count=<n_workers>).

``--spec path.json`` loads a serialized RunSpec instead of flags;
``--spec-out path.json`` dumps the resolved spec next to the metrics, so
every run is reproducible from its artifacts alone.
"""
from __future__ import annotations

import argparse
import dataclasses
import json

from repro.api import RunSpec, build, components, describe, resolve_agg_mode

# spec fields whose CLI choices enumerate from the unified registry
_CHOICE_KINDS = {"arch": "arch", "method": "method", "attack": "attack",
                 "aggregator": "aggregator", "compressor": "compressor",
                 "optimizer": "optimizer"}
# pre-redesign flag spellings, kept as aliases of the spec-named flags
_LEGACY_ALIASES = {"aggregator": ("--agg",), "bucket_size": ("--bucket",),
                   "optimizer": ("--opt",)}
# train-appropriate defaults where they differ from RunSpec's (logreg-tuned)
_TRAIN_DEFAULTS = {"arch": "qwen3-1.7b", "n_workers": 8, "n_byz": 0,
                   "attack": "NA", "lr": 3e-3,
                   # None = derive from --compress-ratio (legacy behaviour)
                   "compressor": None}


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        description="Byzantine-robust training via repro.api.RunSpec")
    for f in dataclasses.fields(RunSpec):
        if f.name == "task":        # this driver is the LM task
            continue
        flag = "--" + f.name.replace("_", "-")
        flags = (flag,) + _LEGACY_ALIASES.get(f.name, ())
        default = _TRAIN_DEFAULTS.get(f.name, f.default)
        if f.name == "agg_mode":
            ap.add_argument(flag, default="auto",
                            choices=("auto",) + components("agg_mode"),
                            help="server-side aggregation backend "
                                 "(auto = pallas on TPU, gspmd elsewhere)")
        elif f.name in _CHOICE_KINDS:
            kind = _CHOICE_KINDS[f.name]
            ap.add_argument(*flags, default=default,
                            choices=components(kind),
                            help=f"registry {kind!r}: "
                                 + ", ".join(components(kind)))
        elif f.default_factory is dict:          # per-component kwargs
            ap.add_argument(flag, type=json.loads, default={},
                            help=f"JSON dict merged into spec.{f.name}")
        elif isinstance(default, bool):          # bool('False') is True
            ap.add_argument(*flags, action="store_true", default=default)
        else:
            ap.add_argument(flags[0], *flags[1:], type=type(f.default),
                            default=default)
    # stream/model knobs (forwarded into spec.data_kwargs)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--per-worker-batch", type=int, default=4)
    ap.add_argument("--heterogeneous", action="store_true")
    ap.add_argument("--remat", action="store_true")
    ap.add_argument("--compress-ratio", type=float, default=1.0,
                    help="legacy: <1.0 selects randk at this ratio when "
                         "--compressor is not given")
    # loop knobs (live in the shared runner, not the spec)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--checkpoint", default=None,
                    help="path prefix: save the full engine state (final + "
                         "every --checkpoint-every steps)")
    ap.add_argument("--checkpoint-every", type=int, default=None,
                    help="periodic checkpoint cadence in steps")
    ap.add_argument("--resume", default=None, metavar="CKPT",
                    help="restart from a checkpoint prefix the runner "
                         "wrote; the trajectory continues exactly where "
                         "the interrupted run left off")
    ap.add_argument("--metrics-out", default=None)
    from repro.obs import profile
    profile.add_cli_args(ap)            # --metrics-out-jsonl, --profile-dir
    ap.add_argument("--spec", default=None,
                    help="load a serialized RunSpec JSON (flags ignored)")
    ap.add_argument("--spec-out", default=None,
                    help="write the resolved spec JSON")
    ap.add_argument("--list-components", action="store_true",
                    help="print every registered component and exit")
    return ap


def spec_from_args(args) -> RunSpec:
    """Resolve CLI flags (including the legacy --compress-ratio derivation)
    into a concrete, serializable RunSpec."""
    if args.spec:
        with open(args.spec) as f:
            return RunSpec.from_json(f.read())
    agg_mode = resolve_agg_mode(args.agg_mode)
    compressor, ckw = args.compressor, dict(args.compressor_kwargs)
    if compressor is None:
        if agg_mode == "sparse_support":
            compressor = "randk"
            ckw = {"ratio": (args.compress_ratio
                             if args.compress_ratio < 1.0 else 0.1),
                   "common_randomness": True, **ckw}
        elif args.compress_ratio < 1.0:
            compressor = "randk"
            ckw = {"ratio": args.compress_ratio, **ckw}
        else:
            compressor = "identity"
    elif compressor == "randk" and "ratio" not in ckw:
        if args.compress_ratio < 1.0:
            ckw["ratio"] = args.compress_ratio
        if agg_mode == "sparse_support":
            ckw.setdefault("common_randomness", True)
    data_kwargs = {"seq_len": args.seq_len,
                   "per_worker_batch": args.per_worker_batch,
                   "reduced": args.reduced,
                   "heterogeneous": args.heterogeneous,
                   "remat": args.remat, **args.data_kwargs}
    return RunSpec(
        task="lm", arch=args.arch, method=args.method,
        n_workers=args.n_workers, n_byz=args.n_byz, attack=args.attack,
        aggregator=args.aggregator, bucket_size=args.bucket_size,
        agg_mode=agg_mode, compressor=compressor, p=args.p, lr=args.lr,
        optimizer=args.optimizer, steps=args.steps, seed=args.seed,
        trace=args.trace, faults=args.faults, fault_guard=args.fault_guard,
        method_kwargs=args.method_kwargs, attack_kwargs=args.attack_kwargs,
        aggregator_kwargs=args.aggregator_kwargs, compressor_kwargs=ckw,
        optimizer_kwargs=args.optimizer_kwargs, data_kwargs=data_kwargs)


def main():
    args = build_parser().parse_args()
    from repro.obs import profile
    if args.profile_dir:
        # before the first backend touch (spec resolution may init jax)
        profile.enable_step_markers()
    if args.list_components:
        for kind in ("arch", "method", "attack", "aggregator", "compressor",
                     "optimizer", "agg_mode"):
            print(f"{kind}:")
            for name, summary in describe(kind).items():
                print(f"  {name:<22} {summary}")
        return []
    spec = spec_from_args(args)
    if args.spec_out:
        with open(args.spec_out, "w") as f:
            f.write(spec.to_json())

    exp = build(spec)
    acfg = exp.arch_cfg
    print(f"[train] {spec.arch} "
          f"({'reduced' if spec.data_kwargs.get('reduced') else 'full'}): "
          f"~{acfg.param_count()/1e6:.1f}M params, method={spec.method}, "
          f"{spec.n_workers} workers ({spec.n_byz} byzantine, "
          f"attack={spec.attack}, agg={exp.cfg.aggregator.name}, "
          f"backend={spec.agg_mode})")
    with profile.profile_trace(args.profile_dir):
        result = exp.run(log_every=args.log_every, verbose=True,
                         checkpoint=args.checkpoint,
                         checkpoint_every=args.checkpoint_every,
                         resume=args.resume,
                         metrics_out=args.metrics_out,
                         metrics_jsonl=args.metrics_out_jsonl)
    if spec.trace and result.traces:
        det = result.detection_summary()
        print(f"[train] detection over {det['rounds']} traced rounds: "
              f"precision {det['precision']:.3f} "
              f"recall {det['recall']:.3f} "
              f"byz_leakage {det['byz_leakage']:.3f}")
    return result.history


if __name__ == "__main__":
    main()
