"""recurrentgemma-2b [hybrid] — RG-LRU + local attention, 1:2 pattern.

26L d_model=2560 10H (GQA kv=1) d_ff=7680 vocab=256000 [arXiv:2402.19427]
Griffin block pattern: two recurrent (RG-LRU) blocks then one local-attention
block, repeated. Local attention window 2048 per the paper.
"""
from repro.configs.base import ArchConfig, RGLRU, SWA, register

CONFIG = register(ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    citation="arXiv:2402.19427",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    d_ff=7680,
    vocab_size=256_000,
    head_dim=256,
    block_pattern=(RGLRU, RGLRU, SWA),
    sliding_window=2048,
    rglru_width=2560,
    conv_width=4,
    supports_long_context=True,
))
