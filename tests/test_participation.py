"""Per-round client sampling (partial participation) — DESIGN.md §7.

Pins the four contracts of the participation axis:

* δ-accounting is over the ACTIVE set (``theory.delta_over_active_set``):
  spec validation errors/warns on the sampled cohort, not the full fleet;
* the sampling stream folds its own tag off the per-round step key —
  pairwise independent of the attack and fault streams, and the zero-knob
  (participation=1) step compiles to a jaxpr canonically identical to a
  spec that never mentions participation;
* bit-replayability: (spec, seed) fully determines which workers speak in
  every round — pinned at n=1024 / participation=0.1 per the acceptance
  bar, including the blocked-Gram Krum path;
* estimator state of NON-sampled workers carries forward bitwise untouched
  (checkpoint-identical rows), while sampled rows move.
"""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import RunSpec, run
from repro.api.runner import build
from repro.core import engine
from repro.core.theory import delta_over_active_set

from _jaxpr_scan import iter_eqns


def _spec(**kw):
    base = dict(task="logreg", method="marina", n_workers=16, n_byz=2,
                p=0.3, lr=0.1, attack="ALIE", aggregator="krum",
                bucket_size=2, steps=4, seed=3,
                data_kwargs={"n_samples": 64, "dim": 6, "batch_size": 8,
                             "data_seed": 0})
    base.update(kw)
    return RunSpec(**base)


# ---------------------------------------------------------------------------
# resolved_participation / delta_over_active_set
# ---------------------------------------------------------------------------

def test_resolved_participation():
    assert _spec().resolved_participation() == 16
    assert _spec(participation=1.0).resolved_participation() == 16
    assert _spec(participation=0.5).resolved_participation() == 8
    assert _spec(participation=3).resolved_participation() == 3
    # tiny fractions clamp to at least one speaker
    assert _spec(participation=1e-6).resolved_participation() == 1
    for bad in (0.0, -0.5, 1.5, 0, 17, -3, True):
        with pytest.raises(ValueError, match="participation"):
            _spec(participation=bad).resolved_participation()


def test_delta_over_active_set():
    assert delta_over_active_set(10, 3) == pytest.approx(0.3)
    assert delta_over_active_set(10, 2, bucket_size=2) == pytest.approx(0.4)
    # byz clamps to the cohort: a 3-worker cohort can't hold 5 byzantines
    assert delta_over_active_set(3, 5) == pytest.approx(1.0)
    # degenerate cohorts are maximally pessimistic
    assert delta_over_active_set(0, 0) == 1.0
    assert delta_over_active_set(-1, 0) == 1.0
    # participation=1 reproduces the full-fleet fraction exactly
    assert delta_over_active_set(16, 2) == 2 / 16


def test_spec_delta_checks_cover_sampled_cohort():
    # full fleet is fine (2/16), but a 4-worker cohort can be 2/4-byz
    with pytest.warns(UserWarning, match="active"):
        _spec(participation=4)
    with warnings.catch_warnings():
        warnings.simplefilter("error")
        _spec(participation=12)          # 2/12 stays < 0.5 worst-case
    # participation needs the masked-aggregation prologue
    with pytest.raises(ValueError, match="participation"):
        _spec(participation=0.5, agg_mode="all_to_all",
              aggregator="cm", n_byz=0, attack="NA")


# ---------------------------------------------------------------------------
# stream independence
# ---------------------------------------------------------------------------

def test_sampling_stream_disjoint_from_attack_and_fault_streams():
    """The per-round masks are a pure function of (step key, n, n_active) —
    flipping the attack or the fault plan must not move them."""
    base = _spec(participation=0.5, trace=True, steps=3)
    variants = [
        _spec(participation=0.5, trace=True, steps=3, attack="NA"),
        _spec(participation=0.5, trace=True, steps=3,
              faults={"seed": 1, "faults": [{"kind": "nan_grad",
                                             "prob": 0.5}]},
              fault_guard=True),
    ]
    masks = [np.asarray(t["sampled_mask"]) for t in run(base,
                                                        log_every=1).traces]
    assert len(masks) == 3
    for v in variants:
        got = [np.asarray(t["sampled_mask"]) for t in run(v,
                                                          log_every=1).traces]
        for a, b in zip(masks, got):
            np.testing.assert_array_equal(a, b)


def test_attack_and_compressor_streams_unmoved_by_participation():
    """Turning the participation knob must not shift any other stream:
    the c_k compressor coin sequence is bit-identical across settings."""
    full = run(_spec(), log_every=1)
    part = run(_spec(participation=0.5), log_every=1)
    ck_full = [h.get("c_k") for h in full.history]
    ck_part = [h.get("c_k") for h in part.history]
    assert ck_full == ck_part
    # ... and participation really did change the trajectory
    assert full.history[-1]["loss"] != part.history[-1]["loss"]


def _canon_eqns(fn, args):
    closed = jax.make_jaxpr(fn)(*args)
    return [(e.primitive.name,
             tuple(str(v.aval) for v in e.invars),
             tuple(str(v.aval) for v in e.outvars))
            for e in iter_eqns(closed.jaxpr)]


@pytest.mark.parametrize("agg_mode", ["gspmd", "pallas"])
def test_zero_knob_jaxpr_identical(agg_mode):
    """participation=1.0 compiles the exact same program as a spec that
    never mentions participation — the knob is free when off."""
    exp_off = build(_spec(agg_mode=agg_mode))
    exp_on = build(_spec(agg_mode=agg_mode, participation=1.0))
    k_init, k_run = jax.random.split(jax.random.PRNGKey(3))
    params = exp_off.init_params(k_init)
    state = exp_off.method.init(params, exp_off.anchor(0), k_run)
    k_step, k_batch = jax.random.split(jax.random.fold_in(k_run, 1))
    args = (state, exp_off.minibatch(0, k_batch), exp_off.anchor(0), k_step)
    assert _canon_eqns(exp_on.method.step, args) == \
        _canon_eqns(exp_off.method.step, args)


def test_sampled_mask_is_uniform_m_subset():
    cfg = _spec(participation=5).build_config()
    key = jax.random.PRNGKey(0)
    seen = set()
    for it in range(20):
        m = np.asarray(engine.sampled_worker_mask(cfg, jax.random.fold_in(
            key, it)))
        assert m.sum() == 5
        seen.add(tuple(m.tolist()))
    assert len(seen) > 1                  # masks move across rounds
    # full participation compiles the mask away entirely
    assert engine.sampled_worker_mask(_spec().build_config(), key) is None


# ---------------------------------------------------------------------------
# bit-replay at the acceptance scale (n=1024, participation=0.1)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_bit_replay_n1024_participation_01():
    spec = _spec(n_workers=1024, n_byz=64, participation=0.1, steps=2,
                 trace=True, data_kwargs={"n_samples": 64, "dim": 4,
                                          "batch_size": 8, "data_seed": 0})
    a = run(spec, log_every=1)
    b = run(spec, log_every=1)
    assert [h["loss"] for h in a.history] == [h["loss"] for h in b.history]
    for x, y in zip(jax.tree.leaves(a.state), jax.tree.leaves(b.state)):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y))
    for ta, tb in zip(a.traces, b.traces):
        ma, mb = np.asarray(ta["sampled_mask"]), np.asarray(tb["sampled_mask"])
        np.testing.assert_array_equal(ma, mb)
        assert ma.sum() == 102            # round(0.1 * 1024)


# ---------------------------------------------------------------------------
# non-sampled estimator state carries forward untouched
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method,worker_key", [
    ("diana", "worker_h"), ("byz_ef21", "worker_g"), ("mvr", "worker_v")])
def test_unsampled_worker_state_untouched(method, worker_key):
    kw = {}
    if method == "byz_ef21":
        kw = dict(compressor="topk", compressor_kwargs={"ratio": 0.5})
    spec = _spec(method=method, participation=0.5, steps=1, trace=True, **kw)
    exp = build(spec)
    k_init, k_run = jax.random.split(jax.random.PRNGKey(spec.seed))
    params = exp.init_params(k_init)
    state0 = exp.method.init(params, exp.anchor(0), k_run)
    assert worker_key in state0
    k_step, k_batch = jax.random.split(jax.random.fold_in(k_run, 1))
    state1, metrics = jax.jit(exp.method.step_traced)(
        state0, exp.minibatch(0, k_batch), exp.anchor(0), k_step)
    sampled = np.asarray(metrics["trace"].sampled_mask)
    assert sampled.sum() == 8
    changed = 0
    for old_leaf, new_leaf in zip(jax.tree.leaves(state0[worker_key]),
                                  jax.tree.leaves(state1[worker_key])):
        old, new = np.asarray(old_leaf), np.asarray(new_leaf)
        # non-sampled rows: bitwise frozen (checkpoint-identical)
        np.testing.assert_array_equal(old[~sampled], new[~sampled])
        changed += int((old[sampled] != new[sampled]).any())
    assert changed > 0                    # sampled rows actually moved
