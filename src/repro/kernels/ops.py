"""jit'd public wrappers for the Pallas kernels.

``interpret=None`` everywhere → resolved once in kernels/backend.py
(interpret on CPU/GPU hosts — this container — compiled on real TPU
backends); the kernels are validated against the jnp oracles in interpret
mode.

The ARAgg wrappers are ZERO-COPY: the Alg. 2 bucketing permutation is
carried as the on-chip ``norm_agg.bucket_matrix`` operator instead of
materializing ``x[perm]`` in HBM, for the coordinate rules and the
norm-based rules (RFA/Krum) alike.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.backend import resolve_interpret  # noqa: F401 (re-export)
from repro.kernels.robust_agg import robust_agg as _robust_agg
from repro.kernels.quantize import block_quantize as _block_quantize
from repro.kernels import norm_agg, ref


def _perm_bucket_matrix(key, n, bucket_size):
    """Alg. 2 random permutation as the (nb, n) on-chip bucket operator."""
    perm = jax.random.permutation(key, n)
    return norm_agg.bucket_matrix(perm, n, bucket_size)


def _bucket_first(x, key, bucket_size):
    """Giant-n prologue (DESIGN.md §7): materialize the Alg. 2 bucket
    reduction in jnp so the rule only ever sees the (nb, d) bucketed stack."""
    from repro.core.aggregators import _bucketize_perm
    y = x.astype(jnp.float32)
    if bucket_size > 1:
        n = y.shape[0]
        perm = (jax.random.permutation(key, n) if key is not None
                else jnp.arange(n))       # key=None: legacy contiguous rows
        y = _bucketize_perm(y, perm, bucket_size)
    return y


def robust_agg(x, key=None, *, bucket_size: int = 1, rule: str = "median",
               trim: int = 1, tile_d: int = norm_agg.DEFAULT_TILE_D,
               interpret=None):
    """Full (δ,c)-ARAgg for (n, d) stacked workers: fused permutation +
    bucket-mean + coordinate rule, one HBM sweep of x. Above
    ``MAX_FUSED_WORKERS`` (the kernel's n-in-sublanes cap) the rule runs
    bucket-first in jnp — coordinate sorts at giant n are XLA's job."""
    if x.shape[0] > norm_agg.MAX_FUSED_WORKERS:
        from repro.core.aggregators import coord_median, coord_trimmed_mean
        y = _bucket_first(x, key, bucket_size)
        if rule == "mean":
            return jnp.mean(y, axis=0)
        if rule == "median":
            return coord_median(y)
        if rule == "trimmed":
            return coord_trimmed_mean(y, trim)
        raise ValueError(rule)
    if key is not None and bucket_size > 1:
        w = _perm_bucket_matrix(key, x.shape[0], bucket_size)
        return _robust_agg(x, w, rule=rule, trim=trim, tile_d=tile_d,
                           interpret=interpret)
    return _robust_agg(x, bucket_size=bucket_size, rule=rule, trim=trim,
                       tile_d=tile_d, interpret=interpret)


def rfa_agg(x, key=None, *, bucket_size: int = 1, iters: int = 8,
            eps: float = 1e-8, tile_d: int = norm_agg.DEFAULT_TILE_D,
            interpret=None):
    """Geometric median (smoothed Weiszfeld) of (n, d) stacked workers via
    the fused norm_agg kernels: T+1 HBM sweeps for T iterations. Above
    ``MAX_FUSED_WORKERS`` the stack is bucket-reduced first; if the bucketed
    rows fit back under the cap the fused kernels run on them, else the
    BLOCKED drivers (worker-tiled) take over."""
    if x.shape[0] > norm_agg.MAX_FUSED_WORKERS:
        y = _bucket_first(x, key, bucket_size)
        if y.shape[0] <= norm_agg.MAX_FUSED_WORKERS:
            return norm_agg.rfa_segments([y], iters=iters, eps=eps,
                                         tile_d=tile_d,
                                         interpret=interpret)[0]
        return norm_agg.rfa_segments_blocked([y], iters=iters, eps=eps,
                                             tile_d=tile_d,
                                             interpret=interpret)[0]
    w = None
    if key is not None and bucket_size > 1:
        w = _perm_bucket_matrix(key, x.shape[0], bucket_size)
    return norm_agg.rfa_segments([x], w_mat=w, iters=iters, eps=eps,
                                 tile_d=tile_d, interpret=interpret)[0]


def krum_agg(x, key=None, *, bucket_size: int = 1, n_byz: int = 1,
             tile_d: int = norm_agg.DEFAULT_TILE_D, interpret=None):
    """Krum (Eq. 15) of (n, d) stacked workers via the fused norm_agg
    kernels: 2 HBM sweeps (Gram + winner extraction). Above
    ``MAX_FUSED_WORKERS`` the stack is bucket-reduced first; the blocked
    Gram driver handles whatever still exceeds the cap — nothing n²·d-sized
    is ever materialized."""
    if x.shape[0] > norm_agg.MAX_FUSED_WORKERS:
        y = _bucket_first(x, key, bucket_size)
        if y.shape[0] <= norm_agg.MAX_FUSED_WORKERS:
            return norm_agg.krum_segments([y], n_byz=n_byz, tile_d=tile_d,
                                          interpret=interpret)[0]
        return norm_agg.krum_segments_blocked([y], n_byz=n_byz,
                                              tile_d=tile_d,
                                              interpret=interpret)[0]
    w = None
    if key is not None and bucket_size > 1:
        w = _perm_bucket_matrix(key, x.shape[0], bucket_size)
    return norm_agg.krum_segments([x], w_mat=w, n_byz=n_byz, tile_d=tile_d,
                                  interpret=interpret)[0]


def wire_agg(src, key=None, *, bucket_size: int = 1, rule: str = "median",
             trim: int = 1, n_byz: int = 1, iters: int = 8, eps: float = 1e-8,
             tile_d: int = norm_agg.DEFAULT_TILE_D, interpret=None):
    """ARAgg over a worker-stacked wire payload (``quantize.WireSrc``): the
    kernels decode + base-add + bucket + rule per (n, TILE_D) block, so the
    dense (n, d) candidate matrix never exists in HBM — the sweep reads the
    wire bytes instead. Any rule; same semantics as the dense wrappers over
    ``quantize.decode``-reconstructed candidates."""
    w = None
    if key is not None and bucket_size > 1:
        w = _perm_bucket_matrix(key, src.n, bucket_size)
    if rule in ("mean", "median", "trimmed"):
        return _robust_agg(src, w, rule=rule, trim=trim, tile_d=tile_d,
                           interpret=interpret)
    if rule == "rfa":
        return norm_agg.rfa_segments([src], w_mat=w, iters=iters, eps=eps,
                                     tile_d=tile_d, interpret=interpret)[0]
    if rule == "krum":
        return norm_agg.krum_segments([src], w_mat=w, n_byz=n_byz,
                                      tile_d=tile_d, interpret=interpret)[0]
    raise ValueError(rule)


def block_quantize(x, key, *, levels: int = 4, block: int = 256,
                   interpret=None):
    u = jax.random.uniform(key, x.shape)
    return _block_quantize(x, u, levels=levels, block=block,
                           interpret=resolve_interpret(interpret))


def robust_agg_oracle(x, *, bucket_size: int = 1, rule: str = "median",
                      trim: int = 1):
    return ref.robust_agg_ref(x, bucket_size=bucket_size, rule=rule, trim=trim)


def block_quantize_oracle(x, u, *, levels: int = 4, block: int = 256):
    return ref.block_quantize_ref(x, u, levels=levels, block=block)


def rfa_oracle(x, *, iters: int = 8, eps: float = 1e-8):
    return ref.rfa_ref(x, iters=iters, eps=eps)


def krum_oracle(x, *, n_byz: int = 1):
    return ref.krum_ref(x, n_byz=n_byz)
