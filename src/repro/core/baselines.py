"""Baseline methods the paper compares against (Section 3 / Appendix B).

* SGD          — Parallel-SGD with plain averaging (Zinkevich et al. 2010).
* BR-SGDm      — robust aggregation of worker momenta (Karimireddy 2021/22).
* CSGD         — compressed SGD; with a robust aggregator = BR-CSGD.
* BR-DIANA     — DIANA (Mishchenko et al. 2019) shifts + robust aggregation.
* Byrd-SVRG    — SVRG estimator + geometric median (App. B.4 proxy of
                 Byrd-SAGA; the paper itself uses SVRG since SAGA's per-sample
                 table is memory-hostile).

All share Byz-VR-MARINA's skeleton: stacked worker axis, omniscient attacks,
(δ,c)-robust aggregation, so every experiment toggles only the estimator.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.byz_vr_marina import ByzVRMarinaConfig, apply_attack, \
    _stacked_grads, _aggregate
from repro.core import tree_utils as tu


def _sgd_update(params, g, lr):
    return jax.tree.map(
        lambda x, gg: (x.astype(jnp.float32) - lr * gg.astype(jnp.float32)
                       ).astype(x.dtype), params, g)


def _maybe_corrupt(cfg, corrupt_fn, batch):
    if corrupt_fn is not None and cfg.attack.flips_labels and cfg.n_byz:
        return corrupt_fn(batch, cfg.byz_mask())
    return batch


# ---------------------------------------------------------------------------
# SGD / BR-SGDm
# ---------------------------------------------------------------------------

def make_sgd_step(cfg: ByzVRMarinaConfig, loss_fn, corrupt_fn=None,
                  momentum: float = 0.0):
    """momentum=0 -> Parallel-SGD; momentum>0 -> BR-SGDm (worker momenta are
    what gets attacked & aggregated, per Karimireddy et al. 2021)."""
    n = cfg.n_workers

    def step(state, batch, anchor, key):
        k_grad, k_attack, k_agg = jax.random.split(key, 3)
        batch = _maybe_corrupt(cfg, corrupt_fn, batch)
        wkeys = tu.per_worker_keys(k_grad, n)
        loss, grads = _stacked_grads(loss_fn, state["params"], batch, wkeys)
        if momentum > 0.0:
            m_new = jax.tree.map(
                lambda m, g: ((1 - momentum) * g.astype(jnp.float32)
                              + momentum * m.astype(jnp.float32)),
                state["worker_m"], grads)
            cand = m_new
        else:
            m_new = state["worker_m"]
            cand = grads
        sent = apply_attack(cfg, k_attack, cand)
        g = _aggregate(cfg, k_agg, sent)
        params = _sgd_update(state["params"], g, cfg.lr)
        new_state = {"params": params, "worker_m": m_new,
                     "step": state["step"] + 1}
        return new_state, {"loss": loss, "g_norm": jnp.sqrt(tu.tree_norm_sq(g))}

    def init(params):
        return {"params": params,
                "worker_m": tu.tree_broadcast_leading(
                    jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32),
                                 params), n),
                "step": jnp.zeros((), jnp.int32)}

    return init, step


# ---------------------------------------------------------------------------
# CSGD / BR-CSGD
# ---------------------------------------------------------------------------

def make_csgd_step(cfg: ByzVRMarinaConfig, loss_fn, corrupt_fn=None):
    n = cfg.n_workers

    def step(state, batch, anchor, key):
        k_grad, k_q, k_attack, k_agg = jax.random.split(key, 4)
        batch = _maybe_corrupt(cfg, corrupt_fn, batch)
        wkeys = tu.per_worker_keys(k_grad, n)
        qkeys = tu.per_worker_keys(k_q, n,
                                   common=cfg.compressor.common_randomness)

        def one(b, kg, kq):
            ln, g = jax.value_and_grad(loss_fn)(state["params"], b, kg)
            return ln, tu.compress_tree(cfg.compressor, kq, g)

        losses, cand = jax.vmap(one)(batch, wkeys, qkeys)
        sent = apply_attack(cfg, k_attack, cand)
        g = _aggregate(cfg, k_agg, sent)
        params = _sgd_update(state["params"], g, cfg.lr)
        return ({"params": params, "step": state["step"] + 1},
                {"loss": jnp.mean(losses),
                 "g_norm": jnp.sqrt(tu.tree_norm_sq(g))})

    def init(params):
        return {"params": params, "step": jnp.zeros((), jnp.int32)}

    return init, step


# ---------------------------------------------------------------------------
# BR-DIANA
# ---------------------------------------------------------------------------

def make_diana_step(cfg: ByzVRMarinaConfig, loss_fn, corrupt_fn=None,
                    alpha: Optional[float] = None):
    """DIANA: worker i keeps a shift h_i, uploads Q(g_i - h_i); the server
    adds the aggregated compressed difference to the shift mean. alpha
    defaults to 1/(1+omega) (Mishchenko et al. 2019)."""
    n = cfg.n_workers

    def step(state, batch, anchor, key):
        k_grad, k_q, k_attack, k_agg = jax.random.split(key, 4)
        batch = _maybe_corrupt(cfg, corrupt_fn, batch)
        wkeys = tu.per_worker_keys(k_grad, n)
        qkeys = tu.per_worker_keys(k_q, n,
                                   common=cfg.compressor.common_randomness)
        h = state["worker_h"]                                  # stacked (n,...)
        a = state["alpha"]

        def one(b, kg, kq, h_i):
            ln, g = jax.value_and_grad(loss_fn)(state["params"], b, kg)
            diff = tu.tree_sub(g, h_i)
            return ln, tu.compress_tree(cfg.compressor, kq, diff)

        losses, qdiff = jax.vmap(one)(batch, wkeys, qkeys, h)
        sent = apply_attack(cfg, k_attack, qdiff)
        agg_diff = _aggregate(cfg, k_agg, sent)
        h_mean = jax.tree.map(lambda x: jnp.mean(x, axis=0), h)
        g = tu.tree_add(h_mean, agg_diff)
        h_new = jax.tree.map(lambda hh, q: hh + a * q, h, qdiff)
        params = _sgd_update(state["params"], g, cfg.lr)
        return ({"params": params, "worker_h": h_new, "alpha": a,
                 "step": state["step"] + 1},
                {"loss": jnp.mean(losses),
                 "g_norm": jnp.sqrt(tu.tree_norm_sq(g))})

    def init(params, d_hint: int = 1):
        # d_hint is static (python int): used only to size alpha
        omega = cfg.compressor.omega(int(d_hint))
        a = alpha if alpha is not None else 1.0 / (1.0 + omega)
        return {"params": params,
                "worker_h": tu.tree_broadcast_leading(
                    jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32),
                                 params), n),
                "alpha": jnp.asarray(a, jnp.float32),
                "step": jnp.zeros((), jnp.int32)}

    return init, step


# ---------------------------------------------------------------------------
# Byrd-SVRG (App. B.4)
# ---------------------------------------------------------------------------

def make_br_mvr_step(cfg: ByzVRMarinaConfig, loss_fn, corrupt_fn=None,
                     alpha: float = 0.1):
    """BR-MVR (Karimireddy et al. 2021): momentum variance reduction
    (STORM/MVR estimator) per worker + robust aggregation.

        v_i^k = g_i(x^k) + (1-α)(v_i^{k-1} - g_i(x^{k-1}))
    """
    n = cfg.n_workers

    def step(state, batch, anchor, key):
        k_grad, k_attack, k_agg = jax.random.split(key, 3)
        batch = _maybe_corrupt(cfg, corrupt_fn, batch)
        wkeys = tu.per_worker_keys(k_grad, n)
        params, prev = state["params"], state["prev_params"]

        def one(b, kg, v_i):
            ln, gx = jax.value_and_grad(loss_fn)(params, b, kg)
            _, gp = jax.value_and_grad(loss_fn)(prev, b, kg)
            v_new = jax.tree.map(
                lambda g, vv, go: g.astype(jnp.float32)
                + (1 - alpha) * (vv - go.astype(jnp.float32)),
                gx, v_i, gp)
            return ln, v_new

        losses, v = jax.vmap(one)(batch, wkeys, state["worker_v"])
        sent = apply_attack(cfg, k_attack, v)
        g = _aggregate(cfg, k_agg, sent)
        new_params = _sgd_update(params, g, cfg.lr)
        return ({"params": new_params, "prev_params": params,
                 "worker_v": v, "step": state["step"] + 1},
                {"loss": jnp.mean(losses),
                 "g_norm": jnp.sqrt(tu.tree_norm_sq(g))})

    def init(params, batch, key):
        batch = _maybe_corrupt(cfg, corrupt_fn, batch)
        wkeys = tu.per_worker_keys(key, n)
        _, grads = _stacked_grads(loss_fn, params, batch, wkeys)
        v0 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        return {"params": params, "prev_params": params, "worker_v": v0,
                "step": jnp.zeros((), jnp.int32)}

    return init, step


def make_byrd_saga_step(cfg: ByzVRMarinaConfig, grad_sample_fn, n_samples,
                        params_template, corrupt_labels=None):
    """Byrd-SAGA (Wu et al. 2020): per-worker SAGA estimator (per-sample
    gradient table — O(m·d) memory, which is why the paper benchmarks the
    SVRG proxy instead; we provide the real thing for small problems) +
    geometric-median aggregation.

    grad_sample_fn(params, x_j, y_j) -> per-sample gradient pytree.
    The returned step takes idx (n, b) minibatch indices and data
    {"x": (n, m, d), "y": (n, m)} (stacked per worker).
    """
    n = cfg.n_workers
    m = n_samples

    def one_worker(params, table, table_mean, xw, yw, idx_w):
        def g_of(j):
            return grad_sample_fn(params, xw[j], yw[j])

        g_new = jax.vmap(g_of)(idx_w)                       # (b, ...)
        old = jax.tree.map(lambda t: t[idx_w], table)       # (b, ...)
        # SAGA estimator: mean_j[ g_new - old ] + table_mean
        v = jax.tree.map(
            lambda gn, go, tm: jnp.mean(gn - go, axis=0) + tm,
            g_new, old, table_mean)
        # table update
        new_table = jax.tree.map(lambda t, gn: t.at[idx_w].set(gn),
                                 table, g_new)
        new_mean = jax.tree.map(
            lambda tm, t_old, gn: tm + jnp.sum(
                gn - t_old[idx_w], axis=0) / m,
            table_mean, table, g_new)
        return v, new_table, new_mean

    def step(state, data, idx, key):
        k_attack, k_agg = jax.random.split(key)
        params = state["params"]
        xw, yw = data["x"], data["y"]
        if corrupt_labels is not None and cfg.attack.flips_labels \
                and cfg.n_byz:
            yw = corrupt_labels(yw, cfg.byz_mask())
        v, tables, means = jax.vmap(
            lambda t, tm, x, y, i: one_worker(params, t, tm, x, y, i)
        )(state["tables"], state["table_means"], xw, yw, idx)
        sent = apply_attack(cfg, k_attack, v)
        g = _aggregate(cfg, k_agg, sent)
        new_params = _sgd_update(params, g, cfg.lr)
        return ({"params": new_params, "tables": tables,
                 "table_means": means, "step": state["step"] + 1},
                {"g_norm": jnp.sqrt(tu.tree_norm_sq(g))})

    def init(params, data):
        def zero_table(leaf):
            return jnp.zeros((n, m) + leaf.shape, jnp.float32)

        tables = jax.tree.map(zero_table, params)
        means = jax.tree.map(
            lambda p: jnp.zeros((n,) + p.shape, jnp.float32), params)
        return {"params": params, "tables": tables, "table_means": means,
                "step": jnp.zeros((), jnp.int32)}

    return init, step


def make_byrd_svrg_step(cfg: ByzVRMarinaConfig, loss_fn, corrupt_fn=None):
    """Loopless SVRG: with prob p refresh the snapshot w <- x and the full
    worker gradients; each round worker i sends
    v_i = g_i(x, mb) - g_i(w, mb) + full_i, aggregated with RFA (geometric
    median) per Wu et al. (2020)."""
    n = cfg.n_workers

    def step(state, batch, anchor, key):
        k_bern, k_grad, k_attack, k_agg = jax.random.split(key, 4)
        c_k = jax.random.bernoulli(k_bern, cfg.p)
        batch = _maybe_corrupt(cfg, corrupt_fn, batch)
        anchor = _maybe_corrupt(cfg, corrupt_fn, anchor)
        wkeys = tu.per_worker_keys(k_grad, n)
        params = state["params"]

        def refresh(_):
            _, fulls = _stacked_grads(loss_fn, params, anchor, wkeys)
            return params, fulls

        def keep(_):
            return state["snapshot"], state["worker_full"]

        w, fulls = lax.cond(c_k, refresh, keep, operand=None)

        def one(b, kg, full_i):
            ln, gx = jax.value_and_grad(loss_fn)(params, b, kg)
            _, gw = jax.value_and_grad(loss_fn)(w, b, kg)
            v = tu.tree_add(tu.tree_sub(gx, gw), full_i)
            return ln, v

        losses, cand = jax.vmap(one)(batch, wkeys, fulls)
        sent = apply_attack(cfg, k_attack, cand)
        g = _aggregate(cfg, k_agg, sent)
        new_params = _sgd_update(params, g, cfg.lr)
        return ({"params": new_params, "snapshot": w, "worker_full": fulls,
                 "step": state["step"] + 1},
                {"loss": jnp.mean(losses),
                 "g_norm": jnp.sqrt(tu.tree_norm_sq(g))})

    def init(params, anchor, key):
        anchor = _maybe_corrupt(cfg, corrupt_fn, anchor)
        wkeys = tu.per_worker_keys(key, n)
        _, fulls = _stacked_grads(loss_fn, params, anchor, wkeys)
        return {"params": params, "snapshot": params, "worker_full": fulls,
                "step": jnp.zeros((), jnp.int32)}

    return init, step
