"""Training driver: Byzantine-robust LM training through the unified round
engine — any registered method (Byz-VR-MARINA or a baseline estimator), any
aggregation backend.

Runs end-to-end on whatever devices exist (1 CPU here; the production mesh on
a pod — same code path, mesh size is the only difference). Example:

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --reduced \\
      --steps 100 --n-workers 8 --n-byz 2 --attack ALIE --agg cm \\
      --method marina --agg-mode auto

--method picks the gradient estimator (core/estimators.py registry);
--agg-mode picks the aggregation backend: "auto" resolves to the fused
Pallas kernel path on TPU and the paper-faithful gspmd path elsewhere.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import save_checkpoint
from repro.configs import get_config
from repro.core import (ByzVRMarinaConfig, get_aggregator, get_attack,
                        get_compressor, list_methods, make_method)
from repro.data import TokenStream, corrupt_labels_lm
from repro.models import init_params, loss_fn
from repro.optim import get_optimizer


def resolve_agg_mode(mode: str) -> str:
    if mode != "auto":
        return mode
    # the fused one-HBM-sweep kernel is the default server-side backend on
    # real TPU backends; interpret-mode pallas would only slow a CPU host.
    return "pallas" if jax.default_backend() == "tpu" else "gspmd"


def build(args):
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    agg_mode = resolve_agg_mode(args.agg_mode)
    if agg_mode == "sparse_support":
        compressor = get_compressor(
            "randk",
            ratio=args.compress_ratio if args.compress_ratio < 1.0 else 0.1,
            common_randomness=True)
    elif args.compress_ratio < 1.0:
        compressor = get_compressor("randk", ratio=args.compress_ratio)
    else:
        compressor = get_compressor("identity")
    bcfg = ByzVRMarinaConfig(
        n_workers=args.n_workers,
        n_byz=args.n_byz,
        p=args.p,
        lr=args.lr,
        aggregator=get_aggregator(args.agg, bucket_size=args.bucket),
        compressor=compressor,
        attack=get_attack(args.attack),
        agg_mode=agg_mode,
        optimizer=(get_optimizer(args.opt, lr=args.lr)
                   if args.opt != "none" else None),
    )
    stream = TokenStream(
        vocab_size=cfg.vocab_size, seq_len=args.seq_len,
        n_workers=args.n_workers, per_worker_batch=args.per_worker_batch,
        num_codebooks=cfg.num_codebooks,
        frontend_tokens=cfg.frontend_tokens, d_model=cfg.d_model,
        heterogeneous=args.heterogeneous, seed=args.seed)

    def loss(params, batch, key):
        return loss_fn(params, cfg, batch, remat=args.remat)

    return cfg, bcfg, stream, loss


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen3-1.7b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--method", default="marina", choices=list_methods(),
                    help="gradient estimator plugged into the round engine")
    ap.add_argument("--agg-mode", default="auto",
                    choices=["auto", "gspmd", "pallas", "sparse_support"],
                    help="server-side aggregation backend")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--per-worker-batch", type=int, default=4)
    ap.add_argument("--n-workers", type=int, default=8)
    ap.add_argument("--n-byz", type=int, default=0)
    ap.add_argument("--p", type=float, default=0.1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--agg", default="cm")
    ap.add_argument("--bucket", type=int, default=2)
    ap.add_argument("--attack", default="NA")
    ap.add_argument("--compress-ratio", type=float, default=1.0)
    ap.add_argument("--opt", default="none", choices=["none", "sgd", "adam"])
    ap.add_argument("--remat", action="store_true")
    ap.add_argument("--heterogeneous", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--checkpoint", default=None)
    ap.add_argument("--metrics-out", default=None)
    args = ap.parse_args()

    cfg, bcfg, stream, loss = build(args)
    key = jax.random.PRNGKey(args.seed)
    k_init, k_run = jax.random.split(key)
    params = init_params(k_init, cfg)
    n_params = sum(x.size for x in jax.tree.leaves(params))
    print(f"[train] {args.arch} ({'reduced' if args.reduced else 'full'}): "
          f"{n_params/1e6:.1f}M params, method={args.method}, "
          f"{args.n_workers} workers ({args.n_byz} byzantine, "
          f"attack={args.attack}, agg={bcfg.aggregator.name}, "
          f"backend={bcfg.agg_mode})")

    method = make_method(args.method, bcfg, loss, corrupt_labels_lm)
    step = jax.jit(method.step)
    state = method.init(params, stream.anchor(0), k_run)

    history = []
    comm_bits_total = 0.0
    pending_ck = []          # device arrays; synced only on log steps so the
    t0 = time.time()         # loop keeps JAX's async dispatch pipelined
    for it in range(args.steps):
        k_it = jax.random.fold_in(k_run, it + 1)
        state, metrics = step(state, stream.minibatch(it), stream.anchor(it),
                              k_it)
        pending_ck.append(metrics["c_k"] if "c_k" in metrics else None)
        if it % args.log_every == 0 or it == args.steps - 1:
            for ck in pending_ck:
                comm_bits_total += method.round_bits(
                    n_params, True if ck is None else bool(ck))
            pending_ck.clear()
            m = {k: float(v) for k, v in metrics.items()}
            m["step"] = it
            m["wall_s"] = round(time.time() - t0, 2)
            m["comm_gbits"] = round(comm_bits_total / 1e9, 4)
            history.append(m)
            ck = f" c_k={int(m['c_k'])}" if "c_k" in m else ""
            print(f"  step {it:5d} loss {m['loss']:.4f} "
                  f"|g| {m['g_norm']:.3e}{ck} "
                  f"comm {m['comm_gbits']:.3g}Gb ({m['wall_s']}s)")

    if args.checkpoint:
        save_checkpoint(args.checkpoint, state["params"],
                        step=int(state["step"]))
        print(f"[train] checkpoint -> {args.checkpoint}.npz")
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(history, f, indent=1)
    return history


if __name__ == "__main__":
    main()
