"""Sweep-engine baseline: serial vs batched execution of a synthetic
3-aggregator x 3-attack x 5-seed logreg grid (45 cells, 9 jit-signature
groups).

Measures cells/sec and the step-compile count for both engines and writes
``experiments/bench/BENCH_sweep.json`` so future PRs have a perf
trajectory to beat — the batched engine's contract is 9 compiles (one per
group) against the serial engine's 45 (one per cell).

  PYTHONPATH=src python -m benchmarks.bench_sweep [--steps 20]
"""
import argparse
import json
import os
import time

from benchmarks.common import ART_DIR
from repro import exec as xc
from repro.api import RunSpec, Sweep

BASE = RunSpec(task="logreg", method="marina", n_workers=5, n_byz=1,
               p=0.2, lr=0.4, bucket_size=2, steps=20,
               data_kwargs={"n_samples": 120, "dim": 12, "batch_size": 16,
                            "data_seed": 0})
GRID = {
    "aggregator": ("mean", "cm", "tm"),
    "attack": ("NA", "BF", "ALIE"),
    "seed": tuple(range(5)),
}


def _time_engine(cells, batch, run_kw):
    t0 = time.perf_counter()
    srun = xc.run_cells(cells, batch=batch, run_kw=run_kw)
    dt = time.perf_counter() - t0
    assert not srun.failures, srun.failures
    return {"wall_s": round(dt, 3),
            "cells_per_s": round(len(cells) / dt, 3),
            "step_compiles": srun.stats["step_compiles"],
            "vmapped_groups": srun.stats["vmapped_groups"],
            "serial_cells": srun.stats["serial_cells"]}


def run(steps=20):
    sweep = Sweep(BASE.replace(steps=steps), GRID)
    cells = list(sweep.expand())
    run_kw = {"log_every": steps}
    serial = _time_engine(cells, False, run_kw)
    batched = _time_engine(cells, "auto", run_kw)
    payload = {
        "grid": "3 aggregators x 3 attacks x 5 seeds (logreg)",
        "n_cells": len(cells), "n_groups": len(xc.group_cells(cells)),
        "steps": steps,
        "serial": serial, "batched": batched,
        "speedup": round(serial["wall_s"] / batched["wall_s"], 2),
        "compile_reduction": round(
            serial["step_compiles"] / max(batched["step_compiles"], 1), 2),
    }
    os.makedirs(ART_DIR, exist_ok=True)
    with open(os.path.join(ART_DIR, "BENCH_sweep.json"), "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)
    print(f"sweep/serial,{serial['wall_s'] * 1e6:.1f},"
          f"cells_per_s={serial['cells_per_s']};"
          f"compiles={serial['step_compiles']}")
    print(f"sweep/batched,{batched['wall_s'] * 1e6:.1f},"
          f"cells_per_s={batched['cells_per_s']};"
          f"compiles={batched['step_compiles']};"
          f"speedup={payload['speedup']}x")
    return payload


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=20)
    run(steps=ap.parse_args().steps)
