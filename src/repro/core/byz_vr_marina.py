"""Byz-VR-MARINA (Algorithm 1) — the paper's contribution as a composable
JAX trainer.

One implementation serves both scales:

* laptop scale — ``n_workers`` simulated with ``vmap`` on one device (the
  paper's own logreg experiments, the convergence tests, the examples);
* pod scale — the same step ``jit``-ed onto the production mesh with the
  worker axis of every stacked input sharded over ``("pod", "data")`` and
  params/grads sharded over ``"model"`` (launch/train.py, launch/dryrun.py).

Per iteration (paper lines 4-10):

    c_k ~ Be(p)                                    (shared coin, broadcast)
    x^{k+1} = x^k - γ g^k                          (or any optim.Optimizer)
    good i: g_i = ∇f_i(x^{k+1})                    if c_k = 1   (anchor batch)
            g_i = g^k + Q(Δ̂_i(x^{k+1}, x^k))      otherwise    (minibatch)
    byz  i: g_i = attack(...)                      (omniscient; masked psums)
    g^{k+1} = ARAgg(g_1, ..., g_n)                 (bucketing + CM/RFA/Krum)

Since the unified-round-engine refactor (DESIGN.md §2) this module is a thin
facade: the round skeleton lives in ``core/engine.py``, the MARINA estimator
(dense + sparse-support) in ``core/estimators.py``, and this file keeps the
config, the legacy ``make_step`` / ``make_init`` entry points, and the
communication accounting. ``cfg.agg_mode`` selects the aggregation backend
(``engine.AGG_BACKENDS``): gspmd | all_to_all | sparse_support | pallas —
see core/sharded_agg.py and kernels/robust_agg.py for the beyond-paper
backends.
"""
from __future__ import annotations

import dataclasses
import warnings
from typing import Callable, Optional

import jax.numpy as jnp

from repro.core.aggregators import Aggregator
from repro.core.attacks import Attack, no_attack
from repro.core.compressors import Compressor, identity
from repro.core.engine import (AGG_BACKENDS, apply_attack,     # noqa: F401
                               make_method, stacked_grads, aggregate)


@dataclasses.dataclass(frozen=True)
class ByzVRMarinaConfig:
    n_workers: int
    n_byz: int = 0
    # partial participation: number of workers sampled each round (uniform
    # without replacement, seeded stream disjoint from attack/fault RNG).
    # None = all n_workers participate — compiles the identical program as
    # before the field existed.
    n_active: Optional[int] = None
    p: float = 0.1                       # full-gradient probability
    lr: float = 0.05
    aggregator: Aggregator = Aggregator("mean")
    compressor: Compressor = dataclasses.field(default_factory=identity)
    attack: Attack = dataclasses.field(default_factory=no_attack)
    agg_mode: str = "gspmd"   # gspmd | all_to_all | sparse_support | pallas
    optimizer: Optional[object] = None   # optim.Optimizer or None = plain SGD
    # distributed extras
    worker_axes: tuple = ()              # mesh axes carrying the worker dim
    model_axis: Optional[str] = None
    mesh: Optional[object] = None        # jax Mesh (all_to_all mode)
    grad_specs: Optional[object] = None  # PartitionSpec pytree (all_to_all)
    # system-fault chaos layer (repro.faults, DESIGN.md §6)
    fault_plan: Optional[object] = None  # faults.FaultPlan or None
    fault_guard: bool = False            # fail-closed non-finite masking

    def __post_init__(self):
        """Eager validation: a bad agg_mode / byzantine count used to
        surface as a bare ValueError at call time *inside jit* (or as a
        silently-poisoned aggregate); fail at construction instead."""
        if self.agg_mode not in AGG_BACKENDS:
            raise ValueError(
                f"agg_mode {self.agg_mode!r} not in {AGG_BACKENDS} "
                "(see engine.AGG_BACKENDS / DESIGN.md §3)")
        if not 0.0 <= self.p <= 1.0:
            raise ValueError(f"p={self.p} must be a probability in [0, 1]")
        if self.n_workers < 1:
            raise ValueError(f"n_workers={self.n_workers} must be >= 1")
        from repro.core.theory import delta_over_active_set
        if (not 0 <= self.n_byz
                or delta_over_active_set(self.n_workers, self.n_byz) >= 0.5):
            raise ValueError(
                f"n_byz={self.n_byz} must satisfy 0 <= n_byz < n_workers/2 "
                f"(= {self.n_workers / 2:g}): no (delta,c)-robust aggregator "
                "exists for a byzantine majority (Def. 2.1)")
        if self.n_active is not None:
            if not 1 <= self.n_active <= self.n_workers:
                raise ValueError(
                    f"n_active={self.n_active} must be in [1, n_workers="
                    f"{self.n_workers}]")
            if self.n_active < self.n_workers \
                    and self.agg_mode not in ("gspmd", "pallas"):
                raise ValueError(
                    f"partial participation (n_active={self.n_active}) is "
                    f"not supported under agg_mode={self.agg_mode!r}: the "
                    "masked aggregation prologue lives in the gspmd and "
                    "pallas backends (DESIGN.md §7)")
        n_act = self.active_count()
        s = max(self.aggregator.bucket_size, 1)
        if (self.aggregator.robust and s > 1
                and delta_over_active_set(
                    n_act, self.n_byz, bucket_size=s) >= 0.5):
            warnings.warn(
                f"after bucketing (s={s}) the byzantine fraction over the "
                f"active set is "
                f"{delta_over_active_set(n_act, self.n_byz, bucket_size=s):.2f}"
                " >= 1/2; Def. 2.1's robustness guarantee is void — reduce "
                "bucket_size or n_byz",
                stacklevel=2)
        if self.fault_plan is not None:
            f = self.fault_plan.worst_case_faulty(self.n_workers)
            if f and delta_over_active_set(n_act, self.n_byz + f) >= 0.5:
                warnings.warn(
                    f"fault plan can corrupt up to f={f} workers on top of "
                    f"n_byz={self.n_byz}: byz+faulty over the active set "
                    f"(n_active={n_act}) reaches >= 1/2, so the guarded δ "
                    "budget is exceeded in the worst round — the masked "
                    "aggregate may be unprotected (DESIGN.md §6)",
                    stacklevel=2)

    def active_count(self) -> int:
        """Workers sampled per round; n_workers when participation is off."""
        return self.n_workers if self.n_active is None else self.n_active

    def byz_mask(self):
        return jnp.arange(self.n_workers) < self.n_byz


def train_state(params, g0, opt_state=None, step=0):
    return {"params": params, "g": g0, "opt_state": opt_state,
            "step": jnp.asarray(step, jnp.int32)}


# ---------------------------------------------------------------------------
# legacy entry points — thin wrappers over the shared round engine
# ---------------------------------------------------------------------------

def make_step(cfg: ByzVRMarinaConfig, loss_fn: Callable,
              corrupt_fn: Optional[Callable] = None):
    """loss_fn(params, batch, key) -> scalar loss.

    ``batch`` / ``anchor`` passed to the returned step are stacked pytrees
    with a leading worker axis (n, ...). ``corrupt_fn(batch, byz_mask)``
    implements data-level attacks (label flipping).
    """
    return make_method("marina", cfg, loss_fn, corrupt_fn).step


def make_init(cfg: ByzVRMarinaConfig, loss_fn: Callable,
              corrupt_fn: Optional[Callable] = None):
    """g^0 initialization (paper: g^0 = ARAgg(∇f_1(x^0), ..., ∇f_n(x^0)))."""
    return make_method("marina", cfg, loss_fn, corrupt_fn).init


# ---------------------------------------------------------------------------
# communication accounting (paper Fig. 8 / footnote 3)
# ---------------------------------------------------------------------------

def comm_bits(cfg: ByzVRMarinaConfig, d: int, c_k: bool) -> int:
    """Bits uploaded per worker this round (delegates to the estimator's
    own accounting so legacy and registry callers can never diverge)."""
    from repro.core.estimators import MarinaEstimator
    return MarinaEstimator().round_bits(cfg, d, bool(c_k))


def expected_comm_bits(cfg: ByzVRMarinaConfig, d: int) -> float:
    from repro.core.estimators import MarinaEstimator
    return MarinaEstimator().expected_bits(cfg, d)
