"""Declarative experiment API (DESIGN.md §1.5).

One serializable ``RunSpec`` describes any method x attack x backend
experiment; ``build``/``run`` assemble and drive it through the unified
round engine, ``Sweep`` expands grids, and ``registry`` enumerates every
pluggable component from one source of truth.

    from repro.api import RunSpec, run
    result = run(RunSpec(task="logreg", method="marina", attack="ALIE",
                         aggregator="cm", steps=300))
"""
from repro.api.registry import (  # noqa: F401
    check, components, describe, kinds, resolve,
)
from repro.api.spec import RunSpec, ServeSpec, resolve_agg_mode  # noqa: F401
from repro.api.runner import (  # noqa: F401
    Experiment, RunResult, build, run,
)
from repro.api.sweep import Sweep, run_sweep  # noqa: F401
