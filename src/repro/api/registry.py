"""One namespaced component registry (DESIGN.md §1.5).

Before this layer, five ad-hoc registries lived in five modules (estimator
factories, attack factories, aggregator rules, compressor factories,
optimizer classes) plus the arch-config registry — and every CLI hard-coded
its own ``choices=[...]`` subset, which is how ``--agg-mode`` drifted out of
sync with ``engine.AGG_BACKENDS``. This module folds them into ONE namespaced
view so CLIs, docs, and ``RunSpec`` validation all enumerate from the same
source of truth:

    components("method")            -> ("byz_ef21", "cmfilter", ..., "svrg")
    describe("attack", "ALIE")      -> one-line summary
    resolve("compressor", "randk", ratio=0.1) -> Compressor instance
    check("aggregator", "krun")     -> ValueError: ... did you mean 'krum'?

The underlying per-module registries remain the single owners of their
entries (this module only *references* them), so registering a new estimator
in ``core/estimators.py`` or a new arch config automatically shows up here.
"""
from __future__ import annotations

import difflib
from typing import Any, Optional

from repro.configs import get_config, list_configs
from repro.core import aggregators as _aggregators
from repro.core import attacks as _attacks
from repro.core import compressors as _compressors
from repro.core.engine import AGG_BACKENDS
from repro.optim import optimizers as _optimizers


# ---------------------------------------------------------------------------
# per-kind descriptions (the factories' own docstrings are multi-paragraph;
# these are the one-liners surfaced by `describe` / CLI help)
# ---------------------------------------------------------------------------

_METHOD_DESCRIPTIONS = {
    "marina": "Byz-VR-MARINA (Alg. 1): geometric coin between anchor "
              "full-gradients and compressed SARAH differences",
    "sgd": "Parallel-SGD with (robust) averaging (Zinkevich et al. 2010)",
    "sgdm": "BR-SGDm: worker momenta attacked & aggregated "
            "(Karimireddy et al. 2021/22)",
    "csgd": "compressed SGD; with a robust aggregator = BR-CSGD",
    "diana": "BR-DIANA: worker shifts h_i, uploads Q(g_i - h_i) "
             "(Mishchenko et al. 2019)",
    "mvr": "BR-MVR / STORM momentum variance reduction "
           "(Karimireddy et al. 2021)",
    "svrg": "Byrd-SVRG: loopless SVRG + robust aggregation "
            "(App. B.4, Wu et al. 2020)",
    "byz_ef21": "Byz-EF21: biased contractive compression + per-worker "
                "error feedback (Rammal et al. 2023)",
    "cmfilter": "compressed momentum filtering: worker momenta uploaded as "
                "compressed differences, robustly filtered "
                "(Liu et al. 2024)",
    "saga": "Byrd-SAGA: per-worker per-sample gradient table over the "
            "anchor partition (Wu et al. 2020)",
}

_ATTACK_DESCRIPTIONS = {
    "NA": "no attack (clean training)",
    "LF": "label flipping (data-level; update hook is identity)",
    "BF": "bit flipping: send -honest",
    "ALIE": "A Little Is Enough: mean - z*std (Baruch et al. 2019)",
    "IPM": "inner-product manipulation: -eps*mean (Xie et al. 2020)",
    "RN": "random gaussian noise",
}

_AGGREGATOR_DESCRIPTIONS = {
    "mean": "plain averaging (not robust; the paper's AVG row)",
    "cm": "coordinate-wise median (c=O(d), delta<1/2 with bucketing)",
    "tm": "coordinate-wise trimmed mean",
    "rfa": "geometric median via smoothed Weiszfeld (c=O(1), delta<1/2)",
    "krum": "Krum selection rule (c=O(1), delta<1/4 with bucketing)",
}

_COMPRESSOR_DESCRIPTIONS = {
    "identity": "no compression (32d bits per vector)",
    "randk": "RandK sparsification, omega = d/K - 1 "
             "(block selection above 2^22 units)",
    "topk": "TopK magnitude sparsification (BIASED, contractive "
            "delta=1-K/d; EF21-family methods)",
    "dither": "l2 random dithering / QSGD-style quantization "
              "(Alistarh et al. 2017)",
    "natural": "natural compression: stochastic power-of-two rounding, "
               "omega = 1/8",
    "sign": "sign(x)*||x||_1/d (BIASED; signSGD baselines only)",
    "int8": "blockwise l2-dithering on a real int8 wire (QSGD s=127 per "
            "256-coord block; fused pallas payload)",
    "bf16": "deterministic bfloat16 rounding (BIASED, contractive "
            "delta=2^-16; the trivial kernel wire)",
}

_OPTIMIZER_DESCRIPTIONS = {
    "none": "plain x <- x - lr*g (the paper's Alg. 1 update)",
    "sgd": "SGD with optional momentum / weight decay on top of the "
           "robust estimator",
    "adam": "Adam(W) on top of the robust estimator",
}

_AGG_MODE_DESCRIPTIONS = {
    "gspmd": "paper-faithful jnp over the stacked worker axis "
             "(GSPMD all-gather on a mesh)",
    "all_to_all": "shard_map sharded aggregation: ~2x d_local collective "
                  "bytes, O(n) less memory (coordinate-wise rules only)",
    "sparse_support": "common-randomness RandK: attack + aggregate only the "
                      "shared K-coordinate support (marina)",
    "pallas": "fused one-HBM-sweep kernels serving every rule leaf-wise, "
              "with kernel-fusable attacks injected in the load",
}

_TASK_DESCRIPTIONS = {
    "logreg": "l2-regularized logistic regression on synthetic a9a-like "
              "data (the paper's own experiments)",
    "lm": "synthetic-token LM training on a registered arch config "
          "(framework scale)",
}

TASKS = tuple(sorted(_TASK_DESCRIPTIONS))
OPTIMIZER_CHOICES = ("none",) + tuple(sorted(_optimizers.OPTIMIZERS))


# ---------------------------------------------------------------------------
# kind table: name -> (component enumerator, describe fn, resolver)
# ---------------------------------------------------------------------------

def _method_names():
    from repro.core.estimators import ESTIMATORS
    return tuple(sorted(ESTIMATORS))


def _resolve_method(name, **kw):
    """Methods are (cfg, loss_fn)-bound; resolve returns the estimator
    factory — use ``engine.make_method`` / ``RunSpec.method_kwargs`` to
    configure one, so estimator knobs can't be dropped silently here."""
    if kw:
        raise TypeError(
            f"resolve('method', {name!r}, ...) takes no kwargs — estimator "
            "knobs go through make_method(...) or RunSpec.method_kwargs; "
            f"got {sorted(kw)}")
    from repro.core.estimators import ESTIMATORS
    return ESTIMATORS[name]


_KINDS = {
    "method": (_method_names,
               lambda n: _METHOD_DESCRIPTIONS.get(n, ""),
               _resolve_method),
    "attack": (lambda: tuple(sorted(_attacks.REGISTRY)),
               lambda n: _ATTACK_DESCRIPTIONS.get(n, ""),
               lambda n, **kw: _attacks.get_attack(n, **kw)),
    "aggregator": (lambda: tuple(sorted(_aggregators.RULES)),
                   lambda n: _AGGREGATOR_DESCRIPTIONS.get(n, ""),
                   lambda n, **kw: _aggregators.get_aggregator(n, **kw)),
    "compressor": (lambda: tuple(sorted(_compressors.REGISTRY)),
                   lambda n: _COMPRESSOR_DESCRIPTIONS.get(n, ""),
                   lambda n, **kw: _compressors.get_compressor(n, **kw)),
    "optimizer": (lambda: OPTIMIZER_CHOICES,
                  lambda n: _OPTIMIZER_DESCRIPTIONS.get(n, ""),
                  lambda n, **kw: (None if n == "none"
                                   else _optimizers.get_optimizer(n, **kw))),
    "agg_mode": (lambda: tuple(AGG_BACKENDS),
                 lambda n: _AGG_MODE_DESCRIPTIONS.get(n, ""),
                 lambda n, **kw: n),
    "arch": (lambda: tuple(list_configs()),
             lambda n: (lambda c: f"{c.family}: {c.citation}")(get_config(n)),
             lambda n, **kw: get_config(n)),
    "task": (lambda: TASKS,
             lambda n: _TASK_DESCRIPTIONS.get(n, ""),
             lambda n, **kw: n),
}


def kinds() -> tuple:
    """All registered component namespaces."""
    return tuple(sorted(_KINDS))


def components(kind: str) -> tuple:
    """Registered names under ``kind``, sorted."""
    _check_kind(kind)
    return _KINDS[kind][0]()


def describe(kind: str, name: Optional[str] = None):
    """One-line summary of ``name``, or {name: summary} for the whole kind."""
    _check_kind(kind)
    if name is None:
        return {n: _KINDS[kind][1](n) for n in components(kind)}
    check(kind, name)
    return _KINDS[kind][1](name)


def check(kind: str, name: str) -> str:
    """Validate ``name`` is registered under ``kind``; raise a did-you-mean
    ValueError otherwise. Returns the name so it composes in expressions."""
    _check_kind(kind)
    known = components(kind)
    if name not in known:
        raise ValueError(_unknown(kind, name, known))
    return name


def resolve(kind: str, name: str, **kwargs) -> Any:
    """Build the named component (e.g. a Compressor instance)."""
    check(kind, name)
    return _KINDS[kind][2](name, **kwargs)


def _check_kind(kind: str) -> None:
    if kind not in _KINDS:
        raise ValueError(_unknown("registry kind", kind, sorted(_KINDS)))


def _unknown(kind: str, name, known) -> str:
    msg = f"unknown {kind} {name!r}; registered: {', '.join(known)}"
    close = difflib.get_close_matches(str(name), [str(k) for k in known],
                                      n=1, cutoff=0.6)
    if close:
        msg += f" — did you mean {close[0]!r}?"
    return msg
