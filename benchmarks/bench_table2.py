"""Paper Table 2 (empirical analogue): communication rounds to reach a target
optimality gap, Byz-VR-MARINA vs BR-SGDm / BR-CSGD / BR-DIANA / Byrd-SVRG,
under the ALIE attack. Also reports uploaded bits per worker to reach the
target (the compression win)."""
import time

import jax

from benchmarks.common import emit, make_logreg_problem
from repro.core import (ByzVRMarinaConfig, expected_comm_bits, get_aggregator,
                        get_attack, get_compressor, make_init, make_step)
from repro.core.baselines import (make_byrd_svrg_step, make_csgd_step,
                                  make_diana_step, make_sgd_step)
from repro.data import corrupt_labels_logreg, init_logreg_params

KEY = jax.random.PRNGKey(1)
DIM = 30
TARGET = 1e-3
MAX_ROUNDS = 1200


def _rounds_to_target(data, loss_fn, full, f_star, state, step, d,
                      bits_per_round):
    k = KEY
    check = jax.jit(lambda p: loss_fn(p, full))
    anchor = data.stacked()
    for it in range(MAX_ROUNDS):
        k, k1, k2 = jax.random.split(k, 3)
        state, _ = step(state, data.sample_batches(k1, 32), anchor, k2)
        if (it + 1) % 25 == 0:
            if float(check(state["params"])) - f_star < TARGET:
                return it + 1
    return -1


def run():
    data, loss_fn, full, f_star = make_logreg_problem(KEY, dim=DIM)
    anchor = data.stacked()
    d = DIM + 1
    agg = get_aggregator("cm", bucket_size=2)
    atk = get_attack("ALIE")
    randk = get_compressor("randk", ratio=0.1)

    def report(name, rounds, bits_per_round):
        bits = rounds * bits_per_round if rounds > 0 else float("inf")
        emit(f"table2/{name}", float(rounds),
             f"rounds_to_{TARGET:g}={rounds};bits/worker={bits:.3g}")

    # Byz-VR-MARINA (no compression)
    cfg = ByzVRMarinaConfig(n_workers=5, n_byz=1, p=0.1, lr=0.5,
                            aggregator=agg, attack=atk)
    st = make_init(cfg, loss_fn, corrupt_labels_logreg)(
        init_logreg_params(DIM), anchor, KEY)
    r = _rounds_to_target(data, loss_fn, full, f_star, st,
                          jax.jit(make_step(cfg, loss_fn,
                                            corrupt_labels_logreg)), d, 0)
    report("byz-vr-marina", r, 32 * d)

    # Byz-VR-MARINA + RandK
    cfgc = ByzVRMarinaConfig(n_workers=5, n_byz=1, p=0.1, lr=0.5,
                             aggregator=agg, compressor=randk, attack=atk)
    st = make_init(cfgc, loss_fn, corrupt_labels_logreg)(
        init_logreg_params(DIM), anchor, KEY)
    r = _rounds_to_target(data, loss_fn, full, f_star, st,
                          jax.jit(make_step(cfgc, loss_fn,
                                            corrupt_labels_logreg)), d, 0)
    report("byz-vr-marina+randk", r, expected_comm_bits(cfgc, d))

    # BR-SGDm
    cfg2 = ByzVRMarinaConfig(n_workers=5, n_byz=1, lr=0.5, aggregator=agg,
                             attack=atk)
    init_s, step_s = make_sgd_step(cfg2, loss_fn, corrupt_labels_logreg,
                                   momentum=0.9)
    r = _rounds_to_target(data, loss_fn, full, f_star,
                          init_s(init_logreg_params(DIM)), jax.jit(step_s),
                          d, 0)
    report("br-sgdm", r, 32 * d)

    # BR-CSGD
    cfg3 = ByzVRMarinaConfig(n_workers=5, n_byz=1, lr=0.5, aggregator=agg,
                             compressor=randk, attack=atk)
    init_c, step_c = make_csgd_step(cfg3, loss_fn, corrupt_labels_logreg)
    r = _rounds_to_target(data, loss_fn, full, f_star,
                          init_c(init_logreg_params(DIM)), jax.jit(step_c),
                          d, 0)
    report("br-csgd+randk", r, randk.bits_per_vector(d))

    # BR-DIANA
    init_d, step_d = make_diana_step(cfg3, loss_fn, corrupt_labels_logreg)
    r = _rounds_to_target(data, loss_fn, full, f_star,
                          init_d(init_logreg_params(DIM), d_hint=d),
                          jax.jit(step_d), d, 0)
    report("br-diana+randk", r, randk.bits_per_vector(d))

    # Byrd-SVRG
    cfg4 = ByzVRMarinaConfig(n_workers=5, n_byz=1, p=0.1, lr=0.5,
                             aggregator=get_aggregator("rfa", bucket_size=2),
                             attack=atk)
    init_v, step_v = make_byrd_svrg_step(cfg4, loss_fn, corrupt_labels_logreg)
    r = _rounds_to_target(data, loss_fn, full, f_star,
                          jax.jit(init_v)(init_logreg_params(DIM), anchor,
                                          KEY),
                          jax.jit(step_v), d, 0)
    report("byrd-svrg", r, 32 * d)


if __name__ == "__main__":
    run()
