"""Build the EXPERIMENTS.md §Dry-run / §Roofline tables from the JSONs."""
import glob
import json
import os
import sys

DIR = sys.argv[1] if len(sys.argv) > 1 else "experiments/dryrun"


def fmt(x):
    return f"{x:.2e}"


def main():
    recs = []
    for f in sorted(glob.glob(os.path.join(DIR, "*.json"))):
        base = os.path.basename(f)[:-5]
        parts = base.split("__")
        if len(parts) != 3:
            continue   # perf-variant files handled separately
        recs.append(json.load(open(f)))

    shapes = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    print("## Roofline (single-pod 16x16, 256 chips; v5e constants)\n")
    print("| arch | shape | compute_s | memory_s | collective_s | dominant |"
          " useful_FLOPs | temp_GiB/dev |")
    print("|---|---|---|---|---|---|---|---|")
    singles = [r for r in recs if r["mesh"] == "single"]
    order = {s: i for i, s in enumerate(shapes)}
    singles.sort(key=lambda r: (r["arch"], order.get(r["shape"], 9)))
    for r in singles:
        if not r.get("ok"):
            print(f"| {r['arch']} | {r['shape']} | FAILED: "
                  f"{r.get('error','')[:60]} | | | | | |")
            continue
        rf = r["roofline"]
        tmp = r["memory_analysis"].get("temp_size_in_bytes", 0) / 2**30
        uf = rf.get("useful_flop_ratio")
        print(f"| {r['arch']} | {r['shape']} | {fmt(rf['compute_s'])} | "
              f"{fmt(rf['memory_s'])} | {fmt(rf['collective_s'])} | "
              f"{rf['dominant'].replace('_s','')} | "
              f"{uf:.3f} | {tmp:.1f} |" if uf else
              f"| {r['arch']} | {r['shape']} | - | - | - | - | - | - |")

    print("\n## Multi-pod (2x16x16, 512 chips) compile status\n")
    print("| arch | shape | ok | compile_s | collective_bytes/dev |")
    print("|---|---|---|---|---|")
    multis = [r for r in recs if r["mesh"] == "multi"]
    multis.sort(key=lambda r: (r["arch"], order.get(r["shape"], 9)))
    for r in multis:
        cb = r.get("collectives", {}).get("total_bytes", 0) if r.get("ok") \
            else "-"
        print(f"| {r['arch']} | {r['shape']} | {r.get('ok')} | "
              f"{r.get('compile_s','-')} | {cb:.3g} |"
              if r.get("ok") else
              f"| {r['arch']} | {r['shape']} | FAIL | - | - |")

    # hillclimb candidates
    print("\n## Hillclimb candidate analysis (single-pod)\n")
    worst_compute_frac = None
    most_collective = None
    for r in singles:
        if not r.get("ok"):
            continue
        rf = r["roofline"]
        tot = rf["compute_s"] + rf["memory_s"] + rf["collective_s"]
        frac = rf["compute_s"] / tot if tot else 0
        if worst_compute_frac is None or frac < worst_compute_frac[0]:
            worst_compute_frac = (frac, r["arch"], r["shape"])
        cfrac = rf["collective_s"] / tot if tot else 0
        if most_collective is None or cfrac > most_collective[0]:
            most_collective = (cfrac, r["arch"], r["shape"])
    print("worst compute fraction:", worst_compute_frac)
    print("most collective-bound:", most_collective)


if __name__ == "__main__":
    main()
