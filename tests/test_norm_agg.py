"""kernels/norm_agg + the zero-copy pallas message phase vs the jnp oracles.

Coverage pinned by ISSUE 4:
  * Pallas rfa/krum ≡ ``Aggregator.tree`` under every attack in the registry
  * non-bucket-multiple n, bf16 leaves, multi-leaf trees incl. the packed
    tiny-leaf buffer
  * in-kernel permutation (``bucket_matrix``) ≡ ``_bucketize_perm``
  * the fused message phase allocates no (n, d) attacked copy and no
    concatenated (n, D) flat intermediate (jaxpr scan)
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ByzVRMarinaConfig, get_aggregator, get_attack
from repro.core.aggregators import Aggregator, _bucketize_perm
from repro.core.attacks import REGISTRY
from repro.core.engine import apply_attack, message_phase
from repro.core.sharded_agg import tree_aggregate_pallas
from repro.kernels import norm_agg, ops

KEY = jax.random.PRNGKey(0)


def _tree(key, n, dims, dtype=jnp.float32):
    ks = jax.random.split(key, len(dims))
    return {f"p{i}": jax.random.normal(k, (n,) + d).astype(dtype)
            for i, (k, d) in enumerate(zip(ks, dims))}


def _cfg(rule, bucket=0, attack="NA", n=8, n_byz=2, mode="pallas"):
    return ByzVRMarinaConfig(
        n_workers=n, n_byz=n_byz,
        aggregator=get_aggregator(rule, bucket_size=bucket, n_byz=n_byz),
        attack=get_attack(attack), agg_mode=mode)


# ---------------------------------------------------------------------------
# bucket_matrix: the in-kernel permutation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,s", [(8, 2), (16, 4), (5, 2), (7, 3), (9, 4)])
def test_bucket_matrix_matches_bucketize_perm(n, s):
    """W @ x ≡ aggregators._bucketize_perm(x, perm, s) — incl. the
    stacked-mean padding of a partial last bucket (Alg. 2)."""
    x = jax.random.normal(jax.random.fold_in(KEY, 11 * n + s), (n, 300))
    perm = jax.random.permutation(jax.random.fold_in(KEY, n - s), n)
    w = norm_agg.bucket_matrix(perm, n, s)
    assert w.shape == (-(-n // s), n)
    np.testing.assert_allclose(np.asarray(w @ x),
                               np.asarray(_bucketize_perm(x, perm, s)),
                               atol=1e-5)


# ---------------------------------------------------------------------------
# flat kernels vs the Aggregator oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [5, 8, 16])
@pytest.mark.parametrize("d", [128, 1500])
@pytest.mark.parametrize("bucket", [0, 2, 3])
def test_rfa_kernel_matches_oracle(n, d, bucket):
    x = jax.random.normal(jax.random.fold_in(KEY, n * d + bucket), (n, d))
    agg = Aggregator("rfa", bucket_size=bucket)
    got = ops.rfa_agg(x, KEY, bucket_size=max(bucket, 1), interpret=True)
    want = agg(KEY, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


@pytest.mark.parametrize("n", [5, 8, 16])
@pytest.mark.parametrize("d", [128, 1500])
@pytest.mark.parametrize("bucket", [0, 2, 3])
def test_krum_kernel_matches_oracle(n, d, bucket):
    x = jax.random.normal(jax.random.fold_in(KEY, n * d - bucket), (n, d))
    agg = Aggregator("krum", bucket_size=bucket, n_byz=1)
    got = ops.krum_agg(x, KEY, bucket_size=max(bucket, 1), n_byz=1,
                       interpret=True)
    want = agg(KEY, x)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


def test_pair_gram_matches_sqdists_oracle():
    x = jax.random.normal(KEY, (8, 700))
    g = norm_agg.pair_gram(x, interpret=True)
    sq = jnp.diag(g)
    d2 = jnp.maximum(sq[:, None] + sq[None, :] - 2.0 * g, 0.0)
    np.testing.assert_allclose(np.asarray(d2),
                               np.asarray(ops.ref.pair_sqdists_ref(x)),
                               atol=1e-3)


# ---------------------------------------------------------------------------
# tree path: every rule x every attack in the registry
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("attack", sorted(REGISTRY))
@pytest.mark.parametrize("rule", ["mean", "cm", "tm", "rfa", "krum"])
def test_pallas_tree_matches_oracle_per_attack(rule, attack):
    """message_phase under agg_mode=pallas (fused attack where fusable) ≡
    materialized apply_attack + Aggregator.tree, for every registry attack."""
    cfg = _cfg(rule, bucket=2, attack=attack)
    cand = _tree(KEY, cfg.n_workers, [(40, 32), (17,)])
    k_attack, k_agg = jax.random.split(KEY)
    got = message_phase(cfg, k_attack, k_agg, cand)
    sent = apply_attack(cfg, k_attack, cand)
    want = cfg.aggregator.tree(k_agg, sent)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), atol=1e-5, rtol=1e-5), got, want)


@pytest.mark.parametrize("rule", ["cm", "rfa", "krum"])
def test_pallas_tree_non_bucket_multiple(rule):
    """n=7, s=2: the in-kernel permutation must pad the partial bucket with
    the stacked mean, exactly like the jnp oracle."""
    cfg = _cfg(rule, bucket=2, n=7, n_byz=1)
    cand = _tree(KEY, 7, [(33,), (6, 5)])
    got = tree_aggregate_pallas(cfg, KEY, cand)
    want = cfg.aggregator.tree(KEY, cand)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), atol=1e-5, rtol=1e-5), got, want)


@pytest.mark.parametrize("attack", ["NA", "ALIE"])
@pytest.mark.parametrize("rule", ["cm", "rfa", "krum"])
def test_pallas_tree_bf16_leaves(rule, attack):
    """bf16 candidates, clean and under a fused attack: the kernel prologue
    round-trips attacked values through the candidate dtype like
    apply_attack's .astype(h.dtype) (packed sub-tile leaves keep fp32 attack
    values — bounded by bf16 eps, covered by the tolerance here)."""
    cfg = _cfg(rule, bucket=2, attack=attack)
    cand = _tree(KEY, cfg.n_workers, [(1500,), (2000,)], dtype=jnp.bfloat16)
    k_attack, k_agg = jax.random.split(KEY)
    got = message_phase(cfg, k_attack, k_agg, cand)
    sent = apply_attack(cfg, k_attack, cand)
    want = cfg.aggregator.tree(k_agg, sent)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        assert a.dtype == jnp.bfloat16
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=4e-2)


def test_coord_attack_is_jit_cache_stable():
    """Two configs built from the same logical attack must share kernel jit
    cache entries: CoordAttack hashes by (kind, param), not closure id."""
    a1 = get_attack("ALIE").coord_apply
    a2 = get_attack("ALIE").coord_apply
    assert a1 == a2 and hash(a1) == hash(a2)
    assert get_attack("ALIE", z=2.0).coord_apply != a1
    x = jax.random.normal(KEY, (4, 256))
    mask = jnp.arange(4) < 1
    m = jnp.zeros((256,))
    s = jnp.ones((256,))
    norm_agg.pair_gram(x, None, mask, m, s, attack_fn=a1, interpret=True)
    before = norm_agg.pair_gram._cache_size()
    norm_agg.pair_gram(x, None, mask, m, s, attack_fn=a2, interpret=True)
    assert norm_agg.pair_gram._cache_size() == before


@pytest.mark.parametrize("rule", ["cm", "rfa", "krum"])
def test_pallas_tree_packs_tiny_leaves(rule):
    """Transformer-style trees (many sub-tile leaves) route through ONE
    packed flat buffer; the packed segmentation must not change results."""
    cfg = _cfg(rule, bucket=2)
    cand = _tree(KEY, cfg.n_workers, [(3,), (7,), (4, 2), (2000,), (11,)])
    got = tree_aggregate_pallas(cfg, KEY, cand)
    want = cfg.aggregator.tree(KEY, cand)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), atol=1e-5, rtol=1e-5), got, want)


def test_pack_rows_reuses_donated_buffer():
    """Eager packing reuses one preallocated buffer per layout (donated back
    each round) and keeps the zero tail intact."""
    from repro.core import sharded_agg as sa
    sa._PACK_CACHE.clear()
    flats = [jax.random.normal(jax.random.fold_in(KEY, i), (4, 11))
             for i in range(3)]
    p1 = sa._pack_rows(flats, "x")
    assert p1.shape == (4, 128) and len(sa._PACK_CACHE) == 1
    np.testing.assert_array_equal(np.asarray(p1[:, 33:]), 0.0)
    p2 = sa._pack_rows([f + 1.0 for f in flats], "x")
    np.testing.assert_allclose(np.asarray(p2[:, :11]),
                               np.asarray(flats[0] + 1.0), atol=1e-6)
    np.testing.assert_array_equal(np.asarray(p2[:, 33:]), 0.0)
    assert len(sa._PACK_CACHE) == 1     # same layout -> same slot
    sa._PACK_CACHE.clear()


# ---------------------------------------------------------------------------
# zero-copy guarantee: jaxpr scan of the fused message phase
# ---------------------------------------------------------------------------

from _jaxpr_scan import iter_eqns as _iter_eqns  # noqa: E402


@pytest.mark.parametrize("rule", ["cm", "rfa", "krum"])
def test_fused_message_phase_is_zero_copy(rule):
    """With a fusable attack (ALIE) and large leaves, the traced pallas
    message phase must contain NO (n, d)-shaped attacked copy (select_n /
    where materialization) and NO concatenated (n, D_total) flat buffer —
    the roofline contract of ISSUE 4."""
    n = 8
    dims = [(1500,), (64, 32)]
    d_total = 1500 + 64 * 32
    cfg = _cfg(rule, bucket=2, attack="ALIE", n=n)
    cand = _tree(KEY, n, dims)
    k1, k2 = jax.random.split(KEY)
    jaxpr = jax.make_jaxpr(
        lambda c: message_phase(cfg, k1, k2, c))(cand).jaxpr
    for eqn in _iter_eqns(jaxpr):
        for out in eqn.outvars:
            shape = getattr(out.aval, "shape", ())
            if len(shape) >= 2 and shape[0] == n:
                assert eqn.primitive.name not in ("concatenate", "select_n"), (
                    f"{eqn.primitive.name} materializes {shape}")
                assert int(np.prod(shape)) < n * d_total, (
                    f"{eqn.primitive.name} allocates flat {shape}")


def test_unfused_message_phase_does_materialize():
    """Sanity check of the scanner itself: the RN (unfusable) path DOES
    select_n-materialize the attacked candidates."""
    n = 8
    cfg = _cfg("rfa", bucket=2, attack="RN", n=n)
    cand = _tree(KEY, n, [(1500,)])
    k1, k2 = jax.random.split(KEY)
    jaxpr = jax.make_jaxpr(
        lambda c: message_phase(cfg, k1, k2, c))(cand).jaxpr
    assert any(eqn.primitive.name == "select_n"
               and getattr(eqn.outvars[0].aval, "shape", ()) == (n, 1500)
               for eqn in _iter_eqns(jaxpr))
