"""Paper App. B.3.1 (Fig. 2/3): heterogeneous data — 15 workers with a
disjoint sequential split, 5 of them Byzantine, robust aggregation with
bucketing. Demonstrates Thm. 2.1's two regimes:

  * robust aggregators converge to the O(cδζ²/p) neighbourhood of the good
    workers' optimum (the Karimireddy et al. lower-bound floor — no
    algorithm can do better under heterogeneity);
  * plain averaging is dragged arbitrarily far by ALIE/IPM.

NOTE the construction-time warning each robust spec raises here: after
s=2 bucketing the byzantine fraction is 2/3 >= 1/2, which is exactly why
convergence is only to the heterogeneity floor — the API flags the regime
the figure demonstrates.

  PYTHONPATH=src python examples/heterogeneous.py [--iters 500]
"""
import argparse
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.api import RunSpec, Sweep, build
from repro.core import theory
from repro.data import logreg_reference

ap = argparse.ArgumentParser()
ap.add_argument("--iters", type=int, default=500)
ap.add_argument("--randk", type=float, default=1.0)
args = ap.parse_args()

DIM = 30
N, NBYZ = 15, 5
BASE = RunSpec(
    task="logreg", method="marina", n_workers=N, n_byz=NBYZ,
    p=0.1, lr=0.2, steps=args.iters,
    compressor="randk" if args.randk < 1 else "identity",
    compressor_kwargs={"ratio": args.randk} if args.randk < 1 else {},
    data_kwargs={"n_samples": 1500, "dim": DIM, "homogeneous": False})

exp0 = build(BASE)
data = exp0.data

# f* over the GOOD workers' pooled data (workers 0..NBYZ-1 are byzantine)
goods = [data.worker_slice(i) for i in range(NBYZ, N)]
full = {"x": jnp.concatenate([g[0] for g in goods]),
        "y": jnp.concatenate([g[1] for g in goods])}
p_star, f_star = logreg_reference(exp0.loss_fn, full, iters=3000)

# empirical ζ² at x* (As. 2.2) and the theoretical floor
grads = [jax.grad(exp0.loss_fn)(p_star, {"x": g[0], "y": g[1]})
         for g in goods]
gbar = jax.tree.map(lambda *x: sum(x) / len(x), *grads)
zeta_sq = float(sum(
    sum(jnp.sum((a - b) ** 2) for a, b in
        zip(jax.tree.leaves(g), jax.tree.leaves(gbar)))
    for g in grads) / len(grads))
floor = theory.error_floor(delta=NBYZ / N, c=6.0, p=0.1, zeta_sq=zeta_sq,
                           mu=0.02)
print(f"heterogeneous split: ζ² = {zeta_sq:.4f}  "
      f"theory floor O(cδζ²/pμ) = {floor:.3f}  f* = {f_star:.4f}")

for attack in ("NA", "LF", "BF", "ALIE", "IPM"):
    row = []
    grid = Sweep(BASE.replace(attack=attack),
                 {"aggregator": ("mean", "cm", "rfa")})
    for _, spec in grid.expand():
        spec = spec.replace(
            bucket_size=0 if spec.aggregator == "mean" else 2)
        exp = build(spec)
        result = exp.run(log_every=args.iters)
        gap = float(exp.loss_fn(result.params, full)) - f_star
        label = {"mean": "AVG", "cm": "CM", "rfa": "RFA"}[spec.aggregator]
        row.append(f"{label}:{gap:9.2e}")
    print(f"{attack:>5} | " + "  ".join(row))
print("\nAll methods plateau at an O(δζ²)-scale gap — the heterogeneous "
      "lower bound of Karimireddy et al. (2022) binds every algorithm; "
      "the theory floor above is the (loose) Thm. 2.1 constant. Compare "
      "the clean-data example (quickstart.py) where the same attacks are "
      "driven to f* exactly. This mirrors the paper's Fig. 2 plateaus.")
