"""Per-cell artifact aggregation into paper-figure tables (DESIGN.md §1.6).

``summarize`` folds the per-cell metric payloads (``RunResult.to_dict()``
JSONs — in-memory from a ``SweepRun`` or loaded back from an artifact
directory) into one summary table: cells are grouped by jit signature
(spec minus seed, the same key the batched engine groups by), each group
reports mean±std over its seeds for every final-step metric, and the
best group per headline metric is selected. Timing fields (``wall_s``)
are excluded so the summary is a pure function of the trajectories —
that's what makes the killed-and-resumed-sweep ≡ uninterrupted-sweep
guarantee checkable bit-for-bit (tests/test_exec_ledger.py).

``write_summary`` serializes with sorted keys so equal summaries are
equal bytes; benchmarks emit ``experiments/bench/<name>_summary.json``
through it.
"""
from __future__ import annotations

import json
import math
import os
from typing import Mapping, Optional

SUMMARY_SCHEMA_VERSION = 1

# per-entry fields that are timing noise, not trajectory
_NONDETERMINISTIC = ("wall_s",)


def _group_spec(payload: dict) -> dict:
    spec = dict(payload.get("spec", {}))
    spec.pop("seed", None)
    return spec


def _fmt_value(v) -> str:
    import re
    if isinstance(v, dict):
        v = ",".join(f"{k}:{v[k]}" for k in sorted(v))
    s = str(v)
    return re.sub(r"[^A-Za-z0-9_.:,+-]+", "-", s) or "none"


def _labels(group_specs: list) -> list:
    """Human labels from the fields that actually vary across groups."""
    if len(group_specs) == 1:
        return ["all"]
    keys = sorted({k for g in group_specs for k in g})
    varying = [k for k in keys
               if len({json.dumps(g.get(k), sort_keys=True)
                       for g in group_specs}) > 1]
    labels = ["__".join(f"{k}={_fmt_value(g.get(k))}" for k in varying)
              or "all" for g in group_specs]
    if len(set(labels)) != len(labels):       # fall back to full signature
        labels = [json.dumps(g, sort_keys=True) for g in group_specs]
    return labels


def _mean_std(values: list) -> dict:
    n = len(values)
    mean = sum(values) / n
    var = sum((v - mean) ** 2 for v in values) / n
    return {"mean": mean, "std": math.sqrt(var),
            "min": min(values), "max": max(values), "n": n}


def summarize(artifacts: Mapping[str, dict], *,
              select_metric: str = "loss") -> dict:
    """{run_id: payload} -> summary dict (see module docstring).

    Groups are sorted by their canonical spec signature and seeds sorted
    within a group, so the summary is independent of execution order.
    """
    groups: dict = {}
    for run_id in sorted(artifacts):
        payload = artifacts[run_id]
        key = json.dumps(_group_spec(payload), sort_keys=True)
        groups.setdefault(key, []).append((run_id, payload))

    keys = sorted(groups)
    labels = _labels([json.loads(k) for k in keys])
    out_groups = []
    for key, label in zip(keys, labels):
        members = groups[key]
        finals, seeds, run_ids = [], [], []
        for run_id, payload in members:
            hist = payload.get("history", [])
            finals.append(hist[-1] if hist else {})
            seeds.append(payload.get("spec", {}).get("seed"))
            run_ids.append(run_id)
        metric_names = sorted({m for f in finals for m in f
                               if m not in _NONDETERMINISTIC
                               and isinstance(f[m], (int, float))})
        final = {m: _mean_std([f[m] for f in finals if m in f])
                 for m in metric_names}
        out_groups.append({
            "label": label,
            "spec": json.loads(key),
            "seeds": sorted(seeds, key=lambda s: (s is None, s)),
            "n_seeds": len(members),
            "run_ids": sorted(run_ids),
            "final": final,
        })

    best = None
    scored = [(g["final"][select_metric]["mean"], g["label"])
              for g in out_groups if select_metric in g["final"]]
    if scored:
        mean, label = min(scored)
        best = {"metric": select_metric, "label": label, "mean": mean}
    return {"schema_version": SUMMARY_SCHEMA_VERSION,
            "n_cells": len(artifacts), "n_groups": len(out_groups),
            "groups": out_groups, "best": best}


def load_artifacts(out_dir: str) -> dict:
    """Load every per-cell artifact JSON under ``out_dir`` (skips the
    ledger, summaries, and anything that isn't a RunResult payload)."""
    artifacts = {}
    for name in sorted(os.listdir(out_dir)):
        if (not name.endswith(".json") or name.endswith("_summary.json")
                or name.endswith(".spec.json")):
            continue
        path = os.path.join(out_dir, name)
        try:
            with open(path) as f:
                payload = json.load(f)
        except (OSError, json.JSONDecodeError):
            continue
        if isinstance(payload, dict) and "spec" in payload:
            artifacts[name[:-len(".json")]] = payload
    return artifacts


def summarize_dir(out_dir: str, **kw) -> dict:
    return summarize(load_artifacts(out_dir), **kw)


def write_summary(path: Optional[str], summary: dict) -> Optional[str]:
    """Deterministic bytes: sorted keys, fixed indent — equal summaries
    are equal files."""
    if not path:
        return None
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(summary, f, indent=1, sort_keys=True)
        f.write("\n")
    os.replace(tmp, path)
    return path
