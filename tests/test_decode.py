"""Serving correctness: incremental decode must match the full forward pass
for every architecture family (KV cache, ring-buffer SWA, MLA latent cache,
Mamba2 recurrent state, RG-LRU state)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ASSIGNED_ARCHS, get_config
from repro.models import decode_step, forward, init_cache, init_params

KEY = jax.random.PRNGKey(1)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_decode_matches_forward(arch):
    cfg = get_config(arch).reduced()
    params = init_params(KEY, cfg)
    b, s = 2, 8
    shape = (b, s) if cfg.num_codebooks == 1 else (b, s, cfg.num_codebooks)
    toks = jax.random.randint(KEY, shape, 0, cfg.vocab_size)
    full_logits, _ = forward(params, cfg, {"tokens": toks, "labels": toks})
    cache = init_cache(cfg, b, s)
    outs = []
    for t in range(s):
        tok_t = toks[:, t] if cfg.num_codebooks == 1 else toks[:, t, :]
        lg, cache = decode_step(params, cfg, cache, tok_t)
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full_logits, np.float32),
                               atol=2e-4, rtol=2e-3)


def test_sliding_window_ring_buffer():
    """Ring-buffer SWA decode == full forward with the same window, even when
    the sequence exceeds the cache capacity (= window)."""
    cfg = get_config("recurrentgemma-2b").reduced()
    # window=64 in reduced; use 8 to force wraparound at s=20
    import dataclasses
    cfg = dataclasses.replace(cfg, sliding_window=8)
    params = init_params(KEY, cfg)
    b, s = 1, 20
    toks = jax.random.randint(KEY, (b, s), 0, cfg.vocab_size)
    full_logits, _ = forward(params, cfg, {"tokens": toks, "labels": toks})
    cache = init_cache(cfg, b, cfg.sliding_window)   # capacity == window
    outs = []
    for t in range(s):
        lg, cache = decode_step(params, cfg, cache, toks[:, t])
        outs.append(lg)
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec, np.float32),
                               np.asarray(full_logits, np.float32),
                               atol=2e-4, rtol=2e-3)


def test_mla_cache_is_compressed():
    """DeepSeek MLA cache stores (kv_lora + rope_dim) per token, not
    2 * heads * head_dim — the whole point of MLA."""
    cfg = get_config("deepseek-v2-lite-16b")
    rcfg = cfg.reduced()
    c = init_cache(rcfg, 1, 16)
    g0 = c["groups"][0]
    assert "c_kv" in g0 and "k_rope" in g0 and "k" not in g0
    assert g0["c_kv"].shape[-1] == rcfg.kv_lora_rank
    per_tok = g0["c_kv"].shape[-1] + g0["k_rope"].shape[-1]
    uncompressed = 2 * rcfg.num_kv_heads * rcfg.resolved_head_dim
    assert per_tok < uncompressed


def test_recurrent_state_is_constant_size():
    """SSM/RG-LRU decode caches don't grow with context length."""
    cfg = get_config("mamba2-130m").reduced()
    c1 = init_cache(cfg, 2, 128)
    c2 = init_cache(cfg, 2, 4096)
    t1 = sum(x.size for x in jax.tree.leaves(c1))
    t2 = sum(x.size for x in jax.tree.leaves(c2))
    assert t1 == t2  # no attention cache at all: context-independent state
