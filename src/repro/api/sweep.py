"""Cartesian grid expansion over ``RunSpec`` fields.

The paper's figures are grids (aggregator x attack x compression); a
``Sweep`` makes any such grid a one-liner with stable, human-readable run
ids, so benchmark artifacts are addressable and diffable:

    sweep = Sweep(base=RunSpec(task="logreg", steps=500),
                  grid={"aggregator": ("mean", "cm", "rfa"),
                        "attack": ("NA", "BF", "ALIE"),
                        "compressor_kwargs.ratio": (0.1, 1.0)})
    for run_id, spec in sweep.expand():
        result = spec.run()

Grid keys are spec field names; dotted keys reach into the per-component
kwargs dicts (``spec.replace`` semantics). Every expanded spec is validated
at construction, so an invalid cell fails before any training starts.
"""
from __future__ import annotations

import dataclasses
import itertools
import re
from typing import Mapping, Sequence

from repro.api.spec import RunSpec


def _fmt(value) -> str:
    s = str(value)
    return re.sub(r"[^A-Za-z0-9_.+-]+", "-", s) or "none"


@dataclasses.dataclass(frozen=True)
class Sweep:
    """``base`` spec + ``grid`` of field -> candidate values (insertion
    order of ``grid`` fixes both the expansion order and the run-id field
    order, so ids are stable across runs)."""
    base: RunSpec
    grid: Mapping[str, Sequence]

    def __post_init__(self):
        for key in self.grid:
            field = key.split(".", 1)[0]
            if field not in {f.name for f in dataclasses.fields(RunSpec)}:
                raise ValueError(
                    f"sweep grid key {key!r}: {field!r} is not a RunSpec "
                    "field")

    def __len__(self) -> int:
        n = 1
        for vals in self.grid.values():
            n *= len(vals)
        return n

    def run_id(self, overrides: Mapping) -> str:
        return "__".join(f"{k}={_fmt(v)}" for k, v in overrides.items())

    def expand(self):
        """Yield ``(run_id, spec)`` per grid cell, row-major in grid order."""
        names = list(self.grid)
        for combo in itertools.product(*(self.grid[n] for n in names)):
            overrides = dict(zip(names, combo))
            yield self.run_id(overrides), self.base.replace(**overrides)


def run_sweep(sweep: Sweep, *, out_dir: str = None, resume: bool = False,
              batch="auto", pool=None, ledger_path: str = None,
              summary_out: str = None, cell_hook=None, **run_kw):
    """Run every cell through the batched execution engine (repro.exec).

    Returns a ``SweepRun`` — a mapping ``{run_id: result}`` exactly like
    the old dict (live ``RunResult``s for cells run here, loaded
    ``CompletedCell``s for resumed ones), plus ``.artifacts`` /
    ``.failures`` / ``.stats``. Same-signature multi-seed cells run as ONE
    vmapped jitted trajectory (``batch=False`` opts out); with
    ``out_dir``, each cell writes ``<run_id>.json`` and the crash-safe
    ledger (``ledger.jsonl``) makes ``resume=True`` skip completed cells.
    ``pool=exec.WorkerPool(...)`` shards un-batchable cells over pinned
    worker subprocesses; ``summary_out`` writes the mean±std-over-seeds
    summary table (exec.aggregate). A failing cell is isolated and
    recorded, not raised — check ``.failures``.
    """
    from repro import exec as xc
    srun = xc.run_cells(list(sweep.expand()), out_dir=out_dir,
                        ledger_path=ledger_path, resume=resume, batch=batch,
                        pool=pool, run_kw=run_kw, cell_hook=cell_hook)
    if summary_out:
        xc.write_summary(summary_out, xc.summarize(srun.artifacts))
    return srun
