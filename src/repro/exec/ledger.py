"""Crash-safe append-only run ledger (DESIGN.md §1.6).

One JSONL file per sweep records every cell's lifecycle as append-only
events keyed by the sweep's stable run ids:

    {"run_id": ..., "status": "started", "spec": {...}, "ts": ...}
    {"run_id": ..., "status": "done", "git_sha": ..., "device_kind": ...,
     "engine": "vmapped", "group": ..., "wall_s": ..., "ts": ...}

Each record is written with flush+fsync, so a killed sweep leaves at worst
one truncated trailing line — ``iter_records`` tolerates (and skips) it.
The LAST record per run id wins: ``completed()`` is the resume set
(scheduler.run_cells skips those cells and re-runs ``started``/``failed``
ones), and the full event stream is the provenance trail the ISSUE asks
for (resolved spec, git sha, device kind, wall time per cell).
"""
from __future__ import annotations

import functools
import json
import os
import subprocess
import time
from typing import Iterator, Optional


@functools.lru_cache(maxsize=1)
def git_sha() -> str:
    """HEAD of the repo this package lives in ("unknown" outside git)."""
    try:
        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], capture_output=True, text=True,
            timeout=10, cwd=os.path.dirname(os.path.abspath(__file__)))
        sha = out.stdout.strip()
        return sha if out.returncode == 0 and sha else "unknown"
    except (OSError, subprocess.SubprocessError):
        return "unknown"


def device_kind() -> str:
    """e.g. "cpu:8" — backend plus visible device count."""
    import jax
    return f"{jax.default_backend()}:{jax.device_count()}"


class Ledger:
    """Append-only JSONL event log for one sweep."""

    def __init__(self, path: str):
        self.path = path
        parent = os.path.dirname(path)
        if parent:
            os.makedirs(parent, exist_ok=True)

    # -- writing ------------------------------------------------------------
    def append(self, run_id: str, status: str, **fields) -> dict:
        rec = {"run_id": run_id, "status": status, "ts": time.time(),
               **fields}
        line = json.dumps(rec, sort_keys=True)
        with open(self.path, "ab") as f:
            # heal a torn tail from a killed writer: never glue a new
            # record onto a half-written line
            if f.tell() > 0:
                with open(self.path, "rb") as r:
                    r.seek(-1, os.SEEK_END)
                    torn = r.read(1) != b"\n"
            else:
                torn = False
            f.write(b"\n" * torn + line.encode() + b"\n")
            f.flush()
            os.fsync(f.fileno())
        return rec

    # -- reading ------------------------------------------------------------
    def iter_records(self) -> Iterator[dict]:
        """Yield records in append order, skipping a torn trailing line."""
        if not os.path.exists(self.path):
            return
        with open(self.path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                try:
                    rec = json.loads(line)
                except json.JSONDecodeError:
                    continue            # torn write from a killed process
                if isinstance(rec, dict) and "run_id" in rec:
                    yield rec

    def load(self) -> dict:
        """{run_id: last record} — later events supersede earlier ones."""
        state = {}
        for rec in self.iter_records():
            state[rec["run_id"]] = rec
        return state

    def by_status(self, status: str) -> set:
        return {rid for rid, rec in self.load().items()
                if rec.get("status") == status}

    def completed(self) -> set:
        return self.by_status("done")

    def failed(self) -> set:
        return self.by_status("failed")

    def record(self, run_id: str) -> Optional[dict]:
        return self.load().get(run_id)
