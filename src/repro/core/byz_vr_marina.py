"""Byz-VR-MARINA (Algorithm 1) — the paper's contribution as a composable
JAX trainer.

One implementation serves both scales:

* laptop scale — ``n_workers`` simulated with ``vmap`` on one device (the
  paper's own logreg experiments, the convergence tests, the examples);
* pod scale — the same step ``jit``-ed onto the production mesh with the
  worker axis of every stacked input sharded over ``("pod", "data")`` and
  params/grads sharded over ``"model"`` (launch/train.py, launch/dryrun.py).

Per iteration (paper lines 4–10):

    c_k ~ Be(p)                                    (shared coin, broadcast)
    x^{k+1} = x^k - γ g^k                          (or any optim.Optimizer)
    good i: g_i = ∇f_i(x^{k+1})                    if c_k = 1   (anchor batch)
            g_i = g^k + Q(Δ̂_i(x^{k+1}, x^k))      otherwise    (minibatch)
    byz  i: g_i = attack(...)                      (omniscient; masked psums)
    g^{k+1} = ARAgg(g_1, ..., g_n)                 (bucketing + CM/RFA/Krum)

Aggregation modes (``agg_mode``):
  * "gspmd"       — paper-faithful: aggregation written as jnp ops over the
                    stacked worker axis; GSPMD inserts the all-gather.
  * "all_to_all"  — beyond-paper (§Perf): coordinate-wise rules are sharded
                    over the worker axis via shard_map all_to_all, cutting
                    the collective bytes from n·d to ~2·d and the peak
                    aggregation memory from n·d_local to d_local.
  * "sparse_support" — beyond-paper (§Perf): with common-randomness RandK
                    only the K-coordinate support is aggregated; off-support
                    coordinates keep g^k (exact for coordinate-wise rules,
                    and enforceable server-side per the paper's remark that
                    dense senders are trivially banned).
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.aggregators import Aggregator, coord_median, coord_trimmed_mean
from repro.core.attacks import Attack, no_attack
from repro.core.compressors import Compressor, identity
from repro.core import tree_utils as tu


@dataclasses.dataclass(frozen=True)
class ByzVRMarinaConfig:
    n_workers: int
    n_byz: int = 0
    p: float = 0.1                       # full-gradient probability
    lr: float = 0.05
    aggregator: Aggregator = Aggregator("mean")
    compressor: Compressor = dataclasses.field(default_factory=identity)
    attack: Attack = dataclasses.field(default_factory=no_attack)
    agg_mode: str = "gspmd"              # gspmd | all_to_all | sparse_support
    optimizer: Optional[object] = None   # optim.Optimizer or None = plain SGD
    # distributed extras
    worker_axes: tuple = ()              # mesh axes carrying the worker dim
    model_axis: Optional[str] = None
    mesh: Optional[object] = None        # jax Mesh (all_to_all mode)
    grad_specs: Optional[object] = None  # PartitionSpec pytree (all_to_all)

    def byz_mask(self):
        return jnp.arange(self.n_workers) < self.n_byz


def train_state(params, g0, opt_state=None, step=0):
    return {"params": params, "g": g0, "opt_state": opt_state,
            "step": jnp.asarray(step, jnp.int32)}


# ---------------------------------------------------------------------------
# attack application on stacked candidates
# ---------------------------------------------------------------------------

def apply_attack(cfg: ByzVRMarinaConfig, key, cand):
    """cand: stacked pytree (n, ...). Returns the vectors actually 'sent'."""
    if cfg.n_byz == 0 or cfg.attack.name in ("NA", "LF"):
        return cand
    mask = cfg.byz_mask()
    good = ~mask
    means, stds = tu.masked_mean_std(cand, good)

    def leaf(h, m, s):
        v = cfg.attack.apply(key, h, m, s).astype(h.dtype)
        bm = mask.reshape((-1,) + (1,) * (h.ndim - 1))
        return jnp.where(bm, v, h)

    return jax.tree.map(leaf, cand, means, stds)


# ---------------------------------------------------------------------------
# worker gradient computation
# ---------------------------------------------------------------------------

def _stacked_grads(loss_fn, params, batches, keys):
    """vmap(value_and_grad) over the leading worker axis of ``batches``."""
    def one(batch, key):
        return jax.value_and_grad(loss_fn)(params, batch, key)

    losses, grads = jax.vmap(one)(batches, keys)
    return jnp.mean(losses), grads


# ---------------------------------------------------------------------------
# step factory
# ---------------------------------------------------------------------------

def make_step(cfg: ByzVRMarinaConfig, loss_fn: Callable,
              corrupt_fn: Optional[Callable] = None):
    """loss_fn(params, batch, key) -> scalar loss.

    ``batch`` / ``anchor`` passed to the returned step are stacked pytrees
    with a leading worker axis (n, ...). ``corrupt_fn(batch, byz_mask)``
    implements data-level attacks (label flipping).
    """
    if cfg.agg_mode == "sparse_support":
        return _make_step_sparse(cfg, loss_fn, corrupt_fn)
    n = cfg.n_workers
    opt = cfg.optimizer

    def maybe_corrupt(batch):
        if corrupt_fn is not None and cfg.attack.flips_labels and cfg.n_byz:
            return corrupt_fn(batch, cfg.byz_mask())
        return batch

    def step(state, batch, anchor, key):
        k_bern, k_grad, k_q, k_attack, k_agg = jax.random.split(key, 5)
        c_k = jax.random.bernoulli(k_bern, cfg.p)
        old_params = state["params"]

        # ---- line 7: x^{k+1} = x^k - γ g^k
        if opt is None:
            new_params = jax.tree.map(
                lambda x, gg: (x.astype(jnp.float32)
                               - cfg.lr * gg.astype(jnp.float32)
                               ).astype(x.dtype),
                old_params, state["g"])
            new_opt = state["opt_state"]
        else:
            new_params, new_opt = opt.update(state["g"], state["opt_state"],
                                             old_params)

        batch = maybe_corrupt(batch)
        anchor = maybe_corrupt(anchor)
        wkeys = tu.per_worker_keys(k_grad, n)

        # ---- line 8: candidates
        def full_branch(_):
            loss, grads = _stacked_grads(loss_fn, new_params, anchor, wkeys)
            return loss, grads

        def vr_branch(_):
            qkeys = tu.per_worker_keys(
                k_q, n, common=cfg.compressor.common_randomness)

            def one(b, kg, kq):
                ln, gn = jax.value_and_grad(loss_fn)(new_params, b, kg)
                _, go = jax.value_and_grad(loss_fn)(old_params, b, kg)
                delta = tu.tree_sub(gn, go)
                q = tu.compress_tree(cfg.compressor, kq, delta)
                return ln, q

            losses, qs = jax.vmap(one)(batch, wkeys, qkeys)
            cand = jax.tree.map(lambda g0, q: g0[None] + q, state["g"], qs)
            return jnp.mean(losses), cand

        loss, cand = lax.cond(c_k, full_branch, vr_branch, operand=None)

        # ---- byzantine workers replace their message
        sent = apply_attack(cfg, k_attack, cand)

        # ---- line 10: robust aggregation
        g_new = _aggregate(cfg, k_agg, sent)

        metrics = {
            "loss": loss,
            "c_k": c_k.astype(jnp.int32),
            "g_norm": jnp.sqrt(tu.tree_norm_sq(g_new)),
        }
        new_state = {"params": new_params, "g": g_new, "opt_state": new_opt,
                     "step": state["step"] + 1}
        return new_state, metrics

    return step


def _aggregate(cfg: ByzVRMarinaConfig, key, sent):
    if cfg.agg_mode in ("gspmd", "sparse_support"):
        # sparse_support only changes the VR branch (see _make_step_sparse);
        # dense aggregations (init, full-grad branch) stay gspmd.
        return cfg.aggregator.tree(key, sent)
    if cfg.agg_mode == "all_to_all":
        from repro.core.sharded_agg import tree_aggregate_all_to_all
        return tree_aggregate_all_to_all(cfg, key, sent)
    raise ValueError(cfg.agg_mode)


# ---------------------------------------------------------------------------
# sparse-support variant (§Perf): common-randomness RandK means every worker
# sends the SAME K coordinates, so only the (K)-sized support is attacked,
# gathered, and aggregated; off-support coordinates keep g^k exactly (the
# paper's own remark: the server bans senders outside the agreed support).
# ---------------------------------------------------------------------------

def _make_step_sparse(cfg: ByzVRMarinaConfig, loss_fn, corrupt_fn=None):
    from repro.core.compressors import unit_partition

    n = cfg.n_workers
    opt = cfg.optimizer
    comp = cfg.compressor
    assert comp.common_randomness and comp.ratio is not None, (
        "sparse_support needs a common-randomness RandK compressor")
    ratio = comp.ratio

    def maybe_corrupt(batch):
        if corrupt_fn is not None and cfg.attack.flips_labels and cfg.n_byz:
            return corrupt_fn(batch, cfg.byz_mask())
        return batch

    def support_take(leaf_flat, idx, blk, d):
        pad = (-d) % blk
        xf = jnp.pad(leaf_flat, (0, pad)).reshape(-1, blk)
        return xf[idx]                                   # (k_units, blk)

    def support_put(leaf, idx, blk, vals):
        d = leaf.size
        pad = (-d) % blk
        xf = jnp.pad(leaf.reshape(-1).astype(jnp.float32), (0, pad))
        xf = xf.reshape(-1, blk).at[idx].set(vals)
        return xf.reshape(-1)[:d].reshape(leaf.shape).astype(leaf.dtype)

    def step(state, batch, anchor, key):
        k_bern, k_grad, k_q, k_attack, k_agg = jax.random.split(key, 5)
        c_k = jax.random.bernoulli(k_bern, cfg.p)
        old_params = state["params"]
        if opt is None:
            new_params = jax.tree.map(
                lambda x, gg: (x.astype(jnp.float32)
                               - cfg.lr * gg.astype(jnp.float32)
                               ).astype(x.dtype), old_params, state["g"])
            new_opt = state["opt_state"]
        else:
            new_params, new_opt = opt.update(state["g"], state["opt_state"],
                                             old_params)
        batch = maybe_corrupt(batch)
        anchor = maybe_corrupt(anchor)
        wkeys = tu.per_worker_keys(k_grad, n)

        def full_branch(_):
            loss, grads = _stacked_grads(loss_fn, new_params, anchor, wkeys)
            sent = apply_attack(cfg, k_attack, grads)
            return loss, cfg.aggregator.tree(k_agg, sent)

        def sparse_branch(_):
            # shared per-leaf supports (same key for every worker)
            g_leaves, treedef = jax.tree.flatten(state["g"])
            meta = []
            for i, gl in enumerate(g_leaves):
                d = gl.size
                blk, n_units = unit_partition(d)
                k_units = max(int(ratio * n_units), 1)
                kk = jax.random.fold_in(k_q, i)
                idx = jax.random.permutation(kk, n_units)[:k_units]
                meta.append((blk, n_units, k_units, idx,
                             n_units / k_units, d))

            def one(b, kg):
                ln, gn = jax.value_and_grad(loss_fn)(new_params, b, kg)
                _, go = jax.value_and_grad(loss_fn)(old_params, b, kg)
                delta = tu.tree_sub(gn, go)
                d_leaves = jax.tree.leaves(delta)
                vals = []
                for (blk, nu, ku, idx, scale, d), dl in zip(meta, d_leaves):
                    v = support_take(dl.reshape(-1).astype(jnp.float32),
                                     idx, blk, d) * scale
                    vals.append(v)
                return ln, tuple(vals)

            losses, dvals = jax.vmap(one)(batch, wkeys)
            # candidates on the support: g^k|support + scaled delta
            cand = []
            for (blk, nu, ku, idx, scale, d), gl, dv in zip(
                    meta, g_leaves, dvals):
                base = support_take(gl.reshape(-1).astype(jnp.float32),
                                    idx, blk, d)
                cand.append(base[None] + dv)
            cand = tuple(cand)
            sent = apply_attack(cfg, k_attack, cand)
            agg_vals = cfg.aggregator.tree(k_agg, sent)
            new_leaves = [support_put(gl, m[3], m[0], av)
                          for m, gl, av in zip(meta, g_leaves, agg_vals)]
            g_new = jax.tree.unflatten(treedef, new_leaves)
            return jnp.mean(losses), g_new

        loss, g_new = lax.cond(c_k, full_branch, sparse_branch, operand=None)
        metrics = {"loss": loss, "c_k": c_k.astype(jnp.int32),
                   "g_norm": jnp.sqrt(tu.tree_norm_sq(g_new))}
        return ({"params": new_params, "g": g_new, "opt_state": new_opt,
                 "step": state["step"] + 1}, metrics)

    return step


# ---------------------------------------------------------------------------
# g^0 initialization (paper: g^0 = ARAgg(∇f_1(x^0), ..., ∇f_n(x^0)))
# ---------------------------------------------------------------------------

def make_init(cfg: ByzVRMarinaConfig, loss_fn: Callable,
              corrupt_fn: Optional[Callable] = None):
    def init(params, anchor, key):
        k_grad, k_attack, k_agg = jax.random.split(key, 3)
        if corrupt_fn is not None and cfg.attack.flips_labels and cfg.n_byz:
            anchor = corrupt_fn(anchor, cfg.byz_mask())
        wkeys = tu.per_worker_keys(k_grad, cfg.n_workers)
        _, grads = _stacked_grads(loss_fn, params, anchor, wkeys)
        sent = apply_attack(cfg, k_attack, grads)
        g0 = _aggregate(cfg, k_agg, sent)
        opt_state = (cfg.optimizer.init(params)
                     if cfg.optimizer is not None else None)
        return train_state(params, g0, opt_state)

    return init


# ---------------------------------------------------------------------------
# communication accounting (paper Fig. 8 / footnote 3)
# ---------------------------------------------------------------------------

def comm_bits(cfg: ByzVRMarinaConfig, d: int, c_k: bool) -> int:
    """Bits uploaded per worker this round."""
    if c_k:
        return 32 * d
    return int(cfg.compressor.bits_per_vector(d))


def expected_comm_bits(cfg: ByzVRMarinaConfig, d: int) -> float:
    return cfg.p * 32 * d + (1 - cfg.p) * cfg.compressor.bits_per_vector(d)
