"""Streaming-service benchmark: updates/sec and round-latency percentiles
for the buffered-async aggregation service (repro.serve, DESIGN.md §4).

For every {backend} x {rule} x {buffer size K} cell the service runs the
same seeded exp-arrival chaos-free stream over a moderate-dimension logreg
task, twice:

  * latency mode   — ``sync_each_fire=True`` blocks on every fired round;
                     p50/p99 of the per-fire wall latency.
  * throughput mode — free-running: ingestion (row writes into the open
                     buffer half) overlaps the still-executing aggregation
                     of the closed half, measuring the double buffer's
                     pipelining; accepted updates / wall second.

Grid (ISSUE 7 acceptance): {gspmd, pallas} x {mean, krum} x K in {64, 256}
-> ``experiments/bench/BENCH_serve.json`` (uploaded by the CI bench job).
The ``overlap`` derived column is throughput_free / throughput_synced —
how much round-blocking was hiding.
"""
import json
import os

import numpy as np

from benchmarks.common import ART_DIR, emit
from repro.api import ServeSpec

BACKENDS = ("gspmd", "pallas")
RULES = ("mean", "krum")
BUFFER_SIZES = (64, 256)
N_CLIENTS = 512
DIM = 1024
N_SAMPLES = 128     # anchor set is replicated per client (homogeneous)
ROUNDS = 12


def _spec(mode: str, rule: str, k: int) -> ServeSpec:
    return ServeSpec(
        task="logreg", method="sgd", n_clients=N_CLIENTS,
        n_byz=N_CLIENTS // 32, attack="ALIE", aggregator=rule,
        bucket_size=2 if rule != "mean" else 0, agg_mode=mode,
        buffer_size=k, rounds=ROUNDS, lr=0.1, arrival="exp",
        arrival_kwargs={"mean_latency": 1.0},
        data_kwargs={"dim": DIM, "n_samples": N_SAMPLES,
                     "batch_size": 8})


def run():
    payload = {"n_clients": N_CLIENTS, "dim": DIM, "rounds": ROUNDS,
               "cells": []}
    for mode in BACKENDS:
        for rule in RULES:
            for k in BUFFER_SIZES:
                spec = _spec(mode, rule, k)
                name = f"serve/{mode}/{rule}/K{k}"
                try:
                    # warm the jit caches off the clock, then measure
                    spec.replace(rounds=2).build().run()
                    lat = spec.build().run(sync_each_fire=True)
                    thr = spec.build().run()
                except Exception as e:  # noqa: BLE001 — report, keep grid
                    emit(name, 0.0, f"FAILED {type(e).__name__}: {e}")
                    continue
                pct = lat.latency_percentiles()
                synced_ups = lat.updates_per_s
                overlap = thr.updates_per_s / max(synced_ups, 1e-9)
                cell = {
                    "agg_mode": mode, "rule": rule, "buffer_size": k,
                    "updates_per_s": round(thr.updates_per_s, 1),
                    "updates_per_s_synced": round(synced_ups, 1),
                    "overlap_gain": round(overlap, 3),
                    "p50_ms": round(pct["p50_ms"], 3),
                    "p99_ms": round(pct["p99_ms"], 3),
                    "rounds": thr.stats["rounds"],
                    "accepted": thr.stats["accepted"],
                    "mean_staleness": round(float(np.mean(
                        [m["staleness_mean"] for m in thr.history])), 3),
                    "spec": spec.to_dict(),
                }
                payload["cells"].append(cell)
                emit(name,
                     pct["p50_ms"] * 1e3,   # us per fired round (p50)
                     f"{cell['updates_per_s']}ups "
                     f"p99={cell['p99_ms']}ms "
                     f"overlap={cell['overlap_gain']}x")
    os.makedirs(ART_DIR, exist_ok=True)
    with open(os.path.join(ART_DIR, "BENCH_serve.json"), "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
