"""Serving example: batched autoregressive decoding with per-family caches
(KV ring buffer / MLA latent / SSM state). Serves a batch of requests of
different prompt lengths through one shared cache, reduced config on CPU.

  PYTHONPATH=src python examples/serve_lm.py --arch deepseek-v2-lite-16b
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.serve import generate
from repro.models import init_params

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="deepseek-v2-lite-16b")
ap.add_argument("--batch", type=int, default=8)
ap.add_argument("--prompt-len", type=int, default=24)
ap.add_argument("--gen-len", type=int, default=48)
ap.add_argument("--temperature", type=float, default=0.8)
args = ap.parse_args()

cfg = get_config(args.arch).reduced()
key = jax.random.PRNGKey(0)
params = init_params(key, cfg)
shape = ((args.batch, args.prompt_len) if cfg.num_codebooks == 1 else
         (args.batch, args.prompt_len, cfg.num_codebooks))
prompts = jax.random.randint(key, shape, 0, cfg.vocab_size)

print(f"[serve] {args.arch} (reduced) — batch={args.batch} "
      f"prompt={args.prompt_len} gen={args.gen_len}")
t0 = time.time()
out = generate(cfg, params, prompts, args.gen_len,
               temperature=args.temperature, key=key)
dt = time.time() - t0
print(f"  generated {out.shape} in {dt:.1f}s "
      f"({args.batch*args.gen_len/dt:.0f} tok/s incl. compile)")
print("  sample:", jax.device_get(out[0])[:12], "...")
