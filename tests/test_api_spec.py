"""repro.api surface: spec serialization round-trips, eager validation with
actionable errors, the unified registry, and sweep expansion."""
import dataclasses
import json
import warnings

import pytest

from repro.api import (RunSpec, Sweep, build, check, components, describe,
                       kinds, resolve)
from repro.core.engine import AGG_BACKENDS


# ---------------------------------------------------------------------------
# serialization: exact round-trip for every method x attack x aggregator
# ---------------------------------------------------------------------------

def test_roundtrip_every_method_attack_aggregator_combination():
    """Property-style (no tracing, fast): from_dict(to_dict(s)) == s and
    from_json(to_json(s)) == s for the full registered cross product."""
    with warnings.catch_warnings():
        warnings.simplefilter("ignore")      # bucketed-delta advisories
        for method in components("method"):
            # byz_ef21 validates eagerly against non-contractive compressors
            comp = "topk" if method == "byz_ef21" else "randk"
            for attack in components("attack"):
                for agg in components("aggregator"):
                    s = RunSpec(task="logreg", method=method, attack=attack,
                                aggregator=agg, n_workers=6, n_byz=1,
                                steps=3,
                                compressor=comp,
                                compressor_kwargs={"ratio": 0.5},
                                data_kwargs={"dim": 7, "batch_size": 4})
                    assert RunSpec.from_dict(s.to_dict()) == s
                    assert RunSpec.from_json(s.to_json()) == s
                    # to_dict is plain JSON (diffable artifact)
                    assert json.loads(json.dumps(s.to_dict())) == s.to_dict()


def test_roundtrip_preserves_all_fields():
    s = RunSpec(task="lm", arch="mamba2-130m", method="diana",
                n_workers=9, n_byz=2, attack="IPM", aggregator="tm",
                bucket_size=3, agg_mode="pallas", compressor="natural",
                p=0.25, lr=1e-3, optimizer="adam",
                optimizer_kwargs={"b1": 0.8}, steps=17, seed=11,
                method_kwargs={"alpha": 0.5},
                attack_kwargs={"eps": 0.2},
                aggregator_kwargs={"trim": 2},
                data_kwargs={"seq_len": 32, "reduced": True})
    d = s.to_dict()
    assert d["schema_version"] == 1
    for f in dataclasses.fields(RunSpec):
        assert d[f.name] == getattr(s, f.name)
    assert RunSpec.from_dict(d) == s


def test_from_dict_rejects_unknown_fields_with_suggestion():
    d = RunSpec(task="logreg").to_dict()
    d["agregator"] = "cm"
    with pytest.raises(ValueError, match="did you mean 'aggregator'"):
        RunSpec.from_dict(d)


def test_from_dict_rejects_schema_version_mismatch():
    d = RunSpec(task="logreg").to_dict()
    d["schema_version"] = 99
    with pytest.raises(ValueError, match="schema_version"):
        RunSpec.from_dict(d)


# ---------------------------------------------------------------------------
# eager validation
# ---------------------------------------------------------------------------

def test_unknown_component_names_suggest():
    with pytest.raises(ValueError, match="did you mean 'marina'"):
        RunSpec(method="marinna")
    with pytest.raises(ValueError, match="did you mean 'ALIE'"):
        RunSpec(attack="ALIEE")
    with pytest.raises(ValueError, match="did you mean 'krum'"):
        RunSpec(aggregator="krun")
    with pytest.raises(ValueError, match="unknown compressor"):
        RunSpec(compressor="gzipq")
    # topk IS registered now (EF21 family) — and byz_ef21 rejects
    # non-contractive compressors eagerly, at spec construction
    with pytest.raises(ValueError, match="contractive"):
        RunSpec(method="byz_ef21", compressor="randk",
                compressor_kwargs={"ratio": 0.5})
    RunSpec(method="byz_ef21", compressor="topk",
            compressor_kwargs={"ratio": 0.5})


def test_agg_mode_validated_eagerly():
    with pytest.raises(ValueError, match="agg_mode"):
        RunSpec(agg_mode="pallass")
    for mode in AGG_BACKENDS:
        if mode == "sparse_support":
            RunSpec(agg_mode=mode, compressor="randk",
                    compressor_kwargs={"ratio": 0.5,
                                       "common_randomness": True})
        else:
            RunSpec(agg_mode=mode)


def test_p_bounds():
    with pytest.raises(ValueError, match="p="):
        RunSpec(p=0.0)
    with pytest.raises(ValueError, match="p="):
        RunSpec(p=1.5)
    RunSpec(p=1.0)


def test_byzantine_majority_rejected():
    with pytest.raises(ValueError, match="delta"):
        RunSpec(n_workers=4, n_byz=2)
    with pytest.raises(ValueError, match="delta"):
        RunSpec(n_workers=5, n_byz=3)


def test_bucketed_delta_warns():
    with warnings.catch_warnings(record=True) as w:
        warnings.simplefilter("always")
        RunSpec(n_workers=15, n_byz=5, aggregator="cm", bucket_size=2)
    assert any("bucketing" in str(x.message) for x in w)


def test_sparse_support_needs_common_randomness_randk():
    with pytest.raises(ValueError, match="sparse_support"):
        RunSpec(agg_mode="sparse_support")
    with pytest.raises(ValueError, match="common_randomness"):
        RunSpec(agg_mode="sparse_support", compressor="randk",
                compressor_kwargs={"ratio": 0.5})


def test_lm_task_requires_arch():
    with pytest.raises(ValueError, match="arch"):
        RunSpec(task="lm")


def test_kwargs_must_be_json_scalars():
    with pytest.raises(ValueError, match="JSON"):
        RunSpec(compressor_kwargs={"ratio": (1, 2)})     # tuple != list


def test_config_validates_eagerly_too():
    """Satellite: a bad agg_mode / byzantine majority used to surface only
    at call time inside jit; the config now fails at construction."""
    from repro.core import ByzVRMarinaConfig
    with pytest.raises(ValueError, match="agg_mode"):
        ByzVRMarinaConfig(n_workers=4, agg_mode="nope")
    with pytest.raises(ValueError, match="n_byz"):
        ByzVRMarinaConfig(n_workers=4, n_byz=2)
    with pytest.raises(ValueError, match="p="):
        ByzVRMarinaConfig(n_workers=4, p=1.5)


# ---------------------------------------------------------------------------
# unified registry
# ---------------------------------------------------------------------------

def test_registry_kinds_and_components():
    assert set(kinds()) >= {"method", "attack", "aggregator", "compressor",
                            "optimizer", "agg_mode", "arch", "task"}
    from repro.core.estimators import ESTIMATORS
    assert components("method") == tuple(sorted(ESTIMATORS))
    assert components("agg_mode") == tuple(AGG_BACKENDS)
    assert "all_to_all" in components("agg_mode")
    assert "none" in components("optimizer")
    assert "qwen3-1.7b" in components("arch")


def test_registry_describe_nonempty_everywhere():
    for kind in kinds():
        table = describe(kind)
        assert table, kind
        for name, summary in table.items():
            assert summary, (kind, name)


def test_registry_check_did_you_mean():
    with pytest.raises(ValueError, match="did you mean 'gspmd'"):
        check("agg_mode", "gspdm")
    with pytest.raises(ValueError, match="unknown registry kind"):
        components("methods")


def test_registry_resolve_builds_components():
    assert resolve("compressor", "randk", ratio=0.5).ratio == 0.5
    assert resolve("attack", "ALIE").name == "ALIE"
    assert resolve("aggregator", "cm", bucket_size=2).bucket_size == 2
    assert resolve("optimizer", "none") is None
    assert resolve("optimizer", "sgd", lr=0.1).lr == 0.1


# ---------------------------------------------------------------------------
# replace / sweep
# ---------------------------------------------------------------------------

def test_replace_dotted_keys():
    s = RunSpec(task="logreg", compressor="randk",
                compressor_kwargs={"ratio": 0.5})
    s2 = s.replace(**{"compressor_kwargs.ratio": 0.1, "attack": "BF"})
    assert s2.compressor_kwargs == {"ratio": 0.1}
    assert s2.attack == "BF"
    assert s.compressor_kwargs == {"ratio": 0.5}      # original untouched
    with pytest.raises(ValueError, match="dotted"):
        s.replace(**{"attack.z": 1.0})


def test_sweep_expand_cartesian_and_stable_ids():
    base = RunSpec(task="logreg", steps=1, compressor="randk",
                   compressor_kwargs={"ratio": 0.5})
    sweep = Sweep(base, {"attack": ("NA", "BF"),
                         "compressor_kwargs.ratio": (0.1, 0.5)})
    cells = list(sweep.expand())
    assert len(cells) == len(sweep) == 4
    ids = [rid for rid, _ in cells]
    assert ids == ["attack=NA__compressor_kwargs.ratio=0.1",
                   "attack=NA__compressor_kwargs.ratio=0.5",
                   "attack=BF__compressor_kwargs.ratio=0.1",
                   "attack=BF__compressor_kwargs.ratio=0.5"]
    assert ids == [rid for rid, _ in sweep.expand()]   # stable
    specs = dict(cells)
    assert specs[ids[2]].attack == "BF"
    assert specs[ids[2]].compressor_kwargs["ratio"] == 0.1


def test_sweep_rejects_unknown_grid_field():
    with pytest.raises(ValueError, match="not a RunSpec field"):
        Sweep(RunSpec(task="logreg"), {"atack": ("NA",)})


# ---------------------------------------------------------------------------
# build surface
# ---------------------------------------------------------------------------

def test_build_config_resolves_components():
    s = RunSpec(task="logreg", aggregator="tm", bucket_size=2,
                aggregator_kwargs={"trim": 2}, compressor="randk",
                compressor_kwargs={"ratio": 0.25}, attack="IPM",
                optimizer="sgd", optimizer_kwargs={"momentum": 0.9},
                lr=0.05)
    cfg = s.build_config()
    assert cfg.aggregator.rule == "tm" and cfg.aggregator.trim == 2
    assert cfg.compressor.ratio == 0.25
    assert cfg.attack.name == "IPM"
    assert cfg.optimizer.momentum == 0.9 and cfg.optimizer.lr == 0.05
    assert cfg.agg_mode == "gspmd"


def test_runner_callback_every_and_early_stop():
    from repro.api import run
    s = RunSpec(task="logreg", steps=10,
                data_kwargs={"dim": 5, "n_samples": 30, "batch_size": 4})
    seen = []
    run(s, log_every=10,
        callback=lambda it, st, m: (seen.append(it), False)[1],
        callback_every=3)
    assert seen == [2, 5, 8, 9]          # every 3rd step + the last
    stopped = []
    result = run(s, log_every=10,
                 callback=lambda it, st, m: (stopped.append(it), it >= 5)[1],
                 callback_every=3)
    assert stopped == [2, 5]             # truthy return stops the run
    assert result.history[-1]["step"] == 5


def test_registry_resolve_method_rejects_kwargs():
    with pytest.raises(TypeError, match="method_kwargs"):
        resolve("method", "sgdm", momentum=0.9)
    assert resolve("method", "sgdm") is not None


def test_build_assembles_experiment():
    s = RunSpec(task="logreg", steps=2,
                data_kwargs={"dim": 7, "n_samples": 40, "batch_size": 4})
    exp = build(s)
    assert exp.method.name == "marina"
    assert exp.data.features.shape == (40, 7)
    batch = exp.minibatch(0, __import__("jax").random.PRNGKey(0))
    assert batch["x"].shape == (5, 4, 7)
