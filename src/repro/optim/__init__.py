from repro.optim.optimizers import SGD, Adam, get_optimizer  # noqa: F401
