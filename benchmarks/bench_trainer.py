"""System throughput: wall-clock steps/s of the full Byzantine-robust
trainer on this host (single device; the distributed step is the same code
jitted onto the mesh). One row per (model, method, aggregator, compressor)
with tokens/s — every method runs through the unified round engine
(core/engine.py), so the estimator is the only thing that varies.
"""
import time

import jax

from benchmarks.common import emit
from repro.configs import get_config
from repro.core import (ByzVRMarinaConfig, get_aggregator, get_attack,
                        get_compressor, make_method)
from repro.data import TokenStream, corrupt_labels_lm
from repro.models import init_params, loss_fn

KEY = jax.random.PRNGKey(0)


def run():
    n, bw, s = 4, 2, 64
    for arch in ["qwen3-1.7b", "mamba2-130m", "phi3.5-moe-42b-a6.6b"]:
        cfg = get_config(arch).reduced()
        stream = TokenStream(vocab_size=cfg.vocab_size, seq_len=s,
                             n_workers=n, per_worker_batch=bw,
                             num_codebooks=cfg.num_codebooks,
                             frontend_tokens=cfg.frontend_tokens,
                             d_model=cfg.d_model)

        def loss(params, batch, key):
            return loss_fn(params, cfg, batch)

        for method_name, agg_name, comp_name in [
                ("marina", "mean", "identity"),
                ("marina", "cm", "identity"),
                ("marina", "cm", "randk"),
                ("marina", "rfa", "identity"),
                ("sgdm", "cm", "identity"),
                ("csgd", "cm", "randk")]:
            comp = (get_compressor("randk", ratio=0.25)
                    if comp_name == "randk" else get_compressor("identity"))
            bcfg = ByzVRMarinaConfig(
                n_workers=n, n_byz=1, p=0.25, lr=1e-2,
                aggregator=get_aggregator(agg_name,
                                          bucket_size=0 if agg_name == "mean"
                                          else 2),
                compressor=comp, attack=get_attack("ALIE"))
            method = make_method(method_name, bcfg, loss, corrupt_labels_lm)
            step = jax.jit(method.step)
            state = method.init(init_params(KEY, cfg), stream.anchor(0), KEY)
            # warmup (compile)
            state, _ = step(state, stream.minibatch(0), stream.anchor(0),
                            KEY)
            jax.block_until_ready(state["g"])
            iters = 8
            t0 = time.perf_counter()
            for it in range(iters):
                state, m = step(state, stream.minibatch(it),
                                stream.anchor(it),
                                jax.random.fold_in(KEY, it))
            jax.block_until_ready(state["g"])
            dt = (time.perf_counter() - t0) / iters
            toks = n * bw * s
            emit(f"trainer/{arch}/{method_name}/{agg_name}+{comp_name}",
                 dt * 1e6, f"tokens_per_s={toks/dt:.0f}")


if __name__ == "__main__":
    run()
