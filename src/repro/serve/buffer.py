"""Double-buffered device-resident update buffer (DESIGN.md §4).

The service ingests one update at a time (a row gathered out of the
in-flight store) while the previously-filled buffer may still be feeding an
asynchronously-dispatched aggregation — the classic double buffer. Both
halves live on device as stacked ``(K, ...)`` pytrees:

  * ``offer`` writes one in-flight row into the next free slot with a
    single fused jitted gather+``dynamic_update_slice`` per leaf. The
    destination buffer argument is DONATED (off CPU), so the write is
    in-place — ingestion costs one row of HBM traffic, never a buffer copy.
  * ``swap`` hands the filled pytree (plus its per-slot host metadata:
    client id, dispatch version, sequence number) to the caller and opens a
    fresh half. The fresh half starts as a new allocation rather than
    recycling the handle the in-flight aggregation is still reading, which
    is what makes overlapping ingest-during-aggregate safe under donation.

Sequence-number dedup is enforced here, at the mouth of the pipe: client
``seq`` numbers are per-client monotone (arrivals.py), so an update is
accepted iff its seq is strictly newer than the client's last accepted one
(rejects network replays, ``rej_replay``) and the client does not already
occupy a slot in the open buffer (one contribution per client per round,
``rej_dup_client``) — a replayed update is never double-counted no matter
where the duplicate lands relative to a fire.
"""
from __future__ import annotations

from typing import Optional

import jax
import numpy as np


def _default_donate() -> bool:
    # buffer donation is an XLA no-op (with a warning) on CPU hosts
    return jax.default_backend() != "cpu"


class DoubleBuffer:
    """K-slot double buffer with per-client sequence dedup."""

    def __init__(self, capacity: int, n_clients: int,
                 donate: Optional[bool] = None):
        if capacity < 1:
            raise ValueError(f"capacity={capacity} must be >= 1")
        self.capacity = int(capacity)
        self.n_clients = int(n_clients)
        self.donate = _default_donate() if donate is None else bool(donate)
        self._buf = None                     # open half, (K, ...) pytree
        self.count = 0
        # per-slot metadata of the open half (host side)
        self.clients = np.full(capacity, -1, np.int64)
        self.versions = np.zeros(capacity, np.int64)
        self.seqs = np.full(capacity, -1, np.int64)
        # dedup state
        self.last_accepted = np.full(n_clients, -1, np.int64)
        self.in_buffer = np.zeros(n_clients, bool)
        self.stats = {"accepted": 0, "rej_replay": 0, "rej_dup_client": 0}
        self._ingest = jax.jit(
            self._ingest_impl,
            donate_argnums=(0,) if self.donate else ())

    @staticmethod
    def _ingest_impl(buf, inflight, client, slot):
        def leaf(B, A):
            row = jax.lax.dynamic_index_in_dim(A, client, 0, keepdims=False)
            return jax.lax.dynamic_update_index_in_dim(
                B, row.astype(B.dtype), slot, 0)

        return jax.tree.map(leaf, buf, inflight)

    def _alloc_like(self, inflight):
        import jax.numpy as jnp
        k = self.capacity
        return jax.tree.map(
            lambda a: jnp.zeros((k,) + a.shape[1:], a.dtype), inflight)

    # -- ingest -------------------------------------------------------------
    def offer(self, client: int, seq: int, version: int, inflight) -> bool:
        """Try to admit client's in-flight row (``inflight[client]``) into
        the next free slot. Returns False (and counts why) when dedup
        rejects it; the caller fires when ``full()``."""
        if self.count >= self.capacity:
            raise RuntimeError("offer() on a full buffer — fire first")
        if seq <= self.last_accepted[client]:
            self.stats["rej_replay"] += 1
            return False
        if self.in_buffer[client]:
            self.stats["rej_dup_client"] += 1
            return False
        if self._buf is None:
            self._buf = self._alloc_like(inflight)
        slot = self.count
        self._buf = self._ingest(self._buf, inflight,
                                 np.int32(client), np.int32(slot))
        self.clients[slot] = client
        self.versions[slot] = version
        self.seqs[slot] = seq
        self.last_accepted[client] = seq
        self.in_buffer[client] = True
        self.count += 1
        self.stats["accepted"] += 1
        return True

    def full(self) -> bool:
        return self.count == self.capacity

    # -- handoff ------------------------------------------------------------
    def swap(self):
        """Close the open half: return ``(tree, clients, versions, seqs)``
        and start a fresh empty half (the returned handle stays valid for
        the caller's async aggregation; new offers never donate it)."""
        if self._buf is None:
            raise RuntimeError("swap() on an empty buffer")
        out = (self._buf, self.clients.copy(), self.versions.copy(),
               self.seqs.copy())
        self._buf = None
        self.count = 0
        self.clients[:] = -1
        self.versions[:] = 0
        self.seqs[:] = -1
        self.in_buffer[:] = False
        return out
