"""Baseline methods the paper compares against (Section 3 / Appendix B).

* SGD          — Parallel-SGD with plain averaging (Zinkevich et al. 2010).
* BR-SGDm      — robust aggregation of worker momenta (Karimireddy 2021/22).
* CSGD         — compressed SGD; with a robust aggregator = BR-CSGD.
* BR-DIANA     — DIANA (Mishchenko et al. 2019) shifts + robust aggregation.
* BR-MVR       — STORM momentum variance reduction + robust aggregation.
* Byrd-SVRG    — SVRG estimator + geometric median (App. B.4 proxy of
                 Byrd-SAGA; the paper itself uses SVRG since SAGA's per-sample
                 table is memory-hostile).

All share Byz-VR-MARINA's round skeleton — that is the point of the paper's
comparison, and of the unified round engine (core/engine.py): every factory
below is a thin wrapper that plugs the matching ``GradientEstimator``
(core/estimators.py) into the shared engine, preserving the pre-refactor
``(init, step)`` signatures. New code should use ``engine.make_method``
directly; these wrappers exist so the paper-era call sites keep working.

Byrd-SAGA keeps its bespoke per-sample-gradient-table interface (it does not
fit the stacked-minibatch protocol) but runs on the same attack/aggregation
primitives.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp

from repro.core.byz_vr_marina import ByzVRMarinaConfig   # noqa: F401
from repro.core.engine import make_method, message_phase
from repro.core import tree_utils as tu


def _sgd_update(params, g, lr):
    return jax.tree.map(
        lambda x, gg: (x.astype(jnp.float32) - lr * gg.astype(jnp.float32)
                       ).astype(x.dtype), params, g)


# ---------------------------------------------------------------------------
# SGD / BR-SGDm
# ---------------------------------------------------------------------------

def make_sgd_step(cfg: ByzVRMarinaConfig, loss_fn, corrupt_fn=None,
                  momentum: float = 0.0):
    """momentum=0 -> Parallel-SGD; momentum>0 -> BR-SGDm (worker momenta are
    what gets attacked & aggregated, per Karimireddy et al. 2021)."""
    m = make_method("sgdm" if momentum > 0.0 else "sgd", cfg, loss_fn,
                    corrupt_fn, momentum=momentum)

    def init(params):
        return m.init(params, None, None)

    return init, m.step


# ---------------------------------------------------------------------------
# CSGD / BR-CSGD
# ---------------------------------------------------------------------------

def make_csgd_step(cfg: ByzVRMarinaConfig, loss_fn, corrupt_fn=None):
    m = make_method("csgd", cfg, loss_fn, corrupt_fn)

    def init(params):
        return m.init(params, None, None)

    return init, m.step


# ---------------------------------------------------------------------------
# BR-DIANA
# ---------------------------------------------------------------------------

def make_diana_step(cfg: ByzVRMarinaConfig, loss_fn, corrupt_fn=None,
                    alpha: Optional[float] = None):
    """DIANA: worker i keeps a shift h_i, uploads Q(g_i - h_i); the server
    adds the aggregated compressed difference to the shift mean. alpha
    defaults to 1/(1+omega) (Mishchenko et al. 2019)."""
    m = make_method("diana", cfg, loss_fn, corrupt_fn, alpha=alpha)

    def init(params, d_hint: int = 1):
        # d_hint is static (python int): used only to size alpha
        m.estimator.d_hint = int(d_hint)
        return m.init(params, None, None)

    return init, m.step


# ---------------------------------------------------------------------------
# BR-MVR
# ---------------------------------------------------------------------------

def make_br_mvr_step(cfg: ByzVRMarinaConfig, loss_fn, corrupt_fn=None,
                     alpha: float = 0.1):
    """BR-MVR (Karimireddy et al. 2021): momentum variance reduction
    (STORM/MVR estimator) per worker + robust aggregation.

        v_i^k = g_i(x^k) + (1-α)(v_i^{k-1} - g_i(x^{k-1}))
    """
    m = make_method("mvr", cfg, loss_fn, corrupt_fn, alpha=alpha)

    def init(params, batch, key):
        return m.init(params, batch, key)

    return init, m.step


# ---------------------------------------------------------------------------
# Byrd-SVRG (App. B.4)
# ---------------------------------------------------------------------------

def make_byrd_svrg_step(cfg: ByzVRMarinaConfig, loss_fn, corrupt_fn=None):
    """Loopless SVRG: with prob p refresh the snapshot w <- x and the full
    worker gradients; each round worker i sends
    v_i = g_i(x, mb) - g_i(w, mb) + full_i, aggregated with RFA (geometric
    median) per Wu et al. (2020)."""
    m = make_method("svrg", cfg, loss_fn, corrupt_fn)
    return m.init, m.step


# ---------------------------------------------------------------------------
# Byrd-SAGA (bespoke interface: per-sample gradient tables)
# ---------------------------------------------------------------------------

def make_byrd_saga_step(cfg: ByzVRMarinaConfig, grad_sample_fn, n_samples,
                        params_template, corrupt_labels=None):
    """Byrd-SAGA (Wu et al. 2020): per-worker SAGA estimator (per-sample
    gradient table — O(m·d) memory, which is why the paper benchmarks the
    SVRG proxy instead; we provide the real thing for small problems) +
    geometric-median aggregation.

    grad_sample_fn(params, x_j, y_j) -> per-sample gradient pytree.
    The returned step takes idx (n, b) minibatch indices and data
    {"x": (n, m, d), "y": (n, m)} (stacked per worker).
    """
    n = cfg.n_workers
    m = n_samples

    def one_worker(params, table, table_mean, xw, yw, idx_w):
        def g_of(j):
            return grad_sample_fn(params, xw[j], yw[j])

        g_new = jax.vmap(g_of)(idx_w)                       # (b, ...)
        old = jax.tree.map(lambda t: t[idx_w], table)       # (b, ...)
        # SAGA estimator: mean_j[ g_new - old ] + table_mean
        v = jax.tree.map(
            lambda gn, go, tm: jnp.mean(gn - go, axis=0) + tm,
            g_new, old, table_mean)
        # table update
        new_table = jax.tree.map(lambda t, gn: t.at[idx_w].set(gn),
                                 table, g_new)
        new_mean = jax.tree.map(
            lambda tm, t_old, gn: tm + jnp.sum(
                gn - t_old[idx_w], axis=0) / m,
            table_mean, table, g_new)
        return v, new_table, new_mean

    def step(state, data, idx, key):
        k_attack, k_agg = jax.random.split(key)
        params = state["params"]
        xw, yw = data["x"], data["y"]
        if corrupt_labels is not None and cfg.attack.flips_labels \
                and cfg.n_byz:
            yw = corrupt_labels(yw, cfg.byz_mask())
        v, tables, means = jax.vmap(
            lambda t, tm, x, y, i: one_worker(params, t, tm, x, y, i)
        )(state["tables"], state["table_means"], xw, yw, idx)
        g = message_phase(cfg, k_attack, k_agg, v)
        new_params = _sgd_update(params, g, cfg.lr)
        return ({"params": new_params, "tables": tables,
                 "table_means": means, "step": state["step"] + 1},
                {"g_norm": jnp.sqrt(tu.tree_norm_sq(g))})

    def init(params, data):
        def zero_table(leaf):
            return jnp.zeros((n, m) + leaf.shape, jnp.float32)

        tables = jax.tree.map(zero_table, params)
        means = jax.tree.map(
            lambda p: jnp.zeros((n,) + p.shape, jnp.float32), params)
        return {"params": params, "tables": tables, "table_means": means,
                "step": jnp.zeros((), jnp.int32)}

    return init, step
