"""Pure-jnp oracles for the Pallas kernels.

The norm-based oracles (rfa_ref/krum_ref/pair_sqdists_ref) delegate to
``core.aggregators.Aggregator`` — the paper-faithful tree path IS the
parity oracle for the fused norm_agg kernels (DESIGN.md §3)."""
from __future__ import annotations

import jax.numpy as jnp


def robust_agg_ref(x, *, bucket_size: int = 1, rule: str = "median",
                   trim: int = 1):
    """x: (n, d) already permuted worker vectors -> (d,) aggregate.

    bucket_size s: contiguous groups of s rows are averaged first (Alg. 2's
    bucketing; the random permutation is applied by the caller).
    """
    n, d = x.shape
    xf = x.astype(jnp.float32)
    if bucket_size > 1:
        # Alg. 2 semantics (aggregators._bucketize_perm): a partial last
        # bucket is padded with the stacked mean, not dropped.
        nb = -(-n // bucket_size)
        pad = nb * bucket_size - n
        if pad:
            fill = jnp.broadcast_to(xf.mean(axis=0, keepdims=True), (pad, d))
            xf = jnp.concatenate([xf, fill], axis=0)
        xf = xf.reshape(nb, bucket_size, d).mean(axis=1)
    m = xf.shape[0]
    if rule == "mean":
        return xf.mean(axis=0)
    xs = jnp.sort(xf, axis=0)
    if rule == "median":
        if m % 2:
            return xs[m // 2]
        return 0.5 * (xs[m // 2 - 1] + xs[m // 2])
    if rule == "trimmed":
        t = min(trim, (m - 1) // 2)
        return xs[t:m - t].mean(axis=0)
    raise ValueError(rule)


def pair_sqdists_ref(x):
    """(n, n) pairwise squared distances of (n, d) rows, fp32, clamped ≥ 0
    (matches aggregators._tree_pair_sqdists on a single flat leaf)."""
    from repro.core.aggregators import _tree_pair_sqdists
    return _tree_pair_sqdists({"x": x})


def rfa_ref(x, *, iters: int = 8, eps: float = 1e-8):
    """Smoothed-Weiszfeld geometric median of (n, d) pre-bucketed rows."""
    from repro.core.aggregators import Aggregator
    agg = Aggregator("rfa", iters=iters, eps=eps)
    return agg(None, x)


def krum_ref(x, *, n_byz: int = 1):
    """Krum (Eq. 15) over (n, d) pre-bucketed rows."""
    from repro.core.aggregators import Aggregator
    return Aggregator("krum", n_byz=n_byz)(None, x)


def block_quantize_ref(x, u, *, levels: int, block: int):
    """Block-wise l2 dithering: per contiguous block of ``block`` coords,
    q(x)_i = ||x_blk|| * sign(x_i) * floor(|x_i|/||x_blk|| * s + u_i) / s.

    x, u: (d,); zero-padded to a block multiple (matching the kernel wrapper).
    Unbiased for u ~ U[0,1) (stochastic rounding), omega bounded block-wise.
    """
    d = x.shape[0]
    s = levels
    pad = (-d) % block
    if pad:
        x = jnp.pad(x, (0, pad))
        u = jnp.pad(u, (0, pad))
    xb = x.astype(jnp.float32).reshape(-1, block)
    ub = u.astype(jnp.float32).reshape(-1, block)
    norm = jnp.sqrt(jnp.sum(xb * xb, axis=1, keepdims=True))
    scaled = jnp.where(norm > 0, jnp.abs(xb) / jnp.maximum(norm, 1e-30), 0.0)
    level = jnp.floor(scaled * s + ub)
    out = norm * jnp.sign(xb) * level / s
    return out.reshape(-1)[:d].astype(x.dtype)
