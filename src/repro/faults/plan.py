"""Seeded fault plans — the replayable chaos schedule.

A ``FaultPlan`` is a *pure description*: which fault kinds fire, with what
per-round probability, on which workers. Every random draw the plan induces
is derived from ``fold_in``s of the engine's own per-round attack key plus
the plan seed (``inject.fault_key``), so a chaotic run is replayable
bit-for-bit from ``(spec, plan)`` alone — the same contract the attack
layer already honors. Nothing here touches jax: the plan is static config,
JSON-round-trippable through ``RunSpec.faults``.

Fault registry (``FAULTS``):

* ``nan_grad``     — tensor: a worker's candidate rows become NaN
                     (fp-overflow gradients).
* ``inf_blowup``   — tensor: candidate rows become +inf (diverged local
                     step).
* ``stale_replay`` — tensor: candidate rows become zero (a replayed,
                     already-applied update; finite, so invisible to the
                     non-finite guard BY DESIGN — robust rules + influence
                     detection are the containment layer, see DESIGN §6).
* ``corrupt_wire`` — wire: random bit-flips XORed into every payload array
                     of the worker's ``WireCandidates`` rows.
* ``crash``        — process: the worker subprocess / serve client dies
                     (exec retry + serve recovery handle it).
* ``hang``         — process: the worker stalls past its timeout.

Kinds are grouped by injection site: TENSOR + WIRE kinds act inside
``engine.message_phase`` (message faults); PROCESS kinds act in
``exec.worker`` / ``serve.arrivals``.
"""
from __future__ import annotations

import dataclasses
import difflib
import json
from typing import Tuple

FAULTS = ("nan_grad", "inf_blowup", "stale_replay", "corrupt_wire",
          "crash", "hang")
TENSOR_FAULTS = ("nan_grad", "inf_blowup", "stale_replay")
WIRE_FAULTS = ("corrupt_wire",)
PROCESS_FAULTS = ("crash", "hang")
MESSAGE_FAULTS = TENSOR_FAULTS + WIRE_FAULTS

# Row-fill values for the tensor kinds (stale_replay replays a no-op
# update: zeros, finite on purpose).
TENSOR_FILL = {"nan_grad": float("nan"), "inf_blowup": float("inf"),
               "stale_replay": 0.0}


def _unknown_kind(kind: str) -> str:
    close = difflib.get_close_matches(kind, FAULTS, n=1)
    hint = f" — did you mean {close[0]!r}?" if close else ""
    return f"unknown fault kind {kind!r}{hint} (known: {', '.join(FAULTS)})"


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """One fault kind's schedule: fire with ``prob`` per round, restricted
    to ``workers`` (empty tuple = every worker is eligible)."""
    kind: str
    prob: float = 1.0
    workers: Tuple[int, ...] = ()

    def __post_init__(self):
        if self.kind not in FAULTS:
            raise ValueError(_unknown_kind(self.kind))
        if not 0.0 <= float(self.prob) <= 1.0:
            raise ValueError(f"fault prob must be in [0, 1], got {self.prob}")
        object.__setattr__(self, "prob", float(self.prob))
        ws = tuple(int(w) for w in self.workers)
        if any(w < 0 for w in ws):
            raise ValueError(f"fault workers must be >= 0, got {ws}")
        object.__setattr__(self, "workers", ws)

    def to_dict(self) -> dict:
        return {"kind": self.kind, "prob": self.prob,
                "workers": list(self.workers)}


@dataclasses.dataclass(frozen=True)
class FaultPlan:
    """The full seeded chaos schedule for one run."""
    seed: int = 0
    faults: Tuple[FaultSpec, ...] = ()

    def __post_init__(self):
        object.__setattr__(self, "seed", int(self.seed))
        fs = tuple(f if isinstance(f, FaultSpec) else FaultSpec(**f)
                   for f in self.faults)
        object.__setattr__(self, "faults", fs)

    # -- site selectors ----------------------------------------------------
    def of_kinds(self, kinds) -> Tuple[FaultSpec, ...]:
        return tuple(f for f in self.faults if f.kind in kinds)

    @property
    def message_faults(self) -> Tuple[FaultSpec, ...]:
        return self.of_kinds(MESSAGE_FAULTS)

    @property
    def tensor_faults(self) -> Tuple[FaultSpec, ...]:
        return self.of_kinds(TENSOR_FAULTS)

    @property
    def wire_faults(self) -> Tuple[FaultSpec, ...]:
        return self.of_kinds(WIRE_FAULTS)

    @property
    def process_faults(self) -> Tuple[FaultSpec, ...]:
        return self.of_kinds(PROCESS_FAULTS)

    def worst_case_faulty(self, n: int) -> int:
        """Upper bound on simultaneously message-faulted workers — the f in
        the 2·(n_byz + f) < n budget check (spec validation)."""
        hit = set()
        for f in self.message_faults:
            if f.prob <= 0.0:
                continue
            hit |= set(f.workers) if f.workers else set(range(n))
        return len(hit & set(range(n)))

    # -- (de)serialization -------------------------------------------------
    def to_dict(self) -> dict:
        return {"seed": self.seed,
                "faults": [f.to_dict() for f in self.faults]}

    @classmethod
    def from_dict(cls, d: dict) -> "FaultPlan":
        if not isinstance(d, dict):
            raise TypeError(f"FaultPlan dict expected, got {type(d).__name__}")
        extra = set(d) - {"seed", "faults"}
        if extra:
            raise ValueError(f"unknown FaultPlan keys {sorted(extra)} "
                             "(expected: seed, faults)")
        faults = []
        for f in d.get("faults", ()):
            if isinstance(f, str):         # shorthand: ["nan_grad", ...]
                f = {"kind": f}
            unknown = set(f) - {"kind", "prob", "workers"}
            if unknown:
                raise ValueError(f"unknown FaultSpec keys {sorted(unknown)} "
                                 "(expected: kind, prob, workers)")
            faults.append(FaultSpec(**f))
        return cls(seed=d.get("seed", 0), faults=tuple(faults))

    def to_json(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True)

    @classmethod
    def from_json(cls, s: str) -> "FaultPlan":
        return cls.from_dict(json.loads(s))


def as_plan(obj) -> "FaultPlan | None":
    """Coerce ``RunSpec.faults``-style input into a FaultPlan. ``None`` or
    an empty dict means no plan."""
    if obj is None or obj == {}:
        return None
    if isinstance(obj, FaultPlan):
        return obj
    return FaultPlan.from_dict(obj)
