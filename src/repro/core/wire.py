"""The wire protocol layer: compressed payloads from worker to kernel.

The jnp ``Compressor`` path materializes every worker's DENSE compressed
candidate — compress writes (n, d), aggregation reads (n, d) again — so
compression saves wire bytes in the story but not a single HBM byte in the
simulation. This module closes that gap for ``agg_mode="pallas"``
(DESIGN.md §Wire): estimators hand the engine a ``WireCandidates`` payload
(the actual wire bytes: sparse (vals, idx) / int8 levels / signs / bf16)
instead of a dense stacked tree, and the aggregation kernels reconstruct
``cand = base + decode(payload)`` per (n, TILE_D) block in VMEM
(``kernels/quantize.recon_block``). The corrupt→compress→reconstruct→
attack→bucket→aggregate chain then touches HBM exactly once — for the wire
bytes, not for (n, d).

Layer contract:

* ``pack_candidates``   — per (worker, leaf) packing with compress_tree's
                          exact RNG schedule (fold_in(worker_key, leaf_i)),
                          so randk supports / int8 dither coincide
                          bit-for-bit with the jnp oracle.
* ``decoded_payload``   — jnp reconstruction ≡ vmap(compress_tree): the
                          worker-/server-side state updates (DIANA's h,
                          EF21's g_i, cmfilter's u) reuse the payload
                          instead of compressing twice.
* ``reconstruct``       — dense candidate tree (base + decoded, leaf-dtype
                          arithmetic): the fallback for attacks that need
                          materialized candidates (RN) or non-pallas modes.
* ``wire_stats``        — good-worker mean/std for omniscient attacks read
                          FROM the wire: elementwise decode for dense
                          formats, flat scatter-add + gathered cross-terms
                          for sparse — never an (n, d) scatter. (One
                          documented exception: sparse payloads with a
                          non-f32 candidate dtype reconstruct densely for
                          stats, because leaf-dtype rounding of the
                          candidates cannot be expressed termwise.)
* ``wire_message_phase``— the engine's lines 9–10 over a WireCandidates:
                          fused attack + one-sweep aggregation
                          (sharded_agg.tree_aggregate_pallas_wire), with
                          dense-reconstruct fallbacks that keep trajectories
                          method-identical.

``measured_bits`` reads the semantic wire size off the packed arrays (k,
block counts, value dtypes as actually packed); the conformance harness
pins it to ``theory.comm_bits_per_round(..., dims=...)`` so the payloads
the kernels consume are exactly what the theory bills for.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import tree_utils as tu
from repro.core.compressors import _MAX_UNITS
from repro.kernels import quantize


@dataclasses.dataclass(frozen=True)
class WireCandidates:
    """A stacked candidate pytree in wire form — what estimators hand the
    engine's message phase instead of the dense (n, ...) tree.

    ``payloads[j]`` is leaf j's packed dict (each array worker-stacked,
    (n, ...)); ``base`` is None or a tuple of (rows, d_j) reconstruction
    bases (rows = n for per-worker EF/mirror state, 1 for a shared server
    estimate); ``dtypes[j]`` is the dtype the ORACLE candidate leaf would
    have (decode + base arithmetic round-trips through it);
    ``src_dtypes[j]`` is the compressed leaf's own dtype (what
    ``compress`` would return — ``decoded_payload``'s output dtype).
    """
    fmt: str
    n: int
    payloads: tuple
    base: Optional[tuple]
    treedef: object
    shapes: tuple
    dtypes: tuple
    src_dtypes: tuple


def _wc_flatten(wc):
    return (wc.payloads, wc.base), (wc.fmt, wc.n, wc.treedef, wc.shapes,
                                    wc.dtypes, wc.src_dtypes)


def _wc_unflatten(aux, children):
    fmt, n, treedef, shapes, dtypes, src_dtypes = aux
    payloads, base = children
    return WireCandidates(fmt=fmt, n=n, payloads=tuple(payloads), base=base,
                          treedef=treedef, shapes=shapes, dtypes=dtypes,
                          src_dtypes=src_dtypes)


jax.tree_util.register_pytree_node(WireCandidates, _wc_flatten, _wc_unflatten)


def _leaf_d(shape) -> int:
    return int(math.prod(shape)) if shape else 1


# ---------------------------------------------------------------------------
# routing + packing
# ---------------------------------------------------------------------------

def wire_supported(cfg, stacked=None) -> bool:
    """Whether this (cfg, candidate tree) pair routes through the fused
    wire. Static — estimators branch on it at trace time. Requires the
    pallas backend, a declared kernel wire format, and (for sparse) leaves
    inside rand_k's per-coordinate selection regime (block selection on
    >2^22-unit leaves has no kernel wire; the jnp path handles it)."""
    comp = getattr(cfg, "compressor", None)
    if comp is None or getattr(cfg, "agg_mode", None) != "pallas":
        return False
    fmt = comp.wire_format
    if fmt is None or fmt == "dense32" or comp.fallback_only:
        return False
    if fmt == "sparse" and stacked is not None:
        dims = [_leaf_d(l.shape[1:]) for l in jax.tree.leaves(stacked)]
        if any(d > _MAX_UNITS for d in dims):
            return False
    return True


def _pack_fn(compressor):
    fmt = compressor.wire_format
    if fmt == "sparse":
        # TopK is the contractive sparse operator, RandK the unbiased one —
        # the same split Compressor encodes via contractive_fn.
        return functools.partial(quantize.pack_sparse,
                                 ratio=compressor.ratio,
                                 topk=compressor.contractive_fn is not None)
    return {"int8": quantize.pack_int8, "sign": quantize.pack_sign,
            "bf16": quantize.pack_bf16}[fmt]


def pack_candidates(compressor, qkeys, stacked, *, base=None,
                    base_shared: bool = False) -> WireCandidates:
    """Pack the to-be-compressed stacked tree into its wire payload.

    RNG contract: leaf i of worker w packs under fold_in(qkeys[w], i) —
    exactly ``jax.vmap(compress_tree)(qkeys, stacked)``'s key schedule, so
    the selected supports / dither draws coincide bit-for-bit with the jnp
    oracle. ``base`` is the reconstruction base tree (stacked (n, ...), or
    unstacked with ``base_shared=True`` for a server-shared estimate).
    """
    leaves, treedef = jax.tree.flatten(stacked)
    n = leaves[0].shape[0]
    fn = _pack_fn(compressor)
    base_leaves = (jax.tree.leaves(base) if base is not None
                   else [None] * len(leaves))
    payloads, bases, shapes, dtypes, src_dtypes = [], [], [], [], []
    for i, leaf in enumerate(leaves):
        lkeys = jax.vmap(lambda k, i=i: jax.random.fold_in(k, i))(qkeys)
        payloads.append(jax.vmap(fn)(lkeys, leaf.reshape(n, -1)))
        shapes.append(leaf.shape[1:])
        src_dtypes.append(leaf.dtype)
        b = base_leaves[i]
        if b is None:
            bases.append(None)
            dtypes.append(leaf.dtype)
        else:
            bases.append(b.reshape(1 if base_shared else n, -1))
            dtypes.append(jnp.result_type(b.dtype, leaf.dtype))
    return WireCandidates(
        fmt=compressor.wire_format, n=n, payloads=tuple(payloads),
        base=None if base is None else tuple(bases), treedef=treedef,
        shapes=tuple(shapes), dtypes=tuple(dtypes),
        src_dtypes=tuple(src_dtypes))


# ---------------------------------------------------------------------------
# jnp-side views of the wire
# ---------------------------------------------------------------------------

def decoded_payload(wc: WireCandidates):
    """Stacked dense tree EQUAL to ``vmap(compress_tree)`` on the packed
    input — the worker-side state updates reuse the payload instead of
    running the compressor a second time."""
    outs = []
    for payload, shape, dt in zip(wc.payloads, wc.shapes, wc.src_dtypes):
        d = _leaf_d(shape)
        dec = jax.vmap(lambda p: quantize.decode(wc.fmt, p, d))(payload)
        outs.append(dec.astype(dt).reshape((wc.n,) + shape))
    return jax.tree.unflatten(wc.treedef, outs)


def reconstruct(wc: WireCandidates):
    """The dense candidate tree the oracle path would materialize:
    decode → candidate dtype → + base → candidate dtype (leaf-dtype add,
    like the estimator's own arithmetic). The RN-attack / non-pallas
    fallback, and the stats fallback for sparse non-f32 leaves."""
    outs = []
    for j, (payload, shape, dt) in enumerate(zip(wc.payloads, wc.shapes,
                                                 wc.dtypes)):
        d = _leaf_d(shape)
        dec = jax.vmap(lambda p: quantize.decode(wc.fmt, p, d))(payload)
        x = dec.astype(dt)
        if wc.base is not None:
            x = (x.astype(jnp.float32)
                 + wc.base[j].astype(jnp.float32)).astype(dt)
        outs.append(jnp.broadcast_to(x, (wc.n, d)).reshape((wc.n,) + shape))
    return jax.tree.unflatten(wc.treedef, outs)


def wire_srcs(wc: WireCandidates):
    """Per-leaf ``quantize.WireSrc`` launch inputs for the kernels."""
    srcs = []
    for j, (payload, shape, dt) in enumerate(zip(wc.payloads, wc.shapes,
                                                 wc.dtypes)):
        d = _leaf_d(shape)
        arrays = tuple((nm, a.reshape(wc.n, -1)) for nm, a in payload.items())
        srcs.append(quantize.WireSrc(
            fmt=wc.fmt, n=wc.n, d=d, arrays=arrays,
            base=None if wc.base is None else wc.base[j], cand_dtype=dt))
    return srcs


# ---------------------------------------------------------------------------
# wire-size accounting
# ---------------------------------------------------------------------------

def _semantic_bits(fmt, d, *, k=None, vbits=32, nblocks=None) -> float:
    """Bits one worker's leaf payload carries: values at their packed
    precision + 32-bit indices/norms/scale. Signs are 1 bit each — the int8
    array is the TPU-side layout, not the wire entropy."""
    if fmt == "sparse":
        return k * (vbits + 32)
    if fmt == "int8":
        return 8 * d + 32 * nblocks
    if fmt == "sign":
        return d + 32
    if fmt == "bf16":
        return 16 * d
    raise ValueError(fmt)


def measured_bits(wc: WireCandidates) -> float:
    """Semantic wire bits per worker per round, read off the PACKED arrays
    (the k / block counts / value dtypes the kernels actually consumed)."""
    total = 0.0
    for payload, shape in zip(wc.payloads, wc.shapes):
        d = _leaf_d(shape)
        if wc.fmt == "sparse":
            total += _semantic_bits(
                "sparse", d, k=payload["vals"].shape[-1],
                vbits=payload["vals"].dtype.itemsize * 8)
        elif wc.fmt == "int8":
            total += _semantic_bits("int8", d,
                                    nblocks=payload["norms"].shape[-1])
        else:
            total += _semantic_bits(wc.fmt, d)
    return float(total)


def tree_wire_bits(compressor, stacked) -> float:
    """What ``measured_bits(pack_candidates(...))`` would return, from
    static shapes alone — the dense path's metric twin, so both backends
    report the identical per-round ``wire_bits``. Falls back to the theory
    accounting (``Compressor.tree_bits``) for compressors without a kernel
    wire format."""
    fmt = compressor.wire_format
    leaves = jax.tree.leaves(stacked)
    dims = [_leaf_d(l.shape[1:]) for l in leaves]
    if fmt in (None, "dense32") or compressor.fallback_only:
        return compressor.tree_bits(dims)
    total = 0.0
    for leaf, d in zip(leaves, dims):
        if fmt == "sparse":
            total += _semantic_bits(
                "sparse", d, k=max(int(compressor.ratio * d), 1),
                vbits=jnp.dtype(leaf.dtype).itemsize * 8)
        elif fmt == "int8":
            total += _semantic_bits("int8", d,
                                    nblocks=-(-d // quantize.INT8_BLOCK))
        else:
            total += _semantic_bits(fmt, d)
    return float(total)


# ---------------------------------------------------------------------------
# omniscient-attack stats from the wire
# ---------------------------------------------------------------------------

def wire_stats(wc: WireCandidates, good_mask, sanitize: bool = False):
    """Good-worker per-coordinate (mean, std) of the candidates, as per-leaf
    FLAT (d_j,) lists — ``tree_utils.masked_mean_std`` semantics, computed
    from the wire. Dense formats decode elementwise (no scatter); sparse
    payloads use a flat scatter-add for Σ w·q plus gathered cross-terms for
    Σ w·(x-m)², so no (n, d) gather/scatter ever appears. Sparse leaves
    with a non-f32 candidate dtype reconstruct densely instead (leaf-dtype
    rounding is not termwise-expressible) — the documented fallback.

    ``sanitize`` (fault guard): select-replace masked-out rows before the
    weighted sums — a zero weight does not neutralize a fault-poisoned
    payload (0·NaN = NaN, and garbled sparse indices would scatter out of
    range). Static, so the unguarded jaxpr is unchanged."""
    g = good_mask.astype(jnp.float32)
    cnt = jnp.maximum(jnp.sum(g), 1.0)
    w = g[:, None]
    means, stds = [], []
    for j, (payload, shape, dt) in enumerate(zip(wc.payloads, wc.shapes,
                                                 wc.dtypes)):
        d = _leaf_d(shape)
        base = None if wc.base is None else wc.base[j]
        if wc.fmt != "sparse" or jnp.dtype(dt) != jnp.float32:
            dec = jax.vmap(lambda p: quantize.decode(wc.fmt, p, d))(payload)
            x = dec.astype(dt).astype(jnp.float32)
            if base is not None:
                x = ((x + base.astype(jnp.float32))
                     .astype(dt).astype(jnp.float32))
            if sanitize:
                # select-zero, not multiply: masked rows are finite again,
                # so the weighted sums below cannot see 0·NaN
                x = jnp.where(w > 0.0, x, 0.0)
            m = jnp.sum(x * w, axis=0) / cnt
            var = jnp.sum(jnp.square(x - m[None]) * w, axis=0) / cnt
        else:
            vals = payload["vals"].astype(jnp.float32)        # (n, k)
            idx = payload["idx"]                              # (n, k) int32
            if sanitize:
                ok = good_mask[:, None]
                vals = jnp.where(ok, vals, 0.0)
                idx = jnp.where(ok, idx, 0)
            fi = idx.reshape(-1)
            qsum = jnp.zeros((d,), jnp.float32).at[fi].add(
                (w * vals).reshape(-1))
            if base is None:
                m = qsum / cnt
                s2 = jnp.zeros((d,), jnp.float32).at[fi].add(
                    (w * vals * vals).reshape(-1))
                var = s2 / cnt - jnp.square(m)
            else:
                bf = base.astype(jnp.float32)                 # (rows, d)
                rows = bf.shape[0]
                bmean = (jnp.sum(bf * w, axis=0) / cnt if rows == wc.n
                         else bf[0])
                m = bmean + qsum / cnt
                db = bf - m[None]
                t1 = (jnp.sum(jnp.square(db) * w, axis=0) if rows == wc.n
                      else cnt * jnp.square(db[0]))
                bg = (jnp.take_along_axis(bf, idx, axis=1) if rows == wc.n
                      else jnp.take(bf[0], idx))              # (n, k)
                mg = jnp.take(m, idx)                         # (n, k)
                cross = jnp.zeros((d,), jnp.float32).at[fi].add(
                    (w * vals * (2.0 * (bg - mg) + vals)).reshape(-1))
                var = (t1 + cross) / cnt
        means.append(m)
        stds.append(jnp.sqrt(jnp.maximum(var, 0.0)))
    return means, stds


# ---------------------------------------------------------------------------
# the wire message phase (engine lines 9-10 over a WireCandidates)
# ---------------------------------------------------------------------------

def wire_message_phase(cfg, attack_key, agg_key, wc: WireCandidates,
                       return_info=False, return_valid=False):
    """Omniscient attack + robust aggregation over a wire payload. The
    fused path (kernel-fusable attacks, pallas backend) never materializes
    the (n, d) candidates; RN-style attacks (exact jax.random stream on the
    materialized tensor) and non-pallas modes reconstruct densely, keeping
    the trajectory identical to the Compressor-oracle path.

    ``cfg.fault_guard`` (DESIGN.md §6) adds the fail-closed decode guard:
    rows whose payload does not decode safely (``faults.guard.payload_valid``
    — non-finite floats, sparse indices outside [0, d)) are *rejected*
    before they can touch the aggregate or the omniscient attack's
    statistics. Structurally valid garbage (garbled int8 levels under finite
    norms, a replayed zero payload) passes BY DESIGN — arbitrary finite
    deviation is the robust aggregator's job. The guard branch is static
    Python; guard-off traces the pre-faults jaxpr unchanged.

    ``return_info`` (repro.obs telemetry) returns ``(agg, info)`` with the
    rule drivers' scoring intermediates; ``return_valid`` appends the final
    (n,) validity mask (None when unguarded). The aggregate itself is
    produced by the identical calls either way."""
    from repro.core import engine

    def _ret(out, valid):
        return (out, valid) if return_valid else out

    guard = bool(getattr(cfg, "fault_guard", False))
    valid = None
    if guard:
        from repro.faults import guard as fguard
        valid = fguard.payload_valid(wc)
    if cfg.agg_mode != "pallas":   # defensive: estimators gate on pallas
        sent = engine.apply_attack(cfg, attack_key, reconstruct(wc),
                                   stats_valid=valid)
        if guard:
            from repro.faults import guard as fguard
            valid = valid & fguard.finite_row_mask(sent)
        if return_info:
            if guard:
                return _ret(cfg.aggregator.tree_masked(
                    agg_key, sent, valid, return_info=True), valid)
            return _ret(cfg.aggregator.tree_traced(agg_key, sent), valid)
        return _ret(engine.aggregate(cfg, agg_key, sent, valid=valid), valid)
    from repro.core.sharded_agg import (AttackCtx, tree_aggregate_pallas,
                                        tree_aggregate_pallas_wire)
    if cfg.n_byz == 0 or cfg.attack.name in ("NA", "LF"):
        return _ret(tree_aggregate_pallas_wire(cfg, agg_key, wc,
                                               return_info=return_info,
                                               valid=valid), valid)
    if cfg.attack.coord_apply is not None:
        mask = cfg.byz_mask()
        means = stds = None
        if cfg.attack.needs_mean or cfg.attack.needs_std:
            good = ~mask if valid is None else ~mask & valid
            means, stds = wire_stats(wc, good, sanitize=guard)
            if not cfg.attack.needs_std:
                stds = None
        ctx = AttackCtx(fn=cfg.attack.coord_apply, mask=mask,
                        means=means, stds=stds)
        return _ret(tree_aggregate_pallas_wire(cfg, agg_key, wc,
                                               attack_ctx=ctx,
                                               return_info=return_info,
                                               valid=valid), valid)
    sent = engine.apply_attack(cfg, attack_key, reconstruct(wc),
                               stats_valid=valid)
    if guard:
        from repro.faults import guard as fguard
        valid = valid & fguard.finite_row_mask(sent)
    return _ret(tree_aggregate_pallas(cfg, agg_key, sent,
                                      return_info=return_info, valid=valid),
                valid)
