"""Production mesh construction + sharding helpers.

``make_production_mesh`` is a FUNCTION (never touched at import time) so that
importing this module never initializes jax device state — only
launch/dryrun.py (which sets XLA_FLAGS first) builds the 256/512-way mesh.
"""
from __future__ import annotations

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def make_production_mesh(*, multi_pod: bool = False,
                         model_parallel: int = 16) -> Mesh:
    """v5e pod mesh: 16x16 = 256 chips single pod; 2x16x16 = 512 multi-pod.

    ``model_parallel`` reshapes the within-pod 256 chips between the data and
    model axes (a §Perf knob: llama3-405b wants model=64). Default 16x16.
    """
    per_pod = 256
    assert per_pod % model_parallel == 0
    data = per_pod // model_parallel
    if multi_pod:
        return jax.make_mesh((2, data, model_parallel),
                             ("pod", "data", "model"))
    return jax.make_mesh((data, model_parallel), ("data", "model"))


def worker_axes(mesh) -> tuple:
    """Mesh axes that carry the Byzantine worker dimension."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def n_workers(mesh) -> int:
    n = 1
    for a in worker_axes(mesh):
        n *= mesh.shape[a]
    return n


def shardings(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda s: isinstance(s, P))


def _axis_size(mesh, entry) -> int:
    if entry is None:
        return 1
    if isinstance(entry, (tuple, list)):
        n = 1
        for a in entry:
            n *= mesh.shape[a]
        return n
    return mesh.shape[entry]


def sanitize_specs(mesh, abs_tree, spec_tree):
    """Drop named axes from PartitionSpecs whose dimension size is not
    divisible by the axis size (e.g. vocab 50280 on a 16-way model axis).
    abs_tree: matching pytree of ShapeDtypeStructs / arrays."""

    def fix(aval, spec):
        if spec is None or not isinstance(spec, P):
            return spec
        dims = tuple(spec) + (None,) * (len(aval.shape) - len(tuple(spec)))
        out = []
        for size, entry in zip(aval.shape, dims):
            if entry is not None and size % _axis_size(mesh, entry) != 0:
                entry = None
            out.append(entry)
        return P(*out)

    return jax.tree.map(fix, abs_tree, spec_tree,
                        is_leaf=lambda s: s is None or isinstance(s, P))
