import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
# ^^ MUST be the first two lines: jax locks the device count on first init.
# Only the dry-run sees 512 placeholder devices; tests/benches see 1.

"""Multi-pod dry-run: prove every (arch x input-shape x mesh) combination
lowers, compiles, and report the roofline terms from the compiled artifact.

Per combination we lower the *real* step the framework runs in production:

  train_4k     -> Byz-VR-MARINA train_step (Alg. 1: per-worker grads, attack,
                  compression, bucketing+CM robust aggregation, update)
  prefill_32k  -> prefill_step (forward to last-token logits)
  decode_32k   -> serve_step (single token, KV/recurrent cache)
  long_500k    -> serve_step with the sub-quadratic variant (SWA window 8192 /
                  recurrent state); see DESIGN.md §4 for the carve-out.

Usage:
  python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both --out experiments/dryrun
"""
import argparse
import dataclasses
import json
import time
import traceback

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.api import RunSpec, components
from repro.configs import (ATTN, SWA, INPUT_SHAPES, ASSIGNED_ARCHS,
                           get_config)
from repro.configs.base import ArchConfig, InputShape
from repro.core import ByzVRMarinaConfig, list_methods, make_method
from repro.launch import hlo_analysis
from repro.launch.mesh import (make_production_mesh, n_workers,
                               sanitize_specs, worker_axes)
from repro.models import layers as Lyr
from repro.models import model as M

# ---------------------------------------------------------------------------
# TPU v5e hardware constants (roofline denominators)
# ---------------------------------------------------------------------------
HW = {
    "peak_flops_bf16": 197e12,   # per chip
    "hbm_bw": 819e9,             # B/s per chip
    "ici_bw": 50e9,              # B/s per link
}

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")


# ---------------------------------------------------------------------------
# input specs — ShapeDtypeStruct stand-ins for every model input
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _token_shape(cfg: ArchConfig, lead, s_text):
    if cfg.num_codebooks == 1:
        return lead + (s_text,)
    return lead + (s_text, cfg.num_codebooks)


def input_specs(cfg: ArchConfig, shape: InputShape, n_work: int,
                anchor_mult: int = 1):
    """Abstract (ShapeDtypeStruct) inputs for the given arch x shape.

    train: stacked worker batches {tokens, labels[, frontend]} of
           (n, per_worker_batch, ...); anchor is ``anchor_mult`` x larger.
    prefill: {tokens[, frontend]} of (global_batch, ...).
    decode: token ids (global_batch,[K]) — cache comes from cache_specs.
    """
    s_text = shape.seq_len - (cfg.frontend_tokens or 0)
    if shape.kind == "train":
        bw = shape.global_batch // n_work
        assert bw >= 1, (shape.global_batch, n_work)

        def batch_of(mult):
            lead = (n_work, bw * mult)
            b = {"tokens": _sds(_token_shape(cfg, lead, s_text), jnp.int32),
                 "labels": _sds(_token_shape(cfg, lead, s_text), jnp.int32)}
            if cfg.frontend_tokens:
                b["frontend"] = _sds(lead + (cfg.frontend_tokens, cfg.d_model),
                                     jnp.bfloat16)
            return b

        return {"batch": batch_of(1), "anchor": batch_of(anchor_mult)}
    if shape.kind == "prefill":
        lead = (shape.global_batch,)
        b = {"tokens": _sds(_token_shape(cfg, lead, s_text), jnp.int32)}
        if cfg.frontend_tokens:
            b["frontend"] = _sds(lead + (cfg.frontend_tokens, cfg.d_model),
                                 jnp.bfloat16)
        return {"batch": b}
    if shape.kind == "decode":
        tok = ((shape.global_batch,) if cfg.num_codebooks == 1
               else (shape.global_batch, cfg.num_codebooks))
        return {"tokens": _sds(tok, jnp.int32)}
    raise ValueError(shape.kind)


def _long_context_cfg(cfg: ArchConfig, window: int = 8192) -> ArchConfig:
    """Sub-quadratic variant for long_500k: full-attention blocks become
    sliding-window (block-sparse carve-out); recurrent blocks unchanged."""
    pat = tuple(SWA if k == ATTN else k for k in cfg.block_pattern)
    return dataclasses.replace(cfg, block_pattern=pat, sliding_window=window)


def decode_cache_capacity(cfg: ArchConfig, shape: InputShape) -> int:
    if shape.name == "long_500k":
        return min(shape.seq_len, max(cfg.sliding_window, 1))
    return shape.seq_len


# ---------------------------------------------------------------------------
# step builders
# ---------------------------------------------------------------------------

def make_byz_config(n_work: int, mesh, *, agg="cm", bucket=2,
                    compressor="randk", compressor_kwargs=None,
                    agg_mode="gspmd") -> ByzVRMarinaConfig:
    """Declarative spec -> engine config; the mesh extras (worker axes /
    grad specs) are attached afterwards because they are not serializable."""
    ckw = dict(compressor_kwargs if compressor_kwargs is not None
               else {"ratio": 0.1})
    if agg_mode == "sparse_support":
        compressor, ckw = "randk", {"ratio": ckw.get("ratio", 0.1),
                                    "common_randomness": True}
    # the spec's task/arch fields don't reach build_config (the dry-run owns
    # model construction); validation of the byzantine geometry still applies,
    # so clamp n_byz under the delta < 1/2 bound for tiny worker meshes
    n_byz = min(max(n_work // 8, 1), max((n_work - 1) // 2, 0))
    spec = RunSpec(
        n_workers=n_work, n_byz=n_byz, p=0.1, lr=3e-3,
        attack="ALIE", aggregator=agg, bucket_size=bucket,
        compressor=compressor, compressor_kwargs=ckw, agg_mode=agg_mode)
    return dataclasses.replace(
        spec.build_config(),
        worker_axes=worker_axes(mesh), model_axis="model",
        mesh=mesh if agg_mode == "all_to_all" else None)


def _train_state_specs(state_abs, pspecs, w_spec):
    """PartitionSpecs for an engine train state: params-shaped entries get
    the model sharding, ``worker_*`` stacked entries get the worker axis
    prepended, scalars replicate."""
    def worker_specs(ps):
        return jax.tree.map(
            lambda s: P(w_spec, *(tuple(s) if s is not None else ())), ps,
            is_leaf=lambda s: isinstance(s, P) or s is None)

    out = {}
    for k, sub in state_abs.items():
        if k in ("params", "g", "prev_params", "snapshot"):
            out[k] = pspecs
        elif k.startswith("worker_"):
            out[k] = worker_specs(pspecs)
        elif k == "opt_state":
            out[k] = None
        else:                                   # step / alpha / scalars
            out[k] = P()
    return out


def build_train(cfg: ArchConfig, mesh, shape: InputShape, *,
                byz_overrides=None, xent_chunk=1024):
    overrides = dict(byz_overrides or {})
    method_name = overrides.pop("method", "marina")
    n_work = n_workers(mesh)
    w_axes = worker_axes(mesh)
    bcfg = make_byz_config(n_work, mesh, **overrides)

    def loss(params, batch, key):
        return M.loss_fn(params, cfg, batch, remat=True,
                         xent_chunk=xent_chunk)

    params_abs = jax.eval_shape(lambda: M.init_params(jax.random.PRNGKey(0),
                                                      cfg))
    pspecs = M.param_specs(cfg)
    if bcfg.agg_mode == "all_to_all":
        bcfg = dataclasses.replace(
            bcfg, grad_specs=sanitize_specs(mesh, params_abs, pspecs))
    method = make_method(method_name, bcfg, loss)
    step = method.step
    specs_in = input_specs(cfg, shape, n_work)

    if method_name == "marina":
        # no extra estimator state; skip tracing the init
        state_abs = {"params": params_abs, "g": params_abs,
                     "opt_state": None, "step": _sds((), jnp.int32)}
    else:
        state_abs = dict(jax.eval_shape(
            method.init, params_abs, specs_in["anchor"],
            _sds((2,), jnp.uint32)))
    w_spec = tuple(w_axes) if len(w_axes) > 1 else w_axes[0]
    state_specs = _train_state_specs(state_abs, pspecs, w_spec)

    def batch_spec(b):
        return jax.tree.map(
            lambda s: P(w_spec, *([None] * (len(s.shape) - 1))), b)

    batch_specs = batch_spec(specs_in["batch"])
    anchor_specs = batch_spec(specs_in["anchor"])
    key_abs = _sds((2,), jnp.uint32)

    state_specs = sanitize_specs(mesh, state_abs, state_specs)
    batch_specs = sanitize_specs(mesh, specs_in["batch"], batch_specs)
    anchor_specs = sanitize_specs(mesh, specs_in["anchor"], anchor_specs)
    jitted = jax.jit(
        step,
        in_shardings=(_ns(mesh, state_specs), _ns(mesh, batch_specs),
                      _ns(mesh, anchor_specs), NamedSharding(mesh, P())),
        out_shardings=(_ns(mesh, state_specs), NamedSharding(mesh, P())),
    )
    args = (state_abs, specs_in["batch"], specs_in["anchor"], key_abs)
    return jitted, args


def build_prefill(cfg: ArchConfig, mesh, shape: InputShape):
    w_axes = worker_axes(mesh)
    batch_axis = tuple(w_axes) if len(w_axes) > 1 else w_axes[0]

    def prefill_step(params, batch):
        x, _ = M.hidden(params, cfg, batch, remat=False)
        return M.model_logits_last(params, cfg, x)

    params_abs = jax.eval_shape(lambda: M.init_params(jax.random.PRNGKey(0),
                                                      cfg))
    pspecs = M.param_specs(cfg)
    specs_in = input_specs(cfg, shape, 1)
    bspecs = jax.tree.map(
        lambda s: P(batch_axis, *([None] * (len(s.shape) - 1))),
        specs_in["batch"])
    pspecs = sanitize_specs(mesh, params_abs, pspecs)
    bspecs = sanitize_specs(mesh, specs_in["batch"], bspecs)
    jitted = jax.jit(prefill_step,
                     in_shardings=(_ns(mesh, pspecs), _ns(mesh, bspecs)))
    return jitted, (params_abs, specs_in["batch"])


def build_decode(cfg: ArchConfig, mesh, shape: InputShape):
    w_axes = worker_axes(mesh)
    batch_axis = tuple(w_axes) if len(w_axes) > 1 else w_axes[0]
    total_workers = n_workers(mesh)
    shard_batch = shape.global_batch % total_workers == 0 and \
        shape.global_batch >= total_workers
    b_ax = batch_axis if shard_batch else None

    run_cfg = _long_context_cfg(cfg) if shape.name == "long_500k" else cfg
    cap = decode_cache_capacity(run_cfg, shape)

    def serve_step(params, cache, tokens):
        return M.decode_step(params, run_cfg, cache, tokens)

    params_abs = jax.eval_shape(
        lambda: M.init_params(jax.random.PRNGKey(0), run_cfg))
    pspecs = M.param_specs(run_cfg)
    cache_abs = jax.eval_shape(
        lambda: M.init_cache(run_cfg, shape.global_batch, cap))
    cspecs = M.cache_specs(run_cfg, b_ax)
    tok = input_specs(cfg, shape, 1)["tokens"]
    tok_spec = P(b_ax) if cfg.num_codebooks == 1 else P(b_ax, None)
    pspecs = sanitize_specs(mesh, params_abs, pspecs)
    cspecs = sanitize_specs(mesh, cache_abs, cspecs)
    tok_spec = sanitize_specs(mesh, tok, tok_spec)
    jitted = jax.jit(
        serve_step,
        in_shardings=(_ns(mesh, pspecs), _ns(mesh, cspecs),
                      NamedSharding(mesh, tok_spec)))
    return jitted, (params_abs, cache_abs, tok)


def _ns(mesh, spec_tree):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s) if isinstance(s, P) else s,
        spec_tree, is_leaf=lambda s: isinstance(s, P) or s is None)


# ---------------------------------------------------------------------------
# roofline
# ---------------------------------------------------------------------------

def roofline(flops: float, bytes_: float, coll: dict, chips: int,
             cfg: ArchConfig, shape: InputShape) -> dict:
    coll_bytes = float(coll.get("total_bytes", 0))
    # cost_analysis is per-device on SPMD modules; scale to global.
    compute_t = flops / HW["peak_flops_bf16"]
    memory_t = bytes_ / HW["hbm_bw"]
    collective_t = coll_bytes / HW["ici_bw"]
    # model flops: 6 N D (causal attention term excluded; reported separately)
    n_params = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.seq_len * shape.global_batch
        model_flops = 6 * n_params * tokens
    elif shape.kind == "prefill":
        tokens = shape.seq_len * shape.global_batch
        model_flops = 2 * n_params * tokens
    else:
        tokens = shape.global_batch
        model_flops = 2 * n_params * tokens
    model_flops_per_chip = model_flops / chips
    terms = {"compute_s": compute_t, "memory_s": memory_t,
             "collective_s": collective_t}
    dominant = max(terms, key=terms.get)
    return {
        **terms,
        "dominant": dominant,
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": bytes_,
        "collective_bytes_per_device": coll_bytes,
        "model_flops_per_chip": model_flops_per_chip,
        "useful_flop_ratio": (model_flops_per_chip / flops) if flops else None,
    }


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------

def _probe_cfg(cfg: ArchConfig, k: int) -> ArchConfig:
    """k pattern-groups, fully unrolled (n_groups=1 => trip count 1, so
    cost_analysis counts every layer exactly once)."""
    pat = tuple(cfg.block_pattern) * k
    return dataclasses.replace(cfg, block_pattern=pat, num_layers=len(pat))


def _build(kind, cfg, mesh, shape, byz_overrides, xent_chunk=1024):
    if kind == "train":
        return build_train(cfg, mesh, shape, byz_overrides=byz_overrides,
                           xent_chunk=xent_chunk)
    if kind == "prefill":
        return build_prefill(cfg, mesh, shape)
    return build_decode(cfg, mesh, shape)


def _cost_dict(compiled) -> dict:
    """compiled.cost_analysis() returns a dict on older jax and a
    one-element list of dicts on newer releases; normalize to a dict."""
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):
        cost = cost[0] if cost else {}
    return cost


def _compile_costs(kind, cfg, mesh, shape, byz_overrides):
    """flops/bytes of a probe config with every inner scan fully unrolled
    (so cost_analysis counts each trip; memory behaviour matches the real
    chunked artifact)."""
    Lyr.PROBE_UNROLL[0] = True
    try:
        jitted, args = _build(kind, cfg, mesh, shape, byz_overrides)
        with mesh:
            compiled = jitted.lower(*args).compile()
        cost = _cost_dict(compiled)
        return (float(cost.get("flops", 0.0) or 0.0),
                float(cost.get("bytes accessed", 0.0) or 0.0))
    finally:
        Lyr.PROBE_UNROLL[0] = False


def corrected_costs(kind, cfg, mesh, shape, byz_overrides):
    """Extrapolate full-depth flops/bytes from 1-group and 2-group probes:
    total ~= probe1 + (G-1) * (probe2 - probe1), G = num_layers/len(pattern).
    Exact for depth-linear cost (true here: groups are identical)."""
    f1, b1 = _compile_costs(kind, _probe_cfg(cfg, 1), mesh, shape,
                            byz_overrides)
    f2, b2 = _compile_costs(kind, _probe_cfg(cfg, 2), mesh, shape,
                            byz_overrides)
    g = cfg.num_layers / len(cfg.block_pattern)
    fl = f1 + max(f2 - f1, 0.0) * (g - 1)
    by = b1 + max(b2 - b1, 0.0) * (g - 1)
    return fl, by, {"probe1": [f1, b1], "probe2": [f2, b2], "groups": g}


def run_one(arch: str, shape_name: str, mesh_kind: str, *,
            byz_overrides=None, model_parallel: int = 16,
            probes: bool = True, verbose: bool = True) -> dict:
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"),
                                model_parallel=model_parallel)
    chips = 1
    for a in mesh.axis_names:
        chips *= mesh.shape[a]
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "mesh_shape": dict(mesh.shape), "chips": chips,
           "model_parallel": model_parallel,
           "byz_overrides": {k: str(v) for k, v in
                             (byz_overrides or {}).items()},
           "ok": False}
    t0 = time.time()
    try:
        jitted, args = _build(shape.kind, cfg, mesh, shape, byz_overrides)
        with mesh:
            lowered = jitted.lower(*args)
            t1 = time.time()
            compiled = lowered.compile()
            t2 = time.time()
        mem = compiled.memory_analysis()
        cost = _cost_dict(compiled)
        hlo = compiled.as_text()
        coll = hlo_analysis.collective_bytes(hlo)   # trip-count aware
        raw_flops = float(cost.get("flops", 0.0) or 0.0)
        raw_bytes = float(cost.get("bytes accessed", 0.0) or 0.0)
        if probes:
            flops, bytes_, probe_info = corrected_costs(
                shape.kind, cfg, mesh, shape, byz_overrides)
        else:
            flops, bytes_, probe_info = raw_flops, raw_bytes, None
        rec.update({
            "ok": True,
            "lower_s": round(t1 - t0, 2),
            "compile_s": round(t2 - t1, 2),
            "memory_analysis": _mem_dict(mem),
            "flops_per_device_raw": raw_flops,
            "bytes_per_device_raw": raw_bytes,
            "flops_per_device": flops,
            "bytes_per_device": bytes_,
            "probe_info": probe_info,
            "collectives": {k: v for k, v in coll.items()},
            "roofline": roofline(flops, bytes_, coll, chips, cfg, shape),
            "hlo_lines": hlo.count("\n"),
        })
        if verbose:
            print(f"[dryrun] {arch} x {shape_name} x {mesh_kind}: OK "
                  f"(lower {rec['lower_s']}s, compile {rec['compile_s']}s)")
            print("  memory:", rec["memory_analysis"])
            print("  cost(corrected): flops/dev=%.3e bytes/dev=%.3e" %
                  (flops, bytes_))
            print("  collectives:", {k: v for k, v in coll.items()
                                     if isinstance(v, dict) and v["count"]})
            print("  roofline:", {k: (f"{v:.3e}" if isinstance(v, float)
                                      else v)
                                  for k, v in rec["roofline"].items()})
    except Exception as e:  # noqa: BLE001 — report, don't crash the sweep
        rec["error"] = f"{type(e).__name__}: {e}"
        rec["traceback"] = traceback.format_exc()[-2000:]
        if verbose:
            print(f"[dryrun] {arch} x {shape_name} x {mesh_kind}: FAIL {e}")
    return rec


def _mem_dict(mem) -> dict:
    out = {}
    for attr in ("temp_size_in_bytes", "argument_size_in_bytes",
                 "output_size_in_bytes", "alias_size_in_bytes",
                 "generated_code_size_in_bytes"):
        if hasattr(mem, attr):
            out[attr] = int(getattr(mem, attr))
    if not out:
        out["repr"] = str(mem)
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None, choices=list(INPUT_SHAPES))
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--model-parallel", type=int, default=16)
    ap.add_argument("--agg", default="cm", choices=components("aggregator"))
    ap.add_argument("--method", default="marina", choices=list_methods(),
                    help="gradient estimator plugged into the round engine")
    ap.add_argument("--agg-mode", default="gspmd",
                    choices=components("agg_mode"))
    ap.add_argument("--attn-impl", default="chunked",
                    choices=["chunked", "online"])
    ap.add_argument("--moe-ep-constraint", action="store_true")
    ap.add_argument("--capacity-factor", type=float, default=None)
    ap.add_argument("--compressor", default="randk",
                    choices=components("compressor"))
    ap.add_argument("--compress-ratio", type=float, default=0.1)
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args()

    Lyr.ATTN_IMPL[0] = args.attn_impl
    if args.moe_ep_constraint:
        Lyr.MOE_EP_CONSTRAINT[0] = "model"
    comp_kw = ({"ratio": args.compress_ratio}
               if args.compressor == "randk" else {})
    overrides = {"agg": args.agg, "compressor": args.compressor,
                 "compressor_kwargs": comp_kw,
                 "agg_mode": args.agg_mode, "method": args.method}

    if args.capacity_factor is not None:
        import repro.configs.base as _cb
        _orig_get = _cb.get_config

        def _patched(name):
            c = _orig_get(name)
            if c.moe is not None:
                c = dataclasses.replace(c, moe=dataclasses.replace(
                    c.moe, capacity_factor=args.capacity_factor))
            return c
        # NB: running under `python -m`, this module is __main__; patch OUR
        # globals (run_one resolves get_config from here).
        globals()["get_config"] = _patched
    archs = ASSIGNED_ARCHS if (args.all or args.arch is None) else [args.arch]
    shapes = list(INPUT_SHAPES) if (args.all or args.shape is None) \
        else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    os.makedirs(args.out, exist_ok=True)
    for arch in archs:
        for shape in shapes:
            for mesh_kind in meshes:
                rec = run_one(arch, shape, mesh_kind,
                              byz_overrides=overrides,
                              model_parallel=args.model_parallel)
                tag = f"{arch}__{shape}__{mesh_kind}"
                if args.model_parallel != 16:
                    tag += f"__mp{args.model_parallel}"
                if args.method != "marina":
                    tag += f"__{args.method}"
                if args.agg_mode != "gspmd":
                    tag += f"__{args.agg_mode}"
                if args.attn_impl != "chunked":
                    tag += f"__{args.attn_impl}"
                if args.moe_ep_constraint:
                    tag += "__epc"
                if args.capacity_factor is not None:
                    tag += f"__cf{args.capacity_factor}"
                with open(os.path.join(args.out, tag + ".json"), "w") as f:
                    json.dump(rec, f, indent=1)


if __name__ == "__main__":
    main()
