"""core/wire + the fused compressed-wire message phase vs the jnp oracle.

Coverage pinned by ISSUE 6:
  * ``decoded_payload`` ≡ ``vmap(compress_tree)`` bit-for-bit (the RNG /
    support contract every wire estimator leans on)
  * fused wire phase ≡ Compressor-oracle dense path, across rules ×
    {randk, topk, sign, int8} × bf16 leaves × non-tile-multiple d, with
    and without EF-style reconstruction bases
  * the fused phase emits NO (n, d)-sized gather / scatter / concatenate /
    select_n / dynamic_update_slice between compress and aggregate (jaxpr
    scan, tests/_jaxpr_scan.py) — the one-sweep roofline contract
  * ``wire_supported`` routing (fallback-only / dense32 / huge-sparse
    leaves bail to the jnp path) and the measured-bits static twin
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _jaxpr_scan import iter_eqns
from repro.core import ByzVRMarinaConfig, get_aggregator, get_attack, wire
from repro.core import tree_utils as tu
from repro.core.compressors import (_MAX_UNITS, get_compressor,
                                    l2_dithering)
from repro.core.engine import apply_attack

KEY = jax.random.PRNGKey(42)

WIRE_COMPS = {
    "randk": lambda: get_compressor("randk", ratio=0.25),
    "topk": lambda: get_compressor("topk", ratio=0.25),
    "sign": lambda: get_compressor("sign"),
    "int8": lambda: get_compressor("int8"),
    "bf16": lambda: get_compressor("bf16"),
}


def _tree(key, n, dims, dtype=jnp.float32):
    ks = jax.random.split(key, len(dims))
    return {f"p{i}": jax.random.normal(k, (n,) + d).astype(dtype)
            for i, (k, d) in enumerate(zip(ks, dims))}


def _cfg(rule, comp, *, bucket=2, attack="ALIE", n=8, n_byz=2,
         mode="pallas"):
    return ByzVRMarinaConfig(
        n_workers=n, n_byz=n_byz,
        aggregator=get_aggregator(rule, bucket_size=bucket, n_byz=n_byz),
        attack=get_attack(attack), compressor=comp, agg_mode=mode)


def _qkeys(n):
    return jax.vmap(lambda i: jax.random.fold_in(KEY, 1000 + i))(
        jnp.arange(n))


def _oracle_cand(comp, qkeys, stacked, base=None):
    """The dense candidates the jnp Compressor path would hand the engine:
    per-worker compress_tree, plus the estimator's leaf-dtype base add."""
    qs = jax.vmap(lambda kq, g: tu.compress_tree(comp, kq, g))(qkeys, stacked)
    if base is None:
        return qs
    return jax.tree.map(lambda b, q: b + q, base, qs)


# ---------------------------------------------------------------------------
# decoded_payload: the RNG / support contract
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", sorted(WIRE_COMPS))
def test_decoded_payload_matches_compress_tree(name):
    """pack → decode reproduces vmap(compress_tree) EXACTLY: same fold_in
    key schedule, same supports / dither draws / scales, same dtypes."""
    comp = WIRE_COMPS[name]()
    n = 6
    stacked = _tree(KEY, n, [(300,), (7, 11)])
    qkeys = _qkeys(n)
    wc = wire.pack_candidates(comp, qkeys, stacked)
    got = wire.decoded_payload(wc)
    want = jax.vmap(lambda kq, g: tu.compress_tree(comp, kq, g))(
        qkeys, stacked)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        assert a.dtype == b.dtype and a.shape == b.shape
        np.testing.assert_array_equal(np.asarray(a, np.float32),
                                      np.asarray(b, np.float32))


# ---------------------------------------------------------------------------
# fused wire phase ≡ the dense Compressor-oracle path
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("name", ["randk", "topk", "sign", "int8"])
@pytest.mark.parametrize("rule", ["mean", "cm", "tm", "rfa", "krum"])
def test_wire_phase_matches_oracle(rule, name):
    """wire_message_phase under pallas ≡ apply_attack + Aggregator.tree on
    the materialized compress_tree candidates — non-tile-multiple d
    (3000 > TILE, 300 < TILE), omniscient ALIE, bucketing."""
    comp = WIRE_COMPS[name]()
    cfg = _cfg(rule, comp)
    n = cfg.n_workers
    stacked = _tree(KEY, n, [(3000,), (300,)])
    qkeys = _qkeys(n)
    k_attack, k_agg = jax.random.split(KEY)
    wc = wire.pack_candidates(comp, qkeys, stacked)
    got = wire.wire_message_phase(cfg, k_attack, k_agg, wc)
    sent = apply_attack(cfg, k_attack, _oracle_cand(comp, qkeys, stacked))
    want = cfg.aggregator.tree(k_agg, sent)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), atol=1e-5, rtol=1e-5), got, want)


@pytest.mark.parametrize("shared", [False, True], ids=["base_n", "base_1"])
@pytest.mark.parametrize("name", ["randk", "topk"])
def test_wire_phase_with_base_matches_oracle(name, shared):
    """EF/VR-style payloads: candidate = base + decode(payload). base_n is
    the per-worker EF21/cmfilter state, base_1 the MARINA server-shared
    g^{k} broadcast."""
    comp = WIRE_COMPS[name]()
    cfg = _cfg("cm", comp)
    n = cfg.n_workers
    stacked = _tree(KEY, n, [(1500,), (300,)])
    rows = 1 if shared else n
    base = _tree(jax.random.fold_in(KEY, 9), rows, [(1500,), (300,)])
    base_arg = (jax.tree.map(lambda b: b[0], base) if shared else base)
    qkeys = _qkeys(n)
    k_attack, k_agg = jax.random.split(KEY)
    wc = wire.pack_candidates(comp, qkeys, stacked, base=base_arg,
                              base_shared=shared)
    got = wire.wire_message_phase(cfg, k_attack, k_agg, wc)
    dense_base = jax.tree.map(lambda b: jnp.broadcast_to(b, (n,) + b.shape[1:]),
                              base)
    sent = apply_attack(cfg, k_attack,
                        _oracle_cand(comp, qkeys, stacked, base=dense_base))
    want = cfg.aggregator.tree(k_agg, sent)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), atol=1e-5, rtol=1e-5), got, want)


@pytest.mark.parametrize("name", ["randk", "topk", "sign", "int8"])
def test_wire_phase_bf16_leaves(name):
    """bf16 candidate leaves: the kernel reconstruction round-trips through
    the candidate dtype exactly like the jnp path's leaf arithmetic (bf16
    attack rounding bounded by bf16 eps — same tolerance as the dense
    bf16 parity test)."""
    comp = WIRE_COMPS[name]()
    cfg = _cfg("cm", comp)
    n = cfg.n_workers
    stacked = _tree(KEY, n, [(1500,), (300,)], dtype=jnp.bfloat16)
    qkeys = _qkeys(n)
    k_attack, k_agg = jax.random.split(KEY)
    wc = wire.pack_candidates(comp, qkeys, stacked)
    got = wire.wire_message_phase(cfg, k_attack, k_agg, wc)
    sent = apply_attack(cfg, k_attack, _oracle_cand(comp, qkeys, stacked))
    want = cfg.aggregator.tree(k_agg, sent)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        assert a.dtype == jnp.bfloat16
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=4e-2)


@pytest.mark.parametrize("attack", ["NA", "BF", "IPM", "RN"])
def test_wire_phase_attack_routing(attack):
    """Every attack family routes correctly: clean/LF skip stats, coord
    attacks fuse, RN reconstructs densely — all ≡ the oracle."""
    comp = WIRE_COMPS["randk"]()
    cfg = _cfg("rfa", comp, attack=attack)
    n = cfg.n_workers
    stacked = _tree(KEY, n, [(1500,)])
    qkeys = _qkeys(n)
    k_attack, k_agg = jax.random.split(KEY)
    wc = wire.pack_candidates(comp, qkeys, stacked)
    got = wire.wire_message_phase(cfg, k_attack, k_agg, wc)
    sent = apply_attack(cfg, k_attack, _oracle_cand(comp, qkeys, stacked))
    want = cfg.aggregator.tree(k_agg, sent)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), atol=1e-5, rtol=1e-5), got, want)


# ---------------------------------------------------------------------------
# one-sweep guarantee: jaxpr scan of the fused wire phase
# ---------------------------------------------------------------------------

_BANNED = ("concatenate", "select_n", "gather", "scatter", "scatter-add",
           "scatter_add", "dynamic_update_slice")


@pytest.mark.parametrize("name", ["randk", "topk", "sign", "int8"])
def test_wire_phase_is_one_sweep(name):
    """Between compress and aggregate the pallas wire phase must never
    materialize the (n, d) candidates: no gather/scatter/concatenate/
    select_n/dynamic_update_slice with an (n, d)-sized output appears in
    the host-side jaxpr (kernel-internal VMEM ops excluded). (n, k)
    gathers and flat (d,) scatter-adds — the sparse attack-stats path —
    stay legal."""
    comp = WIRE_COMPS[name]()
    n, d_large = 8, 4096
    cfg = _cfg("cm", comp, n=n)
    stacked = _tree(KEY, n, [(d_large,), (64, 48)])
    qkeys = _qkeys(n)
    k1, k2 = jax.random.split(KEY)
    wc = wire.pack_candidates(comp, qkeys, stacked)
    jaxpr = jax.make_jaxpr(
        lambda c: wire.wire_message_phase(cfg, k1, k2, c))(wc).jaxpr
    for eqn in iter_eqns(jaxpr):
        if eqn.primitive.name not in _BANNED:
            continue
        for out in eqn.outvars:
            shape = getattr(out.aval, "shape", ())
            assert int(np.prod(shape)) < n * d_large, (
                f"{eqn.primitive.name} materializes {shape} on the host")


def test_wire_phase_rn_fallback_does_materialize():
    """Scanner sanity: the RN fallback (exact jax.random stream on the
    materialized tensor) DOES scatter the (n, d) reconstruction."""
    comp = WIRE_COMPS["randk"]()
    n, d = 8, 4096
    cfg = _cfg("cm", comp, attack="RN", n=n)
    stacked = _tree(KEY, n, [(d,)])
    wc = wire.pack_candidates(comp, _qkeys(n), stacked)
    k1, k2 = jax.random.split(KEY)
    jaxpr = jax.make_jaxpr(
        lambda c: wire.wire_message_phase(cfg, k1, k2, c))(wc).jaxpr
    assert any(
        eqn.primitive.name in _BANNED
        and any(int(np.prod(getattr(o.aval, "shape", ()))) >= n * d
                for o in eqn.outvars)
        for eqn in iter_eqns(jaxpr))


# ---------------------------------------------------------------------------
# routing + accounting
# ---------------------------------------------------------------------------

def test_wire_supported_routing():
    small = jax.ShapeDtypeStruct((4, 1000), jnp.float32)
    huge = jax.ShapeDtypeStruct((4, _MAX_UNITS + 1), jnp.float32)
    randk = WIRE_COMPS["randk"]()
    assert wire.wire_supported(_cfg("cm", randk), [small])
    # sparse formats bail out of the kernel wire on block-selected leaves
    assert not wire.wire_supported(_cfg("cm", randk), [small, huge])
    # ...but dense wire formats don't care about leaf size
    assert wire.wire_supported(_cfg("cm", WIRE_COMPS["int8"]()),
                               [small, huge])
    # fallback-only / dense32 / non-pallas all take the jnp path
    assert not wire.wire_supported(_cfg("cm", l2_dithering(4)))
    assert not wire.wire_supported(_cfg("cm", get_compressor("identity")))
    assert not wire.wire_supported(_cfg("cm", randk, mode="gspmd"))


@pytest.mark.parametrize("name", sorted(WIRE_COMPS))
def test_measured_bits_matches_static_twin(name):
    """measured_bits (off the packed arrays) == tree_wire_bits (off static
    shapes): the dense path's wire_bits metric equals what the pallas path
    actually ships."""
    comp = WIRE_COMPS[name]()
    stacked = _tree(KEY, 4, [(300,), (7, 11)])
    wc = wire.pack_candidates(comp, _qkeys(4), stacked)
    assert wire.measured_bits(wc) == wire.tree_wire_bits(comp, stacked)


@pytest.mark.parametrize("base_mode", ["none", "base_n", "base_1"])
@pytest.mark.parametrize("name", ["randk", "sign", "int8"])
def test_wire_stats_matches_masked_mean_std(name, base_mode):
    """Attack stats computed FROM the wire ≡ tree_utils.masked_mean_std on
    the reconstructed dense candidates (incl. the sparse cross-term
    expansion with per-worker and shared bases)."""
    comp = WIRE_COMPS[name]()
    n = 6
    stacked = _tree(KEY, n, [(500,)])
    base = None
    if base_mode != "none":
        rows = n if base_mode == "base_n" else 1
        b = _tree(jax.random.fold_in(KEY, 5), rows, [(500,)])
        base = jax.tree.map(lambda x: x[0], b) if rows == 1 else b
    wc = wire.pack_candidates(comp, _qkeys(n), stacked, base=base,
                              base_shared=base_mode == "base_1")
    mask = jnp.arange(n) < 2            # 2 byzantine, stats over the rest
    means, stds = wire.wire_stats(wc, ~mask)
    m_tree, s_tree = tu.masked_mean_std(wire.reconstruct(wc), ~mask)
    np.testing.assert_allclose(np.asarray(means[0]),
                               np.asarray(jax.tree.leaves(m_tree)[0]),
                               atol=1e-5, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(stds[0]),
                               np.asarray(jax.tree.leaves(s_tree)[0]),
                               atol=1e-4, rtol=1e-4)
