"""Composable decoder stack.

The per-layer block kind comes from ``cfg.block_pattern`` tiled over depth
(e.g. recurrentgemma's (RGLRU, RGLRU, SWA)). Layers are *stacked per pattern
position* and iterated with ``lax.scan`` so the HLO contains one copy of each
distinct block kind regardless of depth — essential for compiling 126-layer
configs in the dry-run.

Params layout::

    {"embed": ..., "unembed": ..., "final_norm": ...,
     "groups": (per-pattern-position dict with leading repeat axis R, ...),
     "tail":   (per-leftover-layer dict, ...)}          # num_layers % len(pattern)
"""
from __future__ import annotations


import jax
import jax.numpy as jnp
from jax import lax

from repro.configs.base import ATTN, SWA, MLA, RGLRU, MAMBA2, ArchConfig
from repro.models import layers as L


# ---------------------------------------------------------------------------
# single-block init / apply / decode dispatch
# ---------------------------------------------------------------------------

def _init_block(key, cfg: ArchConfig, kind: str):
    k1, k2, k3 = jax.random.split(key, 3)
    d = cfg.d_model
    p = {"norm1": jnp.zeros((d,), cfg.jnp_dtype)}
    if kind in (ATTN, SWA):
        p["mixer"] = L.init_attention(k1, cfg)
    elif kind == MLA:
        p["mixer"] = L.init_mla(k1, cfg)
    elif kind == RGLRU:
        p["mixer"] = L.init_rglru(k1, cfg)
    elif kind == MAMBA2:
        p["mixer"] = L.init_mamba2(k1, cfg)
    else:
        raise ValueError(kind)
    if kind != MAMBA2:
        p["norm2"] = jnp.zeros((d,), cfg.jnp_dtype)
        if cfg.moe is not None:
            p["ffn"] = L.init_moe(k2, cfg)
        else:
            p["ffn"] = L.init_mlp(k2, cfg)
    return p


def _apply_block(params, cfg: ArchConfig, kind: str, x, positions, aux):
    h = L.rms_norm(x, params["norm1"], cfg.norm_eps)
    if kind == ATTN:
        mixed = L.attention(params["mixer"], cfg, h, positions)
    elif kind == SWA:
        mixed = L.attention(params["mixer"], cfg, h, positions,
                            window=cfg.sliding_window)
    elif kind == MLA:
        mixed = L.mla_attention(params["mixer"], cfg, h, positions)
    elif kind == RGLRU:
        mixed = L.rglru_block(params["mixer"], cfg, h)
    elif kind == MAMBA2:
        mixed = L.mamba2_block(params["mixer"], cfg, h)
    else:
        raise ValueError(kind)
    x = x + mixed
    if kind != MAMBA2:
        h = L.rms_norm(x, params["norm2"], cfg.norm_eps)
        if cfg.moe is not None:
            y, a = L.moe_ffn(params["ffn"], cfg, h)
            aux = aux + a
        else:
            y = L.mlp(params["ffn"], h)
        x = x + y
    return x, aux


def _decode_block(params, cfg: ArchConfig, kind: str, x, cache):
    h = L.rms_norm(x, params["norm1"], cfg.norm_eps)
    if kind == ATTN:
        mixed, cache = L.attention_decode(params["mixer"], cfg, h, cache)
    elif kind == SWA:
        mixed, cache = L.attention_decode(params["mixer"], cfg, h, cache,
                                          window=cfg.sliding_window)
    elif kind == MLA:
        mixed, cache = L.mla_decode(params["mixer"], cfg, h, cache)
    elif kind == RGLRU:
        mixed, cache = L.rglru_decode(params["mixer"], cfg, h, cache)
    elif kind == MAMBA2:
        mixed, cache = L.mamba2_decode(params["mixer"], cfg, h, cache)
    else:
        raise ValueError(kind)
    x = x + mixed
    if kind != MAMBA2:
        h = L.rms_norm(x, params["norm2"], cfg.norm_eps)
        if cfg.moe is not None:
            y, _ = L.moe_ffn(params["ffn"], cfg, h)
        else:
            y = L.mlp(params["ffn"], h)
        x = x + y
    return x, cache


def _init_block_cache(cfg: ArchConfig, kind: str, batch, capacity):
    if kind == ATTN:
        return L.init_attention_cache(cfg, batch, capacity)
    if kind == SWA:
        return L.init_attention_cache(cfg, batch, capacity,
                                      window=cfg.sliding_window)
    if kind == MLA:
        return L.init_mla_cache(cfg, batch, capacity)
    if kind == RGLRU:
        return L.init_rglru_cache(cfg, batch)
    if kind == MAMBA2:
        return L.init_mamba2_cache(cfg, batch)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# stack
# ---------------------------------------------------------------------------

def _split_depth(cfg: ArchConfig):
    pat = tuple(cfg.block_pattern)
    n_groups = cfg.num_layers // len(pat)
    tail = tuple(cfg.blocks()[n_groups * len(pat):])
    return pat, n_groups, tail


def init_stack(key, cfg: ArchConfig):
    pat, n_groups, tail = _split_depth(cfg)
    keys = jax.random.split(key, len(pat) + len(tail))
    groups = []
    for j, kind in enumerate(pat):
        # giant stacks (e.g. llama3-405b: 126 x 16384 x 53248) would overflow
        # the int32 iota inside a vmapped threefry; those configs only ever
        # exist abstractly (dry-run), so replicate one block's init instead.
        one_abs = jax.eval_shape(
            lambda k: _init_block(k, cfg, pat[j]), keys[j])
        biggest = max(a.size for a in jax.tree.leaves(one_abs))
        if n_groups * biggest > 2**31 - 8:
            one = _init_block(keys[j], cfg, kind)
            stacked = jax.tree.map(
                lambda a: jnp.broadcast_to(a[None], (n_groups,) + a.shape),
                one)
        else:
            sub = jax.random.split(keys[j], max(n_groups, 1))
            stacked = jax.vmap(lambda k: _init_block(k, cfg, kind))(sub)
        groups.append(stacked)
    tail_params = tuple(
        _init_block(keys[len(pat) + i], cfg, kind)
        for i, kind in enumerate(tail))
    return {"groups": tuple(groups), "tail": tail_params}


def apply_stack(params, cfg: ArchConfig, x, positions, *, remat: bool = False):
    pat, n_groups, tail = _split_depth(cfg)
    aux = jnp.zeros((), jnp.float32)

    if n_groups > 0:
        def body(carry, group_params):
            h, a = carry
            for j, kind in enumerate(pat):
                h, a = _apply_block(group_params[j], cfg, kind, h, positions, a)
            return (h, a), None

        if remat:
            body = jax.checkpoint(body)   # save only per-group inputs
        (x, aux), _ = lax.scan(body, (x, aux), params["groups"])
    for i, kind in enumerate(tail):
        x, aux = _apply_block(params["tail"][i], cfg, kind, x, positions, aux)
    return x, aux


def init_stack_cache(cfg: ArchConfig, batch, capacity):
    pat, n_groups, tail = _split_depth(cfg)
    groups = []
    for kind in pat:
        one = _init_block_cache(cfg, kind, batch, capacity)
        stacked = jax.tree.map(
            lambda a: jnp.broadcast_to(a, (n_groups,) + a.shape), one)
        groups.append(stacked)
    tail_caches = tuple(_init_block_cache(cfg, kind, batch, capacity)
                        for kind in tail)
    return {"groups": tuple(groups), "tail": tail_caches}


def decode_stack(params, cfg: ArchConfig, x, cache):
    pat, n_groups, tail = _split_depth(cfg)

    if n_groups > 0:
        def body(h, scanned):
            group_params, group_cache = scanned
            new_caches = []
            for j, kind in enumerate(pat):
                h, c = _decode_block(group_params[j], cfg, kind, h,
                                     group_cache[j])
                new_caches.append(c)
            return h, tuple(new_caches)

        x, new_group_cache = lax.scan(body, x,
                                      (params["groups"], cache["groups"]))
    else:
        new_group_cache = cache["groups"]
    new_tail = []
    for i, kind in enumerate(tail):
        x, c = _decode_block(params["tail"][i], cfg, kind, x, cache["tail"][i])
        new_tail.append(c)
    return x, {"groups": new_group_cache, "tail": tuple(new_tail)}
