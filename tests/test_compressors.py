"""Unit tests for unbiased compression operators (Def. 2.2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.compressors import (identity, l2_dithering,
                                    natural_compression, rand_k,
                                    sign_compressor, top_k)

KEY = jax.random.PRNGKey(7)


def _empirical_mean(comp, x, n=400):
    acc = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(n):
        acc = acc + comp.compress(jax.random.fold_in(KEY, i), x)
    return acc / n


@pytest.mark.parametrize("maker", [
    lambda: rand_k(0.25), lambda: l2_dithering(4),
    lambda: natural_compression(), lambda: identity()])
def test_unbiasedness(maker):
    comp = maker()
    x = jax.random.normal(KEY, (64,))
    m = _empirical_mean(comp, x)
    # statistical tolerance: 400 draws, per-coordinate std <= omega^0.5 |x|
    tol = 4.0 * (max(comp.omega(64), 0.01) ** 0.5) * float(
        jnp.max(jnp.abs(x))) / 20.0 + 0.05
    assert float(jnp.max(jnp.abs(m - x))) < tol


def test_randk_density_exact():
    comp = rand_k(0.25)
    x = jax.random.normal(KEY, (100,))
    q = comp.compress(KEY, x)
    assert int(jnp.sum(q != 0)) == 25
    # kept coords scaled by d/k = 4
    kept = q[q != 0]
    orig = x[q != 0]
    np.testing.assert_allclose(np.asarray(kept), np.asarray(orig) * 4.0,
                               rtol=1e-5)


def test_randk_variance_bound():
    comp = rand_k(0.5)
    x = jax.random.normal(KEY, (128,))
    omega = comp.omega(128)
    errs = []
    for i in range(300):
        q = comp.compress(jax.random.fold_in(KEY, i), x)
        errs.append(float(jnp.sum((q - x) ** 2)))
    emp = np.mean(errs)
    bound = omega * float(jnp.sum(x * x))
    assert emp <= bound * 1.15, (emp, bound)


def test_dithering_variance_bound():
    comp = l2_dithering(2)
    x = jax.random.normal(KEY, (64,))
    omega = comp.omega(64)
    errs = []
    for i in range(300):
        q = comp.compress(jax.random.fold_in(KEY, i), x)
        errs.append(float(jnp.sum((q - x) ** 2)))
    assert np.mean(errs) <= omega * float(jnp.sum(x * x)) * 1.15


def test_natural_compression_omega():
    comp = natural_compression()
    assert comp.omega(1000) == pytest.approx(1 / 8)
    x = jax.random.normal(KEY, (256,))
    errs = []
    for i in range(200):
        q = comp.compress(jax.random.fold_in(KEY, i), x)
        errs.append(float(jnp.sum((q - x) ** 2)))
    assert np.mean(errs) <= (1 / 8) * float(jnp.sum(x * x)) * 1.2


def test_natural_compression_powers_of_two():
    comp = natural_compression()
    x = jnp.asarray([0.3, -1.7, 5.0, 0.0])
    q = comp.compress(KEY, x)
    nz = np.asarray(q[q != 0])
    exps = np.log2(np.abs(nz))
    np.testing.assert_allclose(exps, np.round(exps), atol=1e-6)
    assert float(q[3]) == 0.0


def test_sign_compressor_is_sign():
    comp = sign_compressor()
    x = jnp.asarray([1.5, -2.0, 3.0])
    q = comp.compress(KEY, x)
    assert jnp.all(jnp.sign(q) == jnp.sign(x))


def test_topk_keeps_largest_unscaled():
    comp = top_k(0.25)
    x = jnp.asarray([0.1, -5.0, 0.3, 2.0, -0.2, 0.05, 1.0, -0.4])
    q = comp.compress(KEY, x)
    # k = 2 largest magnitudes kept raw (no unbiasedness scaling)
    np.testing.assert_allclose(
        np.asarray(q), [0, -5.0, 0, 2.0, 0, 0, 0, 0], atol=1e-7)


def test_topk_contractive_bound_deterministic():
    """||C(x) - x||^2 <= (1 - k/d) ||x||^2, with equality only when all
    magnitudes are equal — check on random vectors (top_k is deterministic,
    no sampling slack needed)."""
    comp = top_k(0.3)
    for i in range(20):
        x = jax.random.normal(jax.random.fold_in(KEY, i), (50,))
        q = comp.compress(KEY, x)
        err = float(jnp.sum((q - x) ** 2))
        bound = comp.contractive_delta(50) * float(jnp.sum(x * x))
        assert err <= bound + 1e-6, (err, bound)
    assert comp.contractive_delta(50) == pytest.approx(1 - 15 / 50)
    assert np.isnan(comp.omega(50))      # biased: no Def. 2.2 omega


def test_contractive_delta_surface():
    assert identity().contractive_delta(10) == 0.0
    assert sign_compressor().contractive_delta(10) == pytest.approx(0.9)
    assert rand_k(0.5).contractive_delta(10) is None     # unbiased, unscaled
    assert l2_dithering(2).contractive_delta(10) is None


def test_bits_accounting():
    d = 1000
    assert rand_k(0.1).bits_per_vector(d) == 100 * 64
    assert top_k(0.1).bits_per_vector(d) == 100 * 64
    assert identity().bits_per_vector(d) == 32 * d
    assert natural_compression().bits_per_vector(d) == 9 * d


def test_huge_leaf_block_selection():
    """Leaves above the unit cap switch to block selection, stay unbiased."""
    comp = rand_k(0.5)
    x = jnp.ones((1 << 23,))          # 8M coords -> block size 2
    q = comp.compress(KEY, x)
    # mean over coords of q should be ~1 (unbiased), support ratio ~0.5
    assert abs(float(q.mean()) - 1.0) < 0.01
    frac = float((q != 0).mean())
    assert abs(frac - 0.5) < 0.01
