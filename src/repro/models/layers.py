"""Model building blocks: norms, RoPE/M-RoPE, GQA / sliding-window / MLA
attention, gated MLP, sort-based MoE, Mamba2 SSD, RG-LRU.

All functions are pure: ``params`` pytrees in, arrays out. Initializers return
plain nested dicts so the whole model is a vanilla pytree (no framework dep).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax

# ---------------------------------------------------------------------------
# init helpers
# ---------------------------------------------------------------------------

def _dense_init(key, shape, dtype, scale=None):
    fan_in = shape[0] if len(shape) >= 1 else 1
    scale = scale if scale is not None else 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def rms_norm(x, scale, eps=1e-6):
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * (1.0 + scale.astype(jnp.float32))).astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE (+ M-RoPE)
# ---------------------------------------------------------------------------

def _rope_freqs(head_dim, theta):
    half = head_dim // 2
    return 1.0 / (theta ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x, positions, theta=10_000.0, mrope_sections=None):
    """x: (..., S, H, D). positions: (..., S) int or (..., S, 3) for M-RoPE.

    M-RoPE (Qwen2-VL, arXiv:2409.12191): the head-dim frequency bands are
    partitioned into (temporal, height, width) sections; each band rotates by
    its own position component. Text tokens use t=h=w so it reduces to RoPE.
    """
    head_dim = x.shape[-1]
    half = head_dim // 2
    freqs = _rope_freqs(head_dim, theta)                      # (half,)
    if mrope_sections is not None and positions.ndim == x.ndim - 2 + 1:
        # positions (..., S, 3)
        sec = mrope_sections
        assert sum(sec) == half, (sec, half)
        comp = []
        start = 0
        for i, s in enumerate(sec):
            comp.append(jnp.broadcast_to(positions[..., i:i + 1],
                                         positions.shape[:-1] + (s,)))
            start += s
        pos = jnp.concatenate(comp, axis=-1).astype(jnp.float32)  # (..., S, half)
        angles = pos * freqs                                       # (..., S, half)
    else:
        pos = positions.astype(jnp.float32)[..., None]             # (..., S, 1)
        angles = pos * freqs                                       # (..., S, half)
    cos = jnp.cos(angles)[..., None, :]                            # (..., S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention (GQA, causal, optional sliding window), q-chunked for long seqs
# ---------------------------------------------------------------------------

def init_attention(key, cfg):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    nq, nkv = cfg.num_heads, cfg.num_kv_heads
    ks = jax.random.split(key, 6)
    p = {
        "wq": _dense_init(ks[0], (d, nq * hd), cfg.jnp_dtype),
        "wk": _dense_init(ks[1], (d, nkv * hd), cfg.jnp_dtype),
        "wv": _dense_init(ks[2], (d, nkv * hd), cfg.jnp_dtype),
        "wo": _dense_init(ks[3], (nq * hd, d), cfg.jnp_dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), cfg.jnp_dtype)
        p["k_norm"] = jnp.zeros((hd,), cfg.jnp_dtype)
    return p


def _attend(q, k, v, q_pos, k_pos, window=None, k_valid=None):
    """q: (B,Sq,Hq,D) k/v: (B,Sk,Hkv,D). Causal + optional sliding window.

    q_pos (B,Sq) / k_pos (B,Sk) absolute positions; k_valid optional bool mask.
    """
    b, sq, hq, dh = q.shape
    hkv = k.shape[2]
    group = hq // hkv
    qg = q.reshape(b, sq, hkv, group, dh)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                        k.astype(jnp.float32)) / math.sqrt(dh)
    mask = q_pos[:, None, None, :, None] >= k_pos[:, None, None, None, :]
    if window is not None:
        mask &= (q_pos[:, None, None, :, None] - k_pos[:, None, None, None, :]
                 ) < window
    if k_valid is not None:
        mask &= k_valid[:, None, None, None, :]
    scores = jnp.where(mask, scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", probs, v.astype(jnp.float32))
    return out.reshape(b, sq, hq, v.shape[-1]).astype(q.dtype)


# global default for the query-chunked attention loop; the dry-run's cost
# probes set this to a huge value to disable the (cost-undercounted) scan.
Q_CHUNK = [1024]

# attention implementation: "chunked" (materialize (Sq,Sk) scores per q-chunk)
# or "online" (flash-style online-softmax over KV chunks: no (S,S) tensor is
# ever materialized — §Perf beyond-paper optimization).
ATTN_IMPL = ["chunked"]

# dry-run cost probes set this so every inner lax.scan fully unrolls: XLA's
# cost_analysis counts a while body once regardless of trip count, so probes
# must not contain data-independent loops (launch/dryrun.py corrected_costs).
PROBE_UNROLL = [False]


def _unroll(n_trips: int):
    return n_trips if PROBE_UNROLL[0] else 1


def _attend_online(q, k, v, q_pos, k_pos, window=None, k_valid=None,
                   kv_chunk=1024):
    """Flash-style attention: scan over KV chunks with running (max, denom,
    acc). HBM traffic O(S*d) instead of O(S^2); numerically identical to
    softmax attention up to fp error."""
    b, sq, hq, dh = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    group = hq // hkv
    kv_chunk = min(kv_chunk, sk)
    if sk % kv_chunk:
        pad = kv_chunk - sk % kv_chunk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=-1)
        kv_pad_valid = jnp.pad(
            k_valid if k_valid is not None
            else jnp.ones((b, sk), bool), ((0, 0), (0, pad)))
        k_valid = kv_pad_valid
        sk += pad
    elif k_valid is None:
        k_valid = jnp.ones((b, sk), bool)
    nkc = sk // kv_chunk
    qg = q.reshape(b, sq, hkv, group, dh).astype(jnp.float32)
    kc = jnp.moveaxis(k.reshape(b, nkc, kv_chunk, hkv, dh), 1, 0)
    vc = jnp.moveaxis(v.reshape(b, nkc, kv_chunk, hkv, dh), 1, 0)
    pc = jnp.moveaxis(k_pos.reshape(b, nkc, kv_chunk), 1, 0)
    valc = jnp.moveaxis(k_valid.reshape(b, nkc, kv_chunk), 1, 0)
    scale = 1.0 / math.sqrt(dh)

    def body(carry, inp):
        m, l, acc = carry                        # (b,hkv,g,sq), ..., (..,dh)
        ki, vi, pi, vali = inp
        s = jnp.einsum("bqhgd,bkhd->bhgqk", qg, ki.astype(jnp.float32))
        s = s * scale
        mask = q_pos[:, None, None, :, None] >= pi[:, None, None, None, :]
        if window is not None:
            mask &= (q_pos[:, None, None, :, None]
                     - pi[:, None, None, None, :]) < window
        mask &= (pi >= 0)[:, None, None, None, :]
        mask &= vali[:, None, None, None, :]
        s = jnp.where(mask, s, -jnp.inf)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        # guard: all-masked rows keep m = -inf; exp(-inf - -inf) -> use where
        m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
        p = jnp.exp(s - m_safe[..., None])
        corr = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
        l_new = l * corr + jnp.sum(p, axis=-1)
        acc_new = (acc * corr[..., None]
                   + jnp.einsum("bhgqk,bkhd->bhgqd", p,
                                vi.astype(jnp.float32)))
        return (m_new, l_new, acc_new), None

    m0 = jnp.full((b, hkv, group, sq), -jnp.inf, jnp.float32)
    l0 = jnp.zeros((b, hkv, group, sq), jnp.float32)
    a0 = jnp.zeros((b, hkv, group, sq, v.shape[-1]), jnp.float32)
    (m, l, acc), _ = lax.scan(body, (m0, l0, a0), (kc, vc, pc, valc),
                              unroll=_unroll(nkc))
    out = acc / jnp.maximum(l, 1e-30)[..., None]
    out = jnp.moveaxis(out, 3, 1).reshape(b, sq, hq, v.shape[-1])
    return out.astype(q.dtype)


def attention(params, cfg, x, positions, *, window=None, q_chunk=None,
              cache=None, layer_kind="attention"):
    """Full attention path used for train/prefill. positions: (B,S) or (B,S,3)."""
    q_chunk = q_chunk or Q_CHUNK[0]
    b, s, d = x.shape
    hd = cfg.resolved_head_dim
    nq, nkv = cfg.num_heads, cfg.num_kv_heads
    q = jnp.einsum("bsd,de->bse", x, params["wq"]).reshape(b, s, nq, hd)
    k = jnp.einsum("bsd,de->bse", x, params["wk"]).reshape(b, s, nkv, hd)
    v = jnp.einsum("bsd,de->bse", x, params["wv"]).reshape(b, s, nkv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    scalar_pos = positions if positions.ndim == 2 else positions[..., 0]
    q = apply_rope(q, positions, cfg.rope_theta, cfg.mrope_sections)
    k = apply_rope(k, positions, cfg.rope_theta, cfg.mrope_sections)

    if ATTN_IMPL[0] == "online" and s > 1:
        out = _attend_online(q, k, v, scalar_pos, scalar_pos, window=window)
    elif s <= q_chunk or s % q_chunk:
        out = _attend(q, k, v, scalar_pos, scalar_pos, window=window)
    else:
        n_chunks = s // q_chunk
        qc = q.reshape(b, n_chunks, q_chunk, nq, hd)
        pc = scalar_pos.reshape(b, n_chunks, q_chunk)

        def chunk_fn(carry, inp):
            qi, pi = inp
            o = _attend(qi, k, v, pi, scalar_pos, window=window)
            return carry, o

        _, outs = lax.scan(chunk_fn, 0,
                           (jnp.moveaxis(qc, 1, 0), jnp.moveaxis(pc, 1, 0)),
                           unroll=_unroll(n_chunks))
        out = jnp.moveaxis(outs, 0, 1).reshape(b, s, nq, hd)
    return jnp.einsum("bse,ed->bsd", out.reshape(b, s, nq * hd), params["wo"])


def attention_decode(params, cfg, x, cache, *, window=None):
    """One-token decode with KV cache.

    cache: {"k": (B,L,Hkv,D), "v": ..., "pos": (B,L) int32 absolute positions
            (-1 = empty), "len": () int32 tokens seen so far}
    Sliding window uses the cache as a ring buffer of capacity L.
    """
    b, s, d = x.shape
    assert s == 1
    hd = cfg.resolved_head_dim
    nq, nkv = cfg.num_heads, cfg.num_kv_heads
    cur = cache["len"]
    cap = cache["k"].shape[1]
    q = jnp.einsum("bsd,de->bse", x, params["wq"]).reshape(b, 1, nq, hd)
    k = jnp.einsum("bsd,de->bse", x, params["wk"]).reshape(b, 1, nkv, hd)
    v = jnp.einsum("bsd,de->bse", x, params["wv"]).reshape(b, 1, nkv, hd)
    if cfg.qk_norm:
        q = rms_norm(q, params["q_norm"], cfg.norm_eps)
        k = rms_norm(k, params["k_norm"], cfg.norm_eps)
    pos = jnp.broadcast_to(cur, (b, 1)).astype(jnp.int32)
    if cfg.mrope_sections is not None:
        pos3 = jnp.broadcast_to(pos[..., None], (b, 1, 3))
        q = apply_rope(q, pos3, cfg.rope_theta, cfg.mrope_sections)
        k = apply_rope(k, pos3, cfg.rope_theta, cfg.mrope_sections)
    else:
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    slot = jnp.mod(cur, cap)
    ck = lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
    cv = lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
    cpos = lax.dynamic_update_slice(
        cache["pos"], jnp.broadcast_to(pos, (b, 1)), (0, slot))
    valid = cpos >= 0
    out = _attend(q, ck, cv, pos, cpos, window=window, k_valid=valid)
    y = jnp.einsum("bse,ed->bsd", out.reshape(b, 1, nq * hd), params["wo"])
    new_cache = {"k": ck, "v": cv, "pos": cpos, "len": cur + 1}
    return y, new_cache


def init_attention_cache(cfg, batch, capacity, *, window=None):
    hd = cfg.resolved_head_dim
    cap = min(capacity, window) if window else capacity
    return {
        "k": jnp.zeros((batch, cap, cfg.num_kv_heads, hd), cfg.jnp_dtype),
        "v": jnp.zeros((batch, cap, cfg.num_kv_heads, hd), cfg.jnp_dtype),
        "pos": jnp.full((batch, cap), -1, jnp.int32),
        "len": jnp.zeros((), jnp.int32),
    }


# ---------------------------------------------------------------------------
# MLA — multi-head latent attention (DeepSeek-V2, arXiv:2405.04434)
# ---------------------------------------------------------------------------

def init_mla(key, cfg):
    d, hd = cfg.d_model, cfg.resolved_head_dim
    nq, r, rd = cfg.num_heads, cfg.kv_lora_rank, cfg.qk_rope_dim
    ks = jax.random.split(key, 7)
    return {
        "wq": _dense_init(ks[0], (d, nq * (hd + rd)), cfg.jnp_dtype),
        "w_dkv": _dense_init(ks[1], (d, r), cfg.jnp_dtype),       # down proj
        "w_uk": _dense_init(ks[2], (r, nq * hd), cfg.jnp_dtype),  # up proj K
        "w_uv": _dense_init(ks[3], (r, nq * hd), cfg.jnp_dtype),  # up proj V
        "w_kr": _dense_init(ks[4], (d, rd), cfg.jnp_dtype),       # shared rope key
        "wo": _dense_init(ks[5], (nq * hd, d), cfg.jnp_dtype),
        "kv_norm": jnp.zeros((r,), cfg.jnp_dtype),
    }


def mla_attention(params, cfg, x, positions, *, q_chunk=1024):
    """Train/prefill MLA: materialize per-head K/V from the latent."""
    b, s, d = x.shape
    hd, nq = cfg.resolved_head_dim, cfg.num_heads
    r, rd = cfg.kv_lora_rank, cfg.qk_rope_dim
    q = jnp.einsum("bsd,de->bse", x, params["wq"]).reshape(b, s, nq, hd + rd)
    q_nope, q_rope = q[..., :hd], q[..., hd:]
    c_kv = rms_norm(jnp.einsum("bsd,dr->bsr", x, params["w_dkv"]),
                    params["kv_norm"], cfg.norm_eps)
    k_nope = jnp.einsum("bsr,re->bse", c_kv, params["w_uk"]).reshape(b, s, nq, hd)
    v = jnp.einsum("bsr,re->bse", c_kv, params["w_uv"]).reshape(b, s, nq, hd)
    k_rope = jnp.einsum("bsd,dr->bsr", x, params["w_kr"])[:, :, None, :]  # shared
    pos = positions if positions.ndim == 2 else positions[..., 0]
    q_rope = apply_rope(q_rope, pos, cfg.rope_theta)
    k_rope = apply_rope(k_rope, pos, cfg.rope_theta)
    qf = jnp.concatenate([q_nope, q_rope], axis=-1)
    kf = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (b, s, nq, rd))],
                         axis=-1)
    out = _attend(qf, kf, v, pos, pos)
    return jnp.einsum("bse,ed->bsd", out.reshape(b, s, nq * hd), params["wo"])


def mla_decode(params, cfg, x, cache):
    """Absorbed-form MLA decode: the cache stores only (c_kv, k_rope) —
    576 floats/token for the full config — and W_uk/W_uv are folded into the
    query/output so no per-head K/V is ever materialized. TPU-friendly: two
    (B,H,r)x(B,L,r) einsums instead of a (B,L,H,D) gather."""
    b, s, d = x.shape
    assert s == 1
    hd, nq = cfg.resolved_head_dim, cfg.num_heads
    r, rd = cfg.kv_lora_rank, cfg.qk_rope_dim
    cur = cache["len"]
    cap = cache["c_kv"].shape[1]
    q = jnp.einsum("bsd,de->bse", x, params["wq"]).reshape(b, 1, nq, hd + rd)
    q_nope, q_rope = q[..., :hd], q[..., hd:]
    pos = jnp.broadcast_to(cur, (b, 1)).astype(jnp.int32)
    q_rope = apply_rope(q_rope, pos, cfg.rope_theta)
    c_new = rms_norm(jnp.einsum("bsd,dr->bsr", x, params["w_dkv"]),
                     params["kv_norm"], cfg.norm_eps)
    kr_new = apply_rope(
        jnp.einsum("bsd,dr->bsr", x, params["w_kr"])[:, :, None, :], pos,
        cfg.rope_theta)[:, :, 0, :]
    slot = jnp.mod(cur, cap)
    c_kv = lax.dynamic_update_slice(cache["c_kv"], c_new, (0, slot, 0))
    k_rope = lax.dynamic_update_slice(cache["k_rope"], kr_new, (0, slot, 0))
    cpos = lax.dynamic_update_slice(cache["pos"], pos, (0, slot))
    # absorb W_uk into q:  score = (q_nope W_uk^T) . c  + q_rope . k_rope
    w_uk = params["w_uk"].reshape(r, nq, hd)
    q_eff = jnp.einsum("bqhd,rhd->bqhr", q_nope.astype(jnp.float32),
                       w_uk.astype(jnp.float32))
    scores = (jnp.einsum("bqhr,blr->bhql", q_eff, c_kv.astype(jnp.float32))
              + jnp.einsum("bqhr,blr->bhql", q_rope.astype(jnp.float32),
                           k_rope.astype(jnp.float32)))
    scores = scores / math.sqrt(hd + rd)
    mask = (cpos >= 0) & (cpos <= cur)
    scores = jnp.where(mask[:, None, None, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhql,blr->bqhr", probs, c_kv.astype(jnp.float32))
    w_uv = params["w_uv"].reshape(r, nq, hd)
    out = jnp.einsum("bqhr,rhd->bqhd", ctx, w_uv.astype(jnp.float32))
    out = out.reshape(b, 1, nq * hd).astype(x.dtype)
    y = jnp.einsum("bse,ed->bsd", out, params["wo"])
    return y, {"c_kv": c_kv, "k_rope": k_rope, "pos": cpos, "len": cur + 1}


def init_mla_cache(cfg, batch, capacity, *, window=None):
    cap = min(capacity, window) if window else capacity
    return {
        "c_kv": jnp.zeros((batch, cap, cfg.kv_lora_rank), cfg.jnp_dtype),
        "k_rope": jnp.zeros((batch, cap, cfg.qk_rope_dim), cfg.jnp_dtype),
        "pos": jnp.full((batch, cap), -1, jnp.int32),
        "len": jnp.zeros((), jnp.int32),
    }


# ---------------------------------------------------------------------------
# Gated MLP + MoE (sort-based dispatch)
# ---------------------------------------------------------------------------

def init_mlp(key, cfg, d_ff=None):
    d = cfg.d_model
    ff = d_ff or cfg.d_ff
    ks = jax.random.split(key, 3)
    return {
        "w1": _dense_init(ks[0], (d, ff), cfg.jnp_dtype),
        "w3": _dense_init(ks[1], (d, ff), cfg.jnp_dtype),
        "w2": _dense_init(ks[2], (ff, d), cfg.jnp_dtype),
    }


def mlp(params, x):
    h = jax.nn.silu(jnp.einsum("...d,df->...f", x, params["w1"]))
    h = h * jnp.einsum("...d,df->...f", x, params["w3"])
    return jnp.einsum("...f,fd->...d", h, params["w2"])


def init_moe(key, cfg):
    m = cfg.moe
    d = cfg.d_model
    de = m.d_expert or cfg.d_ff
    ks = jax.random.split(key, 5)
    p = {
        "router": _dense_init(ks[0], (d, m.num_experts), cfg.jnp_dtype),
        "w1": _dense_init(ks[1], (m.num_experts, d, de), cfg.jnp_dtype),
        "w3": _dense_init(ks[2], (m.num_experts, d, de), cfg.jnp_dtype),
        "w2": _dense_init(ks[3], (m.num_experts, de, d), cfg.jnp_dtype),
    }
    if m.num_shared:
        p["shared"] = init_mlp(ks[4], cfg, d_ff=de * m.num_shared)
    return p


# §Perf knob: constrain the MoE dispatch buffers to expert-parallel sharding
# so GSPMD converts the (E, C, d) reshards into all-to-alls instead of
# all-gathering the whole buffer on every device (launch/dryrun.py
# --moe-ep-constraint; axis name injected by the launcher).
MOE_EP_CONSTRAINT = [None]   # None = off; else mesh axis name (e.g. "model")


def _maybe_ep_constrain(t):
    axis = MOE_EP_CONSTRAINT[0]
    if axis is None:
        return t
    from jax.sharding import PartitionSpec as _P
    spec = _P(*((axis,) + (None,) * (t.ndim - 1)))
    return jax.lax.with_sharding_constraint(t, spec)


def moe_ffn(params, cfg, x):
    """Sort-based capacity-constrained MoE dispatch (MaxText-style).

    x: (B, S, d) -> (B, S, d), plus scalar aux load-balance loss.
    The expert dim of w1/w2/w3 shards over the `model` mesh axis
    (expert parallelism); dispatch is argsort + scatter, no (T,E,C) one-hot.
    """
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    e, k = m.num_experts, m.top_k
    xf = x.reshape(t, d)
    logits = jnp.einsum("td,de->te", xf, params["router"]).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, idx = lax.top_k(probs, k)                       # (t,k)
    gate = gate / jnp.clip(gate.sum(-1, keepdims=True), 1e-9)
    cap = int(m.capacity_factor * t * k / e) + 1

    flat_e = idx.reshape(-1)                              # (t*k,)
    flat_t = jnp.repeat(jnp.arange(t), k)                 # (t*k,)
    flat_g = gate.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sg = flat_e[order], flat_t[order], flat_g[order]
    counts = jnp.bincount(se, length=e)
    starts = jnp.cumsum(counts) - counts
    rank = jnp.arange(t * k) - starts[se]
    keep = rank < cap
    dest = jnp.where(keep, se * cap + rank, e * cap)      # overflow slot dropped
    if MOE_EP_CONSTRAINT[0] is not None:
        # 3D scatter straight into the expert-sharded buffer: the expert dim
        # is laid out over the EP axis BEFORE expert compute, so the reshard
        # happens on the (t*k, d) token stream (all-to-all-sized), not by
        # all-gathering the whole (E, C, d) buffer.
        rank_c = jnp.where(keep, rank, cap)
        buf3 = jnp.zeros((e, cap + 1, d), xf.dtype).at[se, rank_c].set(
            xf[st], mode="drop")
        ex_in = _maybe_ep_constrain(buf3[:, :cap, :])
    else:
        buf = jnp.zeros((e * cap + 1, d), xf.dtype).at[dest].set(xf[st])
        ex_in = buf[:-1].reshape(e, cap, d)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", ex_in, params["w1"]))
    h = h * jnp.einsum("ecd,edf->ecf", ex_in, params["w3"])
    ex_out = _maybe_ep_constrain(jnp.einsum("ecf,efd->ecd", h, params["w2"]))
    picked = ex_out.reshape(e * cap, d)[jnp.minimum(dest, e * cap - 1)]
    picked = picked * (keep * sg)[:, None].astype(picked.dtype)
    yf = jnp.zeros((t, d), xf.dtype).at[st].add(picked)
    y = yf.reshape(b, s, d)
    if m.num_shared:
        y = y + mlp(params["shared"], x)
    # load-balance aux (Switch-style): E * sum_e f_e * P_e
    frac = jnp.bincount(flat_e, length=e) / (t * k)
    pmean = probs.mean(0)
    aux = e * jnp.sum(frac * pmean) * m.router_aux_weight
    return y, aux


# ---------------------------------------------------------------------------
# Depthwise causal conv1d (shared by Mamba2 and RG-LRU blocks)
# ---------------------------------------------------------------------------

def causal_conv1d(x, w):
    """x: (B, T, C); w: (W, C) depthwise causal filter."""
    width = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(width):
        out = out + xp[:, i:i + x.shape[1], :].astype(jnp.float32) * w[i].astype(jnp.float32)
    return out.astype(x.dtype)


def causal_conv1d_step(x, w, conv_state):
    """x: (B, 1, C). conv_state: (B, W-1, C) previous inputs."""
    width = w.shape[0]
    window = jnp.concatenate([conv_state, x], axis=1)       # (B, W, C)
    out = jnp.einsum("bwc,wc->bc", window.astype(jnp.float32),
                     w.astype(jnp.float32))[:, None, :].astype(x.dtype)
    return out, window[:, 1:, :]


# ---------------------------------------------------------------------------
# Mamba2 SSD block (arXiv:2405.21060) — chunked state-space duality
# ---------------------------------------------------------------------------

def init_mamba2(key, cfg):
    d = cfg.d_model
    di = cfg.ssm_expand * d
    n = cfg.ssm_state
    nh = di // cfg.ssm_headdim
    ks = jax.random.split(key, 6)
    return {
        # in_proj -> [z (di), x (di), B (n), C (n), dt (nh)]
        "w_in": _dense_init(ks[0], (d, 2 * di + 2 * n + nh), cfg.jnp_dtype),
        "conv_w": _dense_init(ks[1], (cfg.conv_width, di + 2 * n),
                              cfg.jnp_dtype, scale=0.5),
        "a_log": jnp.log(jnp.linspace(1.0, 16.0, nh)).astype(cfg.jnp_dtype),
        "dt_bias": jnp.zeros((nh,), cfg.jnp_dtype),
        "d_skip": jnp.ones((nh,), cfg.jnp_dtype),
        "out_norm": jnp.zeros((di,), cfg.jnp_dtype),
        "w_out": _dense_init(ks[2], (di, d), cfg.jnp_dtype),
    }


def _ssd_chunked(xh, bmat, cmat, dt, a_log, chunk=64):
    """SSD over chunks. xh: (B,T,H,P), bmat/cmat: (B,T,N), dt: (B,T,H).

    h_t = exp(dt_t * A_h) h_{t-1} + dt_t * B_t (x) x_t ;  y_t = C_t . h_t
    Returns y (B,T,H,P) and final state (B,H,N,P).
    """
    b, t, h, p = xh.shape
    n = bmat.shape[-1]
    chunk = min(chunk, t)
    nc = t // chunk
    assert t % chunk == 0, (t, chunk)
    a = -jnp.exp(a_log.astype(jnp.float32))                      # (H,) negative
    dt = dt.astype(jnp.float32)
    da = dt * a                                                  # (B,T,H) logdecay
    xr = xh.reshape(b, nc, chunk, h, p).astype(jnp.float32)
    br = bmat.reshape(b, nc, chunk, n).astype(jnp.float32)
    cr = cmat.reshape(b, nc, chunk, n).astype(jnp.float32)
    dar = da.reshape(b, nc, chunk, h)
    dtr = dt.reshape(b, nc, chunk, h)
    cum = jnp.cumsum(dar, axis=2)                                # (B,nc,Lc,H)
    # ---- intra-chunk (quadratic within chunk)
    g = jnp.einsum("bcqn,bckn->bcqk", cr, br)                    # (B,nc,Lc,Lc)
    rel = cum[:, :, :, None, :] - cum[:, :, None, :, :]          # q - k
    causal = jnp.tril(jnp.ones((chunk, chunk), bool))
    # mask in log-space BEFORE exp: exp of the (positive) acausal rel would
    # overflow and poison the gradient through the where.
    rel = jnp.where(causal[None, None, :, :, None], rel, -jnp.inf)
    decay = jnp.exp(rel)
    m = g[..., None] * decay * dtr[:, :, None, :, :]             # (B,nc,q,k,H)
    y_intra = jnp.einsum("bcqkh,bckhp->bcqhp", m, xr)
    # ---- chunk states
    tail = cum[:, :, -1:, :] - cum                               # decay to chunk end
    sx = xr * (dtr * jnp.exp(tail))[..., None]                   # (B,nc,Lc,H,P)
    states = jnp.einsum("bckn,bckhp->bchnp", br, sx)             # (B,nc,H,N,P)
    chunk_decay = jnp.exp(cum[:, :, -1, :])                      # (B,nc,H)

    def scan_fn(carry, inp):
        s_c, dec = inp                                           # (B,H,N,P),(B,H)
        prev = carry
        new = prev * dec[..., None, None] + s_c
        return new, prev

    init = jnp.zeros((b, h, n, p), jnp.float32)
    final, prevs = lax.scan(scan_fn, init,
                            (jnp.moveaxis(states, 1, 0),
                             jnp.moveaxis(chunk_decay, 1, 0)),
                            unroll=_unroll(nc))
    prev_states = jnp.moveaxis(prevs, 0, 1)                      # (B,nc,H,N,P)
    # ---- inter-chunk contribution
    y_inter = jnp.einsum("bcqn,bchnp,bcqh->bcqhp", cr, prev_states,
                         jnp.exp(cum))
    y = (y_intra + y_inter).reshape(b, t, h, p)
    return y, final


def mamba2_block(params, cfg, x, *, chunk=64):
    b, t, d = x.shape
    di = cfg.ssm_expand * d
    n = cfg.ssm_state
    nh = di // cfg.ssm_headdim
    ph = cfg.ssm_headdim
    zxbcdt = jnp.einsum("btd,de->bte", x, params["w_in"])
    z, xbc, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * n], axis=-1)
    xbc = causal_conv1d(jax.nn.silu(xbc), params["conv_w"])
    xi, bmat, cmat = jnp.split(xbc, [di, di + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))
    xh = xi.reshape(b, t, nh, ph)
    y, _ = _ssd_chunked(xh, bmat, cmat, dt, params["a_log"], chunk=chunk)
    y = y + xh.astype(jnp.float32) * params["d_skip"].astype(jnp.float32)[None, None, :, None]
    y = y.reshape(b, t, di).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rms_norm(y, params["out_norm"], cfg.norm_eps)
    return jnp.einsum("bte,ed->btd", y, params["w_out"])


def mamba2_decode(params, cfg, x, cache):
    """O(1) per-token recurrent decode. cache: {"h": (B,H,N,P), "conv": ...}"""
    b, s, d = x.shape
    assert s == 1
    di = cfg.ssm_expand * d
    n = cfg.ssm_state
    nh = di // cfg.ssm_headdim
    ph = cfg.ssm_headdim
    zxbcdt = jnp.einsum("btd,de->bte", x, params["w_in"])
    z, xbc, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * n], axis=-1)
    xbc, conv_state = causal_conv1d_step(jax.nn.silu(xbc), params["conv_w"],
                                         cache["conv"])
    xi, bmat, cmat = jnp.split(xbc, [di, di + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"].astype(jnp.float32))[:, 0]  # (B,H)
    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    dec = jnp.exp(dt * a)                                        # (B,H)
    xh = xi[:, 0].reshape(b, nh, ph).astype(jnp.float32)
    bm = bmat[:, 0].astype(jnp.float32)                          # (B,N)
    cm = cmat[:, 0].astype(jnp.float32)
    hnew = (cache["h"] * dec[..., None, None]
            + jnp.einsum("bn,bhp,bh->bhnp", bm, xh, dt))
    y = jnp.einsum("bn,bhnp->bhp", cm, hnew)
    y = y + xh * params["d_skip"].astype(jnp.float32)[None, :, None]
    y = y.reshape(b, 1, di).astype(x.dtype)
    y = y * jax.nn.silu(z)
    y = rms_norm(y, params["out_norm"], cfg.norm_eps)
    out = jnp.einsum("bte,ed->btd", y, params["w_out"])
    return out, {"h": hnew, "conv": conv_state, "len": cache["len"] + 1}


def init_mamba2_cache(cfg, batch):
    di = cfg.ssm_expand * cfg.d_model
    nh = di // cfg.ssm_headdim
    return {
        "h": jnp.zeros((batch, nh, cfg.ssm_state, cfg.ssm_headdim), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, di + 2 * cfg.ssm_state),
                          cfg.jnp_dtype),
        "len": jnp.zeros((), jnp.int32),
    }


# ---------------------------------------------------------------------------
# RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427)
# ---------------------------------------------------------------------------

_RGLRU_C = 8.0


def init_rglru(key, cfg):
    d = cfg.d_model
    w = cfg.rglru_width or d
    ks = jax.random.split(key, 7)
    return {
        "w_gate_branch": _dense_init(ks[0], (d, w), cfg.jnp_dtype),
        "w_rec_branch": _dense_init(ks[1], (d, w), cfg.jnp_dtype),
        "conv_w": _dense_init(ks[2], (cfg.conv_width, w), cfg.jnp_dtype,
                              scale=0.5),
        "w_a": _dense_init(ks[3], (w, w), cfg.jnp_dtype),
        "b_a": jnp.zeros((w,), cfg.jnp_dtype),
        "w_i": _dense_init(ks[4], (w, w), cfg.jnp_dtype),
        "b_i": jnp.zeros((w,), cfg.jnp_dtype),
        # Λ init so that a = exp(-c softplus(Λ)) in [0.9, 0.999]
        "lam": jnp.asarray(
            jnp.log(jnp.expm1(-jnp.log(jnp.linspace(0.9, 0.999, w)) / _RGLRU_C)),
            cfg.jnp_dtype),
        "w_out": _dense_init(ks[5], (w, d), cfg.jnp_dtype),
    }


def _rglru_gates(params, u):
    r = jax.nn.sigmoid(jnp.einsum("btw,wv->btv", u, params["w_a"]).astype(jnp.float32)
                       + params["b_a"].astype(jnp.float32))
    i = jax.nn.sigmoid(jnp.einsum("btw,wv->btv", u, params["w_i"]).astype(jnp.float32)
                       + params["b_i"].astype(jnp.float32))
    log_a = -_RGLRU_C * jax.nn.softplus(params["lam"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    gated = jnp.sqrt(jnp.clip(1.0 - a * a, 1e-12)) * (i * u.astype(jnp.float32))
    return a, gated


def rglru_block(params, cfg, x):
    """Griffin recurrent block: (gate branch) * RG-LRU(conv(rec branch))."""
    gate = jax.nn.gelu(jnp.einsum("btd,dw->btw", x, params["w_gate_branch"]))
    u = jnp.einsum("btd,dw->btw", x, params["w_rec_branch"])
    u = causal_conv1d(u, params["conv_w"])
    a, gated = _rglru_gates(params, u)

    def combine(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    _, h = lax.associative_scan(combine, (a, gated), axis=1)
    h = h.astype(x.dtype)
    y = h * gate
    return jnp.einsum("btw,wd->btd", y, params["w_out"])


def rglru_decode(params, cfg, x, cache):
    gate = jax.nn.gelu(jnp.einsum("btd,dw->btw", x, params["w_gate_branch"]))
    u = jnp.einsum("btd,dw->btw", x, params["w_rec_branch"])
    u, conv_state = causal_conv1d_step(u, params["conv_w"], cache["conv"])
    a, gated = _rglru_gates(params, u)
    h = a[:, 0] * cache["h"] + gated[:, 0]                      # (B,W)
    y = h[:, None, :].astype(x.dtype) * gate
    out = jnp.einsum("btw,wd->btd", y, params["w_out"])
    return out, {"h": h, "conv": conv_state, "len": cache["len"] + 1}


def init_rglru_cache(cfg, batch):
    w = cfg.rglru_width or cfg.d_model
    return {
        "h": jnp.zeros((batch, w), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, w), cfg.jnp_dtype),
        "len": jnp.zeros((), jnp.int32),
    }
