"""The paper's theory, executable: step sizes, convergence constants, and
communication/oracle complexity bounds (Thm. 2.1/2.2, Cor. E.1–E.7).

This closes the loop between analysis and practice: examples and benchmarks
can ask for the *theory-prescribed* γ = 1/(L+√A) instead of hand-tuning,
and the complexity calculator reproduces Table 2's regimes.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax.numpy as jnp


# (δ_max, c) certified by Theorem D.1 for each rule ∘ bucketing
AGG_CONSTANTS = {
    "krum": {"delta_max": 0.25, "c": 6.0},
    "rfa": {"delta_max": 0.5, "c": 6.0},
    "cm": {"delta_max": 0.5, "c": None},   # c = O(d): filled per-problem
    "tm": {"delta_max": 0.5, "c": 6.0},    # trimmed mean ~ CM-class
    "mean": {"delta_max": 0.0, "c": 0.0},
}


@dataclasses.dataclass(frozen=True)
class ProblemConstants:
    """Smoothness / heterogeneity constants of problem (1)."""
    L: float                  # global smoothness (As. 2.1)
    L_pm: float = 0.0         # global Hessian variance L± (As. 2.3)
    calL_pm: float = 0.0      # local Hessian variance L± (As. 2.4, batch-free)
    zeta_sq: float = 0.0      # ζ² heterogeneity (As. 2.2)
    mu: float = 0.0           # PŁ constant (As. 2.5); 0 = general non-convex
    m: int = 1                # local dataset size
    d: int = 1                # dimension


def marina_A(pc: ProblemConstants, *, p: float, b: int, G: int,
             delta: float, c: float, omega: float) -> float:
    """The A constant of Thm. 2.1/2.2 (B = 0 case):
    A = 6(1-p)/p [ (4cδ/p + 1/2G)(ω L² + (1+ω) 𝓛±²/b)
                  + (4cδ(1+ω)/p + ω/2G) L±² ]
    """
    t1 = (4 * c * delta / p + 1 / (2 * G)) * (
        omega * pc.L ** 2 + (1 + omega) * pc.calL_pm ** 2 / b)
    t2 = (4 * c * delta * (1 + omega) / p + omega / (2 * G)) * pc.L_pm ** 2
    return 6 * (1 - p) / p * (t1 + t2)


def step_size(pc: ProblemConstants, *, p: float, b: int, G: int,
              delta: float, c: float, omega: float,
              pl: bool = False) -> float:
    """γ = 1/(L+√A) (Thm 2.1) or min{1/(L+√2A), p/4μ} (Thm 2.2)."""
    A = marina_A(pc, p=p, b=b, G=G, delta=delta, c=c, omega=omega)
    if pl:
        g1 = 1.0 / (pc.L + math.sqrt(2 * A))
        if pc.mu > 0:
            return min(g1, p / (4 * pc.mu))
        return g1
    return 1.0 / (pc.L + math.sqrt(A))


def recommended_p(*, b: int, m: int, omega: float) -> float:
    """p = min{b/m, 1/(1+ω)} (footnote 3: equalizes the expected cost of
    full-gradient rounds and compressed rounds)."""
    return min(b / m, 1.0 / (1.0 + omega))


def error_floor(*, delta: float, c: float, p: float, zeta_sq: float,
                mu: Optional[float] = None) -> float:
    """The heterogeneity floor: 24cδζ²/p on E||∇f||² (Thm 2.1), or
    24cδζ²/μ(p) on f-f* under PŁ (Thm 2.2). Zero iff ζ=0 or δ=0."""
    if mu:
        return 24 * c * delta * zeta_sq / (mu * p)
    return 24 * c * delta * zeta_sq / p


def communication_rounds_nc(pc: ProblemConstants, *, eps_sq: float,
                            delta0: float, p: float, b: int, G: int,
                            delta: float, c: float, omega: float) -> float:
    """Non-convex rounds bound: 2Φ0 / (γ ε²) with Φ0 ≈ 2Δ0 (Eq. 30)."""
    gamma = step_size(pc, p=p, b=b, G=G, delta=delta, c=c, omega=omega)
    return 4 * delta0 / (gamma * eps_sq)


def communication_rounds_pl(pc: ProblemConstants, *, eps: float,
                            delta0: float, p: float, b: int, G: int,
                            delta: float, c: float, omega: float) -> float:
    """PŁ rounds bound: (1/γμ(1)) log(2Δ0/ε) (Thm 2.2, ζ=0)."""
    assert pc.mu > 0
    gamma = step_size(pc, p=p, b=b, G=G, delta=delta, c=c, omega=omega,
                      pl=True)
    return math.log(max(2 * delta0 / eps, 1.0 + 1e-9)) / (gamma * pc.mu)


# ---------------------------------------------------------------------------
# constants estimation for the logreg task (used by examples/tests)
# ---------------------------------------------------------------------------

def logreg_constants(features, lam: float, *, n_workers: int,
                     homogeneous: bool = True) -> ProblemConstants:
    """ℓ2-regularized logistic regression: per-sample smoothness
    L_ij = ||a_ij||²/4 + 2λ; f is (2λ)-strongly convex => PŁ with μ=2λ."""
    x = jnp.asarray(features)
    row_sq = jnp.sum(x * x, axis=1)
    L_i = float(jnp.max(row_sq)) / 4 + 2 * lam
    L_avg = float(jnp.mean(row_sq)) / 4 + 2 * lam
    return ProblemConstants(
        L=L_avg, L_pm=0.0 if homogeneous else L_avg,
        calL_pm=L_i,                     # worst-case bound (Ex. E.1)
        mu=2 * lam, m=x.shape[0], d=x.shape[1])


def importance_weights(features, lam: float):
    """Example E.2 importance sampling: P(j) ∝ L_j = ||a_j||²/4 + 2λ.
    Returns (probs (m,), Lbar) — 𝓛±(IS) ≤ L̄ ≤ max_j L_j = 𝓛±(US)."""
    x = jnp.asarray(features)
    L_j = jnp.sum(x * x, axis=1) / 4 + 2 * lam
    Lbar = jnp.mean(L_j)
    return L_j / jnp.sum(L_j), float(Lbar)
