"""Ablations over the paper's knobs (App. E.5 discussions):

* p sweep      — "On the choice of p": oracle vs communication tradeoff.
* bucket sweep — s ∈ {1,2,4}: Alg. 2's robustness/variance tradeoff
                 (paper recommends s=2).
* batch sweep  — "On the batchsizes": gains saturate once
                 b ≳ max{∛(cδm²), √m}.
* IS vs US     — Example E.2: importance sampling reaches the target in
                 fewer rounds when 𝓛±(IS) ≪ 𝓛±(US).
"""
import jax
import jax.numpy as jnp

from benchmarks.common import emit, make_logreg_problem
from repro.core import (ByzVRMarinaConfig, get_aggregator, get_attack,
                        get_compressor, make_method, theory)
from repro.data import corrupt_labels_logreg, init_logreg_params

KEY = jax.random.PRNGKey(5)
DIM = 30


def _final_gap(data, loss_fn, full, f_star, cfg, iters=400, sampler=None):
    method = make_method("marina", cfg, loss_fn, corrupt_labels_logreg)
    step = jax.jit(method.step)
    anchor = data.stacked()
    state = method.init(init_logreg_params(DIM), anchor, KEY)
    k = KEY
    for it in range(iters):
        k, k1, k2 = jax.random.split(k, 3)
        mb = sampler(k1) if sampler else data.sample_batches(k1, 32)
        state, _ = step(state, mb, anchor, k2)
    return float(loss_fn(state["params"], full)) - f_star


def run():
    data, loss_fn, full, f_star = make_logreg_problem(KEY, dim=DIM)
    base = dict(n_workers=5, n_byz=1, lr=0.5,
                aggregator=get_aggregator("cm", bucket_size=2),
                attack=get_attack("ALIE"))

    for p in [0.02, 0.1, 0.5]:
        cfg = ByzVRMarinaConfig(p=p, **base)
        gap = _final_gap(data, loss_fn, full, f_star, cfg)
        emit(f"ablate/p{p}", 0.0, f"gap={gap:.2e}")

    for s in [1, 2, 4]:
        kw = dict(base)
        kw["aggregator"] = get_aggregator("cm", bucket_size=s)
        cfg = ByzVRMarinaConfig(p=0.1, **kw)
        gap = _final_gap(data, loss_fn, full, f_star, cfg)
        emit(f"ablate/bucket{s}", 0.0, f"gap={gap:.2e}")

    for b in [8, 32, 128]:
        cfg = ByzVRMarinaConfig(p=0.1, **base)
        gap = _final_gap(data, loss_fn, full, f_star, cfg, iters=300,
                         sampler=lambda k: data.sample_batches(k, b))
        emit(f"ablate/batch{b}", 0.0, f"gap={gap:.2e}")

    # importance vs uniform sampling (Example E.2)
    probs, lbar = theory.importance_weights(data.features, 0.01)
    pc = theory.logreg_constants(data.features, 0.01, n_workers=5)
    cfg = ByzVRMarinaConfig(p=0.1, **base)
    gap_us = _final_gap(data, loss_fn, full, f_star, cfg, iters=250)
    gap_is = _final_gap(
        data, loss_fn, full, f_star, cfg, iters=250,
        sampler=lambda k: data.sample_batches_importance(k, 32, probs))
    emit("ablate/sampling-uniform", 0.0,
         f"gap={gap_us:.2e};calL={pc.calL_pm:.2f}")
    emit("ablate/sampling-importance", 0.0,
         f"gap={gap_is:.2e};calL={lbar:.2f}")


if __name__ == "__main__":
    run()
