"""Estimator conformance harness (DESIGN.md §2 "estimator-plugin contract").

One parametrized suite over EVERY ``ESTIMATORS`` entry — old and new — so a
future estimator gets the whole contract checked for free the moment it is
registered:

  * state pytree round-trips through checkpoint/resume bit-for-bit (the
    engine state dict, INCLUDING estimator extras: worker tables, EF21
    error-feedback state, momenta, snapshots);
  * ``run(spec)`` ≡ the hand-wired engine (spec.build_config() +
    make_method + the runner's documented key schedule) bit-for-bit;
  * communication accounting matches ``theory.comm_bits_per_round`` (and
    the internal p-mixture identity between round_bits and expected_bits);
  * descent on a deterministic quadratic (full-batch least squares, fixed
    keys — any estimator that fails this is not an optimizer);
  * pallas ≡ gspmd aggregation backends at the pinned 2e-5 tolerance.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import RunSpec, components, run
from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.core import (ByzVRMarinaConfig, get_aggregator, get_attack,
                        get_compressor, make_method)
from repro.core import estimators as E
from repro.core import theory
from repro.data import (corrupt_labels_logreg, init_logreg_params,
                        logreg_loss, make_logreg_data)

KEY = jax.random.PRNGKey(11)
DIM = 8
N = 5
STEPS = 5
BATCH = 8

METHODS = components("method")

# canonical per-method spec tweaks: byz_ef21 needs a contractive
# compressor, svrg's paper pairing is RFA, saga's table stays toy-sized
_METHOD_KW = {
    "byz_ef21": {"compressor": "topk",
                 "compressor_kwargs": {"ratio": 0.5}},
    "svrg": {"aggregator": "rfa"},
    "saga": {"method_kwargs": {"batch_size": 8}},
}


def _spec(method, **kw):
    base = dict(task="logreg", method=method, n_workers=N, n_byz=1, p=0.3,
                lr=0.25, attack="ALIE", aggregator="cm", bucket_size=2,
                compressor="randk", compressor_kwargs={"ratio": 0.5},
                steps=STEPS, seed=3,
                data_kwargs={"n_samples": 60, "dim": DIM,
                             "batch_size": BATCH, "data_seed": 0})
    base.update(_METHOD_KW.get(method, {}))
    base.update(kw)
    return RunSpec(**base)


def _assert_trees_equal(a, b):
    jax.tree.map(lambda x, y: np.testing.assert_array_equal(
        np.asarray(x), np.asarray(y)), a, b)


# ---------------------------------------------------------------------------
# registry coherence
# ---------------------------------------------------------------------------

def test_trait_registries_cover_every_estimator():
    """The trait maps next to ``ESTIMATORS`` must never drift: a method
    missing from ``ESTIMATOR_CLASSES`` silently runs un-batched (fail-
    closed, but slow), one missing from ``theory.BITS_FAMILY`` breaks comm
    accounting. Registering an estimator means registering its traits."""
    assert set(E.ESTIMATOR_CLASSES) == set(E.ESTIMATORS)
    assert set(theory.BITS_FAMILY) == set(E.ESTIMATORS)
    # unknown names classify as un-batchable, never as vmappable
    assert E.seed_batchable("not-a-method") is False
    # drivers map keep-ratios onto compressor kinds through this one trait
    assert E.needs_contractive_compressor("byz_ef21") is True
    assert E.needs_contractive_compressor("marina") is False
    assert E.needs_contractive_compressor("not-a-method") is False


# ---------------------------------------------------------------------------
# checkpoint / resume round-trip
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", METHODS)
def test_state_checkpoints_and_resumes_bit_for_bit(method, tmp_path):
    """The FULL engine state (params + g + every estimator extra) must
    survive ``repro.checkpoint`` exactly, and an interrupted-and-resumed
    ``api.runner`` run must reproduce the uninterrupted trajectory."""
    spec = _spec(method)
    full = run(spec, log_every=1)

    # 1) direct pytree round-trip, bit-for-bit over every leaf
    ck = str(tmp_path / "state")
    save_checkpoint(ck, full.state, step=int(full.state["step"]))
    restored, step = load_checkpoint(ck, like=full.state)
    assert step == STEPS
    _assert_trees_equal(full.state, restored)

    # 2) interrupted at step 2, resumed through the runner
    ck2 = str(tmp_path / "resume")
    run(spec.replace(steps=2), log_every=1, checkpoint=ck2)
    resumed = run(spec, log_every=1, resume=ck2)
    _assert_trees_equal(full.state, resumed.state)
    tail = [h["loss"] for h in full.history[2:]]
    np.testing.assert_array_equal(
        np.asarray(tail, np.float32),
        np.asarray([h["loss"] for h in resumed.history], np.float32))


# ---------------------------------------------------------------------------
# run(spec) ≡ hand-wired engine
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", METHODS)
def test_run_spec_matches_hand_wired_engine(method):
    spec = _spec(method)
    result = run(spec, log_every=1)

    data = make_logreg_data(
        jax.random.PRNGKey(spec.data_kwargs["data_seed"]),
        n_samples=spec.data_kwargs["n_samples"], dim=DIM, n_workers=N,
        homogeneous=True)
    loss = logreg_loss(0.01)
    m = make_method(spec.method, spec.build_config(), loss,
                    corrupt_labels_logreg, **spec.method_kwargs)
    anchor = data.stacked()
    _, k_run = jax.random.split(jax.random.PRNGKey(spec.seed))
    state = m.init(init_logreg_params(DIM), anchor, k_run)
    step = jax.jit(m.step)
    losses = []
    for it in range(spec.steps):
        k_step, k_batch = jax.random.split(jax.random.fold_in(k_run, it + 1))
        state, met = step(state, data.sample_batches(k_batch, BATCH),
                          anchor, k_step)
        losses.append(np.asarray(met["loss"]))
    _assert_trees_equal(state["params"], result.params)
    _assert_trees_equal(state["g"], result.state["g"])
    np.testing.assert_array_equal(
        np.asarray(losses, np.float32),
        np.asarray([h["loss"] for h in result.history], np.float32))


# ---------------------------------------------------------------------------
# communication accounting ≡ theory
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", METHODS)
def test_comm_accounting_matches_theory(method):
    spec = _spec(method)
    cfg = spec.build_config()
    est = E.get_estimator(spec.method, cfg, **spec.method_kwargs)
    for d in (64, 937):
        expected = est.expected_bits(cfg, d)
        assert expected == pytest.approx(
            theory.comm_bits_per_round(method, cfg.compressor, d, p=cfg.p))
        # the p-mixture identity between per-round and expected accounting
        mix = (cfg.p * est.round_bits(cfg, d, True)
               + (1 - cfg.p) * est.round_bits(cfg, d, False))
        assert expected == pytest.approx(mix)
        assert est.round_bits(cfg, d, True) > 0


@pytest.mark.parametrize("method", ["marina", "sgd", "byz_ef21"])
def test_comm_accounting_under_partial_participation(method):
    """Partial participation bills the wire for the SAMPLED cohort only:
    measured bits are exactly participation-scaled — the c_k coin stream is
    participation-independent (its own fold_in tag), so the full- and
    partial-participation runs share a coin trajectory and the ratio is
    exact, matching ``theory.comm_bits_per_round(..., participation=)``."""
    part = 3
    full = run(_spec(method), log_every=1)
    sampled = run(_spec(method, participation=part), log_every=1)
    assert sampled.comm_bits == pytest.approx(full.comm_bits * part / N,
                                              rel=1e-12)
    # theory twin scales identically
    spec = _spec(method, participation=part)
    cfg = spec.build_config()
    d = full.n_params
    assert theory.comm_bits_per_round(
        method, cfg.compressor, d, p=cfg.p, participation=part / N) == \
        pytest.approx(part / N * theory.comm_bits_per_round(
            method, cfg.compressor, d, p=cfg.p))


# ---------------------------------------------------------------------------
# descent on the deterministic quadratic
# ---------------------------------------------------------------------------

def _quadratic_problem():
    """Full-batch least squares: loss is an exact quadratic in w, the data
    is fixed, and the batch IS the anchor — the only randomness left is the
    estimators' own (key-deterministic) coins/compressors."""
    kx, kw = jax.random.split(jax.random.PRNGKey(5))
    x = jax.random.normal(kx, (N, 12, 6)) / jnp.sqrt(6.0)
    w_true = jax.random.normal(kw, (6,))
    y = x @ w_true
    anchor = {"x": x, "y": y}

    def qloss(params, batch, key=None):
        r = batch["x"] @ params["w"] - batch["y"]
        return 0.5 * jnp.mean(r * r) + 0.005 * jnp.sum(params["w"] ** 2)

    return anchor, qloss, {"w": jnp.zeros((6,), jnp.float32)}


@pytest.mark.parametrize("method", METHODS)
def test_descends_on_deterministic_quadratic(method):
    anchor, qloss, params0 = _quadratic_problem()
    spec = _spec(method)              # reuse the canonical component picks
    comp = get_compressor(spec.compressor, **spec.compressor_kwargs)
    cfg = ByzVRMarinaConfig(
        n_workers=N, n_byz=1, p=0.3, lr=0.3,
        aggregator=get_aggregator(spec.aggregator, bucket_size=2),
        compressor=comp, attack=get_attack("NA"))
    m = make_method(method, cfg, qloss, **spec.method_kwargs)
    state = m.init(params0, anchor, KEY)
    step = jax.jit(m.step)
    l0 = float(qloss(state["params"], {"x": anchor["x"].reshape(-1, 6),
                                       "y": anchor["y"].reshape(-1)}))
    k = KEY
    for _ in range(80):
        k, k_step = jax.random.split(k)
        state, met = step(state, anchor, anchor, k_step)
        assert bool(jnp.isfinite(met["loss"])), method
    l1 = float(qloss(state["params"], {"x": anchor["x"].reshape(-1, 6),
                                       "y": anchor["y"].reshape(-1)}))
    assert l1 < 0.5 * l0, (method, l0, l1)


# ---------------------------------------------------------------------------
# pallas ≡ gspmd
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("method", METHODS)
def test_pallas_backend_matches_gspmd(method):
    """Every estimator must run under the fused Pallas message phase and
    stay on the gspmd trajectory at the pinned tolerance (DESIGN.md §3:
    the kernel path reassociates fp32 sums, so 2e-5, not bit-equal)."""
    results = {}
    for mode in ("gspmd", "pallas"):
        results[mode] = run(_spec(method, agg_mode=mode), log_every=1)
    for h_g, h_p in zip(results["gspmd"].history,
                        results["pallas"].history):
        # identical metric keys AND values (wall_s is wall-clock, exempt):
        # the wire path must not fork the logged trajectory shape
        assert set(h_g) == set(h_p)
        for k in set(h_g) - {"wall_s"}:
            np.testing.assert_allclose(h_g[k], h_p[k],
                                       atol=2e-5, rtol=2e-5, err_msg=k)
    jax.tree.map(lambda a, b: np.testing.assert_allclose(
        np.asarray(a), np.asarray(b), atol=2e-5, rtol=2e-5),
        results["gspmd"].params, results["pallas"].params)


# ---------------------------------------------------------------------------
# wire conformance: measured payload == theory billing
# ---------------------------------------------------------------------------

# every method that puts a compressed payload on the wire (non-"dense"
# BITS_FAMILY) must log a per-round wire_bits metric and route through
# core.wire under pallas
WIRE_METHODS = sorted(m for m in METHODS
                      if theory.BITS_FAMILY[m] != "dense")


@pytest.mark.parametrize("method", WIRE_METHODS)
def test_wire_bytes_match_theory(method):
    """The measured per-round wire payload (wire_bits / 8 bytes, read off
    the packed arrays the pallas kernels consume) must equal
    ``theory.comm_bits_per_round(..., dims=...) / 8`` — the tree-boundary
    accounting the paper's Fig. 8 bills for. MARINA's per-round value is
    one of the two coin branches; its expectation is the theory number."""
    spec = _spec(method, agg_mode="pallas")
    res = run(spec, log_every=1)
    cfg = spec.build_config()
    dims = [int(np.prod(l.shape)) for l in jax.tree.leaves(res.params)]
    want_bits = theory.comm_bits_per_round(method, cfg.compressor, 0,
                                           p=cfg.p, dims=dims)
    wb = [float(h["wire_bits"]) for h in res.history]
    assert len(wb) == STEPS
    if theory.BITS_FAMILY[method] == "vr_switch":
        dense = 32.0 * sum(dims)
        bits_q = float(cfg.compressor.tree_bits(dims))
        for b in wb:
            assert (b == pytest.approx(dense)
                    or b == pytest.approx(bits_q)), b
        assert want_bits == pytest.approx(
            cfg.p * dense + (1 - cfg.p) * bits_q)
    else:
        for b in wb:
            assert b / 8.0 == pytest.approx(want_bits / 8.0)
