"""Top-level model API: init / forward / loss / decode, plus sharding specs.

Batch dict convention
---------------------
    tokens   (B, S) int32              — or (B, S, K) for codebook (audio) archs
    labels   (B, S[, K]) int32         — -1 marks masked positions
    frontend (B, F, d_model) float     — stubbed modality embeddings (vlm/audio)
    positions optional (B, S) or (B, S, 3) for M-RoPE

For frontend archs the *total* sequence is F + S_text; ``input_specs`` keeps
seq_len = F + S_text so the assigned shapes are respected end to end.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, ATTN, SWA, MLA, RGLRU, MAMBA2
from repro.models import layers as L
from repro.models import transformer as T


# ---------------------------------------------------------------------------
# init
# ---------------------------------------------------------------------------

def init_params(key, cfg: ArchConfig):
    k_embed, k_stack, k_out = jax.random.split(key, 3)
    d, v, kk = cfg.d_model, cfg.vocab_size, cfg.num_codebooks
    embed_shape = (v, d) if kk == 1 else (kk, v, d)
    params = {
        "embed": (jax.random.normal(k_embed, embed_shape) * 0.02
                  ).astype(cfg.jnp_dtype),
        "final_norm": jnp.zeros((d,), cfg.jnp_dtype),
        **T.init_stack(k_stack, cfg),
    }
    if not cfg.tie_embeddings:
        un_shape = (d, v) if kk == 1 else (kk, d, v)
        params["unembed"] = (jax.random.normal(k_out, un_shape) *
                             (1.0 / d ** 0.5)).astype(cfg.jnp_dtype)
    return params


# ---------------------------------------------------------------------------
# embedding / head
# ---------------------------------------------------------------------------

def _embed(params, cfg: ArchConfig, tokens):
    if cfg.num_codebooks == 1:
        return params["embed"][tokens]
    # (B,S,K) -> sum_k embed[k][tok]
    outs = [params["embed"][k][tokens[..., k]]
            for k in range(cfg.num_codebooks)]
    return sum(outs)


def _logits(params, cfg: ArchConfig, x):
    if cfg.tie_embeddings:
        table = params["embed"]
        if cfg.num_codebooks == 1:
            return jnp.einsum("bsd,vd->bsv", x, table)
        return jnp.einsum("bsd,kvd->bskv", x, table)
    if cfg.num_codebooks == 1:
        return jnp.einsum("bsd,dv->bsv", x, params["unembed"])
    return jnp.einsum("bsd,kdv->bskv", x, params["unembed"])


def _positions(cfg: ArchConfig, batch, total_len):
    pos = batch.get("positions")
    if pos is not None:
        return pos
    b = batch["tokens"].shape[0]
    base = jnp.broadcast_to(jnp.arange(total_len, dtype=jnp.int32),
                            (b, total_len))
    if cfg.mrope_sections is not None:
        # text default: t = h = w = index (reduces to plain RoPE)
        return jnp.broadcast_to(base[..., None], (b, total_len, 3))
    return base


# ---------------------------------------------------------------------------
# forward / loss
# ---------------------------------------------------------------------------

def hidden(params, cfg: ArchConfig, batch, *, remat: bool = False):
    """Final hidden states on token positions: (B, S_text, d), aux loss."""
    tokens = batch["tokens"]
    x = _embed(params, cfg, tokens)
    n_front = 0
    if cfg.frontend_tokens and "frontend" in batch:
        fe = batch["frontend"].astype(x.dtype)
        n_front = fe.shape[1]
        x = jnp.concatenate([fe, x], axis=1)
    positions = _positions(cfg, batch, x.shape[1])
    x, aux = T.apply_stack(params, cfg, x, positions, remat=remat)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    if n_front:
        x = x[:, n_front:, :]
    return x, aux


def forward(params, cfg: ArchConfig, batch, *, remat: bool = False):
    """Returns (logits_on_token_positions, aux_loss)."""
    x, aux = hidden(params, cfg, batch, remat=remat)
    return _logits(params, cfg, x), aux


def _chunk_nll(params, cfg: ArchConfig, xc, labels_c):
    """xc: (B, C, d), labels_c: (B, C[, K]). Returns (nll_sum, mask_sum)."""
    logits = _logits(params, cfg, xc)
    mask = (labels_c >= 0).astype(jnp.float32)
    safe = jnp.maximum(labels_c, 0)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    nll = -jnp.take_along_axis(logp, safe[..., None], axis=-1)[..., 0]
    return jnp.sum(nll * mask), jnp.sum(mask)


def loss_fn(params, cfg: ArchConfig, batch, *, remat: bool = False,
            xent_chunk: int = 1024):
    """Sequence-chunked cross entropy: the (B, S, V) logits tensor is never
    materialized (269 TB for llama3-405b @ train_4k); each (B, C, V) chunk is
    computed, reduced, and rematerialized in the backward pass."""
    x, aux = hidden(params, cfg, batch, remat=remat)
    labels = batch["labels"]
    b, s = x.shape[0], x.shape[1]
    if s <= xent_chunk or s % xent_chunk:
        nll, msk = _chunk_nll(params, cfg, x, labels)
    else:
        nc = s // xent_chunk
        xc = x.reshape((b, nc, xent_chunk) + x.shape[2:])
        lc = labels.reshape((b, nc, xent_chunk) + labels.shape[2:])

        def body(carry, inp):
            xi, li = inp
            n, m = jax.checkpoint(
                lambda a, l: _chunk_nll(params, cfg, a, l))(xi, li)
            return (carry[0] + n, carry[1] + m), None

        (nll, msk), _ = jax.lax.scan(
            body, (jnp.zeros(()), jnp.zeros(())),
            (jnp.moveaxis(xc, 1, 0), jnp.moveaxis(lc, 1, 0)),
            unroll=L._unroll(nc))
    loss = nll / jnp.clip(msk, 1.0)
    return loss + aux.astype(jnp.float32)


def model_logits_last(params, cfg: ArchConfig, x):
    """Last-position logits only (prefill output): avoids (B, S, V)."""
    return _logits(params, cfg, x[:, -1:, :])[:, 0]


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def init_cache(cfg: ArchConfig, batch, capacity):
    return T.init_stack_cache(cfg, batch, capacity)


def decode_step(params, cfg: ArchConfig, cache, tokens):
    """One decode step. tokens: (B,) int32 or (B, K) for codebook archs.
    Returns (logits (B, V[, K...]), new_cache)."""
    tok = tokens[:, None] if cfg.num_codebooks == 1 else tokens[:, None, :]
    x = _embed(params, cfg, tok)
    x, cache = T.decode_stack(params, cfg, x, cache)
    x = L.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _logits(params, cfg, x)
    return logits[:, 0], cache


# ---------------------------------------------------------------------------
# sharding specs (model axis = tensor/expert parallel; see DESIGN.md §3)
# ---------------------------------------------------------------------------

def _block_specs(cfg: ArchConfig, kind: str, axis: str):
    a = axis
    sp = {"norm1": P()}
    if kind in (ATTN, SWA):
        mixer = {"wq": P(None, a), "wk": P(None, a), "wv": P(None, a),
                 "wo": P(a, None)}
        if cfg.qk_norm:
            mixer["q_norm"] = P()
            mixer["k_norm"] = P()
    elif kind == MLA:
        mixer = {"wq": P(None, a), "w_dkv": P(None, None), "w_uk": P(None, a),
                 "w_uv": P(None, a), "w_kr": P(None, None), "wo": P(a, None),
                 "kv_norm": P()}
    elif kind == RGLRU:
        mixer = {"w_gate_branch": P(None, a), "w_rec_branch": P(None, a),
                 "conv_w": P(None, a), "w_a": P(None, a), "b_a": P(a),
                 "w_i": P(None, a), "b_i": P(a), "lam": P(a),
                 "w_out": P(a, None)}
    elif kind == MAMBA2:
        mixer = {"w_in": P(None, None), "conv_w": P(None, None),
                 "a_log": P(), "dt_bias": P(), "d_skip": P(),
                 "out_norm": P(), "w_out": P(None, None)}
    else:
        raise ValueError(kind)
    sp["mixer"] = mixer
    if kind != MAMBA2:
        sp["norm2"] = P()
        if cfg.moe is not None:
            ffn = {"router": P(None, None), "w1": P(a, None, None),
                   "w3": P(a, None, None), "w2": P(a, None, None)}
            if cfg.moe.num_shared:
                ffn["shared"] = {"w1": P(None, a), "w3": P(None, a),
                                 "w2": P(a, None)}
            sp["ffn"] = ffn
        else:
            sp["ffn"] = {"w1": P(None, a), "w3": P(None, a), "w2": P(a, None)}
    return sp


def _prepend(spec_tree, extra):
    return jax.tree.map(lambda s: P(*((extra,) + tuple(s))), spec_tree,
                        is_leaf=lambda s: isinstance(s, P))


def param_specs(cfg: ArchConfig, axis: str = "model"):
    """PartitionSpec pytree matching ``init_params`` output."""
    pat, n_groups, tail = T._split_depth(cfg)
    kk = cfg.num_codebooks
    specs = {
        "embed": P(axis, None) if kk == 1 else P(None, axis, None),
        "final_norm": P(),
        "groups": tuple(_prepend(_block_specs(cfg, kind, axis), None)
                        for kind in pat),
        "tail": tuple(_block_specs(cfg, kind, axis) for kind in tail),
    }
    if not cfg.tie_embeddings:
        specs["unembed"] = P(None, axis) if kk == 1 else P(None, None, axis)
    return specs


def _cache_leaf_spec(path_leaf_shape_ndim, axis_data, axis_model):
    raise NotImplementedError


def cache_specs(cfg: ArchConfig, data_axis, model_axis="model"):
    """Shard caches: batch dim over data axis; head/width dims over model."""
    def leaf_spec(kind):
        if kind in (ATTN, SWA):
            return {"k": P(data_axis, None, None, None),
                    "v": P(data_axis, None, None, None),
                    "pos": P(data_axis, None), "len": P()}
        if kind == MLA:
            return {"c_kv": P(data_axis, None, None),
                    "k_rope": P(data_axis, None, None),
                    "pos": P(data_axis, None), "len": P()}
        if kind == RGLRU:
            return {"h": P(data_axis, model_axis),
                    "conv": P(data_axis, None, model_axis), "len": P()}
        if kind == MAMBA2:
            return {"h": P(data_axis, None, None, None),
                    "conv": P(data_axis, None, None), "len": P()}
        raise ValueError(kind)

    pat, n_groups, tail = T._split_depth(cfg)
    return {
        "groups": tuple(_prepend(leaf_spec(kind), None) for kind in pat),
        "tail": tuple(leaf_spec(kind) for kind in tail),
    }
