"""Byzantine attacks (Section 3 of the paper).

Each attack maps the would-be-honest update of a Byzantine worker (and
omniscient statistics of the good workers' updates) to the malicious vector
it actually sends:

    attack(key, honest, good_mean, good_std) -> sent

* NA  — no attack (clean training).
* LF  — label flipping: implemented at the DATA level (data/synthetic.py
        flips labels for byzantine workers); the update hook is identity.
* BF  — bit flipping: send -honest.
* ALIE — "A Little Is Enough" (Baruch et al. 2019): send mean - z*std.
* IPM — inner-product manipulation (Xie et al. 2020): send -(eps)*mean.
* RN  — random gaussian noise (extra, used in tests).

good_mean/good_std are the coordinate-wise mean/std over the good workers'
updates — the standard omniscient-adversary model. In the distributed trainer
these are computed with masked psums over the worker mesh axis.

Deterministic per-coordinate attacks (BF/ALIE/IPM) additionally carry a
``coord_apply(x2d, mean_row, std_row) -> attacked2d`` form — a pure
elementwise/broadcast function over a (n, TILE_D) block — so the pallas
aggregation backend can inject the attack inside the kernel's VMEM load and
never write the attacked (n, d) ``sent`` tensor to HBM (DESIGN.md §3). RN
stays kernel-unfusable (it needs the exact jax.random normal stream).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class CoordAttack:
    """Kernel-fusable attack form: (x (n, t), mean (1, t) | None,
    std (1, t) | None) -> attacked (n, t), pure elementwise/broadcast.

    A frozen dataclass (hash/eq by (kind, param)) rather than a closure on
    purpose: it rides as a STATIC jit argument through the Pallas kernel
    wrappers, so two configs built from the same logical attack hit the
    same compiled kernels instead of re-tracing per ``get_attack()`` call
    (and pinning every dead closure in the jit caches).
    """
    kind: str                       # BF | ALIE | IPM
    param: float = 0.0              # ALIE z / IPM eps

    def __call__(self, x, m, s):
        if self.kind == "BF":
            return -x
        if self.kind == "ALIE":
            return jnp.broadcast_to(m - self.param * s, x.shape)
        if self.kind == "IPM":
            return jnp.broadcast_to(-self.param * m, x.shape)
        raise ValueError(self.kind)


@dataclasses.dataclass(frozen=True)
class Attack:
    name: str
    apply: Callable                 # (key, honest, good_mean, good_std) -> v
    flips_labels: bool = False
    # kernel-fusable form; None = attack must materialize via ``apply``.
    coord_apply: Optional[CoordAttack] = None
    needs_mean: bool = False        # which omniscient stats coord_apply reads
    needs_std: bool = False


def no_attack() -> Attack:
    return Attack("NA", lambda key, h, m, s: h)


def label_flip() -> Attack:
    # the data pipeline flips the byzantine workers' labels; update untouched
    return Attack("LF", lambda key, h, m, s: h, flips_labels=True)


def bit_flip() -> Attack:
    return Attack("BF", lambda key, h, m, s: -h,
                  coord_apply=CoordAttack("BF"))


def alie(z: float = 1.06) -> Attack:
    """mu_G - z * sigma_G: hides just outside the honest cluster."""
    def apply(key, h, m, s):
        return jnp.broadcast_to((m - z * s).astype(h.dtype), h.shape)

    return Attack("ALIE", apply, coord_apply=CoordAttack("ALIE", z),
                  needs_mean=True, needs_std=True)


def ipm(eps: float = 0.1) -> Attack:
    """-(eps) * mean of good updates: flips the aggregate's inner product."""
    def apply(key, h, m, s):
        return jnp.broadcast_to((-eps * m).astype(h.dtype), h.shape)

    return Attack("IPM", apply, coord_apply=CoordAttack("IPM", eps),
                  needs_mean=True)


def random_noise(scale: float = 10.0) -> Attack:
    def apply(key, h, m, s):
        return scale * jax.random.normal(key, h.shape, h.dtype)
    return Attack("RN", apply)


REGISTRY = {
    "NA": no_attack,
    "LF": label_flip,
    "BF": bit_flip,
    "ALIE": alie,
    "IPM": ipm,
    "RN": random_noise,
}


def get_attack(name: str, **kw) -> Attack:
    return REGISTRY[name](**kw)
