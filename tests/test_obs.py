"""Observability invariants (repro.obs, DESIGN.md §5).

The load-bearing guarantees:

* the telemetry twin is FREE on the trajectory: trace=True finishes with
  bit-identical engine state to trace=False on every backend x rule cell
  (the traced aggregate runs through the identical backend calls);
* the OFF path is untouched: the untraced step's jaxpr is canonically
  identical whether or not the spec enables tracing, and the traced twin
  is a strict superset (its diagnostics only ADD equations);
* rule intermediates are faithful: Krum's recorded selection/scores and
  RFA's Weiszfeld weights reproduce the Aggregator oracle, and
  ``influence`` actually reconstructs the aggregate (infl @ sent == agg);
* detection metrics and the MetricSink protocol behave per contract,
  including the fail-closed JSONL verification CI gates on.
"""
import json
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import RunSpec, build
from repro.core import get_aggregator
from repro.core.byz_vr_marina import ByzVRMarinaConfig
from repro.obs import detect
from repro.obs import trace as obs_trace
from repro.obs.sink import (FanoutSink, JsonlSink, RingSink, TagSink, span,
                            verify_jsonl)
from tests._jaxpr_scan import iter_eqns

KEY = jax.random.PRNGKey(0)


def _spec(agg_mode, rule, *, method="marina", attack="ALIE", trace=False):
    return RunSpec(task="logreg", method=method, n_workers=8, n_byz=2,
                   attack=attack, aggregator=rule,
                   bucket_size=2 if rule != "mean" else 0,
                   agg_mode=agg_mode, steps=6, seed=3, trace=trace,
                   data_kwargs={"dim": 12, "n_samples": 64,
                                "batch_size": 8})


def _run_steps(exp, traced, steps=6):
    """The runner's exact key schedule, returning (state, traces)."""
    k_init, k_run = jax.random.split(jax.random.PRNGKey(exp.spec.seed))
    params = exp.init_params(k_init)
    state = exp.method.init(params, exp.anchor(0), k_run)
    fn = jax.jit(exp.method.step_traced if traced else exp.method.step)
    traces = []
    for it in range(steps):
        k_step, k_batch = jax.random.split(
            jax.random.fold_in(k_run, it + 1))
        state, metrics = fn(state, exp.minibatch(it, k_batch),
                            exp.anchor(it), k_step)
        traces.append(metrics.pop("trace", None))
    return state, traces


# ---------------------------------------------------------------------------
# bit-identity: the telemetry twin never perturbs the trajectory
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("agg_mode", ["gspmd", "pallas"])
@pytest.mark.parametrize("rule", ["mean", "cm", "rfa", "krum"])
def test_traced_trajectory_bit_identical(agg_mode, rule):
    exp = build(_spec(agg_mode, rule))
    s_off, _ = _run_steps(exp, traced=False)
    s_on, traces = _run_steps(exp, traced=True)
    for a, b in zip(jax.tree.leaves(s_off), jax.tree.leaves(s_on)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # every round produced a populated, finite trace
    for rt in traces:
        assert rt is not None and rt.rule == rule
        infl = np.asarray(rt.influence)
        assert infl.shape == (8,) and np.isfinite(infl).all()
        assert abs(infl.sum() - 1.0) < 1e-4
        assert np.isfinite(np.asarray(rt.dist_to_agg)).all()
        assert np.asarray(rt.byz_mask).sum() == 2
        if rule == "krum":
            assert int(rt.krum_selected) >= 0
        if rule == "rfa":
            assert float(rt.rfa_residual) >= 0.0


# ---------------------------------------------------------------------------
# OFF path untouched: jaxpr pin
# ---------------------------------------------------------------------------

def _canon_eqns(fn, args):
    """Canonical (primitive, in-avals, out-avals) sequence — stable across
    processes, unlike str(jaxpr) var names (see tests/_jaxpr_scan.py)."""
    closed = jax.make_jaxpr(fn)(*args)
    return [(e.primitive.name,
             tuple(str(v.aval) for v in e.invars),
             tuple(str(v.aval) for v in e.outvars))
            for e in iter_eqns(closed.jaxpr)]


@pytest.mark.parametrize("agg_mode", ["gspmd", "pallas"])
def test_off_path_jaxpr_unchanged_by_trace_flag(agg_mode):
    exp_off = build(_spec(agg_mode, "krum", trace=False))
    exp_on = build(_spec(agg_mode, "krum", trace=True))
    k_init, k_run = jax.random.split(jax.random.PRNGKey(3))
    params = exp_off.init_params(k_init)
    state = exp_off.method.init(params, exp_off.anchor(0), k_run)
    k_step, k_batch = jax.random.split(jax.random.fold_in(k_run, 1))
    args = (state, exp_off.minibatch(0, k_batch), exp_off.anchor(0), k_step)
    base = _canon_eqns(exp_off.method.step, args)
    # enabling spec.trace must not change the untraced step's jaxpr
    assert _canon_eqns(exp_on.method.step, args) == base
    # ... and the telemetry twin only ADDS equations
    assert len(_canon_eqns(exp_on.method.step_traced, args)) > len(base)


# ---------------------------------------------------------------------------
# rule intermediates vs the Aggregator oracle
# ---------------------------------------------------------------------------

def _cand(n=8, d=6):
    kw, kb = jax.random.split(KEY)
    return {"w": jax.random.normal(kw, (n, d), jnp.float32),
            "b": jax.random.normal(kb, (n,), jnp.float32)}


def _flat(tree, n=None):
    leaves = jax.tree.leaves(tree)
    if n is None:                        # single vector
        return np.concatenate([np.asarray(a, np.float64).ravel()
                               for a in leaves])
    return np.concatenate([np.asarray(a, np.float64).reshape(n, -1)
                           for a in leaves], axis=1)


@pytest.mark.parametrize("agg_mode", ["gspmd", "pallas"])
def test_krum_trace_matches_oracle(agg_mode):
    cfg = ByzVRMarinaConfig(n_workers=8,
                            aggregator=get_aggregator("krum"),
                            agg_mode=agg_mode)
    cand = _cand()
    k_att, k_agg = jax.random.split(KEY)
    agg, rt = obs_trace.traced_ingest_message_phase(cfg, k_att, k_agg, cand)
    sel = int(rt.krum_selected)
    scores = np.asarray(rt.krum_scores)
    assert sel == int(np.argmin(scores))
    # bucketless Krum returns a row verbatim; the one-hot says which
    np.testing.assert_allclose(np.asarray(rt.bucket_weights),
                               np.eye(8)[sel], atol=1e-6)
    np.testing.assert_allclose(_flat(agg), _flat(cand, 8)[sel], atol=1e-5)
    # the untraced rule agrees with the traced twin's aggregate
    oracle = cfg.aggregator.tree(k_agg, cand)
    np.testing.assert_allclose(_flat(agg), _flat(oracle), atol=1e-6)


@pytest.mark.parametrize("agg_mode", ["gspmd", "pallas"])
def test_rfa_trace_matches_oracle(agg_mode):
    cfg = ByzVRMarinaConfig(n_workers=8,
                            aggregator=get_aggregator("rfa", bucket_size=2),
                            agg_mode=agg_mode)
    cand = _cand()
    k_att, k_agg = jax.random.split(KEY)
    agg, rt = obs_trace.traced_ingest_message_phase(cfg, k_att, k_agg, cand)
    w = np.asarray(rt.rfa_weights)
    assert w.shape == (4,) and (w >= 0).all()
    np.testing.assert_allclose(w.sum(), 1.0, atol=1e-5)
    np.testing.assert_array_equal(w, np.asarray(rt.bucket_weights))
    assert float(rt.rfa_residual) >= 0.0
    # influence reconstructs the aggregate: agg == infl @ sent
    infl = np.asarray(rt.influence, np.float64)
    np.testing.assert_allclose(infl @ _flat(cand, 8), _flat(agg),
                               atol=2e-5)
    oracle = cfg.aggregator.tree(k_agg, cand)
    np.testing.assert_allclose(_flat(agg), _flat(oracle), atol=1e-6)


def test_trace_rejects_unsupported_backends():
    with pytest.raises(ValueError, match="all_to_all"):
        RunSpec(trace=True, agg_mode="all_to_all")
    with pytest.raises(ValueError, match="sparse_support"):
        RunSpec(trace=True, agg_mode="sparse_support")


# ---------------------------------------------------------------------------
# detection metrics
# ---------------------------------------------------------------------------

def test_detection_metrics_handbuilt():
    t = {"influence": [0.0, 0.05, 0.475, 0.475],
         "byz_mask": [True, True, False, False]}
    m = detect.detection_metrics(t)          # threshold = 0.5/4 = 0.125
    assert m["n_filtered"] == 2
    assert m["precision"] == 1.0 and m["recall"] == 1.0
    assert abs(m["byz_leakage"] - 0.05) < 1e-12

    # false accusation: an honest worker below threshold
    t2 = {"influence": [0.3, 0.05, 0.35, 0.3],
          "byz_mask": [True, False, False, False]}
    m2 = detect.detection_metrics(t2)
    assert m2["n_filtered"] == 1
    assert m2["precision"] == 0.0 and m2["recall"] == 0.0
    assert abs(m2["byz_leakage"] - 0.3) < 1e-12

    # empty-denominator conventions
    clean = detect.detection_metrics(
        {"influence": [0.5, 0.5], "byz_mask": [False, False]})
    assert clean["precision"] == 1.0 and clean["recall"] == 1.0
    assert clean["byz_leakage"] == 0.0

    s = detect.summarize([t, t2])
    assert s["rounds"] == 2
    assert abs(s["precision"] - 0.5) < 1e-12
    assert detect.summarize([]) == {}


# ---------------------------------------------------------------------------
# sinks
# ---------------------------------------------------------------------------

def test_sink_protocol(tmp_path):
    ring = RingSink(capacity=4)
    for i in range(6):
        ring.emit({"type": "counter", "name": "c", "value": i})
    assert len(ring.events) == 4                     # ring evicts oldest
    assert [e["value"] for e in ring.by_name("c")] == [2, 3, 4, 5]

    tagged = RingSink()
    TagSink(tagged, run_id="cell-7").emit({"type": "gauge", "name": "g",
                                           "value": 1.0})
    assert tagged.events[0]["run_id"] == "cell-7"

    path = str(tmp_path / "m.jsonl")
    jl = JsonlSink(path)
    fan = FanoutSink(jl, ring)
    with span(fan, "work", round=0):
        pass
    fan.close()
    assert ring.by_type("span")[0]["name"] == "work"
    assert "wall_s" in json.loads(open(path).read().splitlines()[-1])


def test_verify_jsonl_fail_closed(tmp_path):
    ok = tmp_path / "ok.jsonl"
    ok.write_text(json.dumps({"type": "round", "loss": 0.5}) + "\n"
                  + json.dumps({"type": "trace", "influence": [0.5]}) + "\n")
    counts = verify_jsonl(str(ok))
    assert counts == {"round": 1, "trace": 1}

    nan = tmp_path / "nan.jsonl"
    nan.write_text(json.dumps({"type": "trace",
                               "influence": [0.5, float("nan")]}) + "\n")
    with pytest.raises(ValueError, match="non-finite"):
        verify_jsonl(str(nan))

    empty = tmp_path / "empty.jsonl"
    empty.write_text("")
    with pytest.raises(ValueError, match="empty"):
        verify_jsonl(str(empty))


# ---------------------------------------------------------------------------
# runner integration: sink events + detection summary
# ---------------------------------------------------------------------------

def test_runner_emits_rounds_traces_and_detection(tmp_path):
    ring = RingSink()
    path = str(tmp_path / "run.jsonl")
    res = build(_spec("gspmd", "krum", trace=True)).run(
        log_every=2, sink=ring, metrics_jsonl=path)
    rounds = ring.by_type("round")
    assert rounds and all("detect_precision" in e for e in rounds)
    tr = ring.by_type("trace")
    assert len(tr) == len(res.traces) > 0
    assert all(len(e["influence"]) == 8 for e in tr)
    assert ring.by_name("run")[0]["type"] == "span"
    det = res.detection_summary()
    assert det["rounds"] == len(res.traces)
    assert res.to_dict()["detection"] == det
    assert all(math.isfinite(v) for v in
               (det["precision"], det["recall"], det["byz_leakage"]))
    # the JSONL fan-out carries the same stream and passes the CI gate
    counts = verify_jsonl(path)
    assert counts["round"] == len(rounds) and counts["trace"] == len(tr)
