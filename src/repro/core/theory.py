"""The paper's theory, executable: step sizes, convergence constants, and
communication/oracle complexity bounds (Thm. 2.1/2.2, Cor. E.1–E.7).

This closes the loop between analysis and practice: examples and benchmarks
can ask for the *theory-prescribed* γ = 1/(L+√A) instead of hand-tuning,
and the complexity calculator reproduces Table 2's regimes.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

import jax.numpy as jnp


# (δ_max, c) certified by Theorem D.1 for each rule ∘ bucketing
AGG_CONSTANTS = {
    "krum": {"delta_max": 0.25, "c": 6.0},
    "rfa": {"delta_max": 0.5, "c": 6.0},
    "cm": {"delta_max": 0.5, "c": None},   # c = O(d): filled per-problem
    "tm": {"delta_max": 0.5, "c": 6.0},    # trimmed mean ~ CM-class
    "mean": {"delta_max": 0.0, "c": 0.0},
}


def delta_over_active_set(n_active: int, n_byz_active: int, *,
                          bucket_size: int = 1) -> float:
    """Effective Byzantine fraction δ over the ACTIVE cohort.

    The (δ,c)-robustness guarantees are stated over whatever set the
    aggregator actually sees — the sampled participants of a partial-
    participation round, serve's buffered subset, or the guard's valid
    subset — NOT the configured worker set (BROADCAST, Zhu & Ling 2021,
    analyzes exactly this: δ over the per-round active set with possibly
    time-varying Byzantine membership). Every δ-budget check in spec,
    serve, and the fault layer goes through this single helper so the
    three bookkeepings cannot drift.

    Bucketing with size s multiplies the adversarial fraction by s (one
    Byzantine member poisons its whole bucket, Karimireddy et al. 2022),
    so the bucketed budget is δ·s. ``n_byz_active`` is clamped to
    ``n_active`` (a cohort cannot contain more Byzantines than members);
    an empty cohort is fully adversarial by convention.
    """
    n_active = int(n_active)
    if n_active <= 0:
        return 1.0
    b = min(int(n_byz_active), n_active)
    return b * max(int(bucket_size), 1) / n_active


@dataclasses.dataclass(frozen=True)
class ProblemConstants:
    """Smoothness / heterogeneity constants of problem (1)."""
    L: float                  # global smoothness (As. 2.1)
    L_pm: float = 0.0         # global Hessian variance L± (As. 2.3)
    calL_pm: float = 0.0      # local Hessian variance L± (As. 2.4, batch-free)
    zeta_sq: float = 0.0      # ζ² heterogeneity (As. 2.2)
    mu: float = 0.0           # PŁ constant (As. 2.5); 0 = general non-convex
    m: int = 1                # local dataset size
    d: int = 1                # dimension


def marina_A(pc: ProblemConstants, *, p: float, b: int, G: int,
             delta: float, c: float, omega: float) -> float:
    """The A constant of Thm. 2.1/2.2 (B = 0 case):
    A = 6(1-p)/p [ (4cδ/p + 1/2G)(ω L² + (1+ω) 𝓛±²/b)
                  + (4cδ(1+ω)/p + ω/2G) L±² ]
    """
    t1 = (4 * c * delta / p + 1 / (2 * G)) * (
        omega * pc.L ** 2 + (1 + omega) * pc.calL_pm ** 2 / b)
    t2 = (4 * c * delta * (1 + omega) / p + omega / (2 * G)) * pc.L_pm ** 2
    return 6 * (1 - p) / p * (t1 + t2)


def step_size(pc: ProblemConstants, *, p: float, b: int, G: int,
              delta: float, c: float, omega: float,
              pl: bool = False) -> float:
    """γ = 1/(L+√A) (Thm 2.1) or min{1/(L+√2A), p/4μ} (Thm 2.2)."""
    A = marina_A(pc, p=p, b=b, G=G, delta=delta, c=c, omega=omega)
    if pl:
        g1 = 1.0 / (pc.L + math.sqrt(2 * A))
        if pc.mu > 0:
            return min(g1, p / (4 * pc.mu))
        return g1
    return 1.0 / (pc.L + math.sqrt(A))


def recommended_p(*, b: int, m: int, omega: float) -> float:
    """p = min{b/m, 1/(1+ω)} (footnote 3: equalizes the expected cost of
    full-gradient rounds and compressed rounds)."""
    return min(b / m, 1.0 / (1.0 + omega))


def error_floor(*, delta: float, c: float, p: float, zeta_sq: float,
                mu: Optional[float] = None) -> float:
    """The heterogeneity floor: 24cδζ²/p on E||∇f||² (Thm 2.1), or
    24cδζ²/μ(p) on f-f* under PŁ (Thm 2.2). Zero iff ζ=0 or δ=0."""
    if mu:
        return 24 * c * delta * zeta_sq / (mu * p)
    return 24 * c * delta * zeta_sq / p


def communication_rounds_nc(pc: ProblemConstants, *, eps_sq: float,
                            delta0: float, p: float, b: int, G: int,
                            delta: float, c: float, omega: float) -> float:
    """Non-convex rounds bound: 2Φ0 / (γ ε²) with Φ0 ≈ 2Δ0 (Eq. 30)."""
    gamma = step_size(pc, p=p, b=b, G=G, delta=delta, c=c, omega=omega)
    return 4 * delta0 / (gamma * eps_sq)


def communication_rounds_pl(pc: ProblemConstants, *, eps: float,
                            delta0: float, p: float, b: int, G: int,
                            delta: float, c: float, omega: float) -> float:
    """PŁ rounds bound: (1/γμ(1)) log(2Δ0/ε) (Thm 2.2, ζ=0)."""
    assert pc.mu > 0
    gamma = step_size(pc, p=p, b=b, G=G, delta=delta, c=c, omega=omega,
                      pl=True)
    return math.log(max(2 * delta0 / eps, 1.0 + 1e-9)) / (gamma * pc.mu)


# ---------------------------------------------------------------------------
# successor methods: EF21 family (biased/contractive compression)
# ---------------------------------------------------------------------------

def contractive_delta(compressor, d: int) -> Optional[float]:
    """δ_C with E||C(x) - x||² <= δ_C ||x||².

    Native for biased compressors (TopK: 1 - K/d, sign: 1 - 1/d, identity:
    0); an unbiased ω-compressor becomes contractive after 1/(1+ω) scaling
    with δ_C = ω/(1+ω) (Beznosikov et al. 2020, Lemma 1) — returned here so
    the EF21-side theory can still rank unbiased operators. None when no
    bound exists (ω = NaN and no native δ_C).
    """
    delta = compressor.contractive_delta(d)
    if delta is not None:
        return float(delta)
    omega = compressor.omega(d)
    if math.isnan(omega):
        return None
    return omega / (1.0 + omega)


def tree_contractive_delta(compressor, dims) -> Optional[float]:
    """δ_C of a compressor applied PER LEAF (``tree_utils.compress_tree``'s
    pinned boundary) to a pytree with leaf sizes ``dims``: the worst leaf,
    max_l δ_C(d_l) — summing ||C(x_l) - x_l||² ≤ δ_C(d_l) ||x_l||² over
    leaves bounds the tree error by the largest per-leaf factor, and TopK's
    per-leaf k = max(int(ratio·d_l), 1) genuinely differs across leaves
    (a scalar bias leaf has δ_C = 0; a wide weight leaf sets the bound).
    None if any leaf has no contractive bound."""
    deltas = [contractive_delta(compressor, int(d)) for d in dims]
    if any(dl is None for dl in deltas):
        return None
    return max(deltas)


def ef21_step_size(pc: ProblemConstants, *, delta_c: float,
                   byz_delta: float = 0.0, c: float = 6.0) -> float:
    """Byz-EF21 step size.

    EF21 (Richtárik et al. 2021, Thm. 1): with a δ_C-contractive compressor
    the error-feedback recursion contracts at θ = 1 - √δ_C with Young
    remainder β = δ_C/θ, giving γ = 1/(L + L̃ √(β/θ)) = 1/(L + L̃ √δ_C/θ).
    The robust-aggregation degradation of Rammal et al. 2023 (Thm. 4.1
    shape) scales the error-feedback term by (1 + √(4cδ)) for a δ-fraction
    of Byzantines under a (δ,c)-robust aggregator. δ_C = 0 (identity)
    recovers γ = 1/L regardless of δ — full-gradient descent is already
    exact, Byzantines only raise the ζ² floor.
    """
    if not 0.0 <= delta_c < 1.0:
        raise ValueError(f"delta_c={delta_c} must be in [0, 1) (contractive)")
    if delta_c == 0.0:
        return 1.0 / pc.L
    theta = 1.0 - math.sqrt(delta_c)
    l_tilde = max(pc.calL_pm, pc.L)
    ef_term = l_tilde * math.sqrt(delta_c) / theta
    ef_term *= 1.0 + math.sqrt(4.0 * c * byz_delta)
    return 1.0 / (pc.L + ef_term)


def ef21_rounds_nc(pc: ProblemConstants, *, eps_sq: float, delta0: float,
                   delta_c: float, byz_delta: float = 0.0,
                   c: float = 6.0) -> float:
    """Non-convex rounds bound for the EF21 family: 2Φ0/(γ ε²) with
    Φ0 ≈ 2Δ0 (the G^0 error-feedback term vanishes — g_i^0 = ∇f_i(x^0) is
    exact at init)."""
    gamma = ef21_step_size(pc, delta_c=delta_c, byz_delta=byz_delta, c=c)
    return 4 * delta0 / (gamma * eps_sq)


# ---------------------------------------------------------------------------
# communication cost per round (paper Fig. 8 / footnote 3, extended)
# ---------------------------------------------------------------------------

# method -> wire family. "vr_switch" = geometric coin between full 32d
# uploads and Q(·) rounds (MARINA); "compressed" = one Q(·) upload every
# round; "contractive_ef" = one C(·) upload every round — error feedback
# absorbs the compressor bias so there are NO full-gradient correction
# rounds (the EF21 error term lives in the rate, not on the wire);
# "dense" = 32d every round (tables/momenta/snapshots are worker-local).
BITS_FAMILY = {
    "marina": "vr_switch",
    "csgd": "compressed",
    "diana": "compressed",
    "cmfilter": "compressed",
    "byz_ef21": "contractive_ef",
    "sgd": "dense",
    "sgdm": "dense",
    "mvr": "dense",
    "svrg": "dense",
    "saga": "dense",
}


def comm_bits_per_round(method: str, compressor, d: int, *,
                        p: float = 1.0, dims=None,
                        participation: float = 1.0) -> float:
    """Expected uploaded bits per worker per round, the theory-side twin of
    ``GradientEstimator.expected_bits`` (pinned to it by the conformance
    harness, tests/test_estimator_contract.py).

    ``dims`` (per-leaf flat sizes) switches to the tree-boundary accounting
    — Σ_l bits_Q(d_l), what ``compress_tree``/``wire.pack_candidates``
    actually put on the wire, which differs from bits_Q(Σ_l d_l) whenever
    the per-leaf k/block counts round (``Compressor.tree_bits``). The
    wire-conformance test pins the pallas path's measured payload to this
    number. Without ``dims``, the flat-d single-vector accounting is kept.

    The original formulas here assumed unbiased compressors (every
    compressed upload costs bits_Q(d), full rounds 32d with probability p);
    the biased/contractive branch differs in kind: an EF21-family method
    never pays a full-gradient round, because the per-worker error-feedback
    state absorbs the bias instead of a p-coin correcting it.

    ``participation`` (fraction of the configured workers sampled each
    round) scales the per-configured-worker expectation: a non-sampled
    worker uploads ZERO bits that round, so the average upload per worker
    per round is participation × (per-participant bits). The runner bills
    the measured side identically (n_active/n_workers × round_bits), which
    is what the conformance harness pins.
    """
    if method not in BITS_FAMILY:
        raise KeyError(
            f"unknown method {method!r}; known: {sorted(BITS_FAMILY)}")
    family = BITS_FAMILY[method]
    if dims is not None:
        d = int(sum(int(x) for x in dims))
    dense = 32.0 * d
    if family == "dense":
        return participation * dense
    bits_q = (float(compressor.tree_bits(dims)) if dims is not None
              else float(compressor.bits_per_vector(d)))
    if family == "vr_switch":
        return participation * (p * dense + (1.0 - p) * bits_q)
    return participation * bits_q      # compressed | contractive_ef


# ---------------------------------------------------------------------------
# constants estimation for the logreg task (used by examples/tests)
# ---------------------------------------------------------------------------

def logreg_constants(features, lam: float, *, n_workers: int,
                     homogeneous: bool = True) -> ProblemConstants:
    """ℓ2-regularized logistic regression: per-sample smoothness
    L_ij = ||a_ij||²/4 + 2λ; f is (2λ)-strongly convex => PŁ with μ=2λ."""
    x = jnp.asarray(features)
    row_sq = jnp.sum(x * x, axis=1)
    L_i = float(jnp.max(row_sq)) / 4 + 2 * lam
    L_avg = float(jnp.mean(row_sq)) / 4 + 2 * lam
    return ProblemConstants(
        L=L_avg, L_pm=0.0 if homogeneous else L_avg,
        calL_pm=L_i,                     # worst-case bound (Ex. E.1)
        mu=2 * lam, m=x.shape[0], d=x.shape[1])


def importance_weights(features, lam: float):
    """Example E.2 importance sampling: P(j) ∝ L_j = ||a_j||²/4 + 2λ.
    Returns (probs (m,), Lbar) — 𝓛±(IS) ≤ L̄ ≤ max_j L_j = 𝓛±(US)."""
    x = jnp.asarray(features)
    L_j = jnp.sum(x * x, axis=1) / 4 + 2 * lam
    Lbar = jnp.mean(L_j)
    return L_j / jnp.sum(L_j), float(Lbar)
