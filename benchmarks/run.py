"""Benchmark harness — one module per paper table/figure.

  fig1      Fig. 1  — 3 aggregators x 5 attacks optimality gaps (+ RandK)
  table2    Tbl. 2  — rounds-to-epsilon, Byz-VR-MARINA vs baselines
  fig8      Fig. 8  — optimality gap vs transmitted bits
  agg       (system) aggregation throughput, jnp vs Pallas, ALL five rules
            x bucketing; analytic HBM-sweep roofline accounting ->
            experiments/bench/BENCH_agg.json (the aggregator-perf
            trajectory, uploaded by the CI bench job)
  compress  (system) message path per wire format: jnp Compressor vs fused
            Pallas wire, measured wire bytes + HBM-sweep roofline ->
            experiments/bench/BENCH_compress.json (CI bench job)
  roofline  §Roofline terms from the dry-run artifacts
  sweep     (system) sweep engine: serial vs vmapped-batched grid execution
  serve     (system) buffered-async aggregation service: updates/sec +
            p50/p99 round latency, {gspmd, pallas} x {mean, krum} x
            buffer {64, 256} -> experiments/bench/BENCH_serve.json
            (CI bench job)
  obs       (system) telemetry overhead: steps/sec with the RoundTrace
            twin ON vs OFF, {gspmd, pallas} x {mean, krum, rfa} ->
            experiments/bench/BENCH_obs.json (CI bench job; bar is
            <= 5% overhead at log_every=10)
  faults    (system) fault-guard overhead: steps/sec with the fail-closed
            guard ON (live nan_grad plan) vs OFF, {gspmd, pallas} x
            {cm, krum, rfa} -> experiments/bench/BENCH_faults.json
            (CI chaos job)

Prints ``name,us_per_call,derived`` CSV. Select a subset with argv, e.g.
``python -m benchmarks.run fig1 roofline``.
"""
import sys
import traceback


def main() -> None:
    from benchmarks import (bench_ablations, bench_aggregators,
                            bench_compressors, bench_faults, bench_fig1,
                            bench_fig8, bench_obs, bench_roofline,
                            bench_serve, bench_sweep, bench_table2,
                            bench_trainer)
    suites = {
        "ablate": bench_ablations.run,
        "sweep": bench_sweep.run,
        "trainer": bench_trainer.run,
        "agg": bench_aggregators.run,
        "compress": bench_compressors.run,
        "serve": bench_serve.run,
        "obs": bench_obs.run,
        "faults": bench_faults.run,
        "fig1": bench_fig1.run,
        "table2": bench_table2.run,
        "fig8": bench_fig8.run,
        "roofline": bench_roofline.run,
    }
    chosen = sys.argv[1:] or list(suites)
    print("name,us_per_call,derived")
    for name in chosen:
        try:
            suites[name]()
        except Exception as e:  # noqa: BLE001 — a broken suite must not
            traceback.print_exc()  # silence the others
            print(f"{name}/SUITE-FAILED,0,{type(e).__name__}: {e}")


if __name__ == "__main__":
    main()
