"""System throughput: wall-clock steps/s of the full Byzantine-robust
trainer on this host (single device; the distributed step is the same code
jitted onto the mesh). One row per (model, method, aggregator, compressor)
with tokens/s — every row is one ``RunSpec`` executed through the sweep
engine (``repro.exec``; LM cells are un-batchable so they take the serial
path, with per-cell failure isolation), warmup=True compiles before the
timer starts, and the resolved spec JSON is emitted per row.
"""
from benchmarks.common import emit
from repro import exec as xc
from repro.api import RunSpec

N, BW, S = 4, 2, 64
ITERS = 8

ROWS = [
    ("marina", "mean", "identity"),
    ("marina", "cm", "identity"),
    ("marina", "cm", "randk"),
    ("marina", "rfa", "identity"),
    ("sgdm", "cm", "identity"),
    ("csgd", "cm", "randk"),
    # successor estimators (ISSUE 5): EF21 error feedback with a
    # contractive TopK and compressed momentum filtering. SAGA is absent by
    # design — RunSpec rejects method='saga' on the lm task (TokenStream
    # resamples the anchor its table indexes into); bench_fig1 tracks it.
    ("byz_ef21", "cm", "topk"),
    ("cmfilter", "cm", "randk"),
]


def run():
    cells = []
    for arch in ["qwen3-1.7b", "mamba2-130m", "phi3.5-moe-42b-a6.6b"]:
        for method, agg, comp in ROWS:
            spec = RunSpec(
                task="lm", arch=arch, method=method,
                n_workers=N, n_byz=1, p=0.25, lr=1e-2, attack="ALIE",
                aggregator=agg, bucket_size=0 if agg == "mean" else 2,
                compressor=comp,
                compressor_kwargs=({"ratio": 0.25}
                                   if comp in ("randk", "topk") else {}),
                steps=ITERS, seed=0,
                data_kwargs={"reduced": True, "seq_len": S,
                             "per_worker_batch": BW})
            cells.append((f"trainer/{arch}/{method}/{agg}+{comp}", spec))
    srun = xc.run_cells(cells, run_kw={"log_every": ITERS, "warmup": True})
    for run_id, spec in cells:
        if run_id in srun.failures:
            continue
        result = srun[run_id]
        dt = result.wall_s / ITERS
        toks = N * BW * S
        emit(run_id, dt * 1e6, f"tokens_per_s={toks/dt:.0f}", spec=spec)


if __name__ == "__main__":
    run()
