"""Beyond-paper §Perf: the non-default aggregation backends.

Two backends live here, both reachable through the engine's ``agg_mode``
dispatch (core/engine.py):

* ``all_to_all``  — distributed robust aggregation via shard_map (below).
* ``pallas``      — single-host/default-trainer dense path: the candidate
                    pytree is flattened to one (n, D) matrix and routed
                    through the fused bucket+sort Pallas kernel
                    (kernels/robust_agg), so the one-HBM-sweep kernel serves
                    the default (non-shard_map) trainer too. Norm-based
                    rules (RFA/Krum) fall back to the jnp tree path.

Paper-faithful aggregation gathers every worker's full vector to every
device (GSPMD all-gather: n x d_local bytes in, n x d_local held in memory)
and each device computes the identical aggregate for its model shard.

Coordinate-wise rules (mean / CM / trimmed-mean, incl. bucketing) commute
with coordinate partitioning, so instead each device can:

  1. all_to_all: send the j-th 1/n slice of its worker's local shard to
     device row j (wire: d_local bytes per device),
  2. aggregate its slice across all n workers locally,
  3. all_gather the n aggregated slices (wire: d_local bytes).

Peak memory drops from n x d_local to ~2 x d_local and the collective bytes
from n x d_local to ~2 x d_local — an O(n) reduction on both axes.

v2 NOTE (hillclimb lesson, see EXPERIMENTS.md §Perf): the first version
flattened the whole gradient pytree to one (n, D) matrix and re-sharded it
— the re-layout all-gathers cost MORE than the aggregation saved (llama:
collective 398s -> 705s). This version maps LEAF-WISE in each leaf's native
model sharding (``cfg.grad_specs``), so the shard_map body only ever
touches local contiguous shards and the re-layout disappears.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core.aggregators import (_bucketize_perm, coord_median,
                                    coord_trimmed_mean)


def _shard_map(body, mesh, in_specs, out_specs):
    """jax.shard_map (new API, check_vma) with a fallback to
    jax.experimental.shard_map (check_rep) on older jax."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(body, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


# route the per-device coordinate rule through the Pallas kernel
# (kernels/robust_agg.py): fused bucket-mean + sort in VMEM, one HBM sweep.
# None = auto: default-ON where the kernel compiles (TPU), off on CPU/GPU
# hosts where interpret-mode would only slow the rule down. Explicit
# True/False (tests, launchers) or REPRO_PALLAS_AGG=0/1 override auto.
USE_PALLAS_AGG = [None]


def use_pallas_agg() -> bool:
    """Resolve the kernel toggle: explicit setting > env var > backend."""
    if USE_PALLAS_AGG[0] is not None:
        return bool(USE_PALLAS_AGG[0])
    import os
    env = os.environ.get("REPRO_PALLAS_AGG")
    if env is not None:
        return env.strip().lower() not in ("", "0", "false", "off", "no")
    return jax.default_backend() == "tpu"


def _coord_rule(agg, y, key):
    if use_pallas_agg() and agg.rule in ("cm", "tm", "mean"):
        from repro.kernels.ops import robust_agg as pallas_agg
        rule = {"cm": "median", "tm": "trimmed", "mean": "mean"}[agg.rule]
        k = key if agg.bucket_size > 1 else None
        return pallas_agg(y.astype(jnp.float32), k,
                          bucket_size=max(agg.bucket_size, 1), rule=rule,
                          trim=agg.trim)
    if agg.bucket_size > 1 and agg.rule != "mean":
        perm = jax.random.permutation(key, y.shape[0])
        y = _bucketize_perm(y, perm, agg.bucket_size)
    if agg.rule == "mean":
        return jnp.mean(y, axis=0)
    if agg.rule == "cm":
        return coord_median(y)
    return coord_trimmed_mean(y, agg.trim)


def tree_aggregate_all_to_all(cfg, key, sent):
    """cfg: ByzVRMarinaConfig with .mesh, .worker_axes, .model_axis and
    .grad_specs (pytree of PartitionSpec matching the param tree, model
    sharding only). sent: stacked pytree (n, ...)."""
    mesh = cfg.mesh
    assert mesh is not None, "all_to_all mode needs cfg.mesh"
    agg = cfg.aggregator
    assert agg.coordinatewise, (
        f"{agg.rule} is not coordinate-wise; all_to_all sharding only "
        "commutes with coordinate partitioning")
    specs = cfg.grad_specs
    assert specs is not None, "all_to_all mode needs cfg.grad_specs"
    w_axes = tuple(cfg.worker_axes)
    n = cfg.n_workers
    w_spec = w_axes if len(w_axes) > 1 else w_axes[0]

    def agg_leaf(leaf, spec):
        spec_t = tuple(spec) if spec is not None else ()
        in_spec = P(w_spec, *spec_t)
        out_spec = P(*spec_t)

        def body(x, k):
            # x: (n_local=1, *local_shape) — this worker's local model shard
            xf = x.reshape(-1).astype(jnp.float32)
            dl = xf.shape[0]
            pad = (-dl) % n
            if pad:
                xf = jnp.pad(xf, (0, pad))
            xc = xf.reshape(1, n, -1)
            y = lax.all_to_all(xc, w_axes, split_axis=1, concat_axis=0,
                               tiled=True).reshape(n, -1)
            a = _coord_rule(agg, y, k)
            g = lax.all_gather(a, w_axes, axis=0, tiled=True)
            return g[:dl].reshape(x.shape[1:]).astype(x.dtype)

        return _shard_map(body, mesh, (in_spec, P()), out_spec)(leaf, key)

    return jax.tree.map(agg_leaf, sent, specs)


# ---------------------------------------------------------------------------
# pallas dense backend (agg_mode="pallas")
# ---------------------------------------------------------------------------

def tree_aggregate_pallas(cfg, key, sent):
    """Flatten the stacked candidate pytree to one (n, D) matrix and run the
    fused bucket-mean + coordinate-rule kernel (kernels/robust_agg) in a
    single sweep; split the (D,) aggregate back into the tree.

    Semantics match the gspmd tree path exactly: one shared bucketing
    permutation across all leaves (coordinate-wise rules commute with the
    flatten/split), fp32 accumulation, per-leaf output dtype preserved.
    RFA/Krum are not coordinate-wise — they fall back to the jnp tree path.
    """
    agg = cfg.aggregator
    if not agg.coordinatewise:
        return agg.tree(key, sent)
    from repro.kernels.ops import robust_agg as pallas_agg

    leaves, treedef = jax.tree.flatten(sent)
    n = leaves[0].shape[0]
    flat = jnp.concatenate(
        [x.reshape(n, -1).astype(jnp.float32) for x in leaves], axis=1)
    rule = {"cm": "median", "tm": "trimmed", "mean": "mean"}[agg.rule]
    bucketed = agg.bucket_size > 1 and agg.rule != "mean"
    out = pallas_agg(flat, key if bucketed else None,
                     bucket_size=agg.bucket_size if bucketed else 1,
                     rule=rule, trim=agg.trim)
    outs, off = [], 0
    for x in leaves:
        sz = x[0].size
        outs.append(out[off:off + sz].reshape(x.shape[1:]).astype(x.dtype))
        off += sz
    return jax.tree.unflatten(treedef, outs)
