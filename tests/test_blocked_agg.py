"""Giant-n hierarchical aggregation (DESIGN.md §7): blocked-Gram parity and
the no-O(n²·d) memory pin.

Above ``MAX_FUSED_WORKERS`` both backends switch representation: the jnp
oracle accumulates the pairwise-distance Gram row-tile by row-tile
(``_tree_pair_sqdists_blocked``), and the pallas backend routes through the
bucket-then-aggregate tier (``sharded_agg._tree_aggregate_large_n``) whose
kernels tile the worker axis too. These tests pin:

* parity of Krum/RFA across the fused/blocked seam at n ∈ {16, 256, 1024}
  plus non-tile-multiple n, on both backends, masked and unmasked;
* the ≤64-worker path structurally untouched (no scan in its jaxpr);
* the acceptance bar: Krum at n = 4096 traces with NO intermediate that
  scales like n²·d — the largest live aval is O(n²), on the jnp path and
  on the host-side trace of the blocked-kernel path alike.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import aggregators as A
from repro.core import sharded_agg as SA
from repro.core.byz_vr_marina import ByzVRMarinaConfig
from repro.kernels import norm_agg as NA

from _jaxpr_scan import iter_eqns

KEY = jax.random.PRNGKey(42)


def _stack(n, d=24, seed=0):
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    return {"w": jax.random.normal(k1, (n, d), jnp.float32),
            "b": jax.random.normal(k2, (n, 3), jnp.float32)}


def _max_err(a, b):
    return max(float(jnp.max(jnp.abs(x - y)))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


# ---------------------------------------------------------------------------
# blocked jnp Gram == the fused formula
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [16, 130, 256, 1000, 1024])
def test_blocked_pair_sqdists_matches_fused_formula(n):
    xs = _stack(n)
    got = A._tree_pair_sqdists(xs)
    flat = jnp.concatenate(
        [a.reshape(n, -1) for a in jax.tree.leaves(xs)], axis=1)
    sq = jnp.sum(flat * flat, axis=1)
    want = jnp.maximum(sq[:, None] + sq[None, :] - 2.0 * flat @ flat.T, 0.0)
    assert float(jnp.max(jnp.abs(got - want))) < 1e-3


def test_small_n_path_structurally_untouched():
    """n ≤ MAX_FUSED_WORKERS must NOT take the blocked branch: its jaxpr
    stays scan-free, so the pre-existing fused program is byte-stable."""
    prims = {e.primitive.name for e in iter_eqns(jax.make_jaxpr(
        lambda x: A._tree_pair_sqdists({"x": x}))(
            jnp.zeros((A.MAX_FUSED_WORKERS, 8))).jaxpr)}
    assert "scan" not in prims and "while" not in prims
    prims_big = {e.primitive.name for e in iter_eqns(jax.make_jaxpr(
        lambda x: A._tree_pair_sqdists({"x": x}))(
            jnp.zeros((A.MAX_FUSED_WORKERS + 1, 8))).jaxpr)}
    assert "scan" in prims_big            # the blocked branch engaged


# ---------------------------------------------------------------------------
# Krum / RFA parity across the fused/blocked seam, both backends
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rule", ["krum", "rfa"])
@pytest.mark.parametrize("n", [16, 130, 256, 1024])
def test_rule_parity_across_backends(rule, n):
    agg = A.get_aggregator(rule, bucket_size=2, n_byz=max(1, n // 16))
    cfg = ByzVRMarinaConfig(n_workers=n, n_byz=max(1, n // 16),
                            aggregator=agg)
    xs = _stack(n, d=24 if n <= 256 else 8)
    key = jax.random.PRNGKey(1)
    oracle = agg.tree(key, xs)            # gspmd backend (jnp, blocked >64)
    got = SA.tree_aggregate_pallas(cfg, key, xs)
    assert _max_err(got, oracle) < 2e-5


@pytest.mark.parametrize("rule", ["krum", "rfa"])
@pytest.mark.parametrize("n", [130, 256])
def test_masked_rule_parity_across_backends(rule, n):
    """Fault-guard / participation masking through the giant-n tier."""
    agg = A.get_aggregator(rule, bucket_size=2, n_byz=4)
    cfg = ByzVRMarinaConfig(n_workers=n, n_byz=4, aggregator=agg)
    xs = _stack(n)
    valid = jax.random.bernoulli(jax.random.PRNGKey(9), 0.8, (n,))
    key = jax.random.PRNGKey(1)
    oracle = agg.tree_masked(key, xs, valid)
    got = SA.tree_aggregate_pallas(cfg, key, xs, valid=valid)
    assert _max_err(got, oracle) < 2e-5


@pytest.mark.parametrize("n", [96, 130])
def test_unbucketed_giant_n_uses_blocked_drivers(n):
    """bucket_size=0 at giant n: the full stack reaches the blocked
    drivers directly (no bucket reduction shrinks it under the cap)."""
    for rule in ("krum", "rfa"):
        agg = A.get_aggregator(rule, n_byz=3)
        cfg = ByzVRMarinaConfig(n_workers=n, n_byz=3, aggregator=agg)
        xs = _stack(n)
        key = jax.random.PRNGKey(2)
        assert _max_err(SA.tree_aggregate_pallas(cfg, key, xs),
                        agg.tree(key, xs)) < 2e-5


@pytest.mark.parametrize("rule,kw", [("krum", {"n_byz": 5}),
                                     ("rfa", {"iters": 4})])
def test_blocked_drivers_match_flat_oracle(rule, kw):
    """The blocked drivers alone (dense prologue pre-applied) against the
    flat Aggregator call, at a non-tile-multiple n."""
    n = 150
    x = jax.random.normal(KEY, (n, 70), jnp.float32)
    agg = A.get_aggregator(rule, **kw)
    want = agg(jax.random.PRNGKey(0), x)
    if rule == "krum":
        got = NA.krum_segments_blocked([x], n_byz=5)[0]
    else:
        got = NA.rfa_segments_blocked([x], iters=4)[0]
    assert float(jnp.max(jnp.abs(got - want))) < 2e-5


def test_blocked_info_matches_oracle_info():
    n = 100
    xs = _stack(n)
    agg = A.get_aggregator("krum", n_byz=4)
    cfg = ByzVRMarinaConfig(n_workers=n, n_byz=4, aggregator=agg)
    _, want = agg.tree_traced(jax.random.PRNGKey(0), xs)
    _, info = SA.tree_aggregate_pallas(cfg, jax.random.PRNGKey(0), xs,
                                       return_info=True)
    assert int(info["krum_selected"]) == int(want["krum_selected"])
    np.testing.assert_allclose(np.asarray(info["krum_scores"]),
                               np.asarray(want["krum_scores"]), rtol=1e-4)


# ---------------------------------------------------------------------------
# the acceptance pin: Krum at n = 4096 with no n²·d-sized intermediate
# ---------------------------------------------------------------------------

def _max_aval_size(jaxpr):
    sizes = [0]
    for eqn in iter_eqns(jaxpr):
        for v in list(eqn.invars) + list(eqn.outvars):
            aval = getattr(v, "aval", None)
            if aval is not None and hasattr(aval, "shape"):
                sizes.append(int(np.prod(aval.shape or (1,))))
    return max(sizes)


@pytest.mark.parametrize("d", [32])
def test_krum_4096_jnp_no_n2d_intermediate(d):
    n = 4096
    agg = A.get_aggregator("krum", n_byz=128)

    def f(x):
        return agg.tree(jax.random.PRNGKey(0), {"x": x})

    closed = jax.make_jaxpr(f)(
        jax.ShapeDtypeStruct((n, d), jnp.float32))
    # O(n²) (the distance matrix itself) is allowed; anything that scales
    # like n²·d is not. 4·n² sits far below n²·d for every real d.
    assert _max_aval_size(closed.jaxpr) <= 4 * n * n


@pytest.mark.parametrize("d", [256])
def test_krum_4096_blocked_kernels_no_n2d_intermediate(d):
    n = 4096

    def f(x):
        return NA.krum_segments_blocked([x], n_byz=128)[0]

    closed = jax.make_jaxpr(f)(
        jax.ShapeDtypeStruct((n, d), jnp.float32))
    # host-side trace only (iter_eqns skips pallas_call bodies — in-kernel
    # blocks are (TILE_N, TILE_D) by construction of the BlockSpecs)
    assert _max_aval_size(closed.jaxpr) <= 4 * n * n


def test_giant_n_tree_path_no_n2d_intermediate():
    n, d = 4096, 64
    agg = A.get_aggregator("krum", bucket_size=2, n_byz=128)
    cfg = ByzVRMarinaConfig(n_workers=n, n_byz=128, aggregator=agg)

    def f(x):
        return SA.tree_aggregate_pallas(cfg, jax.random.PRNGKey(0),
                                        {"x": x})

    closed = jax.make_jaxpr(f)(
        jax.ShapeDtypeStruct((n, d), jnp.float32))
    assert _max_aval_size(closed.jaxpr) <= 4 * n * n
