"""Shared jaxpr scanner for zero-copy pins.

Walks every equation reachable from a jaxpr WITHOUT descending into
``pallas_call`` bodies: ops inside the kernel run in VMEM and are the
whole point of the fused pipeline, so only the host-side (HBM) trace is
audited. Used by test_norm_agg.py (fused attack phase) and
test_wire.py (fused compressed-wire phase).
"""
import jax

_JAXPR_TYPES = (jax.core.Jaxpr, jax.core.ClosedJaxpr)


def iter_eqns(jaxpr):
    """All eqns reachable from ``jaxpr``, NOT descending into pallas_call."""
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "pallas_call":
            continue
        yield eqn
        for v in eqn.params.values():
            for sub in jax.tree.leaves(
                    v, is_leaf=lambda x: isinstance(x, _JAXPR_TYPES)):
                if isinstance(sub, jax.core.ClosedJaxpr):
                    yield from iter_eqns(sub.jaxpr)
                elif isinstance(sub, jax.core.Jaxpr):
                    yield from iter_eqns(sub)
