"""Small pytree helpers shared by the trainers."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def tree_add(a, b):
    return jax.tree.map(jnp.add, a, b)


def tree_sub(a, b):
    return jax.tree.map(jnp.subtract, a, b)


def tree_scale(s, a):
    return jax.tree.map(lambda x: (s * x.astype(jnp.float32)).astype(x.dtype), a)


def tree_zeros_like(a):
    return jax.tree.map(jnp.zeros_like, a)


def tree_dot(a, b):
    return sum(jnp.vdot(x.astype(jnp.float32), y.astype(jnp.float32))
               for x, y in zip(jax.tree.leaves(a), jax.tree.leaves(b)))


def tree_norm_sq(a):
    return tree_dot(a, a)


def tree_size(a):
    return sum(x.size for x in jax.tree.leaves(a))


def tree_broadcast_leading(a, n):
    return jax.tree.map(lambda x: jnp.broadcast_to(x, (n,) + x.shape), a)


def masked_mean_std(xs, good_mask, sanitize: bool = False):
    """Per-coordinate mean/std over the good workers of a stacked pytree.

    xs leaves: (n, ...). good_mask: (n,) bool. Returns (mean_tree, std_tree).

    ``sanitize`` (fault guard, DESIGN.md §6): select-replace masked-out rows
    before the weighted sums — a zero weight does NOT neutralize a
    non-finite row (0·NaN = NaN), so guarded callers whose excluded rows may
    be fault-poisoned must pass True. Static, so the default path's jaxpr is
    unchanged.
    """
    g = good_mask.astype(jnp.float32)
    cnt = jnp.maximum(jnp.sum(g), 1.0)

    def mean_leaf(a):
        w = g.reshape((-1,) + (1,) * (a.ndim - 1))
        af = a.astype(jnp.float32)
        if sanitize:
            af = jnp.where(w > 0.0, af, 0.0)
        return jnp.sum(af * w, axis=0) / cnt

    means = jax.tree.map(mean_leaf, xs)

    def std_leaf(a, m):
        w = g.reshape((-1,) + (1,) * (a.ndim - 1))
        af = a.astype(jnp.float32)
        if sanitize:
            af = jnp.where(w > 0.0, af, m[None])
        var = jnp.sum(jnp.square(af - m[None]) * w,
                      axis=0) / cnt
        return jnp.sqrt(jnp.maximum(var, 0.0))

    stds = jax.tree.map(std_leaf, xs, means)
    return means, stds


def per_worker_keys(key, n, *, common: bool = False):
    if common:
        return jnp.broadcast_to(key, (n,) + key.shape)
    return jax.vmap(lambda i: jax.random.fold_in(key, i))(jnp.arange(n))


def compress_tree(compressor, key, tree):
    """Apply an unbiased compressor leaf-wise (block compression). Each leaf
    gets its own fold_in'd key so RandK supports differ across leaves."""
    leaves, treedef = jax.tree.flatten(tree)
    out = []
    for i, leaf in enumerate(leaves):
        k = jax.random.fold_in(key, i)
        out.append(compressor.compress(k, leaf))
    return jax.tree.unflatten(treedef, out)
