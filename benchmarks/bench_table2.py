"""Paper Table 2 (empirical analogue): communication rounds to reach a target
optimality gap, Byz-VR-MARINA vs BR-SGDm / BR-CSGD / BR-DIANA / Byrd-SVRG,
under the ALIE attack. Also reports uploaded bits per worker to reach the
target (the compression win).

Every contender is one ``make_method`` call — the registry is the row key,
and per-round communication comes from the estimator's own accounting."""
import jax

from benchmarks.common import emit, make_logreg_problem
from repro.core import (ByzVRMarinaConfig, get_aggregator, get_attack,
                        get_compressor, make_method)
from repro.data import corrupt_labels_logreg, init_logreg_params

KEY = jax.random.PRNGKey(1)
DIM = 30
TARGET = 1e-3
MAX_ROUNDS = 1200


def _rounds_to_target(data, loss_fn, full, f_star, state, step):
    k = KEY
    check = jax.jit(lambda p: loss_fn(p, full))
    anchor = data.stacked()
    for it in range(MAX_ROUNDS):
        k, k1, k2 = jax.random.split(k, 3)
        state, _ = step(state, data.sample_batches(k1, 32), anchor, k2)
        if (it + 1) % 25 == 0:
            if float(check(state["params"])) - f_star < TARGET:
                return it + 1
    return -1


def run():
    data, loss_fn, full, f_star = make_logreg_problem(KEY, dim=DIM)
    anchor = data.stacked()
    d = DIM + 1
    agg = get_aggregator("cm", bucket_size=2)
    atk = get_attack("ALIE")
    randk = get_compressor("randk", ratio=0.1)

    base = dict(n_workers=5, n_byz=1, p=0.1, lr=0.5, aggregator=agg,
                attack=atk)
    rows = [
        ("byz-vr-marina", "marina", {}),
        ("byz-vr-marina+randk", "marina", {"compressor": randk}),
        ("br-sgdm", "sgdm", {}),
        ("br-csgd+randk", "csgd", {"compressor": randk}),
        ("br-diana+randk", "diana", {"compressor": randk}),
        ("byrd-svrg", "svrg",
         {"aggregator": get_aggregator("rfa", bucket_size=2)}),
    ]
    for label, method_name, cfg_kw in rows:
        cfg = ByzVRMarinaConfig(**{**base, **cfg_kw})
        method = make_method(method_name, cfg, loss_fn,
                             corrupt_labels_logreg)
        state = method.init(init_logreg_params(DIM), anchor, KEY)
        rounds = _rounds_to_target(data, loss_fn, full, f_star, state,
                                   jax.jit(method.step))
        bits_per_round = method.expected_bits(d)
        bits = rounds * bits_per_round if rounds > 0 else float("inf")
        emit(f"table2/{label}", float(rounds),
             f"rounds_to_{TARGET:g}={rounds};bits/worker={bits:.3g}")


if __name__ == "__main__":
    run()
