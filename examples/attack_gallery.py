"""Fig. 1 reproduction driver: every (aggregator x attack) cell, with and
without compression, printed as the paper's grid. Feeds EXPERIMENTS.md
§Paper-validation.

The grid is one ``Sweep`` over a base ``RunSpec`` executed through the
batched sweep engine (``repro.exec``): with ``--seeds k`` > 1 each
(compressor, aggregator, attack) cell becomes a jit-signature group that
runs as ONE vmapped-over-seeds trajectory (one compile per group instead
of one per cell) and the table shows the mean gap over seeds.

  PYTHONPATH=src python examples/attack_gallery.py [--iters 600] [--seeds 3]
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro import exec as xc
from repro.api import RunSpec, Sweep, build
from repro.data import logreg_reference

ap = argparse.ArgumentParser()
ap.add_argument("--iters", type=int, default=600)
ap.add_argument("--n-workers", type=int, default=5)
ap.add_argument("--n-byz", type=int, default=1)
ap.add_argument("--seeds", type=int, default=1,
                help="seeds per cell; >1 runs each cell group vmapped")
ap.add_argument("--heterogeneous", action="store_true")
ap.add_argument("--detect", action="store_true",
                help="also run every rule x attack cell with trace=True "
                     "and print detection precision/recall + byzantine "
                     "influence leakage (repro.obs, DESIGN.md §5)")
args = ap.parse_args()

DIM = 30
BASE = RunSpec(
    task="logreg", method="marina", n_workers=args.n_workers,
    n_byz=args.n_byz, p=0.1, lr=0.5, steps=args.iters,
    data_kwargs={"n_samples": 600, "dim": DIM,
                 "homogeneous": not args.heterogeneous})

exp0 = build(BASE)
full = {"x": exp0.data.features, "y": exp0.data.labels}
_, f_star = logreg_reference(exp0.loss_fn, full, iters=3000)

ATTACKS = ("NA", "LF", "BF", "ALIE", "IPM")
AGGS = [("AVG", "mean", 0), ("CM", "cm", 2), ("RFA", "rfa", 2)]
SEEDS = tuple(range(args.seeds))

for comp_name, comp_spec in [
        ("no compression", {}),
        ("RandK K=0.1d", {"compressor": "randk",
                          "compressor_kwargs": {"ratio": 0.1}})]:
    print(f"\n=== Byz-VR-MARINA, {comp_name} "
          f"({args.n_workers} workers, {args.n_byz} byzantine, "
          f"{len(SEEDS)} seed{'s' if len(SEEDS) > 1 else ''}) ===")
    print(f"{'agg':>5} | " + " | ".join(f"{a:>9}" for a in ATTACKS))
    for label, rule, bucket in AGGS:
        base = BASE.replace(aggregator=rule, bucket_size=bucket, **comp_spec)
        grid = {"attack": ATTACKS}
        if len(SEEDS) > 1:
            grid["seed"] = SEEDS
        cells = list(Sweep(base, grid).expand())
        srun = xc.run_cells(cells, run_kw={"log_every": args.iters})
        row = []
        for attack in ATTACKS:
            gaps = [float(exp0.loss_fn(srun[rid].params, full)) - f_star
                    for rid, spec in cells
                    if spec.attack == attack and rid in srun]
            row.append(f"{sum(gaps) / len(gaps):9.1e}" if gaps
                       else f"{'failed':>9}")
        print(f"{label:>5} | " + " | ".join(row))
        for rid, rec in srun.failures.items():
            print(f"      ! {rid}: {rec['error']}")
print("\n(cells = final optimality gap f(x)-f*; the paper's Fig. 1 pattern: "
      "CM/RFA rows reach ~0 everywhere, AVG breaks under BF/ALIE/IPM)")

if args.detect:
    # every robust rule (all five) x attack, traced: who did the rule
    # actually filter, and did the byzantines keep any influence?
    DETECT_AGGS = AGGS + [("TM", "tm", 2), ("KRUM", "krum", 2)]
    steps = min(args.iters, 100)
    print(f"\n=== aggregator-decision telemetry ({steps} steps, traced at "
          f"log cadence; precision/recall of filtered-vs-byzantine, "
          f"leak = byzantine influence share) ===")
    print(f"{'agg':>5} | " + " | ".join(f"{a:>17}" for a in ATTACKS))
    for label, rule, bucket in DETECT_AGGS:
        row = []
        for attack in ATTACKS:
            spec = BASE.replace(aggregator=rule, bucket_size=bucket,
                                attack=attack, steps=steps, trace=True)
            det = build(spec).run(log_every=10).detection_summary()
            row.append(f"P{det['precision']:.2f} R{det['recall']:.2f} "
                       f"L{det['byz_leakage']:.2f}")
        print(f"{label:>5} | " + " | ".join(f"{c:>17}" for c in row))
    print("\n(honest-majority rules should pin the byzantines — high "
          "recall, leak near the uniform byz share or below; AVG filters "
          "nothing by construction)")
