"""Architecture + input-shape config system.

Every assigned architecture registers an ``ArchConfig`` here via its own module in
``repro.configs``. The full configs are exercised only through the dry-run
(ShapeDtypeStruct lowering); smoke tests use ``reduced()`` variants.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Block kinds that models/transformer.py knows how to build.
# ---------------------------------------------------------------------------
ATTN = "attention"            # full-causal GQA attention
SWA = "sliding_window"        # sliding-window (local) causal attention
MLA = "mla"                   # DeepSeek multi-head latent attention
RGLRU = "rg_lru"              # RecurrentGemma gated linear recurrence block
MAMBA2 = "mamba2"             # Mamba2 SSD block (attention-free)


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    num_shared: int = 0
    d_expert: Optional[int] = None      # expert hidden dim (defaults to d_ff)
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                          # dense | moe | ssm | hybrid | vlm | audio
    citation: str
    num_layers: int
    d_model: int
    num_heads: int                       # query heads (0 for attention-free)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: Optional[int] = None       # default d_model // num_heads
    # block pattern repeated over depth; default all-attention
    block_pattern: tuple = (ATTN,)
    moe: Optional[MoEConfig] = None
    # attention extras
    qk_norm: bool = False
    rope_theta: float = 10_000.0
    mrope_sections: Optional[tuple] = None   # (t, h, w) head_dim split for M-RoPE
    sliding_window: int = 4096               # window used by SWA blocks
    # MLA extras
    kv_lora_rank: int = 0
    qk_rope_dim: int = 64
    # SSM / RG-LRU extras
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    conv_width: int = 4
    rglru_width: int = 0                 # lru width (defaults d_model)
    # modality frontend stub: number of prepended embedding tokens in input_specs
    frontend_tokens: int = 0
    num_codebooks: int = 1               # musicgen-style parallel codebooks
    tie_embeddings: bool = False
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"
    # decode support
    supports_long_context: bool = True   # via SWA/recurrent state (see DESIGN.md)

    # ------------------------------------------------------------------
    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim is not None:
            return self.head_dim
        return self.d_model // max(self.num_heads, 1)

    @property
    def jnp_dtype(self):
        return jnp.dtype(self.dtype)

    def blocks(self) -> list:
        """Per-layer block kinds, the pattern tiled to num_layers."""
        pat = list(self.block_pattern)
        reps = (self.num_layers + len(pat) - 1) // len(pat)
        return (pat * reps)[: self.num_layers]

    # parameter count (approx, embedding included once) --------------------
    def param_count(self) -> int:
        d, ff, L = self.d_model, self.d_ff, self.num_layers
        hd = self.resolved_head_dim
        nq, nkv = self.num_heads, self.num_kv_heads
        total = self.vocab_size * d * self.num_codebooks          # embed
        if not self.tie_embeddings:
            total += self.vocab_size * d * self.num_codebooks     # unembed
        counts = {}
        for kind in self.blocks():
            counts[kind] = counts.get(kind, 0) + 1
        for kind, n in counts.items():
            if kind in (ATTN, SWA):
                attn = d * (nq * hd) + 2 * d * (nkv * hd) + (nq * hd) * d
                total += n * attn
            elif kind == MLA:
                r = self.kv_lora_rank
                attn = (d * r + r * nq * (hd + hd)               # kv down/up
                        + d * self.qk_rope_dim                    # rope key
                        + d * nq * hd + nq * hd * d)              # q and out
                total += n * attn
            elif kind == RGLRU:
                w = self.rglru_width or self.d_model
                total += n * (2 * d * w + 2 * w + w * d + self.conv_width * w)
            elif kind == MAMBA2:
                di = self.ssm_expand * d
                total += n * (d * (2 * di + 2 * self.ssm_state) + di * d
                              + self.conv_width * di)
            # mlp for every block except pure mamba2 (mamba2 has none)
            if kind != MAMBA2:
                if self.moe is not None:
                    de = self.moe.d_expert or ff
                    n_e = self.moe.num_experts + self.moe.num_shared
                    total += n * (n_e * 3 * d * de + d * self.moe.num_experts)
                else:
                    total += n * 3 * d * ff
        total += L * 2 * d + d                                    # norms
        return int(total)

    def active_param_count(self) -> int:
        """Params touched per token (MoE: only routed top-k + shared)."""
        if self.moe is None:
            return self.param_count()
        d, ff = self.d_model, self.d_ff
        de = self.moe.d_expert or ff
        n_e = self.moe.num_experts + self.moe.num_shared
        act = self.moe.top_k + self.moe.num_shared
        dense_like = self.param_count() - self.num_layers * n_e * 3 * d * de
        return int(dense_like + self.num_layers * act * 3 * d * de)

    # reduced variant for CPU smoke tests -----------------------------------
    def reduced(self) -> "ArchConfig":
        kw = dict(
            num_layers=min(self.num_layers, len(self.block_pattern), 3) or 2,
            d_model=min(self.d_model, 128),
            d_ff=min(self.d_ff, 256),
            vocab_size=min(self.vocab_size, 512),
            sliding_window=64,
            kv_lora_rank=min(self.kv_lora_rank, 32) if self.kv_lora_rank else 0,
            qk_rope_dim=16,
            ssm_state=min(self.ssm_state, 16) if self.ssm_state else 0,
            ssm_headdim=32,
            rglru_width=min(self.rglru_width, 128) if self.rglru_width else 0,
            frontend_tokens=min(self.frontend_tokens, 4),
            dtype="float32",
        )
        # keep at least one of each pattern element
        kw["num_layers"] = max(2, len(self.block_pattern))
        nh = max(2, min(self.num_heads, 4))
        nkv = max(1, min(self.num_kv_heads, nh))
        while nh % nkv:
            nkv -= 1
        kw["num_heads"] = nh
        kw["num_kv_heads"] = nkv
        kw["head_dim"] = 32
        if self.mrope_sections is not None:
            kw["mrope_sections"] = (4, 6, 6)   # sums to head_dim//2 = 16
        if self.moe is not None:
            kw["moe"] = MoEConfig(
                num_experts=min(self.moe.num_experts, 4),
                top_k=min(self.moe.top_k, 2),
                num_shared=min(self.moe.num_shared, 1),
                d_expert=64,
                capacity_factor=2.0,
            )
        return dataclasses.replace(self, **kw)


# ---------------------------------------------------------------------------
# Input shapes (assigned)
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str        # train | prefill | decode


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4_096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32_768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524_288, 1, "decode"),
}


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------
_REGISTRY: dict = {}
_LOADED = [False]


def register(cfg: ArchConfig) -> ArchConfig:
    _REGISTRY[cfg.name] = cfg
    return cfg


def get_config(name: str) -> ArchConfig:
    _ensure_loaded()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; have {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_configs() -> list:
    _ensure_loaded()
    return sorted(_REGISTRY)


def _ensure_loaded():
    # import all sibling config modules once
    if _LOADED[0]:
        return
    from repro.configs import (  # noqa: F401
        recurrentgemma_2b, phi35_moe_42b, starcoder2_3b, qwen2_vl_2b,
        qwen3_1p7b, mamba2_130m, mistral_large_123b, deepseek_v2_lite_16b,
        llama3_405b, musicgen_medium,
    )
    _LOADED[0] = True


ASSIGNED_ARCHS = (
    "recurrentgemma-2b", "phi3.5-moe-42b-a6.6b", "starcoder2-3b", "qwen2-vl-2b",
    "qwen3-1.7b", "mamba2-130m", "mistral-large-123b", "deepseek-v2-lite-16b",
    "llama3-405b", "musicgen-medium",
)
