"""``run(spec)`` must match the engine driven the PR-1 way — hand-assembled
``ByzVRMarinaConfig`` + ``make_method`` with the runner's documented key
schedule — bit-for-bit on fixed seeds.

The per-method version of this assertion lives in the estimator
conformance harness (tests/test_estimator_contract.py::
test_run_spec_matches_hand_wired_engine, parametrized over every
``ESTIMATORS`` entry); this module keeps the cases the harness does not
cover: the sparse-support message-phase owner and the pre-redesign
``make_init``/``make_step`` facade."""
import jax
import numpy as np

from repro.api import RunSpec, run
from repro.core import (ByzVRMarinaConfig, get_aggregator, get_attack,
                        get_compressor, make_method)
from repro.data import (corrupt_labels_logreg, init_logreg_params,
                        logreg_loss, make_logreg_data)

DIM = 13
N = 5
STEPS = 4
BATCH = 16


def _spec(method, **kw):
    base = dict(task="logreg", method=method, n_workers=N, n_byz=1,
                p=0.3, lr=0.25, attack="ALIE", aggregator="cm",
                bucket_size=2, compressor="randk",
                compressor_kwargs={"ratio": 0.5}, steps=STEPS, seed=3,
                data_kwargs={"n_samples": 120, "dim": DIM,
                             "batch_size": BATCH, "data_seed": 0})
    base.update(kw)
    return RunSpec(**base)


def _legacy_run(spec):
    """Drive the engine exactly the way PR-1 call sites did, replicating the
    runner's canonical key schedule by hand."""
    data = make_logreg_data(
        jax.random.PRNGKey(spec.data_kwargs["data_seed"]),
        n_samples=spec.data_kwargs["n_samples"],
        dim=spec.data_kwargs["dim"], n_workers=spec.n_workers,
        homogeneous=True)
    loss = logreg_loss(0.01)
    comp = get_compressor(spec.compressor, **spec.compressor_kwargs)
    cfg = ByzVRMarinaConfig(
        n_workers=spec.n_workers, n_byz=spec.n_byz, p=spec.p, lr=spec.lr,
        aggregator=get_aggregator(spec.aggregator,
                                  bucket_size=spec.bucket_size,
                                  n_byz=spec.n_byz),
        compressor=comp, attack=get_attack(spec.attack),
        agg_mode=spec.agg_mode)
    method = make_method(spec.method, cfg, loss, corrupt_labels_logreg,
                         **spec.method_kwargs)
    anchor = data.stacked()
    k_init, k_run = jax.random.split(jax.random.PRNGKey(spec.seed))
    state = method.init(init_logreg_params(spec.data_kwargs["dim"]),
                        anchor, k_run)
    step = jax.jit(method.step)
    losses = []
    for it in range(spec.steps):
        k_step, k_batch = jax.random.split(jax.random.fold_in(k_run, it + 1))
        state, m = step(state, data.sample_batches(k_batch, BATCH), anchor,
                        k_step)
        losses.append(np.asarray(m["loss"]))
    return state, losses


def _assert_trees_equal(a, b):
    jax.tree.map(lambda x, y: np.testing.assert_array_equal(
        np.asarray(x), np.asarray(y)), a, b)


def test_run_spec_matches_legacy_wiring_sparse_support():
    spec = _spec("marina", agg_mode="sparse_support",
                 compressor_kwargs={"ratio": 0.5, "common_randomness": True})
    result = run(spec, log_every=1)
    state_l, _ = _legacy_run(spec)
    _assert_trees_equal(state_l["params"], result.params)


def test_legacy_facade_make_step_matches_spec_run():
    """The pre-redesign facade (make_init/make_step) and run(spec) are the
    same computation when driven with the same keys."""
    from repro.core import make_init, make_step
    spec = _spec("marina")
    data = make_logreg_data(jax.random.PRNGKey(0), n_samples=120, dim=DIM,
                            n_workers=N, homogeneous=True)
    loss = logreg_loss(0.01)
    cfg = spec.build_config()
    anchor = data.stacked()
    k_init, k_run = jax.random.split(jax.random.PRNGKey(spec.seed))
    state = make_init(cfg, loss, corrupt_labels_logreg)(
        init_logreg_params(DIM), anchor, k_run)
    step = jax.jit(make_step(cfg, loss, corrupt_labels_logreg))
    for it in range(STEPS):
        k_step, k_batch = jax.random.split(jax.random.fold_in(k_run, it + 1))
        state, _ = step(state, data.sample_batches(k_batch, BATCH), anchor,
                        k_step)
    result = run(spec, log_every=STEPS)
    _assert_trees_equal(state["params"], result.params)
