"""Paper Figure 1: optimality gap of 3 aggregation rules (AVG, CM, RFA)
under 5 attacks (NA, LF, BF, ALIE, IPM), homogeneous data, 4 good + 1
byzantine worker, with and without RandK (K = 0.1 d) compression.

The whole grid is ONE declarative ``Sweep`` over a base ``RunSpec``; each
emitted row carries the resolved spec JSON (experiments/bench/), so any cell
reproduces with ``RunSpec.from_dict(artifact["spec"]).run()``.
"""
from benchmarks.common import emit, final_gap, logreg_reference
from repro.api import RunSpec, Sweep, build

DIM = 30
BASE = RunSpec(task="logreg", method="marina", n_workers=5, n_byz=1,
               p=0.1, lr=0.5, seed=0,
               data_kwargs={"n_samples": 400, "dim": DIM, "data_seed": 0})

GRID = {
    "compressor_kwargs.ratio": (1.0, 0.1),          # none vs RandK(0.1d)
    "aggregator": ("mean", "cm", "rfa"),
    "attack": ("NA", "LF", "BF", "ALIE", "IPM"),
}
_AGG_LABEL = {"mean": "avg", "cm": "cm", "rfa": "rfa"}


def run(iters=500):
    base = BASE.replace(steps=iters, compressor="randk")
    full, f_star = logreg_reference(build(base))
    for _, spec in Sweep(base=base, grid=GRID).expand():
        ratio = spec.compressor_kwargs["ratio"]
        if ratio >= 1.0:    # identity wire format, not RandK(d)
            spec = spec.replace(compressor="identity", compressor_kwargs={})
        if spec.aggregator == "mean":
            spec = spec.replace(bucket_size=0)
        exp = build(spec)
        result = exp.run(log_every=iters)
        gap = final_gap(exp, result, full, f_star)
        comp_name = "none" if ratio >= 1.0 else f"randk{ratio}"
        emit(f"fig1/{comp_name}/{_AGG_LABEL[spec.aggregator]}/{spec.attack}",
             result.wall_s / iters * 1e6, f"gap={gap:.3e}", spec=spec)


if __name__ == "__main__":
    run()
