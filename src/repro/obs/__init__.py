"""repro.obs — the observability layer (DESIGN.md §5).

Four pieces, shared by ``api.runner``, ``repro.exec`` and ``repro.serve``:

* ``trace``   — ``RoundTrace``: per-round aggregator-decision telemetry
                (who the rule picked, how much each worker influenced the
                aggregate) emitted from the *same* backend calls that
                compute the aggregate, gated by ``RunSpec.trace``.
* ``detect``  — host-side detection-quality metrics against the ground-
                truth byzantine mask (filter precision/recall, influence
                leakage).
* ``sink``    — the ``MetricSink`` event protocol (JSONL stream, in-memory
                ring, fan-out) plus wall-clock spans that fence with
                ``block_until_ready`` only at log-cadence boundaries.
* ``profile`` — ``jax.profiler`` trace context + the XLA step-marker env
                idiom, wired into the launch CLIs as ``--profile-dir``.
"""
from repro.obs.detect import detection_metrics, filtered_mask, summarize
from repro.obs.sink import (FanoutSink, JsonlSink, MetricSink, NullSink,
                            RingSink, TagSink, span, verify_jsonl)
from repro.obs.trace import (RoundTrace, to_host, traced_ingest_message_phase,
                             traced_message_phase)

__all__ = [
    "RoundTrace", "traced_message_phase", "traced_ingest_message_phase",
    "to_host", "detection_metrics", "filtered_mask", "summarize",
    "MetricSink", "JsonlSink", "RingSink", "FanoutSink", "NullSink",
    "TagSink", "span", "verify_jsonl",
]
