"""Pallas TPU kernel: fused bucketing + coordinate-wise robust aggregation.

Server-side hot spot at pod scale: aggregating n worker vectors of
d_local ≈ 1.6e9 coordinates. The fusion argument (DESIGN.md §3): the naive
jnp path materializes the bucketed (n/s, d) intermediate and the sorted
(n/s, d) tensor in HBM — 3 full HBM sweeps of the worker-stacked matrix.
This kernel streams (n, TILE_D) blocks through VMEM once: bucket-mean and
the fixed-n sorting network happen in-register; HBM traffic is exactly
read(n·d) + write(d), the roofline floor for this op.

Zero-copy message phase (norm_agg.py holds the shared machinery): the
Alg. 2 permutation rides on-chip as the (nb, n) ``bucket_matrix`` applied
to the block in VMEM (so callers never materialize ``x[perm]``), and the
omniscient attack can be injected in the same load via
``attack.coord_apply`` + mask/mean/std inputs — the attacked ``sent``
tensor never hits HBM. The legacy contiguous path (pre-permuted rows +
``bucket_size``) is kept for callers that already hold a permuted stack.

TPU adaptation: the worker axis (n ≤ norm_agg.MAX_FUSED_WORKERS = 64) lives
in the sublane dimension; TILE_D is lane-aligned (multiple of 128).
``jnp.sort`` along axis 0 inside the kernel lowers to a fixed-size bitonic
network over sublanes. Giant-n stacks never reach this kernel: callers
(kernels/ops.py, core/sharded_agg.py) bucket-reduce first and run the
coordinate rule in jnp — DESIGN.md §7.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.backend import resolve_interpret
from repro.kernels.norm_agg import _assemble, _prologue, src_dims


DEFAULT_TILE_D = 2048     # (64 workers x 2048 lanes x 4B = 512 KiB in VMEM)


def _coord_rule_block(x, *, bucket_size, rule, trim, n):
    """The coordinate rule on one in-VMEM block; contiguous Alg. 2 bucketing
    (pre-permuted rows) when ``bucket_size`` > 1."""
    if bucket_size > 1:
        # matches aggregators._bucketize_perm (Alg. 2): when n is not a
        # bucket multiple the last bucket is padded with the stacked mean,
        # so no trailing worker is silently dropped.
        nb = -(-n // bucket_size)
        pad = nb * bucket_size - n
        if pad:
            fill = jnp.broadcast_to(jnp.mean(x, axis=0, keepdims=True),
                                    (pad, x.shape[1]))
            x = jnp.concatenate([x, fill], axis=0)
        x = x.reshape(nb, bucket_size, -1).mean(axis=1)
    m = x.shape[0]
    if rule == "mean":
        return jnp.mean(x, axis=0)
    xs = jnp.sort(x, axis=0)
    if rule == "median":
        if m % 2:
            return xs[m // 2]
        return 0.5 * (xs[m // 2 - 1] + xs[m // 2])
    if rule == "trimmed":
        t = min(trim, (m - 1) // 2)
        return jnp.mean(xs[t:m - t], axis=0)
    raise ValueError(rule)


def _masked_coord_rule_block(x, bvalid, *, rule, trim):
    """Fault-guarded coordinate rule on one in-VMEM block (DESIGN.md §6).

    ``x`` (m, tile) is already sanitized (+ W-bucketed) by ``_prologue``;
    ``bvalid`` (m, 1) marks the rows (buckets) with at least one valid
    member. Invalid rows re-fill with +inf so the sublane sort pushes them
    past every real entry, and the selection ranks track the TRACED valid
    count c — the in-kernel twin of ``aggregators.masked_coord_median`` /
    ``masked_coord_trimmed_mean``. Rank gathers are iota-compare selects
    (dynamic sublane indexing doesn't vectorize on the VPU)."""
    m = x.shape[0]
    c = jnp.sum(bvalid.astype(jnp.int32))
    if rule == "mean":
        return jnp.sum(x, axis=0) / jnp.maximum(c, 1).astype(jnp.float32)
    xf = jnp.where(bvalid > 0.0, x, jnp.inf)
    xs = jnp.sort(xf, axis=0)
    rank = jax.lax.broadcasted_iota(jnp.int32, (m, 1), 0)
    if rule == "median":
        lo = jnp.sum(jnp.where(rank == (c - 1) // 2, xs, 0.0), axis=0)
        hi = jnp.sum(jnp.where(rank == c // 2, xs, 0.0), axis=0)
        return 0.5 * (lo + hi)
    if rule == "trimmed":
        t = jnp.minimum(trim, (c - 1) // 2)
        keep = (rank >= t) & (rank < c - t)
        kept = jnp.sum(jnp.where(keep, xs, 0.0), axis=0)
        return kept / jnp.maximum(c - 2 * t, 1).astype(jnp.float32)
    raise ValueError(rule)


@functools.partial(jax.jit, static_argnames=("bucket_size", "rule", "trim",
                                             "tile_d", "interpret",
                                             "attack_fn"))
def robust_agg(x, bucket_matrix=None, mask=None, good_mean=None,
               good_std=None, valid=None, bvalid=None, *,
               bucket_size: int = 1, rule: str = "median",
               trim: int = 1, tile_d: int = DEFAULT_TILE_D, interpret=None,
               attack_fn=None):
    """x: (n, d) dense stack OR a ``quantize.WireSrc`` payload -> (d,)
    aggregate, one HBM sweep (of the wire bytes, when compressed).

    Either ``bucket_matrix`` ((nb, n), from ``norm_agg.bucket_matrix`` —
    carries the random permutation + Alg. 2 bucket means on-chip) or the
    legacy ``bucket_size`` over pre-permuted rows. ``attack_fn``/``mask``/
    ``good_mean``/``good_std`` inject the omniscient attack in-kernel.
    ``valid`` ((n,), fault guard) select-zeroes invalid worker rows in the
    prologue and ``bvalid`` ((m,) over the post-bucket rows) switches the
    rule to its masked twin (``_masked_coord_rule_block``); guarded callers
    pass ``faults.guard.masked_bucket_matrix`` as ``bucket_matrix``.
    ``interpret=None`` resolves per backend (kernels/backend.py).
    """
    n, d = src_dims(x)
    vals, specs, names, grid, dp, wire = _assemble(x, bucket_matrix, mask,
                                                   good_mean, good_std,
                                                   tile_d, valid=valid)
    tile = dp // grid[0]
    contiguous = bucket_size if bucket_matrix is None else 1
    if bvalid is not None:
        m = bucket_matrix.shape[0] if bucket_matrix is not None else n
        vals.append(bvalid.reshape(m, 1).astype(jnp.float32))
        specs.append(pl.BlockSpec((m, 1), lambda i: (0, 0)))
        names.append("bvalid")

    def kernel(*refs):
        env = dict(zip(names, refs[:-1]))
        o_ref = refs[-1]
        xb = _prologue(env, attack_fn, wire)    # attacked (+W-bucketed)
        if "bvalid" in env:
            o_ref[...] = _masked_coord_rule_block(xb, env["bvalid"][...],
                                                  rule=rule, trim=trim)
        else:
            o_ref[...] = _coord_rule_block(xb, bucket_size=contiguous,
                                           rule=rule, trim=trim, n=n)

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=specs,
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((dp,), jnp.float32),
        interpret=resolve_interpret(interpret),
    )(*vals)
    return out[:d]
