"""Telemetry-overhead benchmark: steps/sec with the RoundTrace twin ON vs
OFF (repro.obs, DESIGN.md §5).

For every {backend} x {rule} cell the same seeded logreg trajectory runs
twice — ``trace=False`` (the untouched hot path) and ``trace=True`` (the
telemetry twin fires at log cadence, materializing influence / distance /
filter-decision diagnostics and detection precision/recall). Both runs are
compile-warmed off the clock, so the ratio isolates the steady-state cost
of (a) the extra traced jaxpr at 1-in-``LOG_EVERY`` steps and (b) the
host materialization of the trace pytree at those same steps.

Grid (ISSUE 8 acceptance): {gspmd, pallas} x {mean, krum, rfa} ->
``experiments/bench/BENCH_obs.json`` (uploaded by the CI bench job).
The acceptance bar is ``overhead_pct <= 5`` at ``log_every=10``.
"""
import json
import os

from benchmarks.common import ART_DIR, emit
from repro.api import RunSpec

BACKENDS = ("gspmd", "pallas")
RULES = ("mean", "krum", "rfa")
N_WORKERS = 16
DIM = 512
STEPS = 200
LOG_EVERY = 10


def _spec(mode: str, rule: str, trace: bool) -> RunSpec:
    return RunSpec(
        task="logreg", method="marina", n_workers=N_WORKERS,
        n_byz=N_WORKERS // 8, attack="ALIE", aggregator=rule,
        bucket_size=2 if rule != "mean" else 0, agg_mode=mode,
        steps=STEPS, lr=0.1, trace=trace,
        data_kwargs={"dim": DIM, "n_samples": 256, "batch_size": 16})


REPS = 5


def _steps_per_s(spec: RunSpec) -> tuple:
    exp = spec.build()
    # warmup=True compiles both twins off the runner's clock; the last
    # history entry's wall_s is pure post-compile loop time. Best-of-REPS
    # because a single 200-step pass on this small problem is noisy.
    best, result = 0.0, None
    for _ in range(REPS):
        result = exp.run(log_every=LOG_EVERY, warmup=True)
        best = max(best, STEPS / max(result.history[-1]["wall_s"], 1e-9))
    return best, result


def run():
    payload = {"n_workers": N_WORKERS, "dim": DIM, "steps": STEPS,
               "log_every": LOG_EVERY, "cells": []}
    for mode in BACKENDS:
        for rule in RULES:
            name = f"obs/{mode}/{rule}"
            try:
                off_sps, off_res = _steps_per_s(_spec(mode, rule, False))
                on_sps, on_res = _steps_per_s(_spec(mode, rule, True))
            except Exception as e:  # noqa: BLE001 — report, keep grid
                emit(name, 0.0, f"FAILED {type(e).__name__}: {e}")
                continue
            overhead = (off_sps / max(on_sps, 1e-9) - 1.0) * 100.0
            det = on_res.detection_summary()
            identical = (off_res.history[-1]["loss"]
                         == on_res.history[-1]["loss"])
            cell = {
                "agg_mode": mode, "rule": rule,
                "steps_per_s_off": round(off_sps, 1),
                "steps_per_s_on": round(on_sps, 1),
                "overhead_pct": round(overhead, 2),
                "traced_rounds": det["rounds"],
                "detect_precision": round(det["precision"], 3),
                "detect_recall": round(det["recall"], 3),
                "final_loss_identical": identical,
                "spec": _spec(mode, rule, True).to_dict(),
            }
            payload["cells"].append(cell)
            emit(name,
                 1e6 / max(on_sps, 1e-9),   # us per traced-run step
                 f"off={cell['steps_per_s_off']}sps "
                 f"on={cell['steps_per_s_on']}sps "
                 f"overhead={cell['overhead_pct']}% "
                 f"identical={identical}")
    os.makedirs(ART_DIR, exist_ok=True)
    with open(os.path.join(ART_DIR, "BENCH_obs.json"), "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
