"""Paper Figure 8: effect of compression on communication efficiency —
optimality gap vs transmitted bits under the ALIE attack.

Emits gap checkpoints as a function of cumulative uploaded bits per worker
for Byz-VR-MARINA with and without RandK(0.1d)."""
import jax

from benchmarks.common import emit, make_logreg_problem
from repro.core import (ByzVRMarinaConfig, comm_bits, get_aggregator,
                        get_attack, get_compressor, make_init, make_step)
from repro.data import corrupt_labels_logreg, init_logreg_params

KEY = jax.random.PRNGKey(2)
DIM = 30


def run(iters=600):
    data, loss_fn, full, f_star = make_logreg_problem(KEY, dim=DIM)
    anchor = data.stacked()
    d = DIM + 1
    for comp_name, comp in [("none", get_compressor("identity")),
                            ("randk0.1", get_compressor("randk", ratio=0.1))]:
        cfg = ByzVRMarinaConfig(n_workers=5, n_byz=1, p=0.1, lr=0.5,
                                aggregator=get_aggregator("cm",
                                                          bucket_size=2),
                                compressor=comp, attack=get_attack("ALIE"))
        step = jax.jit(make_step(cfg, loss_fn, corrupt_labels_logreg))
        state = make_init(cfg, loss_fn, corrupt_labels_logreg)(
            init_logreg_params(DIM), anchor, KEY)
        k = KEY
        bits = 0
        for it in range(iters):
            k, k1, k2 = jax.random.split(k, 3)
            state, m = step(state, data.sample_batches(k1, 32), anchor, k2)
            bits += comm_bits(cfg, d, bool(m["c_k"]))
            if (it + 1) % 150 == 0:
                gap = float(loss_fn(state["params"], full)) - f_star
                emit(f"fig8/{comp_name}/round{it+1}", 0.0,
                     f"bits={bits};gap={gap:.3e}")


if __name__ == "__main__":
    run()
