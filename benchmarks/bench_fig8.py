"""Paper Figure 8: effect of compression on communication efficiency —
optimality gap vs transmitted bits under the ALIE attack.

Emits gap checkpoints as a function of cumulative uploaded bits per worker
for Byz-VR-MARINA with and without RandK(0.1d). Both curves run through
the sweep-execution engine (``repro.exec``): the per-curve probe rides in
as a ``cell_hook`` (host-side callbacks pin a cell to the serial
in-process path), failures are isolated per cell, and the final-step
summary lands in ``experiments/bench/fig8_summary.json`` next to the
per-row resolved-spec artifacts."""
import os

from benchmarks.common import ART_DIR, emit, logreg_reference
from repro import exec as xc
from repro.api import RunSpec, build

DIM = 30
BASE = RunSpec(task="logreg", method="marina", n_workers=5, n_byz=1,
               p=0.1, lr=0.5, attack="ALIE", aggregator="cm", bucket_size=2,
               data_kwargs={"n_samples": 400, "dim": DIM, "data_seed": 2})

def run(iters=600, log_every=150):
    full, f_star = logreg_reference(build(BASE))
    cells = [("none", BASE.replace(steps=iters)),
             ("randk0.1", BASE.replace(steps=iters, compressor="randk",
                                       compressor_kwargs={"ratio": 0.1}))]

    def hook(run_id, spec, exp):
        def probe(it, state, m):
            gap = float(exp.loss_fn(state["params"], full)) - f_star
            emit(f"fig8/{run_id}/round{it + 1}", 0.0,
                 f"bits={m['comm_bits']:.0f};gap={gap:.3e}", spec=spec)

        return {"callback": probe, "callback_every": log_every}

    srun = xc.run_cells(cells, run_kw={"log_every": iters}, cell_hook=hook)
    xc.write_summary(os.path.join(ART_DIR, "fig8_summary.json"),
                     xc.summarize(srun.artifacts))


if __name__ == "__main__":
    run()
