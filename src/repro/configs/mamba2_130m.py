"""mamba2-130m [ssm] — SSD (state-space duality), attention-free.

24L d_model=768 d_ff=0 vocab=50280, ssm_state=128 [arXiv:2405.21060]
"""
from repro.configs.base import ArchConfig, MAMBA2, register

CONFIG = register(ArchConfig(
    name="mamba2-130m",
    family="ssm",
    citation="arXiv:2405.21060",
    num_layers=24,
    d_model=768,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50_280,
    block_pattern=(MAMBA2,),
    ssm_state=128,
    ssm_expand=2,
    ssm_headdim=64,
    conv_width=4,
    tie_embeddings=True,
    supports_long_context=True,
))
