"""System-fault chaos layer (repro.faults, DESIGN.md §6).

The load-bearing guarantees:

* a ``FaultPlan`` is pure replayable config: JSON round-trip exact, every
  injection a deterministic function of (plan, round key);
* masked aggregation IS the drop-workers oracle: ``tree_masked`` with
  ``valid`` equals the rule run on the physically-dropped subset (exact
  for the coordinate rules, fp-tolerance for the norm rules), and the
  pallas masked kernels match the gspmd masked oracle;
* the guard fails closed end-to-end: NaN/inf rows and undecodable wire
  payloads get zero aggregation weight and the aggregate stays finite;
* the OFF path is untouched: with no plan and the guard off,
  ``engine.message_phase`` traces the identical jaxpr as the raw
  attack+aggregate composition — zero guard equations on the hot path;
* a crash-injected subprocess sweep retries to a summary byte-identical
  to the fault-free run (process-site chaos is absorbed, not recorded).
"""
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.aggregators import get_aggregator
from repro.core.byz_vr_marina import ByzVRMarinaConfig
from repro.core import engine
from repro.faults import guard, inject
from repro.faults.plan import FaultPlan, FaultSpec, as_plan
from tests._jaxpr_scan import iter_eqns

KEY = jax.random.PRNGKey(0)
RULES = ("mean", "cm", "tm", "rfa", "krum")


def _cand(key, n=10, dims=((7,), (3, 2))):
    ks = jax.random.split(key, len(dims))
    return {f"p{i}": jax.random.normal(k, (n,) + d)
            for i, (k, d) in enumerate(zip(ks, dims))}


def _agg(rule, **kw):
    kw.setdefault("n_byz", 2)
    return get_aggregator(rule, **kw)


# ---------------------------------------------------------------------------
# plan: pure, validated, JSON-round-trippable config
# ---------------------------------------------------------------------------

def test_plan_roundtrip_exact():
    plan = FaultPlan(seed=7, faults=(
        FaultSpec("nan_grad", prob=0.5, workers=(1, 3)),
        FaultSpec("corrupt_wire"), FaultSpec("crash", prob=0.1)))
    assert FaultPlan.from_json(plan.to_json()) == plan
    assert FaultPlan.from_dict(json.loads(plan.to_json())) == plan
    # string shorthand + as_plan coercion
    assert as_plan({"faults": ["stale_replay"]}).faults[0].kind == \
        "stale_replay"
    assert as_plan(None) is None and as_plan({}) is None
    assert as_plan(plan) is plan


def test_plan_validation_fails_closed():
    with pytest.raises(ValueError, match="did you mean 'nan_grad'"):
        FaultSpec("nan_gradd")
    with pytest.raises(ValueError, match="prob"):
        FaultSpec("crash", prob=1.5)
    with pytest.raises(ValueError, match="unknown FaultPlan keys"):
        FaultPlan.from_dict({"seed": 0, "fault": []})
    with pytest.raises(ValueError, match="unknown FaultSpec keys"):
        FaultPlan.from_dict({"faults": [{"kind": "crash", "probs": 1}]})


def test_worst_case_faulty_counts_message_sites_only():
    plan = FaultPlan(faults=(FaultSpec("nan_grad", workers=(1, 2)),
                             FaultSpec("corrupt_wire", workers=(2, 3)),
                             FaultSpec("crash", workers=(0, 1, 2, 3, 4))))
    # crash is process-site: absorbed by retry, not a message-budget hit
    assert plan.worst_case_faulty(10) == 3
    assert FaultPlan(faults=(FaultSpec("inf_blowup"),)).worst_case_faulty(6) \
        == 6
    assert FaultPlan(faults=(FaultSpec("nan_grad", prob=0.0),)
                     ).worst_case_faulty(6) == 0


# ---------------------------------------------------------------------------
# injection: deterministic, row-exact, honest rows untouched
# ---------------------------------------------------------------------------

def test_tensor_injection_deterministic_and_row_exact():
    cand = _cand(KEY)
    plan = FaultPlan(seed=3, faults=(
        FaultSpec("nan_grad", workers=(1,)),
        FaultSpec("inf_blowup", workers=(4,)),
        FaultSpec("stale_replay", workers=(6,))))
    out = inject.inject_candidates(plan, KEY, cand)
    out2 = inject.inject_candidates(plan, KEY, cand)
    for a, b in zip(jax.tree.leaves(out), jax.tree.leaves(out2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for leaf, src in zip(jax.tree.leaves(out), jax.tree.leaves(cand)):
        leaf, src = np.asarray(leaf), np.asarray(src)
        assert np.isnan(leaf[1]).all()
        assert np.isposinf(leaf[4]).all()
        assert (leaf[6] == 0.0).all()
        keep = [i for i in range(10) if i not in (1, 4, 6)]
        np.testing.assert_array_equal(leaf[keep], src[keep])
    mask = np.asarray(inject.injected_mask(plan, KEY, 10,
                                           inject.TENSOR_FAULTS))
    np.testing.assert_array_equal(mask, np.isin(np.arange(10), (1, 4, 6)))


def test_probabilistic_injection_replayable_and_key_sensitive():
    plan = FaultPlan(seed=11, faults=(FaultSpec("nan_grad", prob=0.5),))
    m1 = np.asarray(inject.injected_mask(plan, KEY, 64))
    m2 = np.asarray(inject.injected_mask(plan, KEY, 64))
    np.testing.assert_array_equal(m1, m2)
    m3 = np.asarray(inject.injected_mask(plan, jax.random.PRNGKey(9), 64))
    assert (m1 != m3).any()          # a fresh round key redraws the hits
    assert 0 < m1.sum() < 64


# ---------------------------------------------------------------------------
# masked aggregation == drop-workers oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rule", RULES)
def test_tree_masked_equals_drop_oracle(rule):
    """Zero-weighting invalid rows IS dropping them (s=1): identical to the
    rule on the surviving subset even when the dead rows are NaN/inf."""
    agg = _agg(rule)
    cand = _cand(KEY, n=10)
    valid_np = np.ones(10, bool)
    valid_np[[2, 7]] = False
    poisoned = jax.tree.map(
        lambda a: a.at[2].set(jnp.nan).at[7].set(jnp.inf), cand)
    got = agg.tree_masked(KEY, poisoned, jnp.asarray(valid_np))
    want = agg.tree(KEY, jax.tree.map(lambda a: a[valid_np], cand))
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        g, w = np.asarray(g), np.asarray(w)
        assert np.isfinite(g).all()
        if rule == "cm":
            # pure selection, no arithmetic: bit-exact
            np.testing.assert_array_equal(g, w)
        else:
            # the masked twins reduce in a different order over the
            # select-zeroed full stack; parity is within ~1 fp32 ulp
            np.testing.assert_allclose(g, w, rtol=1e-6, atol=1e-6)


@pytest.mark.parametrize("bucket", [0, 2, 3])
@pytest.mark.parametrize("rule", RULES)
def test_pallas_masked_matches_gspmd_masked(rule, bucket):
    """The pallas kernels' ``valid`` operand implements the same masked
    semantics as the gspmd oracle, including renormalized masked bucketing
    and a non-tile-multiple d."""
    from repro.core.sharded_agg import tree_aggregate_pallas
    n = 9 if bucket == 3 else 10
    cfg = ByzVRMarinaConfig(
        n_workers=n, n_byz=1, agg_mode="pallas",
        aggregator=_agg(rule, bucket_size=bucket))
    cand = _cand(KEY, n=n, dims=((5,), (3, 2)))     # d=11, not tile-sized
    valid_np = np.ones(n, bool)
    valid_np[[1, n - 2]] = False
    poisoned = jax.tree.map(lambda a: a.at[1].set(jnp.nan), cand)
    valid = jnp.asarray(valid_np)
    got = tree_aggregate_pallas(cfg, KEY, poisoned, valid=valid)
    want = cfg.aggregator.tree_masked(KEY, poisoned, valid)
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=2e-5, atol=2e-6)


# ---------------------------------------------------------------------------
# the guard-OFF hot path is untouched (jaxpr pin)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["gspmd", "pallas"])
def test_guard_off_jaxpr_identical_to_raw_composition(mode):
    """With fault_plan=None and fault_guard=False, message_phase must trace
    the exact jaxpr of attack+aggregate — the chaos layer's routing is
    Python-static and adds ZERO equations to the hot path."""
    cfg = ByzVRMarinaConfig(n_workers=8, n_byz=0, agg_mode=mode,
                            aggregator=_agg("cm"))
    cand = _cand(KEY, n=8)
    k1, k2 = jax.random.split(KEY)

    def routed(c):
        return engine.message_phase(cfg, k1, k2, c)

    def raw(c):
        if mode == "pallas":
            from repro.core.sharded_agg import tree_aggregate_pallas
            return tree_aggregate_pallas(cfg, k2, c)
        return engine.aggregate(cfg, k2, engine.apply_attack(cfg, k1, c))

    assert str(jax.make_jaxpr(routed)(cand)) == \
        str(jax.make_jaxpr(raw)(cand))
    for eqn in iter_eqns(jax.make_jaxpr(routed)(cand).jaxpr):
        assert eqn.primitive.name != "is_finite"


@pytest.mark.parametrize("mode", ["gspmd", "pallas"])
def test_guard_on_adds_finiteness_reduction(mode):
    cfg = ByzVRMarinaConfig(n_workers=8, n_byz=0, agg_mode=mode,
                            aggregator=_agg("cm"), fault_guard=True)
    cand = _cand(KEY, n=8)
    k1, k2 = jax.random.split(KEY)
    prims = {e.primitive.name for e in iter_eqns(jax.make_jaxpr(
        lambda c: engine.message_phase(cfg, k1, k2, c))(cand).jaxpr)}
    assert "is_finite" in prims


# ---------------------------------------------------------------------------
# engine-level graceful degradation
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("mode", ["gspmd", "pallas"])
def test_guarded_phase_equals_physical_drop(mode):
    """End to end through the engine: a plan that NaNs workers {8, 9} plus
    the guard produces the aggregate of the 8-worker run that never had
    them (bitwise under gspmd, kernel tolerance under pallas)."""
    plan = FaultPlan(seed=5, faults=(FaultSpec("nan_grad", workers=(8, 9)),))
    cfg = ByzVRMarinaConfig(n_workers=10, n_byz=0, agg_mode=mode,
                            aggregator=_agg("cm"), fault_plan=plan,
                            fault_guard=True)
    cfg_sub = ByzVRMarinaConfig(n_workers=8, n_byz=0, agg_mode=mode,
                                aggregator=_agg("cm"))
    cand = _cand(KEY, n=10)
    k1, k2 = jax.random.split(KEY)
    got = engine.message_phase(cfg, k1, k2, cand)
    want = engine.message_phase(cfg_sub, k1, k2,
                                jax.tree.map(lambda a: a[:8], cand))
    for g, w in zip(jax.tree.leaves(got), jax.tree.leaves(want)):
        g, w = np.asarray(g), np.asarray(w)
        assert np.isfinite(g).all()
        if mode == "gspmd":
            np.testing.assert_array_equal(g, w)
        else:
            np.testing.assert_allclose(g, w, rtol=2e-5, atol=2e-6)


def test_guard_never_credits_byzantine_rows_back():
    """A byz∩faulty row stays rejected even when the fused attack would
    overwrite it: BF transforms the candidate value, so the attacked row
    is still NaN and crediting it back would poison the kernel."""
    from repro.core.attacks import get_attack
    plan = FaultPlan(seed=1, faults=(FaultSpec("nan_grad", workers=(0, 7)),))
    cfg = ByzVRMarinaConfig(n_workers=10, n_byz=2, agg_mode="pallas",
                            aggregator=_agg("cm"), attack=get_attack("BF"),
                            fault_plan=plan, fault_guard=True)
    cand = _cand(KEY, n=10)
    k1, k2 = jax.random.split(KEY)
    agg = engine.message_phase(cfg, k1, k2, cand)
    for leaf in jax.tree.leaves(agg):
        assert np.isfinite(np.asarray(leaf)).all()


# ---------------------------------------------------------------------------
# wire-site faults: decode guard rejects undecodable payloads
# ---------------------------------------------------------------------------

def test_corrupt_wire_rejected_pinned_seed():
    from repro.core import wire
    from repro.core.compressors import top_k
    comp = top_k(ratio=0.5)
    cand = _cand(KEY, n=8, dims=((33,),))
    qkeys = jax.random.split(jax.random.PRNGKey(2), 8)
    wc = wire.pack_candidates(comp, qkeys, cand)
    plan = FaultPlan(seed=4, faults=(
        FaultSpec("corrupt_wire", workers=(1, 5)),))
    wc2 = inject.inject_wire(plan, KEY, wc)
    pv = np.asarray(guard.payload_valid(wc2))
    # pinned (plan.seed, round key): the bit-flipped sparse indices land
    # outside [0, d) and/or the values go non-finite -> rejected
    assert not pv[1] and not pv[5]
    assert pv[[0, 2, 3, 4, 6, 7]].all()
    # honest rows' payloads are bit-identical through injection
    dense, dense2 = wire.reconstruct(wc), wire.reconstruct(wc2)
    for a, b in zip(jax.tree.leaves(dense), jax.tree.leaves(dense2)):
        keep = [i for i in range(8) if i not in (1, 5)]
        np.testing.assert_array_equal(np.asarray(a)[keep],
                                      np.asarray(b)[keep])


def test_wire_guarded_phase_masks_corrupted_rows():
    from repro.core import wire
    from repro.core.compressors import top_k
    plan = FaultPlan(seed=4, faults=(
        FaultSpec("corrupt_wire", workers=(1, 5)),))
    cfg = ByzVRMarinaConfig(n_workers=8, n_byz=0, agg_mode="pallas",
                            aggregator=_agg("cm"), compressor=top_k(0.5),
                            fault_plan=plan, fault_guard=True)
    cand = _cand(KEY, n=8, dims=((33,),))
    qkeys = jax.random.split(jax.random.PRNGKey(2), 8)
    wc = inject.inject_wire(plan, KEY,
                            wire.pack_candidates(cfg.compressor, qkeys, cand))
    k1, k2 = jax.random.split(KEY)
    (agg, _), valid = wire.wire_message_phase(cfg, k1, k2, wc,
                                              return_info=True,
                                              return_valid=True)
    v = np.asarray(valid)
    assert not v[1] and not v[5] and v.sum() == 6
    for leaf in jax.tree.leaves(agg):
        assert np.isfinite(np.asarray(leaf)).all()
    # and the guarded aggregate equals the masked oracle over the
    # reconstructed stack
    want = cfg.aggregator.tree_masked(k2, wire.reconstruct(wc),
                                      jnp.asarray(v))
    for g, w in zip(jax.tree.leaves(agg), jax.tree.leaves(want)):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w),
                                   rtol=2e-5, atol=2e-6)


# ---------------------------------------------------------------------------
# spec plumbing + fault telemetry through a real run
# ---------------------------------------------------------------------------

def test_runspec_fault_validation():
    from repro.api import RunSpec
    with pytest.raises(ValueError, match="did you mean"):
        RunSpec(faults={"faults": [{"kind": "nan_gradd"}]})
    with pytest.raises(ValueError, match="all_to_all"):
        RunSpec(agg_mode="all_to_all", fault_guard=True)
    with pytest.warns(UserWarning, match="budget"):
        RunSpec(n_workers=5, n_byz=1,
                faults={"faults": [{"kind": "nan_grad",
                                    "workers": [2, 3]}]})


def test_run_reports_fault_recall():
    from repro.api import RunSpec
    spec = RunSpec(task="logreg", method="sgd", n_workers=10, n_byz=2,
                   attack="ALIE", aggregator="cm", bucket_size=0,
                   agg_mode="gspmd", steps=4, seed=0, trace=True,
                   faults={"seed": 2, "faults": [{"kind": "nan_grad",
                                                  "workers": [8, 9]}]},
                   fault_guard=True,
                   data_kwargs={"dim": 12, "n_samples": 64,
                                "batch_size": 8})
    res = spec.run(log_every=1)
    for m in res.history:
        assert np.isfinite(m["loss"])
        assert m["fault_recall"] == 1.0
        assert m["n_fault_rejected"] == 2


def test_verify_jsonl_gates_fault_events(tmp_path):
    """``python -m repro.obs.sink --verify`` fails closed on schema-less or
    non-finite fault events (satellite 6)."""
    from repro.obs.sink import JsonlSink, verify_jsonl

    def stream(name, event):
        p = tmp_path / name
        s = JsonlSink(str(p))
        s.emit(event)
        s.close()
        return str(p)

    ok = stream("ok.jsonl", {"type": "fault", "kind": "nan_grad",
                             "site": "tensor", "rule": "cm"})
    assert verify_jsonl(ok)["fault"] == 1
    with pytest.raises(ValueError, match="malformed fault"):
        verify_jsonl(stream("kind.jsonl",
                            {"type": "fault", "kind": "meteor_strike",
                             "site": "tensor"}))
    with pytest.raises(ValueError, match="malformed fault"):
        verify_jsonl(stream("site.jsonl",
                            {"type": "fault", "kind": "crash",
                             "site": "moon"}))
    with pytest.raises(ValueError, match="non-finite"):
        verify_jsonl(stream("inf.jsonl",
                            {"type": "fault", "kind": "crash",
                             "site": "process", "lag": float("inf")}))


def test_verify_jsonl_chaos_trace_carveout(tmp_path):
    """A chaos-context trace (fault_mask/guard_valid present) may record
    +inf in rule intermediates — the guard's sort-fill for a rejected
    bucket IS inf, and that is honest telemetry. Outside a chaos context
    (or in any other field/event type) non-finite still fails closed."""
    from repro.obs.sink import JsonlSink, verify_jsonl

    def stream(name, *events):
        p = tmp_path / name
        s = JsonlSink(str(p))
        for e in events:
            s.emit(e)
        s.close()
        return str(p)

    chaos = stream("chaos.jsonl",
                   {"type": "trace", "rule": "krum",
                    "guard_valid": [True, True, False],
                    "krum_scores": [1.0, float("inf")],
                    "influence": [0.5, 0.5, float("nan")]})
    assert verify_jsonl(chaos)["trace"] == 1
    # same inf score WITHOUT the chaos declaration: still rejected
    with pytest.raises(ValueError, match="non-finite"):
        verify_jsonl(stream("plain.jsonl",
                            {"type": "trace", "rule": "krum",
                             "krum_scores": [1.0, float("inf")]}))
    # chaos context does not launder non-diagnostic fields or round events
    with pytest.raises(ValueError, match="non-finite"):
        verify_jsonl(stream("field.jsonl",
                            {"type": "trace", "rule": "cm",
                             "guard_valid": [True],
                             "byz_mask": [False],
                             "custom_metric": float("nan")}))
    with pytest.raises(ValueError, match="non-finite"):
        verify_jsonl(stream("round.jsonl",
                            {"type": "round", "loss": float("inf"),
                             "step": 0}))


def test_train_cli_plumbs_faults_into_spec():
    """--faults / --fault-guard reach the resolved RunSpec on the lm path
    (they are auto-generated from RunSpec fields, but spec_from_args builds
    the spec explicitly — a dropped field here fails silently)."""
    from repro.launch.train import build_parser, spec_from_args
    args = build_parser().parse_args(
        ["--steps", "2",
         "--faults", '{"seed": 3, "faults": [{"kind": "nan_grad", '
                     '"workers": [7]}]}',
         "--fault-guard"])
    from repro.faults.plan import as_plan
    spec = spec_from_args(args)
    assert spec.fault_guard is True
    plan = as_plan(spec.faults)
    assert plan is not None and plan.seed == 3
    assert plan.faults[0].kind == "nan_grad"
    assert plan.faults[0].workers == (7,)


# ---------------------------------------------------------------------------
# process-site chaos: a crash-injected sweep converges to the same bytes
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_kill_injected_sweep_summary_identical(tmp_path):
    """Crash-on-first-attempt cells retry on a fresh slot and finish: the
    sweep summary is byte-identical to the fault-free sweep, and the
    ledger keeps the forensic trail (exit 137 + attempt history)."""
    from repro import exec as xc
    from repro.api import RunSpec, Sweep

    base = RunSpec(task="logreg", method="sgd", n_workers=4, n_byz=1,
                   attack="ALIE", aggregator="cm", bucket_size=0, steps=4,
                   data_kwargs={"dim": 8, "n_samples": 32, "batch_size": 8})
    cells = list(Sweep(base, {"lr": (0.5, 0.1)}).expand())
    plan = FaultPlan(seed=0, faults=(FaultSpec("crash", workers=(0,)),))

    def sweep_summary(subdir, fault_plan):
        pool = xc.WorkerPool(max_workers=2, timeout_s=300,
                             jax_platform="cpu", max_retries=2,
                             backoff_s=0.05, fault_plan=fault_plan)
        srun = xc.run_cells(cells, out_dir=str(tmp_path / subdir),
                            pool=pool, batch=False,
                            run_kw={"log_every": 2})
        assert not srun.failures
        path = tmp_path / subdir / "summary.json"
        xc.write_summary(str(path), xc.summarize(srun.artifacts))
        return path

    clean = sweep_summary("clean", None)
    chaotic = sweep_summary("chaos", plan)
    assert clean.read_bytes() == chaotic.read_bytes()

    led = xc.Ledger(str(tmp_path / "chaos" / "ledger.jsonl"))
    recs = [r for r in led.load().values() if r.get("status") == "done"]
    crashed = [r for r in recs if r.get("injected_fault") == "crash"]
    assert len(crashed) == 1
    hist = crashed[0]["attempt_history"]
    assert hist and hist[0]["returncode"] == 137
    assert crashed[0]["attempts"] == 2
