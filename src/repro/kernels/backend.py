"""Kernel-backend resolution, shared by every Pallas entry point.

All kernel wrappers take ``interpret=None`` and resolve it HERE — interpret
mode on CPU/GPU hosts (where the TPU kernels can't compile), compiled on
real TPU backends. Centralizing the default kills the old footgun where
``robust_agg`` hardcoded ``interpret=True`` in its jitted signature, so any
caller bypassing ``ops.py`` silently ran interpret mode on TPU.
"""
from __future__ import annotations

import jax


def resolve_interpret(interpret=None) -> bool:
    """None -> backend-resolved (interpret unless on TPU); bool -> as given."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return bool(interpret)
