"""Ablations over the paper's knobs (App. E.5 discussions):

* p sweep      — "On the choice of p": oracle vs communication tradeoff.
* bucket sweep — s ∈ {1,2,4}: Alg. 2's robustness/variance tradeoff
                 (paper recommends s=2).
* batch sweep  — "On the batchsizes": gains saturate once
                 b ≳ max{∛(cδm²), √m}.
* IS vs US     — Example E.2: importance sampling reaches the target in
                 fewer rounds when 𝓛±(IS) ≪ 𝓛±(US).

Every knob is a ``Sweep`` axis over one base ``RunSpec``, executed through
the sweep engine (``repro.exec``) so a diverging knob setting is isolated
per cell; specs are emitted per row and the fold lands in
``experiments/bench/ablations_summary.json``."""
import os

from benchmarks.common import ART_DIR, emit, final_gap, logreg_reference
from repro import exec as xc
from repro.api import RunSpec, Sweep, build
from repro.core import theory

DIM = 30
BASE = RunSpec(task="logreg", method="marina", n_workers=5, n_byz=1,
               p=0.1, lr=0.5, attack="ALIE", aggregator="cm", bucket_size=2,
               steps=400,
               data_kwargs={"n_samples": 400, "dim": DIM, "data_seed": 5})


def _run_grid(sweep, exp0, full, f_star):
    """-> ({run_id: gap}, artifacts) for one knob sweep. The gap probe only
    needs a loss_fn, identical across cells — reuse the base Experiment's."""
    cells = list(sweep.expand())
    srun = xc.run_cells(cells, run_kw={"log_every": sweep.base.steps})
    gaps = {}
    for run_id, spec in cells:
        if run_id in srun.failures:
            continue
        gaps[run_id] = (spec, final_gap(exp0, srun[run_id], full, f_star))
    return gaps, srun.artifacts


def run():
    exp0 = build(BASE)
    full, f_star = logreg_reference(exp0)
    artifacts = {}

    gaps, arts = _run_grid(Sweep(BASE, {"p": (0.02, 0.1, 0.5)}), exp0,
                           full, f_star)
    artifacts.update(arts)
    for spec, gap in gaps.values():
        emit(f"ablate/p{spec.p}", 0.0, f"gap={gap:.2e}", spec=spec)

    gaps, arts = _run_grid(Sweep(BASE, {"bucket_size": (1, 2, 4)}), exp0,
                           full, f_star)
    artifacts.update(arts)
    for spec, gap in gaps.values():
        emit(f"ablate/bucket{spec.bucket_size}", 0.0, f"gap={gap:.2e}",
             spec=spec)

    gaps, arts = _run_grid(
        Sweep(BASE.replace(steps=300),
              {"data_kwargs.batch_size": (8, 32, 128)}), exp0, full, f_star)
    artifacts.update(arts)
    for spec, gap in gaps.values():
        emit(f"ablate/batch{spec.data_kwargs['batch_size']}", 0.0,
             f"gap={gap:.2e}", spec=spec)

    # importance vs uniform sampling (Example E.2)
    _, lbar = theory.importance_weights(exp0.data.features, 0.01)
    pc = theory.logreg_constants(exp0.data.features, 0.01, n_workers=5)
    call = {"uniform": pc.calL_pm, "importance": lbar}
    gaps, arts = _run_grid(
        Sweep(BASE.replace(steps=250),
              {"data_kwargs.sampling": ("uniform", "importance")}),
        exp0, full, f_star)
    artifacts.update(arts)
    for spec, gap in gaps.values():
        mode = spec.data_kwargs["sampling"]
        emit(f"ablate/sampling-{mode}", 0.0,
             f"gap={gap:.2e};calL={call[mode]:.2f}", spec=spec)

    xc.write_summary(os.path.join(ART_DIR, "ablations_summary.json"),
                     xc.summarize(artifacts))


if __name__ == "__main__":
    run()
