"""Paper Figure 1: optimality gap of 3 aggregation rules (AVG, CM, RFA)
under 5 attacks (NA, LF, BF, ALIE, IPM), homogeneous data, 4 good + 1
byzantine worker, with and without RandK (K = 0.1 d) compression.

Emits one CSV row per (compression, aggregator, attack): the final
optimality gap after ``iters`` rounds plus wall time per round.
"""
import time

import jax

from benchmarks.common import emit, make_logreg_problem
from repro.core import (ByzVRMarinaConfig, get_aggregator, get_attack,
                        get_compressor, make_init, make_step)
from repro.data import corrupt_labels_logreg, init_logreg_params

KEY = jax.random.PRNGKey(0)
ATTACKS = ["NA", "LF", "BF", "ALIE", "IPM"]
AGGS = [("avg", "mean", 0), ("cm", "cm", 2), ("rfa", "rfa", 2)]
DIM = 30


def run(iters=500):
    data, loss_fn, full, f_star = make_logreg_problem(KEY, dim=DIM)
    anchor = data.stacked()
    for comp_name, comp in [("none", get_compressor("identity")),
                            ("randk0.1", get_compressor("randk", ratio=0.1))]:
        for agg_label, agg_rule, bucket in AGGS:
            for attack in ATTACKS:
                cfg = ByzVRMarinaConfig(
                    n_workers=5, n_byz=1, p=0.1, lr=0.5,
                    aggregator=get_aggregator(agg_rule, bucket_size=bucket),
                    compressor=comp, attack=get_attack(attack))
                step = jax.jit(make_step(cfg, loss_fn, corrupt_labels_logreg))
                state = make_init(cfg, loss_fn, corrupt_labels_logreg)(
                    init_logreg_params(DIM), anchor, KEY)
                k = KEY
                t0 = time.perf_counter()
                for it in range(iters):
                    k, k1, k2 = jax.random.split(k, 3)
                    state, _ = step(state, data.sample_batches(k1, 32),
                                    anchor, k2)
                us = (time.perf_counter() - t0) / iters * 1e6
                gap = float(loss_fn(state["params"], full)) - f_star
                emit(f"fig1/{comp_name}/{agg_label}/{attack}", us,
                     f"gap={gap:.3e}")


if __name__ == "__main__":
    run()
