"""Span profiling: jax.profiler traces + the XLA step-marker idiom.

``profile_trace(dir)`` wraps a run in ``jax.profiler.trace`` so the launch
CLIs can dump a TensorBoard-loadable device trace with ``--profile-dir``.
``enable_step_markers()`` applies the XLA step-marker env idiom
(``--xla_step_marker_location=1`` — mark the outer while/training step, 0
would mark the program entry) so profiler timelines show per-step
boundaries; it must run before the first backend touch, which is why the
CLIs call it at parse time rather than inside the run. The flag only
exists in TPU XLA builds — and XLA's env-flag parsing is fail-closed
(an unknown flag aborts the process) — so it is applied only when a TPU
runtime is detectable without initializing the backend.
"""
from __future__ import annotations

import contextlib
import glob
import importlib.util
import os


STEP_MARKER_FLAG = "--xla_step_marker_location=1"


def _tpu_runtime_present() -> bool:
    """TPU detection WITHOUT touching the jax backend (which would freeze
    XLA_FLAGS): an explicit platform request, or the libtpu wheel plus an
    actual accelerator device node (the wheel alone proves nothing — CPU
    images ship it and then fall back)."""
    if "tpu" in os.environ.get("JAX_PLATFORMS", "").lower():
        return True
    return (importlib.util.find_spec("libtpu") is not None
            and bool(glob.glob("/dev/accel*")))


def enable_step_markers() -> None:
    """Prepend the step-marker flag to XLA_FLAGS (idempotent). No-op once
    the backend is initialized — call before any jax import touches it —
    and on non-TPU builds, whose XLA rejects the flag outright."""
    flags = os.environ.get("XLA_FLAGS", "")
    if "xla_step_marker_location" in flags or not _tpu_runtime_present():
        return
    os.environ["XLA_FLAGS"] = (STEP_MARKER_FLAG + (" " + flags if flags
                                                  else ""))


@contextlib.contextmanager
def profile_trace(profile_dir=None):
    """``jax.profiler.trace`` context when ``profile_dir`` is set; a
    nullcontext otherwise, so call sites can wrap unconditionally."""
    if not profile_dir:
        yield
        return
    import jax
    os.makedirs(profile_dir, exist_ok=True)
    with jax.profiler.trace(profile_dir):
        yield


def add_cli_args(ap) -> None:
    """The shared observability CLI surface for the launch drivers."""
    ap.add_argument("--metrics-out-jsonl", metavar="PATH",
                    help="append metric events (rounds, traces, spans) as "
                         "one JSON line each — the obs.sink stream")
    ap.add_argument("--profile-dir", metavar="DIR",
                    help="dump a jax.profiler device trace here "
                         "(TensorBoard-loadable) with XLA step markers")
