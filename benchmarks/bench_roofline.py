"""§Roofline source: reads experiments/dryrun/*.json (produced by
launch/dryrun.py) and emits the three-term roofline table per
(arch, shape, mesh). Run the dry-run sweep first."""
import glob
import json
import os

from benchmarks.common import emit

DRYRUN_DIR = os.environ.get("DRYRUN_DIR", "experiments/dryrun")


def run():
    files = sorted(glob.glob(os.path.join(DRYRUN_DIR, "*.json")))
    if not files:
        emit("roofline/NO-DRYRUN-DATA", 0.0,
             "run: python -m repro.launch.dryrun --all --mesh both")
        return
    for f in files:
        rec = json.load(open(f))
        tag = f"{rec['arch']}/{rec['shape']}/{rec['mesh']}"
        extra = "__".join(os.path.basename(f).split("__")[3:]).replace(
            ".json", "")
        if extra:
            tag += "/" + extra
        if not rec.get("ok"):
            emit(f"roofline/{tag}", 0.0, f"FAILED={rec.get('error')}")
            continue
        r = rec["roofline"]
        emit(f"roofline/{tag}", r["compute_s"] * 1e6,
             (f"compute={r['compute_s']:.3e}s;memory={r['memory_s']:.3e}s;"
              f"collective={r['collective_s']:.3e}s;"
              f"dominant={r['dominant'].replace('_s','')};"
              f"useful_flops={r['useful_flop_ratio']:.3f}" if
              r['useful_flop_ratio'] else
              f"dominant={r['dominant']}"))


if __name__ == "__main__":
    run()
