"""Integration: the paper's headline claims on the logreg task (Sec. 3).

Byz-VR-MARINA converges (near-)linearly to f* under every attack with a
robust aggregator, with and without compression; mean aggregation breaks
under strong attacks; VR beats plain SGD baselines under ALIE.
"""
import jax
import jax.numpy as jnp
import pytest

from repro.core import (ByzVRMarinaConfig, get_aggregator, get_attack,
                        get_compressor, make_init, make_step)
from repro.core.baselines import make_sgd_step
from repro.data import (corrupt_labels_logreg, init_logreg_params,
                        logreg_loss, make_logreg_data)

# full-length convergence runs: minutes of wall clock -> opt-in
pytestmark = pytest.mark.slow

KEY = jax.random.PRNGKey(0)
DIM = 25


@pytest.fixture(scope="module")
def problem():
    data = make_logreg_data(KEY, n_samples=400, dim=DIM, n_workers=5,
                            homogeneous=True)
    loss_fn = logreg_loss(0.01)
    full = {"x": data.features, "y": data.labels}
    p = init_logreg_params(DIM)
    gd = jax.jit(lambda q: jax.tree.map(
        lambda a, g: a - 0.5 * g, q, jax.grad(loss_fn)(q, full)))
    for _ in range(2500):
        p = gd(p)
    return data, loss_fn, full, float(loss_fn(p, full))


def _run_marina(problem, attack, iters=500, compressor=None, agg="cm"):
    data, loss_fn, full, f_star = problem
    cfg = ByzVRMarinaConfig(
        n_workers=5, n_byz=1, p=0.1, lr=0.5,
        aggregator=get_aggregator(agg, bucket_size=2),
        compressor=compressor or get_compressor("identity"),
        attack=get_attack(attack))
    step = jax.jit(make_step(cfg, loss_fn, corrupt_labels_logreg))
    anchor = data.stacked()
    state = make_init(cfg, loss_fn, corrupt_labels_logreg)(
        init_logreg_params(DIM), anchor, KEY)
    k = KEY
    for it in range(iters):
        k, k1, k2 = jax.random.split(k, 3)
        state, _ = step(state, data.sample_batches(k1, 32), anchor, k2)
    return float(loss_fn(state["params"], full)) - f_star


@pytest.mark.parametrize("attack", ["NA", "BF", "ALIE", "IPM"])
def test_marina_converges_under_attack(problem, attack):
    gap = _run_marina(problem, attack)
    assert gap < 1e-4, (attack, gap)


def test_marina_converges_under_label_flip(problem):
    # LF perturbs the honest-looking gradients; CM keeps the gap small
    gap = _run_marina(problem, "LF", iters=600)
    assert gap < 5e-2, gap


def test_marina_with_compression(problem):
    gap = _run_marina(problem, "ALIE",
                      compressor=get_compressor("randk", ratio=0.1))
    assert gap < 1e-4, gap


@pytest.mark.parametrize("agg", ["rfa", "krum", "tm"])
def test_other_robust_aggregators(problem, agg):
    gap = _run_marina(problem, "ALIE", iters=400, agg=agg)
    assert gap < 1e-3, (agg, gap)


def test_mean_aggregation_breaks_under_bf(problem):
    """Non-robust averaging must NOT reach f* under bit-flipping."""
    gap_mean = _run_marina(problem, "BF", iters=300, agg="mean")
    gap_cm = _run_marina(problem, "BF", iters=300)
    assert gap_mean > 10 * max(gap_cm, 1e-8), (gap_mean, gap_cm)


def test_vr_beats_parallel_sgd_under_alie(problem):
    data, loss_fn, full, f_star = problem
    cfg = ByzVRMarinaConfig(n_workers=5, n_byz=1, lr=0.5,
                            aggregator=get_aggregator("cm", bucket_size=2),
                            attack=get_attack("ALIE"))
    init_s, step_s = make_sgd_step(cfg, loss_fn, corrupt_labels_logreg)
    step_s = jax.jit(step_s)
    state = init_s(init_logreg_params(DIM))
    k = KEY
    anchor = data.stacked()
    for it in range(500):
        k, k1, k2 = jax.random.split(k, 3)
        state, _ = step_s(state, data.sample_batches(k1, 32), anchor, k2)
    gap_sgd = float(loss_fn(state["params"], full)) - f_star
    gap_vr = _run_marina(problem, "ALIE")
    # the paper's Fig. 1: SGD stalls at its noise floor, VR goes to f*
    assert gap_vr < gap_sgd / 10, (gap_vr, gap_sgd)


def test_heterogeneous_data_reaches_neighborhood():
    """ζ²>0: convergence to an O(c δ ζ²/p) neighbourhood (Thm. 2.1 floor)."""
    data = make_logreg_data(KEY, n_samples=600, dim=DIM, n_workers=6,
                            homogeneous=False)
    loss_fn = logreg_loss(0.01)
    # f over the good workers' pooled data (workers 2..5 good; 0,1 byz)
    goods = [data.worker_slice(i) for i in range(2, 6)]
    full = {"x": jnp.concatenate([g[0] for g in goods]),
            "y": jnp.concatenate([g[1] for g in goods])}
    p = init_logreg_params(DIM)
    gd = jax.jit(lambda q: jax.tree.map(
        lambda a, g: a - 0.5 * g, q, jax.grad(loss_fn)(q, full)))
    for _ in range(2000):
        p = gd(p)
    f_star = float(loss_fn(p, full))
    cfg = ByzVRMarinaConfig(n_workers=6, n_byz=2, p=0.1, lr=0.2,
                            aggregator=get_aggregator("cm", bucket_size=2),
                            attack=get_attack("ALIE"))
    step = jax.jit(make_step(cfg, loss_fn, corrupt_labels_logreg))
    anchor = data.stacked()
    state = make_init(cfg, loss_fn, corrupt_labels_logreg)(
        init_logreg_params(DIM), anchor, KEY)
    k = KEY
    for it in range(400):
        k, k1, k2 = jax.random.split(k, 3)
        state, _ = step(state, data.sample_batches(k1, 32), anchor, k2)
    gap = float(loss_fn(state["params"], full)) - f_star
    assert gap < 0.1, gap
