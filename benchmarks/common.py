"""Shared benchmark harness utilities.

``emit(name, us, derived, spec=...)`` prints the CSV row every suite always
printed AND, when given the ``RunSpec`` that produced the number, writes
``{name, us, derived, spec}`` JSON under ``experiments/bench/`` (override
with BENCH_ART_DIR) — so every benchmark trajectory is reproducible from its
artifact alone: ``RunSpec.from_dict(json.load(f)["spec"]).run()``.
"""
import json
import os
import time

import jax


ART_DIR = os.environ.get("BENCH_ART_DIR", "experiments/bench")


def time_fn(fn, *args, warmup=2, iters=10):
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6   # us


def emit(name, us, derived="", spec=None):
    print(f"{name},{us:.1f},{derived}")
    if spec is not None:
        path = os.path.join(ART_DIR, name.replace("/", "__") + ".json")
        os.makedirs(os.path.dirname(path), exist_ok=True)
        with open(path, "w") as f:
            json.dump({"name": name, "us": us, "derived": derived,
                       "spec": spec.to_dict()}, f, indent=1)


def logreg_reference(exp, *, gd_iters=2500, gd_lr=0.5):
    """(full_batch, f_star) for a spec-built logreg Experiment: the exact-GD
    optimum on the pooled dataset, shared by every cell of a sweep."""
    from repro.data import logreg_reference as _reference
    full = {"x": exp.data.features, "y": exp.data.labels}
    _, f_star = _reference(exp.loss_fn, full, iters=gd_iters, lr=gd_lr)
    return full, f_star


def final_gap(exp, result, full, f_star):
    """Optimality gap of a RunResult against the shared reference."""
    return float(exp.loss_fn(result.params, full)) - f_star
