"""Subprocess worker entry point for the sweep scheduler.

``python -m repro.exec.worker --spec cell.spec.json --out cell.json``
runs ONE sweep cell in a fresh process and writes the artifact JSON
(``RunResult.to_dict()``) atomically. The scheduler launches this with
per-worker ``CUDA_VISIBLE_DEVICES`` / ``JAX_PLATFORMS`` already pinned in
the environment — device selection must happen before jax initializes,
which is exactly why un-batchable cells get a process each. Exit code 0
means the artifact was written; anything else (traceback on stderr) is a
failed cell the scheduler records and isolates.
"""
from __future__ import annotations

import argparse
import json
import os
import sys


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description="run one sweep cell")
    ap.add_argument("--spec", required=True,
                    help="path to the cell's RunSpec JSON")
    ap.add_argument("--out", required=True,
                    help="artifact path for RunResult.to_dict() JSON")
    ap.add_argument("--run-kw", default="{}",
                    help="JSON dict of loop knobs (log_every, warmup, ...)")
    args = ap.parse_args(argv)

    from repro.api import RunSpec, run
    with open(args.spec) as f:
        spec = RunSpec.from_json(f.read())
    result = run(spec, **json.loads(args.run_kw))

    payload = result.to_dict()
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    tmp = args.out + f".tmp{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(payload, f, indent=1)
    os.replace(tmp, args.out)
    return 0


if __name__ == "__main__":
    sys.exit(main())
