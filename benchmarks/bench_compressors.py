"""Compressor throughput + realized wire compression (Def. 2.2 operators and
the Pallas block quantizer). One row per (compressor, d)."""
import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core.compressors import get_compressor
from repro.kernels.quantize import block_quantize

KEY = jax.random.PRNGKey(0)


def run():
    for d in [1 << 16, 1 << 20]:
        x = jax.random.normal(KEY, (d,))
        for name, kw in [("randk", {"ratio": 0.1}), ("dither", {"levels": 4}),
                         ("natural", {})]:
            comp = get_compressor(name, **kw)
            f = jax.jit(lambda k, a: comp.compress(k, a))
            us = time_fn(f, KEY, x)
            ratio = 32 * d / comp.bits_per_vector(d)
            emit(f"compress/{comp.name}/d{d}", us,
                 f"wire_compression={ratio:.1f}x;omega={comp.omega(d):.3g}")
        u = jax.random.uniform(KEY, (d,))
        fq = jax.jit(lambda a, uu: block_quantize(a, uu, levels=4, block=256,
                                                  interpret=True))
        us = time_fn(fq, x, u, iters=3)
        emit(f"compress/pallas-blockquant/d{d}", us,
             "wire_compression=~8x(4b+block norms)")


if __name__ == "__main__":
    run()
