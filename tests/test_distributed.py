"""Distributed semantics: the jitted Byz-VR-MARINA step on a multi-device
mesh must produce the SAME trajectory as the single-device run (same seeds),
and the sharded aggregation path must equal the gspmd path.

Multi-device CPU requires XLA_FLAGS set before jax init, so these tests run
in subprocesses.
"""
import os
import subprocess
import sys

import pytest

# each case spawns a fresh 8-device jax subprocess -> opt-in
pytestmark = pytest.mark.slow

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import json
import jax, jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import (ByzVRMarinaConfig, get_aggregator, get_attack,
                        get_compressor, make_init, make_step)
from repro.data import (corrupt_labels_logreg, init_logreg_params,
                        logreg_loss, make_logreg_data)

assert jax.device_count() == 8
KEY = jax.random.PRNGKey(0)
DIM = 16
N = 4
data = make_logreg_data(KEY, n_samples=200, dim=DIM, n_workers=N,
                        homogeneous=True)
loss_fn = logreg_loss(0.01)
cfg = ByzVRMarinaConfig(n_workers=N, n_byz=1, p=0.3, lr=0.3,
                        aggregator=get_aggregator("cm", bucket_size=2),
                        compressor=get_compressor("randk", ratio=0.5),
                        attack=get_attack("ALIE"))
step_fn = make_step(cfg, loss_fn, corrupt_labels_logreg)
anchor = data.stacked()
state0 = make_init(cfg, loss_fn, corrupt_labels_logreg)(
    init_logreg_params(DIM), anchor, KEY)

def run(jit_kwargs, tag):
    step = jax.jit(step_fn, **jit_kwargs)
    state = jax.tree.map(lambda x: x, state0)
    k = KEY
    losses = []
    for it in range(10):
        k, k1, k2 = jax.random.split(k, 3)
        mb = data.sample_batches(k1, 16)
        state, m = step(state, mb, anchor, k2)
        losses.append(float(m["loss"]))
    return losses, [float(x) for x in
                    jax.device_get(state["params"]["w"]).tolist()]

# single-logical-device reference (everything replicated on device 0)
ref_losses, ref_w = run({}, "ref")

# sharded: worker axis over 'data' (4), model params replicated over 'model'
mesh = jax.make_mesh((4, 2), ("data", "model"))
wspec = NamedSharding(mesh, P("data"))
rep = NamedSharding(mesh, P())
state_sh = {"params": {"w": rep, "b": rep}, "g": {"w": rep, "b": rep},
            "opt_state": None, "step": rep}
batch_sh = {"x": NamedSharding(mesh, P("data", None, None)),
            "y": NamedSharding(mesh, P("data", None))}
with mesh:
    sh_losses, sh_w = run(dict(in_shardings=(state_sh, batch_sh, batch_sh,
                                             rep),
                               out_shardings=None), "sharded")

import numpy as np
err_l = max(abs(a - b) for a, b in zip(ref_losses, sh_losses))
err_w = max(abs(a - b) for a, b in zip(ref_w, sh_w))
print(json.dumps({"err_loss": err_l, "err_w": err_w,
                  "losses": ref_losses[:3]}))
assert err_l < 1e-4, (ref_losses, sh_losses)
assert err_w < 1e-4
print("DISTRIBUTED_OK")
"""

A2A_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
import dataclasses
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.core import ByzVRMarinaConfig, get_aggregator
from repro.core.sharded_agg import tree_aggregate_all_to_all

mesh = jax.make_mesh((4, 2), ("data", "model"))
n = 4
key = jax.random.PRNGKey(0)
sent = {"w": jax.random.normal(key, (n, 6, 8)),
        "b": jax.random.normal(jax.random.fold_in(key, 1), (n, 10))}
specs = {"w": P(None, "model"), "b": P(None)}
agg = get_aggregator("cm", bucket_size=2)
cfg = ByzVRMarinaConfig(n_workers=n, aggregator=agg,
                        worker_axes=("data",), model_axis="model",
                        mesh=mesh, grad_specs=specs, agg_mode="all_to_all")

with mesh:
    got = jax.jit(lambda s: tree_aggregate_all_to_all(cfg, key, s))(sent)
want = agg.tree(key, sent)
import numpy as np
for k in sent:
    np.testing.assert_allclose(np.asarray(got[k]), np.asarray(want[k]),
                               rtol=1e-5, atol=1e-6)
print("A2A_OK")

# Pallas-kernel aggregation path inside the shard_map body: default-on for
# TPU backends (use_pallas_agg auto), pinned here via the env-var override
from repro.core import sharded_agg
assert sharded_agg.use_pallas_agg() == (jax.default_backend() == "tpu")
os.environ["REPRO_PALLAS_AGG"] = "1"
assert sharded_agg.use_pallas_agg()
try:
    with mesh:
        got_p = jax.jit(lambda s: tree_aggregate_all_to_all(cfg, key, s))(sent)
finally:
    os.environ["REPRO_PALLAS_AGG"] = "0"
    assert not sharded_agg.use_pallas_agg()
    del os.environ["REPRO_PALLAS_AGG"]
for k in sent:
    np.testing.assert_allclose(np.asarray(got_p[k]), np.asarray(want[k]),
                               rtol=1e-5, atol=1e-6)
print("A2A_PALLAS_OK")
"""

SPARSE_SCRIPT = r"""
import jax, jax.numpy as jnp
from repro.core import (ByzVRMarinaConfig, get_aggregator, get_attack,
                        get_compressor, make_init, make_step)
from repro.data import (init_logreg_params, logreg_loss, make_logreg_data)

KEY = jax.random.PRNGKey(0)
DIM = 20
data = make_logreg_data(KEY, n_samples=200, dim=DIM, n_workers=4)
loss_fn = logreg_loss(0.01)
full = {"x": data.features, "y": data.labels}

cfg = ByzVRMarinaConfig(
    n_workers=4, n_byz=1, p=0.15, lr=0.4,
    aggregator=get_aggregator("cm", bucket_size=2),
    compressor=get_compressor("randk", ratio=0.5, common_randomness=True),
    attack=get_attack("ALIE"), agg_mode="sparse_support")
step = jax.jit(make_step(cfg, loss_fn))
anchor = data.stacked()
state = make_init(cfg, loss_fn)(init_logreg_params(DIM), anchor, KEY)
k = KEY
l0 = float(loss_fn(state["params"], full))
for it in range(400):
    k, k1, k2 = jax.random.split(k, 3)
    state, m = step(state, data.sample_batches(k1, 16), anchor, k2)
    assert jnp.isfinite(m["loss"])
l1 = float(loss_fn(state["params"], full))
assert l1 < l0 - 0.1, (l0, l1)
print("SPARSE_OK", l0, l1)
"""

SPEC_A2A_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
from repro.api import RunSpec, run

spec = RunSpec(task="logreg", method="marina", n_workers=4, n_byz=1,
               p=0.3, lr=0.3, attack="ALIE", aggregator="cm", bucket_size=2,
               agg_mode="all_to_all", steps=4,
               data_kwargs={"n_samples": 80, "dim": 12, "batch_size": 8})
a2a = run(spec, log_every=1)
ref = run(spec.replace(agg_mode="gspmd"), log_every=1)
err = max(abs(a["loss"] - b["loss"])
          for a, b in zip(a2a.history, ref.history))
assert err < 1e-5, err
print("SPEC_A2A_OK", err)
"""

MESH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import jax
from repro.launch.mesh import make_production_mesh, n_workers, worker_axes

m1 = make_production_mesh()
assert dict(m1.shape) == {"data": 16, "model": 16}, m1.shape
assert n_workers(m1) == 16
m2 = make_production_mesh(multi_pod=True)
assert dict(m2.shape) == {"pod": 2, "data": 16, "model": 16}
assert n_workers(m2) == 32
assert worker_axes(m2) == ("pod", "data")
m3 = make_production_mesh(model_parallel=64)
assert dict(m3.shape) == {"data": 4, "model": 64}
print("MESH_OK")
"""


def _run(src):
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    return subprocess.run([sys.executable, "-c", src], capture_output=True,
                          text=True, env=env, timeout=600)


def test_sharded_step_matches_single_device():
    r = _run(SCRIPT)
    assert "DISTRIBUTED_OK" in r.stdout, r.stdout + r.stderr


def test_production_mesh_shapes():
    r = _run(MESH_SCRIPT)
    assert "MESH_OK" in r.stdout, r.stdout + r.stderr


def test_all_to_all_aggregation_matches_gspmd():
    """§Perf all_to_all sharded CM == reference tree CM on a real mesh,
    with both the jnp and the Pallas-kernel per-device rules."""
    r = _run(A2A_SCRIPT)
    assert "A2A_OK" in r.stdout, r.stdout + r.stderr
    assert "A2A_PALLAS_OK" in r.stdout, r.stdout + r.stderr


def test_run_spec_all_to_all_matches_gspmd():
    """The declarative API's agg_mode="all_to_all" (mesh derived from the
    visible devices by api.runner) must match the gspmd trajectory."""
    r = _run(SPEC_A2A_SCRIPT)
    assert "SPEC_A2A_OK" in r.stdout, r.stdout + r.stderr


def test_sparse_support_mode_trains():
    """§Perf sparse-support (common-randomness RandK) trains under attack."""
    r = _run(SPARSE_SCRIPT)
    assert "SPARSE_OK" in r.stdout, r.stdout + r.stderr
