"""Serving driver: batched autoregressive decoding with KV/recurrent caches.

  PYTHONPATH=src python -m repro.launch.serve --arch mamba2-130m --reduced \\
      --batch 4 --prompt-len 16 --gen-len 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.models import decode_step, init_cache, init_params


# one shared jit: repeated generate() calls (and the warmup pass) hit the
# same compiled decode step instead of re-tracing a fresh lambda per call
_decode_step = jax.jit(decode_step, static_argnames=("cfg",))


def generate(cfg, params, prompt, gen_len: int, *, temperature: float = 0.0,
             key=None, capacity: int | None = None):
    """prompt: (B, S[, K]) int32. Greedy (or sampled) continuation."""
    b = prompt.shape[0]
    s = prompt.shape[1]
    cap = capacity or (s + gen_len)
    cache = init_cache(cfg, b, cap)

    def step(c, t):
        return _decode_step(params, cfg, c, t)

    # prefill via decode steps (teacher-forcing the prompt)
    logits = None
    for t in range(s):
        tok = prompt[:, t] if cfg.num_codebooks == 1 else prompt[:, t, :]
        logits, cache = step(cache, tok)

    outs = []
    tok = _pick(logits, temperature, key, 0)
    for t in range(gen_len):
        outs.append(tok)
        logits, cache = step(cache, tok)
        tok = _pick(logits, temperature, key, t + 1)
    return jnp.stack(outs, axis=1)


def _pick(logits, temperature, key, t):
    # logits: (B, V) or (B, K, V)
    if temperature <= 0.0 or key is None:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    k = jax.random.fold_in(key, t)
    return jax.random.categorical(k, logits / temperature).astype(jnp.int32)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-130m")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen-len", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = cfg.reduced()
    key = jax.random.PRNGKey(args.seed)
    params = init_params(key, cfg)
    shape = ((args.batch, args.prompt_len) if cfg.num_codebooks == 1 else
             (args.batch, args.prompt_len, cfg.num_codebooks))
    prompt = jax.random.randint(key, shape, 0, cfg.vocab_size)
    # warmup pass: same shapes/capacity as the measured one, so the shared
    # jitted decode step is compiled exactly once here
    t0 = time.time()
    out = jax.block_until_ready(
        generate(cfg, params, prompt, args.gen_len,
                 temperature=args.temperature, key=key))
    t_first = time.time() - t0

    t0 = time.time()
    out = jax.block_until_ready(
        generate(cfg, params, prompt, args.gen_len,
                 temperature=args.temperature, key=key))
    t_steady = time.time() - t0
    toks = args.batch * args.gen_len
    print(f"[serve] {args.arch}: generated {out.shape} — "
          f"compile {max(t_first - t_steady, 0.0):.2f}s, "
          f"steady-state {t_steady:.2f}s ({toks / t_steady:.1f} tok/s; "
          f"first call incl. compile: {toks / t_first:.1f} tok/s)")
    print(out[0][:16])


if __name__ == "__main__":
    main()
