"""api/runner checkpoint-resume: an interrupted-and-resumed run must
reproduce the uninterrupted trajectory exactly (same key schedule, full
engine state restored)."""
import jax
import numpy as np
import pytest

from repro.api import RunSpec, run

STEPS = 6
KILL_AT = 3


def _spec(method="marina", **kw):
    d = dict(task="logreg", method=method, n_workers=5, n_byz=1, p=0.3,
             lr=0.25, attack="ALIE", aggregator="cm", bucket_size=2,
             compressor="randk", compressor_kwargs={"ratio": 0.5},
             steps=STEPS, seed=7,
             data_kwargs={"n_samples": 60, "dim": 8, "batch_size": 8,
                          "data_seed": 0})
    d.update(kw)
    return RunSpec(**d)


def _assert_state_equal(a, b):
    jax.tree.map(lambda x, y: np.testing.assert_array_equal(
        np.asarray(x), np.asarray(y)), a, b)


@pytest.mark.parametrize("method", ["marina", "sgdm"])
def test_resume_reproduces_uninterrupted_run(method, tmp_path):
    spec = _spec(method)
    full = run(spec, log_every=1)

    ck = str(tmp_path / "ck")
    # "interrupted": the runner checkpointed the full engine state at KILL_AT
    run(spec.replace(steps=KILL_AT), log_every=1, checkpoint=ck)
    resumed = run(spec, log_every=1, resume=ck)

    _assert_state_equal(full.state["params"], resumed.state["params"])
    _assert_state_equal(full.state["g"], resumed.state["g"])
    assert int(resumed.state["step"]) == STEPS
    # the resumed segment logs steps KILL_AT..STEPS-1 with matching losses
    assert [h["step"] for h in resumed.history] == list(range(KILL_AT, STEPS))
    tail = [h["loss"] for h in full.history[KILL_AT:]]
    np.testing.assert_array_equal(
        np.asarray(tail, np.float32),
        np.asarray([h["loss"] for h in resumed.history], np.float32))


def test_periodic_checkpoint_then_resume(tmp_path):
    spec = _spec("marina")
    ck = str(tmp_path / "ck")
    # checkpoint_every writes restart points mid-run; simulate a crash by
    # only running KILL_AT steps of the schedule
    run(spec.replace(steps=KILL_AT + 1), log_every=1, checkpoint=ck,
        checkpoint_every=KILL_AT)
    # the *periodic* file at KILL_AT was overwritten by the final save at
    # KILL_AT + 1; resume from it and finish the schedule
    resumed = run(spec, log_every=1, resume=ck)
    full = run(spec, log_every=1)
    _assert_state_equal(full.state["params"], resumed.state["params"])
    assert resumed.history[0]["step"] == KILL_AT + 1


def test_resume_through_train_cli_flags():
    from repro.launch.train import build_parser
    args = build_parser().parse_args(
        ["--steps", "4", "--resume", "foo/ck", "--checkpoint-every", "2"])
    assert args.resume == "foo/ck"
    assert args.checkpoint_every == 2
