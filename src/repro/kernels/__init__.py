"""Pallas TPU kernels for the system's compute hot-spots (DESIGN.md §3):

- robust_agg: fused bucketing + coordinate-wise median/trimmed-mean over the
  worker-stacked matrix (server-side aggregation, one HBM sweep).
- quantize: block-wise l2-dithering compress+dequantize (worker-side).

ops.py = jit'd wrappers (interpret on CPU, compiled on TPU);
ref.py = pure-jnp oracles the tests sweep against.
"""
from repro.kernels import ops, ref  # noqa: F401
