"""FROZEN pre-refactor step implementations — parity reference only.

These are verbatim copies of the seed-era ``core/byz_vr_marina.py`` /
``core/baselines.py`` step factories, kept so tests/test_engine_parity.py
can assert that the unified round engine (core/engine.py +
core/estimators.py) reproduces every legacy trajectory bit-for-bit on a
fixed seed. Do NOT import from application code and do NOT "improve" —
any behavioural change here defeats the parity guarantee.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import tree_utils as tu


def apply_attack(cfg, key, cand):
    if cfg.n_byz == 0 or cfg.attack.name in ("NA", "LF"):
        return cand
    mask = cfg.byz_mask()
    good = ~mask
    means, stds = tu.masked_mean_std(cand, good)

    def leaf(h, m, s):
        v = cfg.attack.apply(key, h, m, s).astype(h.dtype)
        bm = mask.reshape((-1,) + (1,) * (h.ndim - 1))
        return jnp.where(bm, v, h)

    return jax.tree.map(leaf, cand, means, stds)


def _stacked_grads(loss_fn, params, batches, keys):
    def one(batch, key):
        return jax.value_and_grad(loss_fn)(params, batch, key)

    losses, grads = jax.vmap(one)(batches, keys)
    return jnp.mean(losses), grads


def _aggregate(cfg, key, sent):
    # the legacy gspmd/sparse_support dense path (parity tests run on one
    # host, so the all_to_all branch is irrelevant here)
    assert cfg.agg_mode in ("gspmd", "sparse_support")
    return cfg.aggregator.tree(key, sent)


def _sgd_update(params, g, lr):
    return jax.tree.map(
        lambda x, gg: (x.astype(jnp.float32) - lr * gg.astype(jnp.float32)
                       ).astype(x.dtype), params, g)


def _maybe_corrupt(cfg, corrupt_fn, batch):
    if corrupt_fn is not None and cfg.attack.flips_labels and cfg.n_byz:
        return corrupt_fn(batch, cfg.byz_mask())
    return batch


# ---------------------------------------------------------------------------
# Byz-VR-MARINA (seed core/byz_vr_marina.py)
# ---------------------------------------------------------------------------

def make_step(cfg, loss_fn, corrupt_fn=None):
    if cfg.agg_mode == "sparse_support":
        return _make_step_sparse(cfg, loss_fn, corrupt_fn)
    n = cfg.n_workers
    opt = cfg.optimizer

    def maybe_corrupt(batch):
        if corrupt_fn is not None and cfg.attack.flips_labels and cfg.n_byz:
            return corrupt_fn(batch, cfg.byz_mask())
        return batch

    def step(state, batch, anchor, key):
        k_bern, k_grad, k_q, k_attack, k_agg = jax.random.split(key, 5)
        c_k = jax.random.bernoulli(k_bern, cfg.p)
        old_params = state["params"]

        if opt is None:
            new_params = jax.tree.map(
                lambda x, gg: (x.astype(jnp.float32)
                               - cfg.lr * gg.astype(jnp.float32)
                               ).astype(x.dtype),
                old_params, state["g"])
            new_opt = state["opt_state"]
        else:
            new_params, new_opt = opt.update(state["g"], state["opt_state"],
                                             old_params)

        batch = maybe_corrupt(batch)
        anchor = maybe_corrupt(anchor)
        wkeys = tu.per_worker_keys(k_grad, n)

        def full_branch(_):
            loss, grads = _stacked_grads(loss_fn, new_params, anchor, wkeys)
            return loss, grads

        def vr_branch(_):
            qkeys = tu.per_worker_keys(
                k_q, n, common=cfg.compressor.common_randomness)

            def one(b, kg, kq):
                ln, gn = jax.value_and_grad(loss_fn)(new_params, b, kg)
                _, go = jax.value_and_grad(loss_fn)(old_params, b, kg)
                delta = tu.tree_sub(gn, go)
                q = tu.compress_tree(cfg.compressor, kq, delta)
                return ln, q

            losses, qs = jax.vmap(one)(batch, wkeys, qkeys)
            cand = jax.tree.map(lambda g0, q: g0[None] + q, state["g"], qs)
            return jnp.mean(losses), cand

        loss, cand = lax.cond(c_k, full_branch, vr_branch, operand=None)
        sent = apply_attack(cfg, k_attack, cand)
        g_new = _aggregate(cfg, k_agg, sent)

        metrics = {
            "loss": loss,
            "c_k": c_k.astype(jnp.int32),
            "g_norm": jnp.sqrt(tu.tree_norm_sq(g_new)),
        }
        new_state = {"params": new_params, "g": g_new, "opt_state": new_opt,
                     "step": state["step"] + 1}
        return new_state, metrics

    return step


def _make_step_sparse(cfg, loss_fn, corrupt_fn=None):
    from repro.core.compressors import unit_partition

    n = cfg.n_workers
    opt = cfg.optimizer
    comp = cfg.compressor
    assert comp.common_randomness and comp.ratio is not None
    ratio = comp.ratio

    def maybe_corrupt(batch):
        if corrupt_fn is not None and cfg.attack.flips_labels and cfg.n_byz:
            return corrupt_fn(batch, cfg.byz_mask())
        return batch

    def support_take(leaf_flat, idx, blk, d):
        pad = (-d) % blk
        xf = jnp.pad(leaf_flat, (0, pad)).reshape(-1, blk)
        return xf[idx]

    def support_put(leaf, idx, blk, vals):
        d = leaf.size
        pad = (-d) % blk
        xf = jnp.pad(leaf.reshape(-1).astype(jnp.float32), (0, pad))
        xf = xf.reshape(-1, blk).at[idx].set(vals)
        return xf.reshape(-1)[:d].reshape(leaf.shape).astype(leaf.dtype)

    def step(state, batch, anchor, key):
        k_bern, k_grad, k_q, k_attack, k_agg = jax.random.split(key, 5)
        c_k = jax.random.bernoulli(k_bern, cfg.p)
        old_params = state["params"]
        if opt is None:
            new_params = jax.tree.map(
                lambda x, gg: (x.astype(jnp.float32)
                               - cfg.lr * gg.astype(jnp.float32)
                               ).astype(x.dtype), old_params, state["g"])
            new_opt = state["opt_state"]
        else:
            new_params, new_opt = opt.update(state["g"], state["opt_state"],
                                             old_params)
        batch = maybe_corrupt(batch)
        anchor = maybe_corrupt(anchor)
        wkeys = tu.per_worker_keys(k_grad, n)

        def full_branch(_):
            loss, grads = _stacked_grads(loss_fn, new_params, anchor, wkeys)
            sent = apply_attack(cfg, k_attack, grads)
            return loss, cfg.aggregator.tree(k_agg, sent)

        def sparse_branch(_):
            g_leaves, treedef = jax.tree.flatten(state["g"])
            meta = []
            for i, gl in enumerate(g_leaves):
                d = gl.size
                blk, n_units = unit_partition(d)
                k_units = max(int(ratio * n_units), 1)
                kk = jax.random.fold_in(k_q, i)
                idx = jax.random.permutation(kk, n_units)[:k_units]
                meta.append((blk, n_units, k_units, idx,
                             n_units / k_units, d))

            def one(b, kg):
                ln, gn = jax.value_and_grad(loss_fn)(new_params, b, kg)
                _, go = jax.value_and_grad(loss_fn)(old_params, b, kg)
                delta = tu.tree_sub(gn, go)
                d_leaves = jax.tree.leaves(delta)
                vals = []
                for (blk, nu, ku, idx, scale, d), dl in zip(meta, d_leaves):
                    v = support_take(dl.reshape(-1).astype(jnp.float32),
                                     idx, blk, d) * scale
                    vals.append(v)
                return ln, tuple(vals)

            losses, dvals = jax.vmap(one)(batch, wkeys)
            cand = []
            for (blk, nu, ku, idx, scale, d), gl, dv in zip(
                    meta, g_leaves, dvals):
                base = support_take(gl.reshape(-1).astype(jnp.float32),
                                    idx, blk, d)
                cand.append(base[None] + dv)
            cand = tuple(cand)
            sent = apply_attack(cfg, k_attack, cand)
            agg_vals = cfg.aggregator.tree(k_agg, sent)
            new_leaves = [support_put(gl, m[3], m[0], av)
                          for m, gl, av in zip(meta, g_leaves, agg_vals)]
            g_new = jax.tree.unflatten(treedef, new_leaves)
            return jnp.mean(losses), g_new

        loss, g_new = lax.cond(c_k, full_branch, sparse_branch, operand=None)
        metrics = {"loss": loss, "c_k": c_k.astype(jnp.int32),
                   "g_norm": jnp.sqrt(tu.tree_norm_sq(g_new))}
        return ({"params": new_params, "g": g_new, "opt_state": new_opt,
                 "step": state["step"] + 1}, metrics)

    return step


def make_init(cfg, loss_fn, corrupt_fn=None):
    def init(params, anchor, key):
        k_grad, k_attack, k_agg = jax.random.split(key, 3)
        if corrupt_fn is not None and cfg.attack.flips_labels and cfg.n_byz:
            anchor = corrupt_fn(anchor, cfg.byz_mask())
        wkeys = tu.per_worker_keys(k_grad, cfg.n_workers)
        _, grads = _stacked_grads(loss_fn, params, anchor, wkeys)
        sent = apply_attack(cfg, k_attack, grads)
        g0 = _aggregate(cfg, k_agg, sent)
        opt_state = (cfg.optimizer.init(params)
                     if cfg.optimizer is not None else None)
        return {"params": params, "g": g0, "opt_state": opt_state,
                "step": jnp.asarray(0, jnp.int32)}

    return init


# ---------------------------------------------------------------------------
# baselines (seed core/baselines.py)
# ---------------------------------------------------------------------------

def make_sgd_step(cfg, loss_fn, corrupt_fn=None, momentum: float = 0.0):
    n = cfg.n_workers

    def step(state, batch, anchor, key):
        k_grad, k_attack, k_agg = jax.random.split(key, 3)
        batch = _maybe_corrupt(cfg, corrupt_fn, batch)
        wkeys = tu.per_worker_keys(k_grad, n)
        loss, grads = _stacked_grads(loss_fn, state["params"], batch, wkeys)
        if momentum > 0.0:
            m_new = jax.tree.map(
                lambda m, g: ((1 - momentum) * g.astype(jnp.float32)
                              + momentum * m.astype(jnp.float32)),
                state["worker_m"], grads)
            cand = m_new
        else:
            m_new = state["worker_m"]
            cand = grads
        sent = apply_attack(cfg, k_attack, cand)
        g = _aggregate(cfg, k_agg, sent)
        params = _sgd_update(state["params"], g, cfg.lr)
        new_state = {"params": params, "worker_m": m_new,
                     "step": state["step"] + 1}
        return new_state, {"loss": loss,
                           "g_norm": jnp.sqrt(tu.tree_norm_sq(g))}

    def init(params):
        return {"params": params,
                "worker_m": tu.tree_broadcast_leading(
                    jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32),
                                 params), n),
                "step": jnp.zeros((), jnp.int32)}

    return init, step


def make_csgd_step(cfg, loss_fn, corrupt_fn=None):
    n = cfg.n_workers

    def step(state, batch, anchor, key):
        k_grad, k_q, k_attack, k_agg = jax.random.split(key, 4)
        batch = _maybe_corrupt(cfg, corrupt_fn, batch)
        wkeys = tu.per_worker_keys(k_grad, n)
        qkeys = tu.per_worker_keys(k_q, n,
                                   common=cfg.compressor.common_randomness)

        def one(b, kg, kq):
            ln, g = jax.value_and_grad(loss_fn)(state["params"], b, kg)
            return ln, tu.compress_tree(cfg.compressor, kq, g)

        losses, cand = jax.vmap(one)(batch, wkeys, qkeys)
        sent = apply_attack(cfg, k_attack, cand)
        g = _aggregate(cfg, k_agg, sent)
        params = _sgd_update(state["params"], g, cfg.lr)
        return ({"params": params, "step": state["step"] + 1},
                {"loss": jnp.mean(losses),
                 "g_norm": jnp.sqrt(tu.tree_norm_sq(g))})

    def init(params):
        return {"params": params, "step": jnp.zeros((), jnp.int32)}

    return init, step


def make_diana_step(cfg, loss_fn, corrupt_fn=None, alpha=None):
    n = cfg.n_workers

    def step(state, batch, anchor, key):
        k_grad, k_q, k_attack, k_agg = jax.random.split(key, 4)
        batch = _maybe_corrupt(cfg, corrupt_fn, batch)
        wkeys = tu.per_worker_keys(k_grad, n)
        qkeys = tu.per_worker_keys(k_q, n,
                                   common=cfg.compressor.common_randomness)
        h = state["worker_h"]
        a = state["alpha"]

        def one(b, kg, kq, h_i):
            ln, g = jax.value_and_grad(loss_fn)(state["params"], b, kg)
            diff = tu.tree_sub(g, h_i)
            return ln, tu.compress_tree(cfg.compressor, kq, diff)

        losses, qdiff = jax.vmap(one)(batch, wkeys, qkeys, h)
        sent = apply_attack(cfg, k_attack, qdiff)
        agg_diff = _aggregate(cfg, k_agg, sent)
        h_mean = jax.tree.map(lambda x: jnp.mean(x, axis=0), h)
        g = tu.tree_add(h_mean, agg_diff)
        h_new = jax.tree.map(lambda hh, q: hh + a * q, h, qdiff)
        params = _sgd_update(state["params"], g, cfg.lr)
        return ({"params": params, "worker_h": h_new, "alpha": a,
                 "step": state["step"] + 1},
                {"loss": jnp.mean(losses),
                 "g_norm": jnp.sqrt(tu.tree_norm_sq(g))})

    def init(params, d_hint: int = 1):
        omega = cfg.compressor.omega(int(d_hint))
        a = alpha if alpha is not None else 1.0 / (1.0 + omega)
        return {"params": params,
                "worker_h": tu.tree_broadcast_leading(
                    jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32),
                                 params), n),
                "alpha": jnp.asarray(a, jnp.float32),
                "step": jnp.zeros((), jnp.int32)}

    return init, step


def make_br_mvr_step(cfg, loss_fn, corrupt_fn=None, alpha: float = 0.1):
    n = cfg.n_workers

    def step(state, batch, anchor, key):
        k_grad, k_attack, k_agg = jax.random.split(key, 3)
        batch = _maybe_corrupt(cfg, corrupt_fn, batch)
        wkeys = tu.per_worker_keys(k_grad, n)
        params, prev = state["params"], state["prev_params"]

        def one(b, kg, v_i):
            ln, gx = jax.value_and_grad(loss_fn)(params, b, kg)
            _, gp = jax.value_and_grad(loss_fn)(prev, b, kg)
            v_new = jax.tree.map(
                lambda g, vv, go: g.astype(jnp.float32)
                + (1 - alpha) * (vv - go.astype(jnp.float32)),
                gx, v_i, gp)
            return ln, v_new

        losses, v = jax.vmap(one)(batch, wkeys, state["worker_v"])
        sent = apply_attack(cfg, k_attack, v)
        g = _aggregate(cfg, k_agg, sent)
        new_params = _sgd_update(params, g, cfg.lr)
        return ({"params": new_params, "prev_params": params,
                 "worker_v": v, "step": state["step"] + 1},
                {"loss": jnp.mean(losses),
                 "g_norm": jnp.sqrt(tu.tree_norm_sq(g))})

    def init(params, batch, key):
        batch = _maybe_corrupt(cfg, corrupt_fn, batch)
        wkeys = tu.per_worker_keys(key, n)
        _, grads = _stacked_grads(loss_fn, params, batch, wkeys)
        v0 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        return {"params": params, "prev_params": params, "worker_v": v0,
                "step": jnp.zeros((), jnp.int32)}

    return init, step


def make_byrd_svrg_step(cfg, loss_fn, corrupt_fn=None):
    n = cfg.n_workers

    def step(state, batch, anchor, key):
        k_bern, k_grad, k_attack, k_agg = jax.random.split(key, 4)
        c_k = jax.random.bernoulli(k_bern, cfg.p)
        batch = _maybe_corrupt(cfg, corrupt_fn, batch)
        anchor = _maybe_corrupt(cfg, corrupt_fn, anchor)
        wkeys = tu.per_worker_keys(k_grad, n)
        params = state["params"]

        def refresh(_):
            _, fulls = _stacked_grads(loss_fn, params, anchor, wkeys)
            return params, fulls

        def keep(_):
            return state["snapshot"], state["worker_full"]

        w, fulls = lax.cond(c_k, refresh, keep, operand=None)

        def one(b, kg, full_i):
            ln, gx = jax.value_and_grad(loss_fn)(params, b, kg)
            _, gw = jax.value_and_grad(loss_fn)(w, b, kg)
            v = tu.tree_add(tu.tree_sub(gx, gw), full_i)
            return ln, v

        losses, cand = jax.vmap(one)(batch, wkeys, fulls)
        sent = apply_attack(cfg, k_attack, cand)
        g = _aggregate(cfg, k_agg, sent)
        new_params = _sgd_update(params, g, cfg.lr)
        return ({"params": new_params, "snapshot": w, "worker_full": fulls,
                 "step": state["step"] + 1},
                {"loss": jnp.mean(losses),
                 "g_norm": jnp.sqrt(tu.tree_norm_sq(g))})

    def init(params, anchor, key):
        anchor = _maybe_corrupt(cfg, corrupt_fn, anchor)
        wkeys = tu.per_worker_keys(key, n)
        _, fulls = _stacked_grads(loss_fn, params, anchor, wkeys)
        return {"params": params, "snapshot": params, "worker_full": fulls,
                "step": jnp.zeros((), jnp.int32)}

    return init, step
