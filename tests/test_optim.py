"""Optimizer substrate unit tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import SGD, Adam, get_optimizer

KEY = jax.random.PRNGKey(0)


def test_sgd_step():
    opt = SGD(lr=0.1)
    p = {"w": jnp.ones((3,))}
    g = {"w": jnp.full((3,), 2.0)}
    new, _ = opt.update(g, opt.init(p), p)
    np.testing.assert_allclose(np.asarray(new["w"]), 0.8)


def test_sgd_momentum():
    opt = SGD(lr=0.1, momentum=0.9)
    p = {"w": jnp.zeros((1,))}
    s = opt.init(p)
    g = {"w": jnp.ones((1,))}
    p, s = opt.update(g, s, p)        # m=1, p=-0.1
    p, s = opt.update(g, s, p)        # m=1.9, p=-0.29
    np.testing.assert_allclose(np.asarray(p["w"]), -0.29, rtol=1e-6)


def test_adam_matches_reference_step():
    opt = Adam(lr=1e-2, b1=0.9, b2=0.999, eps=1e-8)
    p = {"w": jnp.asarray([1.0])}
    s = opt.init(p)
    g = {"w": jnp.asarray([0.5])}
    p1, s = opt.update(g, s, p)
    # first step: mhat=g, vhat=g^2 -> step = lr * g/(|g|+eps) = lr
    np.testing.assert_allclose(np.asarray(p1["w"]), 1.0 - 1e-2, rtol=1e-5)


def test_adam_weight_decay_decoupled():
    opt = Adam(lr=1e-2, weight_decay=0.1)
    p = {"w": jnp.asarray([1.0])}
    s = opt.init(p)
    g = {"w": jnp.asarray([0.0])}
    p1, _ = opt.update(g, s, p)
    np.testing.assert_allclose(np.asarray(p1["w"]), 1.0 - 1e-2 * 0.1 * 1.0,
                               rtol=1e-5)


def test_registry():
    assert isinstance(get_optimizer("sgd", lr=0.1), SGD)
    assert isinstance(get_optimizer("adam", lr=0.1), Adam)


def test_dtype_preserved():
    opt = Adam(lr=1e-2)
    p = {"w": jnp.ones((3,), jnp.bfloat16)}
    s = opt.init(p)
    g = {"w": jnp.ones((3,), jnp.float32)}
    new, _ = opt.update(g, s, p)
    assert new["w"].dtype == jnp.bfloat16
