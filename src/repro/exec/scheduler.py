"""Sweep-execution orchestrator + multi-process worker pool (DESIGN.md §1.6).

``run_cells`` owns sweep execution end-to-end: it partitions cells into
jit-signature groups (exec/batching.py), runs batchable groups as single
vmapped trajectories in-process, shards the un-batchable remainder across
a bounded subprocess pool (per-worker ``CUDA_VISIBLE_DEVICES`` /
``JAX_PLATFORMS`` pinning, per-cell timeout, failure isolation — one
diverging attack cell records ``failed`` in the ledger and the grid keeps
going), journals every cell in the crash-safe ledger (exec/ledger.py), and
writes one artifact JSON per cell (``RunResult.to_dict()``, the same
payload ``api.sweep.run_sweep`` always wrote).

Resume semantics (``resume=True``): cells whose last ledger record is
``done`` AND whose artifact exists are loaded, not re-run; ``started`` /
``failed`` cells re-run. Granularity is chosen so a killed-and-resumed
sweep is bit-identical to an uninterrupted one:

  * serial cells commit independently — per-cell granularity;
  * a vmapped group commits atomically, and if ANY member is missing the
    WHOLE group re-runs at full width — so a cell never sees a different
    vmap width (and hence different float reassociation) than the
    uninterrupted sweep would have given it.

Keep the batch/pool configuration fixed across resume attempts; switching
e.g. ``batch=False`` mid-sweep re-runs cells on a different engine path,
which is numerically equivalent but not bit-identical.
"""
from __future__ import annotations

import concurrent.futures
import dataclasses
import hashlib
import json
import os
import queue
import shutil
import subprocess
import sys
import tempfile
import traceback
from typing import Callable, Mapping, Optional, Sequence, Tuple

from repro.api.runner import RunResult, build
from repro.api.runner import run as run_spec
from repro.api.spec import RunSpec
from repro.exec import batching
from repro.exec.ledger import Ledger, device_kind, git_sha
from repro.obs.sink import TagSink
from repro.obs.sink import span as obs_span


# ---------------------------------------------------------------------------
# results
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class CompletedCell:
    """A cell loaded from a prior artifact (resume) or a worker subprocess —
    history and spec are available; live device state is not."""
    run_id: str
    payload: dict

    @property
    def history(self) -> list:
        return self.payload.get("history", [])

    @property
    def final(self) -> dict:
        return self.history[-1] if self.history else {}

    @property
    def spec(self) -> RunSpec:
        return RunSpec.from_dict(self.payload["spec"])

    def to_dict(self) -> dict:
        return self.payload


class SweepRun(Mapping):
    """The outcome of ``run_cells`` — a mapping ``run_id -> result``.

    Values are live ``RunResult``s for cells run in-process this call and
    ``CompletedCell``s for cells loaded from artifacts (resume / worker
    subprocesses); both expose ``history`` / ``final`` / ``to_dict()``.
    ``artifacts`` holds every completed cell's JSON payload (what
    ``exec.aggregate`` folds into summaries), ``failures`` the per-cell
    failure records, and ``stats`` the engine accounting (compile counts).
    """

    def __init__(self):
        self.results: dict = {}          # run_id -> RunResult (in-process)
        self.artifacts: dict = {}        # run_id -> payload dict
        self.failures: dict = {}         # run_id -> failure record
        self.skipped: set = set()        # resumed, loaded from artifacts
        self.stats: dict = {"n_cells": 0, "executed_cells": 0,
                            "vmapped_groups": 0, "serial_cells": 0,
                            "subprocess_cells": 0, "step_compiles": 0,
                            "max_group_cache": 0}

    def __getitem__(self, run_id):
        if run_id in self.results:
            return self.results[run_id]
        if run_id in self.artifacts:
            return CompletedCell(run_id, self.artifacts[run_id])
        raise KeyError(run_id)

    def __iter__(self):
        seen = set(self.results)
        yield from self.results
        for rid in self.artifacts:
            if rid not in seen:
                yield rid

    def __len__(self):
        return len(set(self.results) | set(self.artifacts))


# ---------------------------------------------------------------------------
# subprocess worker pool
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class WorkerPool:
    """Bounded local pool of subprocess workers for un-batchable cells.

    Each worker is a fresh ``python -m repro.exec.worker`` process so
    device pinning happens before jax initializes: ``gpu_ids`` round-robins
    ``CUDA_VISIBLE_DEVICES`` across workers, ``jax_platform`` sets
    ``JAX_PLATFORMS`` (e.g. "cpu" to keep sweep workers off the trainer's
    accelerator). ``timeout_s`` bounds each cell attempt; a crashed or
    timed-out attempt is retried up to ``max_retries`` times on a FRESH
    slot (the failed slot goes back to the queue — host re-queue), with
    ``backoff_s · 2^attempt`` sleep between attempts and the per-attempt
    timeout escalating by ``timeout_escalation``× each retry (a cell that
    legitimately needs more time eventually gets it; a hung worker is
    reaped each round). Only a cell that fails every attempt records
    ``failed`` — with the final returncode, the stderr tail and the full
    per-attempt history — and the rest of the grid proceeds.

    ``fault_plan`` (repro.faults, DESIGN.md §6) injects process-site
    chaos: cells selected by the plan's crash/hang specs get ``--fault``
    on their FIRST attempt only, so with retries enabled the sweep
    completes with artifacts byte-identical to a fault-free run.
    """
    max_workers: int = 2
    timeout_s: Optional[float] = None
    gpu_ids: Optional[Sequence[str]] = None
    jax_platform: Optional[str] = None
    extra_env: Mapping = dataclasses.field(default_factory=dict)
    max_retries: int = 2
    backoff_s: float = 0.25
    timeout_escalation: float = 2.0
    fault_plan: Optional[object] = None     # faults.FaultPlan or None
    hang_timeout_s: float = 60.0            # cap for injected hangs when
                                            # timeout_s is None

    def cell_env(self, slot) -> dict:
        env = dict(os.environ)
        env.update(self.extra_env)
        if self.jax_platform:
            env["JAX_PLATFORMS"] = self.jax_platform
        if self.gpu_ids:
            env["CUDA_VISIBLE_DEVICES"] = str(slot)
        return env


def process_fault(plan, run_id: str, idx: int) -> Optional[str]:
    """Which process fault (if any) the plan injects into this cell's first
    attempt. Deterministic in (plan.seed, spec.kind, run_id) — a chaotic
    sweep replays the same kills. ``FaultSpec.workers`` for process-site
    specs are CELL indices in submission order (empty = every cell,
    thinned by ``prob``)."""
    if plan is None:
        return None
    import zlib

    from repro.faults.plan import PROCESS_FAULTS
    for spec in plan.faults:
        if spec.kind not in PROCESS_FAULTS:
            continue
        if spec.workers and idx not in spec.workers:
            continue
        if spec.prob >= 1.0:
            return spec.kind
        h = zlib.crc32(f"{plan.seed}:{spec.kind}:{run_id}".encode())
        if (h % (1 << 20)) / float(1 << 20) < spec.prob:
            return spec.kind
    return None


def _attempt_cell(pool: WorkerPool, slot, run_id: str, spec, out_path: str,
                  run_kw: Mapping, fault: Optional[str],
                  attempt: int) -> dict:
    """One subprocess attempt; returns {"ok": bool, ...} with returncode +
    stderr tail on failure."""
    with tempfile.NamedTemporaryFile(
            "w", suffix=".spec.json", delete=False) as f:
        f.write(spec.to_json())
        spec_path = f.name
    cmd = [sys.executable, "-m", "repro.exec.worker",
           "--spec", spec_path, "--out", out_path,
           "--run-kw", json.dumps(dict(run_kw))]
    if fault is not None:
        cmd += ["--fault", fault]
    timeout = pool.timeout_s
    if timeout is not None:
        timeout = timeout * (pool.timeout_escalation ** attempt)
    elif fault == "hang":
        timeout = pool.hang_timeout_s    # never let injected chaos wedge
    env = pool.cell_env(slot)
    env.setdefault("PYTHONPATH", os.pathsep.join(
        p for p in (os.path.dirname(os.path.dirname(
            os.path.dirname(os.path.abspath(__file__)))),
            os.environ.get("PYTHONPATH")) if p))
    try:
        proc = subprocess.run(cmd, capture_output=True, text=True,
                              env=env, timeout=timeout)
    except subprocess.TimeoutExpired:
        return {"ok": False, "error": "timeout", "attempt": attempt,
                "slot": str(slot), "injected_fault": fault,
                "detail": f"attempt exceeded {timeout}s"}
    finally:
        os.unlink(spec_path)
    if proc.returncode != 0 or not os.path.exists(out_path):
        return {"ok": False, "error": "worker-failed", "attempt": attempt,
                "slot": str(slot), "injected_fault": fault,
                "returncode": proc.returncode,
                "stderr_tail": (proc.stderr or proc.stdout or "")[-2000:]}
    return {"ok": True, "attempt": attempt, "injected_fault": fault}


def _run_cell_subprocess(pool: WorkerPool, slots: queue.Queue, run_id: str,
                         spec, out_path: str, run_kw: Mapping,
                         fault: Optional[str] = None) -> dict:
    """Run one cell with bounded retry; returns a status dict carrying the
    per-attempt history (ledger failure forensics — satellite of the chaos
    layer). ``fault`` is injected on attempt 0 only."""
    import time

    history = []
    max_attempts = 1 + max(int(pool.max_retries), 0)
    for attempt in range(max_attempts):
        slot = slots.get()          # fresh slot per attempt: host re-queue
        try:
            status = _attempt_cell(pool, slot, run_id, spec, out_path,
                                   run_kw, fault if attempt == 0 else None,
                                   attempt)
        finally:
            slots.put(slot)
        if status.get("ok"):
            status["attempts"] = attempt + 1
            status["attempt_history"] = history
            status["injected_fault"] = fault
            return status
        history.append(status)
        if attempt + 1 < max_attempts and pool.backoff_s > 0:
            time.sleep(pool.backoff_s * (2 ** attempt))
    last = history[-1]
    return {"ok": False, "error": last.get("error", "unknown"),
            "detail": last.get("detail") or last.get("stderr_tail", ""),
            "returncode": last.get("returncode"),
            "stderr_tail": last.get("stderr_tail", ""),
            "attempts": max_attempts, "attempt_history": history,
            "injected_fault": fault}


# ---------------------------------------------------------------------------
# orchestration
# ---------------------------------------------------------------------------

def _atomic_write_json(path: str, payload: dict):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(payload, f, indent=1)
    os.replace(tmp, path)


def _artifact_path(out_dir: str, run_id: str) -> str:
    # hand-made run ids may contain path separators (e.g. "fig1/cm/ALIE")
    return os.path.join(out_dir, run_id.replace(os.sep, "__") + ".json")


def _group_digest(key: str) -> str:
    return hashlib.sha1(key.encode()).hexdigest()[:10]


def run_cells(cells: Sequence[Tuple[str, object]], *,
              out_dir: Optional[str] = None,
              ledger_path: Optional[str] = None,
              resume: bool = False,
              batch="auto",
              pool: Optional[WorkerPool] = None,
              run_kw: Optional[Mapping] = None,
              cell_hook: Optional[Callable] = None,
              sink=None,
              verbose: bool = False) -> SweepRun:
    """Execute ``[(run_id, spec), ...]`` through the batched engine.

    ``batch``: "auto" vmaps every eligible multi-seed group (see
    ``batching.can_batch``); False forces per-cell serial execution.
    ``cell_hook(run_id, spec, exp) -> extra run_kw`` attaches per-cell loop
    knobs that need the built Experiment (benchmark probes / early-stop
    callbacks); hooked cells always run serially in-process.
    ``pool`` sends serial cells to pinned worker subprocesses instead
    (hooked cells and non-JSON loop knobs stay in-process — closures don't
    cross processes; without ``out_dir`` the workers hand results back
    through a scratch dir that is cleaned up afterwards).
    ``sink``: a ``repro.obs.sink.MetricSink``. In-process serial cells get
    a run_id-tagged view of it threaded into the runner (round/trace
    events), every cell and vmapped group is wrapped in a span event, and
    the final engine accounting lands as ``sweep_*`` gauges. Subprocess
    cells don't stream (sinks don't cross processes) — their artifacts
    carry the history instead.
    """
    run_kw = dict(run_kw or {})
    srun = SweepRun()
    srun.stats["n_cells"] = len(cells)
    ledger = None
    if ledger_path is None and out_dir:
        ledger_path = os.path.join(out_dir, "ledger.jsonl")
    if ledger_path:
        ledger = Ledger(ledger_path)

    # subprocess workers hand results back as artifact files; without an
    # out_dir they land in a scratch dir so a pool still works (pinning,
    # timeout, isolation) when the caller only wants in-memory results
    tmp_art_dir = None
    if pool is not None and out_dir is None:
        tmp_art_dir = tempfile.mkdtemp(prefix="repro-exec-")
    art_dir = out_dir or tmp_art_dir

    def _jsonable(kw) -> bool:
        try:
            json.dumps(kw)
            return True
        except (TypeError, ValueError):
            return False

    done = ledger.completed() if (resume and ledger) else set()

    def _load_completed(run_id):
        if out_dir is None:
            return False
        path = _artifact_path(out_dir, run_id)
        if not os.path.exists(path):
            return False
        try:
            with open(path) as f:
                srun.artifacts[run_id] = json.load(f)
        except (OSError, json.JSONDecodeError):
            return False
        srun.skipped.add(run_id)
        return True

    prov = {"git_sha": git_sha(), "device_kind": device_kind()}

    def _start(run_id, spec, engine, group):
        if ledger:
            ledger.append(run_id, "started", spec=spec.to_dict(),
                          engine=engine, group=group, **prov)

    def _commit(run_id, result: RunResult, engine, group):
        payload = result.to_dict()
        srun.results[run_id] = result
        srun.artifacts[run_id] = payload
        if out_dir:
            _atomic_write_json(_artifact_path(out_dir, run_id), payload)
        if ledger:
            ledger.append(run_id, "done", engine=engine, group=group,
                          wall_s=result.wall_s, **prov)

    def _fail(run_id, engine, group, err):
        rec = {"engine": engine, "group": group,
               "error": f"{type(err).__name__}: {err}",
               "traceback": traceback.format_exc(limit=20)}
        srun.failures[run_id] = rec
        if ledger:
            ledger.append(run_id, "failed", **{**prov, **rec})

    executor = slots = None
    futures = {}
    sub_idx = [0]          # subprocess submission order (fault selection)
    if pool is not None:
        executor = concurrent.futures.ThreadPoolExecutor(pool.max_workers)
        slots = queue.Queue()
        ids = list(pool.gpu_ids) if pool.gpu_ids else list(
            range(pool.max_workers))
        for s in ids:
            slots.put(s)

    def _run_serial(run_id, spec, group):
        if run_id in done and _load_completed(run_id):
            return
        kw = dict(run_kw)
        exp = None
        if cell_hook is not None:
            exp = build(spec)
            kw.update(cell_hook(run_id, spec, exp) or {})
        if pool is not None and exp is None and _jsonable(kw):
            _start(run_id, spec, "subprocess", group)
            out_path = _artifact_path(art_dir, run_id)
            fault = process_fault(pool.fault_plan, run_id, sub_idx[0])
            sub_idx[0] += 1
            fut = executor.submit(_run_cell_subprocess, pool, slots, run_id,
                                  spec, out_path, kw, fault)
            futures[fut] = (run_id, out_path, group)
            return
        engine = "serial"
        _start(run_id, spec, engine, group)
        if sink is not None and "sink" not in kw:
            kw["sink"] = TagSink(sink, run_id=run_id)
        try:
            with obs_span(sink, "cell", run_id=run_id, engine=engine):
                if exp is not None:
                    result = exp.run(**kw)
                else:
                    result = run_spec(spec, **kw)
        except Exception as e:                    # noqa: BLE001 — isolate
            _fail(run_id, engine, group, e)
            return
        srun.stats["executed_cells"] += 1
        srun.stats["serial_cells"] += 1
        srun.stats["step_compiles"] += 1
        _commit(run_id, result, engine, group)

    for key, members in batching.group_cells(cells):
        digest = _group_digest(key)
        batchable = (batch is not False    # "auto"/True both allow vmap
                     and cell_hook is None
                     and batching.can_batch(members, run_kw))
        if not batchable:
            for run_id, spec in members:
                _run_serial(run_id, spec, digest)
            continue
        # vmapped groups commit atomically: resume either skips the whole
        # group or re-runs it at full width (bit-identical either way).
        if done.issuperset(rid for rid, _ in members):
            if all(_load_completed(rid) for rid, _ in members):
                continue
            for rid, _ in members:       # torn artifacts: recompute
                srun.artifacts.pop(rid, None)
                srun.skipped.discard(rid)
        for run_id, spec in members:
            _start(run_id, spec, "vmapped", digest)
        try:
            with obs_span(sink, "vmapped_group", group=digest,
                          n_cells=len(members)):
                results, stats = batching.run_group(members, **run_kw)
        except Exception as e:                    # noqa: BLE001 — isolate
            for run_id, _ in members:
                _fail(run_id, "vmapped", digest, e)
            continue
        srun.stats["vmapped_groups"] += 1
        srun.stats["executed_cells"] += len(members)
        srun.stats["step_compiles"] += stats["step_compiles"]
        srun.stats["max_group_cache"] = max(srun.stats["max_group_cache"],
                                            stats["step_compiles"])
        for run_id, _ in members:
            _commit(run_id, results[run_id], "vmapped", digest)

    try:
        for fut in concurrent.futures.as_completed(futures):
            run_id, out_path, group = futures[fut]
            try:
                status = fut.result()
            except Exception as e:                # noqa: BLE001 — isolate
                status = {"ok": False,
                          "error": f"{type(e).__name__}: {e}",
                          "detail": traceback.format_exc(limit=20)}
            if status.get("ok"):
                with open(out_path) as f:
                    srun.artifacts[run_id] = json.load(f)
                srun.stats["executed_cells"] += 1
                srun.stats["subprocess_cells"] += 1
                if status.get("attempts", 1) > 1:
                    srun.stats["retried_cells"] = (
                        srun.stats.get("retried_cells", 0) + 1)
                if ledger:
                    ledger.append(run_id, "done", engine="subprocess",
                                  group=group,
                                  attempts=status.get("attempts", 1),
                                  injected_fault=status.get("injected_fault"),
                                  attempt_history=status.get(
                                      "attempt_history", []),
                                  **prov)
            else:
                rec = {"engine": "subprocess", "group": group,
                       "error": status.get("error", "unknown"),
                       "detail": status.get("detail", ""),
                       "returncode": status.get("returncode"),
                       "stderr_tail": status.get("stderr_tail", ""),
                       "attempts": status.get("attempts", 1),
                       "attempt_history": status.get("attempt_history", []),
                       "injected_fault": status.get("injected_fault")}
                srun.failures[run_id] = rec
                if ledger:
                    ledger.append(run_id, "failed", **{**prov, **rec})
    finally:
        if executor is not None:
            executor.shutdown()
        if tmp_art_dir is not None:
            shutil.rmtree(tmp_art_dir, ignore_errors=True)
    if verbose and srun.failures:
        for rid, rec in srun.failures.items():
            print(f"[exec] FAILED {rid}: {rec['error']}")
    if sink is not None:
        for k, v in srun.stats.items():
            sink.emit({"type": "gauge", "name": f"sweep_{k}", "value": v})
        sink.emit({"type": "counter", "name": "sweep_failures",
                   "value": len(srun.failures)})
    return srun
