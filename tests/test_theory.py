"""Theory module: step sizes, floors, complexity bounds, importance
sampling (Thm 2.1/2.2, Cor. E.1–E.7, Examples E.1/E.2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ByzVRMarinaConfig, get_aggregator, get_attack,
                        make_init, make_step, theory)
from repro.core.baselines import make_br_mvr_step, make_byrd_saga_step
from repro.data import (corrupt_labels_logreg, init_logreg_params,
                        logreg_loss, make_logreg_data)

KEY = jax.random.PRNGKey(0)
DIM = 20


@pytest.fixture(scope="module")
def problem():
    data = make_logreg_data(KEY, n_samples=300, dim=DIM, n_workers=5)
    return data, logreg_loss(0.01), {"x": data.features, "y": data.labels}


def test_marina_A_zero_without_byz_compression_stochasticity():
    pc = theory.ProblemConstants(L=1.0, L_pm=0.0, calL_pm=0.0)
    A = theory.marina_A(pc, p=0.5, b=1, G=4, delta=0.0, c=6.0, omega=0.0)
    assert A == 0.0
    # then gamma = 1/L (recovers GD step)
    assert theory.step_size(pc, p=0.5, b=1, G=4, delta=0.0, c=6.0,
                            omega=0.0) == pytest.approx(1.0)


def test_A_monotonic_in_adversity():
    pc = theory.ProblemConstants(L=2.0, L_pm=0.5, calL_pm=3.0)
    kw = dict(p=0.1, b=32, G=4, omega=0.0, c=6.0)
    a0 = theory.marina_A(pc, delta=0.0, **kw)
    a1 = theory.marina_A(pc, delta=0.1, **kw)
    a2 = theory.marina_A(pc, delta=0.2, **kw)
    assert a0 < a1 < a2
    # more compression (omega) also hurts
    a_w = theory.marina_A(pc, p=0.1, b=32, G=4, delta=0.1, c=6.0, omega=9.0)
    assert a_w > a1


def test_recommended_p():
    assert theory.recommended_p(b=32, m=320, omega=0.0) == pytest.approx(0.1)
    # heavy compression dominates: p = 1/(1+omega)
    assert theory.recommended_p(b=32, m=64, omega=9.0) == pytest.approx(0.1)


def test_error_floor_zero_iff_homogeneous_or_clean():
    assert theory.error_floor(delta=0.2, c=6.0, p=0.1, zeta_sq=0.0) == 0.0
    assert theory.error_floor(delta=0.0, c=6.0, p=0.1, zeta_sq=1.0) == 0.0
    assert theory.error_floor(delta=0.2, c=6.0, p=0.1, zeta_sq=1.0) > 0.0


def test_logreg_constants_and_pl(problem):
    data, loss_fn, full = problem
    pc = theory.logreg_constants(data.features, 0.01, n_workers=5)
    assert pc.mu == pytest.approx(0.02)
    assert pc.L <= pc.calL_pm   # avg smoothness <= worst-sample smoothness


def test_theory_step_size_trains(problem):
    """γ = 1/(L+√2A) with certified (δ,c) must give monotone-ish descent."""
    data, loss_fn, full = problem
    pc = theory.logreg_constants(data.features, 0.01, n_workers=5)
    p = theory.recommended_p(b=32, m=pc.m, omega=0.0)
    gamma = theory.step_size(pc, p=p, b=32, G=4, delta=0.2, c=6.0,
                             omega=0.0, pl=True)
    assert 0 < gamma <= 1 / pc.L
    cfg = ByzVRMarinaConfig(n_workers=5, n_byz=1, p=p, lr=gamma,
                            aggregator=get_aggregator("cm", bucket_size=2),
                            attack=get_attack("ALIE"))
    step = jax.jit(make_step(cfg, loss_fn, corrupt_labels_logreg))
    anchor = data.stacked()
    state = make_init(cfg, loss_fn, corrupt_labels_logreg)(
        init_logreg_params(DIM), anchor, KEY)
    l0 = float(loss_fn(state["params"], full))
    k = KEY
    for it in range(200):
        k, k1, k2 = jax.random.split(k, 3)
        state, _ = step(state, data.sample_batches(k1, 32), anchor, k2)
    assert float(loss_fn(state["params"], full)) < l0 - 0.05


def test_importance_sampling_constants(problem):
    """Example E.2: 𝓛±(IS) ≤ L̄ < max_j L_j = 𝓛±(US) bound."""
    data, _, _ = problem
    probs, lbar = theory.importance_weights(data.features, 0.01)
    pc = theory.logreg_constants(data.features, 0.01, n_workers=5)
    assert lbar < pc.calL_pm          # IS strictly better here
    np.testing.assert_allclose(float(jnp.sum(probs)), 1.0, rtol=1e-5)


def test_importance_sampling_unbiased(problem):
    """Weighted IS minibatch gradient is unbiased for the full gradient."""
    data, loss_fn, full = problem
    probs, _ = theory.importance_weights(data.features, 0.01)
    params = init_logreg_params(DIM)
    params = jax.tree.map(lambda x: x + 0.3, params)
    g_full = jax.grad(loss_fn)(params, full)
    acc = jax.tree.map(jnp.zeros_like, g_full)
    n_draws = 600
    for i in range(n_draws):
        mb = data.sample_batches_importance(jax.random.fold_in(KEY, i), 32,
                                            probs)
        g = jax.grad(loss_fn)(params, {"x": mb["x"][0], "y": mb["y"][0],
                                       "w": mb["w"][0]})
        acc = jax.tree.map(lambda a, b: a + b / n_draws, acc, g)
    err = float(jnp.max(jnp.abs(acc["w"] - g_full["w"])))
    assert err < 0.05, err


def test_comm_bits_per_round_unbiased_vs_contractive_branch():
    """Regression pin (the old formulas assumed unbiased compressors):
    rand-k under MARINA pays the p-weighted full-gradient rounds; top-k
    under Byz-EF21 pays ONE compressed upload every round — the error
    feedback absorbs the bias, there is no correction traffic."""
    from repro.core.compressors import get_compressor
    d, ratio, p = 1000, 0.1, 0.2
    randk = get_compressor("randk", ratio=ratio)
    topk = get_compressor("topk", ratio=ratio)
    # wire formats coincide (k values + k indices)...
    assert randk.bits_per_vector(d) == 100 * 64
    assert topk.bits_per_vector(d) == 100 * 64
    # ...but the per-round expectations do not:
    marina_bits = theory.comm_bits_per_round("marina", randk, d, p=p)
    ef21_bits = theory.comm_bits_per_round("byz_ef21", topk, d, p=p)
    assert marina_bits == pytest.approx(0.2 * 32000 + 0.8 * 6400)  # 11520
    assert ef21_bits == pytest.approx(6400)                        # no p-term
    # dense family ignores the compressor entirely
    assert theory.comm_bits_per_round("saga", randk, d) == 32 * d
    assert theory.comm_bits_per_round("sgdm", topk, d) == 32 * d
    # compressed-every-round family (diana/csgd/cmfilter)
    assert theory.comm_bits_per_round("cmfilter", randk, d) == 6400
    with pytest.raises(KeyError):
        theory.comm_bits_per_round("nope", randk, d)


def test_contractive_delta_native_and_scaled():
    from repro.core.compressors import get_compressor
    d = 200
    assert theory.contractive_delta(get_compressor("topk", ratio=0.1),
                                    d) == pytest.approx(1 - 20 / 200)
    assert theory.contractive_delta(get_compressor("sign"),
                                    d) == pytest.approx(1 - 1 / 200)
    assert theory.contractive_delta(get_compressor("identity"), d) == 0.0
    # unbiased randk: contractive after 1/(1+omega) scaling
    randk = get_compressor("randk", ratio=0.25)
    omega = randk.omega(d)
    assert theory.contractive_delta(randk, d) == pytest.approx(
        omega / (1 + omega))


def test_ef21_step_size_limits_and_monotonicity():
    pc = theory.ProblemConstants(L=2.0, calL_pm=3.0)
    # identity compressor: exact gradients, gamma = 1/L regardless of byz
    assert theory.ef21_step_size(pc, delta_c=0.0) == pytest.approx(0.5)
    assert theory.ef21_step_size(pc, delta_c=0.0,
                                 byz_delta=0.2) == pytest.approx(0.5)
    # heavier contraction and more byzantines both shrink gamma
    g1 = theory.ef21_step_size(pc, delta_c=0.5)
    g2 = theory.ef21_step_size(pc, delta_c=0.9)
    assert 0 < g2 < g1 < 0.5
    g_byz = theory.ef21_step_size(pc, delta_c=0.5, byz_delta=0.2)
    assert g_byz < g1
    # rounds bound scales inversely with gamma * eps^2
    r = theory.ef21_rounds_nc(pc, eps_sq=1e-4, delta0=1.0, delta_c=0.5)
    assert r == pytest.approx(4 * 1.0 / (g1 * 1e-4))
    with pytest.raises(ValueError):
        theory.ef21_step_size(pc, delta_c=1.0)


def test_br_mvr_descends(problem):
    data, loss_fn, full = problem
    cfg = ByzVRMarinaConfig(n_workers=5, n_byz=1, lr=0.3,
                            aggregator=get_aggregator("cm", bucket_size=2),
                            attack=get_attack("ALIE"))
    init, step = make_br_mvr_step(cfg, loss_fn, corrupt_labels_logreg)
    anchor = data.stacked()
    state = jax.jit(init)(init_logreg_params(DIM), anchor, KEY)
    step = jax.jit(step)
    l0 = float(loss_fn(state["params"], full))
    k = KEY
    for it in range(150):
        k, k1, k2 = jax.random.split(k, 3)
        state, m = step(state, data.sample_batches(k1, 32), anchor, k2)
        assert jnp.isfinite(m["loss"])
    assert float(loss_fn(state["params"], full)) < l0 - 0.1


def test_byrd_saga_descends(problem):
    data, loss_fn, full = problem
    m = data.features.shape[0]

    def grad_sample(params, xj, yj):
        return jax.grad(
            lambda p: loss_fn(p, {"x": xj[None], "y": yj[None]}))(params)

    cfg = ByzVRMarinaConfig(n_workers=5, n_byz=1, lr=0.3,
                            aggregator=get_aggregator("rfa", bucket_size=2),
                            attack=get_attack("ALIE"))
    init, step = make_byrd_saga_step(cfg, grad_sample, m,
                                     init_logreg_params(DIM))
    anchor = data.stacked()
    state = init(init_logreg_params(DIM), anchor)
    step = jax.jit(step)
    l0 = float(loss_fn(state["params"], full))
    k = KEY
    for it in range(200):
        k, k1, k2 = jax.random.split(k, 3)
        idx = jax.random.randint(k1, (5, 16), 0, m)
        state, _ = step(state, anchor, idx, k2)
    assert float(loss_fn(state["params"], full)) < l0 - 0.1
