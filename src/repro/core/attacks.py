"""Byzantine attacks (Section 3 of the paper).

Each attack maps the would-be-honest update of a Byzantine worker (and
omniscient statistics of the good workers' updates) to the malicious vector
it actually sends:

    attack(key, honest, good_mean, good_std) -> sent

* NA  — no attack (clean training).
* LF  — label flipping: implemented at the DATA level (data/synthetic.py
        flips labels for byzantine workers); the update hook is identity.
* BF  — bit flipping: send -honest.
* ALIE — "A Little Is Enough" (Baruch et al. 2019): send mean - z*std.
* IPM — inner-product manipulation (Xie et al. 2020): send -(eps)*mean.
* RN  — random gaussian noise (extra, used in tests).

good_mean/good_std are the coordinate-wise mean/std over the good workers'
updates — the standard omniscient-adversary model. In the distributed trainer
these are computed with masked psums over the worker mesh axis.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class Attack:
    name: str
    apply: Callable                 # (key, honest, good_mean, good_std) -> v
    flips_labels: bool = False


def no_attack() -> Attack:
    return Attack("NA", lambda key, h, m, s: h)


def label_flip() -> Attack:
    # the data pipeline flips the byzantine workers' labels; update untouched
    return Attack("LF", lambda key, h, m, s: h, flips_labels=True)


def bit_flip() -> Attack:
    return Attack("BF", lambda key, h, m, s: -h)


def alie(z: float = 1.06) -> Attack:
    """mu_G - z * sigma_G: hides just outside the honest cluster."""
    def apply(key, h, m, s):
        return jnp.broadcast_to((m - z * s).astype(h.dtype), h.shape)
    return Attack("ALIE", apply)


def ipm(eps: float = 0.1) -> Attack:
    """-(eps) * mean of good updates: flips the aggregate's inner product."""
    def apply(key, h, m, s):
        return jnp.broadcast_to((-eps * m).astype(h.dtype), h.shape)
    return Attack("IPM", apply)


def random_noise(scale: float = 10.0) -> Attack:
    def apply(key, h, m, s):
        return scale * jax.random.normal(key, h.shape, h.dtype)
    return Attack("RN", apply)


REGISTRY = {
    "NA": no_attack,
    "LF": label_flip,
    "BF": bit_flip,
    "ALIE": alie,
    "IPM": ipm,
    "RN": random_noise,
}


def get_attack(name: str, **kw) -> Attack:
    return REGISTRY[name](**kw)
