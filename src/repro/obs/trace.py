"""RoundTrace — per-round aggregator-decision telemetry (DESIGN.md §5).

The robust aggregator is the whole point of Byz-VR-MARINA, yet the round's
metrics only report scalars; nothing records *who* Krum selected, what RFA's
Weiszfeld weights converged to, or how much byzantine mass leaked into the
aggregate. ``traced_message_phase`` / ``traced_ingest_message_phase`` are
the telemetry twins of the engine's message phase: they produce the SAME
aggregate — bit-identical, because the aggregation runs through the
identical backend calls (``Aggregator.tree_traced`` on gspmd,
``tree_aggregate_pallas(..., return_info=True)`` on pallas) — plus a
``RoundTrace`` pytree assembled from quantities those backends already hold:

* ``influence``      — (n,) effective weight of each worker's row in the
                       final aggregate: rule weights pushed back through the
                       bucketing operator (``bucket_matrix``) and any
                       per-row staleness scale. Sums to ~1.
* ``dist_to_agg``    — (n,) distance from each SENT vector (post-attack) to
                       the aggregate.
* ``bucket_weights`` — (m,) the rule's weight per (bucketed) row: uniform
                       for mean, final Weiszfeld weights for RFA, the
                       selection one-hot for Krum, coordinate-averaged
                       selection fractions for CM/TM.
* ``byz_mask``       — (n,) ground truth (static worker prefix, or the
                       per-fire buffered mask in repro.serve).
* ``krum_scores`` / ``krum_selected`` / ``rfa_weights`` / ``rfa_residual``
                     — rule-specific intermediates (None for other rules).
* ``fault_mask``     — (n,) ground truth of the chaos layer's injected
                       faults this round (repro.faults, DESIGN.md §6),
                       recomputed from ``(plan, attack_key)`` — injection
                       is deterministic, so no side channel is needed.
                       None when no plan is set.
* ``guard_valid``    — (n,) the fail-closed guard's final row-validity
                       verdict (False = rejected / zero weight); the
                       guard's *detection*, scored against ``fault_mask``
                       by ``repro.obs.detect.fault_metrics``. None when
                       ``fault_guard`` is off.
* ``sampled_mask``   — (n,) this round's participation cohort (DESIGN.md
                       §7): True = the worker spoke. Bit-replayable from
                       ``(spec, seed)`` — the mask is drawn from its own
                       fold_in stream, independent of the attack and
                       fault streams. None at full participation.

Everything here is diagnostics-only: the aggregate value never flows
through this module's extra ops, so numerics cannot drift (pinned by
tests/test_obs.py), and none of it traces when ``RunSpec.trace`` is off.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import engine


@dataclasses.dataclass(frozen=True)
class RoundTrace:
    """One round's aggregator decisions. Registered as a pytree (``rule``
    is static aux data) so it can ride through jit in the step's metrics."""
    rule: str
    influence: Any                 # (n,) f32
    dist_to_agg: Any               # (n,) f32
    bucket_weights: Any            # (m,) f32
    byz_mask: Any                  # (n,) bool
    krum_scores: Any = None        # (m,) f32 | None
    krum_selected: Any = None      # ()   i32 | None
    rfa_weights: Any = None        # (m,) f32 | None
    rfa_residual: Any = None       # ()   f32 | None
    fault_mask: Any = None         # (n,) bool | None (injected ground truth)
    guard_valid: Any = None        # (n,) bool | None (guard's verdict)
    sampled_mask: Any = None       # (n,) bool | None (participation cohort)


_RT_DATA = ("influence", "dist_to_agg", "bucket_weights", "byz_mask",
            "krum_scores", "krum_selected", "rfa_weights", "rfa_residual",
            "fault_mask", "guard_valid", "sampled_mask")

jax.tree_util.register_pytree_node(
    RoundTrace,
    lambda rt: (tuple(getattr(rt, f) for f in _RT_DATA), rt.rule),
    lambda rule, kids: RoundTrace(rule, *kids))


def to_host(rt: RoundTrace) -> dict:
    """Materialize a (device) RoundTrace into a JSON-ready dict: lists /
    scalars only, None fields dropped. This is the only sync point."""
    import numpy as np
    out = {"rule": rt.rule}
    for f in _RT_DATA:
        v = getattr(rt, f)
        if v is None:
            continue
        a = np.asarray(jax.device_get(v))
        if a.ndim == 0:
            out[f] = a.item()
        elif a.dtype == np.bool_:
            out[f] = [bool(x) for x in a]
        else:
            out[f] = [float(x) for x in a]
    return out


# ---------------------------------------------------------------------------
# the traced message phase
# ---------------------------------------------------------------------------

def traced_message_phase(cfg, attack_key, agg_key, cand):
    """Telemetry twin of ``engine.message_phase``: (agg, RoundTrace) with
    ``agg`` bit-identical to the untraced phase. Under partial
    participation (the engine step published a sampled mask) the twin
    mirrors ``engine.participating_message_phase`` instead and the trace
    carries ``sampled_mask``."""
    if engine._PHASE_SAMPLED[0] is not None:
        return _traced_participating(cfg, attack_key, agg_key, cand,
                                     engine._PHASE_SAMPLED[0])
    return traced_ingest_message_phase(cfg, attack_key, agg_key, cand)


def _traced_participating(cfg, attack_key, agg_key, cand, sampled):
    """Telemetry twin of ``engine.participating_message_phase``: the same
    masked backend calls (non-sampled rows at zero weight, attack
    statistics over the sampled cohort) with ``return_info=True``, plus
    the sampled mask recorded in the trace. Aggregates stay bit-identical
    to the untraced participating phase."""
    from repro.core import wire
    plan = getattr(cfg, "fault_plan", None)
    fault_mask = None
    if isinstance(cand, wire.WireCandidates):
        from repro.faults import inject
        if plan is not None and plan.message_faults:
            cand = inject.inject_wire(plan, attack_key, cand)
        if plan is not None:
            fault_mask = inject.injected_mask(plan, attack_key, cand.n,
                                              inject.MESSAGE_FAULTS)
        cand = wire.reconstruct(cand)
    elif plan is not None:
        from repro.faults import inject
        if plan.tensor_faults:
            cand = inject.inject_candidates(plan, attack_key, cand)
        fault_mask = inject.injected_mask(
            plan, attack_key, jax.tree.leaves(cand)[0].shape[0],
            inject.TENSOR_FAULTS)
    if getattr(cfg, "fault_guard", False):
        from repro.faults import guard as fguard
        valid_pre = fguard.finite_row_mask(cand) & sampled
        sent = engine.apply_attack(cfg, attack_key, cand,
                                   stats_valid=valid_pre)
        valid = fguard.finite_row_mask(sent) & sampled
        if cfg.agg_mode == "pallas":
            from repro.core.sharded_agg import tree_aggregate_pallas
            agg, info = tree_aggregate_pallas(cfg, agg_key, sent,
                                              valid=valid, return_info=True)
        else:
            agg, info = cfg.aggregator.tree_masked(agg_key, sent, valid,
                                                   return_info=True)
        return agg, _build_trace(cfg, agg_key, sent, agg, byz_mask=None,
                                 weights=None, info=info, valid=valid,
                                 fault_mask=fault_mask, sampled=sampled)
    clean = cfg.n_byz == 0 or cfg.attack.name in ("NA", "LF")
    if cfg.agg_mode == "pallas":
        from repro.core.sharded_agg import tree_aggregate_pallas
        if clean:
            agg, info = tree_aggregate_pallas(cfg, agg_key, cand,
                                              valid=sampled,
                                              return_info=True)
            sent = cand
        elif cfg.attack.coord_apply is not None:
            ctx = engine.fusable_attack_ctx(cfg, cand, cfg.byz_mask(),
                                            stats_valid=sampled)
            agg, info = tree_aggregate_pallas(cfg, agg_key, cand,
                                              attack_ctx=ctx, valid=sampled,
                                              return_info=True)
            sent = engine.apply_attack(cfg, attack_key, cand,
                                       stats_valid=sampled)
        else:
            sent = engine.apply_attack(cfg, attack_key, cand,
                                       stats_valid=sampled)
            agg, info = tree_aggregate_pallas(cfg, agg_key, sent,
                                              valid=sampled,
                                              return_info=True)
    else:
        sent = engine.apply_attack(cfg, attack_key, cand,
                                   stats_valid=sampled)
        agg, info = cfg.aggregator.tree_masked(agg_key, sent, sampled,
                                               return_info=True)
    return agg, _build_trace(cfg, agg_key, sent, agg, byz_mask=None,
                             weights=None, info=info, valid=sampled,
                             fault_mask=fault_mask, sampled=sampled,
                             record_guard=False)


def traced_ingest_message_phase(cfg, attack_key, agg_key, cand, *,
                                byz_mask=None, weights=None):
    """Telemetry twin of ``engine.ingest_message_phase``.

    The aggregate is produced by the SAME backend calls the engine makes
    (same branch structure: fused attack ctx under pallas, scaled-tree
    oracle under gspmd) with ``return_info=True`` where the norm rules
    compute their scores — so trajectories are bit-identical with tracing
    on. The diagnostics additionally materialize the attacked ``sent``
    stack (the oracle twin of the fused in-kernel injection) to measure
    per-worker distances; that tensor feeds ONLY the trace, never ``g``.

    The chaos layer mirrors ``engine.message_phase`` exactly: the plan's
    injections are re-applied here (deterministic in the attack key, so
    the injected tensors are identical) and the guard reroutes to the same
    masked backend calls ``engine.guarded_message_phase`` makes — plus the
    trace gains ``fault_mask`` (recomputed ground truth) and
    ``guard_valid`` (the guard's verdict).
    """
    from repro.core import wire

    if cfg.agg_mode == "all_to_all":
        raise ValueError(
            "trace is not supported under agg_mode='all_to_all' — the "
            "shard_map backend never holds the stacked candidates in one "
            "place (RunSpec validates this)")

    plan = getattr(cfg, "fault_plan", None)
    guard = bool(getattr(cfg, "fault_guard", False))
    fault_mask = None

    if isinstance(cand, wire.WireCandidates):
        if byz_mask is not None or weights is not None:
            raise TypeError("wire payloads carry no per-entry mask/weights")
        if plan is not None:
            # fault_mask is materialized (zeros if no wire kinds fire) so
            # the trace pytree is branch-stable under lax.cond — MARINA's
            # sync round takes the dense path below, and both branches
            # must return the same RoundTrace structure
            from repro.faults import inject
            if plan.message_faults:
                cand = inject.inject_wire(plan, attack_key, cand)
            fault_mask = inject.injected_mask(plan, attack_key, cand.n,
                                              inject.MESSAGE_FAULTS)
        (agg, info), valid = wire.wire_message_phase(
            cfg, attack_key, agg_key, cand, return_info=True,
            return_valid=True)
        dense = wire.reconstruct(cand)
        sent = engine.apply_attack(cfg, attack_key, dense,
                                   stats_valid=valid)
        return agg, _build_trace(cfg, agg_key, sent, agg, byz_mask=None,
                                 weights=None, info=info, valid=valid,
                                 fault_mask=fault_mask)

    if plan is not None:
        from repro.faults import inject
        if plan.tensor_faults:
            cand = inject.inject_candidates(plan, attack_key, cand)
        fault_mask = inject.injected_mask(
            plan, attack_key, jax.tree.leaves(cand)[0].shape[0],
            inject.TENSOR_FAULTS)

    clean = cfg.attack.name in ("NA", "LF") or (byz_mask is None
                                                and cfg.n_byz == 0)
    if guard:
        return _traced_guarded(cfg, attack_key, agg_key, cand, clean,
                               byz_mask=byz_mask, weights=weights,
                               fault_mask=fault_mask)
    if cfg.agg_mode == "pallas":
        from repro.core.sharded_agg import tree_aggregate_pallas
        if clean:
            agg, info = tree_aggregate_pallas(cfg, agg_key, cand,
                                              weights=weights,
                                              return_info=True)
            sent = cand
        elif cfg.attack.coord_apply is not None:
            mask = byz_mask if byz_mask is not None else cfg.byz_mask()
            ctx = engine.fusable_attack_ctx(cfg, cand, mask)
            agg, info = tree_aggregate_pallas(cfg, agg_key, cand,
                                              attack_ctx=ctx,
                                              weights=weights,
                                              return_info=True)
            # diagnostics twin of the in-kernel injection (same values up
            # to the packed-leaf dtype round-trip); feeds only the trace
            sent = engine.apply_attack(cfg, attack_key, cand, mask=byz_mask)
        else:                        # unfusable attack (RN): materialize
            sent = engine.apply_attack(cfg, attack_key, cand, mask=byz_mask)
            agg, info = tree_aggregate_pallas(cfg, agg_key, sent,
                                              weights=weights,
                                              return_info=True)
    else:                            # gspmd / sparse_support dense rounds
        sent = engine.apply_attack(cfg, attack_key, cand, mask=byz_mask)
        scaled = sent
        if weights is not None:
            w = weights.astype(jnp.float32)
            scaled = jax.tree.map(
                lambda a: (a.astype(jnp.float32)
                           * w.reshape((-1,) + (1,) * (a.ndim - 1))
                           ).astype(a.dtype), sent)
        agg, info = cfg.aggregator.tree_traced(agg_key, scaled)

    return agg, _build_trace(cfg, agg_key, sent, agg, byz_mask=byz_mask,
                             weights=weights, info=info,
                             fault_mask=fault_mask)


def _traced_guarded(cfg, attack_key, agg_key, cand, clean, *, byz_mask,
                    weights, fault_mask):
    """Guarded telemetry twin: the same masked backend calls as
    ``engine.guarded_message_phase`` (full roster) / the guarded branch of
    ``engine.ingest_message_phase`` (buffered mask/weights), with
    ``return_info=True``."""
    from repro.faults import guard as fguard

    valid_pre = fguard.finite_row_mask(cand)
    if byz_mask is None and weights is None:
        if cfg.agg_mode == "pallas":
            from repro.core.sharded_agg import tree_aggregate_pallas
            if clean:
                agg, info = tree_aggregate_pallas(
                    cfg, agg_key, cand, valid=valid_pre, return_info=True)
                sent, valid = cand, valid_pre
            elif cfg.attack.coord_apply is not None:
                ctx = engine.fusable_attack_ctx(cfg, cand, cfg.byz_mask(),
                                                stats_valid=valid_pre)
                agg, info = tree_aggregate_pallas(
                    cfg, agg_key, cand, attack_ctx=ctx, valid=valid_pre,
                    return_info=True)
                sent = engine.apply_attack(cfg, attack_key, cand,
                                           stats_valid=valid_pre)
                valid = valid_pre
            else:
                sent = engine.apply_attack(cfg, attack_key, cand,
                                           stats_valid=valid_pre)
                valid = fguard.finite_row_mask(sent)
                agg, info = tree_aggregate_pallas(
                    cfg, agg_key, sent, valid=valid, return_info=True)
        else:
            sent = engine.apply_attack(cfg, attack_key, cand,
                                       stats_valid=valid_pre)
            valid = fguard.finite_row_mask(sent)
            agg, info = cfg.aggregator.tree_masked(agg_key, sent, valid,
                                                   return_info=True)
        return agg, _build_trace(cfg, agg_key, sent, agg, byz_mask=None,
                                 weights=None, info=info, valid=valid,
                                 fault_mask=fault_mask)

    sent = engine.apply_attack(cfg, attack_key, cand, mask=byz_mask,
                               stats_valid=valid_pre)
    valid = fguard.finite_row_mask(sent)
    if cfg.agg_mode == "pallas":
        from repro.core.sharded_agg import tree_aggregate_pallas
        agg, info = tree_aggregate_pallas(cfg, agg_key, sent,
                                          weights=weights, valid=valid,
                                          return_info=True)
    else:
        scaled = sent
        if weights is not None:
            w = weights.astype(jnp.float32)
            scaled = jax.tree.map(
                lambda a: (a.astype(jnp.float32)
                           * w.reshape((-1,) + (1,) * (a.ndim - 1))
                           ).astype(a.dtype), sent)
        agg, info = cfg.aggregator.tree_masked(agg_key, scaled, valid,
                                               return_info=True)
    return agg, _build_trace(cfg, agg_key, sent, agg, byz_mask=byz_mask,
                             weights=weights, info=info, valid=valid,
                             fault_mask=fault_mask)


# ---------------------------------------------------------------------------
# trace assembly
# ---------------------------------------------------------------------------

def _build_trace(cfg, agg_key, sent, agg, *, byz_mask, weights,
                 info, valid=None, fault_mask=None, sampled=None,
                 record_guard=True) -> RoundTrace:
    """Assemble the RoundTrace from the backend's rule intermediates plus
    the materialized sent stack. All fp32, diagnostics only.

    ``valid`` (guarded runs) select-replaces rejected rows with zero before
    any reduction — a multiplicative zero would propagate their NaN/inf
    (0·NaN = NaN) into every diagnostic — and swaps in the guard's
    renormalized bucket operator so influence reflects the masked rule.
    Rejected rows read zero influence and a finite distance-to-aggregate
    (measured from the zero row that replaced them).
    """
    from repro.kernels.norm_agg import bucket_matrix

    agg_obj = cfg.aggregator
    leaves = jax.tree.leaves(sent)
    n = leaves[0].shape[0]
    x = jnp.concatenate(
        [a.reshape(n, -1).astype(jnp.float32) for a in leaves], axis=1)
    if valid is not None:
        x = jnp.where(valid[:, None], x, 0.0)
    w_row = None if weights is None else weights.astype(jnp.float32)
    xs = x if w_row is None else x * w_row[:, None]

    w_b = None
    if agg_obj.bucket_size > 1 and agg_obj.rule != "mean":
        perm = info.get("perm")
        if perm is None:
            # pallas holds the operator on-chip; the permutation is a pure
            # function of agg_key (engine key schedule), so recompute it
            perm = jax.random.permutation(agg_key, n)
        if valid is not None:
            from repro.faults.guard import masked_bucket_matrix
            w_b, _ = masked_bucket_matrix(perm, n, agg_obj.bucket_size,
                                          valid)
        else:
            w_b = bucket_matrix(perm, n, agg_obj.bucket_size)
        y = w_b @ xs
    else:
        y = xs
    m = y.shape[0]

    rule = agg_obj.rule
    krum_scores = krum_selected = rfa_weights = rfa_residual = None
    if rule == "mean":
        bw = jnp.full((m,), 1.0 / m, jnp.float32)
    elif rule in ("cm", "tm"):
        # per-coordinate selection fractions via ranks of the (bucketed)
        # stack the rule actually sorted, averaged over coordinates
        r = jnp.argsort(jnp.argsort(y, axis=0), axis=0)
        if rule == "cm":
            if m % 2:
                sel = (r == m // 2).astype(jnp.float32)
            else:
                sel = 0.5 * ((r == m // 2 - 1) | (r == m // 2)
                             ).astype(jnp.float32)
        else:
            t = min(agg_obj.trim, (m - 1) // 2)
            sel = ((r >= t) & (r < m - t)).astype(jnp.float32) / (m - 2 * t)
        bw = jnp.mean(sel, axis=1)
    elif rule == "rfa":
        bw = rfa_weights = info["bucket_weights"]
        rfa_residual = jnp.mean(jnp.sqrt(info["rfa_sq"] + agg_obj.eps))
    else:                            # krum
        bw = info["bucket_weights"]
        krum_scores = info["krum_scores"]
        krum_selected = jnp.asarray(info["krum_selected"], jnp.int32)

    infl = bw if w_b is None else bw @ w_b
    if w_row is not None:
        infl = infl * w_row
    if valid is not None:
        infl = jnp.where(valid, infl, 0.0)

    agg_flat = jnp.concatenate(
        [a.reshape(-1).astype(jnp.float32) for a in jax.tree.leaves(agg)])
    dist = jnp.sqrt(jnp.sum((x - agg_flat[None, :]) ** 2, axis=1))

    mask = byz_mask
    if mask is None:
        mask = (cfg.byz_mask() if cfg.n_byz
                else jnp.zeros((n,), bool))
    return RoundTrace(rule=rule, influence=infl, dist_to_agg=dist,
                      bucket_weights=bw, byz_mask=mask,
                      krum_scores=krum_scores, krum_selected=krum_selected,
                      rfa_weights=rfa_weights, rfa_residual=rfa_residual,
                      fault_mask=fault_mask,
                      guard_valid=valid if record_guard else None,
                      sampled_mask=sampled)
