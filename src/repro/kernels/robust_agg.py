"""Pallas TPU kernel: fused bucketing + coordinate-wise robust aggregation.

Server-side hot spot at pod scale: aggregating n worker vectors of
d_local ≈ 1.6e9 coordinates. The fusion argument (DESIGN.md §3): the naive
jnp path materializes the bucketed (n/s, d) intermediate and the sorted
(n/s, d) tensor in HBM — 3 full HBM sweeps of the worker-stacked matrix.
This kernel streams (n, TILE_D) blocks through VMEM once: bucket-mean and
the fixed-n sorting network happen in-register; HBM traffic is exactly
read(n·d) + write(d), the roofline floor for this op.

TPU adaptation: the worker axis (n ≤ 64) lives in the sublane dimension;
TILE_D is lane-aligned (multiple of 128). ``jnp.sort`` along axis 0 inside
the kernel lowers to a fixed-size bitonic network over sublanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


DEFAULT_TILE_D = 2048     # (64 workers x 2048 lanes x 4B = 512 KiB in VMEM)


def _agg_kernel(x_ref, o_ref, *, bucket_size, rule, trim, n):
    x = x_ref[...].astype(jnp.float32)            # (n, TILE_D)
    if bucket_size > 1:
        # matches aggregators._bucketize_perm (Alg. 2): when n is not a
        # bucket multiple the last bucket is padded with the stacked mean,
        # so no trailing worker is silently dropped.
        nb = -(-n // bucket_size)
        pad = nb * bucket_size - n
        if pad:
            fill = jnp.broadcast_to(jnp.mean(x, axis=0, keepdims=True),
                                    (pad, x.shape[1]))
            x = jnp.concatenate([x, fill], axis=0)
        x = x.reshape(nb, bucket_size, -1).mean(axis=1)
    m = x.shape[0]
    if rule == "mean":
        o_ref[...] = jnp.mean(x, axis=0)
        return
    xs = jnp.sort(x, axis=0)
    if rule == "median":
        if m % 2:
            out = xs[m // 2]
        else:
            out = 0.5 * (xs[m // 2 - 1] + xs[m // 2])
    elif rule == "trimmed":
        t = min(trim, (m - 1) // 2)
        out = jnp.mean(xs[t:m - t], axis=0)
    else:
        raise ValueError(rule)
    o_ref[...] = out


@functools.partial(jax.jit, static_argnames=("bucket_size", "rule", "trim",
                                             "tile_d", "interpret"))
def robust_agg(x, *, bucket_size: int = 1, rule: str = "median",
               trim: int = 1, tile_d: int = DEFAULT_TILE_D,
               interpret: bool = True):
    """x: (n, d) (pre-permuted rows) -> (d,) aggregate. Pads d to tile_d."""
    n, d = x.shape
    pad = (-d) % tile_d
    if pad:
        x = jnp.pad(x, ((0, 0), (0, pad)))
    dp = d + pad
    grid = (dp // tile_d,)
    out = pl.pallas_call(
        functools.partial(_agg_kernel, bucket_size=bucket_size, rule=rule,
                          trim=trim, n=n),
        grid=grid,
        in_specs=[pl.BlockSpec((n, tile_d), lambda i: (0, i))],
        out_specs=pl.BlockSpec((tile_d,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((dp,), jnp.float32),
        interpret=interpret,
    )(x)
    return out[:d]
