"""Checkpointing: pytree <-> .npz with structure manifest.

Arrays are gathered to host (fully addressable on the CPU dry-run host;
on a real pod this is where a sharded-save would slot in — the manifest
format already records per-leaf paths so per-shard files are a drop-in).
"""
from __future__ import annotations

import json
import os

import jax
import jax.numpy as jnp
import ml_dtypes
import numpy as np

# dtypes numpy's npz cannot store natively -> stored as raw uint16/uint8 views
_EXOTIC = {"bfloat16": (ml_dtypes.bfloat16, np.uint16),
           "float8_e4m3fn": (ml_dtypes.float8_e4m3fn, np.uint8),
           "float8_e5m2": (ml_dtypes.float8_e5m2, np.uint8)}


def _flatten_with_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(_path_str(p) for p in path)
        out[key] = leaf
    return out


def _path_str(p):
    if hasattr(p, "key"):
        return str(p.key)
    if hasattr(p, "idx"):
        return f"[{p.idx}]"
    return str(p)


def save_checkpoint(path: str, state, step: int | None = None):
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    flat = _flatten_with_paths(state)
    arrays = {}
    manifest = {"step": int(step) if step is not None else None, "leaves": {}}
    for i, (key, leaf) in enumerate(sorted(flat.items())):
        name = f"leaf_{i:05d}"
        arr = np.asarray(jax.device_get(leaf))
        dtype_name = str(arr.dtype)
        if dtype_name in _EXOTIC:
            arr = arr.view(_EXOTIC[dtype_name][1])
        arrays[name] = arr
        manifest["leaves"][key] = {"name": name, "dtype": dtype_name,
                                   "shape": list(arr.shape)}
    np.savez(path + ".npz", **arrays)
    with open(path + ".json", "w") as f:
        json.dump(manifest, f, indent=1)


def load_checkpoint(path: str, like):
    """Restore into the structure of ``like`` (a template pytree)."""
    with open(path + ".json") as f:
        manifest = json.load(f)
    data = np.load(path + ".npz")
    flat_like = _flatten_with_paths(like)
    restored = {}
    for key in flat_like:
        entry = manifest["leaves"][key]
        raw = data[entry["name"]]
        if entry["dtype"] in _EXOTIC:
            raw = raw.view(_EXOTIC[entry["dtype"]][0])
        restored[key] = jnp.asarray(raw)
    # rebuild in tree order
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path, _ in flat:
        key = "/".join(_path_str(p) for p in path)
        leaves.append(restored[key])
    return jax.tree_util.tree_unflatten(treedef, leaves), manifest["step"]
