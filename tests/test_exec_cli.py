"""launch/sweep CLI + subprocess worker pool (pool test is slow: it spawns
fresh jax processes)."""
import json
import os

import pytest

from repro import exec as xc
from repro.api import RunSpec, Sweep
from repro.launch import sweep as sweep_cli

BASE_KW = dict(task="logreg", method="marina", n_workers=5, n_byz=1, p=0.3,
               lr=0.25, attack="ALIE", aggregator="cm", bucket_size=2,
               steps=3,
               data_kwargs={"n_samples": 60, "dim": 8, "batch_size": 8})


def _base_path(tmp_path):
    path = tmp_path / "base.json"
    path.write_text(RunSpec(**BASE_KW).to_json())
    return str(path)


def test_cli_list_expands_grid(tmp_path, capsys):
    out = sweep_cli.main(["--base", _base_path(tmp_path),
                          "--grid", '{"aggregator": ["mean", "cm"]}',
                          "--seeds", "0:2", "--list"])
    assert out is None
    lines = capsys.readouterr().out.strip().splitlines()
    assert len(lines) == 4
    assert "aggregator=mean__seed=0" in lines


def test_cli_runs_grid_and_writes_summary(tmp_path, monkeypatch):
    monkeypatch.setenv("BENCH_ART_DIR", str(tmp_path / "bench"))
    out_dir = tmp_path / "cells"
    summary = sweep_cli.main([
        "--base", _base_path(tmp_path),
        "--grid", '{"aggregator": ["mean", "cm"]}', "--seeds", "0:2",
        "--out-dir", str(out_dir), "--name", "clitest", "--log-every", "3"])
    assert summary["n_cells"] == 4 and summary["n_groups"] == 2
    assert (out_dir / "ledger.jsonl").exists()
    assert (out_dir / "clitest_summary.json").exists()
    with open(tmp_path / "bench" / "clitest_summary.json") as f:
        assert json.load(f) == summary
    # resume: everything skips, summary identical bytes
    summary2 = sweep_cli.main([
        "--base", _base_path(tmp_path),
        "--grid", '{"aggregator": ["mean", "cm"]}', "--seeds", "0:2",
        "--out-dir", str(out_dir), "--name", "clitest", "--log-every", "3",
        "--resume"])
    assert json.dumps(summary, sort_keys=True) == \
           json.dumps(summary2, sort_keys=True)


def test_cli_set_overrides_and_seed_parsing():
    args = sweep_cli.build_parser().parse_args(
        ["--set", "lr=0.1", "--set", "attack=BF",
         "--set", "data_kwargs.dim=8", "--seeds", "0,2,5"])
    sweep = sweep_cli.sweep_from_args(args)
    assert sweep.base.lr == 0.1 and sweep.base.attack == "BF"
    assert sweep.base.data_kwargs["dim"] == 8
    assert sweep.grid["seed"] == (0, 2, 5)


@pytest.mark.slow
def test_worker_pool_subprocess_cells(tmp_path):
    """Un-batchable cells shard over pinned worker subprocesses; a bad cell
    fails in isolation."""
    cells = list(Sweep(RunSpec(**BASE_KW),
                       {"aggregator": ("mean", "cm")}).expand())
    pool = xc.WorkerPool(max_workers=2, timeout_s=300, jax_platform="cpu")
    srun = xc.run_cells(cells, out_dir=str(tmp_path), pool=pool,
                        batch=False, run_kw={"log_every": 3})
    assert not srun.failures
    assert srun.stats["subprocess_cells"] == 2
    for rid, _ in cells:
        assert srun[rid].history                      # loaded CompletedCell
        assert os.path.exists(tmp_path / f"{rid}.json")
    led = xc.Ledger(str(tmp_path / "ledger.jsonl"))
    assert led.completed() == {rid for rid, _ in cells}
