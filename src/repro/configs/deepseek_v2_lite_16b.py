"""deepseek-v2-lite-16b [moe] — MLA kv_lora=512, shared + routed experts top-6.

27L d_model=2048 16H (kv=16) d_ff=1408 vocab=102400, MoE 64e top-6
[arXiv:2405.04434]

NOTE: the assignment line lists both "MoE 64e top-6" and "2 shared+160 routed";
these conflict (the HF card has 64 routed + 2 shared, top-6). We follow
64 routed + 2 shared, top-6, and record the discrepancy in DESIGN.md.
"""
from repro.configs.base import ArchConfig, MLA, MoEConfig, register

CONFIG = register(ArchConfig(
    name="deepseek-v2-lite-16b",
    family="moe",
    citation="arXiv:2405.04434",
    num_layers=27,
    d_model=2048,
    num_heads=16,
    num_kv_heads=16,
    d_ff=1408,
    vocab_size=102_400,
    head_dim=128,
    block_pattern=(MLA,),
    moe=MoEConfig(num_experts=64, top_k=6, num_shared=2, d_expert=1408),
    kv_lora_rank=512,
    qk_rope_dim=64,
))
