"""qwen3-1.7b [dense] — qk_norm, GQA.

28L d_model=2048 16H (GQA kv=8) d_ff=6144 vocab=151936 [hf:Qwen/Qwen3-8B]
"""
from repro.configs.base import ArchConfig, ATTN, register

CONFIG = register(ArchConfig(
    name="qwen3-1.7b",
    family="dense",
    citation="hf:Qwen/Qwen3-8B",
    num_layers=28,
    d_model=2048,
    num_heads=16,
    num_kv_heads=8,
    d_ff=6144,
    vocab_size=151_936,
    head_dim=128,
    block_pattern=(ATTN,),
    qk_norm=True,
    rope_theta=1_000_000.0,
))
