"""sharded_agg.USE_PALLAS_AGG auto default: ON for TPU backends, off on
CPU/GPU hosts, env-var override both ways — and the per-device coordinate
rule the a2a path routes through the fused kernel must match the jnp rule."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import sharded_agg
from repro.core.aggregators import get_aggregator


@pytest.fixture
def pallas_auto(monkeypatch):
    """Reset the toggle to auto and scrub the env override."""
    old = sharded_agg.USE_PALLAS_AGG[0]
    sharded_agg.USE_PALLAS_AGG[0] = None
    monkeypatch.delenv("REPRO_PALLAS_AGG", raising=False)
    yield
    sharded_agg.USE_PALLAS_AGG[0] = old


def test_auto_default_keys_on_backend(pallas_auto):
    assert sharded_agg.use_pallas_agg() == \
           (jax.default_backend() == "tpu")


def test_env_var_opt_in_and_out(pallas_auto, monkeypatch):
    monkeypatch.setenv("REPRO_PALLAS_AGG", "1")
    assert sharded_agg.use_pallas_agg()
    monkeypatch.setenv("REPRO_PALLAS_AGG", "0")
    assert not sharded_agg.use_pallas_agg()
    monkeypatch.setenv("REPRO_PALLAS_AGG", "off")
    assert not sharded_agg.use_pallas_agg()


def test_explicit_toggle_beats_env(pallas_auto, monkeypatch):
    monkeypatch.setenv("REPRO_PALLAS_AGG", "0")
    sharded_agg.USE_PALLAS_AGG[0] = True
    assert sharded_agg.use_pallas_agg()
    monkeypatch.setenv("REPRO_PALLAS_AGG", "1")
    sharded_agg.USE_PALLAS_AGG[0] = False
    assert not sharded_agg.use_pallas_agg()


@pytest.mark.parametrize("rule,bucket", [("cm", 1), ("cm", 2), ("tm", 2),
                                         ("mean", 1)])
def test_coord_rule_pallas_matches_jnp(pallas_auto, rule, bucket):
    """Parity pin for the a2a path's per-device rule: the fused kernel
    (interpret mode on CPU) ≡ the jnp rule, bucketing included."""
    agg = get_aggregator(rule, bucket_size=bucket, n_byz=1)
    key = jax.random.PRNGKey(3)
    y = jax.random.normal(jax.random.PRNGKey(1), (8, 96), jnp.float32)

    sharded_agg.USE_PALLAS_AGG[0] = False
    want = sharded_agg._coord_rule(agg, y, key)
    sharded_agg.USE_PALLAS_AGG[0] = True
    got = sharded_agg._coord_rule(agg, y, key)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)


@pytest.mark.parametrize("rule,bucket", [("mean", 1), ("cm", 2), ("tm", 2),
                                         ("rfa", 1), ("rfa", 2),
                                         ("krum", 1), ("krum", 2)])
def test_flat_rule_pallas_matches_jnp(pallas_auto, rule, bucket):
    """flat_rule serves ALL five rules through the kernel backend (norm_agg
    for RFA/Krum) and must match the jnp Aggregator on the same key."""
    agg = get_aggregator(rule, bucket_size=bucket, n_byz=1)
    key = jax.random.PRNGKey(3)
    y = jax.random.normal(jax.random.PRNGKey(1), (8, 96), jnp.float32)

    sharded_agg.USE_PALLAS_AGG[0] = False
    want = sharded_agg.flat_rule(agg, y, key)
    sharded_agg.USE_PALLAS_AGG[0] = True
    got = sharded_agg.flat_rule(agg, y, key)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want),
                               rtol=1e-5, atol=1e-6)
