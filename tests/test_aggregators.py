"""Unit tests for the (δ,c)-robust aggregation rules (Def. 2.1, Alg. 2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.aggregators import (bucketize, coord_median,
                                    coord_trimmed_mean, get_aggregator)

KEY = jax.random.PRNGKey(0)


def test_coord_median_matches_numpy():
    for n in [3, 4, 5, 16]:
        x = jax.random.normal(jax.random.fold_in(KEY, n), (n, 37))
        np.testing.assert_allclose(np.asarray(coord_median(x)),
                                   np.median(np.asarray(x), axis=0),
                                   rtol=1e-6)


def test_trimmed_mean_matches_manual():
    x = jax.random.normal(KEY, (10, 13))
    got = coord_trimmed_mean(x, 2)
    xs = np.sort(np.asarray(x), axis=0)
    np.testing.assert_allclose(np.asarray(got), xs[2:8].mean(0), rtol=1e-6)


def test_bucketize_shapes_and_mean_preservation():
    x = jax.random.normal(KEY, (10, 5))
    b = bucketize(KEY, x, 2)
    assert b.shape == (5, 5)
    # bucketing preserves the global mean (permutation + averaging)
    np.testing.assert_allclose(np.asarray(b.mean(0)), np.asarray(x.mean(0)),
                               rtol=1e-5, atol=1e-6)


def test_mean_aggregator():
    x = jax.random.normal(KEY, (8, 11))
    agg = get_aggregator("mean")
    np.testing.assert_allclose(np.asarray(agg(KEY, x)),
                               np.asarray(x.mean(0)), rtol=1e-6)


def test_rfa_approximates_geometric_median():
    # for 1-d clusters, geometric median == ordinary median-ish robust point
    good = jnp.ones((9, 4))
    outlier = 100.0 * jnp.ones((1, 4))
    x = jnp.concatenate([good, outlier])
    agg = get_aggregator("rfa", iters=32)
    z = agg(KEY, x)
    assert float(jnp.max(jnp.abs(z - 1.0))) < 0.2, z


def test_krum_picks_a_good_vector():
    good = jax.random.normal(KEY, (8, 6)) * 0.01
    bad = 50.0 + jax.random.normal(jax.random.fold_in(KEY, 1), (2, 6))
    x = jnp.concatenate([good, bad])
    agg = get_aggregator("krum", n_byz=2)
    z = agg(KEY, x)
    assert float(jnp.max(jnp.abs(z))) < 1.0


@pytest.mark.parametrize("rule", ["mean", "cm", "tm", "rfa", "krum"])
def test_translation_equivariance(rule):
    """All rules commute with translation — the property that lets the server
    add g^k after aggregating Q(Δ_i) (Sec. 2 discussion)."""
    x = jax.random.normal(KEY, (8, 9))
    c = jax.random.normal(jax.random.fold_in(KEY, 2), (9,))
    agg = get_aggregator(rule, bucket_size=2)
    a1 = agg(KEY, x + c[None, :])
    a2 = agg(KEY, x) + c
    tol = 1e-4 if rule in ("rfa",) else 1e-5
    np.testing.assert_allclose(np.asarray(a1), np.asarray(a2), atol=tol)


@pytest.mark.parametrize("rule", ["cm", "tm", "rfa", "krum"])
def test_robustness_to_one_outlier(rule):
    """Def. 2.1-style sanity: with δn=1 outlier, the aggregate stays within
    the good cluster's diameter of the good mean."""
    good = jax.random.normal(KEY, (9, 20)) * 0.1
    bad = 1e4 * jnp.ones((1, 20))
    x = jnp.concatenate([good, bad])
    agg = get_aggregator(rule, bucket_size=2, n_byz=1)
    z = agg(KEY, x)
    err = float(jnp.linalg.norm(z - good.mean(0)))
    assert err < 5.0, (rule, err)
    # non-robust mean is pulled away by ~1e3
    pulled = float(jnp.linalg.norm(x.mean(0) - good.mean(0)))
    assert pulled > 100.0


def test_tree_matches_flat():
    """tree-mode aggregation == flat aggregation on the concatenated vector."""
    n = 8
    leaves = {"a": jax.random.normal(KEY, (n, 3, 4)),
              "b": jax.random.normal(jax.random.fold_in(KEY, 1), (n, 7))}
    flat = jnp.concatenate([leaves["a"].reshape(n, -1),
                            leaves["b"].reshape(n, -1)], axis=1)
    for rule in ["mean", "cm", "tm", "rfa", "krum"]:
        agg = get_aggregator(rule, bucket_size=2)
        zt = agg.tree(KEY, leaves)
        zf = agg(KEY, flat)
        zt_flat = jnp.concatenate([zt["a"].reshape(-1), zt["b"].reshape(-1)])
        np.testing.assert_allclose(np.asarray(zt_flat), np.asarray(zf),
                                   rtol=2e-4, atol=2e-5, err_msg=rule)


def test_bucketing_uses_shared_permutation_across_leaves():
    """If leaves were permuted independently, tree != flat for CM."""
    n = 6
    leaves = {"a": jax.random.normal(KEY, (n, 5)),
              "b": jax.random.normal(jax.random.fold_in(KEY, 3), (n, 5))}
    agg = get_aggregator("cm", bucket_size=3)
    zt = agg.tree(KEY, leaves)
    flat = jnp.concatenate([leaves["a"], leaves["b"]], axis=1)
    zf = agg(KEY, flat)
    np.testing.assert_allclose(
        np.asarray(jnp.concatenate([zt["a"], zt["b"]])), np.asarray(zf),
        rtol=1e-5)
