"""Byz-VR-MARINA core: the paper's contribution.

- engine: the shared Byzantine-robust round skeleton + method registry
- estimators: pluggable gradient estimators (marina, sgd, sgdm, csgd,
  diana, mvr, svrg, byz_ef21, cmfilter, saga)
- compressors: unbiased Q (Def 2.2) + biased/contractive C (TopK, sign)
- aggregators: (δ,c)-ARAgg via bucketing + CM/RFA/Krum (Def 2.1, Alg. 2)
- attacks: NA / LF / BF / ALIE / IPM omniscient adversaries
- byz_vr_marina: Algorithm 1 facade (laptop vmap & pod pjit, same code)
- baselines: legacy (init, step) wrappers for SGD, BR-SGDm, CSGD, BR-DIANA,
  BR-MVR, Byrd-SVRG/-SAGA
"""
from repro.core.aggregators import Aggregator, get_aggregator  # noqa: F401
from repro.core.attacks import Attack, get_attack              # noqa: F401
from repro.core.compressors import Compressor, get_compressor  # noqa: F401
from repro.core.engine import (                                # noqa: F401
    AGG_BACKENDS, GradientEstimator, Method, aggregate, apply_attack,
    list_methods, make_method, message_phase,
)
from repro.core.byz_vr_marina import (                         # noqa: F401
    ByzVRMarinaConfig, make_step, make_init, train_state,
    comm_bits, expected_comm_bits,
)
