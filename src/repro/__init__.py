"""Byz-VR-MARINA multi-pod JAX framework (see README.md / DESIGN.md)."""
