"""``RunSpec`` — one frozen, serializable description of an experiment.

The paper's experimental claim is a grid (methods x attacks x aggregators,
with and without compression), and before this layer every benchmark/example
hand-assembled its own ``ByzVRMarinaConfig`` + registry lookups. A ``RunSpec``
is the declarative alternative: every component is named by its registry
string plus a JSON-scalar kwargs dict, so a spec

  * validates eagerly at construction (registry membership with did-you-mean
    suggestions, ``agg_mode`` in ``AGG_BACKENDS``, ``p`` in (0,1], the
    delta < 1/2 byzantine bound — before any jit tracing);
  * round-trips exactly through ``to_dict``/``from_dict``/``to_json``, so
    benchmarks can emit the resolved spec next to each result file and any
    trajectory is reproducible from artifacts alone;
  * builds the full experiment: ``spec.build_config()`` -> ByzVRMarinaConfig,
    ``spec.build()`` -> Experiment (method + stream + loss + corrupt_fn),
    ``spec.run()`` -> metrics via the shared training loop (api/runner.py).

Grid expansion over any spec fields is ``api.sweep.Sweep``.
"""
from __future__ import annotations

import dataclasses
import json
import warnings
from typing import Optional

from repro.api import registry
from repro.core.engine import AGG_BACKENDS


SCHEMA_VERSION = 1

_KWARGS_FIELDS = ("method_kwargs", "attack_kwargs", "aggregator_kwargs",
                  "compressor_kwargs", "optimizer_kwargs", "data_kwargs")


def resolve_agg_mode(mode: str) -> str:
    """CLI convenience: "auto" -> the fused Pallas kernel path on real TPU
    backends, the paper-faithful gspmd path elsewhere (interpret-mode pallas
    would only slow a CPU host). Specs always store the resolved mode."""
    if mode != "auto":
        return mode
    import jax
    return "pallas" if jax.default_backend() == "tpu" else "gspmd"


@dataclasses.dataclass(frozen=True)
class RunSpec:
    """Declarative experiment description; every field is a JSON scalar or a
    JSON-scalar dict, validated eagerly in ``__post_init__``."""

    # task / model
    task: str = "logreg"                 # registry "task": logreg | lm
    arch: Optional[str] = None           # registry "arch" (lm task)
    # gradient estimator (registry "method")
    method: str = "marina"
    # byzantine setup
    n_workers: int = 5
    n_byz: int = 1
    attack: str = "ALIE"                 # registry "attack"
    # robust aggregation
    aggregator: str = "cm"               # registry "aggregator"
    bucket_size: int = 2                 # Alg. 2 bucketing (0/1 = off)
    agg_mode: str = "gspmd"              # engine.AGG_BACKENDS
    # compression
    compressor: str = "identity"         # registry "compressor"
    # optimization
    p: float = 0.1                       # full-gradient probability
    lr: float = 0.5
    optimizer: str = "none"              # registry "optimizer"
    # schedule
    steps: int = 100
    seed: int = 0
    # per-component kwargs (JSON scalars only)
    method_kwargs: dict = dataclasses.field(default_factory=dict)
    attack_kwargs: dict = dataclasses.field(default_factory=dict)
    aggregator_kwargs: dict = dataclasses.field(default_factory=dict)
    compressor_kwargs: dict = dataclasses.field(default_factory=dict)
    optimizer_kwargs: dict = dataclasses.field(default_factory=dict)
    data_kwargs: dict = dataclasses.field(default_factory=dict)

    # -- validation ---------------------------------------------------------
    def __post_init__(self):
        registry.check("task", self.task)
        registry.check("method", self.method)
        registry.check("attack", self.attack)
        registry.check("aggregator", self.aggregator)
        registry.check("compressor", self.compressor)
        registry.check("optimizer", self.optimizer)
        if self.arch is not None:
            registry.check("arch", self.arch)
        if self.agg_mode not in AGG_BACKENDS:
            hint = (" — pass 'auto' through api.spec.resolve_agg_mode() "
                    "first" if self.agg_mode == "auto" else "")
            raise ValueError(
                f"agg_mode {self.agg_mode!r} not in {AGG_BACKENDS}{hint}")
        if not 0.0 < self.p <= 1.0:
            raise ValueError(
                f"p={self.p} must be in (0, 1] (full-gradient probability)")
        if self.n_workers < 1:
            raise ValueError(f"n_workers={self.n_workers} must be >= 1")
        if self.n_byz < 0:
            raise ValueError(f"n_byz={self.n_byz} must be >= 0")
        if 2 * self.n_byz >= self.n_workers:
            raise ValueError(
                f"n_byz={self.n_byz} of n_workers={self.n_workers} gives "
                f"delta={self.n_byz / self.n_workers:.2f} >= 1/2 — no "
                "(delta,c)-robust aggregator exists; reduce n_byz or add "
                "workers")
        s = max(self.bucket_size, 1)
        if (self.aggregator != "mean" and s > 1
                and 2 * self.n_byz * s >= self.n_workers):
            warnings.warn(
                f"after bucketing (s={s}) the byzantine fraction is "
                f"{self.n_byz * s / self.n_workers:.2f} >= 1/2: Def. 2.1's "
                "guarantee is void and convergence is only to the "
                "heterogeneity floor; reduce bucket_size or n_byz",
                stacklevel=2)
        if self.bucket_size < 0:
            raise ValueError(f"bucket_size={self.bucket_size} must be >= 0")
        if self.steps < 0:
            raise ValueError(f"steps={self.steps} must be >= 0")
        if self.task == "lm" and self.arch is None:
            raise ValueError(
                "task='lm' needs arch=<name>; registered: "
                + ", ".join(registry.components("arch")))
        if self.method == "saga" and self.task == "lm":
            raise ValueError(
                "method='saga' needs a FIXED anchor set (its per-sample "
                "gradient table is indexed by position into the anchor), "
                "but the lm task's TokenStream resamples the anchor every "
                "round — the 'correction' term would be noise, not SAGA. "
                "Use task='logreg', or a VR method without per-sample "
                "state (marina / byz_ef21 / mvr)")
        if self.method == "byz_ef21":
            comp = registry.resolve("compressor", self.compressor,
                                    **self.compressor_kwargs)
            if comp.contractive_fn is None:
                raise ValueError(
                    "method='byz_ef21' needs a contractive compressor "
                    "(topk / sign / identity): EF21's error-feedback "
                    "recursion contracts only under "
                    "E||C(x)-x||^2 <= delta_C ||x||^2, and unbiasedness "
                    "scaling (randk's d/K) breaks it; got "
                    f"compressor={self.compressor!r}")
        if self.method == "marina" and self.agg_mode == "sparse_support":
            if (self.compressor != "randk"
                    or not self.compressor_kwargs.get("common_randomness")):
                raise ValueError(
                    "agg_mode='sparse_support' needs compressor='randk' with "
                    "compressor_kwargs={'ratio': ..., "
                    "'common_randomness': True} so all workers share the "
                    f"per-step support; got compressor={self.compressor!r} "
                    f"kwargs={self.compressor_kwargs}")
        for fname in _KWARGS_FIELDS:
            val = getattr(self, fname)
            if not isinstance(val, dict):
                raise TypeError(f"{fname} must be a dict, got {type(val)}")
            try:
                ok = json.loads(json.dumps(val)) == val
            except (TypeError, ValueError):
                ok = False
            if not ok:
                raise ValueError(
                    f"{fname}={val!r} must round-trip through JSON exactly "
                    "(plain str/int/float/bool/None scalars, lists, dicts) "
                    "so the spec stays a serializable artifact")

    # -- serialization ------------------------------------------------------
    def to_dict(self) -> dict:
        """Plain-JSON dict in field order; exact ``from_dict`` inverse."""
        out = {"schema_version": SCHEMA_VERSION}
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            out[f.name] = dict(v) if isinstance(v, dict) else v
        return out

    @classmethod
    def from_dict(cls, d: dict) -> "RunSpec":
        d = dict(d)
        version = d.pop("schema_version", SCHEMA_VERSION)
        if version != SCHEMA_VERSION:
            raise ValueError(
                f"spec schema_version {version} != {SCHEMA_VERSION}")
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            import difflib
            hints = []
            for k in sorted(unknown):
                close = difflib.get_close_matches(k, sorted(known), n=1)
                hints.append(f"{k!r}"
                             + (f" (did you mean {close[0]!r}?)"
                                if close else ""))
            raise ValueError("unknown RunSpec field(s): " + ", ".join(hints))
        return cls(**d)

    def to_json(self, **dumps_kw) -> str:
        dumps_kw.setdefault("indent", 1)
        return json.dumps(self.to_dict(), **dumps_kw)

    @classmethod
    def from_json(cls, s: str) -> "RunSpec":
        return cls.from_dict(json.loads(s))

    def replace(self, **updates) -> "RunSpec":
        """``dataclasses.replace`` plus dotted-key merges into the kwargs
        dicts: ``spec.replace(**{"compressor_kwargs.ratio": 0.1})``."""
        merged: dict = {}
        for key, val in updates.items():
            if "." in key:
                parent, sub = key.split(".", 1)
                if parent not in _KWARGS_FIELDS:
                    raise ValueError(
                        f"dotted override {key!r}: {parent!r} is not one of "
                        f"{_KWARGS_FIELDS}")
                base = merged.get(parent, dict(getattr(self, parent)))
                base[sub] = val
                merged[parent] = base
            else:
                merged[key] = val
        return dataclasses.replace(self, **merged)

    # -- builders -----------------------------------------------------------
    def build_config(self):
        """Resolve the named components into a ``ByzVRMarinaConfig`` (the
        engine-facing config; distributed extras like mesh/grad_specs are
        added by the caller via ``dataclasses.replace``)."""
        from repro.core.byz_vr_marina import ByzVRMarinaConfig
        agg_kw = {"n_byz": self.n_byz, **self.aggregator_kwargs}
        if self.aggregator == "mean":
            agg_kw.pop("n_byz")          # mean ignores it; keep cfg minimal
        opt_kw = {"lr": self.lr, **self.optimizer_kwargs}
        return ByzVRMarinaConfig(
            n_workers=self.n_workers,
            n_byz=self.n_byz,
            p=self.p,
            lr=self.lr,
            aggregator=registry.resolve("aggregator", self.aggregator,
                                        bucket_size=self.bucket_size,
                                        **agg_kw),
            compressor=registry.resolve("compressor", self.compressor,
                                        **self.compressor_kwargs),
            attack=registry.resolve("attack", self.attack,
                                    **self.attack_kwargs),
            agg_mode=self.agg_mode,
            optimizer=(None if self.optimizer == "none"
                       else registry.resolve("optimizer", self.optimizer,
                                             **opt_kw)),
        )

    def build(self):
        """-> ``runner.Experiment`` (method, data stream, loss, corrupt_fn)."""
        from repro.api import runner
        return runner.build(self)

    def run(self, **run_kw):
        """Build and run via the shared training loop (api/runner.py)."""
        from repro.api import runner
        return runner.run(self, **run_kw)
