"""Compression operators: unbiased Q (Def. 2.2) and biased/contractive C.

Unbiased compressors map (key, x) -> x_hat with E[x_hat] = x and
E||x_hat - x||^2 <= omega ||x||^2. The ``omega`` attribute and the
``expected_density`` (zeta_Q, expected #nonzeros / floats sent) drive both the
theory-side step size and the communication accounting in the benchmarks.

Biased compressors (``top_k``, ``sign_compressor``) are *contractive*
instead: E||C(x) - x||^2 <= delta_C ||x||^2 with delta_C < 1, exposed via
``Compressor.contractive_delta(d)`` so ``core/theory.py`` can compute the
EF21-family step sizes. They are only sound inside error-feedback
estimators (Byz-EF21); plugging one into an unbiased-Q method silently
biases the estimator, which is why ``omega`` is NaN for them.

All compressors return a *dense* vector (the mathematical value the server
reconstructs). Wire-format size is reported by ``bits_per_vector`` so the
communication benchmarks (paper Fig. 8) are exact without simulating packets.

Wire formats (DESIGN.md §Wire): every registry entry either declares a
kernel-side ``wire_format`` — the payload layout ``core/wire.py`` packs and
``kernels/quantize.py`` reconstructs per (n, TILE_D) block inside the Pallas
aggregation kernels — or is explicitly ``fallback_only`` (the dense jnp
``compress`` stays the only implementation). The registry test fails CLOSED:
an entry declaring neither is a bug, like a method missing from
``seed_batchable``. ``compress`` remains the oracle in all cases; the fused
wire path must reproduce it exactly (tests/test_wire.py).

Tree boundary (pinned): compressors apply PER LEAF via
``tree_utils.compress_tree`` — TopK/RandK's k = max(int(ratio*d_leaf), 1) is
computed from each leaf's own size, never from the flat parameter count.
``bits_per_vector(d)``/``contractive_delta(d)`` therefore describe ONE
applied vector; tree-level accounting sums/maxes per-leaf values
(``theory.comm_bits_per_round(..., dims=...)``, ``theory.tree_contractive_delta``).

``common_randomness`` RandK is the beyond-paper variant (DESIGN.md §3): all
workers share the per-step key so the K coordinates coincide and the
all-gather can physically move only K values (see core/byz_vr_marina.py).
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Optional

import jax
import jax.numpy as jnp
from jax import lax


def _uniform_like(key, x):
    """U[0,1) of x's shape; chunked via scan for huge arrays so the threefry
    iota stays int32-safe (llama's stacked leaves exceed 2^31 coords)."""
    size = x.size
    chunk = 1 << 26
    if size <= chunk:
        return jax.random.uniform(key, x.shape)
    trips = -(-size // chunk)

    def body(c, i):
        return c, jax.random.uniform(jax.random.fold_in(key, i), (chunk,))

    _, us = lax.scan(body, 0, jnp.arange(trips))
    return us.reshape(-1)[:size].reshape(x.shape)


@dataclasses.dataclass(frozen=True)
class Compressor:
    name: str
    compress: Callable          # (key, x) -> dense x_hat
    omega_fn: Callable          # d -> omega
    bits_fn: Callable           # d -> bits on the wire per vector
    density_fn: Callable        # d -> expected nonzeros (zeta_Q)
    common_randomness: bool = False
    ratio: Optional[float] = None    # RandK/TopK keep-ratio
    contractive_fn: Optional[Callable] = None   # d -> delta_C in [0, 1)
    # kernel-side wire routing (core/wire.py): one of quantize.WIRE_FORMATS
    # ("sparse" | "int8" | "sign" | "bf16" | "dense32"), or None with
    # fallback_only=True for compressors that only exist as dense jnp.
    # Exactly one of (wire_format is not None, fallback_only) must hold —
    # enforced fail-closed by the conformance harness.
    wire_format: Optional[str] = None
    fallback_only: bool = False

    def omega(self, d):
        return self.omega_fn(d)

    def bits_per_vector(self, d):
        return self.bits_fn(d)

    def contractive_delta(self, d) -> Optional[float]:
        """delta_C with E||C(x) - x||^2 <= delta_C ||x||^2, or None when no
        contraction bound is known (unbiased compressors are contractive
        only after 1/(1+omega) scaling — see theory.contractive_delta).
        Per applied vector — i.e. per LEAF under ``compress_tree``; the
        tree-level bound is ``theory.tree_contractive_delta``."""
        return None if self.contractive_fn is None else self.contractive_fn(d)

    def tree_bits(self, dims) -> float:
        """Wire bits for one compressed pytree upload: Σ_leaf bits(d_leaf).
        The tree-boundary twin of ``bits_per_vector`` — matches what
        ``compress_tree``/``wire.pack_tree`` actually put on the wire."""
        return float(sum(self.bits_fn(int(d)) for d in dims))


# ---------------------------------------------------------------------------

def identity() -> Compressor:
    return Compressor(
        name="identity",
        compress=lambda key, x: x,
        omega_fn=lambda d: 0.0,
        bits_fn=lambda d: 32 * d,
        density_fn=lambda d: d,
        contractive_fn=lambda d: 0.0,    # C(x) = x: trivially contractive
        wire_format="dense32",   # no payload transform: the dense path IS
                                 # the wire, so wire routing is a no-op
    )


_MAX_UNITS = 1 << 22     # selection-unit cap: keeps RNG/scatter sizes int32-safe
                         # even under a 32-way worker vmap on 1e11-param leaves


def rand_k(ratio: float = 0.1, *, common_randomness: bool = False) -> Compressor:
    """RandK sparsification: keep K = ratio*d coords, scale by d/K (unbiased).

    omega = d/K - 1 (Beznosikov et al. 2020). Wire: K values + K indices.

    For huge leaves (stacked 126-layer groups of llama3-405b: 1.1e11 coords)
    per-coordinate selection is replaced by contiguous-*block* selection
    (unit = ceil(d / 2^22) coords): still exactly unbiased with the same
    omega, int32-safe, and matches how production senders actually pack
    sparsified tensors (block-sparse wire format; cf. kernels/quantize.py).
    """
    if not (0 < ratio <= 1):
        raise ValueError(ratio)

    def compress(key, x):
        d = x.size
        shape = x.shape
        blk = max(-(-d // _MAX_UNITS), 1)
        n_units = -(-d // blk)
        k_units = max(int(ratio * n_units), 1)
        scale = n_units / k_units
        perm = jax.random.permutation(key, n_units)
        mask = jnp.zeros((n_units,), bool).at[perm[:k_units]].set(True)
        if blk == 1:
            out = jnp.where(mask.reshape(shape), x * scale, 0)
            return out.astype(x.dtype)
        pad = n_units * blk - d
        xf = jnp.pad(x.reshape(-1), (0, pad)).reshape(n_units, blk)
        out = jnp.where(mask[:, None], xf * scale, 0)
        return out.reshape(-1)[:d].reshape(shape).astype(x.dtype)

    def _selection(d):
        """The (block, n_units, k_units) partition ``compress`` actually
        samples from — omega/bits/density are derived from the SAME
        partition so the theory-side constants and the wire accounting
        stay exact for huge (block-selected) leaves. For d <= 2^22 the
        block size is 1 and everything reduces to per-coordinate RandK."""
        blk, n_units = unit_partition(d)
        return blk, n_units, max(int(ratio * n_units), 1)

    def omega_fn(d):
        _, n_units, k_units = _selection(d)
        return n_units / k_units - 1.0

    def bits_fn(d):
        # wire: k_units dense blocks of blk fp32 values + one index per block
        blk, _, k_units = _selection(d)
        return k_units * (32 * blk + 32)

    def density_fn(d):
        blk, _, k_units = _selection(d)
        return min(k_units * blk, d)

    return Compressor(
        name=f"randk_{ratio}" + ("_cr" if common_randomness else ""),
        compress=compress,
        omega_fn=omega_fn,
        bits_fn=bits_fn,
        density_fn=density_fn,
        common_randomness=common_randomness,
        ratio=ratio,
        wire_format="sparse",
    )


def unit_partition(d: int):
    """(block_size, n_units) used by RandK's block selection — shared with
    the sparse-support aggregation path so supports line up exactly."""
    blk = max(-(-d // _MAX_UNITS), 1)
    return blk, -(-d // blk)


def top_k(ratio: float = 0.1) -> Compressor:
    """TopK magnitude sparsification — BIASED, contractive (Def. 3 of
    Beznosikov et al. 2020): keeping the K = ratio*d largest-magnitude
    coordinates unscaled gives ||C(x) - x||^2 <= (1 - K/d) ||x||^2.

    The compressor of choice for the EF21 family (Byz-EF21): the
    error-feedback state absorbs the bias, so the K kept coordinates go on
    the wire raw (K values + K indices) with NO unbiasedness scaling —
    unlike RandK there are no d/K-amplified values for Byzantines to hide
    noise in. ``omega`` is NaN: TopK must not be used where Def. 2.2
    unbiasedness is assumed.

    Tree boundary (PINNED): K is PER LEAF — ``compress_tree`` applies this
    operator to each leaf independently with k = max(int(ratio*d_leaf), 1),
    NOT one global top-k over the flattened parameter vector. Consequently
    ``contractive_delta(d)`` describes one leaf; the tree-level bound is
    the worst leaf, max_l (1 - k_l/d_l) = ``theory.tree_contractive_delta``
    (per-leaf top-k cannot beat its weakest leaf in the EF21 recursion).
    """
    if not (0 < ratio <= 1):
        raise ValueError(ratio)

    def _k(d):
        return max(int(ratio * d), 1)

    def compress(key, x):
        d = x.size
        k = _k(d)
        xf = x.reshape(-1).astype(jnp.float32)
        _, idx = lax.top_k(jnp.abs(xf), k)
        mask = jnp.zeros((d,), bool).at[idx].set(True)
        out = jnp.where(mask, xf, 0.0)
        return out.reshape(x.shape).astype(x.dtype)

    return Compressor(
        name=f"topk_{ratio}",
        compress=compress,
        omega_fn=lambda d: float("nan"),         # biased; no omega
        bits_fn=lambda d: _k(d) * (32 + 32),     # k values + k indices
        density_fn=lambda d: _k(d),
        ratio=ratio,
        contractive_fn=lambda d: 1.0 - _k(d) / d,
        wire_format="sparse",
    )


def l2_dithering(levels: int = 1) -> Compressor:
    """Random dithering / QSGD-style l2 quantization (Alistarh et al. 2017).

    q(x)_i = ||x||_2 * sign(x_i) * xi_i where xi_i is a random rounding of
    |x_i|/||x|| onto {0, 1/s, ..., 1}. Unbiased; omega <= min(d/s^2, sqrt(d)/s).
    """
    s = levels

    def compress(key, x):
        shape = x.shape
        xf = x.reshape(-1).astype(jnp.float32)
        norm = jnp.linalg.norm(xf)
        scaled = jnp.where(norm > 0, jnp.abs(xf) / jnp.maximum(norm, 1e-30), 0.0)
        u = _uniform_like(key, xf)
        level = jnp.floor(scaled * s + u)          # stochastic rounding
        out = norm * jnp.sign(xf) * level / s
        return out.reshape(shape).astype(x.dtype)

    def omega(d):
        return min(d / s**2, (d ** 0.5) / s)

    # wire: norm (32) + sign+level per coord (~(1 + log2(s+1)) bits), but a
    # coordinate is only sent when level>0: expected density s(s+sqrt(d)).
    def density(d):
        return min(s * (s + d ** 0.5), d)

    return Compressor(
        name=f"dither_s{s}",
        compress=compress,
        omega_fn=omega,
        bits_fn=lambda d: int(32 + density(d) * (2 + 32)),
        density_fn=density,
        # global-norm coupling: every tile needs ||x||_2 of the WHOLE vector
        # before any level can be decoded, which breaks one-sweep blockwise
        # reconstruction. The blockwise variant with a kernel wire is int8.
        fallback_only=True,
    )


def natural_compression() -> Compressor:
    """Natural compression (Horvath et al. 2019a): stochastic rounding of the
    magnitude to a power of two. omega = 1/8; wire = 9 bits/coord (sign+exp).
    """

    def compress(key, x):
        shape = x.shape
        xf = x.reshape(-1).astype(jnp.float32)
        mag = jnp.abs(xf)
        safe = jnp.maximum(mag, 1e-38)
        lo = jnp.floor(jnp.log2(safe))
        plo = 2.0 ** lo
        phi = plo * 2.0
        p_hi = (safe - plo) / plo                   # P(round up)
        u = _uniform_like(key, xf)
        rounded = jnp.where(u < p_hi, phi, plo)
        out = jnp.where(mag > 0, jnp.sign(xf) * rounded, 0.0)
        return out.reshape(shape).astype(x.dtype)

    return Compressor(
        name="natural",
        compress=compress,
        omega_fn=lambda d: 1.0 / 8.0,
        bits_fn=lambda d: 9 * d,
        density_fn=lambda d: d,
        # 9-bit sign+exponent words have no packed-array dtype on TPU; a
        # kernel wire would round-trip through int16 and save nothing over
        # bf16. Dense jnp stays the only implementation.
        fallback_only=True,
    )


def sign_compressor() -> Compressor:
    """sign(x)*||x||_1/d — BIASED, contractive: Cauchy–Schwarz gives
    ||C(x) - x||^2 = ||x||^2 - ||x||_1^2/d <= (1 - 1/d) ||x||^2. Serves the
    signSGD-style baselines and the EF21 family (1-bit-per-coord wire)."""

    def compress(key, x):
        xf = x.reshape(-1).astype(jnp.float32)
        scale = jnp.mean(jnp.abs(xf))
        return (jnp.sign(xf) * scale).reshape(x.shape).astype(x.dtype)

    return Compressor(
        name="sign",
        compress=compress,
        omega_fn=lambda d: float("nan"),     # not unbiased; no omega
        bits_fn=lambda d: d + 32,
        density_fn=lambda d: d,
        contractive_fn=lambda d: 1.0 - 1.0 / d,
        wire_format="sign",
    )


# ---------------------------------------------------------------------------
# kernel-native quantized wires (int8 / bf16)
# ---------------------------------------------------------------------------

INT8_BLOCK = 256       # per-block l2 norm granularity (one fp32 per block)
INT8_LEVELS = 127      # levels fit the signed-int8 payload exactly


def _int8_encode(key, x):
    """Blockwise l2-dithering onto signed int8 levels — the EXACT encoder
    shared by the jnp oracle ``compress`` and ``wire.pack_tree``, so the
    fused path reconstructs bit-identical values. Returns
    (levels (nb, B) int8, norms (nb,) f32) over zero-padded blocks."""
    xf = x.reshape(-1).astype(jnp.float32)
    d = xf.size
    pad = (-d) % INT8_BLOCK
    xb = jnp.pad(xf, (0, pad)).reshape(-1, INT8_BLOCK)
    norm = jnp.sqrt(jnp.sum(xb * xb, axis=1, keepdims=True))
    scaled = jnp.where(norm > 0, jnp.abs(xb) / jnp.maximum(norm, 1e-30), 0.0)
    u = jax.random.uniform(key, xb.shape)
    level = jnp.floor(scaled * INT8_LEVELS + u)      # <= 127: scaled <= 1
    return (jnp.sign(xb) * level).astype(jnp.int8), norm[:, 0]


def _int8_decode(levels, norms):
    """(nb, B) int8 + (nb,) f32 -> (nb*B,) f32 dequantized values."""
    out = norms[:, None] * levels.astype(jnp.float32) / INT8_LEVELS
    return out.reshape(-1)


def int8_quantization() -> Compressor:
    """Blockwise l2-dithering packed into a real int8 wire (QSGD with
    s = 127 levels per 256-coord block — Alistarh et al. 2017, blockwise).

    Unbiased with omega <= min(B/s², √B/s) = 256/127² ≈ 0.016 per block
    (blocks quantize independently, so the per-block bound is the vector
    bound). Wire: 8 bits/coord + one fp32 norm per block — the payload the
    Pallas kernels dequantize per (n, TILE_D) block (kernels/quantize.py).
    """
    s, b = INT8_LEVELS, INT8_BLOCK

    def compress(key, x):
        levels, norms = _int8_encode(key, x)
        out = _int8_decode(levels, norms)
        return out[:x.size].reshape(x.shape).astype(x.dtype)

    return Compressor(
        name="int8",
        compress=compress,
        omega_fn=lambda d: min(b / s**2, (b ** 0.5) / s),
        bits_fn=lambda d: 8 * d + 32 * (-(-d // b)),
        density_fn=lambda d: d,
        wire_format="int8",
    )


def bf16_cast() -> Compressor:
    """Deterministic bfloat16 rounding — BIASED (round-to-nearest, no
    dither), contractive with delta_C = 2^-16: the relative rounding error
    per coordinate is at most 2^-8 (8 mantissa bits incl. the hidden one),
    so ||C(x) - x||² <= 2^-16 ||x||². Wire: 16 bits/coord, the trivial
    kernel wire (the payload IS a TPU dtype). bf16 leaves pass through
    exactly."""

    def compress(key, x):
        return x.astype(jnp.bfloat16).astype(x.dtype)

    return Compressor(
        name="bf16",
        compress=compress,
        omega_fn=lambda d: float("nan"),     # deterministic rounding: biased
        bits_fn=lambda d: 16 * d,
        density_fn=lambda d: d,
        contractive_fn=lambda d: 2.0 ** -16,
        wire_format="bf16",
    )


REGISTRY = {
    "identity": identity,
    "randk": rand_k,
    "topk": top_k,
    "dither": l2_dithering,
    "natural": natural_compression,
    "sign": sign_compressor,
    "int8": int8_quantization,
    "bf16": bf16_cast,
}


def get_compressor(name: str, **kw) -> Compressor:
    return REGISTRY[name](**kw)
