"""(δ, c)-robust aggregation rules (Def. 2.1) with bucketing (Alg. 2).

Two call paths:

* ``agg(key, x)`` — flat stacked workers ``x: (n, d) -> (d,)``. Used by unit
  tests, the Pallas kernel oracle, and the explicit shard_map path (where an
  optional ``axis_name`` psums partial norms over the model axis so RFA/Krum
  distances are global even though each device only holds a model shard).
* ``agg.tree(key, xs)`` — ``xs`` is a gradient pytree whose leaves carry a
  leading worker axis ``(n, ...)``. Coordinate-wise rules map leaf-wise;
  norm-based rules (RFA/Krum) compute *global* distances by summing per-leaf
  contributions. The bucketing permutation is shared across leaves.

Theorem D.1: Krum∘Bucketing (c=O(1), δ<1/4), RFA∘Bucketing (c=O(1), δ<1/2),
CM∘Bucketing (c=O(d), δ<1/2) all satisfy Def. 2.1.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
from jax import lax


# ---------------------------------------------------------------------------
# primitive coordinate-wise rules on (n, ...) arrays
# ---------------------------------------------------------------------------

def coord_median(x):
    """Exact coordinate-wise median over axis 0 (Eq. 17)."""
    n = x.shape[0]
    xs = jnp.sort(x, axis=0)
    if n % 2:
        return xs[n // 2]
    return 0.5 * (xs[n // 2 - 1] + xs[n // 2])


def coord_trimmed_mean(x, trim: int):
    n = x.shape[0]
    t = min(trim, (n - 1) // 2)
    xs = jnp.sort(x, axis=0)
    return jnp.mean(xs[t:n - t], axis=0)


def bucketize(key, x, s: int):
    """Alg. 2: random permutation, then average buckets of size s."""
    n = x.shape[0]
    perm = jax.random.permutation(key, n)
    return _bucketize_perm(x, perm, s)


def _bucketize_perm(x, perm, s: int):
    n = x.shape[0]
    xp = x[perm]
    n_buckets = (n + s - 1) // s
    pad = n_buckets * s - n
    if pad:
        xp = jnp.concatenate(
            [xp, jnp.broadcast_to(jnp.mean(xp, 0, keepdims=True),
                                  (pad,) + xp.shape[1:])], axis=0)
    return jnp.mean(xp.reshape((n_buckets, s) + x.shape[1:]), axis=1)


# ---------------------------------------------------------------------------
# masked primitives (the fault-guard oracle; repro.faults.guard supplies the
# validity masks and the renormalized bucket operator)
# ---------------------------------------------------------------------------

def _row_mask(valid, a):
    return valid.reshape((-1,) + (1,) * (a.ndim - 1))


def _sanitize_rows(xs, valid):
    """Zero out invalid rows — NEVER multiply (0·NaN = NaN); select."""
    return jax.tree.map(
        lambda a: jnp.where(_row_mask(valid, a), a, jnp.zeros((), a.dtype)),
        xs)


def masked_mean(x, valid):
    """Mean over valid rows only (invalid rows contribute nothing)."""
    cnt = jnp.maximum(jnp.sum(valid.astype(jnp.float32)), 1.0)
    xc = jnp.where(_row_mask(valid, x), x, jnp.zeros((), x.dtype))
    return jnp.sum(xc, axis=0) / cnt.astype(x.dtype)


def masked_coord_median(x, valid):
    """Coordinate-wise median over the valid rows: invalid rows fill with
    +inf so the sort pushes them past every real entry, then the two middle
    ranks of the valid count c are gathered at traced indices. For odd c
    the two ranks coincide and 0.5·(v + v) == v bitwise."""
    c = jnp.sum(valid.astype(jnp.int32))
    xs = jnp.sort(jnp.where(_row_mask(valid, x),
                            x, jnp.asarray(jnp.inf, x.dtype)), axis=0)
    lo = jnp.take(xs, (c - 1) // 2, axis=0)
    hi = jnp.take(xs, c // 2, axis=0)
    return 0.5 * (lo + hi)


def masked_coord_trimmed_mean(x, valid, trim: int):
    """Trimmed mean over the valid rows: sort with +inf fill, keep ranks
    [t, c - t) of the valid count c, t = min(trim, (c-1)//2)."""
    m = x.shape[0]
    c = jnp.sum(valid.astype(jnp.int32))
    t = jnp.minimum(trim, (c - 1) // 2)
    xs = jnp.sort(jnp.where(_row_mask(valid, x),
                            x, jnp.asarray(jnp.inf, x.dtype)), axis=0)
    rank = jnp.arange(m).reshape((-1,) + (1,) * (x.ndim - 1))
    keep = (rank >= t) & (rank < c - t)
    kept = jnp.where(keep, xs, jnp.zeros((), x.dtype))
    return jnp.sum(kept, axis=0) / jnp.maximum(c - 2 * t, 1).astype(x.dtype)


# ---------------------------------------------------------------------------
# tree helpers
# ---------------------------------------------------------------------------

# worker counts above this take the blocked-Gram path: the pairwise distance
# matrix is accumulated row-tile by row-tile (lax.map over worker tiles), so
# the largest live intermediate on the giant-n path is (tile, d) + (tile, n)
# — never anything that scales like n^2 * d. The <=64 path is untouched and
# its jaxpr stays byte-identical (tests pin this).
MAX_FUSED_WORKERS = 64


def _tree_pair_sqdists_blocked(leaves, n, tile: int = MAX_FUSED_WORKERS):
    """Blocked (n, n) Gram for giant n: lax.map over row tiles of size
    ``tile`` keeps every step's working set to a (tile, d) slice times the
    resident (n, d) stack, with a (tile, n) partial result per step."""
    flats = [a.reshape(n, -1).astype(jnp.float32) for a in leaves]
    sq = sum(jnp.sum(f * f, axis=-1) for f in flats)
    nt = -(-n // tile)
    pad = nt * tile - n
    padded = [jnp.pad(f, ((0, pad), (0, 0))) if pad else f for f in flats]

    def row_tile(i):
        return sum(
            lax.dynamic_slice_in_dim(p, i * tile, tile, 0) @ f.T
            for p, f in zip(padded, flats))

    gram = lax.map(row_tile, jnp.arange(nt)).reshape(nt * tile, n)[:n]
    d2 = sq[:, None] + sq[None, :] - 2.0 * gram
    return jnp.maximum(d2, 0.0)


def _tree_pair_sqdists(xs, axis_name=None):
    """(n, n) global pairwise squared distances from a stacked pytree."""
    leaves = jax.tree.leaves(xs)
    n = leaves[0].shape[0]
    if axis_name is None and n > MAX_FUSED_WORKERS:
        return _tree_pair_sqdists_blocked(leaves, n)

    def leaf(a):
        af = a.reshape(n, -1).astype(jnp.float32)
        sq = jnp.sum(af * af, axis=-1)
        gram = af @ af.T
        return sq, gram

    parts = [leaf(a) for a in leaves]
    sq = sum(p[0] for p in parts)
    gram = sum(p[1] for p in parts)
    if axis_name is not None:
        sq = lax.psum(sq, axis_name)
        gram = lax.psum(gram, axis_name)
    d2 = sq[:, None] + sq[None, :] - 2.0 * gram
    return jnp.maximum(d2, 0.0)


def _tree_sqdist_to(xs, z, axis_name=None):
    """(n,) global squared distances from each stacked row to pytree z."""
    def leaf(a, b):
        n = a.shape[0]
        diff = (a.astype(jnp.float32) - b.astype(jnp.float32)[None]
                ).reshape(n, -1)
        return jnp.sum(diff * diff, axis=-1)

    tot = sum(leaf(a, b) for a, b in zip(jax.tree.leaves(xs),
                                         jax.tree.leaves(z)))
    if axis_name is not None:
        tot = lax.psum(tot, axis_name)
    return tot


def _tree_weighted_sum(w, xs):
    return jax.tree.map(
        lambda a: jnp.einsum("n,n...->...", w.astype(jnp.float32),
                             a.astype(jnp.float32)).astype(a.dtype), xs)


# ---------------------------------------------------------------------------
# Aggregator
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Aggregator:
    rule: str                    # mean | cm | tm | rfa | krum
    bucket_size: int = 0         # s; 0/1 = no bucketing
    trim: int = 1                # for tm
    n_byz: int = 1               # for krum neighbour count
    iters: int = 8               # Weiszfeld steps (paper: T=8)
    eps: float = 1e-8

    @property
    def name(self) -> str:
        nm = self.rule
        if self.rule == "tm":
            nm += str(self.trim)
        if self.bucket_size > 1:
            nm += f"_b{self.bucket_size}"
        return nm

    @property
    def robust(self) -> bool:
        return self.rule != "mean"

    @property
    def coordinatewise(self) -> bool:
        """True if the rule commutes with coordinate sharding — admits the
        all_to_all sharded-aggregation path (DESIGN.md §3)."""
        return self.rule in ("mean", "cm", "tm")

    @property
    def norm_based(self) -> bool:
        """RFA/Krum: rules driven by global inter-worker distances. Served
        by the fused kernels/norm_agg path under agg_mode=pallas; this jnp
        tree path is their parity oracle."""
        return self.rule in ("rfa", "krum")

    # -- flat path ---------------------------------------------------------
    def __call__(self, key, x, axis_name=None):
        if self.bucket_size > 1 and self.rule != "mean":
            x = bucketize(key, x, self.bucket_size)
        if self.rule == "mean":
            return jnp.mean(x, axis=0)
        if self.rule == "cm":
            return coord_median(x)
        if self.rule == "tm":
            return coord_trimmed_mean(x, self.trim)
        if self.rule == "rfa":
            return self._rfa_tree(key, {"x": x}, axis_name)["x"]
        if self.rule == "krum":
            return self._krum_tree(key, {"x": x}, axis_name)["x"]
        raise ValueError(self.rule)

    # -- tree path ----------------------------------------------------------
    def tree(self, key, xs, axis_name=None):
        """xs: pytree with leading worker axis n on every leaf."""
        n = jax.tree.leaves(xs)[0].shape[0]
        if self.bucket_size > 1 and self.rule != "mean":
            perm = jax.random.permutation(key, n)
            xs = jax.tree.map(
                lambda a: _bucketize_perm(a, perm, self.bucket_size), xs)
        if self.rule == "mean":
            return jax.tree.map(lambda a: jnp.mean(a, axis=0), xs)
        if self.rule == "cm":
            return jax.tree.map(coord_median, xs)
        if self.rule == "tm":
            return jax.tree.map(lambda a: coord_trimmed_mean(a, self.trim), xs)
        if self.rule == "rfa":
            return self._rfa_tree(key, xs, axis_name)
        if self.rule == "krum":
            return self._krum_tree(key, xs, axis_name)
        raise ValueError(self.rule)

    # -- traced twin (repro.obs telemetry) ----------------------------------
    def tree_traced(self, key, xs, axis_name=None):
        """``(tree(key, xs), info)``: the identical aggregate — same op
        sequence, so the output is bitwise equal to ``tree`` — plus the
        rule's own intermediates for ``repro.obs.trace.RoundTrace``:

          * ``perm``            — the shared bucketing permutation (None when
                                  bucketing is off / rule is mean);
          * ``bucket_weights``  — RFA's final Weiszfeld weights or Krum's
                                  selection one-hot over the (bucketed) rows;
          * ``rfa_sq``          — squared distances of the rows to the RFA
                                  output (one extra distance pass);
          * ``krum_scores`` / ``krum_selected`` — Eq. 15 scores and argmin.

        Coordinate-wise rules return only ``perm``; their per-row selection
        fractions are recomputed host-of-band by the obs layer."""
        n = jax.tree.leaves(xs)[0].shape[0]
        info = {"perm": None}
        if self.bucket_size > 1 and self.rule != "mean":
            perm = jax.random.permutation(key, n)
            info["perm"] = perm
            xs = jax.tree.map(
                lambda a: _bucketize_perm(a, perm, self.bucket_size), xs)
        if self.rule == "mean":
            return jax.tree.map(lambda a: jnp.mean(a, axis=0), xs), info
        if self.rule == "cm":
            return jax.tree.map(coord_median, xs), info
        if self.rule == "tm":
            return (jax.tree.map(lambda a: coord_trimmed_mean(a, self.trim),
                                 xs), info)
        if self.rule == "rfa":
            z, extra = self._rfa_tree(key, xs, axis_name, return_info=True)
        elif self.rule == "krum":
            z, extra = self._krum_tree(key, xs, axis_name, return_info=True)
        else:
            raise ValueError(self.rule)
        info.update(extra)
        return z, info

    # -- masked (fault-guarded) tree path ------------------------------------
    def tree_masked(self, key, xs, valid, axis_name=None, return_info=False):
        """Guarded twin of ``tree``: rows with ``valid[i] == False`` get
        exactly zero aggregation weight — the oracle for "drop these
        workers explicitly". Invalid rows are select-zeroed (never
        multiplied) before any arithmetic, so NaN/inf rows cannot poison
        the aggregate; bucketing renormalizes each bucket over its valid
        members (``faults.guard.masked_bucket_matrix``), and a bucket with
        no valid members is itself dropped. This is a separate method (not
        a ``valid=`` default) so the unguarded path's jaxpr stays pinned
        byte-identical."""
        from repro.faults.guard import masked_bucket_matrix
        n = jax.tree.leaves(xs)[0].shape[0]
        info = {"perm": None}
        if self.bucket_size > 1 and self.rule != "mean":
            perm = jax.random.permutation(key, n)
            info["perm"] = perm
            w_mat, bvalid = masked_bucket_matrix(perm, n, self.bucket_size,
                                                 valid)
            xs = _sanitize_rows(xs, valid)
            xs = jax.tree.map(
                lambda a: jnp.einsum("bn,n...->b...", w_mat,
                                     a.astype(jnp.float32)).astype(a.dtype),
                xs)
        else:
            bvalid = valid
            xs = _sanitize_rows(xs, valid)
        if self.rule == "mean":
            agg = jax.tree.map(lambda a: masked_mean(a, bvalid), xs)
        elif self.rule == "cm":
            agg = jax.tree.map(lambda a: masked_coord_median(a, bvalid), xs)
        elif self.rule == "tm":
            agg = jax.tree.map(
                lambda a: masked_coord_trimmed_mean(a, bvalid, self.trim), xs)
        elif self.rule == "rfa":
            agg, extra = self._rfa_masked(xs, bvalid, axis_name)
            info.update(extra)
        elif self.rule == "krum":
            agg, extra = self._krum_masked(xs, bvalid, axis_name)
            info.update(extra)
        else:
            raise ValueError(self.rule)
        return (agg, info) if return_info else agg

    def _rfa_masked(self, xs, valid, axis_name=None):
        """Weiszfeld over the valid (pre-sanitized) rows: invalid rows get
        zero weight at every iteration and the init is the valid mean."""
        v = valid.astype(jnp.float32)
        z = jax.tree.map(lambda a: masked_mean(a, valid), xs)
        w = v / jnp.maximum(jnp.sum(v), 1.0)
        for _ in range(self.iters):
            sq = _tree_sqdist_to(xs, z, axis_name)
            w = jnp.where(valid, 1.0 / jnp.sqrt(sq + self.eps), 0.0)
            w = w / jnp.maximum(jnp.sum(w), 1e-30)
            z = _tree_weighted_sum(w, xs)
        sq_t = _tree_sqdist_to(xs, z, axis_name)
        return z, {"bucket_weights": w, "rfa_sq": sq_t}

    def _krum_masked(self, xs, valid, axis_name=None):
        """Krum over the valid rows: invalid rows/columns are +inf in the
        distance matrix, the neighbour count tracks the valid count c
        (k = max(c - n_byz - 2, 1)), and invalid rows can never win."""
        n = jax.tree.leaves(xs)[0].shape[0]
        d2 = _tree_pair_sqdists(xs, axis_name)
        pair_ok = valid[:, None] & valid[None, :]
        d2 = jnp.where(pair_ok, d2, jnp.inf)
        d2 = d2 + jnp.diag(jnp.full((n,), jnp.inf, d2.dtype))
        c = jnp.sum(valid.astype(jnp.int32))
        k = jnp.maximum(c - self.n_byz - 2, 1)
        srt = jnp.sort(d2, axis=1)
        near = jnp.arange(n)[None, :] < k
        scores = jnp.sum(jnp.where(near, srt, 0.0), axis=1)
        scores = jnp.where(valid, scores, jnp.inf)
        best = jnp.argmin(scores)
        onehot = jax.nn.one_hot(best, n)
        z = _tree_weighted_sum(onehot, xs)
        return z, {"bucket_weights": onehot, "krum_scores": scores,
                   "krum_selected": best}

    # -- norm-based rules (global distances) --------------------------------
    def _rfa_tree(self, key, xs, axis_name=None, return_info=False):
        """Geometric median via smoothed Weiszfeld (Pillutla et al. 2022)."""
        z = jax.tree.map(lambda a: jnp.mean(a, axis=0), xs)
        w = None
        for _ in range(self.iters):
            sq = _tree_sqdist_to(xs, z, axis_name)
            w = 1.0 / jnp.sqrt(sq + self.eps)
            w = w / jnp.sum(w)
            z = _tree_weighted_sum(w, xs)
        if not return_info:
            return z
        if w is None:            # iters == 0: z is the plain mean
            n = jax.tree.leaves(xs)[0].shape[0]
            w = jnp.full((n,), 1.0 / n, jnp.float32)
        sq_t = _tree_sqdist_to(xs, z, axis_name)
        return z, {"bucket_weights": w, "rfa_sq": sq_t}

    def _krum_tree(self, key, xs, axis_name=None, return_info=False):
        """Krum (Eq. 15): vector minimizing the sum of squared distances to
        its n - n_byz - 2 nearest neighbours."""
        n = jax.tree.leaves(xs)[0].shape[0]
        d2 = _tree_pair_sqdists(xs, axis_name)
        d2 = d2 + jnp.diag(jnp.full((n,), jnp.inf, d2.dtype))
        m = max(n - self.n_byz - 2, 1)
        scores = jnp.sum(jnp.sort(d2, axis=1)[:, :m], axis=1)
        best = jnp.argmin(scores)
        onehot = jax.nn.one_hot(best, n)
        z = _tree_weighted_sum(onehot, xs)
        if not return_info:
            return z
        return z, {"bucket_weights": onehot, "krum_scores": scores,
                   "krum_selected": best}


# ---------------------------------------------------------------------------

RULES = ("mean", "cm", "tm", "rfa", "krum")

# registry rule name -> kernels/robust_agg coordinate-rule name (the single
# translation point for every kernel dispatch site)
COORD_KERNEL_RULE = {"mean": "mean", "cm": "median", "tm": "trimmed"}


def get_aggregator(name: str, *, bucket_size: int = 0, **kw) -> Aggregator:
    """name in ``RULES``; paper default bucketing s=2."""
    if name not in RULES:
        raise ValueError(f"unknown aggregation rule {name!r}; known: {RULES}")
    return Aggregator(rule=name, bucket_size=bucket_size, **kw)
