"""Paper Figure 8: effect of compression on communication efficiency —
optimality gap vs transmitted bits under the ALIE attack.

Emits gap checkpoints as a function of cumulative uploaded bits per worker
for Byz-VR-MARINA with and without RandK(0.1d). Each curve is one
``RunSpec`` driven through the shared runner (checkpoints via the runner's
log callback; bits from the estimator's own accounting); the resolved spec
JSON lands next to each CSV row in experiments/bench/."""
from benchmarks.common import emit, logreg_reference
from repro.api import RunSpec, build

DIM = 30
BASE = RunSpec(task="logreg", method="marina", n_workers=5, n_byz=1,
               p=0.1, lr=0.5, attack="ALIE", aggregator="cm", bucket_size=2,
               data_kwargs={"n_samples": 400, "dim": DIM, "data_seed": 2})


def run(iters=600, log_every=150):
    full, f_star = logreg_reference(build(BASE))
    rows = [("none", BASE.replace(steps=iters)),
            ("randk0.1", BASE.replace(steps=iters, compressor="randk",
                                      compressor_kwargs={"ratio": 0.1}))]
    for comp_name, spec in rows:
        exp = build(spec)

        def probe(it, state, m, spec=spec, exp=exp):
            gap = float(exp.loss_fn(state["params"], full)) - f_star
            emit(f"fig8/{comp_name}/round{it + 1}", 0.0,
                 f"bits={m['comm_bits']:.0f};gap={gap:.3e}", spec=spec)

        exp.run(log_every=iters, callback=probe, callback_every=log_every)


if __name__ == "__main__":
    run()
