"""Chaos driver: run the fault matrix and emit the fault report.

One command sweeps {rule} x {backend} x {fault kind} through the chaos
layer (repro.faults, DESIGN.md §6): every cell runs a seeded FaultPlan at
``prob=1`` on a fixed honest-worker set chosen inside the guard's delta
budget (``2·(n_byz + f) < n``), with the fail-closed guard ON and the
telemetry twin tracing, then gates on graceful degradation:

  * the trajectory completes and every logged loss / g_norm is finite;
  * the guard's fault recall is 1.0 for the non-finite kinds (nan_grad,
    inf_blowup) — stale_replay is finite BY DESIGN (robust rules are the
    containment layer) and corrupt_wire garbles payloads that may stay
    structurally valid, so those two report recall without gating on it;
  * gspmd and pallas final losses agree per (rule, kind) — a coarse
    cross-backend parity check (the precise equivalences are pinned in
    tests/test_faults.py).

A guard-OFF control cell (``mean``, nan_grad, no masking) is also run and
is EXPECTED to go non-finite — chaos without the guard must visibly fail,
otherwise the matrix is not testing anything. (The robust rules are used
for the guarded cells precisely because they degrade gracefully even
unguarded: a median never selects a NaN row.)

Artifacts in ``--out-dir`` (default experiments/chaos/):

  * ``fault_report.json``  — the matrix verdict per cell + summary;
  * ``chaos_metrics.jsonl`` — the metric-event stream (round / trace /
    fault events), self-verified through ``repro.obs.sink.verify_jsonl``
    (the same gate CI runs as ``python -m repro.obs.sink --verify``).

Quickstart (README "Chaos testing")::

  PYTHONPATH=src python -m repro.launch.chaos --smoke
"""
from __future__ import annotations

import argparse
import json
import math
import os

from repro.api import RunSpec

RULES = ("cm", "tm", "krum", "rfa")
BACKENDS = ("gspmd", "pallas")
DENSE_KINDS = ("nan_grad", "inf_blowup", "stale_replay")
WIRE_KINDS = ("corrupt_wire",)        # wire payloads exist under pallas only
GATED_RECALL = ("nan_grad", "inf_blowup")


def _faulty_workers(n_workers: int, n_byz: int, f: int) -> list:
    """The last ``f`` (honest) worker indices — disjoint from the byzantine
    prefix, keeping 2·(n_byz + f) < n_workers checkable by the caller."""
    return list(range(n_workers - f, n_workers))


def cell_spec(rule: str, backend: str, kind: str, *, n_workers: int,
              n_byz: int, n_faulty: int, steps: int, seed: int,
              guard: bool = True) -> RunSpec:
    plan = {"seed": seed,
            "faults": [{"kind": kind, "prob": 1.0,
                        "workers": _faulty_workers(n_workers, n_byz,
                                                   n_faulty)}]}
    base = dict(task="logreg", n_workers=n_workers, n_byz=n_byz,
                attack="ALIE", aggregator=rule, bucket_size=0,
                agg_mode=backend, lr=0.2, steps=steps, seed=seed,
                faults=plan, fault_guard=guard, trace=guard,
                data_kwargs={"dim": 64, "n_samples": 16 * n_workers,
                             "batch_size": 8})
    if kind in WIRE_KINDS:
        # bit-flips act on a WireCandidates payload: the MARINA VR rounds
        # pack compressed deltas onto the kernel wire under pallas
        base.update(method="marina", p=0.5, compressor="topk",
                    compressor_kwargs={"ratio": 0.25})
    else:
        base.update(method="sgd")
    return RunSpec(**base)


def run_cell(spec: RunSpec, kind: str, *, log_every: int, sink=None) -> dict:
    res = spec.run(log_every=log_every, warmup=True, sink=sink)
    finite = all(math.isfinite(m["loss"]) and math.isfinite(m["g_norm"])
                 for m in res.history)
    recalls = [m["fault_recall"] for m in res.history
               if "fault_recall" in m]
    precisions = [m["fault_precision"] for m in res.history
                  if "fault_precision" in m]
    out = {
        "rule": spec.aggregator, "backend": spec.agg_mode, "kind": kind,
        "final_loss": res.history[-1]["loss"],
        "finite": finite,
        "fault_recall": (sum(recalls) / len(recalls)) if recalls else None,
        "fault_precision": (sum(precisions) / len(precisions))
        if precisions else None,
        "rounds_traced": len(recalls),
    }
    ok = finite
    if kind in GATED_RECALL and recalls:
        ok = ok and min(recalls) == 1.0
    out["ok"] = ok
    return out


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        description="fault-matrix chaos runs (repro.faults, DESIGN.md §6)")
    ap.add_argument("--rules", default=",".join(RULES),
                    help=f"comma list of robust rules (default {RULES})")
    ap.add_argument("--backends", default=",".join(BACKENDS),
                    help=f"comma list of agg backends (default {BACKENDS})")
    ap.add_argument("--kinds", default=",".join(DENSE_KINDS + WIRE_KINDS),
                    help="comma list of fault kinds to inject")
    ap.add_argument("--n-workers", type=int, default=12)
    ap.add_argument("--n-byz", type=int, default=2)
    ap.add_argument("--n-faulty", type=int, default=2)
    ap.add_argument("--steps", type=int, default=24)
    ap.add_argument("--log-every", type=int, default=4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--smoke", action="store_true",
                    help="tiny matrix for CI: cm+rfa x both backends, "
                         "nan_grad + stale_replay + corrupt_wire, 8 steps")
    ap.add_argument("--out-dir", default="experiments/chaos")
    ap.add_argument("--no-verify", action="store_true",
                    help="skip the obs.sink verify pass on the emitted "
                         "metrics stream")
    return ap


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    rules = tuple(args.rules.split(","))
    backends = tuple(args.backends.split(","))
    kinds = tuple(args.kinds.split(","))
    if args.smoke:
        rules = ("cm", "rfa")
        kinds = ("nan_grad", "stale_replay", "corrupt_wire")
        args.steps, args.log_every = 8, 2
    if 2 * (args.n_byz + args.n_faulty) >= args.n_workers:
        raise SystemExit(
            f"2*(n_byz={args.n_byz} + n_faulty={args.n_faulty}) >= "
            f"n_workers={args.n_workers}: outside the guard's delta budget "
            "— the matrix would test nothing (raise --n-workers)")

    os.makedirs(args.out_dir, exist_ok=True)
    from repro.obs.sink import JsonlSink, verify_jsonl
    stream = os.path.join(args.out_dir, "chaos_metrics.jsonl")
    if os.path.exists(stream):
        os.remove(stream)
    sink = JsonlSink(stream)

    cfg_kw = dict(n_workers=args.n_workers, n_byz=args.n_byz,
                  n_faulty=args.n_faulty, steps=args.steps, seed=args.seed)
    cells = []
    for kind in kinds:
        site = "wire" if kind in WIRE_KINDS else "tensor"
        for rule in rules:
            for backend in backends:
                if kind in WIRE_KINDS and backend != "pallas":
                    continue            # no wire payloads off-pallas
                spec = cell_spec(rule, backend, kind, **cfg_kw)
                try:
                    cell = run_cell(spec, kind, log_every=args.log_every,
                                    sink=sink)
                except Exception as e:  # noqa: BLE001 — report, keep grid
                    cell = {"rule": rule, "backend": backend, "kind": kind,
                            "ok": False,
                            "error": f"{type(e).__name__}: {e}"}
                sink.emit({"type": "fault", "kind": kind, "site": site,
                           "rule": rule, "backend": backend,
                           "injected_workers": _faulty_workers(
                               args.n_workers, args.n_byz, args.n_faulty),
                           "ok": bool(cell["ok"])})
                cells.append(cell)
                status = "ok" if cell["ok"] else "FAIL"
                print(f"[chaos] {kind:12s} {rule:5s} {backend:6s} {status}"
                      + (f"  recall={cell['fault_recall']:.2f}"
                         if cell.get("fault_recall") is not None else "")
                      + (f"  {cell.get('error', '')}"))

    # cross-backend parity per (rule, kind) — coarse gate; the bit-level
    # equivalences live in tests/test_faults.py
    parity = []
    for kind in kinds:
        for rule in rules:
            pair = [c for c in cells
                    if c.get("kind") == kind and c.get("rule") == rule
                    and "final_loss" in c]
            if len(pair) == 2:
                a, b = pair[0]["final_loss"], pair[1]["final_loss"]
                close = math.isfinite(a) and math.isfinite(b) and \
                    abs(a - b) <= 1e-2 * max(abs(a), abs(b), 1e-6)
                parity.append({"rule": rule, "kind": kind,
                               "loss": [a, b], "close": close})

    # the no-guard control: chaos without the guard must visibly fail.
    # Uses ``mean`` — NaN propagates through an unguarded average, whereas
    # the robust rules themselves degrade gracefully (a median never
    # selects a NaN row: XLA sorts NaNs to the top, above the cut)
    ctrl_spec = cell_spec("mean", "gspmd", "nan_grad", guard=False, **cfg_kw)
    ctrl = ctrl_spec.run(log_every=args.steps, warmup=True)
    ctrl_nonfinite = not math.isfinite(ctrl.history[-1]["loss"])
    print(f"[chaos] control (guard OFF, nan_grad): "
          f"{'non-finite as expected' if ctrl_nonfinite else 'FINITE (?)'}")

    green = all(c["ok"] for c in cells) and \
        all(p["close"] for p in parity) and ctrl_nonfinite
    report = {
        "green": green,
        "grid": {"rules": list(rules), "backends": list(backends),
                 "kinds": list(kinds)},
        "budget": {"n_workers": args.n_workers, "n_byz": args.n_byz,
                   "n_faulty": args.n_faulty},
        "cells": cells,
        "cross_backend_parity": parity,
        "control_guard_off_nonfinite": ctrl_nonfinite,
    }
    path = os.path.join(args.out_dir, "fault_report.json")
    with open(path, "w") as f:
        json.dump(report, f, indent=1, sort_keys=True)
    sink.close()
    print(f"[chaos] report -> {path} ({'GREEN' if green else 'RED'})")

    if not args.no_verify:
        counts = verify_jsonl(stream)
        print(f"[chaos] {stream}: verified — "
              + ", ".join(f"{k}={v}" for k, v in sorted(counts.items())))
    return 0 if green else 1


if __name__ == "__main__":
    raise SystemExit(main())
