"""Paper Table 2 (empirical analogue): communication rounds to reach a target
optimality gap, Byz-VR-MARINA vs BR-SGDm / BR-CSGD / BR-DIANA / Byrd-SVRG,
under the ALIE attack. Also reports uploaded bits per worker to reach the
target (the compression win).

Every contender is one ``RunSpec`` — the method name is the row key, and
per-round communication comes from the estimator's own accounting. The
resolved spec JSON is emitted next to each row."""
from benchmarks.common import emit, logreg_reference
from repro.api import RunSpec, build

DIM = 30
TARGET = 1e-3
MAX_ROUNDS = 1200
CHECK_EVERY = 25

BASE = RunSpec(task="logreg", n_workers=5, n_byz=1, p=0.1, lr=0.5,
               attack="ALIE", aggregator="cm", bucket_size=2,
               steps=MAX_ROUNDS,
               data_kwargs={"n_samples": 400, "dim": DIM, "data_seed": 1})

RANDK = {"compressor": "randk", "compressor_kwargs": {"ratio": 0.1}}
ROWS = [
    ("byz-vr-marina", BASE.replace(method="marina")),
    ("byz-vr-marina+randk", BASE.replace(method="marina", **RANDK)),
    ("br-sgdm", BASE.replace(method="sgdm")),
    ("br-csgd+randk", BASE.replace(method="csgd", **RANDK)),
    ("br-diana+randk", BASE.replace(method="diana", **RANDK)),
    ("byrd-svrg", BASE.replace(method="svrg", aggregator="rfa")),
]


def run(max_rounds=MAX_ROUNDS):
    full, f_star = logreg_reference(build(BASE))
    for label, spec in ROWS:
        spec = spec.replace(steps=max_rounds)
        exp = build(spec)
        hit = []

        def probe(it, state, m, exp=exp, hit=hit):
            if float(exp.loss_fn(state["params"], full)) - f_star < TARGET:
                hit.append(it + 1)
            return bool(hit)

        exp.run(log_every=max_rounds, callback=probe,
                callback_every=CHECK_EVERY)
        rounds = hit[0] if hit else -1
        bits_per_round = exp.method.expected_bits(DIM + 1)
        bits = rounds * bits_per_round if rounds > 0 else float("inf")
        emit(f"table2/{label}", float(rounds),
             f"rounds_to_{TARGET:g}={rounds};bits/worker={bits:.3g}",
             spec=spec)


if __name__ == "__main__":
    run()
