"""exec/batching: jit-signature grouping, vmapped-seed-group ≡ serial
per-seed trajectories, and the one-compile-per-group contract."""
import numpy as np
import pytest

from repro import exec as xc
from repro.api import RunSpec, Sweep

DIM = 8
STEPS = 4


def _base(method="marina", **kw):
    d = dict(task="logreg", method=method, n_workers=5, n_byz=1, p=0.3,
             lr=0.25, attack="ALIE", aggregator="cm", bucket_size=2,
             steps=STEPS,
             data_kwargs={"n_samples": 60, "dim": DIM, "batch_size": 8,
                          "data_seed": 0})
    d.update(kw)
    return RunSpec(**d)


def test_group_cells_partitions_by_signature():
    cells = list(Sweep(_base(), {"aggregator": ("mean", "cm"),
                                 "seed": (0, 1, 2)}).expand())
    groups = xc.group_cells(cells)
    assert len(groups) == 2
    for _, members in groups:
        assert len(members) == 3
        assert len({s.seed for _, s in members}) == 3
        assert len({xc.group_key(s) for _, s in members}) == 1


def test_can_batch_rules():
    cells = list(Sweep(_base(), {"seed": (0, 1)}).expand())
    assert xc.can_batch(cells)
    assert not xc.can_batch(cells[:1])                  # nothing to amortize
    assert not xc.can_batch(cells, {"callback": lambda *a: None})
    a2a = [(rid, s.replace(agg_mode="pallas")) for rid, s in cells]
    assert not xc.can_batch(a2a)                        # non-gspmd backend
    mixed = [cells[0], (cells[1][0], cells[1][1].replace(lr=0.1))]
    assert not xc.can_batch(mixed)                      # signature mismatch


@pytest.mark.parametrize("method", ["marina", "sgd"])
def test_vmapped_group_matches_serial_per_seed(method):
    cells = list(Sweep(_base(method=method), {"seed": (0, 1, 2)}).expand())
    results, stats = xc.run_group(cells, log_every=1)
    assert stats["step_compiles"] == 1                  # one trace, all steps
    for run_id, spec in cells:
        serial = spec.run(log_every=1)
        batched = results[run_id]
        # numerically equivalent: vmap only reassociates float math
        np.testing.assert_allclose(
            np.asarray([h["loss"] for h in batched.history]),
            np.asarray([h["loss"] for h in serial.history]),
            rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(np.asarray(batched.params["w"]),
                                   np.asarray(serial.params["w"]),
                                   rtol=1e-5, atol=1e-6)
        # the c_k coin stream is key-deterministic -> exact comm accounting
        assert batched.comm_bits == serial.comm_bits
        assert [h["step"] for h in batched.history] == \
               [h["step"] for h in serial.history]


def test_compile_count_3x3x5_grid():
    """The ISSUE's acceptance pin: a 3-aggregator x 3-attack x 5-seed grid
    runs in <= 9 step compiles — one per jit-signature group."""
    sweep = Sweep(_base(steps=2,
                        data_kwargs={"n_samples": 40, "dim": 6,
                                     "batch_size": 4, "data_seed": 0}),
                  {"aggregator": ("mean", "cm", "tm"),
                   "attack": ("NA", "BF", "ALIE"),
                   "seed": (0, 1, 2, 3, 4)})
    cells = list(sweep.expand())
    assert len(cells) == 45
    srun = xc.run_cells(cells, run_kw={"log_every": 2})
    assert not srun.failures
    assert len(srun) == 45
    assert srun.stats["vmapped_groups"] == 9
    assert srun.stats["step_compiles"] <= 9
    assert srun.stats["max_group_cache"] == 1           # no per-step retrace


def test_can_batch_stateful_table_methods_fall_back_to_serial():
    """SAGA's per-worker gradient tables must NOT be vmapped over seeds
    (``seed_batchable = False``): the cells classify as un-batchable and
    run down the serial / WorkerPool path, where they still complete."""
    cells = list(Sweep(_base(method="saga",
                             method_kwargs={"batch_size": 8}),
                       {"seed": (0, 1)}).expand())
    assert not xc.can_batch(cells)
    srun = xc.run_cells(cells, run_kw={"log_every": STEPS})
    assert not srun.failures
    assert srun.stats["vmapped_groups"] == 0
    assert srun.stats["serial_cells"] == 2
    # an otherwise-identical batchable method still vmaps — the fallback is
    # the estimator trait, not an accident of the grid shape
    ref = list(Sweep(_base(method="sgd"), {"seed": (0, 1)}).expand())
    assert xc.can_batch(ref)


def _ef21_cells():
    base = _base(method="byz_ef21", compressor="topk",
                 compressor_kwargs={"ratio": 0.5})
    return list(Sweep(base, {"aggregator": ("mean", "cm"),
                             "seed": (0, 1, 2)}).expand())


def test_byz_ef21_vmapped_group_matches_serial_per_seed():
    """EF21's per-worker error-feedback state vmaps over seeds like any
    other stacked extra; the batched trajectory must match serial runs."""
    cells = _ef21_cells()[:3]            # one jit-signature group
    assert xc.can_batch(cells)
    results, stats = xc.run_group(cells, log_every=1)
    assert stats["step_compiles"] == 1
    for run_id, spec in cells:
        serial = spec.run(log_every=1)
        np.testing.assert_allclose(
            np.asarray([h["loss"] for h in results[run_id].history]),
            np.asarray([h["loss"] for h in serial.history]),
            rtol=1e-5, atol=1e-6)
        assert results[run_id].comm_bits == serial.comm_bits


def test_killed_and_resumed_byz_ef21_sweep_bit_identical(tmp_path):
    """Kill a byz_ef21 sweep mid-group, resume: the vmapped groups commit
    atomically, so the summary equals the uninterrupted one byte-for-byte
    (the EF21 state makes the trajectory history-dependent — any torn
    half-group re-run at a different width would show up here)."""
    import os
    cells = _ef21_cells()
    d1, d2 = str(tmp_path / "full"), str(tmp_path / "killed")
    xc.run_cells(cells, out_dir=d1, run_kw={"log_every": 1})
    # "kill" after 4 of 6 cells: first group committed, second torn
    xc.run_cells(cells[:4], out_dir=d2, run_kw={"log_every": 1})
    srun = xc.run_cells(cells, out_dir=d2, resume=True,
                        run_kw={"log_every": 1})
    assert len(srun.skipped) == 3
    assert srun.stats["executed_cells"] == 3

    def summary_bytes(out_dir):
        path = xc.write_summary(os.path.join(out_dir, "s_summary.json"),
                                xc.summarize_dir(out_dir))
        with open(path, "rb") as f:
            return f.read()

    assert summary_bytes(d1) == summary_bytes(d2)


def test_run_sweep_returns_mapping_with_artifacts(tmp_path):
    sweep = Sweep(_base(), {"seed": (0, 1)})
    srun = xc.run_cells(list(sweep.expand()), out_dir=str(tmp_path),
                        run_kw={"log_every": STEPS})
    assert len(srun) == 2
    for rid in srun:
        assert srun[rid].history
        assert srun.artifacts[rid]["spec"]["seed"] == srun[rid].spec.seed
        assert (tmp_path / f"{rid}.json").exists()
