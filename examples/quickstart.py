"""Quickstart: Byzantine-robust training in ~40 lines (paper Fig. 1 setup).

Four good workers + one Byzantine running the ALIE attack on ℓ2-regularized
logistic regression. Byz-VR-MARINA with CM∘bucketing converges linearly to
the optimum; try --agg mean to watch plain averaging get poisoned, or
--method sgdm/csgd/diana/mvr/svrg to race any baseline estimator through
the same round engine.

  PYTHONPATH=src python examples/quickstart.py [--attack ALIE] [--agg cm]
"""
import argparse
import sys

sys.path.insert(0, "src")

import jax

from repro.core import (ByzVRMarinaConfig, get_aggregator, get_attack,
                        get_compressor, list_methods, make_method)
from repro.data import (corrupt_labels_logreg, init_logreg_params,
                        logreg_loss, make_logreg_data)

ap = argparse.ArgumentParser()
ap.add_argument("--method", default="marina", choices=list_methods())
ap.add_argument("--attack", default="ALIE",
                choices=["NA", "LF", "BF", "ALIE", "IPM"])
ap.add_argument("--agg", default="cm", choices=["mean", "cm", "rfa", "krum"])
ap.add_argument("--randk", type=float, default=0.1,
                help="RandK ratio (1.0 = no compression)")
ap.add_argument("--iters", type=int, default=600)
args = ap.parse_args()

key = jax.random.PRNGKey(0)
data = make_logreg_data(key, n_samples=500, dim=30, n_workers=5)
loss_fn = logreg_loss(lam=0.01)

# reference optimum f* (exact GD)
full = {"x": data.features, "y": data.labels}
p_star = init_logreg_params(30)
gd = jax.jit(lambda p: jax.tree.map(
    lambda a, g: a - 0.5 * g, p, jax.grad(loss_fn)(p, full)))
for _ in range(3000):
    p_star = gd(p_star)
f_star = float(loss_fn(p_star, full))

cfg = ByzVRMarinaConfig(
    n_workers=5, n_byz=1, p=0.1, lr=0.5,
    aggregator=get_aggregator(args.agg,
                              bucket_size=0 if args.agg == "mean" else 2),
    compressor=(get_compressor("randk", ratio=args.randk)
                if args.randk < 1 else get_compressor("identity")),
    attack=get_attack(args.attack))

method = make_method(args.method, cfg, loss_fn, corrupt_labels_logreg)
step = jax.jit(method.step)
anchor = data.stacked()
state = method.init(init_logreg_params(30), anchor, key)

print(f"method={args.method} attack={args.attack} "
      f"aggregator={cfg.aggregator.name} "
      f"compressor={cfg.compressor.name}  f*={f_star:.6f}")
k = jax.random.PRNGKey(42)
for it in range(args.iters):
    k, k1, k2 = jax.random.split(k, 3)
    state, m = step(state, data.sample_batches(k1, 32), anchor, k2)
    if (it + 1) % 100 == 0:
        gap = float(loss_fn(state["params"], full)) - f_star
        print(f"  round {it+1:4d}  f(x)-f* = {gap:.3e}")
print("done — linear convergence to f* despite the Byzantine worker"
      if float(loss_fn(state['params'], full)) - f_star < 1e-4 else
      "done — did NOT reach f* (expected for --agg mean under attack)")
