"""Pallas TPU kernels for the system's compute hot-spots (DESIGN.md §3):

- robust_agg: fused bucketing + coordinate-wise median/trimmed-mean over the
  worker-stacked matrix (server-side aggregation, one HBM sweep).
- norm_agg: the norm-based rules — tiled pairwise-Gram (Krum) and fused
  Weiszfeld (RFA) kernels — plus the shared zero-copy machinery: the on-chip
  bucket_matrix permutation operator and in-kernel attack injection.
- quantize: block-wise l2-dithering compress+dequantize (worker-side).

ops.py = jit'd wrappers; backend.py resolves ``interpret=None`` once
(interpret on CPU/GPU hosts, compiled on TPU); ref.py = pure-jnp oracles
the tests sweep against (norm-based ones delegate to core.aggregators).
"""
from repro.kernels import ops, ref  # noqa: F401
from repro.kernels.backend import resolve_interpret  # noqa: F401
