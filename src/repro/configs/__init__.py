from repro.configs.base import (  # noqa: F401
    ATTN, SWA, MLA, RGLRU, MAMBA2,
    ArchConfig, MoEConfig, InputShape, INPUT_SHAPES, ASSIGNED_ARCHS,
    get_config, list_configs, register,
)
