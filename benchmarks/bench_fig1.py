"""Paper Figure 1: optimality gap of 3 aggregation rules (AVG, CM, RFA)
under 5 attacks (NA, LF, BF, ALIE, IPM), homogeneous data, 4 good + 1
byzantine worker, with and without RandK (K = 0.1 d) compression.

The whole grid is ONE declarative ``Sweep`` executed through the batched
engine (``repro.exec``): with ``seeds`` > 1 every (compressor, aggregator,
attack) cell becomes a jit-signature group that runs as a single
vmapped-over-seeds trajectory, and the mean±std-over-seeds table lands in
``experiments/bench/fig1_summary.json``. Each emitted row still carries
the resolved spec JSON, so any cell reproduces with
``RunSpec.from_dict(artifact["spec"]).run()``.
"""
import os

from benchmarks.common import ART_DIR, emit, logreg_reference
from repro import exec as xc
from repro.api import RunSpec, Sweep, build

DIM = 30
BASE = RunSpec(task="logreg", method="marina", n_workers=5, n_byz=1,
               p=0.1, lr=0.5, seed=0,
               data_kwargs={"n_samples": 400, "dim": DIM, "data_seed": 0})

GRID = {
    "compressor_kwargs.ratio": (1.0, 0.1),          # none vs RandK(0.1d)
    "aggregator": ("mean", "cm", "rfa"),
    "attack": ("NA", "LF", "BF", "ALIE", "IPM"),
}
_AGG_LABEL = {"mean": "avg", "cm": "cm", "rfa": "rfa"}


def cells(iters, seeds):
    base = BASE.replace(steps=iters, compressor="randk")
    grid = dict(GRID)
    if len(seeds) > 1:
        grid["seed"] = tuple(seeds)
    out = []
    for run_id, spec in Sweep(base=base, grid=grid).expand():
        if spec.compressor_kwargs["ratio"] >= 1.0:
            # identity wire format, not RandK(d)
            spec = spec.replace(compressor="identity", compressor_kwargs={})
        if spec.aggregator == "mean":
            spec = spec.replace(bucket_size=0)
        out.append((run_id, spec))
    return out


def run(iters=500, seeds=(0,)):
    exp0 = build(BASE.replace(steps=iters))
    full, f_star = logreg_reference(exp0)
    loss_fn = exp0.loss_fn
    grid = cells(iters, seeds)
    srun = xc.run_cells(grid, run_kw={"log_every": iters})
    for run_id, spec in grid:
        if run_id in srun.failures:
            continue
        result = srun[run_id]
        gap = float(loss_fn(result.params, full)) - f_star
        ratio = (spec.compressor_kwargs.get("ratio", 1.0)
                 if spec.compressor == "randk" else 1.0)
        comp_name = "none" if ratio >= 1.0 else f"randk{ratio}"
        tag = f"/seed{spec.seed}" if len(seeds) > 1 else ""
        emit(f"fig1/{comp_name}/{_AGG_LABEL[spec.aggregator]}/"
             f"{spec.attack}{tag}",
             result.wall_s / iters * 1e6, f"gap={gap:.3e}", spec=spec)
    xc.write_summary(os.path.join(ART_DIR, "fig1_summary.json"),
                     xc.summarize(srun.artifacts))


if __name__ == "__main__":
    run()
