"""``RunSpec`` — one frozen, serializable description of an experiment.

The paper's experimental claim is a grid (methods x attacks x aggregators,
with and without compression), and before this layer every benchmark/example
hand-assembled its own ``ByzVRMarinaConfig`` + registry lookups. A ``RunSpec``
is the declarative alternative: every component is named by its registry
string plus a JSON-scalar kwargs dict, so a spec

  * validates eagerly at construction (registry membership with did-you-mean
    suggestions, ``agg_mode`` in ``AGG_BACKENDS``, ``p`` in (0,1], the
    delta < 1/2 byzantine bound — before any jit tracing);
  * round-trips exactly through ``to_dict``/``from_dict``/``to_json``, so
    benchmarks can emit the resolved spec next to each result file and any
    trajectory is reproducible from artifacts alone;
  * builds the full experiment: ``spec.build_config()`` -> ByzVRMarinaConfig,
    ``spec.build()`` -> Experiment (method + stream + loss + corrupt_fn),
    ``spec.run()`` -> metrics via the shared training loop (api/runner.py).

Grid expansion over any spec fields is ``api.sweep.Sweep``.
"""
from __future__ import annotations

import dataclasses
import json
import warnings
from typing import Optional

from repro.api import registry
from repro.core.engine import AGG_BACKENDS


SCHEMA_VERSION = 1

_KWARGS_FIELDS = ("method_kwargs", "attack_kwargs", "aggregator_kwargs",
                  "compressor_kwargs", "optimizer_kwargs", "data_kwargs",
                  "faults")


def resolve_agg_mode(mode: str) -> str:
    """CLI convenience: "auto" -> the fused Pallas kernel path on real TPU
    backends, the paper-faithful gspmd path elsewhere (interpret-mode pallas
    would only slow a CPU host). Specs always store the resolved mode."""
    if mode != "auto":
        return mode
    import jax
    return "pallas" if jax.default_backend() == "tpu" else "gspmd"


@dataclasses.dataclass(frozen=True)
class RunSpec:
    """Declarative experiment description; every field is a JSON scalar or a
    JSON-scalar dict, validated eagerly in ``__post_init__``."""

    # task / model
    task: str = "logreg"                 # registry "task": logreg | lm
    arch: Optional[str] = None           # registry "arch" (lm task)
    # gradient estimator (registry "method")
    method: str = "marina"
    # byzantine setup
    n_workers: int = 5
    n_byz: int = 1
    attack: str = "ALIE"                 # registry "attack"
    # robust aggregation
    aggregator: str = "cm"               # registry "aggregator"
    bucket_size: int = 2                 # Alg. 2 bucketing (0/1 = off)
    agg_mode: str = "gspmd"              # engine.AGG_BACKENDS
    # compression
    compressor: str = "identity"         # registry "compressor"
    # optimization
    p: float = 0.1                       # full-gradient probability
    lr: float = 0.5
    optimizer: str = "none"              # registry "optimizer"
    # partial participation: fraction (float in (0,1]) or count (int in
    # [1, n_workers]) of workers sampled uniformly each round. Sampling is
    # seeded and bit-replayable from (spec, seed); non-sampled workers keep
    # their estimator state untouched and upload zero bits. 1.0 = everyone,
    # byte-identical to a spec without the field.
    participation: float = 1.0
    # schedule
    steps: int = 100
    seed: int = 0
    # observability (repro.obs): log-cadence steps run the telemetry twin
    # (bit-identical trajectory) and history rounds carry RoundTrace +
    # detection metrics
    trace: bool = False
    # chaos layer (repro.faults, DESIGN.md §6): ``faults`` is a FaultPlan
    # payload ({"seed": ..., "faults": [{"kind": ..., "prob": ...,
    # "workers": [...]}, ...]}; {} = no plan), ``fault_guard`` turns on the
    # fail-closed non-finite masking in the aggregation prologue
    faults: dict = dataclasses.field(default_factory=dict)
    fault_guard: bool = False
    # per-component kwargs (JSON scalars only)
    method_kwargs: dict = dataclasses.field(default_factory=dict)
    attack_kwargs: dict = dataclasses.field(default_factory=dict)
    aggregator_kwargs: dict = dataclasses.field(default_factory=dict)
    compressor_kwargs: dict = dataclasses.field(default_factory=dict)
    optimizer_kwargs: dict = dataclasses.field(default_factory=dict)
    data_kwargs: dict = dataclasses.field(default_factory=dict)

    # -- validation ---------------------------------------------------------
    def __post_init__(self):
        registry.check("task", self.task)
        registry.check("method", self.method)
        registry.check("attack", self.attack)
        registry.check("aggregator", self.aggregator)
        registry.check("compressor", self.compressor)
        registry.check("optimizer", self.optimizer)
        if self.arch is not None:
            registry.check("arch", self.arch)
        if self.agg_mode not in AGG_BACKENDS:
            hint = (" — pass 'auto' through api.spec.resolve_agg_mode() "
                    "first" if self.agg_mode == "auto" else "")
            raise ValueError(
                f"agg_mode {self.agg_mode!r} not in {AGG_BACKENDS}{hint}")
        if not 0.0 < self.p <= 1.0:
            raise ValueError(
                f"p={self.p} must be in (0, 1] (full-gradient probability)")
        if self.n_workers < 1:
            raise ValueError(f"n_workers={self.n_workers} must be >= 1")
        if self.n_byz < 0:
            raise ValueError(f"n_byz={self.n_byz} must be >= 0")
        from repro.core.theory import delta_over_active_set
        # in-expectation check: uniform sampling preserves the byzantine
        # fraction, so E[delta over the sampled cohort] = delta over the
        # configured set — this is the hard feasibility bound
        if delta_over_active_set(self.n_workers, self.n_byz) >= 0.5:
            raise ValueError(
                f"n_byz={self.n_byz} of n_workers={self.n_workers} gives "
                f"delta={self.n_byz / self.n_workers:.2f} >= 1/2 — no "
                "(delta,c)-robust aggregator exists; reduce n_byz or add "
                "workers")
        n_active = self.resolved_participation()
        if n_active < self.n_workers:
            if self.agg_mode not in ("gspmd", "pallas"):
                raise ValueError(
                    f"participation={self.participation} is not supported "
                    f"under agg_mode={self.agg_mode!r}: per-round client "
                    "sampling needs the masked aggregation prologue, which "
                    "lives in the gspmd and pallas backends (DESIGN.md §7)")
            # worst-case check over the sampled cohort (BROADCAST's
            # time-varying byzantine sets): every byzantine may land in one
            # round's sample
            worst = delta_over_active_set(n_active, self.n_byz)
            if self.aggregator != "mean" and worst >= 0.5:
                warnings.warn(
                    f"worst-case sampled byzantine fraction is "
                    f"{worst:.2f} >= 1/2 (n_byz={self.n_byz} vs n_active="
                    f"{n_active}): a round whose sample is majority-"
                    "byzantine has no (delta,c) guarantee; raise "
                    "participation or reduce n_byz",
                    stacklevel=2)
        s = max(self.bucket_size, 1)
        if (self.aggregator != "mean" and s > 1
                and delta_over_active_set(
                    n_active, self.n_byz, bucket_size=s) >= 0.5):
            warnings.warn(
                f"after bucketing (s={s}) the byzantine fraction over the "
                f"active set is "
                f"{delta_over_active_set(n_active, self.n_byz, bucket_size=s):.2f}"
                " >= 1/2: Def. 2.1's guarantee is void and convergence is "
                "only to the heterogeneity floor; reduce bucket_size or "
                "n_byz",
                stacklevel=2)
        if self.bucket_size < 0:
            raise ValueError(f"bucket_size={self.bucket_size} must be >= 0")
        if self.steps < 0:
            raise ValueError(f"steps={self.steps} must be >= 0")
        if self.task == "lm" and self.arch is None:
            raise ValueError(
                "task='lm' needs arch=<name>; registered: "
                + ", ".join(registry.components("arch")))
        if self.method == "saga" and self.task == "lm":
            raise ValueError(
                "method='saga' needs a FIXED anchor set (its per-sample "
                "gradient table is indexed by position into the anchor), "
                "but the lm task's TokenStream resamples the anchor every "
                "round — the 'correction' term would be noise, not SAGA. "
                "Use task='logreg', or a VR method without per-sample "
                "state (marina / byz_ef21 / mvr)")
        if self.method == "byz_ef21":
            comp = registry.resolve("compressor", self.compressor,
                                    **self.compressor_kwargs)
            if comp.contractive_fn is None:
                raise ValueError(
                    "method='byz_ef21' needs a contractive compressor "
                    "(topk / sign / identity): EF21's error-feedback "
                    "recursion contracts only under "
                    "E||C(x)-x||^2 <= delta_C ||x||^2, and unbiasedness "
                    "scaling (randk's d/K) breaks it; got "
                    f"compressor={self.compressor!r}")
        if self.trace and self.agg_mode in ("all_to_all", "sparse_support"):
            raise ValueError(
                f"trace=True is not supported under agg_mode="
                f"{self.agg_mode!r}: the sharded wire modes never hold the "
                "stacked candidates in one place, so per-worker influence / "
                "distance diagnostics have nothing to read. Use 'gspmd' or "
                "'pallas'")
        if self.faults or self.fault_guard:
            from repro.faults.plan import as_plan
            plan = as_plan(self.faults)    # raises on unknown kinds/keys
            if self.fault_guard and self.agg_mode not in ("gspmd", "pallas"):
                raise ValueError(
                    f"fault_guard=True is not supported under agg_mode="
                    f"{self.agg_mode!r}: the fail-closed masking lives in "
                    "the aggregation prologue of the gspmd and pallas "
                    "backends (DESIGN.md §6)")
            if plan is not None:
                f = plan.worst_case_faulty(self.n_workers)
                n_act = self.resolved_participation()
                if f and delta_over_active_set(
                        n_act, self.n_byz + f) >= 0.5:
                    warnings.warn(
                        f"fault plan can hit {f} worker(s) on top of "
                        f"n_byz={self.n_byz}: worst-case byz+faulty "
                        f"fraction over the active set (n_active={n_act}) "
                        "is >= 1/2, outside the guard's delta budget — "
                        "the drop-faulty-workers equivalence is not "
                        "guaranteed this round",
                        stacklevel=2)
        if self.method == "marina" and self.agg_mode == "sparse_support":
            if (self.compressor != "randk"
                    or not self.compressor_kwargs.get("common_randomness")):
                raise ValueError(
                    "agg_mode='sparse_support' needs compressor='randk' with "
                    "compressor_kwargs={'ratio': ..., "
                    "'common_randomness': True} so all workers share the "
                    f"per-step support; got compressor={self.compressor!r} "
                    f"kwargs={self.compressor_kwargs}")
        for fname in _KWARGS_FIELDS:
            val = getattr(self, fname)
            if not isinstance(val, dict):
                raise TypeError(f"{fname} must be a dict, got {type(val)}")
            try:
                ok = json.loads(json.dumps(val)) == val
            except (TypeError, ValueError):
                ok = False
            if not ok:
                raise ValueError(
                    f"{fname}={val!r} must round-trip through JSON exactly "
                    "(plain str/int/float/bool/None scalars, lists, dicts) "
                    "so the spec stays a serializable artifact")

    # -- participation ------------------------------------------------------
    def resolved_participation(self) -> int:
        """Number of workers sampled each round (n_active).

        ``participation`` is either a fraction (float in (0, 1], rounded
        to the nearest count, never below 1) or an absolute count (int in
        [1, n_workers]). ``n_active == n_workers`` means full
        participation — the engine then compiles the exact same program
        as a spec without the field.
        """
        part = self.participation
        if isinstance(part, bool) or not isinstance(part, (int, float)):
            raise ValueError(
                f"participation={part!r} must be a fraction in (0, 1] or "
                "an integer count in [1, n_workers]")
        if isinstance(part, int):
            if not 1 <= part <= self.n_workers:
                raise ValueError(
                    f"participation={part} (count) must be in [1, "
                    f"n_workers={self.n_workers}]")
            return part
        if not 0.0 < part <= 1.0:
            raise ValueError(
                f"participation={part} (fraction) must be in (0, 1]")
        return max(1, min(self.n_workers, round(part * self.n_workers)))

    # -- serialization ------------------------------------------------------
    def to_dict(self) -> dict:
        """Plain-JSON dict in field order; exact ``from_dict`` inverse."""
        out = {"schema_version": SCHEMA_VERSION}
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            out[f.name] = dict(v) if isinstance(v, dict) else v
        return out

    @classmethod
    def from_dict(cls, d: dict) -> "RunSpec":
        d = dict(d)
        version = d.pop("schema_version", SCHEMA_VERSION)
        if version != SCHEMA_VERSION:
            raise ValueError(
                f"spec schema_version {version} != {SCHEMA_VERSION}")
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            import difflib
            hints = []
            for k in sorted(unknown):
                close = difflib.get_close_matches(k, sorted(known), n=1)
                hints.append(f"{k!r}"
                             + (f" (did you mean {close[0]!r}?)"
                                if close else ""))
            raise ValueError("unknown RunSpec field(s): " + ", ".join(hints))
        return cls(**d)

    def to_json(self, **dumps_kw) -> str:
        dumps_kw.setdefault("indent", 1)
        return json.dumps(self.to_dict(), **dumps_kw)

    @classmethod
    def from_json(cls, s: str) -> "RunSpec":
        return cls.from_dict(json.loads(s))

    def replace(self, **updates) -> "RunSpec":
        """``dataclasses.replace`` plus dotted-key merges into the kwargs
        dicts: ``spec.replace(**{"compressor_kwargs.ratio": 0.1})``."""
        merged: dict = {}
        for key, val in updates.items():
            if "." in key:
                parent, sub = key.split(".", 1)
                if parent not in _KWARGS_FIELDS:
                    raise ValueError(
                        f"dotted override {key!r}: {parent!r} is not one of "
                        f"{_KWARGS_FIELDS}")
                base = merged.get(parent, dict(getattr(self, parent)))
                base[sub] = val
                merged[parent] = base
            else:
                merged[key] = val
        return dataclasses.replace(self, **merged)

    # -- builders -----------------------------------------------------------
    def build_config(self):
        """Resolve the named components into a ``ByzVRMarinaConfig`` (the
        engine-facing config; distributed extras like mesh/grad_specs are
        added by the caller via ``dataclasses.replace``)."""
        from repro.core.byz_vr_marina import ByzVRMarinaConfig
        from repro.faults.plan import as_plan
        agg_kw = {"n_byz": self.n_byz, **self.aggregator_kwargs}
        if self.aggregator == "mean":
            agg_kw.pop("n_byz")          # mean ignores it; keep cfg minimal
        opt_kw = {"lr": self.lr, **self.optimizer_kwargs}
        n_active = self.resolved_participation()
        return ByzVRMarinaConfig(
            fault_plan=as_plan(self.faults),
            fault_guard=self.fault_guard,
            n_workers=self.n_workers,
            n_byz=self.n_byz,
            n_active=None if n_active == self.n_workers else n_active,
            p=self.p,
            lr=self.lr,
            aggregator=registry.resolve("aggregator", self.aggregator,
                                        bucket_size=self.bucket_size,
                                        **agg_kw),
            compressor=registry.resolve("compressor", self.compressor,
                                        **self.compressor_kwargs),
            attack=registry.resolve("attack", self.attack,
                                    **self.attack_kwargs),
            agg_mode=self.agg_mode,
            optimizer=(None if self.optimizer == "none"
                       else registry.resolve("optimizer", self.optimizer,
                                             **opt_kw)),
        )

    def build(self):
        """-> ``runner.Experiment`` (method, data stream, loss, corrupt_fn)."""
        from repro.api import runner
        return runner.build(self)

    def run(self, **run_kw):
        """Build and run via the shared training loop (api/runner.py)."""
        from repro.api import runner
        return runner.run(self, **run_kw)


# ---------------------------------------------------------------------------
# streaming-aggregation service spec (repro.serve)
# ---------------------------------------------------------------------------

SERVE_AGG_MODES = ("gspmd", "pallas")
ARRIVAL_MODES = ("const", "exp", "lognormal", "trace")
STALENESS_MODES = ("none", "fedbuff")
_SERVE_KWARGS_FIELDS = ("arrival_kwargs", "method_kwargs", "attack_kwargs",
                        "aggregator_kwargs", "compressor_kwargs",
                        "data_kwargs")


@dataclasses.dataclass(frozen=True)
class ServeSpec:
    """Declarative description of a buffered-asynchronous aggregation
    service run (``repro.serve.service``), the streaming counterpart of
    ``RunSpec``: n_clients dispatch updates continuously under a seeded
    arrival process, the service fires the robust aggregator whenever the
    device buffer holds ``buffer_size`` deduplicated updates, and stale
    candidates are FedBuff-weighted (``1/sqrt(1+tau)``) inside the
    aggregation's fused ``w`` path. Same contract as RunSpec: every field
    is a JSON scalar / scalar dict, validated eagerly against the registry,
    and the spec round-trips exactly through ``to_dict``/``from_dict``.
    """

    # task / model
    task: str = "logreg"                 # registry "task": logreg | lm
    arch: Optional[str] = None           # registry "arch" (lm task)
    # gradient estimator — must be streamable (pure per-client candidates)
    method: str = "sgd"
    # client population & byzantine setup (fraction is over the BUFFER)
    n_clients: int = 32
    n_byz: int = 4
    attack: str = "ALIE"                 # registry "attack"
    # robust aggregation
    aggregator: str = "cm"               # registry "aggregator"
    bucket_size: int = 0                 # Alg. 2 bucketing (0/1 = off)
    agg_mode: str = "gspmd"              # SERVE_AGG_MODES only
    # compression (applied per dispatched update, like csgd's wire)
    compressor: str = "identity"         # registry "compressor"
    # optimization
    lr: float = 0.5
    # buffered-async protocol
    buffer_size: int = 8                 # K: fire threshold
    rounds: int = 20                     # fired aggregation rounds
    staleness: str = "fedbuff"           # STALENESS_MODES
    # arrival process (repro.serve.arrivals)
    arrival: str = "exp"                 # ARRIVAL_MODES
    seed: int = 0
    # observability (repro.obs): fired rounds additionally run the traced
    # aggregation twin and the result carries per-fire RoundTraces
    trace: bool = False
    # per-component kwargs (JSON scalars only)
    arrival_kwargs: dict = dataclasses.field(default_factory=dict)
    method_kwargs: dict = dataclasses.field(default_factory=dict)
    attack_kwargs: dict = dataclasses.field(default_factory=dict)
    aggregator_kwargs: dict = dataclasses.field(default_factory=dict)
    compressor_kwargs: dict = dataclasses.field(default_factory=dict)
    data_kwargs: dict = dataclasses.field(default_factory=dict)

    # -- validation ---------------------------------------------------------
    def __post_init__(self):
        registry.check("task", self.task)
        registry.check("method", self.method)
        registry.check("attack", self.attack)
        registry.check("aggregator", self.aggregator)
        registry.check("compressor", self.compressor)
        if self.arch is not None:
            registry.check("arch", self.arch)
        from repro.core.estimators import streamable
        if not streamable(self.method):
            raise ValueError(
                f"method {self.method!r} is not streamable: the buffered-"
                "async service needs candidates that are a pure function of "
                "(params, batch, key) per client, but this estimator carries "
                "round-coupled shared state (e.g. MARINA's c_k coin or "
                "anchor broadcasts). Streamable methods: "
                + ", ".join(n for n in registry.components("method")
                            if streamable(n)))
        if self.agg_mode not in SERVE_AGG_MODES:
            raise ValueError(
                f"agg_mode {self.agg_mode!r} not in {SERVE_AGG_MODES} — the "
                "service aggregates a device-resident buffer, so the "
                "sharded wire modes (all_to_all / sparse_support) do not "
                "apply")
        if self.arrival not in ARRIVAL_MODES:
            raise ValueError(
                f"arrival {self.arrival!r} not in {ARRIVAL_MODES}")
        if self.staleness not in STALENESS_MODES:
            raise ValueError(
                f"staleness {self.staleness!r} not in {STALENESS_MODES}")
        if self.n_clients < 1:
            raise ValueError(f"n_clients={self.n_clients} must be >= 1")
        if self.n_byz < 0:
            raise ValueError(f"n_byz={self.n_byz} must be >= 0")
        from repro.core.theory import delta_over_active_set
        if delta_over_active_set(self.n_clients, self.n_byz) >= 0.5:
            raise ValueError(
                f"n_byz={self.n_byz} of n_clients={self.n_clients} gives "
                f"delta={self.n_byz / self.n_clients:.2f} >= 1/2 over the "
                "client population — no (delta,c)-robust aggregator exists")
        if not 1 <= self.buffer_size <= self.n_clients:
            raise ValueError(
                f"buffer_size={self.buffer_size} must be in [1, n_clients="
                f"{self.n_clients}] — sequence-number dedup admits at most "
                "one in-flight update per client into a buffer")
        if self.rounds < 0:
            raise ValueError(f"rounds={self.rounds} must be >= 0")
        if self.bucket_size < 0:
            raise ValueError(f"bucket_size={self.bucket_size} must be >= 0")
        if self.task == "lm" and self.arch is None:
            raise ValueError(
                "task='lm' needs arch=<name>; registered: "
                + ", ".join(registry.components("arch")))
        # the byzantine fraction the aggregator sees is over the BUFFER
        # (the service's active set): in the worst case every byz client
        # lands in one buffer of size K — same delta-over-active-set rule
        # as RunSpec's sampled cohort (DESIGN.md §7).
        worst = delta_over_active_set(self.buffer_size, self.n_byz)
        if self.aggregator != "mean" and worst >= 0.5:
            warnings.warn(
                f"worst-case buffered byzantine fraction is "
                f"{worst:.2f} >= 1/2 (n_byz={self.n_byz} "
                f"vs buffer_size={self.buffer_size}): no (delta,c)-robust "
                "aggregator can cover a buffer where byzantines are the "
                "majority; raise buffer_size or reduce n_byz",
                stacklevel=2)
        if self.arrival == "trace" and "path" not in self.arrival_kwargs \
                and "events" not in self.arrival_kwargs:
            raise ValueError(
                "arrival='trace' needs arrival_kwargs={'path': <trace.json>}"
                " (or an inline 'events' list)")
        for fname in _SERVE_KWARGS_FIELDS:
            val = getattr(self, fname)
            if not isinstance(val, dict):
                raise TypeError(f"{fname} must be a dict, got {type(val)}")
            try:
                ok = json.loads(json.dumps(val)) == val
            except (TypeError, ValueError):
                ok = False
            if not ok:
                raise ValueError(
                    f"{fname}={val!r} must round-trip through JSON exactly "
                    "(plain str/int/float/bool/None scalars, lists, dicts) "
                    "so the spec stays a serializable artifact")

    # -- serialization (same shape as RunSpec) ------------------------------
    def to_dict(self) -> dict:
        out = {"schema_version": SCHEMA_VERSION, "kind": "serve"}
        for f in dataclasses.fields(self):
            v = getattr(self, f.name)
            out[f.name] = dict(v) if isinstance(v, dict) else v
        return out

    @classmethod
    def from_dict(cls, d: dict) -> "ServeSpec":
        d = dict(d)
        version = d.pop("schema_version", SCHEMA_VERSION)
        if version != SCHEMA_VERSION:
            raise ValueError(
                f"spec schema_version {version} != {SCHEMA_VERSION}")
        kind = d.pop("kind", "serve")
        if kind != "serve":
            raise ValueError(f"not a ServeSpec payload: kind={kind!r}")
        known = {f.name for f in dataclasses.fields(cls)}
        unknown = set(d) - known
        if unknown:
            import difflib
            hints = []
            for k in sorted(unknown):
                close = difflib.get_close_matches(k, sorted(known), n=1)
                hints.append(f"{k!r}"
                             + (f" (did you mean {close[0]!r}?)"
                                if close else ""))
            raise ValueError("unknown ServeSpec field(s): "
                             + ", ".join(hints))
        return cls(**d)

    def to_json(self, **dumps_kw) -> str:
        dumps_kw.setdefault("indent", 1)
        return json.dumps(self.to_dict(), **dumps_kw)

    @classmethod
    def from_json(cls, s: str) -> "ServeSpec":
        return cls.from_dict(json.loads(s))

    def replace(self, **updates) -> "ServeSpec":
        """``dataclasses.replace`` plus dotted-key kwargs merges, like
        ``RunSpec.replace``."""
        merged: dict = {}
        for key, val in updates.items():
            if "." in key:
                parent, sub = key.split(".", 1)
                if parent not in _SERVE_KWARGS_FIELDS:
                    raise ValueError(
                        f"dotted override {key!r}: {parent!r} is not one of "
                        f"{_SERVE_KWARGS_FIELDS}")
                base = merged.get(parent, dict(getattr(self, parent)))
                base[sub] = val
                merged[parent] = base
            else:
                merged[key] = val
        return dataclasses.replace(self, **merged)

    # -- builders -----------------------------------------------------------
    def to_run_spec(self, **overrides) -> RunSpec:
        """The synchronous RunSpec this service degenerates to in the
        K = n_clients, zero-latency limit — the sync-parity oracle, and the
        config/experiment builder the service reuses."""
        base = dict(
            task=self.task, arch=self.arch, method=self.method,
            n_workers=self.n_clients, n_byz=self.n_byz, attack=self.attack,
            aggregator=self.aggregator, bucket_size=self.bucket_size,
            agg_mode=self.agg_mode, compressor=self.compressor,
            p=1.0, lr=self.lr, steps=self.rounds, seed=self.seed,
            trace=self.trace,
            method_kwargs=dict(self.method_kwargs),
            attack_kwargs=dict(self.attack_kwargs),
            aggregator_kwargs=dict(self.aggregator_kwargs),
            compressor_kwargs=dict(self.compressor_kwargs),
            data_kwargs=dict(self.data_kwargs))
        base.update(overrides)
        return RunSpec(**base)

    def build(self):
        """-> ``repro.serve.service.AggregationService``."""
        from repro.serve import service
        return service.AggregationService(self)

    def run(self, **run_kw):
        """Build and drive the service for ``rounds`` fired rounds."""
        return self.build().run(**run_kw)
