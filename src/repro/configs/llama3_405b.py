"""llama3-405b [dense] — GQA, 128k vocab.

126L d_model=16384 128H (GQA kv=8) d_ff=53248 vocab=128256 [arXiv:2407.21783]
"""
from repro.configs.base import ArchConfig, ATTN, register

CONFIG = register(ArchConfig(
    name="llama3-405b",
    family="dense",
    citation="arXiv:2407.21783",
    num_layers=126,
    d_model=16384,
    num_heads=128,
    num_kv_heads=8,
    d_ff=53248,
    vocab_size=128_256,
    head_dim=128,
    block_pattern=(ATTN,),
    rope_theta=500_000.0,
))
