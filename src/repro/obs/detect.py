"""Detection-quality metrics against the ground-truth byzantine mask.

The paper-science observable behind Table 2: a robust rule "works" when the
byzantine rows end up with (near-)zero effective weight in the aggregate.
``RoundTrace.influence`` records exactly that weight, so detection quality
is a pure host-side readout:

* a worker counts as FILTERED when its influence falls below ``frac`` of
  the uniform share 1/n (default: half the uniform share);
* precision / recall score the filtered set against ``byz_mask``;
* ``byz_leakage`` is the fraction of total (positive) influence mass held
  by byzantine rows — the quantity that actually perturbs the aggregate,
  and the one ALIE-style attacks are designed to keep high.

Works on a device RoundTrace, a ``to_host`` dict, or a history record that
embeds the trace fields.
"""
from __future__ import annotations

import numpy as np


def _field(trace, name):
    if isinstance(trace, dict):
        return trace.get(name)
    return getattr(trace, name, None)


def filtered_mask(trace, frac: float = 0.5) -> np.ndarray:
    """(n,) bool: workers whose influence is below ``frac``·(1/n)."""
    infl = np.asarray(_field(trace, "influence"), np.float64)
    return infl < frac / infl.shape[0]


def detection_metrics(trace, frac: float = 0.5) -> dict:
    """Precision/recall of the filtered-worker set vs the ground-truth
    byzantine mask, plus the byzantine influence-leakage fraction.

    Empty-denominator convention: with nothing filtered precision is 1.0
    (no false accusations), with no byzantines recall is 1.0.
    """
    infl = np.asarray(_field(trace, "influence"), np.float64)
    byz = np.asarray(_field(trace, "byz_mask"), bool)
    filt = filtered_mask(trace, frac)
    tp = int((filt & byz).sum())
    fp = int((filt & ~byz).sum())
    fn = int((~filt & byz).sum())
    pos = np.clip(infl, 0.0, None)
    tot = pos.sum()
    return {
        "n_filtered": int(filt.sum()),
        "precision": tp / (tp + fp) if tp + fp else 1.0,
        "recall": tp / (tp + fn) if tp + fn else 1.0,
        "byz_leakage": float(pos[byz].sum() / tot) if tot > 0 else 0.0,
    }


def fault_metrics(trace) -> dict:
    """Precision/recall of the fail-closed guard's rejections against the
    chaos layer's injected ground truth (repro.faults, DESIGN.md §6).

    Detection is ``~guard_valid`` (rows the guard zero-weighted); truth is
    ``fault_mask`` (rows the FaultPlan actually hit). {} when the trace
    carries no fault telemetry (no plan or guard off). A Byzantine row the
    attack overwrote with a finite value is excluded from the truth set —
    the guard is *specified* not to catch statistical adversaries, so
    counting it as a miss would score the spec, not the guard.
    """
    fm = _field(trace, "fault_mask")
    gv = _field(trace, "guard_valid")
    if fm is None or gv is None:
        return {}
    truth = np.asarray(fm, bool)
    det = ~np.asarray(gv, bool)
    byz = _field(trace, "byz_mask")
    if byz is not None:
        truth = truth & ~(np.asarray(byz, bool) & ~det)
    tp = int((det & truth).sum())
    fp = int((det & ~truth).sum())
    fn = int((~det & truth).sum())
    return {
        "n_injected": int(truth.sum()),
        "n_rejected": int(det.sum()),
        "fault_precision": tp / (tp + fp) if tp + fp else 1.0,
        "fault_recall": tp / (tp + fn) if tp + fn else 1.0,
    }


def summarize(traces, frac: float = 0.5) -> dict:
    """Mean detection metrics over a run's logged traces (host dicts or
    RoundTrace objects); {} when there is nothing to summarize."""
    mets = [detection_metrics(t, frac) for t in traces
            if _field(t, "influence") is not None]
    if not mets:
        return {}
    out = {k: float(np.mean([m[k] for m in mets]))
           for k in ("precision", "recall", "byz_leakage")}
    out["n_filtered_mean"] = float(np.mean([m["n_filtered"] for m in mets]))
    out["rounds"] = len(mets)
    fmets = [fm for fm in (fault_metrics(t) for t in traces) if fm]
    if fmets:
        for k in ("fault_precision", "fault_recall"):
            out[k] = float(np.mean([m[k] for m in fmets]))
        out["n_injected_mean"] = float(
            np.mean([m["n_injected"] for m in fmets]))
    return out
