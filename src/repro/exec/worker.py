"""Subprocess worker entry point for the sweep scheduler.

``python -m repro.exec.worker --spec cell.spec.json --out cell.json``
runs ONE sweep cell in a fresh process and writes the artifact JSON
(``RunResult.to_dict()``) atomically. The scheduler launches this with
per-worker ``CUDA_VISIBLE_DEVICES`` / ``JAX_PLATFORMS`` already pinned in
the environment — device selection must happen before jax initializes,
which is exactly why un-batchable cells get a process each. Exit code 0
means the artifact was written; anything else (traceback on stderr) is a
failed cell the scheduler records and isolates.

``--fault crash|hang`` is the chaos layer's process-site injection
(repro.faults, DESIGN.md §6): the scheduler passes it on a cell's FIRST
attempt only, so the retry path must absorb an abrupt kill (exit 137,
before any artifact is written) or a hang (the pool's escalating timeout
reaps it) and the eventual artifact stays byte-identical to a fault-free
run.
"""
from __future__ import annotations

import argparse
import json
import os
import sys

CRASH_EXIT_CODE = 137     # what a SIGKILLed worker would report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description="run one sweep cell")
    ap.add_argument("--spec", required=True,
                    help="path to the cell's RunSpec JSON")
    ap.add_argument("--out", required=True,
                    help="artifact path for RunResult.to_dict() JSON")
    ap.add_argument("--run-kw", default="{}",
                    help="JSON dict of loop knobs (log_every, warmup, ...)")
    ap.add_argument("--fault", choices=("crash", "hang"), default=None,
                    help="injected process fault (repro.faults chaos layer)")
    args = ap.parse_args(argv)

    if args.fault == "crash":
        print("repro.faults: injected crash (worker dies before running)",
              file=sys.stderr, flush=True)
        return CRASH_EXIT_CODE
    if args.fault == "hang":
        import time
        print("repro.faults: injected hang (worker sleeps until reaped)",
              file=sys.stderr, flush=True)
        while True:
            time.sleep(3600)

    from repro.api import RunSpec, run
    with open(args.spec) as f:
        spec = RunSpec.from_json(f.read())
    result = run(spec, **json.loads(args.run_kw))

    payload = result.to_dict()
    os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
    tmp = args.out + f".tmp{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(payload, f, indent=1)
    os.replace(tmp, args.out)
    return 0


if __name__ == "__main__":
    sys.exit(main())
