"""Pallas TPU kernels + wire formats for the one-sweep compressed pipeline.

Two layers live here:

1. ``block_quantize`` — the original fused block-wise l2-dithering quantizer
   (Def. 2.2): norm + stochastic-round + dequantize on a VMEM tile in one
   pass, with the dither noise supplied as an input so the kernel is
   deterministic and oracle-testable.

2. The WIRE layer (DESIGN.md §Wire): per-compressor payload layouts
   (``pack_*``), their jnp reconstructions (``reconstruct`` — the oracle and
   the worker-side state-update path), and the per-(n, TILE_D)-block
   in-kernel reconstruction (``recon_block``) that norm_agg/robust_agg fuse
   into their VMEM load. A reconstructed candidate is
   ``cand = base + decode(payload)`` computed per block on-chip: the dense
   (n, d) candidate matrix never exists in HBM between compress and
   aggregate. ``topk_select`` performs the TopK |x| pass on-chip (per-tile
   candidate pools in VMEM + a tiny O(T·c) final select) so even the
   SELECTION never materializes a dense sorted copy.

Formats (payloads are worker-stacked (n, ...) on the kernel side):

  sparse  — vals (n, k) leaf-dtype + idx (n, k) int32 ascending (randk keeps
            the d/k unbiasedness scaling in vals; topk values ride raw).
            In-kernel reconstruction is a windowed one-hot matmul: CSR-style
            row pointers (``starts``, built once per launch by searchsorted)
            bound each (worker, tile) segment, and fixed-size value chunks
            scatter into the tile on the MXU.
  int8    — levels (n, ceil(d/B)·B) int8 + per-block norms (n, ceil(d/B))
            f32, B = compressors.INT8_BLOCK; dequantized blockwise in VMEM.
  sign    — signs (n, d) int8 in {-1, 0, 1} + scale (n, 1) f32.
  bf16    — vals (n, d) bf16; decode is a cast.
  dense32 — no payload transform; the dense kernels already ARE the wire
            (identity compressor). Never routed through this module.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl

from repro.kernels.backend import resolve_interpret
from repro.core.compressors import (INT8_BLOCK, INT8_LEVELS, _int8_decode,
                                    _int8_encode)


DEFAULT_TILE_D = 2048

WIRE_FORMATS = ("sparse", "int8", "sign", "bf16", "dense32")

# sparse reconstruction: value chunk width for the windowed one-hot matmul.
# Lane-aligned; (CHUNK, tile) one-hot = 128·2048·4B = 1 MiB VMEM at the
# default tile.
SCATTER_CHUNK = 128


def _quant_kernel(x_ref, u_ref, o_ref, *, levels, block):
    x = x_ref[...].astype(jnp.float32)            # (TILE_D,)
    u = u_ref[...].astype(jnp.float32)
    xb = x.reshape(-1, block)
    ub = u.reshape(-1, block)
    norm = jnp.sqrt(jnp.sum(xb * xb, axis=1, keepdims=True))
    scaled = jnp.where(norm > 0, jnp.abs(xb) / jnp.maximum(norm, 1e-30), 0.0)
    level = jnp.floor(scaled * levels + ub)
    out = norm * jnp.sign(xb) * level / levels
    o_ref[...] = out.reshape(x.shape)


@functools.partial(jax.jit, static_argnames=("levels", "block", "tile_d",
                                             "interpret"))
def block_quantize(x, u, *, levels: int = 4, block: int = 256,
                   tile_d: int = DEFAULT_TILE_D, interpret=None):
    """x, u: (d,). Returns dequantized (d,) float32. d padded to tile_d;
    tile_d must be a multiple of ``block``. ``interpret=None`` resolves per
    backend (kernels/backend.py)."""
    assert tile_d % block == 0
    d = x.shape[0]
    pad = (-d) % tile_d
    if pad:
        x = jnp.pad(x, (0, pad))
        u = jnp.pad(u, (0, pad))
    dp = d + pad
    out = pl.pallas_call(
        functools.partial(_quant_kernel, levels=levels, block=block),
        grid=(dp // tile_d,),
        in_specs=[pl.BlockSpec((tile_d,), lambda i: (i,)),
                  pl.BlockSpec((tile_d,), lambda i: (i,))],
        out_specs=pl.BlockSpec((tile_d,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((dp,), jnp.float32),
        interpret=resolve_interpret(interpret),
    )(x, u)
    return out[:d]


# ---------------------------------------------------------------------------
# wire descriptor
# ---------------------------------------------------------------------------

def _lane_tile(d: int, tile_d: int) -> int:
    """Lane-aligned tile, shrunk for small d (mirrors norm_agg._tile_for —
    duplicated locally so norm_agg can import this module cycle-free)."""
    return min(tile_d, max(128, -(-d // 128) * 128))


@dataclasses.dataclass(frozen=True)
class WireSrc:
    """One worker-stacked wire payload, standing in for the dense (n, d)
    candidate matrix at an aggregation-kernel call site.

    ``arrays`` is a tuple of (name, (n, ...) array) in a fixed per-format
    order; ``base`` is the reconstruction base added on-chip — (n, d) for
    per-worker EF/mirror state (byz_ef21, cmfilter), (1, d) for a shared
    server estimate (marina's g^k), or None (zero base: csgd, diana).
    ``cand_dtype`` is the candidate leaf dtype the oracle path would carry —
    decoded values and attacked values round-trip through it so fused ≡
    materialized exactly (norm_agg._prologue contract).
    """
    fmt: str
    n: int
    d: int
    arrays: tuple
    base: Optional[object] = None
    cand_dtype: object = jnp.float32


def _wiresrc_flatten(s):
    names = tuple(nm for nm, _ in s.arrays)
    return tuple(a for _, a in s.arrays) + (s.base,), (
        s.fmt, s.n, s.d, names, s.cand_dtype)


def _wiresrc_unflatten(aux, children):
    fmt, n, d, names, cd = aux
    *arrs, base = children
    return WireSrc(fmt=fmt, n=n, d=d, arrays=tuple(zip(names, arrs)),
                   base=base, cand_dtype=cd)


jax.tree_util.register_pytree_node(WireSrc, _wiresrc_flatten,
                                   _wiresrc_unflatten)


@dataclasses.dataclass(frozen=True)
class WireMeta:
    """Static per-launch reconstruction plan (hashable: rides in the traced
    kernel's closure). ``base_rows`` is 0 (no base) / 1 (shared) / n."""
    fmt: str
    n: int
    d: int
    tile: int
    kp: int = 0          # sparse: padded wire length per worker
    base_rows: int = 0
    cand_dtype: object = jnp.float32


# ---------------------------------------------------------------------------
# worker-side packing (jnp; vmapped over workers by core/wire.py)
# ---------------------------------------------------------------------------

def topk_select(x, k: int, *, tile_d: int = DEFAULT_TILE_D, interpret=None):
    """Indices of the k largest |x| — ``lax.top_k(|x|, k)[1]`` semantics.

    Multi-tile inputs run the selection on-chip: a Pallas pass keeps each
    tile's top-c candidates (c = min(k, tile), lane-padded) in VMEM and
    writes only the (T, c) pool; the final exact top-k runs on the tiny
    pool. Every global top-k element is inside its own tile's top-c, so the
    pool provably contains the answer. Cross-tile ties of equal |x| may
    break differently from the dense sort (by pool rank, not global index).
    """
    xf = x.reshape(-1)
    d = xf.shape[0]
    tile = _lane_tile(d, tile_d)
    if d <= 2 * tile:
        return lax.top_k(jnp.abs(xf.astype(jnp.float32)), k)[1]
    cp = min(tile, max(128, -(-min(k, tile) // 128) * 128))
    dp = -(-d // tile) * tile
    t_count = dp // tile
    xp = jnp.pad(xf.astype(jnp.float32), (0, dp - d))

    def kern(x_ref, v_ref, i_ref):
        t = pl.program_id(0)
        xt = x_ref[...].reshape(-1)
        gidx = (t * tile
                + lax.broadcasted_iota(jnp.int32, (1, tile), 1).reshape(-1))
        a = jnp.where(gidx < d, jnp.abs(xt), -1.0)   # pad below any real |x|
        av, ai = lax.top_k(a, cp)
        v_ref[...] = av.reshape(1, cp)
        i_ref[...] = jnp.take(gidx, ai).reshape(1, cp)

    pv, pi = pl.pallas_call(
        kern,
        grid=(t_count,),
        in_specs=[pl.BlockSpec((tile,), lambda i: (i,))],
        out_specs=(pl.BlockSpec((1, cp), lambda i: (i, 0)),
                   pl.BlockSpec((1, cp), lambda i: (i, 0))),
        out_shape=(jax.ShapeDtypeStruct((t_count, cp), jnp.float32),
                   jax.ShapeDtypeStruct((t_count, cp), jnp.int32)),
        interpret=resolve_interpret(interpret),
    )(xp)
    _, sel = lax.top_k(pv.reshape(-1), k)
    return jnp.take(pi.reshape(-1), sel)


def pack_sparse(key, x, ratio: float, *, topk: bool):
    """(vals (k,) leaf-dtype, idx (k,) int32 ascending) for one leaf.

    Selection mirrors the jnp Compressor EXACTLY (same RNG call for randk,
    same |x| ordering for topk), so the fused path reproduces the oracle's
    coordinates bit-for-bit; only the layout differs.
    """
    d = x.size
    xf = x.reshape(-1)
    k = max(int(ratio * d), 1)
    if topk:
        sel = topk_select(xf, k)
        idx = jnp.sort(sel).astype(jnp.int32)
        vals = jnp.take(xf.astype(jnp.float32), idx).astype(x.dtype)
    else:
        # rand_k's block selection degenerates to per-coordinate for
        # d <= _MAX_UNITS; core/wire.py gates the sparse wire on that.
        sel = jax.random.permutation(key, d)[:k]
        idx = jnp.sort(sel).astype(jnp.int32)
        vals = (jnp.take(xf, idx) * (d / k)).astype(x.dtype)
    return {"vals": vals, "idx": idx}


def pack_int8(key, x):
    """(levels (ceil(d/B)·B,) int8, norms (ceil(d/B),) f32) for one leaf."""
    levels, norms = _int8_encode(key, x)
    return {"lev": levels.reshape(-1), "norms": norms}


def pack_sign(key, x):
    xf = x.reshape(-1).astype(jnp.float32)
    return {"signs": jnp.sign(xf).astype(jnp.int8),
            "scale": jnp.mean(jnp.abs(xf)).reshape(1)}


def pack_bf16(key, x):
    return {"vals": x.reshape(-1).astype(jnp.bfloat16)}


def decode(fmt: str, payload: dict, d: int):
    """Payload of ONE worker/leaf -> dense (d,) f32 — the jnp reconstruction
    shared by the oracle-parity tests and the worker-side state updates
    (DIANA's h, EF21's g_i, cmfilter's u). The in-kernel ``recon_block``
    must match this exactly, tile by tile."""
    if fmt == "sparse":
        out = jnp.zeros((d,), jnp.float32)
        return out.at[payload["idx"]].set(
            payload["vals"].astype(jnp.float32), mode="drop")
    if fmt == "int8":
        nb = payload["norms"].shape[0]
        return _int8_decode(payload["lev"].reshape(nb, INT8_BLOCK),
                            payload["norms"])[:d]
    if fmt == "sign":
        return payload["signs"].astype(jnp.float32) * payload["scale"][0]
    if fmt == "bf16":
        return payload["vals"].astype(jnp.float32)
    raise ValueError(fmt)


# ---------------------------------------------------------------------------
# kernel-side assembly + per-block reconstruction
# ---------------------------------------------------------------------------

def wire_tile(src: WireSrc, tile_d: int) -> int:
    """Tile for a wire launch; int8 tiles stay a multiple of the norm block
    so each tile sees whole quantization blocks."""
    t = _lane_tile(src.d, tile_d)
    if src.fmt == "int8":
        t = -(-t // INT8_BLOCK) * INT8_BLOCK
    return t


def _pad_to(a, width, fill=0):
    pad = width - a.shape[-1]
    if pad:
        a = jnp.pad(a, ((0, 0),) * (a.ndim - 1) + ((0, pad),),
                    constant_values=fill)
    return a


def wire_inputs(src: WireSrc, tile: int, dp: int):
    """Build (vals, specs, names, meta) for the aggregation kernels.

    Dense-ish payloads (int8 / sign / bf16 / base) ride as (n, tile) blocks
    like x would; the sparse wire rides WHOLE as constant revisited VMEM
    blocks (vals/idx/starts), with CSR row pointers built here once by
    searchsorted. Column pads use value 0 (decode-neutral) and index
    sentinel dp (matches no tile).
    """
    n, d = src.n, src.d
    arr = dict(src.arrays)
    vals, specs, names = [], [], []

    def add(name, a, spec):
        vals.append(a)
        specs.append(spec)
        names.append(name)

    kp = 0
    if src.fmt == "sparse":
        v, ix = arr["vals"], arr["idx"]
        kp = max(SCATTER_CHUNK, -(-v.shape[1] // 128) * 128)
        v = _pad_to(v, kp)
        ix = _pad_to(ix, kp, fill=dp)          # sentinel: outside every tile
        t_count = dp // tile
        bounds = jnp.arange(t_count + 1, dtype=jnp.int32) * tile
        starts = jax.vmap(
            lambda row: jnp.searchsorted(row, bounds).astype(jnp.int32))(ix)
        sp = -(-(t_count + 1) // 128) * 128
        starts = _pad_to(starts, sp)
        add("w_vals", v, pl.BlockSpec((n, kp), lambda i: (0, 0)))
        add("w_idx", ix, pl.BlockSpec((n, kp), lambda i: (0, 0)))
        add("w_starts", starts, pl.BlockSpec((n, sp), lambda i: (0, 0)))
    elif src.fmt == "int8":
        nb_t = tile // INT8_BLOCK
        lev = _pad_to(arr["lev"], dp)
        norms = _pad_to(arr["norms"], dp // INT8_BLOCK)
        add("w_lev", lev, pl.BlockSpec((n, tile), lambda i: (0, i)))
        add("w_norms", norms, pl.BlockSpec((n, nb_t), lambda i: (0, i)))
    elif src.fmt == "sign":
        add("w_signs", _pad_to(arr["signs"], dp),
            pl.BlockSpec((n, tile), lambda i: (0, i)))
        add("w_scale", arr["scale"].reshape(n, 1),
            pl.BlockSpec((n, 1), lambda i: (0, 0)))
    elif src.fmt == "bf16":
        add("w_bf", _pad_to(arr["vals"], dp),
            pl.BlockSpec((n, tile), lambda i: (0, i)))
    else:  # pragma: no cover — dense32 never builds a WireSrc
        raise ValueError(src.fmt)

    base_rows = 0
    if src.base is not None:
        base_rows = src.base.shape[0]
        add("w_base", _pad_to(src.base, dp),
            pl.BlockSpec((base_rows, tile), lambda i: (0, i)))

    meta = WireMeta(fmt=src.fmt, n=n, d=d, tile=tile, kp=kp,
                    base_rows=base_rows, cand_dtype=src.cand_dtype)
    return vals, specs, names, meta


def _recon_sparse_block(env, meta: WireMeta):
    """(n, tile) f32 payload values of the current tile, decoded from the
    CSR-windowed wire — a chunked one-hot matmul per worker, bounded by the
    row pointers so total work is O(n·k·tile/d + chunk·tile) per tile."""
    n, tile, kp = meta.n, meta.tile, meta.kp
    t = pl.program_id(0)
    lo = t * tile
    vref, iref = env["w_vals"], env["w_idx"]
    starts = env["w_starts"][...]
    cols = lax.broadcasted_iota(jnp.int32, (SCATTER_CHUNK, tile), 1)
    rows = []
    for i in range(n):
        s = starts[i, t]
        e = starts[i, t + 1]
        n_chunks = (e - s + SCATTER_CHUNK - 1) // SCATTER_CHUNK

        def body(c, acc, i=i, s=s, e=e):
            p0 = s + c * SCATTER_CHUNK
            w0 = jnp.minimum(p0, kp - SCATTER_CHUNK)   # clamped window start
            v = vref[pl.ds(i, 1), pl.ds(w0, SCATTER_CHUNK)]
            ix = iref[pl.ds(i, 1), pl.ds(w0, SCATTER_CHUNK)]
            pos = w0 + lax.broadcasted_iota(jnp.int32, (1, SCATTER_CHUNK), 1)
            live = ((pos >= p0) & (pos < e)           # this chunk's segment
                    & (ix >= lo) & (ix < lo + tile))  # sentinel guard
            vm = jnp.where(live, v.astype(jnp.float32), 0.0)
            oh = jnp.where(ix.reshape(-1)[:, None] - lo == cols, 1.0, 0.0)
            return acc + jnp.dot(vm, oh, preferred_element_type=jnp.float32)

        rows.append(lax.fori_loop(0, n_chunks, body,
                                  jnp.zeros((1, tile), jnp.float32)))
    return jnp.concatenate(rows, axis=0)


def recon_block(env, meta: WireMeta):
    """The fused VMEM load: decode this tile's payload, round-trip through
    the candidate dtype (mirroring Compressor.compress's trailing astype),
    add the base, and round-trip the SUM like the oracle's leaf-dtype add.
    Returns the (n, tile) f32 candidate block."""
    if meta.fmt == "sparse":
        q = _recon_sparse_block(env, meta)
    elif meta.fmt == "int8":
        lev = env["w_lev"][...].astype(jnp.float32)       # (n, tile)
        norms = env["w_norms"][...]                        # (n, tile/B)
        nb = norms.shape[1]
        scale = jnp.broadcast_to(norms[:, :, None],
                                 (meta.n, nb, INT8_BLOCK))
        q = scale.reshape(meta.n, -1) * lev / INT8_LEVELS
    elif meta.fmt == "sign":
        q = env["w_signs"][...].astype(jnp.float32) * env["w_scale"][...]
    elif meta.fmt == "bf16":
        q = env["w_bf"][...].astype(jnp.float32)
    else:  # pragma: no cover
        raise ValueError(meta.fmt)
    q = q.astype(meta.cand_dtype).astype(jnp.float32)
    if meta.base_rows:
        x = q + env["w_base"][...].astype(jnp.float32)
        return x.astype(meta.cand_dtype).astype(jnp.float32)
    return q
