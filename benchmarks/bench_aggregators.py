"""Server-side aggregation throughput: jnp tree path vs Pallas kernels,
across ALL five rules × bucketed/unbucketed (interpret mode on CPU — on TPU
the kernel path is the compiled one). One row per (impl, rule, bucket, n, d),
both impls timed with the SAME ``time_fn`` iteration count.

Besides wall time, every row carries the analytic HBM-sweep count — tensor
traversals in units of the raw (n, d) stack, materialize-counted for the jnp
path (each jnp op reads its inputs and writes its result to HBM; sorting and
reductions on the s-bucketed matrix count 1/s) and read(n·d)+write(d) per
pass for the kernels. On a bandwidth-bound TPU, sweeps ∝ wall time;
``normalized_speedup`` = jnp_sweeps / pallas_sweeps is therefore the
interpret-overhead-free throughput ratio the fusion buys. The whole table is
recorded as ``experiments/bench/BENCH_agg.json`` (ISSUE 4 acceptance: fused
RFA ≤ 2 sweeps per Weiszfeld iteration, ≥ 2× normalized over jnp at
n=16, d=2^20).
"""
import json
import os

import jax

from benchmarks.common import ART_DIR, emit, time_fn
from repro.core.aggregators import COORD_KERNEL_RULE, get_aggregator
from repro.kernels import ops

KEY = jax.random.PRNGKey(0)
ITERS = 3          # same for BOTH impls (the old asymmetry made GB/s lies)
WARMUP = 1
RFA_T = 8          # paper default Weiszfeld iterations
BENCH_TILE_D = 1 << 16   # fewer grid steps -> less interpret-mode overhead


def analytic_sweeps(impl: str, rule: str, s: int) -> float:
    """(n·d)-equivalent HBM traversals per call; materialize-counted."""
    if impl == "pallas":
        # every pass re-streams the raw stack once (bucketing is in-VMEM)
        return {"mean": 1.0, "cm": 1.0, "tm": 1.0,
                "rfa": RFA_T + 1.0, "krum": 2.0}[rule]
    bucketize = (3.0 + 1.0 / s) if s > 1 else 0.0   # gather r+w, mean r, w/s
    b = 1.0 / s if s > 1 else 1.0                   # bucketed-matrix sweep
    if rule == "mean":
        return 1.0
    if rule in ("cm", "tm"):                        # sort r+w, reduce r
        return bucketize + 3.0 * b
    if rule == "rfa":                               # init mean + per iter:
        # diff r+w, square-reduce r, weighted-sum r
        return bucketize + b + RFA_T * 4.0 * b
    if rule == "krum":                              # gram r + weighted-sum r
        return bucketize + 2.0 * b
    raise ValueError(rule)


def _pallas_fn(rule, bucket, agg):
    kw = dict(tile_d=BENCH_TILE_D, interpret=True)
    if rule in COORD_KERNEL_RULE:
        kernel_rule = COORD_KERNEL_RULE[rule]
        return lambda k, a: ops.robust_agg(
            a, k if bucket > 1 else None, bucket_size=bucket,
            rule=kernel_rule, trim=agg.trim, **kw)
    if rule == "rfa":
        return lambda k, a: ops.rfa_agg(
            a, k if bucket > 1 else None, bucket_size=bucket,
            iters=agg.iters, eps=agg.eps, **kw)
    return lambda k, a: ops.krum_agg(
        a, k if bucket > 1 else None, bucket_size=bucket, n_byz=agg.n_byz,
        **kw)


def run():
    rows = []
    for n, d in [(16, 1 << 16), (16, 1 << 20), (32, 1 << 16)]:
        x = jax.random.normal(KEY, (n, d))
        nbytes = n * d * 4
        for rule in ["mean", "cm", "tm", "rfa", "krum"]:
            for bucket in ([1] if rule == "mean" else [1, 2]):
                agg = get_aggregator(rule, bucket_size=bucket, n_byz=1)
                impls = {
                    "jnp": jax.jit(lambda k, a, agg=agg: agg(k, a)),
                    "pallas": _pallas_fn(rule, bucket, agg),
                }
                us = {}
                for impl, fn in impls.items():
                    us[impl] = time_fn(fn, KEY, x, warmup=WARMUP,
                                       iters=ITERS)
                    sweeps = analytic_sweeps(impl, rule, bucket)
                    name = f"agg/{impl}/{rule}/b{bucket}/n{n}/d{d}"
                    emit(name, us[impl],
                         f"GBps={nbytes / us[impl] / 1e3:.2f}"
                         f";sweeps={sweeps:g}")
                    rows.append({"impl": impl, "rule": rule,
                                 "bucket": bucket, "n": n, "d": d,
                                 "us": us[impl], "sweeps": sweeps})
                rows.append({
                    "impl": "speedup", "rule": rule, "bucket": bucket,
                    "n": n, "d": d,
                    "measured_interp": us["jnp"] / us["pallas"],
                    "normalized": (analytic_sweeps("jnp", rule, bucket)
                                   / analytic_sweeps("pallas", rule,
                                                     bucket))})
    payload = {
        "schema": 1,
        "note": ("sweeps = (n*d)-equivalent HBM traversals per call, "
                 "materialize-counted for jnp; normalized speedup = "
                 "jnp_sweeps/pallas_sweeps (bandwidth-bound TPU ratio); "
                 "measured us are CPU interpret mode, same iters both "
                 "impls"),
        "rfa_weiszfeld_iters": RFA_T,
        "rfa_pallas_sweeps_per_iter": (RFA_T + 1.0) / RFA_T,
        "rows": rows,
    }
    os.makedirs(ART_DIR, exist_ok=True)
    with open(os.path.join(ART_DIR, "BENCH_agg.json"), "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
