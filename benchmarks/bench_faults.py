"""Fault-guard overhead benchmark: steps/sec with the fail-closed guard ON
vs OFF (repro.faults, DESIGN.md §6).

For every {backend} x {rule} cell the same seeded logreg trajectory runs
twice — ``fault_guard=False`` (the untouched hot path; the guard-OFF jaxpr
is pinned unchanged by tests/test_faults.py) and ``fault_guard=True`` with
a live FaultPlan injecting nan_grad into a fixed honest worker every round.
Both runs are compile-warmed off the clock, so the ratio isolates the
steady-state cost of (a) the per-round finiteness reduction over the
candidate stack and (b) the masked aggregation epilogue (``jnp.where``
select — never multiply — routed through ``tree_masked`` under gspmd and
the ``valid`` operand of the fused kernel under pallas).

Grid (ISSUE 9 satellite 5): {gspmd, pallas} x {cm, krum, rfa} ->
``experiments/bench/BENCH_faults.json`` (uploaded by the CI chaos job).
Methodology matches bench_obs.py: best-of-REPS of the post-compile loop.
"""
import json
import os

from benchmarks.common import ART_DIR, emit
from repro.api import RunSpec

BACKENDS = ("gspmd", "pallas")
RULES = ("cm", "krum", "rfa")
N_WORKERS = 16
DIM = 512
STEPS = 200
LOG_EVERY = 10
REPS = 5


def _spec(mode: str, rule: str, guard: bool) -> RunSpec:
    faults = {"seed": 7, "faults": [{"kind": "nan_grad", "prob": 1.0,
                                     "workers": [N_WORKERS - 1]}]} \
        if guard else {}
    return RunSpec(
        task="logreg", method="marina", n_workers=N_WORKERS,
        n_byz=N_WORKERS // 8, attack="ALIE", aggregator=rule,
        bucket_size=0, agg_mode=mode, steps=STEPS, lr=0.1,
        faults=faults, fault_guard=guard,
        data_kwargs={"dim": DIM, "n_samples": 256, "batch_size": 16})


def _steps_per_s(spec: RunSpec) -> tuple:
    exp = spec.build()
    # warmup=True compiles off the runner's clock; the last history entry's
    # wall_s is pure post-compile loop time. Best-of-REPS because a single
    # 200-step pass on this small problem is noisy.
    best, result = 0.0, None
    for _ in range(REPS):
        result = exp.run(log_every=LOG_EVERY, warmup=True)
        best = max(best, STEPS / max(result.history[-1]["wall_s"], 1e-9))
    return best, result


def run():
    import math
    payload = {"n_workers": N_WORKERS, "dim": DIM, "steps": STEPS,
               "log_every": LOG_EVERY, "cells": []}
    for mode in BACKENDS:
        for rule in RULES:
            name = f"faults/{mode}/{rule}"
            try:
                off_sps, off_res = _steps_per_s(_spec(mode, rule, False))
                on_sps, on_res = _steps_per_s(_spec(mode, rule, True))
            except Exception as e:  # noqa: BLE001 — report, keep grid
                emit(name, 0.0, f"FAILED {type(e).__name__}: {e}")
                continue
            overhead = (off_sps / max(on_sps, 1e-9) - 1.0) * 100.0
            # the guarded run absorbs a round-constant NaN injection: it
            # must stay finite even though a worker is poisoned every step
            finite = math.isfinite(on_res.history[-1]["loss"])
            cell = {
                "agg_mode": mode, "rule": rule,
                "steps_per_s_off": round(off_sps, 1),
                "steps_per_s_on": round(on_sps, 1),
                "overhead_pct": round(overhead, 2),
                "guarded_final_finite": finite,
                "spec": _spec(mode, rule, True).to_dict(),
            }
            payload["cells"].append(cell)
            emit(name,
                 1e6 / max(on_sps, 1e-9),   # us per guarded step
                 f"off={cell['steps_per_s_off']}sps "
                 f"on={cell['steps_per_s_on']}sps "
                 f"overhead={cell['overhead_pct']}% "
                 f"finite={finite}")
    os.makedirs(ART_DIR, exist_ok=True)
    with open(os.path.join(ART_DIR, "BENCH_faults.json"), "w") as f:
        json.dump(payload, f, indent=1, sort_keys=True)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run()
