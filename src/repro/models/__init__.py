from repro.models.model import (  # noqa: F401
    init_params, forward, loss_fn, init_cache, decode_step,
    param_specs, cache_specs,
)
