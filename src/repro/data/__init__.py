from repro.data.synthetic import (  # noqa: F401
    LogRegData, TokenStream, make_logreg_data, logreg_loss,
    init_logreg_params, logreg_reference,
    corrupt_labels_logreg, corrupt_labels_lm,
)
