"""Streaming-aggregation service driver (repro.serve / DESIGN.md §4).

Drives the buffered-asynchronous Byzantine-robust aggregation service from
the command line: a seeded arrival process (with optional straggler /
dropout / duplicate chaos) feeds client updates into the double buffer,
and every K deduplicated updates fire the robust aggregator with FedBuff
staleness weighting. The CLI is generated from ``ServeSpec``'s fields with
choices enumerated from the unified component registry, exactly like
``launch/train.py``. Examples:

  PYTHONPATH=src python -m repro.launch.serve_agg \\
      --n-clients 32 --n-byz 4 --buffer-size 8 --rounds 50 \\
      --attack ALIE --aggregator cm --arrival exp \\
      --chaos straggler_frac=0.2,dropout=0.05,duplicate=0.1

  # replay a canned trace, journal every round, keep restart points
  PYTHONPATH=src python -m repro.launch.serve_agg --arrival trace \\
      --chaos path=trace.json --ledger runs/serve.jsonl \\
      --checkpoint runs/serve_ck --checkpoint-every 10

``--spec``/``--spec-out`` load/dump a serialized ServeSpec; ``--resume``
restarts from a checkpoint prefix and replays the arrival stream from its
saved cursor, reproducing the uninterrupted trajectory bit-for-bit.
"""
from __future__ import annotations

import argparse
import dataclasses
import json

from repro.api import ServeSpec, components
from repro.api.spec import ARRIVAL_MODES, SERVE_AGG_MODES, STALENESS_MODES

_CHOICE_KINDS = {"arch": "arch", "method": "method", "attack": "attack",
                 "aggregator": "aggregator", "compressor": "compressor"}
_STATIC_CHOICES = {"agg_mode": SERVE_AGG_MODES, "arrival": ARRIVAL_MODES,
                   "staleness": STALENESS_MODES, "task": ("logreg", "lm")}


def _parse_kv(text: str) -> dict:
    """"a=1,b=0.5,c=foo" -> {"a": 1, "b": 0.5, "c": "foo"} (JSON scalars)."""
    out: dict = {}
    for item in filter(None, (s.strip() for s in text.split(","))):
        k, _, v = item.partition("=")
        if not _:
            raise argparse.ArgumentTypeError(
                f"expected key=value, got {item!r}")
        try:
            out[k.strip()] = json.loads(v)
        except json.JSONDecodeError:
            out[k.strip()] = v
    return out


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        description="buffered-async robust aggregation via "
                    "repro.api.ServeSpec")
    for f in dataclasses.fields(ServeSpec):
        flag = "--" + f.name.replace("_", "-")
        if f.name in _CHOICE_KINDS:
            ap.add_argument(flag, default=f.default,
                            choices=(components(_CHOICE_KINDS[f.name])
                                     if f.name != "arch"
                                     else (None,) + components("arch")))
        elif f.name in _STATIC_CHOICES:
            ap.add_argument(flag, default=f.default,
                            choices=_STATIC_CHOICES[f.name])
        elif f.name.endswith("_kwargs"):
            alias = ("--chaos",) if f.name == "arrival_kwargs" else ()
            ap.add_argument(flag, *alias, type=_parse_kv,
                            default={}, metavar="K=V,...",
                            help=f"{f.name} as comma-separated key=value")
        elif isinstance(f.default, bool):
            ap.add_argument(flag, action="store_true")
        else:
            ap.add_argument(flag, type=type(f.default), default=f.default)
    ap.add_argument("--spec", help="load a serialized ServeSpec JSON")
    ap.add_argument("--spec-out", help="dump the resolved spec JSON")
    ap.add_argument("--ledger", help="journal fired rounds to this JSONL")
    ap.add_argument("--checkpoint", help="checkpoint path prefix")
    ap.add_argument("--checkpoint-every", type=int, default=None,
                    metavar="R", help="checkpoint cadence in fired rounds")
    ap.add_argument("--resume", help="checkpoint prefix to restart from")
    ap.add_argument("--digest", action="store_true",
                    help="sha1 the params into each ledger record "
                         "(forces a per-round device sync)")
    ap.add_argument("--sync-each-fire", action="store_true",
                    help="block per fire and report latency percentiles "
                         "instead of overlapping ingest with aggregation")
    ap.add_argument("--metrics-out", help="dump ServeResult JSON here")
    ap.add_argument("--latency-sample-every", type=int, default=8,
                    metavar="N", help="free-running mode: fence every Nth "
                    "fire for sampled latency percentiles (0 = never)")
    from repro.obs import profile
    profile.add_cli_args(ap)            # --metrics-out-jsonl, --profile-dir
    ap.add_argument("--quiet", action="store_true")
    return ap


def spec_from_args(args) -> ServeSpec:
    if args.spec:
        with open(args.spec) as f:
            return ServeSpec.from_json(f.read())
    fields = {f.name: getattr(args, f.name)
              for f in dataclasses.fields(ServeSpec)}
    return ServeSpec(**fields)


def main(argv=None) -> None:
    args = build_parser().parse_args(argv)
    from repro.obs import profile
    if args.profile_dir:
        profile.enable_step_markers()   # before the first backend touch
    spec = spec_from_args(args)
    if args.spec_out:
        with open(args.spec_out, "w") as f:
            f.write(spec.to_json())
    with profile.profile_trace(args.profile_dir):
        res = spec.build().run(
            ledger_path=args.ledger, checkpoint=args.checkpoint,
            checkpoint_every=args.checkpoint_every, resume=args.resume,
            sync_each_fire=args.sync_each_fire, digest=args.digest,
            metrics_jsonl=args.metrics_out_jsonl,
            latency_sample_every=args.latency_sample_every,
            verbose=not args.quiet)
    pct = res.latency_percentiles()
    lat = (f" p50 {pct['p50_ms']:.2f}ms p99 {pct['p99_ms']:.2f}ms"
           if pct else "")
    print(f"[serve_agg] {res.stats['rounds']} rounds, "
          f"{res.stats['accepted']} updates "
          f"({res.stats['rej_replay']} replays + "
          f"{res.stats['rej_dup_client']} dups rejected, "
          f"{res.stats['dropped']} dropped) in {res.wall_s:.2f}s — "
          f"{res.updates_per_s:.1f} updates/s{lat}")
    spct = res.staleness_percentiles()
    if spct:
        print(f"[serve_agg] staleness p50 {spct['staleness_p50']:.0f} "
              f"p90 {spct['staleness_p90']:.0f} "
              f"worst {spct['staleness_worst']:.0f}")
    if res.history:
        m = res.history[-1]
        print(f"[serve_agg] final loss {m['loss']:.4f} "
              f"|g| {m['g_norm']:.3e} "
              f"staleness mean {m['staleness_mean']:.2f}")
    if spec.trace and res.traces:
        det = res.detection_summary()
        print(f"[serve_agg] detection over {det['rounds']} traced rounds: "
              f"precision {det['precision']:.3f} "
              f"recall {det['recall']:.3f} "
              f"byz_leakage {det['byz_leakage']:.3f}")
    if args.metrics_out:
        with open(args.metrics_out, "w") as f:
            json.dump(res.to_dict(), f, indent=1)


if __name__ == "__main__":
    main()
