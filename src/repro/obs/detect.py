"""Detection-quality metrics against the ground-truth byzantine mask.

The paper-science observable behind Table 2: a robust rule "works" when the
byzantine rows end up with (near-)zero effective weight in the aggregate.
``RoundTrace.influence`` records exactly that weight, so detection quality
is a pure host-side readout:

* a worker counts as FILTERED when its influence falls below ``frac`` of
  the uniform share 1/n (default: half the uniform share);
* precision / recall score the filtered set against ``byz_mask``;
* ``byz_leakage`` is the fraction of total (positive) influence mass held
  by byzantine rows — the quantity that actually perturbs the aggregate,
  and the one ALIE-style attacks are designed to keep high.

Works on a device RoundTrace, a ``to_host`` dict, or a history record that
embeds the trace fields.
"""
from __future__ import annotations

import numpy as np


def _field(trace, name):
    if isinstance(trace, dict):
        return trace.get(name)
    return getattr(trace, name, None)


def filtered_mask(trace, frac: float = 0.5) -> np.ndarray:
    """(n,) bool: workers whose influence is below ``frac``·(1/n)."""
    infl = np.asarray(_field(trace, "influence"), np.float64)
    return infl < frac / infl.shape[0]


def detection_metrics(trace, frac: float = 0.5) -> dict:
    """Precision/recall of the filtered-worker set vs the ground-truth
    byzantine mask, plus the byzantine influence-leakage fraction.

    Empty-denominator convention: with nothing filtered precision is 1.0
    (no false accusations), with no byzantines recall is 1.0.
    """
    infl = np.asarray(_field(trace, "influence"), np.float64)
    byz = np.asarray(_field(trace, "byz_mask"), bool)
    filt = filtered_mask(trace, frac)
    tp = int((filt & byz).sum())
    fp = int((filt & ~byz).sum())
    fn = int((~filt & byz).sum())
    pos = np.clip(infl, 0.0, None)
    tot = pos.sum()
    return {
        "n_filtered": int(filt.sum()),
        "precision": tp / (tp + fp) if tp + fp else 1.0,
        "recall": tp / (tp + fn) if tp + fn else 1.0,
        "byz_leakage": float(pos[byz].sum() / tot) if tot > 0 else 0.0,
    }


def summarize(traces, frac: float = 0.5) -> dict:
    """Mean detection metrics over a run's logged traces (host dicts or
    RoundTrace objects); {} when there is nothing to summarize."""
    mets = [detection_metrics(t, frac) for t in traces
            if _field(t, "influence") is not None]
    if not mets:
        return {}
    out = {k: float(np.mean([m[k] for m in mets]))
           for k in ("precision", "recall", "byz_leakage")}
    out["n_filtered_mean"] = float(np.mean([m["n_filtered"] for m in mets]))
    out["rounds"] = len(mets)
    return out
