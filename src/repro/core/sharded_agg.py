"""Beyond-paper §Perf: the non-default aggregation backends.

Two backends live here, both reachable through the engine's ``agg_mode``
dispatch (core/engine.py):

* ``all_to_all``  — distributed robust aggregation via shard_map (below).
* ``pallas``      — single-host/default-trainer dense path: every rule
                    (mean/cm/tm via kernels/robust_agg, RFA/Krum via
                    kernels/norm_agg) runs as one-HBM-sweep-per-pass Pallas
                    kernels. Zero-copy: leaves launch kernels LEAF-WISE
                    sharing one on-chip bucketing operator (no concatenated
                    (n, D) flat matrix), many tiny leaves pack into a single
                    donated preallocated flat buffer, and a kernel-fusable
                    omniscient attack (engine.message_phase) is injected in
                    the kernels' VMEM load so the attacked ``sent`` tensor
                    never hits HBM. The jnp tree path (Aggregator.tree) is
                    kept as the parity oracle.

Paper-faithful aggregation gathers every worker's full vector to every
device (GSPMD all-gather: n x d_local bytes in, n x d_local held in memory)
and each device computes the identical aggregate for its model shard.

Coordinate-wise rules (mean / CM / trimmed-mean, incl. bucketing) commute
with coordinate partitioning, so instead each device can:

  1. all_to_all: send the j-th 1/n slice of its worker's local shard to
     device row j (wire: d_local bytes per device),
  2. aggregate its slice across all n workers locally,
  3. all_gather the n aggregated slices (wire: d_local bytes).

Peak memory drops from n x d_local to ~2 x d_local and the collective bytes
from n x d_local to ~2 x d_local — an O(n) reduction on both axes.

v2 NOTE (hillclimb lesson, see EXPERIMENTS.md §Perf): the first version
flattened the whole gradient pytree to one (n, D) matrix and re-sharded it
— the re-layout all-gathers cost MORE than the aggregation saved (llama:
collective 398s -> 705s). This version maps LEAF-WISE in each leaf's native
model sharding (``cfg.grad_specs``), so the shard_map body only ever
touches local contiguous shards and the re-layout disappears.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.core.aggregators import (COORD_KERNEL_RULE, _bucketize_perm,
                                    coord_median, coord_trimmed_mean)


def _shard_map(body, mesh, in_specs, out_specs):
    """jax.shard_map (new API, check_vma) with a fallback to
    jax.experimental.shard_map (check_rep) on older jax."""
    if hasattr(jax, "shard_map"):
        return jax.shard_map(body, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=False)
    from jax.experimental.shard_map import shard_map as _sm
    return _sm(body, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=False)


# route the per-device coordinate rule through the Pallas kernel
# (kernels/robust_agg.py): fused bucket-mean + sort in VMEM, one HBM sweep.
# None = auto: default-ON where the kernel compiles (TPU), off on CPU/GPU
# hosts where interpret-mode would only slow the rule down. Explicit
# True/False (tests, launchers) or REPRO_PALLAS_AGG=0/1 override auto.
USE_PALLAS_AGG = [None]


def use_pallas_agg() -> bool:
    """Resolve the kernel toggle: explicit setting > env var > backend."""
    if USE_PALLAS_AGG[0] is not None:
        return bool(USE_PALLAS_AGG[0])
    import os
    env = os.environ.get("REPRO_PALLAS_AGG")
    if env is not None:
        return env.strip().lower() not in ("", "0", "false", "off", "no")
    return jax.default_backend() == "tpu"


def _coord_rule(agg, y, key):
    if use_pallas_agg() and agg.rule in ("cm", "tm", "mean"):
        from repro.kernels.ops import robust_agg as pallas_agg
        rule = COORD_KERNEL_RULE[agg.rule]
        k = key if agg.bucket_size > 1 else None
        return pallas_agg(y.astype(jnp.float32), k,
                          bucket_size=max(agg.bucket_size, 1), rule=rule,
                          trim=agg.trim)
    if agg.bucket_size > 1 and agg.rule != "mean":
        perm = jax.random.permutation(key, y.shape[0])
        y = _bucketize_perm(y, perm, agg.bucket_size)
    if agg.rule == "mean":
        return jnp.mean(y, axis=0)
    if agg.rule == "cm":
        return coord_median(y)
    return coord_trimmed_mean(y, agg.trim)


def flat_rule(agg, y, key):
    """One (n, d) stack -> (d,) through the kernel backend when enabled —
    ALL five rules, norm-based included — else the jnp Aggregator path."""
    if use_pallas_agg():
        if agg.coordinatewise:
            return _coord_rule(agg, y, key)
        from repro.kernels import ops
        k = key if agg.bucket_size > 1 else None
        if agg.rule == "rfa":
            return ops.rfa_agg(y, k, bucket_size=max(agg.bucket_size, 1),
                               iters=agg.iters, eps=agg.eps)
        return ops.krum_agg(y, k, bucket_size=max(agg.bucket_size, 1),
                            n_byz=agg.n_byz)
    return agg(key, y)


def tree_aggregate_all_to_all(cfg, key, sent):
    """cfg: ByzVRMarinaConfig with .mesh, .worker_axes, .model_axis and
    .grad_specs (pytree of PartitionSpec matching the param tree, model
    sharding only). sent: stacked pytree (n, ...)."""
    mesh = cfg.mesh
    assert mesh is not None, "all_to_all mode needs cfg.mesh"
    agg = cfg.aggregator
    assert agg.coordinatewise, (
        f"{agg.rule} is not coordinate-wise; all_to_all sharding only "
        "commutes with coordinate partitioning")
    specs = cfg.grad_specs
    assert specs is not None, "all_to_all mode needs cfg.grad_specs"
    w_axes = tuple(cfg.worker_axes)
    n = cfg.n_workers
    w_spec = w_axes if len(w_axes) > 1 else w_axes[0]

    def agg_leaf(leaf, spec):
        spec_t = tuple(spec) if spec is not None else ()
        in_spec = P(w_spec, *spec_t)
        out_spec = P(*spec_t)

        def body(x, k):
            # x: (n_local=1, *local_shape) — this worker's local model shard
            xf = x.reshape(-1).astype(jnp.float32)
            dl = xf.shape[0]
            pad = (-dl) % n
            if pad:
                xf = jnp.pad(xf, (0, pad))
            xc = xf.reshape(1, n, -1)
            y = lax.all_to_all(xc, w_axes, split_axis=1, concat_axis=0,
                               tiled=True).reshape(n, -1)
            a = _coord_rule(agg, y, k)
            g = lax.all_gather(a, w_axes, axis=0, tiled=True)
            return g[:dl].reshape(x.shape[1:]).astype(x.dtype)

        return _shard_map(body, mesh, (in_spec, P()), out_spec)(leaf, key)

    return jax.tree.map(agg_leaf, sent, specs)


# ---------------------------------------------------------------------------
# pallas dense backend (agg_mode="pallas")
# ---------------------------------------------------------------------------

# leaves narrower than one lane-tile get packed into a single flat buffer so
# the transformer's many tiny bias/scale leaves don't each pay a kernel launch
SMALL_LEAF_D = 1024

# eager-mode reuse of the small-leaf packing buffer: one preallocated (n, D)
# fp32 buffer per shape, donated to the packing jit each round so XLA writes
# the new leaves in place instead of allocating a fresh flat intermediate.
# (Inside an enclosing jit the packer is traced inline and XLA does the same
# aliasing itself.)
_PACK_CACHE: dict = {}


@functools.partial(jax.jit, donate_argnums=(0,))
def _pack_into(buf, *flats):
    off = 0
    for f in flats:
        buf = jax.lax.dynamic_update_slice(
            buf, f.astype(jnp.float32), (0, off))
        off += f.shape[1]
    return buf


def _pack_rows(flats, tag):
    """Pack [(n, d_j)] into one (n, Dp) fp32 buffer, Dp lane-aligned with a
    zeroed tail (zero columns are neutral for every rule and fused attack).

    Eagerly, the buffer is preallocated per (tag, layout) and DONATED to the
    packing jit each round, so the leaf regions are overwritten in place
    (the zero tail survives — it is outside every leaf slice) and no fresh
    (n, D) intermediate is allocated per call. ``tag`` (x/mean/std) keeps
    same-shaped buffers that are alive simultaneously within one round from
    donating each other away. Under an enclosing jit the packer body is
    traced inline and XLA aliases the update chain itself.

    Packing is fp32: sub-tile bf16 leaves lose the oracle's bf16
    quantization of fused-attack values (bounded by bf16 eps; the large-leaf
    path round-trips through the leaf dtype in the kernel prologue).
    """
    n = flats[0].shape[0]
    widths = tuple(f.shape[1] for f in flats)
    dp = -(-sum(widths) // 128) * 128
    if any(isinstance(f, jax.core.Tracer) for f in flats):
        return _pack_into.__wrapped__(jnp.zeros((n, dp), jnp.float32), *flats)
    key = (tag, n, dp, widths)
    buf = _PACK_CACHE.pop(key, None)
    if buf is None:
        buf = jnp.zeros((n, dp), jnp.float32)
    packed = _pack_into(buf, *flats)
    _PACK_CACHE[key] = packed
    return packed


# ---------------------------------------------------------------------------
# giant-n tier (n > MAX_FUSED_WORKERS): hierarchical bucket-then-aggregate
# ---------------------------------------------------------------------------

def _materialize_attack_flat(flats, dtypes, attack_ctx):
    """jnp twin of the kernel prologue (norm_agg._prologue) for the blocked
    tier: attack → candidate-dtype round-trip → mask select, on flat
    (n, d_j) fp32 views. Bitwise the same malicious values the fused kernels
    would inject (coord_apply is coordinate-wise, so flat vs tiled blocks
    see identical inputs)."""
    if attack_ctx is None or attack_ctx.fn is None or attack_ctx.mask is None:
        return flats
    n = flats[0].shape[0]
    m_l = (jax.tree.leaves(attack_ctx.means)
           if attack_ctx.means is not None else [None] * len(flats))
    s_l = (jax.tree.leaves(attack_ctx.stds)
           if attack_ctx.stds is not None else [None] * len(flats))
    keep = attack_ctx.mask.reshape(n, 1)
    out = []
    for xf, mu, sd, dt in zip(flats, m_l, s_l, dtypes):
        muf = None if mu is None else mu.reshape(1, -1).astype(jnp.float32)
        sdf = None if sd is None else sd.reshape(1, -1).astype(jnp.float32)
        v = attack_ctx.fn(xf, muf, sdf).astype(dt).astype(jnp.float32)
        out.append(jnp.where(keep, v, xf))
    return out


def _tree_aggregate_large_n(cfg, key, sent, attack_ctx, weights,
                            return_info, valid):
    """Giant-n tier of ``tree_aggregate_pallas`` (DESIGN.md §7): above
    ``norm_agg.MAX_FUSED_WORKERS`` the fused kernels' n-in-sublanes layout
    no longer holds, so the hierarchy inverts — bucket FIRST (the Alg. 2
    reduction shrinks the stack leaf-wise before any rule kernel runs, so
    no kernel ever holds the full worker axis), then run the rule:

    * coordinate rules aggregate the bucketed stack in jnp (a sort over the
      worker axis is XLA's job at this scale; the ≤64-sublane coord kernel
      does not apply);
    * RFA / Krum route back to the FUSED norm_agg drivers when the bucketed
      row count fits under MAX_FUSED_WORKERS, else to the BLOCKED drivers
      (worker-tiled Gram / distance / weighted-sum kernels) — Krum at
      n = 4096 never materializes anything that scales like n²·d.

    The kernel prologue (attack injection, guard select-zero, staleness
    weighting) is materialized in jnp first: the zero-copy fusion is a
    ≤64-worker luxury, traded here for unbounded n. Semantics are unchanged
    — ``Aggregator.tree`` / ``tree_masked`` over ``apply_attack``-style
    materialized candidates remain the parity oracle."""
    agg = cfg.aggregator
    from repro.core import aggregators as A
    from repro.kernels import norm_agg

    leaves, treedef = jax.tree.flatten(sent)
    n = leaves[0].shape[0]
    flats = [a.reshape(n, -1).astype(jnp.float32) for a in leaves]
    flats = _materialize_attack_flat(flats, [a.dtype for a in leaves],
                                     attack_ctx)
    if valid is not None:
        keep = valid.reshape(n, 1)
        # select-zero, never multiply (0·NaN = NaN) — guard contract
        flats = [jnp.where(keep, xf, 0.0) for xf in flats]
    if weights is not None:
        flats = [xf * weights.reshape(n, 1).astype(jnp.float32)
                 for xf in flats]

    bvalid = valid
    if agg.bucket_size > 1 and agg.rule != "mean":
        perm = jax.random.permutation(key, n)
        if valid is not None:
            from repro.faults.guard import masked_bucket_matrix
            w_mat, bvalid = masked_bucket_matrix(perm, n, agg.bucket_size,
                                                 valid)
            flats = [w_mat @ xf for xf in flats]
        else:
            flats = [A._bucketize_perm(xf, perm, agg.bucket_size)
                     for xf in flats]
    m = flats[0].shape[0]

    info: dict = {}
    if agg.rule in COORD_KERNEL_RULE:
        if bvalid is not None:
            fns = {"mean": lambda y: A.masked_mean(y, bvalid),
                   "cm": lambda y: A.masked_coord_median(y, bvalid),
                   "tm": lambda y: A.masked_coord_trimmed_mean(
                       y, bvalid, agg.trim)}
            outs = [fns[agg.rule](xf) for xf in flats]
        elif agg.rule == "mean":
            outs = [jnp.mean(xf, axis=0) for xf in flats]
        elif agg.rule == "cm":
            outs = [coord_median(xf) for xf in flats]
        else:
            outs = [coord_trimmed_mean(xf, agg.trim) for xf in flats]
    elif agg.rule == "rfa":
        if m <= norm_agg.MAX_FUSED_WORKERS:
            res = norm_agg.rfa_segments(flats, iters=agg.iters, eps=agg.eps,
                                        return_info=return_info,
                                        bvalid=bvalid)
        else:
            res = norm_agg.rfa_segments_blocked(
                flats, iters=agg.iters, eps=agg.eps, bvalid=bvalid,
                return_info=return_info)
        outs = res[0] if return_info else res
        if return_info:
            info = res[1]
    elif agg.rule == "krum":
        if m <= norm_agg.MAX_FUSED_WORKERS:
            res = norm_agg.krum_segments(flats, n_byz=agg.n_byz,
                                         return_info=return_info,
                                         bvalid=bvalid)
        else:
            res = norm_agg.krum_segments_blocked(
                flats, n_byz=agg.n_byz, bvalid=bvalid,
                return_info=return_info)
        outs = res[0] if return_info else res
        if return_info:
            info = res[1]
    else:  # pragma: no cover — RULES is closed
        raise ValueError(agg.rule)

    tree_out = [o.reshape(a.shape[1:]).astype(a.dtype)
                for o, a in zip(outs, leaves)]
    tree = jax.tree.unflatten(treedef, tree_out)
    return (tree, info) if return_info else tree


@dataclasses.dataclass(frozen=True)
class AttackCtx:
    """Omniscient-attack context for in-kernel injection (engine.message_phase):
    the byzantine mask plus the good workers' per-coordinate mean/std trees
    (None when the attack doesn't read them), and the static coord_apply."""
    fn: object                   # attacks.Attack.coord_apply (static)
    mask: object                 # (n,) bool
    means: object = None         # pytree like cand minus the worker axis
    stds: object = None


def _segments(leaves, attack_ctx):
    """Partition the candidate leaves into kernel launch units.

    Returns (segs, means, stds, splits): segs[j] is a 2-D (n, d_j) view —
    either one large leaf (zero-copy reshape) or the packed small-leaf
    buffer — with per-segment flattened attack stats, and splits[j] the
    [(leaf_idx, offset, size)] map back into the tree.
    """
    n = leaves[0].shape[0]
    m_leaves = (jax.tree.leaves(attack_ctx.means)
                if attack_ctx is not None and attack_ctx.means is not None
                else [None] * len(leaves))
    s_leaves = (jax.tree.leaves(attack_ctx.stds)
                if attack_ctx is not None and attack_ctx.stds is not None
                else [None] * len(leaves))
    small = [i for i, x in enumerate(leaves) if x[0].size < SMALL_LEAF_D]
    segs, means, stds, splits = [], [], [], []
    if len(small) >= 2:
        flats = [leaves[i].reshape(n, -1) for i in small]
        segs.append(_pack_rows(flats, "x"))
        means.append(None if m_leaves[small[0]] is None else _pack_rows(
            [m_leaves[i].reshape(1, -1) for i in small], "mean"))
        stds.append(None if s_leaves[small[0]] is None else _pack_rows(
            [s_leaves[i].reshape(1, -1) for i in small], "std"))
        off, sp = 0, []
        for i in small:
            sp.append((i, off, leaves[i][0].size))
            off += leaves[i][0].size
        splits.append(sp)
        packed = set(small)
    else:
        packed = set()
    for i, x in enumerate(leaves):
        if i in packed:
            continue
        segs.append(x.reshape(n, -1))
        means.append(None if m_leaves[i] is None
                     else m_leaves[i].reshape(-1))
        stds.append(None if s_leaves[i] is None else s_leaves[i].reshape(-1))
        splits.append([(i, 0, x[0].size)])
    return segs, means, stds, splits


def tree_aggregate_pallas(cfg, key, sent, attack_ctx=None, weights=None,
                          return_info=False, valid=None):
    """Aggregate the stacked candidate pytree through the one-sweep Pallas
    kernels — every rule, no jnp fallback, zero per-round HBM copies:

    * leaf-wise kernel launches share ONE bucketing permutation, carried
      on-chip as ``norm_agg.bucket_matrix`` (no ``x[perm]`` gather copy, no
      concatenated (n, D) flat matrix);
    * many tiny leaves pack into a single donated preallocated flat buffer;
    * RFA/Krum sum tiny per-leaf distance accumulators so their distances
      stay GLOBAL across leaves, exactly like ``Aggregator.tree`` (the jnp
      parity oracle), at 2 sweeps/Weiszfeld-iteration and 2 sweeps/Krum;
    * ``attack_ctx`` (engine.message_phase) injects the omniscient attack
      inside the kernels' VMEM load — the attacked ``sent`` tensor is never
      written to HBM;
    * ``weights`` (engine.ingest_message_phase — staleness weighting) scales
      each sent row before bucketing/rule: the (n,) scale rides as a
      diagonal composed into the on-chip ``w_mat`` operator, so the scaled
      stack is never materialized either. Semantics (the jnp oracle):
      ``aggregator.tree(key, sent * weights[:, None])``.

    ``return_info`` (repro.obs telemetry) returns ``(tree, info)`` where
    ``info`` carries the norm-rule drivers' own scoring intermediates
    (final Weiszfeld weights / Krum scores+argmin — see kernels/norm_agg);
    coordinate rules return an empty info. The aggregate is produced by the
    identical kernel calls either way.

    ``valid`` ((n,) bool, fault guard — DESIGN.md §6) switches every rule
    to its masked twin: invalid rows are select-zeroed in the kernel
    prologue, bucketing renormalizes over valid members
    (``faults.guard.masked_bucket_matrix`` rides as the on-chip operator),
    and selection/weighting tracks the valid count. ``None`` is
    byte-for-byte the unguarded launch.

    fp32 accumulation, per-leaf output dtype preserved.
    """
    agg = cfg.aggregator
    from repro.kernels import norm_agg
    from repro.kernels.robust_agg import robust_agg as coord_kernel

    leaves, treedef = jax.tree.flatten(sent)
    n = leaves[0].shape[0]
    if n > norm_agg.MAX_FUSED_WORKERS:
        # giant n: the fused kernels keep the whole worker axis in sublanes
        # (n ≤ 64); route to the hierarchical bucket-then-aggregate tier.
        return _tree_aggregate_large_n(cfg, key, sent, attack_ctx, weights,
                                       return_info, valid)
    w_mat = bvalid = None
    if valid is not None:
        if agg.bucket_size > 1 and agg.rule != "mean":
            from repro.faults.guard import masked_bucket_matrix
            perm = jax.random.permutation(key, n)
            w_mat, bvalid = masked_bucket_matrix(perm, n, agg.bucket_size,
                                                 valid)
        else:
            bvalid = valid
    elif agg.bucket_size > 1 and agg.rule != "mean":
        perm = jax.random.permutation(key, n)
        w_mat = norm_agg.bucket_matrix(perm, n, agg.bucket_size)
    if weights is not None:
        # attack first, then scale, then bucket: W_eff = W_bucket @ diag(w)
        diag = jnp.diag(weights.astype(jnp.float32))
        w_mat = diag if w_mat is None else w_mat @ diag

    attack_fn, mask = None, None
    if attack_ctx is not None:
        attack_fn, mask = attack_ctx.fn, attack_ctx.mask
    segs, means, stds, splits = _segments(leaves, attack_ctx)

    info: dict = {}
    if agg.rule in COORD_KERNEL_RULE:
        rule = COORD_KERNEL_RULE[agg.rule]
        outs = [coord_kernel(xs, w_mat, mask, mu, sd, valid, bvalid,
                             rule=rule, trim=agg.trim, attack_fn=attack_fn)
                for xs, mu, sd in zip(segs, means, stds)]
    elif agg.rule == "rfa":
        outs = norm_agg.rfa_segments(
            segs, w_mat=w_mat, mask=mask, means=means, stds=stds,
            attack_fn=attack_fn, iters=agg.iters, eps=agg.eps,
            return_info=return_info, valid=valid, bvalid=bvalid)
        if return_info:
            outs, info = outs
    elif agg.rule == "krum":
        outs = norm_agg.krum_segments(
            segs, w_mat=w_mat, mask=mask, means=means, stds=stds,
            attack_fn=attack_fn, n_byz=agg.n_byz,
            return_info=return_info, valid=valid, bvalid=bvalid)
        if return_info:
            outs, info = outs
    else:  # pragma: no cover — RULES is closed
        raise ValueError(agg.rule)

    tree_out = [None] * len(leaves)
    for out, split in zip(outs, splits):
        for i, off, sz in split:
            tree_out[i] = (out[off:off + sz]
                           .reshape(leaves[i].shape[1:])
                           .astype(leaves[i].dtype))
    tree = jax.tree.unflatten(treedef, tree_out)
    return (tree, info) if return_info else tree


def tree_aggregate_pallas_wire(cfg, key, wc, attack_ctx=None,
                               return_info=False, valid=None):
    """Wire twin of ``tree_aggregate_pallas``: the candidates arrive as a
    ``wire.WireCandidates`` payload and each leaf launches its kernels on a
    ``quantize.WireSrc`` — reconstruction (decode + base add), attack,
    bucketing and the rule all happen per (n, TILE_D) block in VMEM, so the
    dense (n, d) candidate matrix never exists in HBM; the sweep reads the
    wire bytes instead.

    Differences from the dense path: no tiny-leaf packing (payload layouts
    don't concatenate; each leaf keeps its own launch) and ``attack_ctx``
    carries per-leaf FLAT (d_j,) stat lists (``wire.wire_stats``) rather
    than stat trees. RFA/Krum distances stay global across leaves exactly
    like the dense path. ``valid`` guards exactly as in the dense path —
    invalid rows (``wire.payload_valid`` rejections) are select-zeroed
    post-reconstruction in the kernel prologue.
    """
    agg = cfg.aggregator
    from repro.core import wire as W
    from repro.kernels import norm_agg
    from repro.kernels.robust_agg import robust_agg as coord_kernel

    n = wc.n
    if n > norm_agg.MAX_FUSED_WORKERS:
        # giant n: the wire kernels' n-in-sublanes layout no longer holds —
        # reconstruct (densify) once and take the dense giant-n tier. The
        # wire path's per-leaf FLAT stats reshape back to the aggregate
        # shapes so the dense tier's tree-shaped AttackCtx contract holds.
        cand = W.reconstruct(wc)
        ctx = attack_ctx
        if ctx is not None and (ctx.means is not None
                                or ctx.stds is not None):
            def unflat(stats):
                return jax.tree.unflatten(wc.treedef, [
                    s.reshape(sh) for s, sh in zip(stats, wc.shapes)])
            ctx = AttackCtx(
                fn=ctx.fn, mask=ctx.mask,
                means=None if ctx.means is None else unflat(ctx.means),
                stds=None if ctx.stds is None else unflat(ctx.stds))
        return tree_aggregate_pallas(cfg, key, cand, ctx,
                                     return_info=return_info, valid=valid)
    w_mat = bvalid = None
    if valid is not None:
        if agg.bucket_size > 1 and agg.rule != "mean":
            from repro.faults.guard import masked_bucket_matrix
            perm = jax.random.permutation(key, n)
            w_mat, bvalid = masked_bucket_matrix(perm, n, agg.bucket_size,
                                                 valid)
        else:
            bvalid = valid
    elif agg.bucket_size > 1 and agg.rule != "mean":
        perm = jax.random.permutation(key, n)
        w_mat = norm_agg.bucket_matrix(perm, n, agg.bucket_size)

    attack_fn = mask = None
    means = stds = [None] * len(wc.payloads)
    if attack_ctx is not None:
        attack_fn, mask = attack_ctx.fn, attack_ctx.mask
        if attack_ctx.means is not None:
            means = list(attack_ctx.means)
        if attack_ctx.stds is not None:
            stds = list(attack_ctx.stds)

    srcs = W.wire_srcs(wc)
    info: dict = {}
    if agg.rule in COORD_KERNEL_RULE:
        rule = COORD_KERNEL_RULE[agg.rule]
        outs = [coord_kernel(src, w_mat, mask, mu, sd, valid, bvalid,
                             rule=rule, trim=agg.trim, attack_fn=attack_fn)
                for src, mu, sd in zip(srcs, means, stds)]
    elif agg.rule == "rfa":
        outs = norm_agg.rfa_segments(
            srcs, w_mat=w_mat, mask=mask, means=means, stds=stds,
            attack_fn=attack_fn, iters=agg.iters, eps=agg.eps,
            return_info=return_info, valid=valid, bvalid=bvalid)
        if return_info:
            outs, info = outs
    elif agg.rule == "krum":
        outs = norm_agg.krum_segments(
            srcs, w_mat=w_mat, mask=mask, means=means, stds=stds,
            attack_fn=attack_fn, n_byz=agg.n_byz,
            return_info=return_info, valid=valid, bvalid=bvalid)
        if return_info:
            outs, info = outs
    else:  # pragma: no cover — RULES is closed
        raise ValueError(agg.rule)

    tree_out = [out.reshape(shape).astype(dt)
                for out, shape, dt in zip(outs, wc.shapes, wc.dtypes)]
    tree = jax.tree.unflatten(wc.treedef, tree_out)
    return (tree, info) if return_info else tree
