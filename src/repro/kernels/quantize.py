"""Pallas TPU kernel: fused block-wise l2-dithering quantizer (Def. 2.2).

Worker-side hot spot: compressing the gradient-difference vector each round.
The jnp reference does 4 HBM sweeps (norm reduce, scale, round, dequantize);
this kernel performs norm + stochastic-round + dequantize on a VMEM tile in
one pass. Block-wise norms (per TILE_D block rather than global) are the
standard TPU-friendly adaptation — still unbiased, and the wire format
(per-block norm + per-coord level) is exactly what a real sender packs.

The dither noise u ~ U[0,1) is supplied as an input (generated with
jax.random outside) so the kernel is deterministic and oracle-testable.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.backend import resolve_interpret


DEFAULT_TILE_D = 2048


def _quant_kernel(x_ref, u_ref, o_ref, *, levels, block):
    x = x_ref[...].astype(jnp.float32)            # (TILE_D,)
    u = u_ref[...].astype(jnp.float32)
    xb = x.reshape(-1, block)
    ub = u.reshape(-1, block)
    norm = jnp.sqrt(jnp.sum(xb * xb, axis=1, keepdims=True))
    scaled = jnp.where(norm > 0, jnp.abs(xb) / jnp.maximum(norm, 1e-30), 0.0)
    level = jnp.floor(scaled * levels + ub)
    out = norm * jnp.sign(xb) * level / levels
    o_ref[...] = out.reshape(x.shape)


@functools.partial(jax.jit, static_argnames=("levels", "block", "tile_d",
                                             "interpret"))
def block_quantize(x, u, *, levels: int = 4, block: int = 256,
                   tile_d: int = DEFAULT_TILE_D, interpret=None):
    """x, u: (d,). Returns dequantized (d,) float32. d padded to tile_d;
    tile_d must be a multiple of ``block``. ``interpret=None`` resolves per
    backend (kernels/backend.py)."""
    assert tile_d % block == 0
    d = x.shape[0]
    pad = (-d) % tile_d
    if pad:
        x = jnp.pad(x, (0, pad))
        u = jnp.pad(u, (0, pad))
    dp = d + pad
    out = pl.pallas_call(
        functools.partial(_quant_kernel, levels=levels, block=block),
        grid=(dp // tile_d,),
        in_specs=[pl.BlockSpec((tile_d,), lambda i: (i,)),
                  pl.BlockSpec((tile_d,), lambda i: (i,))],
        out_specs=pl.BlockSpec((tile_d,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((dp,), jnp.float32),
        interpret=resolve_interpret(interpret),
    )(x, u)
    return out[:d]
