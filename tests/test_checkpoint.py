"""Checkpoint roundtrip tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import load_checkpoint, save_checkpoint
from repro.configs import get_config
from repro.models import init_params

KEY = jax.random.PRNGKey(0)


def test_roundtrip_simple(tmp_path):
    state = {"w": jnp.arange(6.0).reshape(2, 3),
             "nested": {"b": jnp.ones((4,), jnp.bfloat16)},
             "t": jnp.asarray(7, jnp.int32)}
    path = str(tmp_path / "ckpt")
    save_checkpoint(path, state, step=42)
    restored, step = load_checkpoint(path, state)
    assert step == 42
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))


def test_roundtrip_model_params(tmp_path):
    cfg = get_config("qwen3-1.7b").reduced()
    params = init_params(KEY, cfg)
    path = str(tmp_path / "model")
    save_checkpoint(path, params, step=0)
    restored, _ = load_checkpoint(path, params)
    flat_a = jax.tree.leaves(params)
    flat_b = jax.tree.leaves(restored)
    assert len(flat_a) == len(flat_b)
    for a, b in zip(flat_a, flat_b):
        assert a.shape == b.shape
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32))
