"""Server-side aggregation throughput: jnp reference vs Pallas kernel
(interpret mode on CPU — on TPU the kernel path is the compiled one), across
worker counts and dimensions. One row per (impl, rule, n, d)."""
import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.core.aggregators import get_aggregator
from repro.kernels import ref
from repro.kernels.robust_agg import robust_agg

KEY = jax.random.PRNGKey(0)


def run():
    for n in [16, 32]:
        for d in [1 << 16, 1 << 20]:
            x = jax.random.normal(KEY, (n, d))
            for rule, kernel_rule in [("cm", "median"), ("tm", "trimmed")]:
                agg = get_aggregator(rule, bucket_size=2)
                jref = jax.jit(lambda k, a: agg(k, a))
                us = time_fn(jref, KEY, x)
                emit(f"agg/jnp/{rule}/n{n}/d{d}", us,
                     f"GBps={n*d*4/us/1e3:.2f}")
                kern = jax.jit(lambda a: robust_agg(
                    a, bucket_size=2, rule=kernel_rule, interpret=True))
                us_k = time_fn(kern, x, iters=3)
                emit(f"agg/pallas-interp/{kernel_rule}/n{n}/d{d}", us_k,
                     f"GBps={n*d*4/us_k/1e3:.2f}")
    # norm-based rules (tree path)
    for rule in ["rfa", "krum"]:
        x = jax.random.normal(KEY, (16, 1 << 18))
        agg = get_aggregator(rule, bucket_size=2)
        jref = jax.jit(lambda k, a: agg(k, a))
        us = time_fn(jref, KEY, x)
        emit(f"agg/jnp/{rule}/n16/d{1<<18}", us, "")


if __name__ == "__main__":
    run()
