"""phi3.5-moe-42b-a6.6b [moe] — 16 experts, top-2 routing.

32L d_model=4096 32H (GQA kv=8) d_ff=6400 vocab=32064
[hf:microsoft/Phi-3.5-MoE-instruct]
"""
from repro.configs.base import ArchConfig, ATTN, MoEConfig, register

CONFIG = register(ArchConfig(
    name="phi3.5-moe-42b-a6.6b",
    family="moe",
    citation="hf:microsoft/Phi-3.5-MoE-instruct",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=6400,
    vocab_size=32_064,
    block_pattern=(ATTN,),
    moe=MoEConfig(num_experts=16, top_k=2, num_shared=0, d_expert=6400),
))
