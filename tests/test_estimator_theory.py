"""Empirical checks of the paper's key lemmas.

* Lemma E.3 (distortion contraction): E||g^k − ∇f(x^k)||² contracts toward
  zero as training converges (the mechanism that starves Byzantines of
  noise to hide in).
* Lemma E.2 (variance bound): the pairwise variance of honest candidates is
  O(||x^{k+1} − x^k||²) in the VR rounds.
* Permutation invariance (App. E.3 discussion): the step output is
  invariant to shuffling the honest workers.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ByzVRMarinaConfig, get_aggregator, get_attack,
                        get_compressor, make_init, make_step)
from repro.core import tree_utils as tu
from repro.data import (init_logreg_params, logreg_loss, make_logreg_data)

KEY = jax.random.PRNGKey(0)
DIM = 15


@pytest.fixture(scope="module")
def problem():
    data = make_logreg_data(KEY, n_samples=240, dim=DIM, n_workers=4)
    return data, logreg_loss(0.01), {"x": data.features, "y": data.labels}


def test_estimator_distortion_contracts(problem):
    """||g^k - grad f(x^k)||² should shrink by orders of magnitude."""
    data, loss_fn, full = problem
    cfg = ByzVRMarinaConfig(n_workers=4, n_byz=1, p=0.2, lr=0.4,
                            aggregator=get_aggregator("cm", bucket_size=2),
                            compressor=get_compressor("randk", ratio=0.5),
                            attack=get_attack("ALIE"))
    step = jax.jit(make_step(cfg, loss_fn))
    anchor = data.stacked()
    state = make_init(cfg, loss_fn)(init_logreg_params(DIM), anchor, KEY)

    def distortion(st):
        g_true = jax.grad(loss_fn)(st["params"], full)
        return float(tu.tree_norm_sq(tu.tree_sub(st["g"], g_true)))

    k = KEY
    early = []
    late = []
    for it in range(400):
        k, k1, k2 = jax.random.split(k, 3)
        state, _ = step(state, data.sample_batches(k1, 16), anchor, k2)
        if 20 <= it < 40:
            early.append(distortion(state))
        if it >= 380:
            late.append(distortion(state))
    assert np.mean(late) < np.mean(early) / 10, (np.mean(early),
                                                 np.mean(late))


def test_honest_candidate_variance_tracks_step_size(problem):
    """Lemma E.2: pairwise variance of honest VR candidates is bounded by
    A' ||x^{k+1} - x^k||² — so when the iterates stop moving, honest
    workers agree. Check the ratio stays bounded across training."""
    data, loss_fn, full = problem
    cfg = ByzVRMarinaConfig(n_workers=4, n_byz=0, p=0.0,  # always VR branch
                            lr=0.4,
                            aggregator=get_aggregator("mean"),
                            compressor=get_compressor("identity"),
                            attack=get_attack("NA"))

    # reimplement one VR candidate computation to inspect the spread
    def candidates(params_new, params_old, g_prev, mb, key):
        wkeys = tu.per_worker_keys(key, 4)

        def one(b, kg):
            gn = jax.grad(loss_fn)(params_new, b)
            go = jax.grad(loss_fn)(params_old, b)
            return tu.tree_sub(gn, go)

        deltas = jax.vmap(one)(mb, wkeys)
        return jax.tree.map(lambda g0, d: g0[None] + d, g_prev, deltas)

    step = jax.jit(make_step(cfg, loss_fn))
    anchor = data.stacked()
    state = make_init(cfg, loss_fn)(init_logreg_params(DIM), anchor, KEY)
    k = KEY
    prev_params = state["params"]
    ratios = []
    for it in range(60):
        k, k1, k2 = jax.random.split(k, 3)
        mb = data.sample_batches(k1, 16)
        old = state["params"]
        state, _ = step(state, mb, anchor, k2)
        move = float(tu.tree_norm_sq(tu.tree_sub(state["params"], old)))
        cand = candidates(state["params"], old, state["g"], mb, k1)
        flat = jnp.stack([jnp.concatenate([leaf[i].reshape(-1)
                                           for leaf in jax.tree.leaves(cand)])
                          for i in range(4)])
        pair_var = float(jnp.mean(
            jnp.sum((flat[:, None] - flat[None, :]) ** 2, -1)))
        if move > 1e-12:
            ratios.append(pair_var / move)
    ratios = np.asarray(ratios)
    # bounded ratio (no blow-up as the method converges)
    assert np.median(ratios[-20:]) < 10 * np.median(ratios[:20]) + 1e3


def test_step_permutation_invariant(problem):
    """Shuffling honest workers' batches leaves the aggregate unchanged
    (homogeneous case, no byz): App. E.3 permutation-invariance."""
    data, loss_fn, full = problem
    cfg = ByzVRMarinaConfig(n_workers=4, n_byz=0, p=0.0, lr=0.3,
                            aggregator=get_aggregator("cm"),
                            compressor=get_compressor("identity"),
                            attack=get_attack("NA"))
    step = jax.jit(make_step(cfg, loss_fn))
    anchor = data.stacked()
    state = make_init(cfg, loss_fn)(init_logreg_params(DIM), anchor, KEY)
    mb = data.sample_batches(KEY, 16)
    perm = jnp.asarray([2, 0, 3, 1])
    mb_p = jax.tree.map(lambda a: a[perm], mb)
    s1, _ = step(state, mb, anchor, KEY)
    s2, _ = step(state, mb_p, anchor, KEY)
    np.testing.assert_allclose(np.asarray(s1["g"]["w"]),
                               np.asarray(s2["g"]["w"]), atol=1e-6)
