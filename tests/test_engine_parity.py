"""Refactor safety net: the unified round engine must reproduce every
legacy step factory bit-for-bit (same seed => identical trajectories), and
the pallas aggregation backend must match gspmd under attack.

The legacy implementations are frozen in tests/_legacy_steps.py.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

import _legacy_steps as legacy
from repro.core import (ByzVRMarinaConfig, get_aggregator, get_attack,
                        get_compressor, make_method)
from repro.data import (corrupt_labels_logreg, init_logreg_params,
                        logreg_loss, make_logreg_data)

KEY = jax.random.PRNGKey(7)
DIM = 13
N = 5
ITERS = 6

LOSS = logreg_loss(0.01)


@pytest.fixture(scope="module")
def data():
    return make_logreg_data(KEY, n_samples=150, dim=DIM, n_workers=N,
                            homogeneous=True)


def _cfg(**kw):
    base = dict(n_workers=N, n_byz=1, p=0.3, lr=0.25,
                aggregator=get_aggregator("cm", bucket_size=2),
                attack=get_attack("ALIE"))
    base.update(kw)
    return ByzVRMarinaConfig(**base)


def _run(data, state, step, iters=ITERS):
    """Shared key schedule: trajectory of (params, loss) per iteration."""
    step = jax.jit(step)
    traj = []
    k = KEY
    anchor = data.stacked()
    for it in range(iters):
        k, k1, k2 = jax.random.split(k, 3)
        state, metrics = step(state, data.sample_batches(k1, 16), anchor, k2)
        traj.append((jax.tree.map(np.asarray, state["params"]),
                     np.asarray(metrics["loss"])))
    return state, traj


def _assert_same_traj(t_legacy, t_new):
    for it, ((p_l, l_l), (p_n, l_n)) in enumerate(zip(t_legacy, t_new)):
        np.testing.assert_array_equal(l_l, l_n, err_msg=f"loss @ step {it}")
        jax.tree.map(
            lambda a, b: np.testing.assert_array_equal(
                a, b, err_msg=f"params @ step {it}"), p_l, p_n)


# ---------------------------------------------------------------------------
# estimator-vs-legacy parity
# ---------------------------------------------------------------------------

def test_parity_marina_dense(data):
    cfg = _cfg(compressor=get_compressor("randk", ratio=0.5))
    anchor = data.stacked()
    params = init_logreg_params(DIM)
    s_l = legacy.make_init(cfg, LOSS, corrupt_labels_logreg)(
        params, anchor, KEY)
    m = make_method("marina", cfg, LOSS, corrupt_labels_logreg)
    s_n = m.init(params, anchor, KEY)
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(a, b),
                 s_l["g"], s_n["g"])
    _, t_l = _run(data, s_l, legacy.make_step(cfg, LOSS,
                                              corrupt_labels_logreg))
    _, t_n = _run(data, s_n, m.step)
    _assert_same_traj(t_l, t_n)


def test_parity_marina_sparse_support(data):
    cfg = _cfg(compressor=get_compressor("randk", ratio=0.5,
                                         common_randomness=True),
               agg_mode="sparse_support")
    anchor = data.stacked()
    params = init_logreg_params(DIM)
    s_l = legacy.make_init(cfg, LOSS, corrupt_labels_logreg)(
        params, anchor, KEY)
    m = make_method("marina", cfg, LOSS, corrupt_labels_logreg)
    s_n = m.init(params, anchor, KEY)
    _, t_l = _run(data, s_l, legacy.make_step(cfg, LOSS,
                                              corrupt_labels_logreg))
    _, t_n = _run(data, s_n, m.step)
    _assert_same_traj(t_l, t_n)


@pytest.mark.parametrize("momentum", [0.0, 0.9])
def test_parity_sgd(data, momentum):
    cfg = _cfg()
    params = init_logreg_params(DIM)
    init_l, step_l = legacy.make_sgd_step(cfg, LOSS, corrupt_labels_logreg,
                                          momentum=momentum)
    m = make_method("sgdm" if momentum else "sgd", cfg, LOSS,
                    corrupt_labels_logreg, momentum=momentum)
    _, t_l = _run(data, init_l(params), step_l)
    _, t_n = _run(data, m.init(params, data.stacked(), KEY), m.step)
    _assert_same_traj(t_l, t_n)


def test_parity_csgd(data):
    cfg = _cfg(compressor=get_compressor("randk", ratio=0.4))
    params = init_logreg_params(DIM)
    init_l, step_l = legacy.make_csgd_step(cfg, LOSS, corrupt_labels_logreg)
    m = make_method("csgd", cfg, LOSS, corrupt_labels_logreg)
    _, t_l = _run(data, init_l(params), step_l)
    _, t_n = _run(data, m.init(params, data.stacked(), KEY), m.step)
    _assert_same_traj(t_l, t_n)


def test_parity_diana(data):
    cfg = _cfg(compressor=get_compressor("randk", ratio=0.4), lr=0.2)
    params = init_logreg_params(DIM)
    init_l, step_l = legacy.make_diana_step(cfg, LOSS, corrupt_labels_logreg)
    m = make_method("diana", cfg, LOSS, corrupt_labels_logreg)
    s_l = init_l(params, d_hint=DIM + 1)
    s_n = m.init(params, data.stacked(), KEY)
    np.testing.assert_array_equal(np.asarray(s_l["alpha"]),
                                  np.asarray(s_n["alpha"]))
    _, t_l = _run(data, s_l, step_l)
    _, t_n = _run(data, s_n, m.step)
    _assert_same_traj(t_l, t_n)


def test_parity_mvr(data):
    cfg = _cfg()
    params = init_logreg_params(DIM)
    anchor = data.stacked()
    init_l, step_l = legacy.make_br_mvr_step(cfg, LOSS,
                                             corrupt_labels_logreg)
    m = make_method("mvr", cfg, LOSS, corrupt_labels_logreg)
    _, t_l = _run(data, init_l(params, anchor, KEY), step_l)
    _, t_n = _run(data, m.init(params, anchor, KEY), m.step)
    _assert_same_traj(t_l, t_n)


def test_parity_svrg(data):
    cfg = _cfg(aggregator=get_aggregator("rfa", bucket_size=2))
    params = init_logreg_params(DIM)
    anchor = data.stacked()
    init_l, step_l = legacy.make_byrd_svrg_step(cfg, LOSS,
                                                corrupt_labels_logreg)
    m = make_method("svrg", cfg, LOSS, corrupt_labels_logreg)
    _, t_l = _run(data, init_l(params, anchor, KEY), step_l)
    _, t_n = _run(data, m.init(params, anchor, KEY), m.step)
    _assert_same_traj(t_l, t_n)


def test_legacy_wrappers_still_match(data):
    """core/baselines.py's compat factories route through the engine and
    must agree with the frozen legacy code too."""
    from repro.core.baselines import make_sgd_step
    cfg = _cfg()
    params = init_logreg_params(DIM)
    init_l, step_l = legacy.make_sgd_step(cfg, LOSS, corrupt_labels_logreg,
                                          momentum=0.9)
    init_n, step_n = make_sgd_step(cfg, LOSS, corrupt_labels_logreg,
                                   momentum=0.9)
    _, t_l = _run(data, init_l(params), step_l)
    _, t_n = _run(data, init_n(params), step_n)
    _assert_same_traj(t_l, t_n)


# ---------------------------------------------------------------------------
# pallas backend vs gspmd under attack
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rule,bucket", [("mean", 0), ("cm", 2), ("tm", 2),
                                         ("rfa", 0), ("rfa", 2),
                                         ("krum", 0), ("krum", 2)])
def test_pallas_backend_matches_gspmd(data, rule, bucket):
    """agg_mode="pallas" serves ALL five rules through the fused kernels —
    coordinate-wise via kernels/robust_agg, RFA/Krum via kernels/norm_agg,
    no jnp fallback; with n=5 workers and bucket_size=2 this also exercises
    the padded (non-divisible) in-kernel bucketing path. fp32 tolerance per
    DESIGN.md §3 (the kernel path reassociates fp32 sums)."""
    anchor = data.stacked()
    params = init_logreg_params(DIM)
    trajs = {}
    for mode in ("gspmd", "pallas"):
        cfg = _cfg(compressor=get_compressor("randk", ratio=0.5),
                   aggregator=get_aggregator(rule, bucket_size=bucket),
                   agg_mode=mode)
        m = make_method("marina", cfg, LOSS, corrupt_labels_logreg)
        _, trajs[mode] = _run(data, m.init(params, anchor, KEY), m.step)
    for (p_g, l_g), (p_p, l_p) in zip(trajs["gspmd"], trajs["pallas"]):
        np.testing.assert_allclose(l_g, l_p, atol=2e-5, rtol=2e-5)
        jax.tree.map(lambda a, b: np.testing.assert_allclose(
            a, b, atol=2e-5, rtol=2e-5), p_g, p_p)


def test_pallas_backend_unfusable_attack_matches_gspmd(data):
    """RN can't fuse into the kernels (it needs the exact jax.random
    stream): message_phase must materialize the attack via apply_attack and
    stay on the same trajectory as gspmd."""
    anchor = data.stacked()
    params = init_logreg_params(DIM)
    trajs = {}
    for mode in ("gspmd", "pallas"):
        cfg = _cfg(aggregator=get_aggregator("rfa", bucket_size=2),
                   attack=get_attack("RN"), agg_mode=mode)
        m = make_method("marina", cfg, LOSS, corrupt_labels_logreg)
        _, trajs[mode] = _run(data, m.init(params, anchor, KEY), m.step)
    for (p_g, l_g), (p_p, l_p) in zip(trajs["gspmd"], trajs["pallas"]):
        np.testing.assert_allclose(l_g, l_p, atol=2e-5, rtol=2e-5)
        jax.tree.map(lambda a, b: np.testing.assert_allclose(
            a, b, atol=2e-5, rtol=2e-5), p_g, p_p)


# ---------------------------------------------------------------------------
# registry surface
# ---------------------------------------------------------------------------

def test_every_registered_method_runs(data):
    from repro.core import list_methods
    anchor = data.stacked()
    params = init_logreg_params(DIM)
    for name in list_methods():
        # byz_ef21 rejects non-contractive compressors by design
        comp = get_compressor("topk" if name == "byz_ef21" else "randk",
                              ratio=0.5)
        cfg = _cfg(compressor=comp)
        m = make_method(name, cfg, LOSS, corrupt_labels_logreg)
        state = m.init(params, anchor, KEY)
        state, metrics = jax.jit(m.step)(state, data.sample_batches(KEY, 8),
                                         anchor, KEY)
        assert jnp.isfinite(metrics["loss"]), name
        assert int(state["step"]) == 1, name
        assert m.expected_bits(DIM + 1) > 0


def test_unknown_method_raises():
    cfg = _cfg()
    with pytest.raises(KeyError):
        make_method("nope", cfg, LOSS)
