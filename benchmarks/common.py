"""Shared benchmark harness utilities."""
import time

import jax
import jax.numpy as jnp


def time_fn(fn, *args, warmup=2, iters=10):
    for _ in range(warmup):
        out = fn(*args)
        jax.block_until_ready(out)
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
        jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6   # us


def emit(name, us, derived=""):
    print(f"{name},{us:.1f},{derived}")


def make_logreg_problem(key, *, dim=30, n_samples=400, n_workers=5,
                        homogeneous=True, lam=0.01):
    from repro.data import make_logreg_data, logreg_loss, init_logreg_params
    data = make_logreg_data(key, n_samples=n_samples, dim=dim,
                            n_workers=n_workers, homogeneous=homogeneous)
    loss_fn = logreg_loss(lam)
    full = {"x": data.features, "y": data.labels}
    p = init_logreg_params(dim)
    gd = jax.jit(lambda q: jax.tree.map(
        lambda a, g: a - 0.5 * g, q, jax.grad(loss_fn)(q, full)))
    for _ in range(2500):
        p = gd(p)
    return data, loss_fn, full, float(loss_fn(p, full))
