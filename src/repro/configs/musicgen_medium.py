"""musicgen-medium [audio] — decoder-only over EnCodec tokens.

48L d_model=1536 24H (GQA kv=24) d_ff=6144 vocab=2048 [arXiv:2306.05284]
The EnCodec conv codec is a STUB per the task carve-out: ``input_specs``
supplies precomputed frame embeddings / codebook token ids. We model the 4
parallel RVQ codebooks as 4 summed embedding tables + 4 output heads
(the paper's delay interleave pattern is a data-layout detail, omitted).
"""
from repro.configs.base import ArchConfig, ATTN, register

CONFIG = register(ArchConfig(
    name="musicgen-medium",
    family="audio",
    citation="arXiv:2306.05284",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    head_dim=64,
    block_pattern=(ATTN,),
    num_codebooks=4,
    frontend_tokens=64,     # stubbed conditioning (text/melody) embeddings
))
