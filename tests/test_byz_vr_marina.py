"""Algorithm-1 semantics tests: reductions to known methods, state handling."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (ByzVRMarinaConfig, comm_bits, expected_comm_bits,
                        get_aggregator, get_attack, get_compressor,
                        make_init, make_step)
from repro.data import (init_logreg_params, logreg_loss, make_logreg_data)
from repro.optim import Adam

KEY = jax.random.PRNGKey(3)
DIM = 12


@pytest.fixture(scope="module")
def data():
    return make_logreg_data(KEY, n_samples=120, dim=DIM, n_workers=4,
                            homogeneous=True)


def test_p1_no_byz_mean_equals_full_gd(data):
    """p=1, no byzantines, mean aggregation, no compression => every step is
    exact distributed GD on the anchor set: g^k == grad f(x^k)."""
    loss_fn = logreg_loss(0.01)
    cfg = ByzVRMarinaConfig(n_workers=4, n_byz=0, p=1.0, lr=0.2,
                            aggregator=get_aggregator("mean"),
                            compressor=get_compressor("identity"),
                            attack=get_attack("NA"))
    step = jax.jit(make_step(cfg, loss_fn))
    anchor = data.stacked()
    state = make_init(cfg, loss_fn)(init_logreg_params(DIM), anchor, KEY)

    # manual full-batch GD
    full = {"x": anchor["x"][0], "y": anchor["y"][0]}
    p_manual = init_logreg_params(DIM)
    for it in range(5):
        g = jax.grad(loss_fn)(p_manual, full)
        p_manual = jax.tree.map(lambda a, b: a - 0.2 * b, p_manual, g)
        state, _ = step(state, anchor, anchor, jax.random.fold_in(KEY, it))
    np.testing.assert_allclose(np.asarray(state["params"]["w"]),
                               np.asarray(p_manual["w"]), rtol=1e-4,
                               atol=1e-6)


def test_estimator_unbiased_direction(data):
    """With p<1 the estimator follows g^{k+1} = g^k + agg(Q(Delta)); with
    identity compression + mean agg + no byz this telescopes to the true
    minibatch SARAH recursion (sanity: finite + descent over iterations)."""
    loss_fn = logreg_loss(0.01)
    cfg = ByzVRMarinaConfig(n_workers=4, n_byz=0, p=0.2, lr=0.3,
                            aggregator=get_aggregator("mean"),
                            compressor=get_compressor("identity"),
                            attack=get_attack("NA"))
    step = jax.jit(make_step(cfg, loss_fn))
    anchor = data.stacked()
    state = make_init(cfg, loss_fn)(init_logreg_params(DIM), anchor, KEY)
    full = {"x": anchor["x"][0], "y": anchor["y"][0]}
    l0 = float(loss_fn(state["params"], full))
    k = KEY
    for it in range(120):
        k, k1, k2 = jax.random.split(k, 3)
        mb = data.sample_batches(k1, 16)
        state, metrics = step(state, mb, anchor, k2)
    l1 = float(loss_fn(state["params"], full))
    assert l1 < l0 - 0.05, (l0, l1)


def test_state_structure_and_step_counter(data):
    loss_fn = logreg_loss(0.01)
    cfg = ByzVRMarinaConfig(n_workers=4, n_byz=1, p=0.5, lr=0.1,
                            aggregator=get_aggregator("cm", bucket_size=2),
                            compressor=get_compressor("randk", ratio=0.5),
                            attack=get_attack("ALIE"))
    step = jax.jit(make_step(cfg, loss_fn))
    anchor = data.stacked()
    state = make_init(cfg, loss_fn)(init_logreg_params(DIM), anchor, KEY)
    assert int(state["step"]) == 0
    state, metrics = step(state, anchor, anchor, KEY)
    assert int(state["step"]) == 1
    assert set(metrics) == {"loss", "c_k", "g_norm", "wire_bits"}
    assert jnp.isfinite(metrics["loss"])


def test_optimizer_composition(data):
    """Adam on top of the robust estimator (framework extension)."""
    loss_fn = logreg_loss(0.01)
    opt = Adam(lr=0.05)
    cfg = ByzVRMarinaConfig(n_workers=4, n_byz=1, p=0.2, lr=0.05,
                            aggregator=get_aggregator("cm", bucket_size=2),
                            attack=get_attack("IPM"), optimizer=opt)
    step = jax.jit(make_step(cfg, loss_fn))
    anchor = data.stacked()
    state = make_init(cfg, loss_fn)(init_logreg_params(DIM), anchor, KEY)
    assert state["opt_state"] is not None
    full = {"x": anchor["x"][0], "y": anchor["y"][0]}
    l0 = float(loss_fn(state["params"], full))
    k = KEY
    for it in range(60):
        k, k1, k2 = jax.random.split(k, 3)
        state, _ = step(state, data.sample_batches(k1, 16), anchor, k2)
    assert float(loss_fn(state["params"], full)) < l0


def test_comm_accounting():
    cfg = ByzVRMarinaConfig(n_workers=4, p=0.25,
                            compressor=get_compressor("randk", ratio=0.1),
                            aggregator=get_aggregator("cm"))
    d = 1000
    assert comm_bits(cfg, d, True) == 32 * d
    assert comm_bits(cfg, d, False) == 100 * 64
    exp = expected_comm_bits(cfg, d)
    assert exp == pytest.approx(0.25 * 32 * d + 0.75 * 100 * 64)
