"""Graceful-degradation primitives: validity masks + masked bucketing.

The guard contract (DESIGN §6): a worker whose message is *structurally*
bad — non-finite candidate coordinates, non-finite wire floats, sparse
indices outside [0, d) — gets **zero aggregation weight** and counts
toward the δ budget, exactly as if the paper's Byzantine set had absorbed
it. Structurally valid garbage (e.g. a replayed zero update, or garbled
int8 levels under finite norms) passes the guard BY DESIGN: arbitrary
finite deviation is precisely what the robust aggregators are for.

Everything here is plain jnp so both backends share the identical validity
and bucket arithmetic — the gspmd masked oracle and the pallas masked
kernels consume the same ``valid`` vector and the same renormalized bucket
matrix, which is what makes the drop-oracle equivalence test exact.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def finite_row_mask(tree):
    """(n,) bool — worker i's row is finite in EVERY leaf coordinate.
    Integer leaves are always finite."""
    leaves = jax.tree.leaves(tree)
    n = leaves[0].shape[0]
    m = jnp.ones((n,), bool)
    for leaf in leaves:
        if not jnp.issubdtype(leaf.dtype, jnp.inexact):
            continue
        axes = tuple(range(1, leaf.ndim))
        m = m & jnp.all(jnp.isfinite(leaf), axis=axes)
    return m


def payload_valid(wc):
    """(n,) bool — worker i's wire payload decodes safely: every float
    payload array finite, and (sparse) every index inside [0, d). A False
    row is *rejected* — routed to zero weight, never reconstructed into
    the aggregate."""
    m = jnp.ones((wc.n,), bool)
    for payload, shape in zip(wc.payloads, wc.shapes):
        d = int(np.prod(shape)) if shape else 1
        for name, arr in payload.items():
            a = arr.reshape(wc.n, -1)
            dt = np.dtype(arr.dtype)
            if np.issubdtype(dt, np.floating) or dt == np.dtype(jnp.bfloat16):
                m = m & jnp.all(jnp.isfinite(a), axis=1)
            elif name == "idx":
                m = m & jnp.all((a >= 0) & (a < d), axis=1)
    return m


def masked_bucket_matrix(perm, n: int, s: int, valid):
    """Renormalized (nb, n) bucket-mean operator over VALID members only,
    plus the (nb,) bucket-validity mask (a bucket with zero valid members
    is itself rejected downstream).

    ``perm`` is the same per-round permutation both backends already use;
    bucket b owns positions [b·s, (b+1)·s). With every worker valid and
    s | n this is the plain bucket-mean operator; invalid members are
    dropped and the bucket renormalizes over the survivors.
    """
    nb = -(-n // s)
    bucket_of = jnp.arange(n) // s                       # position -> bucket
    member = jnp.zeros((nb, n), jnp.float32).at[bucket_of, perm].set(1.0)
    w = member * valid.astype(jnp.float32)[None, :]
    cnt = jnp.sum(w, axis=1, keepdims=True)
    bvalid = cnt[:, 0] > 0.0
    return w / jnp.maximum(cnt, 1.0), bvalid


def identity_bucket_matrix(n: int, valid):
    """The s=1 degenerate case: diag(valid) with bucket validity = worker
    validity — so the guarded path always goes through one (W, bvalid)
    pair regardless of bucketing."""
    w = jnp.eye(n, dtype=jnp.float32) * valid.astype(jnp.float32)[None, :]
    return w, valid


def masked_sort_fill(x, valid, fill=jnp.inf):
    """Rows with valid=False become ``fill`` so a sort pushes them past
    every real entry; used by the masked selection rules."""
    v = valid.reshape((-1,) + (1,) * (x.ndim - 1))
    return jnp.where(v, x, jnp.asarray(fill, x.dtype))
