"""Compiled-HLO analysis: collective bytes with while-loop trip counts.

``compiled.cost_analysis()`` counts each computation ONCE, ignoring while
trip counts (verified empirically), so a scanned 126-layer model would look
like a 1-layer model. For collectives we can do better: the compiled text
names every computation, while-ops carry ``known_trip_count`` backend
configs, and collective ops are plain instructions — so we build the call
graph, propagate multipliers from ENTRY, and sum bytes exactly.

FLOPs/HBM-bytes cannot be recovered from text (they hide inside fusions);
launch/dryrun.py corrects those with 1-group/2-group probe compilations.
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {"f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1,
                "pred": 1, "f8e4m3fn": 1, "f8e5m2": 1, "c64": 8, "c128": 16}

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*->.*{")
_REF_SINGLE = re.compile(r"(calls|to_apply|body|condition)=%?([\w.\-]+)")
_REF_LIST = re.compile(r"(branch_computations|called_computations)="
                       r"\{([^}]*)\}")
_TRIP_RE = re.compile(r'"known_trip_count":{"n":"(\d+)"}')


def shape_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_hlo(hlo_text: str):
    """Returns (computations, entry_name).

    computations: name -> {"collectives": {op: bytes}, "counts": {op: n},
                           "edges": [(child_name, multiplier)]}
    """
    comps = {}
    cur = None
    entry = None
    for raw in hlo_text.splitlines():
        line = raw.rstrip()
        stripped = line.strip()
        if not stripped:
            continue
        if not line.startswith(" ") and stripped.endswith("{"):
            m = _COMP_HDR.match(stripped)
            if m:
                cur = m.group(2)
                comps[cur] = {"collectives": defaultdict(int),
                              "counts": defaultdict(int), "edges": []}
                if m.group(1):
                    entry = cur
                continue
        if cur is None:
            continue
        if stripped == "}":
            cur_done = cur  # keep cur until next header; nested braces rare
            continue
        # instruction line
        m = re.match(r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\S+)\s+"
                     r"([\w\-]+)", stripped)
        if not m:
            continue
        result_type, opname = m.group(1), m.group(2)
        base = opname.split(".")[0]
        if base.endswith("-start"):
            base = base[: -len("-start")]
        if base in COLLECTIVES:
            comps[cur]["collectives"][base] += shape_bytes(result_type)
            comps[cur]["counts"][base] += 1
        # call edges
        trip = 1
        tm = _TRIP_RE.search(stripped)
        if tm:
            trip = int(tm.group(1))
        for cm in _REF_SINGLE.finditer(stripped):
            kind, nm = cm.group(1), cm.group(2)
            mult = trip if kind == "body" else 1
            comps[cur]["edges"].append((nm, mult))
        for cm in _REF_LIST.finditer(stripped):
            for nm in cm.group(2).split(","):
                nm = nm.strip().lstrip("%")
                if nm:
                    comps[cur]["edges"].append((nm, 1))
    return comps, entry


def collective_bytes(hlo_text: str) -> dict:
    """Trip-count-aware per-device collective bytes by op kind."""
    comps, entry = parse_hlo(hlo_text)
    if entry is None:
        return {k: {"count": 0, "bytes": 0} for k in COLLECTIVES} | {
            "total_bytes": 0}
    # propagate multipliers through the DAG in topological order (Kahn)
    indeg = defaultdict(int)
    for name, info in comps.items():
        for child, _ in info["edges"]:
            if child in comps:
                indeg[child] += 1
    mult = defaultdict(int)
    mult[entry] = 1
    queue = [n for n in comps if indeg[n] == 0]
    while queue:
        name = queue.pop()
        for child, m in comps[name]["edges"]:
            if child not in comps:
                continue
            mult[child] += mult[name] * m
            indeg[child] -= 1
            if indeg[child] == 0:
                queue.append(child)
    out = {k: {"count": 0, "bytes": 0} for k in COLLECTIVES}
    for name, info in comps.items():
        f = mult.get(name, 0)
        if f == 0:
            continue
        for op, b in info["collectives"].items():
            out[op]["bytes"] += b * f
            out[op]["count"] += info["counts"][op] * f
    out["total_bytes"] = sum(v["bytes"] for v in out.values()
                             if isinstance(v, dict))
    return out
