"""Unit tests for unbiased compression operators (Def. 2.2)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.compressors import (INT8_LEVELS, _int8_decode, _int8_encode,
                                    bf16_cast, identity, int8_quantization,
                                    l2_dithering, natural_compression,
                                    rand_k, sign_compressor, top_k)

KEY = jax.random.PRNGKey(7)


def _empirical_mean(comp, x, n=400):
    acc = jnp.zeros_like(x, dtype=jnp.float32)
    for i in range(n):
        acc = acc + comp.compress(jax.random.fold_in(KEY, i), x)
    return acc / n


@pytest.mark.parametrize("maker", [
    lambda: rand_k(0.25), lambda: l2_dithering(4),
    lambda: natural_compression(), lambda: identity()])
def test_unbiasedness(maker):
    comp = maker()
    x = jax.random.normal(KEY, (64,))
    m = _empirical_mean(comp, x)
    # statistical tolerance: 400 draws, per-coordinate std <= omega^0.5 |x|
    tol = 4.0 * (max(comp.omega(64), 0.01) ** 0.5) * float(
        jnp.max(jnp.abs(x))) / 20.0 + 0.05
    assert float(jnp.max(jnp.abs(m - x))) < tol


def test_randk_density_exact():
    comp = rand_k(0.25)
    x = jax.random.normal(KEY, (100,))
    q = comp.compress(KEY, x)
    assert int(jnp.sum(q != 0)) == 25
    # kept coords scaled by d/k = 4
    kept = q[q != 0]
    orig = x[q != 0]
    np.testing.assert_allclose(np.asarray(kept), np.asarray(orig) * 4.0,
                               rtol=1e-5)


def test_randk_variance_bound():
    comp = rand_k(0.5)
    x = jax.random.normal(KEY, (128,))
    omega = comp.omega(128)
    errs = []
    for i in range(300):
        q = comp.compress(jax.random.fold_in(KEY, i), x)
        errs.append(float(jnp.sum((q - x) ** 2)))
    emp = np.mean(errs)
    bound = omega * float(jnp.sum(x * x))
    assert emp <= bound * 1.15, (emp, bound)


def test_dithering_variance_bound():
    comp = l2_dithering(2)
    x = jax.random.normal(KEY, (64,))
    omega = comp.omega(64)
    errs = []
    for i in range(300):
        q = comp.compress(jax.random.fold_in(KEY, i), x)
        errs.append(float(jnp.sum((q - x) ** 2)))
    assert np.mean(errs) <= omega * float(jnp.sum(x * x)) * 1.15


def test_natural_compression_omega():
    comp = natural_compression()
    assert comp.omega(1000) == pytest.approx(1 / 8)
    x = jax.random.normal(KEY, (256,))
    errs = []
    for i in range(200):
        q = comp.compress(jax.random.fold_in(KEY, i), x)
        errs.append(float(jnp.sum((q - x) ** 2)))
    assert np.mean(errs) <= (1 / 8) * float(jnp.sum(x * x)) * 1.2


def test_natural_compression_powers_of_two():
    comp = natural_compression()
    x = jnp.asarray([0.3, -1.7, 5.0, 0.0])
    q = comp.compress(KEY, x)
    nz = np.asarray(q[q != 0])
    exps = np.log2(np.abs(nz))
    np.testing.assert_allclose(exps, np.round(exps), atol=1e-6)
    assert float(q[3]) == 0.0


def test_sign_compressor_is_sign():
    comp = sign_compressor()
    x = jnp.asarray([1.5, -2.0, 3.0])
    q = comp.compress(KEY, x)
    assert jnp.all(jnp.sign(q) == jnp.sign(x))


def test_topk_keeps_largest_unscaled():
    comp = top_k(0.25)
    x = jnp.asarray([0.1, -5.0, 0.3, 2.0, -0.2, 0.05, 1.0, -0.4])
    q = comp.compress(KEY, x)
    # k = 2 largest magnitudes kept raw (no unbiasedness scaling)
    np.testing.assert_allclose(
        np.asarray(q), [0, -5.0, 0, 2.0, 0, 0, 0, 0], atol=1e-7)


def test_topk_contractive_bound_deterministic():
    """||C(x) - x||^2 <= (1 - k/d) ||x||^2, with equality only when all
    magnitudes are equal — check on random vectors (top_k is deterministic,
    no sampling slack needed)."""
    comp = top_k(0.3)
    for i in range(20):
        x = jax.random.normal(jax.random.fold_in(KEY, i), (50,))
        q = comp.compress(KEY, x)
        err = float(jnp.sum((q - x) ** 2))
        bound = comp.contractive_delta(50) * float(jnp.sum(x * x))
        assert err <= bound + 1e-6, (err, bound)
    assert comp.contractive_delta(50) == pytest.approx(1 - 15 / 50)
    assert np.isnan(comp.omega(50))      # biased: no Def. 2.2 omega


def test_contractive_delta_surface():
    assert identity().contractive_delta(10) == 0.0
    assert sign_compressor().contractive_delta(10) == pytest.approx(0.9)
    assert rand_k(0.5).contractive_delta(10) is None     # unbiased, unscaled
    assert l2_dithering(2).contractive_delta(10) is None


def test_bits_accounting():
    d = 1000
    assert rand_k(0.1).bits_per_vector(d) == 100 * 64
    assert top_k(0.1).bits_per_vector(d) == 100 * 64
    assert identity().bits_per_vector(d) == 32 * d
    assert natural_compression().bits_per_vector(d) == 9 * d


def test_huge_leaf_block_selection():
    """Leaves above the unit cap switch to block selection, stay unbiased."""
    comp = rand_k(0.5)
    x = jnp.ones((1 << 23,))          # 8M coords -> block size 2
    q = comp.compress(KEY, x)
    # mean over coords of q should be ~1 (unbiased), support ratio ~0.5
    assert abs(float(q.mean()) - 1.0) < 0.01
    frac = float((q != 0).mean())
    assert abs(frac - 0.5) < 0.01


# ---------------------------------------------------------------------------
# kernel-native quantized wires (int8 / bf16) + the wire-format contract
# ---------------------------------------------------------------------------

def test_int8_unbiased_and_bounded():
    """Blockwise l2-dithering: unbiased, with per-block variance inside the
    QSGD omega bound; levels fit signed int8 exactly."""
    comp = int8_quantization()
    x = jax.random.normal(KEY, (600,))       # 3 blocks, last one partial
    m = _empirical_mean(comp, x)
    assert float(jnp.max(jnp.abs(m - x))) < 0.05 * float(
        jnp.max(jnp.abs(x))) + 0.02
    omega = comp.omega(600)
    errs = [float(jnp.sum((comp.compress(jax.random.fold_in(KEY, i), x)
                           - x) ** 2)) for i in range(200)]
    assert np.mean(errs) <= omega * float(jnp.sum(x * x)) * 1.2
    levels, norms = _int8_encode(KEY, x)
    assert levels.dtype == jnp.int8
    assert int(jnp.max(jnp.abs(levels.astype(jnp.int32)))) <= INT8_LEVELS


def test_int8_roundtrip_matches_shared_encoder():
    """compress ≡ decode(encode(·)) for the encoder the wire packer shares —
    the fused kernels reconstruct bit-identical candidates."""
    comp = int8_quantization()
    x = jax.random.normal(KEY, (300,))
    want = _int8_decode(*_int8_encode(KEY, x))[:300]
    np.testing.assert_array_equal(np.asarray(comp.compress(KEY, x)),
                                  np.asarray(want))


def test_bf16_cast_contractive():
    comp = bf16_cast()
    x = jax.random.normal(KEY, (512,))
    q = comp.compress(KEY, x)
    err = float(jnp.sum((q - x) ** 2))
    assert err <= comp.contractive_delta(512) * float(jnp.sum(x * x)) + 1e-9
    # bf16 input passes through exactly
    xb = x.astype(jnp.bfloat16)
    np.testing.assert_array_equal(np.asarray(comp.compress(KEY, xb), np.float32),
                                  np.asarray(xb, np.float32))


def test_quantized_bits_accounting():
    assert sign_compressor().bits_per_vector(1000) == 1000 + 32
    assert int8_quantization().bits_per_vector(1000) == 8 * 1000 + 32 * 4
    assert bf16_cast().bits_per_vector(1000) == 16 * 1000


def test_registry_wire_format_fail_closed():
    """Every registered compressor must either declare a kernel wire format
    (and it must be one kernels/quantize.py implements) or be explicitly
    fallback-only — never silently neither (CI fail-closed gate: a new
    compressor without a routing decision breaks here, not in a fleet)."""
    from repro.core.compressors import REGISTRY
    from repro.kernels import quantize
    for name, maker in REGISTRY.items():
        comp = maker()
        declared = comp.wire_format is not None
        assert declared or comp.fallback_only, (
            f"{name}: declare wire_format or set fallback_only=True")
        if declared:
            assert not comp.fallback_only, (
                f"{name}: wire_format and fallback_only are exclusive")
            assert comp.wire_format in quantize.WIRE_FORMATS, (
                f"{name}: unknown wire format {comp.wire_format!r}")
