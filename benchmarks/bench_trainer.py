"""System throughput: wall-clock steps/s of the full Byzantine-robust
trainer on this host (single device; the distributed step is the same code
jitted onto the mesh). One row per (model, method, aggregator, compressor)
with tokens/s — every row is one ``RunSpec`` driven through the shared
runner (warmup=True compiles before the timer starts), and the resolved
spec JSON is emitted per row.
"""
from benchmarks.common import emit
from repro.api import RunSpec, run as run_spec

N, BW, S = 4, 2, 64
ITERS = 8

ROWS = [
    ("marina", "mean", "identity"),
    ("marina", "cm", "identity"),
    ("marina", "cm", "randk"),
    ("marina", "rfa", "identity"),
    ("sgdm", "cm", "identity"),
    ("csgd", "cm", "randk"),
]


def run():
    for arch in ["qwen3-1.7b", "mamba2-130m", "phi3.5-moe-42b-a6.6b"]:
        for method, agg, comp in ROWS:
            spec = RunSpec(
                task="lm", arch=arch, method=method,
                n_workers=N, n_byz=1, p=0.25, lr=1e-2, attack="ALIE",
                aggregator=agg, bucket_size=0 if agg == "mean" else 2,
                compressor=comp,
                compressor_kwargs={"ratio": 0.25} if comp == "randk" else {},
                steps=ITERS, seed=0,
                data_kwargs={"reduced": True, "seq_len": S,
                             "per_worker_batch": BW})
            result = run_spec(spec, log_every=ITERS, warmup=True)
            dt = result.wall_s / ITERS
            toks = N * BW * S
            emit(f"trainer/{arch}/{method}/{agg}+{comp}", dt * 1e6,
                 f"tokens_per_s={toks/dt:.0f}", spec=spec)


if __name__ == "__main__":
    run()
