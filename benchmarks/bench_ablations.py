"""Ablations over the paper's knobs (App. E.5 discussions):

* p sweep      — "On the choice of p": oracle vs communication tradeoff.
* bucket sweep — s ∈ {1,2,4}: Alg. 2's robustness/variance tradeoff
                 (paper recommends s=2).
* batch sweep  — "On the batchsizes": gains saturate once
                 b ≳ max{∛(cδm²), √m}.
* IS vs US     — Example E.2: importance sampling reaches the target in
                 fewer rounds when 𝓛±(IS) ≪ 𝓛±(US).

Every knob is a ``Sweep`` axis over one base ``RunSpec`` (importance
sampling is ``data_kwargs.sampling``); specs are emitted per row."""
from benchmarks.common import emit, final_gap, logreg_reference
from repro.api import RunSpec, Sweep, build
from repro.core import theory

DIM = 30
BASE = RunSpec(task="logreg", method="marina", n_workers=5, n_byz=1,
               p=0.1, lr=0.5, attack="ALIE", aggregator="cm", bucket_size=2,
               steps=400,
               data_kwargs={"n_samples": 400, "dim": DIM, "data_seed": 5})


def _gap(spec, full, f_star):
    exp = build(spec)
    return final_gap(exp, exp.run(log_every=spec.steps), full, f_star)


def run():
    full, f_star = logreg_reference(build(BASE))

    for _, spec in Sweep(BASE, {"p": (0.02, 0.1, 0.5)}).expand():
        emit(f"ablate/p{spec.p}", 0.0, f"gap={_gap(spec, full, f_star):.2e}",
             spec=spec)

    for _, spec in Sweep(BASE, {"bucket_size": (1, 2, 4)}).expand():
        emit(f"ablate/bucket{spec.bucket_size}", 0.0,
             f"gap={_gap(spec, full, f_star):.2e}", spec=spec)

    batch_sweep = Sweep(BASE.replace(steps=300),
                        {"data_kwargs.batch_size": (8, 32, 128)})
    for _, spec in batch_sweep.expand():
        emit(f"ablate/batch{spec.data_kwargs['batch_size']}", 0.0,
             f"gap={_gap(spec, full, f_star):.2e}", spec=spec)

    # importance vs uniform sampling (Example E.2)
    exp = build(BASE)
    _, lbar = theory.importance_weights(exp.data.features, 0.01)
    pc = theory.logreg_constants(exp.data.features, 0.01, n_workers=5)
    sampling = Sweep(BASE.replace(steps=250),
                     {"data_kwargs.sampling": ("uniform", "importance")})
    call = {"uniform": pc.calL_pm, "importance": lbar}
    for _, spec in sampling.expand():
        mode = spec.data_kwargs["sampling"]
        emit(f"ablate/sampling-{mode}", 0.0,
             f"gap={_gap(spec, full, f_star):.2e};calL={call[mode]:.2f}",
             spec=spec)


if __name__ == "__main__":
    run()
