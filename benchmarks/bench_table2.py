"""Paper Table 2 (empirical analogue): communication rounds to reach a target
optimality gap, Byz-VR-MARINA vs BR-SGDm / BR-CSGD / BR-DIANA / Byrd-SVRG,
under the ALIE attack. Also reports uploaded bits per worker to reach the
target (the compression win).

Every contender is one ``RunSpec`` row executed through the
sweep-execution engine (``repro.exec``): the early-stop probe attaches per
cell via ``cell_hook`` (which also hands back the built Experiment for the
estimator's own bits-per-round accounting), a diverging contender is
isolated as a failed cell instead of killing the table, and the row
summary lands in ``experiments/bench/table2_summary.json``."""
import os

from benchmarks.common import ART_DIR, emit, logreg_reference
from repro import exec as xc
from repro.api import RunSpec, build

DIM = 30
TARGET = 1e-3
MAX_ROUNDS = 1200
CHECK_EVERY = 25

BASE = RunSpec(task="logreg", n_workers=5, n_byz=1, p=0.1, lr=0.5,
               attack="ALIE", aggregator="cm", bucket_size=2,
               steps=MAX_ROUNDS,
               data_kwargs={"n_samples": 400, "dim": DIM, "data_seed": 1})

RANDK = {"compressor": "randk", "compressor_kwargs": {"ratio": 0.1}}
ROWS = [
    ("byz-vr-marina", BASE.replace(method="marina")),
    ("byz-vr-marina+randk", BASE.replace(method="marina", **RANDK)),
    ("br-sgdm", BASE.replace(method="sgdm")),
    ("br-csgd+randk", BASE.replace(method="csgd", **RANDK)),
    ("br-diana+randk", BASE.replace(method="diana", **RANDK)),
    ("byrd-svrg", BASE.replace(method="svrg", aggregator="rfa")),
]


def run(max_rounds=MAX_ROUNDS):
    full, f_star = logreg_reference(build(BASE))
    cells = [(label, spec.replace(steps=max_rounds)) for label, spec in ROWS]
    hits, exps = {}, {}

    def hook(run_id, spec, exp):
        exps[run_id] = exp
        hit = hits.setdefault(run_id, [])

        def probe(it, state, m):
            if float(exp.loss_fn(state["params"], full)) - f_star < TARGET:
                hit.append(it + 1)
            return bool(hit)

        return {"callback": probe, "callback_every": CHECK_EVERY}

    srun = xc.run_cells(cells, run_kw={"log_every": max_rounds},
                        cell_hook=hook)
    for label, spec in cells:
        if label in srun.failures:
            continue
        rounds = hits[label][0] if hits.get(label) else -1
        bits_per_round = exps[label].method.expected_bits(DIM + 1)
        bits = rounds * bits_per_round if rounds > 0 else float("inf")
        emit(f"table2/{label}", float(rounds),
             f"rounds_to_{TARGET:g}={rounds};bits/worker={bits:.3g}",
             spec=spec)
    xc.write_summary(os.path.join(ART_DIR, "table2_summary.json"),
                     xc.summarize(srun.artifacts))


if __name__ == "__main__":
    run()
