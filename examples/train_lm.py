"""End-to-end driver: train a ~100M-parameter LM (mamba2-130m, the paper's
technique at framework scale) for a few hundred Byzantine-robust steps.

8 simulated workers, 2 Byzantine running IPM, CM∘bucketing aggregation,
RandK(25%) compression — all declared in one ``RunSpec`` and driven by the
shared runner (the same loop launch/train.py uses). On this CPU container a
130M model steps slowly; --small swaps in a ~7M variant so the example
finishes in ~a minute.

  PYTHONPATH=src python examples/train_lm.py --steps 300 [--small]
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.api import RunSpec, build, components

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--small", action="store_true",
                help="reduced config (CI-speed)")
ap.add_argument("--seq-len", type=int, default=128)
ap.add_argument("--attack", default="IPM", choices=components("attack"))
ap.add_argument("--method", default="marina", choices=components("method"))
args = ap.parse_args()

spec = RunSpec(
    task="lm", arch="mamba2-130m", method=args.method,
    n_workers=8, n_byz=2, p=0.125, lr=5e-3, attack=args.attack,
    aggregator="cm", bucket_size=2,
    compressor="randk", compressor_kwargs={"ratio": 0.25},
    steps=args.steps,
    data_kwargs={"reduced": args.small, "seq_len": args.seq_len,
                 "per_worker_batch": 2})

exp = build(spec)
n_params = exp.arch_cfg.param_count()
print(f"mamba2 ~{n_params/1e6:.1f}M params | method={spec.method} | "
      f"{spec.n_workers} workers ({spec.n_byz} byzantine, {spec.attack}) | "
      f"CM∘bucketing + RandK(0.25)")
exp.run(log_every=20, verbose=True)
print("done")
