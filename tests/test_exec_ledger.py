"""exec/ledger + scheduler resume semantics: crash-safe JSONL round-trip,
skip-completed / re-run-failed, and the killed-and-resumed-sweep ≡
uninterrupted-sweep bit-for-bit guarantee."""
import json
import os

from repro import exec as xc
from repro.api import RunSpec, Sweep

STEPS = 3


def _base(**kw):
    d = dict(task="logreg", method="marina", n_workers=5, n_byz=1, p=0.3,
             lr=0.25, attack="ALIE", aggregator="cm", bucket_size=2,
             steps=STEPS,
             data_kwargs={"n_samples": 60, "dim": 8, "batch_size": 8,
                          "data_seed": 0})
    d.update(kw)
    return RunSpec(**d)


def _cells(grid=None):
    return list(Sweep(_base(), grid or {"aggregator": ("mean", "cm"),
                                        "seed": (0, 1, 2)}).expand())


def _summary_bytes(out_dir):
    path = xc.write_summary(os.path.join(out_dir, "x_summary.json"),
                            xc.summarize_dir(out_dir))
    with open(path, "rb") as f:
        return f.read()


# ---------------------------------------------------------------------------
# ledger round-trip
# ---------------------------------------------------------------------------

def test_ledger_roundtrip(tmp_path):
    led = xc.Ledger(str(tmp_path / "ledger.jsonl"))
    led.append("a", "started", spec={"seed": 0})
    led.append("a", "done", wall_s=1.5)
    led.append("b", "started")
    led.append("c", "failed", error="ValueError: boom")
    assert led.completed() == {"a"}
    assert led.failed() == {"c"}
    assert led.record("a")["wall_s"] == 1.5
    assert led.record("b")["status"] == "started"
    recs = list(led.iter_records())
    assert [r["run_id"] for r in recs] == ["a", "a", "b", "c"]


def test_ledger_tolerates_torn_trailing_line(tmp_path):
    led = xc.Ledger(str(tmp_path / "ledger.jsonl"))
    led.append("a", "done")
    with open(led.path, "a") as f:
        f.write('{"run_id": "b", "status": "do')     # killed mid-write
    assert led.completed() == {"a"}
    led.append("b", "done")                          # appends still work
    assert led.completed() == {"a", "b"}


# ---------------------------------------------------------------------------
# resume semantics
# ---------------------------------------------------------------------------

def test_killed_and_resumed_sweep_is_bit_identical(tmp_path):
    """Kill mid-sweep (mid-group, even), resume, and the summary must be
    byte-for-byte the uninterrupted sweep's."""
    cells = _cells()
    d1, d2 = str(tmp_path / "full"), str(tmp_path / "killed")
    xc.run_cells(cells, out_dir=d1, run_kw={"log_every": 1})
    # "kill" after 4 of 6 cells: the first vmapped group committed, the
    # second is torn mid-group
    xc.run_cells(cells[:4], out_dir=d2, run_kw={"log_every": 1})
    srun = xc.run_cells(cells, out_dir=d2, resume=True,
                        run_kw={"log_every": 1})
    # the finished group was skipped; the torn group re-ran at full width
    assert len(srun.skipped) == 3
    assert srun.stats["executed_cells"] == 3
    assert _summary_bytes(d1) == _summary_bytes(d2)


def test_resume_skips_done_and_reruns_failed(tmp_path):
    cells = _cells({"seed": (0, 1)})
    out = str(tmp_path / "sweep")
    first = xc.run_cells(cells, out_dir=out, run_kw={"log_every": 1})
    assert first.stats["executed_cells"] == 2
    # mark one cell failed (as a crashed worker would) + drop its artifact
    rid = cells[0][0]
    led = xc.Ledger(os.path.join(out, "ledger.jsonl"))
    led.append(rid, "failed", error="simulated")
    os.unlink(os.path.join(out, rid + ".json"))
    srun = xc.run_cells(cells, out_dir=out, resume=True,
                        run_kw={"log_every": 1})
    # the failed cell re-ran; with its group partial, full-width re-run
    # covers both members (bit-identical policy), never fewer
    assert srun.stats["executed_cells"] == 2
    assert led.completed() == {c[0] for c in cells}


def test_resume_serial_cells_skip_individually(tmp_path):
    cells = _cells({"aggregator": ("mean", "cm")})    # 1 seed -> serial
    out = str(tmp_path / "sweep")
    xc.run_cells(cells[:1], out_dir=out, run_kw={"log_every": 1})
    srun = xc.run_cells(cells, out_dir=out, resume=True,
                        run_kw={"log_every": 1})
    assert srun.skipped == {cells[0][0]}
    assert srun.stats["executed_cells"] == 1
    assert len(srun) == 2


def test_failure_isolation_records_and_continues(tmp_path, monkeypatch):
    cells = _cells({"aggregator": ("mean", "cm")})
    real_run = xc.scheduler.run_spec

    def boom(spec, **kw):
        if spec.aggregator == "mean":
            raise RuntimeError("diverged")
        return real_run(spec, **kw)

    monkeypatch.setattr(xc.scheduler, "run_spec", boom)
    srun = xc.run_cells(cells, out_dir=str(tmp_path),
                        run_kw={"log_every": 1})
    assert set(srun.failures) == {cells[0][0]}
    assert "diverged" in srun.failures[cells[0][0]]["error"]
    assert cells[1][0] in srun                         # grid kept going
    led = xc.Ledger(str(tmp_path / "ledger.jsonl"))
    assert led.failed() == {cells[0][0]}


# ---------------------------------------------------------------------------
# aggregation
# ---------------------------------------------------------------------------

def test_summary_shape_and_determinism(tmp_path):
    cells = _cells()
    srun = xc.run_cells(cells, out_dir=str(tmp_path),
                        run_kw={"log_every": 1})
    s1 = xc.summarize(srun.artifacts)
    s2 = xc.summarize_dir(str(tmp_path))               # via artifacts on disk
    assert json.dumps(s1, sort_keys=True) == json.dumps(s2, sort_keys=True)
    assert s1["n_cells"] == 6 and s1["n_groups"] == 2
    labels = {g["label"] for g in s1["groups"]}
    assert labels == {"aggregator=mean", "aggregator=cm"}
    for g in s1["groups"]:
        assert g["seeds"] == [0, 1, 2] and g["n_seeds"] == 3
        assert "wall_s" not in g["final"]              # timing excluded
        assert g["final"]["loss"]["n"] == 3
    assert s1["best"]["metric"] == "loss"


def test_ledger_records_provenance(tmp_path):
    cells = _cells({"seed": (0,)})
    xc.run_cells(cells, out_dir=str(tmp_path), run_kw={"log_every": 1})
    done = [r for r in
            xc.Ledger(str(tmp_path / "ledger.jsonl")).iter_records()
            if r["status"] == "done"]
    assert done
    for rec in done:
        assert rec["git_sha"]
        assert rec["device_kind"].split(":")[0] in ("cpu", "gpu", "tpu")
        assert rec["engine"] in ("serial", "vmapped", "subprocess")
    started = xc.Ledger(str(tmp_path / "ledger.jsonl")).iter_records()
    spec_recs = [r for r in started if r["status"] == "started"]
    assert spec_recs[0]["spec"] == cells[0][1].to_dict()
