"""End-to-end driver: train a ~100M-parameter LM (mamba2-130m, the paper's
technique at framework scale) for a few hundred Byzantine-robust steps.

8 simulated workers, 2 Byzantine running IPM, CM∘bucketing aggregation,
RandK(25%) compression. On this CPU container a 130M model steps slowly;
--small swaps in a ~7M variant so the example finishes in ~a minute.

  PYTHONPATH=src python examples/train_lm.py --steps 300 [--small]
"""
import argparse
import sys
import time

sys.path.insert(0, "src")

import jax

from repro.configs import get_config
from repro.core import (ByzVRMarinaConfig, get_aggregator, get_attack,
                        get_compressor, list_methods, make_method)
from repro.data import TokenStream, corrupt_labels_lm
from repro.models import init_params, loss_fn as model_loss

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--small", action="store_true",
                help="reduced config (CI-speed)")
ap.add_argument("--seq-len", type=int, default=128)
ap.add_argument("--attack", default="IPM")
ap.add_argument("--method", default="marina", choices=list_methods())
args = ap.parse_args()

cfg = get_config("mamba2-130m")
if args.small:
    cfg = cfg.reduced()
n_workers, n_byz = 8, 2
stream = TokenStream(vocab_size=cfg.vocab_size, seq_len=args.seq_len,
                     n_workers=n_workers, per_worker_batch=2)

bcfg = ByzVRMarinaConfig(
    n_workers=n_workers, n_byz=n_byz, p=0.125, lr=5e-3,
    aggregator=get_aggregator("cm", bucket_size=2),
    compressor=get_compressor("randk", ratio=0.25),
    attack=get_attack(args.attack))


def loss(params, batch, key):
    return model_loss(params, cfg, batch)


key = jax.random.PRNGKey(0)
params = init_params(key, cfg)
n_params = sum(x.size for x in jax.tree.leaves(params))
print(f"mamba2 {n_params/1e6:.1f}M params | method={args.method} | "
      f"{n_workers} workers ({n_byz} byzantine, {args.attack}) | "
      f"CM∘bucketing + RandK(0.25)")

method = make_method(args.method, bcfg, loss, corrupt_labels_lm)
state = method.init(params, stream.anchor(0), key)
step = jax.jit(method.step)
t0 = time.time()
for it in range(args.steps):
    state, m = step(state, stream.minibatch(it), stream.anchor(it),
                    jax.random.fold_in(key, it))
    if it % 20 == 0 or it == args.steps - 1:
        print(f"  step {it:4d}  loss {float(m['loss']):.4f} "
              f"|g| {float(m['g_norm']):.3e}  ({time.time()-t0:.0f}s)")
print("done")
