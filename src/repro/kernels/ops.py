"""jit'd public wrappers for the Pallas kernels.

``interpret`` defaults to True on CPU hosts (this container) and False on
real TPU backends — the kernels are written for TPU (pl.pallas_call +
BlockSpec VMEM tiling) and validated against ref.py in interpret mode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.robust_agg import robust_agg as _robust_agg
from repro.kernels.quantize import block_quantize as _block_quantize
from repro.kernels import ref


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def robust_agg(x, key=None, *, bucket_size: int = 1, rule: str = "median",
               trim: int = 1, interpret=None):
    """Full (δ,c)-ARAgg for (n, d) stacked workers: random permutation
    (host-side jax.random) + fused bucket-mean + coordinate rule kernel."""
    if key is not None and bucket_size > 1:
        perm = jax.random.permutation(key, x.shape[0])
        x = x[perm]
    itp = _default_interpret() if interpret is None else interpret
    return _robust_agg(x, bucket_size=bucket_size, rule=rule, trim=trim,
                       interpret=itp)


def block_quantize(x, key, *, levels: int = 4, block: int = 256,
                   interpret=None):
    u = jax.random.uniform(key, x.shape)
    itp = _default_interpret() if interpret is None else interpret
    return _block_quantize(x, u, levels=levels, block=block, interpret=itp)


def robust_agg_oracle(x, *, bucket_size: int = 1, rule: str = "median",
                      trim: int = 1):
    return ref.robust_agg_ref(x, bucket_size=bucket_size, rule=rule, trim=trim)


def block_quantize_oracle(x, u, *, levels: int = 4, block: int = 256):
    return ref.block_quantize_ref(x, u, levels=levels, block=block)
