"""Paper Figure 1 (extended): optimality gap of 3 aggregation rules (AVG,
CM, RFA) under 5 attacks (NA, LF, BF, ALIE, IPM), homogeneous data, 4 good
+ 1 byzantine worker, with and without compression — for Byz-VR-MARINA and
the successor estimators (Byz-EF21, compressed momentum filtering,
Byrd-SAGA), so the BENCH artifacts track every method family.

The whole grid is ONE declarative ``Sweep`` executed through the batched
engine (``repro.exec``): with ``seeds`` > 1 every (method, compressor,
aggregator, attack) cell becomes a jit-signature group that runs as a
single vmapped-over-seeds trajectory (SAGA cells classify un-batchable and
take the serial path), and the mean±std-over-seeds table lands in
``experiments/bench/fig1_summary.json``. Each emitted row still carries
the resolved spec JSON, so any cell reproduces with
``RunSpec.from_dict(artifact["spec"]).run()``.

Per-method compressor mapping: marina/cmfilter upload unbiased Q (RandK);
byz_ef21 needs a contractive C (TopK at the same keep-ratio); saga uploads
dense SAGA estimates, so its compressed half is skipped (the compressor
never touches the wire).
"""
import os

from benchmarks.common import ART_DIR, emit, logreg_reference
from repro import exec as xc
from repro.api import RunSpec, Sweep, build

DIM = 30
BASE = RunSpec(task="logreg", method="marina", n_workers=5, n_byz=1,
               p=0.1, lr=0.5, seed=0,
               data_kwargs={"n_samples": 400, "dim": DIM, "data_seed": 0})

GRID = {
    "method": ("marina", "byz_ef21", "cmfilter", "saga"),
    "compressor_kwargs.ratio": (1.0, 0.1),          # none vs K = 0.1 d
    "aggregator": ("mean", "cm", "rfa"),
    "attack": ("NA", "LF", "BF", "ALIE", "IPM"),
}
_AGG_LABEL = {"mean": "avg", "cm": "cm", "rfa": "rfa"}


def cells(iters, seeds):
    out = []
    # expand per method: RunSpec validates eagerly, so byz_ef21 must carry
    # its contractive compressor BEFORE the grid product is formed
    from repro.core.estimators import needs_contractive_compressor
    for method in GRID["method"]:
        base = BASE.replace(
            steps=iters, method=method,
            compressor=("topk" if needs_contractive_compressor(method)
                        else "randk"))
        grid = {k: v for k, v in GRID.items() if k != "method"}
        grid["method"] = (method,)           # keep method in the run id
        if len(seeds) > 1:
            grid["seed"] = tuple(seeds)
        for run_id, spec in Sweep(base=base, grid=grid).expand():
            ratio = spec.compressor_kwargs["ratio"]
            if spec.method == "saga" and ratio < 1.0:
                continue               # dense uploads: no compressed half
            if ratio >= 1.0:
                # identity wire format, not RandK(d)/TopK(d)
                spec = spec.replace(compressor="identity",
                                    compressor_kwargs={})
            if spec.aggregator == "mean":
                spec = spec.replace(bucket_size=0)
            out.append((run_id, spec))
    return out


def run(iters=500, seeds=(0,)):
    exp0 = build(BASE.replace(steps=iters))
    full, f_star = logreg_reference(exp0)
    loss_fn = exp0.loss_fn
    grid = cells(iters, seeds)
    srun = xc.run_cells(grid, run_kw={"log_every": iters})
    for run_id, spec in grid:
        if run_id in srun.failures:
            continue
        result = srun[run_id]
        gap = float(loss_fn(result.params, full)) - f_star
        ratio = (spec.compressor_kwargs.get("ratio", 1.0)
                 if spec.compressor in ("randk", "topk") else 1.0)
        comp_name = ("none" if ratio >= 1.0
                     else f"{spec.compressor}{ratio}")
        tag = f"/seed{spec.seed}" if len(seeds) > 1 else ""
        emit(f"fig1/{spec.method}/{comp_name}/"
             f"{_AGG_LABEL[spec.aggregator]}/{spec.attack}{tag}",
             result.wall_s / iters * 1e6, f"gap={gap:.3e}", spec=spec)
    xc.write_summary(os.path.join(ART_DIR, "fig1_summary.json"),
                     xc.summarize(srun.artifacts))


if __name__ == "__main__":
    run()
