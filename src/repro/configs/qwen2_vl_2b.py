"""qwen2-vl-2b [vlm] — M-RoPE, dynamic resolution.

28L d_model=1536 12H (GQA kv=2) d_ff=8960 vocab=151936 [arXiv:2409.12191]
Vision encoder (ViT) is a STUB per the task carve-out: ``input_specs`` supplies
precomputed patch embeddings of shape (batch, frontend_tokens, d_model); this
config is the language decoder that consumes them interleaved with text tokens.
"""
from repro.configs.base import ArchConfig, ATTN, register

CONFIG = register(ArchConfig(
    name="qwen2-vl-2b",
    family="vlm",
    citation="arXiv:2409.12191",
    num_layers=28,
    d_model=1536,
    num_heads=12,
    num_kv_heads=2,
    d_ff=8960,
    vocab_size=151_936,
    block_pattern=(ATTN,),
    mrope_sections=(16, 24, 24),   # t/h/w split of head_dim=128 rotary pairs /2
    frontend_tokens=256,           # stubbed ViT patch embeddings per example
    rope_theta=1_000_000.0,
))
