"""Paper App. B.3.1 (Fig. 2/3): heterogeneous data — 15 workers with a
disjoint sequential split, 5 of them Byzantine, robust aggregation with
bucketing. Demonstrates Thm. 2.1's two regimes:

  * robust aggregators converge to the O(cδζ²/p) neighbourhood of the good
    workers' optimum (the Karimireddy et al. lower-bound floor — no
    algorithm can do better under heterogeneity);
  * plain averaging is dragged arbitrarily far by ALIE/IPM.

  PYTHONPATH=src python examples/heterogeneous.py [--iters 500]
"""
import argparse
import sys

sys.path.insert(0, "src")

import jax
import jax.numpy as jnp

from repro.core import (ByzVRMarinaConfig, get_aggregator, get_attack,
                        get_compressor, make_init, make_step, theory)
from repro.data import (corrupt_labels_logreg, init_logreg_params,
                        logreg_loss, make_logreg_data)

ap = argparse.ArgumentParser()
ap.add_argument("--iters", type=int, default=500)
ap.add_argument("--randk", type=float, default=1.0)
args = ap.parse_args()

DIM = 30
N, NBYZ = 15, 5
key = jax.random.PRNGKey(0)
data = make_logreg_data(key, n_samples=1500, dim=DIM, n_workers=N,
                        homogeneous=False)
loss_fn = logreg_loss(0.01)

# f* over the GOOD workers' pooled data (workers 0..NBYZ-1 are byzantine)
goods = [data.worker_slice(i) for i in range(NBYZ, N)]
full = {"x": jnp.concatenate([g[0] for g in goods]),
        "y": jnp.concatenate([g[1] for g in goods])}
p_star = init_logreg_params(DIM)
gd = jax.jit(lambda p: jax.tree.map(
    lambda a, g: a - 0.5 * g, p, jax.grad(loss_fn)(p, full)))
for _ in range(3000):
    p_star = gd(p_star)
f_star = float(loss_fn(p_star, full))

# empirical ζ² at x* (As. 2.2) and the theoretical floor
grads = [jax.grad(loss_fn)(p_star, {"x": g[0], "y": g[1]}) for g in goods]
gbar = jax.tree.map(lambda *x: sum(x) / len(x), *grads)
zeta_sq = float(sum(
    sum(jnp.sum((a - b) ** 2) for a, b in
        zip(jax.tree.leaves(g), jax.tree.leaves(gbar)))
    for g in grads) / len(grads))
floor = theory.error_floor(delta=NBYZ / N, c=6.0, p=0.1, zeta_sq=zeta_sq,
                           mu=0.02)
print(f"heterogeneous split: ζ² = {zeta_sq:.4f}  "
      f"theory floor O(cδζ²/pμ) = {floor:.3f}  f* = {f_star:.4f}")

comp = (get_compressor("randk", ratio=args.randk) if args.randk < 1
        else get_compressor("identity"))
for attack in ["NA", "LF", "BF", "ALIE", "IPM"]:
    row = []
    for agg_label, rule, bucket in [("AVG", "mean", 0), ("CM", "cm", 2),
                                    ("RFA", "rfa", 2)]:
        cfg = ByzVRMarinaConfig(
            n_workers=N, n_byz=NBYZ, p=0.1, lr=0.2,
            aggregator=get_aggregator(rule, bucket_size=bucket),
            compressor=comp, attack=get_attack(attack))
        step = jax.jit(make_step(cfg, loss_fn, corrupt_labels_logreg))
        anchor = data.stacked()
        state = make_init(cfg, loss_fn, corrupt_labels_logreg)(
            init_logreg_params(DIM), anchor, key)
        k = jax.random.PRNGKey(1)
        for it in range(args.iters):
            k, k1, k2 = jax.random.split(k, 3)
            state, _ = step(state, data.sample_batches(k1, 32), anchor, k2)
        gap = float(loss_fn(state["params"], full)) - f_star
        row.append(f"{agg_label}:{gap:9.2e}")
    print(f"{attack:>5} | " + "  ".join(row))
print("\nAll methods plateau at an O(δζ²)-scale gap — the heterogeneous "
      "lower bound of Karimireddy et al. (2022) binds every algorithm; "
      "the theory floor above is the (loose) Thm. 2.1 constant. Compare "
      "the clean-data example (quickstart.py) where the same attacks are "
      "driven to f* exactly. This mirrors the paper's Fig. 2 plateaus.")
