"""The buffered-asynchronous robust-aggregation round engine (DESIGN.md §4).

``AggregationService`` replaces the synchronous round barrier with a
FedBuff-style protocol over the *unchanged* aggregation stack: clients
dispatch updates continuously (arrivals.py), a double buffer admits them
with sequence dedup (buffer.py), and every time the buffer holds
``buffer_size = K`` updates the service fires lines 9-10 of the paper's
round — omniscient attack + (δ,c)-robust aggregation — through
``engine.ingest_message_phase``, with

  * the Byzantine mask defined over the *buffered* set (whichever updates
    happen to sit in the fired buffer, not a static worker prefix);
  * FedBuff staleness weighting ``s(τ) = 1/sqrt(1+τ)`` (τ = fires since
    the update's dispatch) fused into the aggregation's on-chip ``w``
    operator: candidates are scaled by ``K·s(τ_i)/Σ_j s(τ_j)`` and then
    robustly aggregated, so ``rule="mean"`` reproduces the FedBuff
    weighted mean exactly and the robust rules see staleness-discounted
    vectors at zero extra HBM traffic.

Virtual-time semantics (what makes every run replayable and the sync
limit exact): events at one instant are processed as a wave; a fire ends
the current segment, and clients (re)dispatch at segment ends — so a
client whose update was just consumed pulls the *post-fire* model, and
with ``const`` latency, no chaos and K = n_clients the service reproduces
the synchronous engine trajectory bit-for-bit (tests/test_serve.py).
Dispatch is lazy and batched: a (re)dispatching client is only marked
pending, and one vmapped ``estimator.round`` call — the engine's own
candidate computation, same key schedule as api/runner.py — materializes
every pending client's update at the moment one of them first arrives (or
a fire needs the params to advance). Between flushes params never change,
so all pending clients share one flush.

Crash safety: every fired round is journaled through ``exec.ledger``
(round, cursor, staleness, byz-in-buffer, dedup counters, optional params
digest) and checkpoints snapshot the full service state — engine state,
in-flight store, dispatch versions, dedup table, event cursor — right
after a fire. Resume reloads the snapshot and replays the arrival stream
from the cursor, deterministically rebuilding any mid-buffer state, so a
killed-and-resumed run finishes bit-identical to an uninterrupted one.
"""
from __future__ import annotations

import dataclasses
import hashlib
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import engine
from repro.core import tree_utils as tu
from repro.serve.arrivals import make_arrivals
from repro.serve.buffer import DoubleBuffer


def staleness_weights(tau: np.ndarray) -> np.ndarray:
    """FedBuff weights over one buffer: ``K * s(τ_i) / Σ_j s(τ_j)`` with
    ``s(τ) = 1/sqrt(1+τ)``. Normalized so a plain mean of the scaled
    candidates equals the FedBuff weighted mean ``Σ_i s_i u_i / Σ_j s_j``;
    all-fresh buffers (τ ≡ 0) give exactly 1."""
    s = 1.0 / np.sqrt(1.0 + tau.astype(np.float64))
    return (len(s) * s / s.sum()).astype(np.float32)


@dataclasses.dataclass
class ServeResult:
    """What a service run hands back (the streaming twin of RunResult)."""
    spec: Any
    history: list                  # one metrics dict per fired round
    state: dict                    # final engine state (params, g, ...)
    stats: dict                    # accepted / rejected / dropped counters
    n_params: int
    wall_s: float
    updates_per_s: float           # accepted ingests per wall second
    fire_latencies_s: list         # per-fire wall latency (sync mode: every
    # fire; free-running: every latency_sample_every-th fire is fenced)
    staleness_hist: dict = dataclasses.field(default_factory=dict)
    # tau -> count over every buffered entry of every fired round
    traces: list = dataclasses.field(default_factory=list)
    # host RoundTrace dicts, one per fired round (spec.trace runs only)

    @property
    def params(self):
        return self.state["params"]

    @property
    def final(self) -> dict:
        return self.history[-1] if self.history else {}

    def latency_percentiles(self) -> dict:
        if not self.fire_latencies_s:
            return {}
        lat = np.asarray(self.fire_latencies_s)
        return {"p50_ms": float(np.percentile(lat, 50) * 1e3),
                "p99_ms": float(np.percentile(lat, 99) * 1e3)}

    def staleness_percentiles(self) -> dict:
        """Percentiles of the per-entry staleness distribution, expanded
        from the histogram ({} before the first fire)."""
        if not self.staleness_hist:
            return {}
        taus = np.repeat([int(t) for t in self.staleness_hist],
                         [int(c) for c in self.staleness_hist.values()])
        return {"staleness_p50": float(np.percentile(taus, 50)),
                "staleness_p90": float(np.percentile(taus, 90)),
                "staleness_worst": int(taus.max())}

    def detection_summary(self, frac: float = 0.5) -> dict:
        from repro.obs import detect
        return detect.summarize(self.traces, frac)

    def to_dict(self) -> dict:
        out = {"spec": self.spec.to_dict(), "n_params": self.n_params,
               "wall_s": self.wall_s, "updates_per_s": self.updates_per_s,
               "stats": dict(self.stats),
               **self.latency_percentiles(),
               **self.staleness_percentiles(),
               "staleness_hist": {str(k): int(v) for k, v in
                                  sorted(self.staleness_hist.items())},
               "history": self.history}
        if self.traces:
            out["detection"] = self.detection_summary()
        return out


class AggregationService:
    """Buffered-async service over an ``api.runner.Experiment``."""

    def __init__(self, spec):
        self.spec = spec
        self.exp = spec.to_run_spec().build()
        self.cfg = self.exp.cfg
        self.est = self.exp.method.estimator
        if self.est.update_params_first or not self.est.streamable:
            raise ValueError(
                f"method {spec.method!r} cannot drive the streaming "
                "service (ServeSpec validates this — hand-built spec?)")
        self.n = spec.n_clients
        self.k = spec.buffer_size
        self._flush_jit = jax.jit(self._flush_impl)
        self._commit_jit = jax.jit(self._commit_impl)
        self._fire_jit = jax.jit(self._fire_impl,
                                 static_argnames=("weighted",))
        self._fire_traced_jit = jax.jit(self._fire_traced_impl,
                                        static_argnames=("weighted",))

    # -- jitted bodies ------------------------------------------------------
    def _flush_impl(self, state, batch, anchor, k_step):
        """One vmapped candidate computation for every client at the
        current version — the engine's own ``estimator.round``, same key
        schedule as api/runner.py. Computed at most once per version
        (keys, batch and params are all pure functions of the version, so
        every dispatch within a version sends the same candidate) and
        committed per-client by ``_commit_impl``."""
        cfg, est = self.cfg, self.est
        batch = engine.maybe_corrupt(cfg, self.exp.corrupt_fn, batch)
        anchor = engine.maybe_corrupt(cfg, self.exp.corrupt_fn, anchor)
        keys = dict(zip(est.rng, jax.random.split(k_step, len(est.rng))))
        ro = est.round(cfg, self.exp.loss_fn, state, state["params"],
                       state["params"], batch, anchor, keys)
        from repro.core import wire
        if isinstance(ro.cand, wire.WireCandidates):
            raise TypeError(
                "the service buffers dense updates, but this "
                "compressor+backend takes the packed wire path; use "
                "agg_mode='gspmd' or a non-wire compressor")
        return ro.cand, dict(ro.updates or {}), ro.loss

    def _commit_impl(self, state, inflight, cand, updates, pending):
        """Commit the cached per-version candidates (and any stacked
        estimator state, e.g. sgdm's worker momenta) on the pending rows
        only — non-pending clients keep their older in-flight updates,
        which is where staleness comes from. Idempotent within a version:
        re-committing a row writes the identical values."""

        def sel(new, old):
            if new.shape[:1] != (self.n,):
                return new                     # non-stacked estimator state
            m = pending.reshape((-1,) + (1,) * (new.ndim - 1))
            return jnp.where(m, new, old)

        new_inflight = (jax.tree.map(sel, cand, inflight)
                        if inflight is not None else cand)
        new_state = dict(state)
        for k, v in updates.items():
            new_state[k] = jax.tree.map(sel, v, state[k])
        return new_state, new_inflight

    def _fire_impl(self, state, buf, byz_mask, weights, k_attack, k_agg,
                   *, weighted):
        """Lines 9-10 over the buffered set + the server param update."""
        cfg = self.cfg
        g = engine.ingest_message_phase(
            cfg, k_attack, k_agg, buf, byz_mask=byz_mask,
            weights=weights if weighted else None)
        new_params, new_opt = engine.param_update(
            cfg, state["params"], g, state["opt_state"])
        new_state = {**state, "params": new_params, "g": g,
                     "opt_state": new_opt, "step": state["step"] + 1}
        return new_state, jnp.sqrt(tu.tree_norm_sq(g))

    def _fire_traced_impl(self, state, buf, byz_mask, weights, k_attack,
                          k_agg, *, weighted):
        """Telemetry twin of ``_fire_impl`` (spec.trace): the identical
        aggregation calls plus the fired round's RoundTrace — influence /
        distances over the BUFFERED entries, byz_mask the per-fire one."""
        from repro.obs import trace as obs_trace
        cfg = self.cfg
        g, rt = obs_trace.traced_ingest_message_phase(
            cfg, k_attack, k_agg, buf, byz_mask=byz_mask,
            weights=weights if weighted else None)
        new_params, new_opt = engine.param_update(
            cfg, state["params"], g, state["opt_state"])
        new_state = {**state, "params": new_params, "g": g,
                     "opt_state": new_opt, "step": state["step"] + 1}
        return new_state, jnp.sqrt(tu.tree_norm_sq(g)), rt

    # -- the service state snapshot (checkpoint payload) --------------------
    def _snapshot(self, state, inflight, svc) -> dict:
        return {
            "engine": state,
            "inflight": inflight,
            "pending": svc["pending"].copy(),
            "disp_version": svc["disp_version"].copy(),
            "last_accepted": svc["last_accepted"].copy(),
            "counters": np.array(
                [svc["cursor"], svc["version"], svc["dropped"],
                 svc["crashed"], svc["hung"]], np.int64),
            "buf_stats": np.array(
                [svc["stats"][k] for k in
                 ("accepted", "rej_replay", "rej_dup_client")], np.int64),
        }

    # -- the event loop -----------------------------------------------------
    def run(self, rounds: Optional[int] = None, *,
            ledger_path: Optional[str] = None,
            checkpoint: Optional[str] = None,
            checkpoint_every: Optional[int] = None,
            resume: Optional[str] = None,
            sync_each_fire: bool = False,
            digest: bool = False,
            stop_after_events: Optional[int] = None,
            max_events: Optional[int] = None,
            sink=None,
            metrics_jsonl: Optional[str] = None,
            latency_sample_every: int = 8,
            verbose: bool = False) -> ServeResult:
        """Drive the service for ``rounds`` fired rounds.

        ``sync_each_fire`` blocks on every fire (per-round latency
        percentiles); off, aggregation overlaps ingestion (throughput) and
        every ``latency_sample_every``-th fire is fenced instead, so
        free-running runs still report sampled latency percentiles (0
        disables sampling).
        ``digest`` adds a sha1 of the post-fire params to each ledger
        record (forces a device sync — tests/audits only).
        ``stop_after_events`` aborts after consuming that many arrival
        events WITHOUT checkpointing — the crash-injection hook for the
        kill-and-resume test. ``resume`` reloads a checkpoint prefix and
        replays the arrival stream from its cursor.
        ``sink`` / ``metrics_jsonl``: a ``repro.obs.sink.MetricSink`` (and/
        or a JSONL stream path). In-loop the service emits only host-side
        events — per-fire buffer-occupancy gauge, per-reason rejection
        counters, spans for fenced fires; the per-round {"type": "round"}
        and {"type": "trace"} events are flushed after the final sync so
        telemetry never forces an extra device fence mid-stream.
        """
        spec = self.spec
        rounds = spec.rounds if rounds is None else int(rounds)
        exp = self.exp
        n, K = self.n, self.k
        own_jsonl = None
        if metrics_jsonl:
            from repro.obs.sink import FanoutSink, JsonlSink
            own_jsonl = JsonlSink(metrics_jsonl)
            sink = (FanoutSink(sink, own_jsonl) if sink is not None
                    else own_jsonl)

        key = jax.random.PRNGKey(spec.seed)
        k_init, k_run = jax.random.split(key)
        params = exp.init_params(k_init)
        n_params = int(tu.tree_size(params))
        state = exp.method.init(params, exp.anchor(0), k_run)

        buffer = DoubleBuffer(K, n)
        svc = {"cursor": 0, "version": 0, "dropped": 0,
               "crashed": 0, "hung": 0,
               "pending": np.ones(n, bool),
               "disp_version": np.zeros(n, np.int64),
               "last_accepted": buffer.last_accepted,
               "stats": buffer.stats}
        inflight = None
        last_loss = jnp.float32(0.0)

        if resume:
            from repro.checkpoint import load_checkpoint
            # inflight rows exist for every client after the first flush,
            # so the template needs concrete (n, ...) candidate arrays
            inflight = tu.tree_broadcast_leading(
                jax.tree.map(lambda a: jnp.zeros_like(a, jnp.float32),
                             params), n)
            snap, _ = load_checkpoint(resume, like=self._snapshot(
                state, inflight, svc))
            state, inflight = snap["engine"], snap["inflight"]
            svc["pending"] = np.array(snap["pending"]).astype(bool)
            svc["disp_version"] = np.array(snap["disp_version"],
                                           dtype=np.int64)
            buffer.last_accepted[:] = np.asarray(snap["last_accepted"])
            cur, ver, dropped, crashed, hung = (int(x) for x in np.asarray(
                snap["counters"]))
            svc.update(cursor=cur, version=ver, dropped=dropped,
                       crashed=crashed, hung=hung)
            for k, v in zip(("accepted", "rej_replay", "rej_dup_client"),
                            np.asarray(snap["buf_stats"])):
                buffer.stats[k] = int(v)
            if verbose:
                print(f"[serve] resumed at round {ver}, cursor {cur}")
        svc["last_accepted"] = buffer.last_accepted

        ledger = None
        if ledger_path:
            from repro.exec.ledger import Ledger
            ledger = Ledger(ledger_path)
        if checkpoint:
            from repro.checkpoint import save_checkpoint

        def k_version(v):
            k_step, k_batch = jax.random.split(
                jax.random.fold_in(k_run, v + 1))
            return k_step, k_batch

        # per-version candidate cache: within one version every dispatch
        # sends the identical candidate (keys/batch/params are functions of
        # the version alone), so the vmapped estimator.round runs at most
        # once per version; later flushes just commit cached rows.
        cache = {"version": -1, "cand": None, "updates": None}

        def flush():
            nonlocal state, inflight, last_loss
            v = svc["version"]
            if cache["version"] != v:
                k_step, k_batch = k_version(v)
                cand, upd, last_loss = self._flush_jit(
                    state, exp.minibatch(v, k_batch), exp.anchor(v), k_step)
                cache.update(version=v, cand=cand, updates=upd)
            # snapshot before the device transfer: the CPU backend may alias
            # host numpy memory, and svc["pending"] is mutated right after
            # while the commit may still be executing asynchronously
            mask = jnp.asarray(np.array(svc["pending"]))
            state, inflight = self._commit_jit(
                state, inflight, cache["cand"], cache["updates"], mask)
            svc["pending"][:] = False

        history: list = []
        fire_lat: list = []
        redispatch: list = []
        stale_hist: dict = {}
        dev_traces: list = []      # device RoundTraces; host-side at the end
        occ_sum = 0
        occ_n = 0

        def _finish(result: "ServeResult") -> "ServeResult":
            """Flush the per-round / trace events (post-sync, so the floats
            exist) and close any sink this call opened."""
            if sink is not None:
                for i, m in enumerate(result.history):
                    sink.emit({"type": "round", **m})
                    if i < len(result.traces):
                        sink.emit({"type": "trace", "round": m["round"],
                                   **result.traces[i]})
                if result.staleness_hist:
                    sink.emit({"type": "gauge", "name": "staleness_hist",
                               "value": {str(k): int(v) for k, v in sorted(
                                   result.staleness_hist.items())}})
            if own_jsonl is not None:
                own_jsonl.close()
            return result

        if svc["version"] >= rounds:       # resumed a finished run
            return _finish(self._result(history, state, buffer, svc,
                                        fire_lat, 0.0, n_params))
        start_cursor = svc["cursor"]
        start_round = svc["version"]
        events = self.arrival_process().events(start=start_cursor)
        budget = (max_events if max_events is not None
                  else 1000 + 200 * max(rounds, 1) * K)
        t0 = time.time()
        stop = False
        prev_t = None

        def end_segment():
            """(Re)dispatch every client whose update resolved in the
            segment that just closed, at the current model version."""
            for c in redispatch:
                svc["pending"][c] = True
                svc["disp_version"][c] = svc["version"]
            redispatch.clear()

        for ev in events:
            if prev_t is not None and ev.t != prev_t:
                end_segment()                      # wave boundary
            prev_t = ev.t
            svc["cursor"] += 1
            if not ev.replay:
                # the client re-dispatches at the end of this segment (a
                # fire, so checkpoints capture it, or the wave boundary)
                redispatch.append(ev.client)
            if ev.dropped or ev.crashed:
                # a crash is observationally a drop: nothing is ingested,
                # the client re-dispatches (with recovery lag already baked
                # into the event timeline). Only the counter differs, which
                # is what keeps the relabeled-trace replay bit-identical.
                svc["dropped" if ev.dropped else "crashed"] += 1
            else:
                if ev.hung:
                    svc["hung"] += 1   # late-but-delivered; ingested normally
                if svc["pending"][ev.client] and \
                        ev.seq > buffer.last_accepted[ev.client] and \
                        not buffer.in_buffer[ev.client]:
                    flush()                        # lazy batched dispatch
                offered = buffer.offer(ev.client, ev.seq,
                                       svc["disp_version"][ev.client],
                                       inflight)
                occ_sum += buffer.count            # occupancy sample per
                occ_n += 1                         # offer (host ints only)
                if offered and buffer.full():
                    if np.any(svc["pending"]):
                        flush()                    # params advance next
                    buf, clients, versions, _ = buffer.swap()
                    r = svc["version"]
                    tau = r - versions
                    byz_mask = jnp.asarray(clients < spec.n_byz)
                    weighted = (spec.staleness == "fedbuff"
                                and bool(np.any(tau > 0)))
                    w = (jnp.asarray(staleness_weights(tau)) if weighted
                         else jnp.zeros(K, jnp.float32))
                    k_step, _ = k_version(r)
                    ks = jax.random.split(k_step, len(self.est.rng))
                    keys = dict(zip(self.est.rng, ks))
                    for t in tau.tolist():
                        stale_hist[int(t)] = stale_hist.get(int(t), 0) + 1
                    # fence this fire? always in sync mode; every Nth fire
                    # in free-running mode (sampled latency percentiles)
                    fence = sync_each_fire or (
                        latency_sample_every and (r - start_round)
                        % max(latency_sample_every, 1) == 0)
                    t_fire = time.perf_counter()
                    if spec.trace:
                        state, g_norm, rt = self._fire_traced_jit(
                            state, buf, byz_mask, w, keys["attack"],
                            keys["agg"], weighted=weighted)
                        dev_traces.append(rt)
                    else:
                        state, g_norm = self._fire_jit(
                            state, buf, byz_mask, w, keys["attack"],
                            keys["agg"], weighted=weighted)
                    if fence:
                        jax.block_until_ready(state["params"])
                        lat = time.perf_counter() - t_fire
                        fire_lat.append(lat)
                        if sink is not None:
                            sink.emit({"type": "span", "name": "fire",
                                       "round": r,
                                       "wall_s": round(lat, 6),
                                       "fenced": True})
                    if sink is not None:
                        sink.emit({"type": "gauge",
                                   "name": "buffer_occupancy",
                                   "round": r,
                                   "value": round(occ_sum / max(occ_n, 1),
                                                  4)})
                        for cname in ("accepted", "rej_replay",
                                      "rej_dup_client"):
                            sink.emit({"type": "counter", "name": cname,
                                       "round": r,
                                       "value": int(buffer.stats[cname])})
                        for cname in ("dropped", "crashed", "hung"):
                            sink.emit({"type": "counter", "name": cname,
                                       "round": r,
                                       "value": int(svc[cname])})
                    occ_sum = 0
                    occ_n = 0
                    svc["version"] = r + 1
                    end_segment()                  # contributors redispatch
                    byz_in_buffer = int((clients < spec.n_byz).sum())
                    # per-fire byzantine fraction over the ACTIVE set (the
                    # buffer), same rule the spec validates against — the
                    # streaming twin of RunSpec's sampled-cohort accounting
                    from repro.core.theory import delta_over_active_set
                    m = {"round": r, "t_virtual": float(ev.t),
                         "loss": last_loss, "g_norm": g_norm,
                         "staleness_mean": float(tau.mean()),
                         "staleness_max": int(tau.max()),
                         "byz_in_buffer": byz_in_buffer,
                         "delta_active": delta_over_active_set(
                             K, byz_in_buffer),
                         "cursor": svc["cursor"]}
                    history.append(m)
                    if ledger is not None:
                        rec = {k: v for k, v in m.items()
                               if k not in ("loss", "g_norm")}
                        rec.update(accepted=buffer.stats["accepted"],
                                   rej_replay=buffer.stats["rej_replay"],
                                   rej_dup_client=buffer.stats
                                   ["rej_dup_client"],
                                   dropped=svc["dropped"],
                                   crashed=svc["crashed"],
                                   hung=svc["hung"],
                                   wall_s=round(time.time() - t0, 4))
                        if digest:
                            rec["params_sha1"] = params_digest(
                                state["params"])
                        ledger.append(f"round-{r:06d}", "fired", **rec)
                    if verbose:
                        print(f"[serve] round {r:4d} t={ev.t:9.3f} "
                              f"stale(mean={tau.mean():.2f} "
                              f"max={int(tau.max())}) "
                              f"byz={m['byz_in_buffer']}/{K}")
                    if checkpoint and checkpoint_every and \
                            (r + 1 - start_round) % checkpoint_every == 0:
                        save_checkpoint(checkpoint, self._snapshot(
                            state, inflight, svc), step=svc["version"])
                    if svc["version"] >= rounds:
                        stop = True
            if stop:
                break
            if stop_after_events is not None and \
                    svc["cursor"] - start_cursor >= stop_after_events:
                # simulated crash: no checkpoint, state as-is
                return _finish(self._result(
                    history, state, buffer, svc, fire_lat,
                    time.time() - t0, n_params, stale_hist=stale_hist,
                    dev_traces=dev_traces))
            if svc["cursor"] - start_cursor > budget:
                raise RuntimeError(
                    f"consumed {svc['cursor'] - start_cursor} events "
                    f"without reaching {rounds} rounds — dropout/duplicate "
                    "chaos too high or buffer_size too large; raise "
                    "max_events to override")
        jax.block_until_ready(state["params"])
        wall = time.time() - t0
        if checkpoint and inflight is not None:
            save_checkpoint(checkpoint, self._snapshot(
                state, inflight, svc), step=svc["version"])
        # history device scalars -> floats, one pass after the final sync
        for m in history:
            m["loss"] = float(m["loss"])
            m["g_norm"] = float(m["g_norm"])
        return _finish(self._result(history, state, buffer, svc, fire_lat,
                                    wall, n_params, stale_hist=stale_hist,
                                    dev_traces=dev_traces))

    def _result(self, history, state, buffer, svc, fire_lat, wall,
                n_params, stale_hist=None, dev_traces=None) -> ServeResult:
        for m in history:
            if not isinstance(m.get("loss"), float):
                m["loss"] = float(m["loss"])
                m["g_norm"] = float(m["g_norm"])
        traces: list = []
        if dev_traces:
            # one host materialization pass, after the final sync — the
            # in-loop fire path never fenced for telemetry
            from repro.obs import detect as obs_detect
            from repro.obs import trace as obs_trace
            for m, rt in zip(history, dev_traces):
                th = obs_trace.to_host(rt)
                det = obs_detect.detection_metrics(th)
                m["detect_precision"] = det["precision"]
                m["detect_recall"] = det["recall"]
                m["byz_leakage"] = det["byz_leakage"]
                m["n_filtered"] = det["n_filtered"]
                traces.append(th)
        stats = {**buffer.stats, "dropped": svc["dropped"],
                 "crashed": svc["crashed"], "hung": svc["hung"],
                 "events": svc["cursor"], "rounds": svc["version"]}
        return ServeResult(
            spec=self.spec, history=history, state=state, stats=stats,
            n_params=n_params, wall_s=wall,
            updates_per_s=buffer.stats["accepted"] / max(wall, 1e-9),
            fire_latencies_s=fire_lat, staleness_hist=stale_hist or {},
            traces=traces)

    def arrival_process(self):
        return make_arrivals(self.spec)


def params_digest(params) -> str:
    """sha1 over the raw bytes of every leaf, in tree order (a device
    sync; used by the ledger's audit trail and the resume tests)."""
    h = hashlib.sha1()
    for leaf in jax.tree.leaves(params):
        h.update(np.asarray(jax.device_get(leaf)).tobytes())
    return h.hexdigest()
