"""Unit tests for the Byzantine attack implementations (Sec. 3)."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core.attacks import get_attack
from repro.core.byz_vr_marina import ByzVRMarinaConfig, apply_attack
from repro.core.aggregators import get_aggregator

KEY = jax.random.PRNGKey(0)


def test_bit_flip():
    atk = get_attack("BF")
    h = jax.random.normal(KEY, (4, 7))
    out = atk.apply(KEY, h, h.mean(0), h.std(0))
    np.testing.assert_allclose(np.asarray(out), -np.asarray(h))


def test_alie_formula():
    atk = get_attack("ALIE", z=1.5)
    h = jax.random.normal(KEY, (4, 7))
    m, s = h.mean(0), h.std(0)
    out = atk.apply(KEY, h, m, s)
    np.testing.assert_allclose(np.asarray(out[0]), np.asarray(m - 1.5 * s),
                               rtol=1e-5)
    # all byzantine rows identical (coordinated attack)
    assert jnp.all(out[0] == out[1])


def test_ipm_formula():
    atk = get_attack("IPM", eps=0.4)
    h = jax.random.normal(KEY, (4, 7))
    m = h.mean(0)
    out = atk.apply(KEY, h, m, h.std(0))
    np.testing.assert_allclose(np.asarray(out[2]), -0.4 * np.asarray(m),
                               rtol=1e-5)


def test_label_flip_is_data_level():
    atk = get_attack("LF")
    assert atk.flips_labels
    h = jax.random.normal(KEY, (4, 7))
    out = atk.apply(KEY, h, h.mean(0), h.std(0))
    np.testing.assert_allclose(np.asarray(out), np.asarray(h))


def test_apply_attack_only_touches_byzantines():
    cfg = ByzVRMarinaConfig(n_workers=6, n_byz=2, attack=get_attack("BF"),
                            aggregator=get_aggregator("cm"))
    cand = {"w": jax.random.normal(KEY, (6, 5))}
    sent = apply_attack(cfg, KEY, cand)
    np.testing.assert_allclose(np.asarray(sent["w"][:2]),
                               -np.asarray(cand["w"][:2]))
    np.testing.assert_allclose(np.asarray(sent["w"][2:]),
                               np.asarray(cand["w"][2:]))


def test_alie_uses_good_stats_only():
    """Omniscient stats must exclude the byzantine rows themselves."""
    cfg = ByzVRMarinaConfig(n_workers=5, n_byz=1,
                            attack=get_attack("ALIE", z=0.0),
                            aggregator=get_aggregator("cm"))
    cand = {"w": jnp.concatenate([1e6 * jnp.ones((1, 3)),
                                  jnp.ones((4, 3))])}
    sent = apply_attack(cfg, KEY, cand)
    # z=0 => byzantine sends the GOOD mean = 1.0, not polluted by its 1e6 row
    np.testing.assert_allclose(np.asarray(sent["w"][0]), np.ones(3),
                               rtol=1e-5)
