"""mistral-large-123b [dense].

88L d_model=12288 96H (GQA kv=8) d_ff=28672 vocab=32768
[hf:mistralai/Mistral-Large-Instruct-2407]
"""
from repro.configs.base import ArchConfig, ATTN, register

CONFIG = register(ArchConfig(
    name="mistral-large-123b",
    family="dense",
    citation="hf:mistralai/Mistral-Large-Instruct-2407",
    num_layers=88,
    d_model=12288,
    num_heads=96,
    num_kv_heads=8,
    d_ff=28672,
    vocab_size=32_768,
    head_dim=128,
    block_pattern=(ATTN,),
    rope_theta=1_000_000.0,
))
