"""repro.faults — seeded fault injection + graceful degradation.

Three layers (DESIGN §6):

* ``plan``   — the ``FaultPlan`` registry/schedule (static, JSON-able).
* ``inject`` — deterministic message-site injection (dense rows, wire
               bit-flips), replayable from ``(plan, attack_key)``.
* ``guard``  — fail-closed validity masks + masked bucketing shared by
               the gspmd oracle and the pallas kernels.

Process-site faults (crash / hang) are consumed by ``exec.scheduler`` /
``exec.worker`` and ``serve.arrivals`` rather than injected here.
"""
from repro.faults.plan import (FAULTS, MESSAGE_FAULTS, PROCESS_FAULTS,
                               TENSOR_FAULTS, WIRE_FAULTS, FaultPlan,
                               FaultSpec, as_plan)
from repro.faults import guard, inject  # noqa: F401

__all__ = ["FAULTS", "MESSAGE_FAULTS", "PROCESS_FAULTS", "TENSOR_FAULTS",
           "WIRE_FAULTS", "FaultPlan", "FaultSpec", "as_plan", "guard",
           "inject"]
