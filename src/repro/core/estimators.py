"""Gradient estimators pluggable into the round engine (DESIGN.md §2).

Each estimator owns exactly what distinguishes its method from the others:
its per-worker candidate computation, any extra worker/server state, and its
communication cost. Everything else — parameter update, data corruption,
omniscient attacks, (δ,c)-robust aggregation, metrics — is the engine's.

  marina — Byz-VR-MARINA (Alg. 1): the paper's contribution. Geometric coin
           switches anchor full-gradients and compressed SARAH differences
           g^k + Q(∇f(x^{k+1}) - ∇f(x^k)). With agg_mode="sparse_support"
           and common-randomness RandK, the VR round attacks + aggregates
           only the shared K-coordinate support.
  sgd    — Parallel-SGD with (robust) averaging (Zinkevich et al. 2010).
  sgdm   — BR-SGDm: worker momenta are attacked & aggregated (Karimireddy
           et al. 2021/22).
  csgd   — compressed SGD; with a robust aggregator = BR-CSGD.
  diana  — BR-DIANA: worker shifts h_i, uploads Q(g_i - h_i) (Mishchenko et
           al. 2019 + robust aggregation).
  mvr    — BR-MVR / STORM momentum variance reduction (Karimireddy 2021).
  svrg   — Byrd-SVRG (loopless; App. B.4 proxy of Byrd-SAGA, Wu et al. 2020).

Successor methods over the same engine (ROADMAP "New estimators"):

  byz_ef21 — Byz-EF21 (Rammal et al. 2023): biased/contractive compressors
             + per-worker error feedback; every upload is one compressed
             difference, the EF state absorbs the compressor bias.
  cmfilter — compressed momentum filtering (Liu et al. 2024): worker
             momenta uploaded as compressed differences against a
             server-mirrored reconstruction; the robust aggregator is the
             filter, optionally blended by a server-side momentum.
  saga     — Byrd-SAGA (Wu et al. 2020) fitted to the stacked
             corrupt→attack→aggregate protocol: per-worker per-sample
             gradient table over the anchor partition. Tables are worker
             state, not wire traffic, and do NOT vmap over seeds
             (``seed_batchable = False`` routes sweeps down the serial /
             WorkerPool path — see exec/batching.can_batch).

Every entry must pass tests/test_estimator_contract.py (the conformance
harness): checkpoint round-trip, run(spec) ≡ hand-wired engine, comm
accounting ≡ theory.comm_bits_per_round, descent, pallas ≡ gspmd.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
from jax import lax

from repro.core import tree_utils as tu
from repro.core.engine import (GradientEstimator, RoundOutput,
                               apply_attack, message_phase,
                               phase_with_trace, stacked_grads)


def _zeros_like_f32(params):
    return jax.tree.map(lambda x: jnp.zeros_like(x, jnp.float32), params)


class CompressedUploadBits:
    """Comm accounting for estimators whose every upload is Q(·)."""

    def round_bits(self, cfg, d, full_round=True):
        return int(cfg.compressor.bits_per_vector(d))

    def expected_bits(self, cfg, d):
        return float(cfg.compressor.bits_per_vector(d))


# ---------------------------------------------------------------------------
# Byz-VR-MARINA
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class MarinaEstimator(GradientEstimator):
    """Alg. 1 (lines 4-10): c_k ~ Be(p) picks anchor full-gradients or the
    compressed variance-reduced difference estimator."""
    name = "marina"
    rng = ("bern", "grad", "q", "attack", "agg")
    update_params_first = True

    def init_extras(self, cfg, loss_fn, params, anchor, key):
        # paper: g^0 = ARAgg(∇f_1(x^0), ..., ∇f_n(x^0))
        k_grad, k_attack, k_agg = jax.random.split(key, 3)
        wkeys = tu.per_worker_keys(k_grad, cfg.n_workers)
        _, grads = stacked_grads(loss_fn, params, anchor, wkeys)
        return message_phase(cfg, k_attack, k_agg, grads), {}

    def round(self, cfg, loss_fn, state, params, old_params, batch, anchor,
              keys):
        from repro.core import wire

        n = cfg.n_workers
        c_k = jax.random.bernoulli(keys["bern"], cfg.p)
        wkeys = tu.per_worker_keys(keys["grad"], n)

        # branch-local message phases (lax.cond branches must return one
        # pytree structure, and the VR branch's wire payload has none of the
        # full branch's dense shape): each branch attacks + aggregates with
        # the SAME keys the engine would have used, so trajectories are
        # unchanged vs. the engine-side phase. phase_with_trace lets the
        # telemetry twin's RoundTrace escape the cond (both branches build
        # the same trace structure); on the untraced step it IS
        # message_phase and the None slot adds nothing to the jaxpr.
        def full_branch(_):
            loss, grads = stacked_grads(loss_fn, params, anchor, wkeys)
            g, rt = phase_with_trace(cfg, keys["attack"], keys["agg"],
                                     grads)
            return loss, g, rt

        def vr_branch(_):
            qkeys = tu.per_worker_keys(
                keys["q"], n, common=cfg.compressor.common_randomness)

            def one(b, kg):
                ln, gn = jax.value_and_grad(loss_fn)(params, b, kg)
                _, go = jax.value_and_grad(loss_fn)(old_params, b, kg)
                return ln, tu.tree_sub(gn, go)

            losses, deltas = jax.vmap(one)(batch, wkeys)
            loss = jnp.mean(losses)
            if wire.wire_supported(cfg, deltas):
                # candidate = g^k + Q(delta): g^k rides as the SHARED (1, d)
                # reconstruction base, Q(delta) as the wire payload.
                wc = wire.pack_candidates(cfg.compressor, qkeys, deltas,
                                          base=state["g"], base_shared=True)
                g, rt = phase_with_trace(cfg, keys["attack"], keys["agg"],
                                         wc)
                return loss, g, rt
            qs = jax.vmap(
                lambda kq, t: tu.compress_tree(cfg.compressor, kq, t)
            )(qkeys, deltas)
            cand = jax.tree.map(lambda g0, q: g0[None] + q, state["g"], qs)
            g, rt = phase_with_trace(cfg, keys["attack"], keys["agg"],
                                     cand)
            return loss, g, rt

        loss, g_new, rt = lax.cond(c_k, full_branch, vr_branch, operand=None)
        dims = [int(p.size) for p in jax.tree.leaves(params)]
        vr_bits = wire.tree_wire_bits(
            cfg.compressor,
            jax.tree.map(lambda p: p[None], params))
        wire_bits = jnp.where(c_k, jnp.float32(32.0 * sum(dims)),
                              jnp.float32(vr_bits))
        return RoundOutput(loss=loss, g_new=g_new, trace=rt,
                           metrics={"c_k": c_k.astype(jnp.int32),
                                    "wire_bits": wire_bits})

    def round_bits(self, cfg, d, full_round=True):
        if full_round:
            return 32 * d
        return int(cfg.compressor.bits_per_vector(d))

    def expected_bits(self, cfg, d):
        return (cfg.p * 32 * d
                + (1 - cfg.p) * cfg.compressor.bits_per_vector(d))


@dataclasses.dataclass
class MarinaSparseEstimator(MarinaEstimator):
    """§Perf sparse-support variant: common-randomness RandK means every
    worker sends the SAME K coordinates, so only the K-sized support is
    attacked, gathered, and aggregated; off-support coordinates keep g^k
    exactly (the paper's own remark: the server bans senders outside the
    agreed support). Owns its whole message phase, so attack + aggregation
    live inside the c_k branches."""
    name = "marina_sparse"

    def round(self, cfg, loss_fn, state, params, old_params, batch, anchor,
              keys):
        from repro.core.compressors import unit_partition

        n = cfg.n_workers
        ratio = cfg.compressor.ratio   # validated by _marina_factory
        c_k = jax.random.bernoulli(keys["bern"], cfg.p)
        wkeys = tu.per_worker_keys(keys["grad"], n)

        def support_take(leaf_flat, idx, blk, d):
            pad = (-d) % blk
            xf = jnp.pad(leaf_flat, (0, pad)).reshape(-1, blk)
            return xf[idx]                               # (k_units, blk)

        def support_put(leaf, idx, blk, vals):
            d = leaf.size
            pad = (-d) % blk
            xf = jnp.pad(leaf.reshape(-1).astype(jnp.float32), (0, pad))
            xf = xf.reshape(-1, blk).at[idx].set(vals)
            return xf.reshape(-1)[:d].reshape(leaf.shape).astype(leaf.dtype)

        def full_branch(_):
            loss, grads = stacked_grads(loss_fn, params, anchor, wkeys)
            sent = apply_attack(cfg, keys["attack"], grads)
            return loss, cfg.aggregator.tree(keys["agg"], sent)

        def sparse_branch(_):
            # shared per-leaf supports (same key for every worker)
            g_leaves, treedef = jax.tree.flatten(state["g"])
            meta = []
            for i, gl in enumerate(g_leaves):
                d = gl.size
                blk, n_units = unit_partition(d)
                k_units = max(int(ratio * n_units), 1)
                kk = jax.random.fold_in(keys["q"], i)
                idx = jax.random.permutation(kk, n_units)[:k_units]
                meta.append((blk, n_units, k_units, idx,
                             n_units / k_units, d))

            def one(b, kg):
                ln, gn = jax.value_and_grad(loss_fn)(params, b, kg)
                _, go = jax.value_and_grad(loss_fn)(old_params, b, kg)
                delta = tu.tree_sub(gn, go)
                d_leaves = jax.tree.leaves(delta)
                vals = []
                for (blk, nu, ku, idx, scale, d), dl in zip(meta, d_leaves):
                    v = support_take(dl.reshape(-1).astype(jnp.float32),
                                     idx, blk, d) * scale
                    vals.append(v)
                return ln, tuple(vals)

            losses, dvals = jax.vmap(one)(batch, wkeys)
            # candidates on the support: g^k|support + scaled delta
            cand = []
            for (blk, nu, ku, idx, scale, d), gl, dv in zip(
                    meta, g_leaves, dvals):
                base = support_take(gl.reshape(-1).astype(jnp.float32),
                                    idx, blk, d)
                cand.append(base[None] + dv)
            sent = apply_attack(cfg, keys["attack"], tuple(cand))
            agg_vals = cfg.aggregator.tree(keys["agg"], sent)
            new_leaves = [support_put(gl, m[3], m[0], av)
                          for m, gl, av in zip(meta, g_leaves, agg_vals)]
            return jnp.mean(losses), jax.tree.unflatten(treedef, new_leaves)

        loss, g_new = lax.cond(c_k, full_branch, sparse_branch, operand=None)
        return RoundOutput(loss=loss, g_new=g_new,
                           metrics={"c_k": c_k.astype(jnp.int32)})


# ---------------------------------------------------------------------------
# SGD / BR-SGDm
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SGDEstimator(GradientEstimator):
    """momentum=0 -> Parallel-SGD; momentum>0 -> BR-SGDm (worker momenta are
    what gets attacked & aggregated, per Karimireddy et al. 2021)."""
    momentum: float = 0.0
    name = "sgd"
    rng = ("grad", "attack", "agg")
    streamable = True       # per-client grads/momenta: serve can buffer them

    def init_extras(self, cfg, loss_fn, params, anchor, key):
        g0 = (_zeros_like_f32(params) if self.momentum > 0.0
              else tu.tree_zeros_like(params))
        return g0, {"worker_m": tu.tree_broadcast_leading(
            _zeros_like_f32(params), cfg.n_workers)}

    def round(self, cfg, loss_fn, state, params, old_params, batch, anchor,
              keys):
        wkeys = tu.per_worker_keys(keys["grad"], cfg.n_workers)
        loss, grads = stacked_grads(loss_fn, params, batch, wkeys)
        if self.momentum > 0.0:
            m_new = jax.tree.map(
                lambda m, g: ((1 - self.momentum) * g.astype(jnp.float32)
                              + self.momentum * m.astype(jnp.float32)),
                state["worker_m"], grads)
            cand = m_new
        else:
            m_new = state["worker_m"]
            cand = grads
        return RoundOutput(loss=loss, cand=cand,
                           updates={"worker_m": m_new})


# ---------------------------------------------------------------------------
# CSGD / BR-CSGD
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CSGDEstimator(CompressedUploadBits, GradientEstimator):
    name = "csgd"
    rng = ("grad", "q", "attack", "agg")
    streamable = True       # Q(grad_i) is still a pure per-client function

    def init_extras(self, cfg, loss_fn, params, anchor, key):
        return tu.tree_zeros_like(params), {}

    def round(self, cfg, loss_fn, state, params, old_params, batch, anchor,
              keys):
        from repro.core import wire

        n = cfg.n_workers
        wkeys = tu.per_worker_keys(keys["grad"], n)
        qkeys = tu.per_worker_keys(keys["q"], n,
                                   common=cfg.compressor.common_randomness)
        losses, grads = stacked_grads(loss_fn, params, batch, wkeys)
        metrics = {"wire_bits": jnp.float32(
            wire.tree_wire_bits(cfg.compressor, grads))}
        if wire.wire_supported(cfg, grads):
            cand = wire.pack_candidates(cfg.compressor, qkeys, grads)
        else:
            cand = jax.vmap(
                lambda kq, g: tu.compress_tree(cfg.compressor, kq, g)
            )(qkeys, grads)
        return RoundOutput(loss=losses, cand=cand, metrics=metrics)


# ---------------------------------------------------------------------------
# BR-DIANA
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class DianaEstimator(CompressedUploadBits, GradientEstimator):
    """DIANA: worker i keeps a shift h_i, uploads Q(g_i - h_i); the server
    adds the aggregated compressed difference to the shift mean. alpha
    defaults to 1/(1+omega) (Mishchenko et al. 2019)."""
    alpha: Optional[float] = None
    d_hint: Optional[int] = None
    name = "diana"
    rng = ("grad", "q", "attack", "agg")

    def init_extras(self, cfg, loss_fn, params, anchor, key):
        d = int(self.d_hint if self.d_hint is not None
                else tu.tree_size(params))
        omega = cfg.compressor.omega(d)
        a = self.alpha if self.alpha is not None else 1.0 / (1.0 + omega)
        extras = {
            "worker_h": tu.tree_broadcast_leading(_zeros_like_f32(params),
                                                  cfg.n_workers),
            "alpha": jnp.asarray(a, jnp.float32),
        }
        return _zeros_like_f32(params), extras

    def round(self, cfg, loss_fn, state, params, old_params, batch, anchor,
              keys):
        n = cfg.n_workers
        wkeys = tu.per_worker_keys(keys["grad"], n)
        qkeys = tu.per_worker_keys(keys["q"], n,
                                   common=cfg.compressor.common_randomness)
        h = state["worker_h"]                              # stacked (n, ...)
        a = state["alpha"]

        from repro.core import wire

        def one(b, kg, h_i):
            ln, g = jax.value_and_grad(loss_fn)(params, b, kg)
            return ln, tu.tree_sub(g, h_i)

        losses, diffs = jax.vmap(one)(batch, wkeys, h)
        metrics = {"wire_bits": jnp.float32(
            wire.tree_wire_bits(cfg.compressor, diffs))}
        if wire.wire_supported(cfg, diffs):
            cand = wire.pack_candidates(cfg.compressor, qkeys, diffs)
            qdiff = wire.decoded_payload(cand)   # ≡ vmap(compress_tree)
        else:
            cand = qdiff = jax.vmap(
                lambda kq, t: tu.compress_tree(cfg.compressor, kq, t)
            )(qkeys, diffs)
        h_mean = jax.tree.map(lambda x: jnp.mean(x, axis=0), h)
        h_new = jax.tree.map(lambda hh, q: hh + a * q, h, qdiff)

        def finalize(agg_diff):
            return tu.tree_add(h_mean, agg_diff), {"worker_h": h_new}

        return RoundOutput(loss=jnp.mean(losses), cand=cand,
                           finalize=finalize, metrics=metrics)


# ---------------------------------------------------------------------------
# BR-MVR (STORM)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class MVREstimator(GradientEstimator):
    """BR-MVR (Karimireddy et al. 2021): momentum variance reduction
    (STORM/MVR estimator) per worker + robust aggregation.

        v_i^k = g_i(x^k) + (1-α)(v_i^{k-1} - g_i(x^{k-1}))
    """
    alpha: float = 0.1
    name = "mvr"
    rng = ("grad", "attack", "agg")

    def init_extras(self, cfg, loss_fn, params, anchor, key):
        wkeys = tu.per_worker_keys(key, cfg.n_workers)
        _, grads = stacked_grads(loss_fn, params, anchor, wkeys)
        v0 = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        return _zeros_like_f32(params), {"prev_params": params,
                                         "worker_v": v0}

    def round(self, cfg, loss_fn, state, params, old_params, batch, anchor,
              keys):
        wkeys = tu.per_worker_keys(keys["grad"], cfg.n_workers)
        prev = state["prev_params"]
        alpha = self.alpha

        def one(b, kg, v_i):
            ln, gx = jax.value_and_grad(loss_fn)(params, b, kg)
            _, gp = jax.value_and_grad(loss_fn)(prev, b, kg)
            v_new = jax.tree.map(
                lambda g, vv, go: g.astype(jnp.float32)
                + (1 - alpha) * (vv - go.astype(jnp.float32)),
                gx, v_i, gp)
            return ln, v_new

        losses, v = jax.vmap(one)(batch, wkeys, state["worker_v"])
        return RoundOutput(loss=jnp.mean(losses), cand=v,
                           updates={"prev_params": params, "worker_v": v})


# ---------------------------------------------------------------------------
# Byrd-SVRG (App. B.4)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SVRGEstimator(GradientEstimator):
    """Loopless SVRG: with prob p refresh the snapshot w <- x and the full
    worker gradients; each round worker i sends
    v_i = g_i(x, mb) - g_i(w, mb) + full_i, aggregated with RFA (geometric
    median) per Wu et al. (2020)."""
    name = "svrg"
    rng = ("bern", "grad", "attack", "agg")

    def init_extras(self, cfg, loss_fn, params, anchor, key):
        wkeys = tu.per_worker_keys(key, cfg.n_workers)
        _, fulls = stacked_grads(loss_fn, params, anchor, wkeys)
        return tu.tree_zeros_like(params), {"snapshot": params,
                                            "worker_full": fulls}

    def round(self, cfg, loss_fn, state, params, old_params, batch, anchor,
              keys):
        c_k = jax.random.bernoulli(keys["bern"], cfg.p)
        wkeys = tu.per_worker_keys(keys["grad"], cfg.n_workers)

        def refresh(_):
            _, fulls = stacked_grads(loss_fn, params, anchor, wkeys)
            return params, fulls

        def keep(_):
            return state["snapshot"], state["worker_full"]

        w, fulls = lax.cond(c_k, refresh, keep, operand=None)

        def one(b, kg, full_i):
            ln, gx = jax.value_and_grad(loss_fn)(params, b, kg)
            _, gw = jax.value_and_grad(loss_fn)(w, b, kg)
            return ln, tu.tree_add(tu.tree_sub(gx, gw), full_i)

        losses, cand = jax.vmap(one)(batch, wkeys, fulls)
        return RoundOutput(loss=jnp.mean(losses), cand=cand,
                           updates={"snapshot": w, "worker_full": fulls})


# ---------------------------------------------------------------------------
# Byz-EF21 (Rammal et al. 2023)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class ByzEF21Estimator(CompressedUploadBits, GradientEstimator):
    """Byz-EF21: biased contractive compression + per-worker error feedback.

    Worker i maintains an estimate g_i of its local gradient; each round it
    uploads the compressed correction c_i = C(∇f_i(x^{k+1}) - g_i) and both
    sides update g_i <- g_i + c_i. The server robust-aggregates the
    reconstructed g_i — a Byzantine sender of arbitrary c_i is exactly an
    attack on its candidate g_i + c_i, so the engine's message phase models
    the adversary faithfully. Gradients are taken on the anchor set (the
    paper's deterministic Byz-EF21; the stochastic variant is cmfilter's
    momentum territory).

    EF21's contraction argument needs E||C(x)-x||² <= δ_C ||x||² with
    δ_C < 1 (``Compressor.contractive_delta``) — the factory rejects
    compressors without a contractive bound, since unbiasedness scaling
    (RandK's d/K) breaks the error-feedback recursion.
    """
    name = "byz_ef21"
    rng = ("grad", "q", "attack", "agg")
    update_params_first = True
    needs_contractive = True

    def init_extras(self, cfg, loss_fn, params, anchor, key):
        # g_i^0 = ∇f_i(x^0) (uncompressed init, as in EF21), then
        # g^0 = ARAgg(g_1^0, ..., g_n^0) like every other estimator here.
        k_grad, k_attack, k_agg = jax.random.split(key, 3)
        wkeys = tu.per_worker_keys(k_grad, cfg.n_workers)
        _, grads = stacked_grads(loss_fn, params, anchor, wkeys)
        g_i = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        return message_phase(cfg, k_attack, k_agg, g_i), {"worker_g": g_i}

    def round(self, cfg, loss_fn, state, params, old_params, batch, anchor,
              keys):
        n = cfg.n_workers
        wkeys = tu.per_worker_keys(keys["grad"], n)
        qkeys = tu.per_worker_keys(keys["q"], n,
                                   common=cfg.compressor.common_randomness)

        from repro.core import wire

        def one(b, kg, g_i):
            ln, g = jax.value_and_grad(loss_fn)(params, b, kg)
            return ln, jax.tree.map(lambda a, gi: a.astype(jnp.float32) - gi,
                                    g, g_i)

        losses, diffs = jax.vmap(one)(anchor, wkeys, state["worker_g"])
        metrics = {"wire_bits": jnp.float32(
            wire.tree_wire_bits(cfg.compressor, diffs))}
        if wire.wire_supported(cfg, diffs):
            cand = wire.pack_candidates(cfg.compressor, qkeys, diffs,
                                        base=state["worker_g"])
            c = wire.decoded_payload(cand)
            g_new = tu.tree_add(state["worker_g"], c)
        else:
            c = jax.vmap(
                lambda kq, t: tu.compress_tree(cfg.compressor, kq, t)
            )(qkeys, diffs)
            cand = g_new = tu.tree_add(state["worker_g"], c)
        return RoundOutput(loss=jnp.mean(losses), cand=cand,
                           updates={"worker_g": g_new}, metrics=metrics)


# ---------------------------------------------------------------------------
# compressed momentum filtering (Liu et al. 2024)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class CMFilterEstimator(CompressedUploadBits, GradientEstimator):
    """Compressed momentum filtering: worker i keeps a momentum
    m_i = (1-β) g_i(x^k) + β m_i and a server-mirrored reconstruction u_i,
    uploading only the compressed momentum difference Q(m_i - u_i); both
    sides update u_i <- u_i + Q(m_i - u_i). The robust aggregator IS the
    filter — it sees the reconstructed momenta u_i (what Byzantines can
    steer by sending arbitrary differences), and an optional server
    momentum η blends the filtered aggregate into the previous server
    direction g^k (the "server + worker momentum" of Liu et al. 2024)."""
    momentum: float = 0.9          # worker-side β
    server_momentum: float = 0.0   # server-side η (0 = plain filtering)
    name = "cmfilter"
    rng = ("grad", "q", "attack", "agg")

    def init_extras(self, cfg, loss_fn, params, anchor, key):
        z = _zeros_like_f32(params)
        zn = tu.tree_broadcast_leading(z, cfg.n_workers)
        return z, {"worker_m": zn, "worker_u": zn}

    def round(self, cfg, loss_fn, state, params, old_params, batch, anchor,
              keys):
        n = cfg.n_workers
        beta = self.momentum
        eta = self.server_momentum
        wkeys = tu.per_worker_keys(keys["grad"], n)
        qkeys = tu.per_worker_keys(keys["q"], n,
                                   common=cfg.compressor.common_randomness)

        from repro.core import wire

        def one(b, kg, m_i, u_i):
            ln, g = jax.value_and_grad(loss_fn)(params, b, kg)
            m_new = jax.tree.map(
                lambda gg, mm: (1 - beta) * gg.astype(jnp.float32)
                + beta * mm, g, m_i)
            return ln, m_new, tu.tree_sub(m_new, u_i)

        losses, m_new, diffs = jax.vmap(one)(batch, wkeys,
                                             state["worker_m"],
                                             state["worker_u"])
        metrics = {"wire_bits": jnp.float32(
            wire.tree_wire_bits(cfg.compressor, diffs))}
        if wire.wire_supported(cfg, diffs):
            cand = wire.pack_candidates(cfg.compressor, qkeys, diffs,
                                        base=state["worker_u"])
            q = wire.decoded_payload(cand)
            u_new = tu.tree_add(state["worker_u"], q)
        else:
            q = jax.vmap(
                lambda kq, t: tu.compress_tree(cfg.compressor, kq, t)
            )(qkeys, diffs)
            cand = u_new = tu.tree_add(state["worker_u"], q)
        g_prev = state["g"]

        def finalize(agg):
            g = jax.tree.map(
                lambda a, gp: (1 - eta) * a.astype(jnp.float32)
                + eta * gp.astype(jnp.float32), agg, g_prev)
            return g, {"worker_m": m_new, "worker_u": u_new}

        return RoundOutput(loss=jnp.mean(losses), cand=cand,
                           finalize=finalize, metrics=metrics)


# ---------------------------------------------------------------------------
# Byrd-SAGA over the stacked protocol (Wu et al. 2020)
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SAGAEstimator(GradientEstimator):
    """SAGA fitted to the stacked corrupt→attack→aggregate protocol: worker
    i keeps a per-sample gradient table over ITS slice of the anchor
    partition (the per-worker dataset) plus the table mean, and each round
    sends the SAGA estimate

        v_i = mean_j[ ∇f_{i,j}(x) - table_i[j] ] + mean(table_i)

    over freshly (without-replacement) sampled indices j; the candidates go
    through the engine's attack + robust aggregation unchanged. The table
    lives on the worker — it never hits the wire (``round_bits`` stays the
    dense 32d) — but it IS estimator state, so it rides the engine state
    dict through checkpoints and resume.

    REQUIRES a fixed anchor: table slot j corresponds to anchor sample j
    across rounds, so the driver must pass the same anchor every round
    (the logreg task's full per-worker dataset does; the lm TokenStream
    resamples per round, and ``RunSpec`` rejects that pairing eagerly).

    ``seed_batchable = False``: vmapping a sweep over seeds would stack the
    (n, m, d) tables into (seeds, n, m, d) — a silent memory blow-up on
    anything beyond toy problems — so exec/batching routes SAGA cells down
    the serial / WorkerPool path instead.
    """
    batch_size: int = 16
    name = "saga"
    rng = ("grad", "attack", "agg")
    seed_batchable = False

    def init_extras(self, cfg, loss_fn, params, anchor, key):
        n = cfg.n_workers
        m = jax.tree.leaves(anchor)[0].shape[1]   # per-worker sample count

        def table_leaf(p):
            return jnp.zeros((n, m) + p.shape, jnp.float32)

        return tu.tree_zeros_like(params), {
            "worker_table": jax.tree.map(table_leaf, params),
            "worker_table_mean": tu.tree_broadcast_leading(
                _zeros_like_f32(params), n),
        }

    def round(self, cfg, loss_fn, state, params, old_params, batch, anchor,
              keys):
        table = state["worker_table"]
        m = jax.tree.leaves(table)[0].shape[1]
        b = min(int(self.batch_size), m)
        wkeys = tu.per_worker_keys(keys["grad"], cfg.n_workers)

        def one(anchor_i, kg, table_i, mean_i):
            k_idx, k_loss = jax.random.split(kg)
            idx = jax.random.permutation(k_idx, m)[:b]   # w/o replacement

            def g_of(j):
                sample = jax.tree.map(lambda a: a[j][None], anchor_i)
                return jax.value_and_grad(loss_fn)(params, sample, k_loss)

            losses, g_new = jax.vmap(g_of)(idx)                  # (b, ...)
            g_new = jax.tree.map(lambda g: g.astype(jnp.float32), g_new)
            old = jax.tree.map(lambda t: t[idx], table_i)        # (b, ...)
            v = jax.tree.map(
                lambda gn, go, tm: jnp.mean(gn - go, axis=0) + tm,
                g_new, old, mean_i)
            new_table = jax.tree.map(lambda t, gn: t.at[idx].set(gn),
                                     table_i, g_new)
            new_mean = jax.tree.map(
                lambda tm, go, gn: tm + jnp.sum(gn - go, axis=0) / m,
                mean_i, old, g_new)
            return jnp.mean(losses), v, new_table, new_mean

        losses, v, tables, means = jax.vmap(one)(
            anchor, wkeys, table, state["worker_table_mean"])
        return RoundOutput(loss=jnp.mean(losses), cand=v,
                           updates={"worker_table": tables,
                                    "worker_table_mean": means})


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------

def _marina_factory(cfg, **kw):
    if cfg.agg_mode == "sparse_support":
        comp = cfg.compressor
        if not (comp.common_randomness and comp.ratio is not None):
            raise ValueError(
                "agg_mode='sparse_support' needs a common-randomness RandK "
                f"compressor, got {comp.name!r}")
        return MarinaSparseEstimator(**kw)
    return MarinaEstimator(**kw)


def _ef21_factory(cfg, **kw):
    if cfg.compressor.contractive_fn is None:
        raise ValueError(
            "byz_ef21 needs a contractive compressor (topk / sign / "
            "identity — Compressor.contractive_delta must be defined): the "
            "EF21 recursion contracts the error-feedback state, and "
            f"unbiasedness scaling breaks it; got {cfg.compressor.name!r}")
    return ByzEF21Estimator(**kw)


ESTIMATORS = {
    "marina": _marina_factory,
    "sgd": lambda cfg, **kw: SGDEstimator(momentum=kw.pop("momentum", 0.0),
                                          **kw),
    "sgdm": lambda cfg, **kw: SGDEstimator(momentum=kw.pop("momentum", 0.9),
                                           **kw),
    "csgd": lambda cfg, **kw: CSGDEstimator(**kw),
    "diana": lambda cfg, **kw: DianaEstimator(**kw),
    "mvr": lambda cfg, **kw: MVREstimator(**kw),
    "svrg": lambda cfg, **kw: SVRGEstimator(**kw),
    "byz_ef21": _ef21_factory,
    "cmfilter": lambda cfg, **kw: CMFilterEstimator(**kw),
    "saga": lambda cfg, **kw: SAGAEstimator(**kw),
}

# trait view for code that must answer questions about a method WITHOUT a
# cfg in hand (exec/batching.can_batch classifies cells before building
# anything); the sparse MARINA variant shares MarinaEstimator's traits.
ESTIMATOR_CLASSES = {
    "marina": MarinaEstimator,
    "sgd": SGDEstimator,
    "sgdm": SGDEstimator,
    "csgd": CSGDEstimator,
    "diana": DianaEstimator,
    "mvr": MVREstimator,
    "svrg": SVRGEstimator,
    "byz_ef21": ByzEF21Estimator,
    "cmfilter": CMFilterEstimator,
    "saga": SAGAEstimator,
}


def needs_contractive_compressor(name: str) -> bool:
    """Whether this method rejects unbiased-Q compressors (EF21 family) —
    the ONE place drivers consult to map a generic keep-ratio onto the
    right compressor kind (topk instead of randk). Pinned to the registry
    key set by the conformance harness alongside the other traits."""
    cls = ESTIMATOR_CLASSES.get(name)
    return bool(getattr(cls, "needs_contractive", False))


def streamable(name: str) -> bool:
    """Whether this method's candidates may be computed at dispatch time and
    buffered for asynchronous aggregation (repro.serve). Fails CLOSED like
    ``seed_batchable``: unknown names answer False, so a new estimator joins
    the streaming service only by declaring ``streamable = True``."""
    cls = ESTIMATOR_CLASSES.get(name)
    return False if cls is None else bool(getattr(cls, "streamable", False))


def seed_batchable(name: str) -> bool:
    """Whether same-signature cells of this method may run as one
    vmapped-over-seeds trajectory (exec/batching). Estimators with
    per-worker tables (SAGA) opt out via ``seed_batchable = False``.

    Unknown names answer False — batching is an optimization, so the
    classifier fails CLOSED: a method registered in ``ESTIMATORS`` but
    missing from ``ESTIMATOR_CLASSES`` runs serially (correct, slower)
    instead of vmapping state the author never vetted for a seed axis.
    The conformance harness pins the two registries to the same key set,
    so the miss also fails loudly in CI.
    """
    cls = ESTIMATOR_CLASSES.get(name)
    return False if cls is None else bool(getattr(cls, "seed_batchable",
                                                  True))


def get_estimator(name: str, cfg, **kw) -> GradientEstimator:
    if name not in ESTIMATORS:
        raise KeyError(f"unknown method {name!r}; known: {sorted(ESTIMATORS)}")
    return ESTIMATORS[name](cfg, **kw)
