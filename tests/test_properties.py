"""Property-based tests (hypothesis) on the system's invariants.

hypothesis is a dev-only dependency (requirements-dev.txt); the module is
skipped — not a collection error — when it is absent.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")
from hypothesis import assume, given, settings, strategies as st  # noqa: E402

from repro.core.aggregators import bucketize, coord_median, get_aggregator
from repro.core.compressors import rand_k
from repro.kernels import ref

KEY = jax.random.PRNGKey(0)

arrays = st.integers(min_value=0, max_value=10_000)


@settings(max_examples=25, deadline=None)
@given(seed=arrays, n=st.integers(3, 24), d=st.integers(1, 50))
def test_median_permutation_invariant(seed, n, d):
    """Byz-VR-MARINA is permutation-invariant (App. E.3 discussion)."""
    k = jax.random.PRNGKey(seed)
    x = jax.random.normal(k, (n, d))
    perm = jax.random.permutation(jax.random.fold_in(k, 1), n)
    np.testing.assert_allclose(np.asarray(coord_median(x)),
                               np.asarray(coord_median(x[perm])), atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(seed=arrays, n=st.integers(2, 20), s=st.integers(2, 4))
def test_bucketize_row_count(seed, n, s):
    k = jax.random.PRNGKey(seed)
    x = jax.random.normal(k, (n, 5))
    b = bucketize(k, x, s)
    assert b.shape[0] == -(-n // s)


@settings(max_examples=20, deadline=None)
@given(seed=arrays, ratio=st.sampled_from([0.1, 0.25, 0.5]),
       d=st.integers(8, 200))
def test_randk_support_and_scale(seed, ratio, d):
    """Exactly K nonzeros; kept coordinates scaled by exactly d/K."""
    k = jax.random.PRNGKey(seed)
    x = jax.random.normal(k, (d,)) + 0.1  # keep away from exact zeros
    q = rand_k(ratio).compress(k, x)
    kk = max(int(ratio * d), 1)
    nz = np.flatnonzero(np.asarray(q))
    assert len(nz) == kk
    np.testing.assert_allclose(np.asarray(q)[nz],
                               np.asarray(x)[nz] * (d / kk), rtol=1e-5)


@settings(max_examples=15, deadline=None)
@given(seed=arrays, rule=st.sampled_from(["cm", "tm", "mean"]),
       shift=st.floats(-5, 5))
def test_aggregator_translation_equivariance(seed, rule, shift):
    k = jax.random.PRNGKey(seed)
    x = jax.random.normal(k, (8, 6))
    agg = get_aggregator(rule)
    a = agg(k, x + shift)
    b = agg(k, x) + shift
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(seed=arrays, scale=st.floats(0.1, 10.0))
def test_aggregator_scale_equivariance(seed, scale):
    """Positive scaling commutes with coordinate-wise robust rules."""
    k = jax.random.PRNGKey(seed)
    x = jax.random.normal(k, (9, 4))
    agg = get_aggregator("cm", bucket_size=3)
    np.testing.assert_allclose(np.asarray(agg(k, x * scale)),
                               np.asarray(agg(k, x)) * scale, rtol=1e-4,
                               atol=1e-6)


@settings(max_examples=15, deadline=None)
@given(seed=arrays, n=st.integers(4, 16), d=st.integers(10, 300))
def test_kernel_oracle_equivalence_property(seed, n, d):
    """robust_agg kernel == oracle on arbitrary shapes (interpret mode)."""
    from repro.kernels.robust_agg import robust_agg
    k = jax.random.PRNGKey(seed)
    x = jax.random.normal(k, (n, d))
    got = robust_agg(x, rule="median", tile_d=128, interpret=True)
    want = ref.robust_agg_ref(x, rule="median")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(seed=arrays)
def test_median_breakdown_resilience(seed):
    """With < n/2 arbitrary outliers, CM stays within the good range."""
    k = jax.random.PRNGKey(seed)
    good = jax.random.uniform(k, (7, 5), minval=-1, maxval=1)
    bad = 1e6 * jnp.ones((3, 5))
    z = coord_median(jnp.concatenate([good, bad]))
    assert float(jnp.max(jnp.abs(z))) <= 1.0 + 1e-6


@settings(max_examples=12, deadline=None)
@given(seed=arrays, rule=st.sampled_from(["mean", "cm", "tm", "rfa", "krum"]),
       mode=st.sampled_from(["gspmd", "pallas"]),
       bucket=st.sampled_from([0, 2, 3]),
       n=st.integers(6, 14), d=st.integers(3, 70),
       n_byz=st.integers(0, 2), n_faulty=st.integers(1, 3))
def test_guarded_aggregate_finite_within_budget(seed, rule, mode, bucket, n,
                                                d, n_byz, n_faulty):
    """Fault-guard degradation property (DESIGN.md §6): whenever the finite
    candidates satisfy 2·(n_byz + n_faulty) < n, the masked aggregate is
    finite on EVERY rule x backend — across bucket sizes and
    non-tile-multiple d, with the faulty rows NaN/inf and the byzantine
    rows finite-but-huge (the guard's job vs the aggregator's job)."""
    assume(2 * (n_byz + n_faulty) < n)
    from repro.core.byz_vr_marina import ByzVRMarinaConfig
    from repro.core.sharded_agg import tree_aggregate_pallas
    k = jax.random.PRNGKey(seed)
    x = jax.random.normal(k, (n, d))
    x = x.at[:n_byz].mul(1e6)                       # statistical adversary
    fill = jnp.where(jnp.arange(n_faulty)[:, None] % 2 == 0, jnp.nan,
                     jnp.inf)
    x = x.at[n - n_faulty:].set(fill)               # structural faults
    valid = jnp.arange(n) < n - n_faulty
    agg = get_aggregator(rule, bucket_size=bucket, n_byz=max(n_byz, 1))
    if mode == "gspmd":
        z = agg.tree_masked(k, {"g": x}, valid)["g"]
    else:
        cfg = ByzVRMarinaConfig(n_workers=n, n_byz=n_byz, agg_mode="pallas",
                                aggregator=agg)
        z = tree_aggregate_pallas(cfg, k, {"g": x}, valid=valid)["g"]
    assert np.isfinite(np.asarray(z)).all()
