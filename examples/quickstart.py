"""Quickstart: Byzantine-robust training in ~30 lines (paper Fig. 1 setup).

Four good workers + one Byzantine running the ALIE attack on ℓ2-regularized
logistic regression. The whole experiment is ONE declarative ``RunSpec``:
Byz-VR-MARINA with CM∘bucketing converges linearly to the optimum; try
--agg mean to watch plain averaging get poisoned, or --method
sgdm/csgd/diana/mvr/svrg to race any baseline estimator through the same
round engine.

  PYTHONPATH=src python examples/quickstart.py [--attack ALIE] [--agg cm]
"""
import argparse
import sys

sys.path.insert(0, "src")

from repro.api import RunSpec, build, components
from repro.core.estimators import needs_contractive_compressor
from repro.data import logreg_reference

ap = argparse.ArgumentParser()
ap.add_argument("--method", default="marina", choices=components("method"))
ap.add_argument("--attack", default="ALIE", choices=components("attack"))
ap.add_argument("--agg", default="cm", choices=components("aggregator"))
ap.add_argument("--randk", type=float, default=0.1,
                help="keep-ratio (1.0 = no compression); EF21-family "
                     "methods get TopK at the same ratio, others RandK")
ap.add_argument("--iters", type=int, default=600)
args = ap.parse_args()

# EF21-family methods reject unbiased Q — map the ratio onto TopK for them
_sparsifier = ("topk" if needs_contractive_compressor(args.method)
               else "randk")
spec = RunSpec(
    task="logreg", method=args.method, n_workers=5, n_byz=1,
    p=0.1, lr=0.5, attack=args.attack,
    aggregator=args.agg, bucket_size=0 if args.agg == "mean" else 2,
    compressor=_sparsifier if args.randk < 1 else "identity",
    compressor_kwargs={"ratio": args.randk} if args.randk < 1 else {},
    steps=args.iters,
    data_kwargs={"n_samples": 500, "dim": 30})

exp = build(spec)

# reference optimum f* (exact GD on the pooled data)
full = {"x": exp.data.features, "y": exp.data.labels}
_, f_star = logreg_reference(exp.loss_fn, full, iters=3000)

print(f"method={spec.method} attack={spec.attack} "
      f"aggregator={exp.cfg.aggregator.name} "
      f"compressor={exp.cfg.compressor.name}  f*={f_star:.6f}")


def report(it, state, m):
    gap = float(exp.loss_fn(state["params"], full)) - f_star
    print(f"  round {it+1:4d}  f(x)-f* = {gap:.3e}")


result = exp.run(log_every=args.iters, callback=report, callback_every=100)
final_gap = float(exp.loss_fn(result.params, full)) - f_star
print("done — linear convergence to f* despite the Byzantine worker"
      if final_gap < 1e-4 else
      "done — did NOT reach f* (expected for --agg mean under attack)")
