"""repro.exec — batched, resumable sweep execution (DESIGN.md §1.6).

The paper's evidence is grids; this subsystem owns running them at scale:

* ``batching``  — group cells by jit signature (spec minus seed) and run
                  each group as ONE vmapped-over-seeds jitted trajectory.
* ``scheduler`` — ``run_cells``: the orchestrator (vmapped groups
                  in-process, un-batchable cells optionally sharded over a
                  pinned subprocess ``WorkerPool``, failure isolation).
* ``ledger``    — crash-safe append-only JSONL journal giving
                  ``resume=True`` (skip done, re-run failed) + provenance.
* ``aggregate`` — fold per-cell artifacts into mean±std-over-seeds
                  summary tables (``experiments/bench/*_summary.json``).
* ``worker``    — the ``python -m repro.exec.worker`` subprocess entry.

CLI: ``python -m repro.launch.sweep`` (see README "Running paper grids").
``api.sweep.run_sweep`` routes every sweep through this engine.
"""
from repro.exec.aggregate import (  # noqa: F401
    load_artifacts, summarize, summarize_dir, write_summary,
)
from repro.exec.batching import (  # noqa: F401
    can_batch, group_cells, group_key, run_group,
)
from repro.exec.ledger import Ledger, device_kind, git_sha  # noqa: F401
from repro.exec.scheduler import (  # noqa: F401
    CompletedCell, SweepRun, WorkerPool, run_cells,
)
